"""AOT driver: lower the full L2 function matrix to HLO text + manifest.

Run once by ``make artifacts``; Python never executes on the request path.

Interchange format is HLO **text** (not serialized HloModuleProto): jax>=0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``). ``HloModuleProto::from_text_file`` re-parses and
reassigns ids, so text round-trips cleanly — see /opt/xla-example/README.md.

Outputs:
  artifacts/<model_key>__<fn>.hlo.txt   one per (model variant, entry point)
  artifacts/manifest.json               the complete interchange contract

Env:
  CDNL_KERNEL_IMPL=pallas|ref  masked-activation implementation (default
                               pallas; ref is the test-verified oracle)
  CDNL_CONFIGS=key1,key2       lower only a subset of model variants
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import Model, ModelConfig
from .models.layers import kernel_impl

BATCH = 128

# The experiment grid (DESIGN.md §3/§4):
#   synth10    -> 16x16, 10 classes   (CIFAR-10 analog)
#   synth100   -> 16x16, 20 classes   (CIFAR-100 analog)
#   synthtiny  -> 32x32, 20 classes   (TinyImageNet analog)
# Poly (AutoReP) variants exist for the CIFAR-100 analog only, matching the
# paper's AutoReP experiments (Fig. 4).
MODEL_CONFIGS = [
    ModelConfig("resnet", 10, 16),
    ModelConfig("resnet", 20, 16),
    ModelConfig("resnet", 20, 32),
    ModelConfig("wrn", 10, 16),
    ModelConfig("wrn", 20, 16),
    ModelConfig("wrn", 20, 32),
    ModelConfig("resnet", 20, 16, poly=True),
    ModelConfig("wrn", 20, 16, poly=True),
]

FN_NAMES = ["init", "forward", "eval_batch", "train_step", "snl_step", "kd_step"]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def entry_points(model: Model, batch: int):
    yield "init", model.fn_init()
    yield "forward", model.fn_forward(batch)
    yield "eval_batch", model.fn_eval_batch(batch)
    yield "train_step", model.fn_train_step(batch)
    yield "snl_step", model.fn_snl_step(batch)
    yield "kd_step", model.fn_kd_step(batch)


ARG_NAMES = {
    "init": ["seed"],
    "forward": ["params", "masks", "x"],
    "eval_batch": ["params", "masks", "x", "y"],
    "train_step": ["params", "mom", "masks", "x", "y", "lr"],
    "snl_step": ["params", "mom", "alphas", "x", "y", "lr", "alr", "lam"],
    "kd_step": ["params", "mom", "masks", "x", "y", "t_logits", "lr", "temp"],
}

OUT_NAMES = {
    "init": ["params"],
    "forward": ["logits"],
    "eval_batch": ["loss", "correct"],
    "train_step": ["params", "mom", "loss", "correct"],
    "snl_step": ["params", "mom", "alphas", "loss"],
    "kd_step": ["params", "mom", "loss"],
}


def spec_json(name: str, s) -> dict:
    return {"name": name, "shape": list(s.shape), "dtype": str(s.dtype)}


def lower_model(cfg: ModelConfig, out_dir: str, batch: int) -> dict:
    model = Model(cfg)
    record = {
        "key": cfg.key,
        "backbone": cfg.backbone,
        "num_classes": cfg.num_classes,
        "image_size": cfg.image_size,
        "channels": cfg.channels,
        "poly": cfg.poly,
        "param_size": model.pspec.total,
        "mask_size": model.mspec.total,
        "mask_layers": model.mspec.to_json(),
        "param_entries": model.pspec.to_json(),
        "artifacts": {},
    }
    for fn_name, (fn, arg_specs) in entry_points(model, batch):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{cfg.key}__{fn_name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *arg_specs)
        record["artifacts"][fn_name] = {
            "file": fname,
            "inputs": [
                spec_json(n, s) for n, s in zip(ARG_NAMES[fn_name], arg_specs)
            ],
            "outputs": [
                spec_json(n, s) for n, s in zip(OUT_NAMES[fn_name], outs)
            ],
        }
        print(
            f"  {cfg.key}:{fn_name}  {len(text)/1e6:.2f} MB  {time.time()-t0:.1f}s",
            flush=True,
        )
    return record


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=BATCH)
    ap.add_argument("--configs", default=os.environ.get("CDNL_CONFIGS", ""))
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    wanted = [c for c in args.configs.split(",") if c]
    configs = [c for c in MODEL_CONFIGS if not wanted or c.key in wanted]
    if not configs:
        print(f"no configs match {wanted!r}", file=sys.stderr)
        sys.exit(1)

    manifest = {
        "format": 1,
        "batch": args.batch,
        "kernel_impl": kernel_impl(),
        "jax_version": jax.__version__,
        "models": {},
    }
    t0 = time.time()
    for cfg in configs:
        print(f"lowering {cfg.key} ...", flush=True)
        manifest["models"][cfg.key] = lower_model(cfg, args.out_dir, args.batch)

    # Partial runs (CDNL_CONFIGS) merge into an existing manifest so
    # `make artifacts` stays incremental-friendly.
    mpath = os.path.join(args.out_dir, "manifest.json")
    if wanted and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        old["models"].update(manifest["models"])
        old["kernel_impl"] = manifest["kernel_impl"]
        manifest = old
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath} ({len(manifest['models'])} models, {time.time()-t0:.0f}s)")


if __name__ == "__main__":
    main()
