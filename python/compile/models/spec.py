"""Flat parameter / mask packing specs — the AOT interchange contract.

The rust coordinator treats model parameters as ONE opaque f32 vector ``[P]``
and ReLU masks as ONE f32 vector ``[M]``. This keeps every artifact at a
handful of inputs/outputs regardless of network depth, and makes the paper's
"pool of present ReLUs" literally the set of indices ``i`` with ``m[i] == 1``.

``ParamSpec`` / ``MaskSpec`` record the (name, shape, offset) layout; the
layout is serialized into ``artifacts/manifest.json`` so rust never
duplicates shape knowledge.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Entry:
    """One named tensor inside a flat pack."""

    name: str
    shape: Tuple[int, ...]
    offset: int

    @property
    def size(self) -> int:
        return int(np.prod(self.shape)) if self.shape else 1


class FlatSpec:
    """Ordered collection of named tensors living inside one flat f32 vector."""

    def __init__(self) -> None:
        self.entries: List[Entry] = []
        self._by_name: Dict[str, Entry] = {}
        self.total = 0

    def add(self, name: str, shape: Sequence[int]) -> Entry:
        if name in self._by_name:
            raise ValueError(f"duplicate entry {name!r}")
        e = Entry(name=name, shape=tuple(int(s) for s in shape), offset=self.total)
        self.entries.append(e)
        self._by_name[name] = e
        self.total += e.size
        return e

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def entry(self, name: str) -> Entry:
        return self._by_name[name]

    def unpack(self, flat: jax.Array, name: str) -> jax.Array:
        """Slice one named tensor out of the flat vector (static offsets)."""
        e = self._by_name[name]
        return jax.lax.slice(flat, (e.offset,), (e.offset + e.size,)).reshape(e.shape)

    def pack(self, tensors: Dict[str, jax.Array]) -> jax.Array:
        """Concatenate named tensors into the flat vector, in spec order."""
        missing = [e.name for e in self.entries if e.name not in tensors]
        if missing:
            raise ValueError(f"missing tensors: {missing}")
        parts = [tensors[e.name].reshape(-1).astype(jnp.float32) for e in self.entries]
        return jnp.concatenate(parts) if parts else jnp.zeros((0,), jnp.float32)

    def to_json(self) -> list:
        return [
            {"name": e.name, "shape": list(e.shape), "offset": e.offset, "size": e.size}
            for e in self.entries
        ]


class ParamSpec(FlatSpec):
    """Learnable parameters (conv/gn/dense weights, poly coefficients)."""


class MaskSpec(FlatSpec):
    """ReLU mask layers; one entry per masked activation, shape [C, H, W].

    The flat offset of a layer is the global index base of its ReLUs — the
    rust coordinator samples/removes ReLUs directly in this index space.
    """

    def add_layer(self, name: str, c: int, h: int, w: int) -> Entry:
        return self.add(name, (c, h, w))

    @property
    def relu_count(self) -> int:
        return self.total
