"""ResNetMini — the ResNet18 backbone at reproduction scale.

Identical topology to ResNet18 (He et al. 2016): a 3x3 stem followed by four
stages of two BasicBlocks, channel doubling + stride-2 at each stage entry,
global average pool and a linear head. Width is scaled to 8 base channels so
the full experiment grid trains in CPU-minutes (DESIGN.md §0); the ReLU
*structure* (17 masked activation layers, early layers dominating the count)
matches the paper's Figure 7 setting.
"""

from __future__ import annotations

import jax.numpy as jnp

from .layers import Builder

# ResNet18 block plan: (blocks per stage, width multiplier).
STAGES = [(2, 1), (2, 2), (2, 4), (2, 8)]
BASE_WIDTH = 8


def basic_block(bld: Builder, x, name: str, cout: int, stride: int):
    """conv-gn-act / conv-gn + projection skip, post-activation ResNet v1."""
    identity = x
    y = bld.conv(f"{name}.conv1", x, cout, 3, stride)
    y = bld.gn(f"{name}.gn1", y)
    y = bld.act(f"{name}.act1", y)
    y = bld.conv(f"{name}.conv2", y, cout, 3, 1)
    y = bld.gn(f"{name}.gn2", y)
    if stride != 1 or x.shape[1] != cout:
        identity = bld.conv(f"{name}.proj", x, cout, 1, stride)
        identity = bld.gn(f"{name}.gnp", identity)
    y = y + identity
    return bld.act(f"{name}.act2", y)


def define(bld: Builder, x, num_classes: int):
    """ResNetMini graph: declares every parameter and masked activation."""
    w = BASE_WIDTH
    y = bld.conv("stem.conv", x, w, 3, 1)
    y = bld.gn("stem.gn", y)
    y = bld.act("stem.act", y)
    for si, (blocks, mult) in enumerate(STAGES):
        cout = w * mult
        for bi in range(blocks):
            stride = 2 if (si > 0 and bi == 0) else 1
            y = basic_block(bld, y, f"s{si}.b{bi}", cout, stride)
    feats = y.mean(axis=(2, 3))
    logits = bld.dense("head", feats, num_classes)
    return logits


def config(num_classes: int):
    """(name, define_fn, num_classes) triple used by the AOT driver."""
    return ("resnet", define, num_classes)
