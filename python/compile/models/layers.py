"""Building blocks for the L2 JAX models (pure functions of flat params).

Design notes:
  * NCHW layout everywhere (matches the rust-side dataset tensors).
  * GroupNorm instead of BatchNorm so the lowered train step is a pure
    function — no mutable batch statistics threaded through the artifact
    boundary (documented substitution, DESIGN.md §0).
  * The masked activation is the L1 Pallas kernel; ``CDNL_KERNEL_IMPL=ref``
    swaps in the numerically-identical pure-jnp oracle for fast CPU sweeps
    (equivalence is enforced by python/tests/test_kernel.py).
"""

from __future__ import annotations

import os
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ref as kref
from ..kernels.masked_relu import masked_relu_nchw
from ..kernels.masked_poly import masked_poly_nchw
from .spec import MaskSpec, ParamSpec

GN_EPS = 1e-5


def kernel_impl() -> str:
    """Which implementation the masked activations lower to: pallas | ref."""
    return os.environ.get("CDNL_KERNEL_IMPL", "pallas")


def masked_activation(x: jax.Array, m: jax.Array) -> jax.Array:
    """m*relu(x) + (1-m)*x via the L1 kernel (or its oracle, see above)."""
    if kernel_impl() == "ref":
        return kref.masked_relu_ref(x, m)
    return masked_relu_nchw(x, m)


def masked_poly_activation(x: jax.Array, m: jax.Array, coefs: jax.Array) -> jax.Array:
    """m*relu(x) + (1-m)*poly(x) via the L1 kernel (or its oracle)."""
    if kernel_impl() == "ref":
        return kref.masked_poly_ref(x, m, coefs)
    return masked_poly_nchw(x, m, coefs)


def conv2d(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """3x3/1x1 'SAME' convolution, NCHW/OIHW."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def group_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, groups: int) -> jax.Array:
    """GroupNorm over (channel-group, H, W); pure function of its inputs."""
    b, c, h, w = x.shape
    g = min(groups, c)
    while c % g != 0:
        g -= 1
    xg = x.reshape(b, g, c // g, h, w)
    mean = xg.mean(axis=(2, 3, 4), keepdims=True)
    var = xg.var(axis=(2, 3, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + GN_EPS)
    xn = xg.reshape(b, c, h, w)
    return xn * scale.reshape(1, c, 1, 1) + bias.reshape(1, c, 1, 1)


def global_avg_pool(x: jax.Array) -> jax.Array:
    """[B, C, H, W] -> [B, C]."""
    return x.mean(axis=(2, 3))


# --------------------------------------------------------------------------
# Spec-driven parameter registry: model builders declare parameters once and
# both `init` and `forward` consume the same declarations.
# --------------------------------------------------------------------------


class Builder:
    """Accumulates parameter/mask declarations while tracing a model graph.

    A model definition is a function ``define(bld, x)`` that calls the
    ``bld.*`` helpers. It is executed twice with identical control flow:
    once in *spec* mode (shapes only, builds ParamSpec/MaskSpec + init
    values) and once in *apply* mode (unpacks the flat vectors and computes).
    """

    def __init__(self, mode: str, params: jax.Array | None = None,
                 masks: jax.Array | None = None, rng: jax.Array | None = None,
                 poly: bool = False):
        assert mode in ("spec", "apply")
        self.mode = mode
        self.pspec = ParamSpec()
        self.mspec = MaskSpec()
        self.params = params
        self.masks = masks
        self.rng = rng
        self.poly = poly
        self.init_values: Dict[str, jax.Array] = {}
        self._mask_meta: List[dict] = []

    # -- parameter declaration -------------------------------------------

    def _param(self, name: str, shape, init_fn: Callable[[jax.Array], jax.Array]) -> jax.Array:
        self.pspec.add(name, shape)
        if self.mode == "spec":
            self.rng, sub = jax.random.split(self.rng)
            v = init_fn(sub)
            self.init_values[name] = v
            return v
        return self.pspec.unpack(self.params, name)

    def conv(self, name: str, x: jax.Array, cout: int, ksize: int = 3,
             stride: int = 1) -> jax.Array:
        cin = x.shape[1]
        fan_in = cin * ksize * ksize

        def init(k):
            # He-normal, the standard ResNet initialization.
            return jax.random.normal(k, (cout, cin, ksize, ksize), jnp.float32) * jnp.sqrt(
                2.0 / fan_in
            )

        w = self._param(f"{name}.w", (cout, cin, ksize, ksize), init)
        return conv2d(x, w, stride)

    def gn(self, name: str, x: jax.Array, groups: int = 4) -> jax.Array:
        c = x.shape[1]
        s = self._param(f"{name}.scale", (c,), lambda k: jnp.ones((c,), jnp.float32))
        b = self._param(f"{name}.bias", (c,), lambda k: jnp.zeros((c,), jnp.float32))
        return group_norm(x, s, b, groups)

    def dense(self, name: str, x: jax.Array, dout: int) -> jax.Array:
        din = x.shape[1]

        def init_w(k):
            return jax.random.normal(k, (din, dout), jnp.float32) * jnp.sqrt(1.0 / din)

        w = self._param(f"{name}.w", (din, dout), init_w)
        b = self._param(f"{name}.b", (dout,), lambda k: jnp.zeros((dout,), jnp.float32))
        return x @ w + b

    # -- masked activations (the linearization surface) -------------------

    def act(self, name: str, x: jax.Array) -> jax.Array:
        """Masked ReLU layer — one entry in the mask vector per location."""
        _, c, h, w = x.shape
        self.mspec.add_layer(name, c, h, w)
        self._mask_meta.append({"name": name, "shape": [int(c), int(h), int(w)]})
        if self.poly:
            coefs = self._param(
                f"{name}.poly",
                (3,),
                # AutoReP-style init: approximately relu-like on small inputs
                # (0.25 x^2 + 0.5 x, the degree-2 Chebyshev-ish fit).
                lambda k: jnp.array([0.25, 0.5, 0.0], jnp.float32),
            )
        if self.mode == "spec":
            # Spec mode only needs shapes; behave like the full-ReLU network.
            return jnp.maximum(x, 0.0)
        m = self.mspec.unpack(self.masks, name)
        if self.poly:
            return masked_poly_activation(x, m, coefs)
        return masked_activation(x, m)
