"""WideResNetMini — the WRN-22-8 backbone at reproduction scale.

WRN-22-8 (Zagoruyko & Komodakis 2016) is three groups of pre-activation
blocks with widening factor 8. We keep the pre-activation structure, three
groups with channel doubling and stride-2 group entries, and a widening
factor of 4 over a base width of 4 — scaled so WRN/ResNet ReLU-count and
runtime ratios are close to the paper's (1359K/570K ≈ 2.4x; see
bench_table1). Depth 2 blocks/group mirrors the 22-layer network's role as
the "bigger, wider" backbone relative to ResNet18.
"""

from __future__ import annotations

import jax.numpy as jnp

from .layers import Builder

BASE_WIDTH = 4
WIDEN = 4
GROUPS = [(2, 1), (2, 2), (2, 4)]  # (blocks, multiplier) per group


def preact_block(bld: Builder, x, name: str, cout: int, stride: int):
    """Pre-activation wide block: gn-act-conv / gn-act-conv + skip."""
    y = bld.gn(f"{name}.gn1", x)
    y = bld.act(f"{name}.act1", y)
    if stride != 1 or x.shape[1] != cout:
        # WRN applies the projection to the pre-activated input.
        identity = bld.conv(f"{name}.proj", y, cout, 1, stride)
    else:
        identity = x
    y = bld.conv(f"{name}.conv1", y, cout, 3, stride)
    y = bld.gn(f"{name}.gn2", y)
    y = bld.act(f"{name}.act2", y)
    y = bld.conv(f"{name}.conv2", y, cout, 3, 1)
    return y + identity


def define(bld: Builder, x, num_classes: int):
    """WideResNetMini graph."""
    w = BASE_WIDTH * WIDEN
    y = bld.conv("stem.conv", x, BASE_WIDTH * 2, 3, 1)
    for gi, (blocks, mult) in enumerate(GROUPS):
        cout = w * mult
        for bi in range(blocks):
            stride = 2 if (gi > 0 and bi == 0) else 1
            y = preact_block(bld, y, f"g{gi}.b{bi}", cout, stride)
    y = bld.gn("final.gn", y)
    y = bld.act("final.act", y)
    feats = y.mean(axis=(2, 3))
    logits = bld.dense("head", feats, num_classes)
    return logits


def config(num_classes: int):
    return ("wrn", define, num_classes)
