"""L1 Pallas kernel: masked polynomial activation (AutoReP-style replacement).

AutoReP (Peng et al. 2023) replaces eliminated ReLUs with a learnable
second-order polynomial instead of the identity:

    y = m * relu(x) + (1 - m) * (a * x^2 + b * x + c)

where (a, b, c) are per-layer learnable coefficients. As with
``masked_relu``, the expression is linear in ``m`` so the same kernel serves
both hard (binary) masks and soft indicator values during selective training.

Tiling/layout is identical to masked_relu (see that module and DESIGN.md
§Hardware-Adaptation); the polynomial coefficients ride along as a tiny
``[1, LANE]`` block (first three lanes used) fetched once per tile — on a
real TPU this is an SMEM scalar prefetch, here expressed as a VMEM row so the
interpret path stays faithful to the block structure.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .masked_relu import LANE, DEFAULT_BLOCK_B, DEFAULT_BLOCK_N, _pad_to


def _masked_poly_kernel(x_ref, m_ref, coef_ref, o_ref):
    """out = m*relu(x) + (1-m)*(a*x^2 + b*x + c) over one VMEM tile."""
    x = x_ref[...]
    m = m_ref[...]
    a = coef_ref[0, 0]
    b = coef_ref[0, 1]
    c = coef_ref[0, 2]
    poly = (a * x + b) * x + c
    o_ref[...] = m * jnp.maximum(x, 0.0) + (1.0 - m) * poly


@functools.partial(jax.jit, static_argnames=("block_b", "block_n"))
def masked_poly_2d(
    x: jax.Array,
    m: jax.Array,
    coefs: jax.Array,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_n: int = DEFAULT_BLOCK_N,
) -> jax.Array:
    """Masked quadratic activation over a flattened activation tensor.

    Args:
      x: ``[B, N]`` activations.
      m: ``[N]`` mask row (binary or soft), broadcast over batch.
      coefs: ``[3]`` polynomial coefficients ``(a, b, c)``.

    Returns:
      ``[B, N]`` activations.
    """
    if x.ndim != 2:
        raise ValueError(f"masked_poly_2d expects [B, N], got {x.shape}")
    if m.shape != (x.shape[1],):
        raise ValueError(f"mask shape {m.shape} != ({x.shape[1]},)")
    if coefs.shape != (3,):
        raise ValueError(f"coefs shape {coefs.shape} != (3,)")
    b, n = x.shape
    block_n = max(LANE, min(block_n, _pad_to(n, LANE)))
    block_b = max(1, min(block_b, b))

    pb, pn = _pad_to(b, block_b), _pad_to(n, block_n)
    xp = jnp.pad(x, ((0, pb - b), (0, pn - n)))
    mp = jnp.pad(m.astype(x.dtype), (0, pn - n)).reshape(1, pn)
    cp = jnp.pad(coefs.astype(x.dtype), (0, LANE - 3)).reshape(1, LANE)

    grid = (pb // block_b, pn // block_n)
    out = pl.pallas_call(
        _masked_poly_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((1, LANE), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pb, pn), x.dtype),
        interpret=True,
    )(xp, mp, cp)
    return out[:b, :n]


# Analytic custom VJP (pallas_call has no registered VJP in interpret mode):
#   dy/dx = m * 1[x>0] + (1-m) * (2a x + b)
#   dy/dm = relu(x) - poly(x)                       (batch-summed)
#   dy/da = sum (1-m) x^2 ; dy/db = sum (1-m) x ; dy/dc = sum (1-m)
@jax.custom_vjp
def _masked_poly_vjp(x, m, coefs):
    return masked_poly_2d(x, m, coefs)


def _masked_poly_fwd(x, m, coefs):
    return masked_poly_2d(x, m, coefs), (x, m, coefs)


def _masked_poly_bwd(res, g):
    x, m, coefs = res
    a, b, c = coefs[0], coefs[1], coefs[2]
    mm = m[None, :]
    relu_grad = (x > 0).astype(x.dtype)
    dx = g * (mm * relu_grad + (1.0 - mm) * (2.0 * a * x + b))
    poly = (a * x + b) * x + c
    dm = jnp.sum(g * (jnp.maximum(x, 0.0) - poly), axis=0)
    gnm = g * (1.0 - mm)
    da = jnp.sum(gnm * x * x)
    db = jnp.sum(gnm * x)
    dc = jnp.sum(gnm)
    return dx, dm, jnp.stack([da, db, dc])


_masked_poly_vjp.defvjp(_masked_poly_fwd, _masked_poly_bwd)


def masked_poly_nchw(x: jax.Array, m: jax.Array, coefs: jax.Array) -> jax.Array:
    """[B, C, H, W] wrapper with a [C, H, W] mask; see masked_poly_2d."""
    b = x.shape[0]
    n = x.shape[1] * x.shape[2] * x.shape[3]
    y = _masked_poly_vjp(x.reshape(b, n), m.reshape(n), coefs)
    return y.reshape(x.shape)
