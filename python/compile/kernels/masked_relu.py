"""L1 Pallas kernel: masked (partial) ReLU — the Network-Linearization activation.

The paper replaces a subset of ReLUs with identity functions, keyed by a
binary mask ``m`` over neuron locations:

    y = m * relu(x) + (1 - m) * x

The same kernel also serves SNL's *soft* masks (continuous alpha in [0, 1]),
since the expression is linear in ``m``.

TPU mapping (see DESIGN.md §Hardware-Adaptation): activations are flattened
to ``[B, N]`` (N = C*H*W) and padded to the 128-lane VPU width; the mask row
``[1, N]`` broadcasts across the batch (sublane) dimension. The kernel is
bandwidth-bound (no MXU work) so the BlockSpec is chosen to stream
HBM -> VMEM with lane-aligned tiles. On CPU we must run ``interpret=True``:
real-TPU lowering emits a Mosaic custom-call the CPU PJRT plugin cannot
execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Lane width of the TPU VPU; the last block dimension must be a multiple of
# this for efficient vector loads. We keep the same alignment in interpret
# mode so the lowered structure matches what a real TPU would execute.
LANE = 128

# Default tile: 8 sublanes x 512 lanes = 16 KiB of f32 per x-tile, well under
# the ~16 MiB VMEM budget even with double buffering (see DESIGN.md §7).
DEFAULT_BLOCK_B = 8
DEFAULT_BLOCK_N = 512


def _masked_relu_kernel(x_ref, m_ref, o_ref):
    """out = m * relu(x) + (1 - m) * x, elementwise over one VMEM tile."""
    x = x_ref[...]
    m = m_ref[...]
    o_ref[...] = m * jnp.maximum(x, 0.0) + (1.0 - m) * x


def _pad_to(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult


@functools.partial(jax.jit, static_argnames=("block_b", "block_n"))
def masked_relu_2d(
    x: jax.Array,
    m: jax.Array,
    *,
    block_b: int = DEFAULT_BLOCK_B,
    block_n: int = DEFAULT_BLOCK_N,
) -> jax.Array:
    """Masked ReLU over a flattened activation tensor.

    Args:
      x: ``[B, N]`` activations (any float dtype).
      m: ``[N]`` mask row, broadcast over the batch dimension. Binary for
         linearization, continuous in [0, 1] for SNL-style soft masks.
      block_b / block_n: VMEM tile shape; ``block_n`` must be lane-aligned.

    Returns:
      ``[B, N]`` with the masked activation applied.
    """
    if x.ndim != 2:
        raise ValueError(f"masked_relu_2d expects [B, N], got {x.shape}")
    if m.shape != (x.shape[1],):
        raise ValueError(f"mask shape {m.shape} != ({x.shape[1]},)")
    b, n = x.shape
    block_n = max(LANE, min(block_n, _pad_to(n, LANE)))
    block_b = max(1, min(block_b, b))

    pb, pn = _pad_to(b, block_b), _pad_to(n, block_n)
    xp = jnp.pad(x, ((0, pb - b), (0, pn - n)))
    mp = jnp.pad(m.astype(x.dtype), (0, pn - n)).reshape(1, pn)

    grid = (pb // block_b, pn // block_n)
    out = pl.pallas_call(
        _masked_relu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
            # The mask row is re-fetched per batch tile; index_map pins the
            # sublane block to row 0 so every batch tile sees the same mask.
            pl.BlockSpec((1, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pb, pn), x.dtype),
        interpret=True,
    )(xp, mp)
    return out[:b, :n]


# ``pallas_call`` has no registered VJP in interpret mode, so the masked
# activation carries an analytic custom_vjp:
#   dy/dx = m * 1[x>0] + (1 - m)         (elementwise)
#   dy/dm = relu(x) - x                  (summed over the batch axis)
# The mask cotangent matters: SNL trains soft alphas through this exact op.
@jax.custom_vjp
def _masked_relu_vjp(x: jax.Array, m: jax.Array) -> jax.Array:
    return masked_relu_2d(x, m)


def _masked_relu_fwd(x, m):
    return masked_relu_2d(x, m), (x, m)


def _masked_relu_bwd(res, g):
    x, m = res
    relu_grad = (x > 0).astype(x.dtype)
    dx = g * (m[None, :] * relu_grad + (1.0 - m[None, :]))
    dm = jnp.sum(g * (jnp.maximum(x, 0.0) - x), axis=0)
    return dx, dm


_masked_relu_vjp.defvjp(_masked_relu_fwd, _masked_relu_bwd)


def masked_relu_nchw(x: jax.Array, m: jax.Array) -> jax.Array:
    """Masked ReLU for ``[B, C, H, W]`` activations with a ``[C, H, W]`` mask.

    Flattens the neuron dimensions to the lane axis and defers to
    :func:`masked_relu_2d` (differentiable via the analytic custom VJP).
    """
    b = x.shape[0]
    n = x.shape[1] * x.shape[2] * x.shape[3]
    y = _masked_relu_vjp(x.reshape(b, n), m.reshape(n))
    return y.reshape(x.shape)


def vmem_bytes(block_b: int = DEFAULT_BLOCK_B, block_n: int = DEFAULT_BLOCK_N,
               dtype_bytes: int = 4, double_buffered: bool = True) -> int:
    """Estimated VMEM footprint of one kernel instance (for DESIGN §Perf).

    x tile + mask row + out tile, times 2 when the Pallas pipeline
    double-buffers the HBM->VMEM stream.
    """
    tiles = (block_b + 1 + block_b) * block_n * dtype_bytes
    return tiles * (2 if double_buffered else 1)
