"""Pure-jnp oracles for the Pallas kernels — the CORE correctness signal.

Every kernel in this package must agree with its oracle to float tolerance;
``python/tests/test_kernel.py`` sweeps shapes/dtypes with hypothesis. The
oracles are also selectable as the lowering implementation via
``CDNL_KERNEL_IMPL=ref`` in aot.py (numerically identical by these tests;
used for fast CPU experiment sweeps — see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def masked_relu_ref(x: jax.Array, m: jax.Array) -> jax.Array:
    """y = m * relu(x) + (1 - m) * x with ``m`` broadcast over batch.

    Args:
      x: ``[B, N]`` or ``[B, C, H, W]`` activations.
      m: ``[N]`` or ``[C, H, W]`` mask (binary or soft).
    """
    m = m.astype(x.dtype)
    return m * jnp.maximum(x, 0.0) + (1.0 - m) * x


def masked_poly_ref(x: jax.Array, m: jax.Array, coefs: jax.Array) -> jax.Array:
    """y = m * relu(x) + (1 - m) * (a x^2 + b x + c), ``m`` broadcast over batch."""
    m = m.astype(x.dtype)
    a, b, c = coefs[0], coefs[1], coefs[2]
    poly = (a * x + b) * x + c
    return m * jnp.maximum(x, 0.0) + (1.0 - m) * poly
