"""L2 facade: assemble (backbone x dataset x replacement) into AOT-able fns.

Every function here closes over static shape information and takes/returns
ONLY flat tensors — the interchange contract with the rust coordinator
(see models/spec.py). The functions are lowered once by aot.py.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from .models import resnet, wideresnet
from .models.layers import Builder
from .models.spec import MaskSpec, ParamSpec

BACKBONES = {"resnet": resnet.define, "wrn": wideresnet.define}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static configuration of one compiled model variant."""

    backbone: str  # "resnet" | "wrn"
    num_classes: int
    image_size: int  # H == W
    channels: int = 3
    poly: bool = False  # AutoReP-style quadratic replacement

    @property
    def key(self) -> str:
        p = "_poly" if self.poly else ""
        return f"{self.backbone}_{self.image_size}x{self.image_size}_c{self.num_classes}{p}"

    def input_shape(self, batch: int) -> Tuple[int, int, int, int]:
        return (batch, self.channels, self.image_size, self.image_size)


class Model:
    """Specs + pure functions for one ModelConfig."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        define = BACKBONES[cfg.backbone]
        # Spec pass: fixed probe batch of 2 (shapes don't depend on batch).
        bld = Builder("spec", rng=jax.random.PRNGKey(0), poly=cfg.poly)
        x_probe = jnp.zeros(cfg.input_shape(2), jnp.float32)
        define(bld, x_probe, cfg.num_classes)
        self.pspec: ParamSpec = bld.pspec
        self.mspec: MaskSpec = bld.mspec
        self._define = define

    # -- core pure functions ------------------------------------------------

    def init(self, seed: jax.Array) -> jax.Array:
        """(seed i32) -> flat params [P]. Deterministic in the seed."""
        bld = Builder("spec", rng=jax.random.PRNGKey(seed), poly=self.cfg.poly)
        x_probe = jnp.zeros(self.cfg.input_shape(2), jnp.float32)
        self._define(bld, x_probe, self.cfg.num_classes)
        return bld.pspec.pack(bld.init_values)

    def forward(self, params: jax.Array, masks: jax.Array, x: jax.Array) -> jax.Array:
        """(params [P], masks [M], x [B,C,H,W]) -> logits [B,K]."""
        bld = Builder("apply", params=params, masks=masks, poly=self.cfg.poly)
        return self._define(bld, x, self.cfg.num_classes)

    # -- AOT entry points (each becomes one artifact) -------------------------

    def fn_init(self):
        def init(seed):
            return (self.init(seed[0]),)

        return init, (jax.ShapeDtypeStruct((1,), jnp.int32),)

    def fn_forward(self, batch: int):
        def forward(params, masks, x):
            return (self.forward(params, masks, x),)

        return forward, (
            jax.ShapeDtypeStruct((self.pspec.total,), jnp.float32),
            jax.ShapeDtypeStruct((self.mspec.total,), jnp.float32),
            jax.ShapeDtypeStruct(self.cfg.input_shape(batch), jnp.float32),
        )

    def fn_eval_batch(self, batch: int):
        """(params, masks, x, y) -> (loss, correct). The BCD trial hot path."""

        def eval_batch(params, masks, x, y):
            logits = self.forward(params, masks, x)
            loss = _ce_loss(logits, y, self.cfg.num_classes)
            correct = jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
            return (loss, correct)

        return eval_batch, (
            jax.ShapeDtypeStruct((self.pspec.total,), jnp.float32),
            jax.ShapeDtypeStruct((self.mspec.total,), jnp.float32),
            jax.ShapeDtypeStruct(self.cfg.input_shape(batch), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
        )

    def fn_train_step(self, batch: int):
        """SGD-with-momentum step.

        (params, mom, masks, x, y, lr) -> (params', mom', loss, correct)
        LR arrives as a scalar input so the rust coordinator owns the
        cosine-annealing schedule (L3 controls, L2 computes).
        """

        def train_step(params, mom, masks, x, y, lr):
            def loss_fn(p):
                logits = self.forward(p, masks, x)
                return _ce_loss(logits, y, self.cfg.num_classes), logits

            (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
            mom2 = 0.9 * mom + grads
            params2 = params - lr[0] * mom2
            correct = jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
            return (params2, mom2, loss, correct)

        p = self.pspec.total
        return train_step, (
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((self.mspec.total,), jnp.float32),
            jax.ShapeDtypeStruct(self.cfg.input_shape(batch), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        )

    def fn_snl_step(self, batch: int):
        """Selective (SNL) step: trains weights AND soft alpha masks.

        (params, mom, alphas, x, y, lr, alr, lam)
            -> (params', mom', alphas', loss)
        loss = CE + lam * ||alpha||_1 ; alphas are projected back to [0, 1]
        (projected SGD). The lasso coefficient lam is an input so the rust
        side owns the lambda <- kappa * lambda schedule (paper Fig. 9/10).
        `alr` is a separate alpha learning rate: at our compressed step
        budget (hundreds of steps vs the paper's 100K+) alphas need a much
        larger step than weights for the CE gradient to differentiate which
        ReLUs matter before the lasso pressure sweeps everything across the
        threshold (DESIGN.md §0).
        """

        def snl_step(params, mom, alphas, x, y, lr, alr, lam):
            def loss_fn(p, a):
                logits = self.forward(p, a, x)
                ce = _ce_loss(logits, y, self.cfg.num_classes)
                return ce + lam[0] * jnp.sum(jnp.abs(a)), ce

            (_, ce), grads = jax.value_and_grad(loss_fn, argnums=(0, 1), has_aux=True)(
                params, alphas
            )
            gp, ga = grads
            mom2 = 0.9 * mom + gp
            params2 = params - lr[0] * mom2
            alphas2 = jnp.clip(alphas - alr[0] * ga, 0.0, 1.0)
            return (params2, mom2, alphas2, ce)

        p = self.pspec.total
        return snl_step, (
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((self.mspec.total,), jnp.float32),
            jax.ShapeDtypeStruct(self.cfg.input_shape(batch), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        )

    def fn_kd_step(self, batch: int):
        """Knowledge-distillation step (SENet finetune).

        (params, mom, masks, x, y, t_logits, lr, temp) -> (params', mom', loss)
        loss = 0.5*CE + 0.5*T^2*KL(teacher || student). Teacher logits are an
        input: the rust coordinator computes them once per batch with the
        full-ReLU model (PRAM activation matching is substituted by logit
        distillation — DESIGN.md §0).
        """

        def kd_step(params, mom, masks, x, y, t_logits, lr, temp):
            def loss_fn(p):
                logits = self.forward(p, masks, x)
                ce = _ce_loss(logits, y, self.cfg.num_classes)
                t = temp[0]
                ps = jax.nn.log_softmax(logits / t, axis=1)
                pt = jax.nn.softmax(t_logits / t, axis=1)
                kl = jnp.mean(jnp.sum(pt * (jnp.log(pt + 1e-9) - ps), axis=1))
                return 0.5 * ce + 0.5 * t * t * kl

            loss, grads = jax.value_and_grad(loss_fn)(params)
            mom2 = 0.9 * mom + grads
            params2 = params - lr[0] * mom2
            return (params2, mom2, loss)

        p = self.pspec.total
        k = self.cfg.num_classes
        return kd_step, (
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((p,), jnp.float32),
            jax.ShapeDtypeStruct((self.mspec.total,), jnp.float32),
            jax.ShapeDtypeStruct(self.cfg.input_shape(batch), jnp.float32),
            jax.ShapeDtypeStruct((batch,), jnp.int32),
            jax.ShapeDtypeStruct((batch, k), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        )


def _ce_loss(logits: jax.Array, y: jax.Array, num_classes: int) -> jax.Array:
    logp = jax.nn.log_softmax(logits, axis=1)
    onehot = jax.nn.one_hot(y, num_classes, dtype=logits.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=1))
