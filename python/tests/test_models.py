"""L2 correctness: model assembly, shapes, determinism, and that every
compiled entry point's math behaves (losses decrease, masks clip, KD pulls
toward the teacher).

All tests run on a tiny probe batch — they exercise the exact functions
aot.py lowers, just jitted in-process instead of via PJRT.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import Model, ModelConfig

BATCH = 8


@pytest.fixture(scope="module", params=["resnet", "wrn"])
def model(request):
    return Model(ModelConfig(request.param, num_classes=4, image_size=8))


@pytest.fixture(scope="module")
def poly_model():
    return Model(ModelConfig("resnet", num_classes=4, image_size=8, poly=True))


def batch_for(model, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, model.cfg.input_shape(BATCH))
    y = jax.random.randint(k2, (BATCH,), 0, model.cfg.num_classes)
    return x, y


def test_init_shapes_and_determinism(model):
    init, specs = model.fn_init()
    p1 = init(jnp.array([3], jnp.int32))[0]
    p2 = init(jnp.array([3], jnp.int32))[0]
    p3 = init(jnp.array([4], jnp.int32))[0]
    assert p1.shape == (model.pspec.total,)
    np.testing.assert_array_equal(p1, p2)
    assert not np.allclose(p1, p3), "different seeds must differ"
    assert np.isfinite(np.asarray(p1)).all()


def test_forward_shape_and_finite(model):
    fwd, _ = model.fn_forward(BATCH)
    params = model.init(jnp.array(0))
    masks = jnp.ones((model.mspec.total,))
    x, _ = batch_for(model)
    (logits,) = fwd(params, masks, x)
    assert logits.shape == (BATCH, model.cfg.num_classes)
    assert np.isfinite(np.asarray(logits)).all()


def test_full_vs_zero_mask_differ(model):
    """Linearizing everything must actually change the network output."""
    params = model.init(jnp.array(0))
    x, _ = batch_for(model)
    full = model.forward(params, jnp.ones((model.mspec.total,)), x)
    lin = model.forward(params, jnp.zeros((model.mspec.total,)), x)
    assert not np.allclose(full, lin)


def test_train_step_decreases_loss(model):
    step, _ = model.fn_train_step(BATCH)
    params = model.init(jnp.array(1))
    mom = jnp.zeros_like(params)
    masks = jnp.ones((model.mspec.total,))
    x, y = batch_for(model, seed=1)
    lr = jnp.array([5e-3], jnp.float32)
    jstep = jax.jit(step)
    losses = []
    for _ in range(8):
        params, mom, loss, correct = jstep(params, mom, masks, x, y, lr)
        losses.append(float(loss))
        assert 0.0 <= float(correct) <= BATCH
    assert losses[-1] < losses[0], f"loss did not decrease: {losses}"


def test_train_step_respects_mask_gradients(model):
    """With the full mask vs half mask, updates must differ — the mask is
    part of the differentiated graph, not a post-hoc filter."""
    step = jax.jit(model.fn_train_step(BATCH)[0])
    params = model.init(jnp.array(2))
    mom = jnp.zeros_like(params)
    x, y = batch_for(model, seed=2)
    lr = jnp.array([1e-3], jnp.float32)
    full = jnp.ones((model.mspec.total,))
    half = full.at[: model.mspec.total // 2].set(0.0)
    p_full, *_ = step(params, mom, full, x, y, lr)
    p_half, *_ = step(params, mom, half, x, y, lr)
    assert not np.allclose(p_full, p_half)


def test_snl_step_trains_and_clips(model):
    snl = jax.jit(model.fn_snl_step(BATCH)[0])
    params = model.init(jnp.array(3))
    mom = jnp.zeros_like(params)
    alphas = jnp.ones((model.mspec.total,))
    x, y = batch_for(model, seed=3)
    lr = jnp.array([1e-2], jnp.float32)
    alr = jnp.array([1.0], jnp.float32)
    lam = jnp.array([1e-3], jnp.float32)
    a_l1 = [float(jnp.sum(alphas))]
    for _ in range(5):
        params, mom, alphas, loss = snl(params, mom, alphas, x, y, lr, alr, lam)
        a = np.asarray(alphas)
        assert (a >= 0.0).all() and (a <= 1.0).all(), "projection violated"
        a_l1.append(float(jnp.sum(alphas)))
    assert a_l1[-1] < a_l1[0], "lasso did not shrink the alphas"


def test_snl_lambda_zero_keeps_alphas_higher(model):
    """Higher lambda ⇒ stronger alpha shrinkage (the paper's Fig. 9 knob)."""
    snl = jax.jit(model.fn_snl_step(BATCH)[0])
    params0 = model.init(jnp.array(4))
    x, y = batch_for(model, seed=4)
    lr = jnp.array([1e-2], jnp.float32)
    alr = jnp.array([1.0], jnp.float32)

    def run(lam_val):
        params, mom = params0, jnp.zeros_like(params0)
        alphas = jnp.ones((model.mspec.total,))
        lam = jnp.array([lam_val], jnp.float32)
        for _ in range(5):
            params, mom, alphas, _ = snl(params, mom, alphas, x, y, lr, alr, lam)
        return float(jnp.sum(alphas))

    assert run(1e-2) < run(0.0)


def test_snl_alpha_lr_decouples_weight_and_alpha_steps(model):
    """alr=0 must freeze the alphas while weights still train."""
    snl = jax.jit(model.fn_snl_step(BATCH)[0])
    params = model.init(jnp.array(8))
    mom = jnp.zeros_like(params)
    alphas = jnp.ones((model.mspec.total,)) * 0.7
    x, y = batch_for(model, seed=8)
    p2, _, a2, _ = snl(
        params, mom, alphas, x, y,
        jnp.array([1e-2], jnp.float32),
        jnp.array([0.0], jnp.float32),
        jnp.array([1e-2], jnp.float32),
    )
    np.testing.assert_array_equal(a2, alphas)
    assert not np.allclose(p2, params)


def test_kd_step_pulls_toward_teacher(model):
    kd = jax.jit(model.fn_kd_step(BATCH)[0])
    params = model.init(jnp.array(5))
    mom = jnp.zeros_like(params)
    masks = jnp.ones((model.mspec.total,))
    x, y = batch_for(model, seed=5)
    t_logits = jax.nn.one_hot(y, model.cfg.num_classes) * 5.0
    lr = jnp.array([5e-3], jnp.float32)
    temp = jnp.array([2.0], jnp.float32)
    losses = []
    for _ in range(6):
        params, mom, loss = kd(params, mom, masks, x, y, t_logits, lr, temp)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"KD loss did not decrease: {losses}"


def test_eval_batch_matches_forward(model):
    ev = jax.jit(model.fn_eval_batch(BATCH)[0])
    params = model.init(jnp.array(6))
    masks = jnp.ones((model.mspec.total,))
    x, y = batch_for(model, seed=6)
    loss, correct = ev(params, masks, x, y)
    logits = model.forward(params, masks, x)
    want_correct = float(jnp.sum(jnp.argmax(logits, axis=1) == y))
    assert float(correct) == want_correct
    assert float(loss) > 0.0


def test_poly_model_has_coef_params(poly_model):
    """AutoReP variants must carry learnable polynomial coefficients."""
    coef_names = [e.name for e in poly_model.pspec.entries if "poly" in e.name]
    assert coef_names, "poly model has no poly coefficient entries"
    # And the poly path must change the linearized output.
    params = poly_model.init(jnp.array(0))
    x, _ = batch_for(poly_model)
    zeros = jnp.zeros((poly_model.mspec.total,))
    out = poly_model.forward(params, zeros, x)
    assert np.isfinite(np.asarray(out)).all()


def test_mask_spec_matches_relu_layout(model):
    """The mask spec must tile [0, total) contiguously — the rust manifest
    validation assumes it."""
    off = 0
    for e in model.mspec.entries:
        assert e.offset == off
        off += e.size
    assert off == model.mspec.total


def test_param_pack_unpack_roundtrip(model):
    params = model.init(jnp.array(7))
    for e in model.pspec.entries[:3]:
        sub = model.pspec.unpack(params, e.name)
        assert sub.shape == tuple(e.shape)
