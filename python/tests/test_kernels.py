"""L1 correctness: Pallas kernels vs. the pure-jnp oracles (ref.py).

Hypothesis sweeps shapes/dtypes/block configurations; the kernels must agree
with the oracle to float tolerance — including the analytic custom-VJP the
SNL alpha training differentiates through.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels import ref
from compile.kernels.masked_relu import (
    LANE,
    masked_relu_2d,
    masked_relu_nchw,
    _masked_relu_vjp,
    vmem_bytes,
)
from compile.kernels.masked_poly import masked_poly_2d, masked_poly_nchw

hypothesis.settings.register_profile(
    "cdnl", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("cdnl")


def rand(key, shape, dtype=jnp.float32, scale=3.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rand_mask(key, n, soft: bool):
    if soft:
        return jax.random.uniform(key, (n,), jnp.float32)
    return (jax.random.uniform(key, (n,)) > 0.5).astype(jnp.float32)


@given(
    b=st.integers(1, 17),
    n=st.integers(1, 700),
    soft=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_relu_matches_ref(b, n, soft, seed):
    kx, km = jax.random.split(jax.random.PRNGKey(seed))
    x = rand(kx, (b, n))
    m = rand_mask(km, n, soft)
    got = masked_relu_2d(x, m)
    want = ref.masked_relu_ref(x, m)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@given(
    block_b=st.sampled_from([1, 2, 8, 16]),
    block_n=st.sampled_from([128, 256, 512, 1024]),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_relu_block_shape_invariance(block_b, block_n, seed):
    """The result must not depend on the BlockSpec tiling."""
    kx, km = jax.random.split(jax.random.PRNGKey(seed))
    x = rand(kx, (13, 300))
    m = rand_mask(km, 300, soft=False)
    got = masked_relu_2d(x, m, block_b=block_b, block_n=block_n)
    want = ref.masked_relu_ref(x, m)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_masked_relu_identity_and_full():
    """m=1 is plain ReLU; m=0 is the identity (the linearized network)."""
    x = rand(jax.random.PRNGKey(0), (4, 200))
    ones = jnp.ones((200,))
    zeros = jnp.zeros((200,))
    np.testing.assert_allclose(masked_relu_2d(x, ones), jnp.maximum(x, 0.0), rtol=1e-6)
    np.testing.assert_allclose(masked_relu_2d(x, zeros), x, rtol=1e-6)


def test_masked_relu_bf16():
    kx, km = jax.random.split(jax.random.PRNGKey(7))
    x = rand(kx, (8, 256), dtype=jnp.bfloat16)
    m = rand_mask(km, 256, soft=False)
    got = masked_relu_2d(x, m).astype(jnp.float32)
    want = ref.masked_relu_ref(x, m).astype(jnp.float32)
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-2)


@given(seed=st.integers(0, 2**31 - 1), soft=st.booleans())
def test_masked_relu_grads_match_ref(seed, soft):
    """The analytic custom-VJP must equal autodiff through the oracle —
    both dL/dx and dL/dm (SNL trains alphas through this op)."""
    kx, km = jax.random.split(jax.random.PRNGKey(seed))
    x = rand(kx, (6, 150))
    m = rand_mask(km, 150, soft)

    def loss_kernel(x, m):
        return jnp.sum(jnp.sin(_masked_relu_vjp(x, m)))

    def loss_ref(x, m):
        return jnp.sum(jnp.sin(ref.masked_relu_ref(x, m)))

    gx_k, gm_k = jax.grad(loss_kernel, argnums=(0, 1))(x, m)
    gx_r, gm_r = jax.grad(loss_ref, argnums=(0, 1))(x, m)
    np.testing.assert_allclose(gx_k, gx_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(gm_k, gm_r, rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
def test_masked_relu_nchw(seed):
    kx, km = jax.random.split(jax.random.PRNGKey(seed))
    x = rand(kx, (3, 4, 5, 5))
    m = rand_mask(km, 4 * 5 * 5, soft=False).reshape(4, 5, 5)
    got = masked_relu_nchw(x, m)
    want = ref.masked_relu_ref(x, m.reshape(1, 4, 5, 5))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@given(
    b=st.integers(1, 9),
    n=st.integers(1, 400),
    soft=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_masked_poly_matches_ref(b, n, soft, seed):
    kx, km, kc = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(kx, (b, n))
    m = rand_mask(km, n, soft)
    coefs = jax.random.normal(kc, (3,)) * 0.3
    got = masked_poly_2d(x, m, coefs)
    want = ref.masked_poly_ref(x, m, coefs)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_masked_poly_full_mask_is_relu():
    x = rand(jax.random.PRNGKey(1), (4, 130))
    coefs = jnp.array([0.2, 0.5, 0.1])
    got = masked_poly_2d(x, jnp.ones((130,)), coefs)
    np.testing.assert_allclose(got, jnp.maximum(x, 0.0), rtol=1e-6, atol=1e-6)


def test_masked_poly_zero_mask_is_poly():
    x = rand(jax.random.PRNGKey(2), (4, 130))
    coefs = jnp.array([0.2, 0.5, 0.1])
    got = masked_poly_2d(x, jnp.zeros((130,)), coefs)
    want = (0.2 * x + 0.5) * x + 0.1
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1))
def test_masked_poly_grads_match_ref(seed):
    """Gradients w.r.t. x, m AND the learnable coefficients (AutoReP trains
    the polynomial) must match autodiff through the oracle."""
    kx, km, kc = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = rand(kx, (5, 100))
    m = rand_mask(km, 100, soft=True)
    coefs = jax.random.normal(kc, (3,)) * 0.3

    def loss_kernel(x, m, c):
        return jnp.sum(jnp.tanh(masked_poly_nchw(
            x.reshape(5, 4, 5, 5), m.reshape(4, 5, 5), c
        )))

    def loss_ref(x, m, c):
        return jnp.sum(jnp.tanh(
            ref.masked_poly_ref(x, m, c).reshape(5, 4, 5, 5)
        ))

    gk = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, m, coefs)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(x, m, coefs)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_bad_shapes_rejected():
    x = jnp.zeros((4, 8, 2))
    with pytest.raises(ValueError):
        masked_relu_2d(x, jnp.zeros((8,)))
    with pytest.raises(ValueError):
        masked_relu_2d(jnp.zeros((4, 8)), jnp.zeros((9,)))


def test_vmem_budget():
    """Default tile must fit comfortably in TPU VMEM (16 MiB)."""
    assert vmem_bytes() < 256 * 1024
    assert vmem_bytes(double_buffered=False) * 2 == vmem_bytes()


def test_lane_alignment_constant():
    assert LANE == 128
