"""AOT contract tests: the manifest in artifacts/ must agree with what the
models say about themselves, and the HLO text must be loadable.

These tests run against the checked-out artifacts directory (built by
`make artifacts`); they are skipped when it does not exist yet.
"""

import json
import os

import pytest

from compile.aot import ARG_NAMES, MODEL_CONFIGS, OUT_NAMES
from compile.model import Model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_covers_all_configs(manifest):
    keys = {c.key for c in MODEL_CONFIGS}
    assert keys == set(manifest["models"].keys())


def test_manifest_fields(manifest):
    for key, m in manifest["models"].items():
        assert m["key"] == key
        assert m["param_size"] > 0
        assert m["mask_size"] > 0
        assert m["artifacts"], f"{key} has no artifacts"
        # Mask layers tile [0, mask_size) contiguously (rust validates the
        # same invariant; this catches it at build time).
        off = 0
        for e in m["mask_layers"]:
            assert e["offset"] == off, f"{key}:{e['name']}"
            c, h, w = e["shape"]
            assert e["size"] == c * h * w
            off += e["size"]
        assert off == m["mask_size"]


def test_artifact_files_exist_and_are_hlo_text(manifest):
    for key, m in manifest["models"].items():
        for fn, a in m["artifacts"].items():
            path = os.path.join(ART, a["file"])
            assert os.path.exists(path), f"{key}:{fn} missing {a['file']}"
            with open(path) as f:
                head = f.read(200)
            assert "HloModule" in head, f"{key}:{fn} is not HLO text"


def test_artifact_specs_match_arg_tables(manifest):
    for key, m in manifest["models"].items():
        for fn, a in m["artifacts"].items():
            in_names = [s["name"] for s in a["inputs"]]
            out_names = [s["name"] for s in a["outputs"]]
            assert in_names == ARG_NAMES[fn], f"{key}:{fn} inputs"
            assert out_names == OUT_NAMES[fn], f"{key}:{fn} outputs"


def test_manifest_sizes_match_model_specs(manifest):
    """Re-derive the specs from the model definitions; the manifest must not
    have drifted from the code."""
    for cfg in MODEL_CONFIGS:
        model = Model(cfg)
        m = manifest["models"][cfg.key]
        assert m["param_size"] == model.pspec.total, cfg.key
        assert m["mask_size"] == model.mspec.total, cfg.key
        assert len(m["mask_layers"]) == len(model.mspec.entries), cfg.key


def test_batch_consistency(manifest):
    batch = manifest["batch"]
    for key, m in manifest["models"].items():
        fwd = m["artifacts"]["forward"]
        x = next(s for s in fwd["inputs"] if s["name"] == "x")
        assert x["shape"][0] == batch, f"{key}: forward batch {x['shape']}"
        assert x["shape"][1:] == [m["channels"], m["image_size"], m["image_size"]]


def test_relu_counts_scale_with_image_size(manifest):
    """Paper Table 1: ReLU count grows ~4x with 2x image size and is larger
    for the wide backbone."""
    r16 = manifest["models"]["resnet_16x16_c20"]["mask_size"]
    r32 = manifest["models"]["resnet_32x32_c20"]["mask_size"]
    w16 = manifest["models"]["wrn_16x16_c20"]["mask_size"]
    w32 = manifest["models"]["wrn_32x32_c20"]["mask_size"]
    assert 3.0 < r32 / r16 <= 4.1
    assert 3.0 < w32 / w16 <= 4.1
    assert w16 > r16 and w32 > r32
