//! BCD as a plug-in on top of another method (paper Fig. 4): start from an
//! AutoReP polynomial-replacement model and push it to a lower budget.
//!
//! ```bash
//! make artifacts && cargo run --release --example on_top_of_autorep
//! ```
//!
//! Demonstrates that the coordinator is agnostic to the ReLU replacement
//! function: the same Algorithm 2 drives the `*_poly` model variants, whose
//! masked activation is the L1 `masked_poly` Pallas kernel.

use cdnl::config::Experiment;
use cdnl::methods::autorep::run_autorep;
use cdnl::pipeline::Pipeline;
use cdnl::runtime::open_backend;
use cdnl::util::fmt_relu_count;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    cdnl::util::logging::init();
    let engine = open_backend(Path::new("artifacts"), "auto")?;

    let mut exp = Experiment::default();
    exp.dataset = "synth100".into();
    exp.backbone = "resnet".into();
    exp.poly = true; // selects the resnet_16x16_c20_poly artifacts
    exp.train.steps = 120;
    exp.snl.max_steps = 150;
    exp.bcd.rt = 8;
    exp.bcd.finetune_steps = 8;
    let pl = Pipeline::new(&engine, exp.clone())?;
    let total = pl.sess.info().total_relus();
    assert!(pl.sess.info().poly, "expected a poly model variant");

    // AutoReP reference: quadratic-replacement model at B_ref.
    let b_ref = total / 4;
    let b_target = total / 8;
    let baseline = pl.baseline()?;
    println!(
        "baseline ({}): {:.2}% with {} ReLUs",
        pl.sess.key,
        pl.test_acc(&baseline)?,
        fmt_relu_count(total)
    );

    // The selective-training base comes from exp.snl; exp.autorep carries
    // the hysteresis band (both ride Experiment::dump for provenance).
    let mut arp = baseline.clone();
    let out =
        run_autorep(&pl.sess, &mut arp, &pl.train_ds, b_ref, &pl.exp.snl, &pl.exp.autorep)?;
    println!(
        "autorep reference: {} ReLUs, {:.2}%  ({} steps, {} indicator checks)",
        fmt_relu_count(arp.budget()),
        pl.test_acc(&arp)?,
        out.steps_run,
        out.budget_trace.len()
    );

    // AutoReP straight to the target (the baseline we beat)...
    let mut arp_direct = baseline.clone();
    run_autorep(&pl.sess, &mut arp_direct, &pl.train_ds, b_target, &pl.exp.snl, &pl.exp.autorep)?;
    let arp_acc = pl.test_acc(&arp_direct)?;

    // ...vs BCD on top of the AutoReP reference.
    let (ours, bcd_out) = pl.bcd_from(&arp, b_target)?;
    let ours_acc = pl.test_acc(&ours)?;

    println!(
        "\nat {} ReLUs:\n  AutoReP direct   {arp_acc:.2}%\n  Ours on AutoReP  {ours_acc:.2}%  ({:+.2}, {} BCD iterations)",
        fmt_relu_count(b_target),
        ours_acc - arp_acc,
        bcd_out.iterations.len()
    );
    println!(
        "\npaper Fig. 4 shape: BCD-on-AutoReP reaches AutoReP's accuracy with ~half the budget."
    );
    Ok(())
}
