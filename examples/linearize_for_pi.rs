//! End-to-end driver (EXPERIMENTS.md §End-to-end): the paper's full
//! protocol on a real small workload, proving all three layers compose.
//!
//! ```bash
//! make artifacts && cargo run --release --example linearize_for_pi
//! ```
//!
//! Pipeline (all compute through AOT-compiled XLA artifacts; Python never
//! runs):
//!   1. train the full-ReLU ResNet baseline on the CIFAR-10 analog,
//!      logging the loss curve,
//!   2. SNL-linearize to the reference budget B_ref (the paper's Table 4
//!      protocol),
//!   3. run Block Coordinate Descent down to B_target,
//!   4. compare against SNL-direct at the same target (the paper's headline
//!      comparison), and
//!   5. report the private-inference latency estimate at every stage.

use cdnl::config::Experiment;
use cdnl::coordinator::train::train;
use cdnl::methods::snl::run_snl;
use cdnl::pipeline::Pipeline;
use cdnl::runtime::open_backend;
use cdnl::util::fmt_relu_count;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    cdnl::util::logging::init();
    let engine = open_backend(Path::new("artifacts"), "auto")?;

    let mut exp = Experiment::default();
    exp.dataset = "synth10".into();
    exp.train.steps = 200;
    exp.snl.max_steps = 250;
    exp.bcd.rt = 10;
    exp.bcd.finetune_steps = 12;
    let pl = Pipeline::new(&engine, exp.clone())?;
    let total = pl.sess.info().total_relus();
    let b_target = total / 8; // aggressive linearization: keep 12.5%
    let b_ref = total / 4;

    // --- 1. baseline training with a logged loss curve ----------------------
    let mut st = pl.sess.init_state(exp.train.seed as i32)?;
    let t0 = std::time::Instant::now();
    let stats = train(&pl.sess, &mut st, &pl.train_ds, &exp.train)?;
    println!("\n== stage 1: baseline ({} steps in {:.0}s) ==", exp.train.steps, t0.elapsed().as_secs_f64());
    print_loss_curve(&stats.losses);
    let base_acc = pl.test_acc(&st)?;
    println!("baseline test accuracy: {base_acc:.2}%");

    // --- 2. SNL to the reference budget --------------------------------------
    let t0 = std::time::Instant::now();
    let snl_out = run_snl(&pl.sess, &mut st, &pl.train_ds, b_ref, &exp.snl, 0)?;
    let ref_acc = pl.test_acc(&st)?;
    println!(
        "\n== stage 2: SNL reference ({} steps, {} lambda updates, {:.0}s) ==",
        snl_out.steps_run,
        snl_out.kappa_updates.len(),
        t0.elapsed().as_secs_f64()
    );
    println!(
        "reference model: {} ReLUs, {ref_acc:.2}% test accuracy",
        fmt_relu_count(st.budget())
    );

    // --- 3. BCD to the target -------------------------------------------------
    let (ours, bcd_out) = pl.bcd_from(&st, b_target)?;
    let ours_acc = pl.test_acc(&ours)?;
    println!(
        "\n== stage 3: BCD {} -> {} ({} iterations, {} trials, {:.0}s) ==",
        fmt_relu_count(b_ref),
        fmt_relu_count(b_target),
        bcd_out.iterations.len(),
        bcd_out.total_trials(),
        bcd_out.wall_secs
    );
    println!("ours: {ours_acc:.2}% test accuracy at {}", fmt_relu_count(b_target));

    // --- 4. the headline comparison: SNL straight to the target ----------------
    let mut snl_direct = pl.baseline()?;
    run_snl(&pl.sess, &mut snl_direct, &pl.train_ds, b_target, &exp.snl, 0)?;
    let snl_acc = pl.test_acc(&snl_direct)?;
    println!(
        "\n== stage 4: comparison at {} ReLUs ==\n  SNL  {snl_acc:.2}%\n  Ours {ours_acc:.2}%  ({:+.2})",
        fmt_relu_count(b_target),
        ours_acc - snl_acc
    );

    // --- 5. PI cost at every stage ---------------------------------------------
    println!("\n== stage 5: estimated PI online latency (WAN) ==");
    let info = pl.sess.info();
    let proto = &cdnl::pi::WAN;
    for (name, mask) in [
        ("full ReLUs", cdnl::model::Mask::full(total)),
        ("SNL reference", st.mask.clone()),
        ("ours (BCD)", ours.mask.clone()),
    ] {
        let r = cdnl::pi::estimate_state(info, &mask, proto);
        println!(
            "  {name:<14} {:>7} ReLUs  {:>8.1} ms  {:>6.2} MB",
            r.relus,
            1e3 * r.total_secs,
            r.online_bytes / 1e6
        );
    }
    Ok(())
}

/// Terminal loss curve (the end-to-end "log the loss curve" requirement).
fn print_loss_curve(losses: &[f32]) {
    let pts: Vec<(f64, f64)> = losses
        .iter()
        .enumerate()
        .map(|(i, &l)| (i as f64, l as f64))
        .collect();
    let s = cdnl::metrics::Series::new("train loss", pts);
    println!("{}", cdnl::metrics::ascii_plot("training loss curve", &[s], 64, 12));
}
