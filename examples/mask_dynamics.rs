//! Research-tooling example: inspect SNL mask dynamics (the paper's
//! ablation machinery) from the library API.
//!
//! ```bash
//! make artifacts && cargo run --release --example mask_dynamics
//! ```
//!
//! Runs a short SNL path, prints the budget trace and consecutive-mask IoU,
//! and verifies the "golden set" observation (high overlap between masks of
//! decreasing budgets) that motivates BCD's never-revisit design.

use cdnl::config::Experiment;
use cdnl::methods::snl::{consecutive_iou, run_snl};
use cdnl::pipeline::Pipeline;
use cdnl::runtime::open_backend;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    cdnl::util::logging::init();
    let engine = open_backend(Path::new("artifacts"), "auto")?;

    let mut exp = Experiment::default();
    exp.dataset = "synth10".into();
    exp.train.steps = 120;
    exp.snl.max_steps = 150;
    exp.snl.steps_per_check = 5;
    let pl = Pipeline::new(&engine, exp.clone())?;
    let total = pl.sess.info().total_relus();

    let mut st = pl.baseline()?;
    let out = run_snl(&pl.sess, &mut st, &pl.train_ds, total / 3, &exp.snl, 6)?;

    println!("\nSNL path: {} steps, {} checks", out.steps_run, out.budget_trace.len());
    println!("\nbudget trace (step -> thresholded budget):");
    for &(step, budget) in out.budget_trace.iter().take(20) {
        let lam = out
            .lambda_trace
            .iter()
            .find(|(s, _)| *s == step)
            .map(|(_, l)| *l)
            .unwrap_or(0.0);
        println!("  step {step:>4}  budget {budget:>6}  lambda {lam:.2e}");
    }
    if out.budget_trace.len() > 20 {
        println!("  ... ({} more checks)", out.budget_trace.len() - 20);
    }

    let ious = consecutive_iou(&out.snapshots);
    let min = ious.iter().cloned().fold(1.0f64, f64::min);
    let mean: f64 = ious.iter().sum::<f64>() / ious.len().max(1) as f64;
    println!("\nconsecutive mask IoU: mean {mean:.3}, min {min:.3} (paper Fig. 6: > 0.85)");
    println!(
        "kappa updates fired at steps {:?} — each makes the lasso pressure jump (Fig. 10/11)",
        out.kappa_updates
    );

    println!("\ntracked alpha trajectories (first 10 checks):");
    for (k, trace) in out.alpha_traces.iter().enumerate() {
        let vals: Vec<String> = trace.iter().take(10).map(|a| format!("{a:.2}")).collect();
        println!("  alpha[{:>6}]: {}", out.alpha_indices[k], vals.join(" "));
    }
    println!(
        "\nconclusion: masks shrink with high overlap — evidence for the golden-set \
         conjecture BCD exploits by never revisiting removed ReLUs."
    );
    Ok(())
}
