//! Quickstart: linearize a network down to a ReLU budget with BCD.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Trains a small full-ReLU baseline on the CIFAR-10-analog dataset, runs
//! Block Coordinate Descent (Algorithm 2) to remove 800 ReLUs, and reports
//! accuracy before/after plus the estimated Private-Inference saving.

use cdnl::config::Experiment;
use cdnl::pipeline::Pipeline;
use cdnl::runtime::open_backend;
use cdnl::util::fmt_relu_count;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    cdnl::util::logging::init();
    let engine = open_backend(Path::new("artifacts"), "auto")?;

    // An Experiment bundles dataset + backbone + all hyperparameters.
    let mut exp = Experiment::default();
    exp.dataset = "synth10".into();
    exp.train.steps = 120; // quick demo; the benches use the cached 300-step model
    exp.bcd.rt = 8;
    exp.bcd.finetune_steps = 8;

    let pl = Pipeline::new(&engine, exp)?;
    let info = pl.sess.info();
    println!(
        "model {}: {} params, {} ReLU locations in {} masked layers",
        info.key,
        info.param_size,
        fmt_relu_count(info.total_relus()),
        info.mask_layers.len()
    );

    // 1. Train (or load the cached) full-ReLU baseline.
    let baseline = pl.baseline()?;
    let base_acc = pl.test_acc(&baseline)?;
    println!("baseline: {base_acc:.2}% test accuracy with all ReLUs");

    // 2. BCD: remove 800 ReLUs, 100 per iteration (Algorithm 2).
    let target = baseline.budget() - 800;
    let (reduced, out) = pl.bcd_from(&baseline, target)?;
    let red_acc = pl.test_acc(&reduced)?;
    println!(
        "bcd: {} -> {} ReLUs in {} iterations ({} trials, {:.1}s); accuracy {base_acc:.2}% -> {red_acc:.2}%",
        fmt_relu_count(baseline.budget()),
        fmt_relu_count(reduced.budget()),
        out.iterations.len(),
        out.total_trials(),
        out.wall_secs,
    );

    // 3. What this buys in a private-inference deployment.
    for proto in cdnl::pi::registry() {
        let before = cdnl::pi::estimate_state(info, &baseline.mask, proto);
        let after = cdnl::pi::estimate_state(info, &reduced.mask, proto);
        println!(
            "PI online latency ({}): {:.1} ms -> {:.1} ms  ({:.1} MB -> {:.1} MB comms)",
            proto.name,
            1e3 * before.total_secs,
            1e3 * after.total_secs,
            before.online_bytes / 1e6,
            after.online_bytes / 1e6,
        );
    }
    Ok(())
}
