//! Baseline-method integration over the PJRT runtime: SNL, AutoReP, SENet
//! and DeepReDuce all reach exact budgets and leave consistent state.
//! This is the expensive test binary (compiles train/snl/kd steps once);
//! every method run is kept tiny. Requires `--features pjrt` + artifacts.

#![cfg(feature = "pjrt")]

use cdnl::config::{SnlConfig, TrainConfig};
use cdnl::coordinator::train::train;
use cdnl::data::synth;
use cdnl::methods::autorep::{run_autorep, AutorepConfig};
use cdnl::methods::deepreduce::{run_deepreduce, DeepReduceConfig};
use cdnl::methods::senet::{run_senet, SenetConfig};
use cdnl::methods::snl::{consecutive_iou, run_snl};
use cdnl::model::ModelState;
use cdnl::runtime::engine::Engine;
use cdnl::runtime::session::Session;
use std::path::Path;

#[test]
fn methods_reach_exact_budgets() {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let engine = Engine::new(Path::new("artifacts")).unwrap();
    let sess = Session::new(&engine, "resnet_16x16_c10").unwrap();
    let (train_ds, _) = synth::generate(synth::by_name("synth10").unwrap());
    let total = sess.info().total_relus();

    // --- a few real SGD steps move the loss ---------------------------------
    let mut st = sess.init_state(7).unwrap();
    let tcfg = TrainConfig { steps: 6, lr: 5e-3, warmup_steps: 2, batch: sess.batch, seed: 1 };
    let stats = train(&sess, &mut st, &train_ds, &tcfg).unwrap();
    assert_eq!(stats.losses.len(), 6);
    assert!(
        stats.losses.last().unwrap() < stats.losses.first().unwrap(),
        "training loss did not decrease: {:?}",
        stats.losses
    );
    let trained = st.clone();

    // --- SNL: budget trace decreases, snapshots produced, exact landing ----
    let snl_cfg = SnlConfig {
        lambda0: 4e-3,
        kappa: 1.3,
        stall_patience: 2,
        threshold: 0.5,
        steps_per_check: 4,
        max_steps: 24,
        lr: 1e-2,
        alpha_lr: 1.0,
        finetune_steps: 2,
        finetune_lr: 1e-3,
        seed: 3,
    };
    let target = total - 400;
    let mut st_snl = trained.clone();
    let out = run_snl(&sess, &mut st_snl, &train_ds, target, &snl_cfg, 4).unwrap();
    assert_eq!(st_snl.budget(), target, "SNL must land exactly");
    assert_eq!(out.final_budget, target);
    assert!(!out.budget_trace.is_empty());
    assert!(!out.snapshots.is_empty());
    assert_eq!(out.alpha_traces.len(), 4);
    for tr in &out.alpha_traces {
        assert_eq!(tr.len(), out.budget_trace.len());
        assert!(tr.iter().all(|a| (0.0..=1.0).contains(a)), "alpha out of range");
    }
    // IoU of consecutive snapshots is high (paper Fig. 6: > 0.85); with our
    // short run it should be very high.
    for iou in consecutive_iou(&out.snapshots) {
        assert!(iou > 0.5, "consecutive IoU collapsed: {iou}");
    }
    st_snl.mask.check_invariants().unwrap();

    // --- SENet: allocation + KD, exact landing ------------------------------
    let mut st_se = trained.clone();
    let se_cfg = SenetConfig {
        proxy_batches: 1,
        layer_trials: 2,
        kd_steps: 3,
        kd_lr: 1e-3,
        kd_temp: 4.0,
        seed: 5,
    };
    let se_target = total / 2;
    let out = run_senet(&sess, &mut st_se, &train_ds, se_target, &se_cfg).unwrap();
    assert_eq!(st_se.budget(), se_target);
    assert_eq!(out.sensitivity.len(), sess.info().mask_layers.len());
    assert_eq!(out.allocation.iter().sum::<usize>(), se_target);
    for (a, e) in out.allocation.iter().zip(&sess.info().mask_layers) {
        assert!(a <= &e.size);
    }
    st_se.mask.check_invariants().unwrap();

    // --- DeepReDuce: whole layers drop, exact landing ------------------------
    let mut st_dr = trained.clone();
    let dr_cfg = DeepReduceConfig {
        proxy_batches: 1,
        finetune_steps: 2,
        finetune_lr: 1e-3,
        seed: 6,
    };
    let dr_target = total / 3;
    let out = run_deepreduce(&sess, &mut st_dr, &train_ds, dr_target, &dr_cfg).unwrap();
    assert_eq!(st_dr.budget(), dr_target);
    assert!(!out.dropped_layers.is_empty(), "no layer was fully dropped");
    let hist = st_dr.mask.layer_histogram(sess.info());
    for &l in &out.dropped_layers {
        assert_eq!(hist[l], 0, "dropped layer {l} still has ReLUs");
    }

    // --- checkpoint roundtrip through a method output ------------------------
    let path = std::env::temp_dir().join("cdnl_it_methods/snl.cdnl");
    st_snl.save(&path).unwrap();
    let back = ModelState::load(&path, sess.info()).unwrap();
    assert_eq!(back.budget(), target);
    assert_eq!(back.mask.dense(), st_snl.mask.dense());
    assert_eq!(back.params.data, st_snl.params.data);

    // --- AutoReP on the poly variant ------------------------------------------
    let sess_p = Session::new(&engine, "resnet_16x16_c20_poly").unwrap();
    let (train_100, _) = synth::generate(synth::by_name("synth100").unwrap());
    let mut st_p = sess_p.init_state(9).unwrap();
    let ar_base = SnlConfig {
        steps_per_check: 4,
        max_steps: 16,
        finetune_steps: 2,
        ..snl_cfg.clone()
    };
    let ar_cfg = AutorepConfig { hysteresis: 0.2 };
    let p_total = sess_p.info().total_relus();
    let p_target = p_total - 300;
    let out = run_autorep(&sess_p, &mut st_p, &train_100, p_target, &ar_base, &ar_cfg).unwrap();
    assert_eq!(st_p.budget(), p_target);
    assert!(!out.budget_trace.is_empty());
    st_p.mask.check_invariants().unwrap();

    // AutoReP must refuse non-poly sessions.
    let mut st_bad = trained.clone();
    assert!(run_autorep(&sess, &mut st_bad, &train_ds, 100, &ar_base, &ar_cfg).is_err());
}
