//! BCD coordinator integration over the PJRT runtime: Algorithm 2
//! invariants hold on a live model (budgets exact, ReLUs never revisited,
//! early-exit bound sound), with finetuning disabled so the test only pays
//! the (fast) eval_batch compile. Requires `--features pjrt` + artifacts;
//! the backend-agnostic twin in `integration_reference.rs` always runs.

#![cfg(feature = "pjrt")]

use cdnl::config::BcdConfig;
use cdnl::coordinator::bcd::run_bcd;
use cdnl::coordinator::eval::Evaluator;
use cdnl::coordinator::trials::{scan_trials, BlockSampler};
use cdnl::data::synth;
use cdnl::model::Mask;
use cdnl::runtime::engine::Engine;
use cdnl::runtime::session::Session;
use cdnl::util::prng::Rng;
use std::path::Path;

#[test]
fn bcd_invariants_on_live_model() {
    if !Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    }
    let engine = Engine::new(Path::new("artifacts")).unwrap();
    let sess = Session::new(&engine, "resnet_16x16_c10").unwrap();
    let (train_ds, _) = synth::generate(synth::by_name("synth10").unwrap());
    let mut st = sess.init_state(42).unwrap();
    let total = st.budget();

    // --- evaluator: bound soundness -----------------------------------------
    let ev = Evaluator::new(&sess, &train_ds, 2).unwrap();
    assert_eq!(ev.num_batches(), 2);
    let params = ev.upload_params(&st.params).unwrap();
    let acc = ev.accuracy(&params, st.mask.dense()).unwrap();
    assert!((0.0..=100.0).contains(&acc));
    // A bound below the true accuracy must not cut; far above must cut.
    let kept = ev
        .accuracy_bounded(&params, st.mask.dense(), (acc - 1.0).max(0.0))
        .unwrap();
    assert_eq!(kept, Some(acc), "bound below truth must return the value");
    let cut = ev.accuracy_bounded(&params, st.mask.dense(), 100.1).unwrap();
    assert_eq!(cut, None, "unreachable bound must cut");

    // --- trial scan: honest outputs -----------------------------------------
    let mut rng = Rng::new(1);
    let sampler = BlockSampler::new(cdnl::config::Granularity::Pixel, sess.info());
    let scan =
        scan_trials(&ev, &params, &st.mask, &sampler, 50, 4, -1000.0, acc, &mut rng, 1).unwrap();
    // ADT = -1000 is unreachable => no early accept, all 4 trials evaluated.
    assert!(!scan.early_accept);
    assert_eq!(scan.evaluated, 4);
    assert_eq!(scan.chosen.removed.len(), 50);
    for &i in &scan.chosen.removed {
        assert!(st.mask.is_present(i), "scan proposed an absent ReLU");
    }
    let scan_easy =
        scan_trials(&ev, &params, &st.mask, &sampler, 50, 4, 1000.0, acc, &mut rng, 1).unwrap();
    assert!(scan_easy.early_accept, "ADT=1000%% must accept the first trial");
    assert_eq!(scan_easy.evaluated, 1);

    // --- the full BCD loop ----------------------------------------------------
    let cfg = BcdConfig {
        drc: 64,
        rt: 3,
        adt: 0.5,
        finetune_steps: 0, // keep the test off the train_step compile path
        finetune_lr: 0.0,
        proxy_batches: 2,
        seed: 0xB0B,
        ..Default::default()
    };
    // A target that does NOT divide evenly by DRC: 3 full steps + remainder.
    let target = total - 3 * 64 - 17;
    let before = st.mask.clone();
    let out = run_bcd(&sess, &mut st, &train_ds, target, &cfg, 1).unwrap();

    assert_eq!(st.budget(), target, "BCD must land exactly on the target");
    assert_eq!(out.final_budget, target);
    assert_eq!(out.iterations.len(), 4, "ceil((3*64+17)/64) = 4 iterations");
    assert_eq!(out.iterations.last().unwrap().budget_after, target);
    // Sparse-by-design: the final mask is a strict subset of the start mask.
    assert_eq!(st.mask.containment(&before), 1.0);
    st.mask.check_invariants().unwrap();
    // Budgets strictly decrease across iterations.
    let mut prev = total;
    for rec in &out.iterations {
        assert!(rec.budget_after < prev, "budget did not decrease at t={}", rec.t);
        assert!(rec.trials_evaluated >= 1 && rec.trials_evaluated <= cfg.rt);
        prev = rec.budget_after;
    }
    // Snapshots were recorded each iteration and shrink monotonically.
    assert_eq!(out.snapshots.len(), 4);
    for w in out.snapshots.windows(2) {
        assert!(w[1].0 < w[0].0);
        // Later masks are contained in earlier ones (never-revisit).
        assert_eq!(w[1].1.containment(&w[0].1), 1.0);
    }

    // --- error paths -----------------------------------------------------------
    assert!(
        run_bcd(&sess, &mut st, &train_ds, target + 10, &cfg, 0).is_err(),
        "target above current budget must be rejected"
    );
    let bad = BcdConfig { drc: 0, ..cfg.clone() };
    assert!(run_bcd(&sess, &mut st, &train_ds, 10, &bad, 0).is_err());

    // --- determinism: same seed, same chosen masks ------------------------------
    let mut st_a = sess.init_state(42).unwrap();
    let mut st_b = sess.init_state(42).unwrap();
    let cfg2 = BcdConfig { drc: 80, rt: 2, ..cfg.clone() };
    run_bcd(&sess, &mut st_a, &train_ds, total - 160, &cfg2, 0).unwrap();
    run_bcd(&sess, &mut st_b, &train_ds, total - 160, &cfg2, 0).unwrap();
    assert_eq!(
        st_a.mask.dense(),
        st_b.mask.dense(),
        "same seed must replay bit-exactly"
    );

    // --- mask containment metric on live masks (Fig. 6 machinery) --------------
    let m_small: &Mask = &st.mask;
    assert!(m_small.containment(&before) > 0.999);
}
