//! Run-store integration on the pure-Rust reference backend.
//!
//! The acceptance criterion of the run-store: a BCD run killed mid-search
//! and resumed from its `run.json` + sweep checkpoint produces a final
//! mask, parameter vector and iteration trace **bit-identical** to the
//! same run executed uninterrupted.

use anyhow::bail;
use cdnl::config::{BcdConfig, Experiment};
use cdnl::coordinator::bcd::run_bcd_resumable;
use cdnl::pipeline::Pipeline;
use cdnl::runstore::{save_state_atomic, BcdRecorder, RunManifest, RunStore, COMPLETE, RUNNING};
use cdnl::runtime::RefBackend;
use std::path::PathBuf;

/// Fresh scratch directory per test (process id + tag keeps parallel test
/// binaries and repeated runs apart).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cdnl_it_runstore_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn quick_exp(out_dir: &std::path::Path) -> Experiment {
    let mut exp = Experiment::default();
    exp.out_dir = out_dir.display().to_string();
    exp.bcd = BcdConfig {
        drc: 24,
        rt: 3,
        adt: 0.3,
        finetune_steps: 2,
        finetune_lr: 1e-3,
        proxy_batches: 2,
        seed: 7,
        workers: 2,
        ..Default::default()
    };
    exp
}

fn assert_same_trace(
    a: &[cdnl::coordinator::bcd::IterRecord],
    b: &[cdnl::coordinator::bcd::IterRecord],
) {
    assert_eq!(a.len(), b.len(), "iteration counts differ");
    for (ra, rb) in a.iter().zip(b) {
        assert_eq!(ra.t, rb.t);
        assert_eq!(ra.budget_after, rb.budget_after, "t={}", ra.t);
        assert_eq!(ra.base_acc, rb.base_acc, "t={}", ra.t);
        assert_eq!(ra.chosen_dacc, rb.chosen_dacc, "t={}", ra.t);
        assert_eq!(ra.trials_evaluated, rb.trials_evaluated, "t={}", ra.t);
        assert_eq!(ra.trials_bounded, rb.trials_bounded, "t={}", ra.t);
        assert_eq!(ra.early_accept, rb.early_accept, "t={}", ra.t);
        assert_eq!(ra.finetune.last_loss, rb.finetune.last_loss, "t={}", ra.t);
    }
}

#[test]
fn interrupted_bcd_resumes_bit_identical() {
    let tmp = scratch("kill");
    let be = RefBackend::standard();
    let pl = Pipeline::new(&be, quick_exp(&tmp)).unwrap();
    let st0 = pl.sess.init_state(42).unwrap();
    let total = st0.budget();
    // 3 full sweeps + a 7-ReLU remainder sweep.
    let target = total - 3 * 24 - 7;

    // A: the uninterrupted run.
    let mut st_a = st0.clone();
    let out_a = run_bcd_resumable(
        &pl.sess,
        &mut st_a,
        &pl.train_ds,
        target,
        &pl.exp.bcd,
        0,
        None,
        &mut |_| Ok(()),
    )
    .unwrap();

    // B: the same run, recorded, killed mid-search after sweep 2's
    // checkpoint lands (a hook error aborts exactly like a kill: the
    // process never gets to write a terminal status).
    let store = RunStore::open(tmp.join("runs"));
    let m = RunManifest::new("bcd", &pl.exp, "reference", total, target);
    let mut run = store.create(m).unwrap();
    save_state_atomic(&st0, &run.ref_state_path()).unwrap();
    let run_id = run.manifest.run_id.clone();
    let mut st_b = st0.clone();
    let res = {
        let mut rec = BcdRecorder::new(&mut run);
        run_bcd_resumable(
            &pl.sess,
            &mut st_b,
            &pl.train_ds,
            target,
            &pl.exp.bcd,
            0,
            None,
            &mut |ev| {
                rec.observe(ev)?;
                if ev.cursor.sweeps_done == 2 {
                    bail!("simulated kill");
                }
                Ok(())
            },
        )
    };
    assert!(res.is_err(), "the kill must abort the run");
    drop(run);

    // The directory is in the killed state: status still `running`, two
    // sweeps durable, checkpoint for sweep 2 present.
    let rd = store.get(&run_id).unwrap();
    assert_eq!(rd.manifest.status, RUNNING);
    let prog = rd.manifest.bcd.as_ref().unwrap();
    assert_eq!(prog.sweeps_done, 2);
    assert_eq!(prog.iterations.len(), 2);
    assert!(rd.sweep_state_path(2).exists());
    assert!(!rd.sweep_state_path(1).exists(), "superseded checkpoint not pruned");

    // Simulate the nastiest kill window too: a sweep-3 checkpoint written
    // but the manifest never advanced. Resume must ignore the orphan (the
    // manifest is the source of truth) and overwrite it.
    std::fs::copy(rd.sweep_state_path(2), rd.sweep_state_path(3)).unwrap();

    // C: resume exactly as `cdnl runs resume <id>` does — experiment
    // rebuilt from the recorded config dump, state from the checkpoint,
    // RNG streams from the cursor.
    let exp2 = rd.manifest.experiment().unwrap();
    assert_eq!(exp2.fingerprint(), pl.exp.fingerprint());
    let pl2 = Pipeline::new(&be, exp2).unwrap();
    let (st_r, out_r, run2) = pl2.bcd_resume(rd).unwrap();
    assert_eq!(run2.manifest.status, COMPLETE);

    // Bit-identical to the uninterrupted run.
    assert_eq!(st_r.mask.dense(), st_a.mask.dense(), "final masks diverged");
    assert_eq!(st_r.params.data, st_a.params.data, "final params diverged");
    assert_eq!(st_r.mom.data, st_a.mom.data, "final momentum diverged");
    assert_eq!(st_r.budget(), target);
    assert_same_trace(&out_a.iterations, &out_r.iterations);

    // The recorded removal trace accounts for every removed ReLU, so any
    // intermediate mask is reconstructable from ref.cdnl alone.
    let removed_total: usize = run2
        .manifest
        .bcd
        .as_ref()
        .unwrap()
        .iterations
        .iter()
        .map(|it| it.removed.len())
        .sum();
    assert_eq!(removed_total, total - target);
}

#[test]
fn resume_before_first_sweep_replays_from_scratch() {
    let tmp = scratch("fresh");
    let be = RefBackend::standard();
    let pl = Pipeline::new(&be, quick_exp(&tmp)).unwrap();
    let st0 = pl.sess.init_state(11).unwrap();
    let total = st0.budget();
    let target = total - 2 * 24;

    let mut st_a = st0.clone();
    let out_a = run_bcd_resumable(
        &pl.sess,
        &mut st_a,
        &pl.train_ds,
        target,
        &pl.exp.bcd,
        0,
        None,
        &mut |_| Ok(()),
    )
    .unwrap();

    // Killed after the run directory was created but before any sweep
    // completed: only ref.cdnl exists, manifest has no bcd progress.
    let store = RunStore::open(tmp.join("runs"));
    let m = RunManifest::new("bcd", &pl.exp, "reference", total, target);
    let run = store.create(m).unwrap();
    save_state_atomic(&st0, &run.ref_state_path()).unwrap();
    let run_id = run.manifest.run_id.clone();
    drop(run);

    let rd = store.get(&run_id).unwrap();
    let pl2 = Pipeline::new(&be, rd.manifest.experiment().unwrap()).unwrap();
    let (st_r, out_r, run2) = pl2.bcd_resume(rd).unwrap();
    assert_eq!(run2.manifest.status, COMPLETE);
    assert_eq!(st_r.mask.dense(), st_a.mask.dense());
    assert_eq!(st_r.params.data, st_a.params.data);
    assert_same_trace(&out_a.iterations, &out_r.iterations);
}

#[test]
fn resume_rejects_inconsistent_directory() {
    let tmp = scratch("tamper");
    let be = RefBackend::standard();
    let pl = Pipeline::new(&be, quick_exp(&tmp)).unwrap();
    let st0 = pl.sess.init_state(5).unwrap();
    let total = st0.budget();
    let target = total - 24;

    let store = RunStore::open(tmp.join("runs"));
    let m = RunManifest::new("bcd", &pl.exp, "reference", total, target);
    let mut run = store.create(m).unwrap();
    save_state_atomic(&st0, &run.ref_state_path()).unwrap();
    let run_id = run.manifest.run_id.clone();
    let mut st_b = st0.clone();
    let _ = {
        let mut rec = BcdRecorder::new(&mut run);
        run_bcd_resumable(
            &pl.sess,
            &mut st_b,
            &pl.train_ds,
            target,
            &pl.exp.bcd,
            0,
            None,
            &mut |ev| {
                rec.observe(ev)?;
                bail!("kill after first sweep")
            },
        )
    };
    drop(run);

    // Overwrite the sweep-1 checkpoint with the reference state: its budget
    // contradicts the manifest's recorded progress.
    let rd = store.get(&run_id).unwrap();
    save_state_atomic(&st0, &rd.sweep_state_path(1)).unwrap();
    let pl2 = Pipeline::new(&be, rd.manifest.experiment().unwrap()).unwrap();
    let err = format!("{:#}", pl2.bcd_resume(rd).unwrap_err());
    assert!(err.contains("inconsistent"), "wrong error: {err}");
}

#[test]
fn stage_provenance_records_zoo_accesses() {
    let tmp = scratch("stages");
    let be = RefBackend::standard();
    let mut exp = quick_exp(&tmp);
    exp.train.steps = 5;
    exp.train.warmup_steps = 1;
    let pl = Pipeline::new(&be, exp).unwrap();
    let _ = pl.baseline().unwrap();
    let stages = pl.take_stages();
    assert_eq!(stages.len(), 1, "one zoo access expected: {stages:?}");
    assert_eq!(stages[0].stage, "baseline");
    assert!(!stages[0].cached, "first access must be a build");
    assert!(stages[0].path.contains("zoo"), "path should live in the zoo: {}", stages[0].path);
    // Second access hits the cache; the log was drained by take_stages.
    let _ = pl.baseline().unwrap();
    let stages = pl.take_stages();
    assert_eq!(stages.len(), 1);
    assert!(stages[0].cached, "second access must be a cache hit");
}

#[test]
fn completed_runs_do_not_resume() {
    let tmp = scratch("complete");
    let be = RefBackend::standard();
    let pl = Pipeline::new(&be, quick_exp(&tmp)).unwrap();
    let mut st = pl.sess.init_state(3).unwrap();
    let target = st.budget() - 24;

    let store = RunStore::open(tmp.join("runs"));
    let (out, run) = pl.bcd_record(&store, &mut st, target).unwrap();
    assert_eq!(run.manifest.status, COMPLETE);
    assert_eq!(out.final_budget, target);
    assert_eq!(st.budget(), target);
    let run_id = run.manifest.run_id.clone();
    drop(run);

    let rd = store.get(&run_id).unwrap();
    assert!(!rd.manifest.resumable());
    let pl2 = Pipeline::new(&be, rd.manifest.experiment().unwrap()).unwrap();
    let err = format!("{:#}", pl2.bcd_resume(rd).unwrap_err());
    assert!(err.contains("already complete"), "wrong error: {err}");

    // The stored manifest reflects a completed run: full sweep trace, no
    // CLI-level result (the library leaves that to the caller).
    let stored = store.get(&run_id).unwrap();
    assert!(stored.manifest.result.is_none()); // CLI fills this, not the lib
    assert_eq!(stored.manifest.bcd.as_ref().unwrap().sweeps_done, 1);
}
