//! Distributed-scan integration on the pure-Rust reference backend
//! (DESIGN.md §15).
//!
//! The acceptance criterion of the dist subsystem: a full BCD run whose
//! trial scans are served to loopback HTTP workers produces a final mask,
//! parameter vector and iteration trace **bit-identical** to the same run
//! executed single-machine — for any worker membership ({1, 2, 4}), with a
//! worker killed while holding a lease, a late rejoiner, and duplicate
//! completions injected. The CAS backing the params distribution round-trips
//! with streaming verification and rejects tampered content.

use anyhow::bail;
use cdnl::cas::{digest_hex, CasStore};
use cdnl::config::{BcdConfig, Experiment};
use cdnl::coordinator::bcd::run_bcd_resumable;
use cdnl::dist::{dist_scanner, run_worker, HelloDoc, ScanServer, WorkerOpts, DEFAULT_LEASE_MS};
use cdnl::pipeline::Pipeline;
use cdnl::runstore::{save_state_atomic, BcdRecorder, RunManifest, RunStore, COMPLETE};
use cdnl::runtime::{Backend, RefBackend};
use std::collections::BTreeSet;
use std::path::PathBuf;

/// Fresh scratch directory per test (process id + tag keeps parallel test
/// binaries and repeated runs apart).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cdnl_it_dist_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn quick_exp(out_dir: &std::path::Path, rt: usize) -> Experiment {
    let mut exp = Experiment::default();
    exp.out_dir = out_dir.display().to_string();
    exp.bcd = BcdConfig {
        drc: 24,
        rt,
        adt: 0.3,
        finetune_steps: 2,
        finetune_lr: 1e-3,
        proxy_batches: 2,
        seed: 7,
        workers: 2,
        ..Default::default()
    };
    exp
}

fn assert_same_trace(
    a: &[cdnl::coordinator::bcd::IterRecord],
    b: &[cdnl::coordinator::bcd::IterRecord],
) {
    assert_eq!(a.len(), b.len(), "iteration counts differ");
    for (ra, rb) in a.iter().zip(b) {
        assert_eq!(ra.t, rb.t);
        assert_eq!(ra.budget_after, rb.budget_after, "t={}", ra.t);
        assert_eq!(ra.base_acc, rb.base_acc, "t={}", ra.t);
        assert_eq!(ra.chosen_dacc, rb.chosen_dacc, "t={}", ra.t);
        assert_eq!(ra.trials_evaluated, rb.trials_evaluated, "t={}", ra.t);
        assert_eq!(ra.trials_bounded, rb.trials_bounded, "t={}", ra.t);
        assert_eq!(ra.early_accept, rb.early_accept, "t={}", ra.t);
        assert_eq!(ra.finetune.last_loss, rb.finetune.last_loss, "t={}", ra.t);
    }
}

#[test]
fn cas_round_trips_with_streaming_verification() {
    let tmp = scratch("cas");
    let cas = CasStore::open(tmp.join("cas"));

    // Round trip: the put digest is the content digest, reads verify.
    let blob: Vec<u8> = (0..100_000u32).flat_map(|i| i.to_le_bytes()).collect();
    let put = cas.put_bytes(&blob).unwrap();
    assert_eq!(put.digest, digest_hex(&blob));
    assert_eq!(put.bytes, blob.len() as u64);
    assert!(!put.existed);
    assert_eq!(cas.get(&put.digest).unwrap(), blob);
    assert!(cas.put_bytes(&blob).unwrap().existed, "identical content stored once");

    // Tamper with the object behind the store's back: the read-side
    // streaming checksum must reject it — corrupted content is never served.
    let other = cas.put_bytes(b"second object").unwrap();
    let obj = tmp
        .join("cas")
        .join("objects")
        .join(&other.digest[..2])
        .join(&other.digest);
    let mut bytes = std::fs::read(&obj).unwrap();
    bytes[5] ^= 0x40;
    std::fs::write(&obj, &bytes).unwrap();
    let err = format!("{:#}", cas.get(&other.digest).unwrap_err());
    assert!(err.contains("failed verification"), "wrong error: {err}");
    assert!(cas.verify(&other.digest).is_err());
    assert!(cas.verify(&put.digest).unwrap(), "intact object still verifies");

    // gc spares live digests, previews exactly, then removes the rest.
    let live: BTreeSet<String> = [put.digest.clone()].into_iter().collect();
    let preview = cas.gc(&live, true).unwrap();
    assert_eq!(preview, vec![other.digest.clone()]);
    assert!(cas.contains(&other.digest), "dry run must not delete");
    assert_eq!(cas.gc(&live, false).unwrap(), preview);
    assert!(!cas.contains(&other.digest));
    assert!(cas.contains(&put.digest), "live blob survives");
}

#[test]
fn loopback_scan_is_bit_identical_for_any_membership() {
    let tmp = scratch("members");
    let be = RefBackend::standard();
    let pl = Pipeline::new(&be, quick_exp(&tmp, 3)).unwrap();
    let st0 = pl.sess.init_state(42).unwrap();
    let total = st0.budget();
    let target = total - 2 * 24; // two sweeps

    // The single-machine reference.
    let store = RunStore::open(tmp.join("runs"));
    let mut st_local = st0.clone();
    let (out_local, run_local) = pl.bcd_record(&store, &mut st_local, target).unwrap();
    assert_eq!(run_local.manifest.status, COMPLETE);

    for &w in &[1usize, 2, 4] {
        let srv = ScanServer::start(
            "127.0.0.1:0",
            &HelloDoc::for_experiment(&pl.exp, be.name()),
            CasStore::open(tmp.join(format!("cas_{w}"))),
        )
        .unwrap();
        let addr = srv.addr().to_string();
        let mut st = st0.clone();
        let (out, mut run) = std::thread::scope(|s| {
            let workers: Vec<_> = (0..w)
                .map(|i| {
                    let addr = addr.clone();
                    let be = &be;
                    s.spawn(move || {
                        run_worker(
                            &addr,
                            be,
                            &WorkerOpts {
                                id: format!("w{i}"),
                                poll_ms: 5,
                                ..WorkerOpts::default()
                            },
                        )
                    })
                })
                .collect();
            let mut scan = dist_scanner(&srv, &pl.exp.bcd, DEFAULT_LEASE_MS);
            let got = pl.bcd_record_with(&store, &mut st, target, &mut scan);
            srv.shutdown();
            for h in workers {
                h.join().expect("worker thread panicked").unwrap();
            }
            got
        })
        .unwrap();

        // Bit-identical outcome, wherever each trial was scored.
        assert_eq!(st.mask.dense(), st_local.mask.dense(), "{w} workers: masks diverged");
        assert_eq!(st.params.data, st_local.params.data, "{w} workers: params diverged");
        assert_eq!(out.final_budget, out_local.final_budget);
        assert_same_trace(&out_local.iterations, &out.iterations);

        // The recorded run rebuilds to the same config fingerprint.
        let exp2 = run.manifest.experiment().unwrap();
        assert_eq!(exp2.fingerprint(), pl.exp.fingerprint());

        // Blob provenance rides the manifest (one params blob per sweep)
        // and every referenced digest is intact in the CAS.
        let blobs = srv.take_blobs();
        assert_eq!(blobs.len(), 2, "{w} workers: expected one params blob per sweep");
        run.manifest.blobs = Some(blobs.clone());
        run.save().unwrap();
        let cas = CasStore::open(tmp.join(format!("cas_{w}")));
        for b in &blobs {
            assert_eq!(cas.get(&b.digest).unwrap().len(), b.bytes, "blob {}", b.name);
        }
        let live = store.live_blob_digests(&[]).unwrap();
        for b in &blobs {
            assert!(live.contains(&b.digest), "manifest blob {} must be gc-live", b.name);
        }
    }
}

#[test]
fn worker_death_rejoin_and_duplicates_do_not_move_the_outcome() {
    let tmp = scratch("kill");
    let be = RefBackend::standard();
    // rt 8 with slab width 4 gives two slabs per sweep, so one worker can
    // die holding a lease while another still has work to claim.
    let pl = Pipeline::new(&be, quick_exp(&tmp, 8)).unwrap();
    let st0 = pl.sess.init_state(42).unwrap();
    let total = st0.budget();
    let target = total - 2 * 24;

    let store = RunStore::open(tmp.join("runs"));
    let mut st_local = st0.clone();
    let (out_local, _) = pl.bcd_record(&store, &mut st_local, target).unwrap();

    let srv = ScanServer::start(
        "127.0.0.1:0",
        &HelloDoc::for_experiment(&pl.exp, be.name()),
        CasStore::open(tmp.join("cas")),
    )
    .unwrap();
    let addr = srv.addr().to_string();
    let mut st = st0.clone();
    let lease_ms = 300u64;
    let out = std::thread::scope(|s| {
        // The doomed worker joins first, claims sweep 1's first slab and
        // dies without completing it — its lease must be re-issued.
        let a = {
            let addr = addr.clone();
            let be = &be;
            s.spawn(move || {
                run_worker(
                    &addr,
                    be,
                    &WorkerOpts {
                        id: "doomed".into(),
                        poll_ms: 5,
                        die_after_claim: Some(1),
                        ..WorkerOpts::default()
                    },
                )
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(50));
        // The survivor double-posts every completion (zombie injection).
        let b = {
            let addr = addr.clone();
            let be = &be;
            s.spawn(move || {
                run_worker(
                    &addr,
                    be,
                    &WorkerOpts {
                        id: "survivor".into(),
                        poll_ms: 5,
                        duplicate_completions: true,
                        ..WorkerOpts::default()
                    },
                )
            })
        };
        // A fresh worker rejoins mid-run and picks up whatever remains.
        let c = {
            let addr = addr.clone();
            let be = &be;
            s.spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(250));
                run_worker(
                    &addr,
                    be,
                    &WorkerOpts { id: "rejoin".into(), poll_ms: 5, ..WorkerOpts::default() },
                )
            })
        };
        let mut scan = dist_scanner(&srv, &pl.exp.bcd, lease_ms);
        let got = pl.bcd_record_with(&store, &mut st, target, &mut scan);
        srv.shutdown();
        let a = a.join().expect("doomed thread panicked").unwrap();
        assert_eq!(a.slabs, 0, "the doomed worker must die before completing anything");
        b.join().expect("survivor thread panicked").unwrap();
        c.join().expect("rejoin thread panicked").unwrap();
        got
    })
    .unwrap()
    .0;

    // The injected failures really happened...
    let stats = srv.stats();
    assert!(stats.leases_reissued >= 1, "the dangling lease was never re-issued: {stats:?}");
    assert!(stats.duplicate_completions >= 1, "no duplicate was posted: {stats:?}");

    // ...and the outcome never noticed.
    assert_eq!(st.mask.dense(), st_local.mask.dense(), "final masks diverged");
    assert_eq!(st.params.data, st_local.params.data, "final params diverged");
    assert_same_trace(&out_local.iterations, &out.iterations);
}

#[test]
fn killed_local_run_resumes_distributed_bit_identical() {
    let tmp = scratch("resume");
    let be = RefBackend::standard();
    let pl = Pipeline::new(&be, quick_exp(&tmp, 3)).unwrap();
    let st0 = pl.sess.init_state(42).unwrap();
    let total = st0.budget();
    let target = total - 2 * 24;

    // The uninterrupted single-machine reference.
    let mut st_a = st0.clone();
    let out_a = run_bcd_resumable(
        &pl.sess,
        &mut st_a,
        &pl.train_ds,
        target,
        &pl.exp.bcd,
        0,
        None,
        &mut |_| Ok(()),
    )
    .unwrap();

    // A local run killed after sweep 1's checkpoint lands.
    let store = RunStore::open(tmp.join("runs"));
    let m = RunManifest::new("bcd", &pl.exp, "reference", total, target);
    let mut run = store.create(m).unwrap();
    save_state_atomic(&st0, &run.ref_state_path()).unwrap();
    let run_id = run.manifest.run_id.clone();
    let mut st_b = st0.clone();
    let res = {
        let mut rec = BcdRecorder::new(&mut run);
        run_bcd_resumable(
            &pl.sess,
            &mut st_b,
            &pl.train_ds,
            target,
            &pl.exp.bcd,
            0,
            None,
            &mut |ev| {
                rec.observe(ev)?;
                if ev.cursor.sweeps_done == 1 {
                    bail!("simulated kill");
                }
                Ok(())
            },
        )
    };
    assert!(res.is_err(), "the kill must abort the run");
    drop(run);

    // Finish it with the DISTRIBUTED scanner — `cdnl coordinate --resume`:
    // the run.json cursor is substrate-agnostic, so a run started locally
    // resumes onto workers and still lands bit-identical.
    let rd = store.get(&run_id).unwrap();
    let srv = ScanServer::start(
        "127.0.0.1:0",
        &HelloDoc::for_experiment(&pl.exp, be.name()),
        CasStore::open(tmp.join("cas")),
    )
    .unwrap();
    let addr = srv.addr().to_string();
    let (st_r, out_r, run2) = std::thread::scope(|s| {
        let workers: Vec<_> = (0..2)
            .map(|i| {
                let addr = addr.clone();
                let be = &be;
                s.spawn(move || {
                    run_worker(
                        &addr,
                        be,
                        &WorkerOpts { id: format!("r{i}"), poll_ms: 5, ..WorkerOpts::default() },
                    )
                })
            })
            .collect();
        let mut scan = dist_scanner(&srv, &pl.exp.bcd, DEFAULT_LEASE_MS);
        let got = pl.bcd_resume_with(rd, &mut scan);
        srv.shutdown();
        for h in workers {
            h.join().expect("worker thread panicked").unwrap();
        }
        got
    })
    .unwrap();
    assert_eq!(run2.manifest.status, COMPLETE);
    assert_eq!(st_r.mask.dense(), st_a.mask.dense(), "final masks diverged");
    assert_eq!(st_r.params.data, st_a.params.data, "final params diverged");
    assert_eq!(st_r.budget(), target);
    assert_same_trace(&out_a.iterations, &out_r.iterations);
}
