//! Property-based tests on coordinator invariants (mask state, budget
//! allocation, selection, JSON, config) — no PJRT engine required.
//!
//! Uses the in-tree mini property harness (`cdnl::util::prop`): seeded
//! generators + shrinking, DESIGN.md §0's proptest substitute.

use cdnl::methods::{senet::allocate_budget, top_k_mask};
use cdnl::model::{Mask, MaskDelta};
use cdnl::util::json;
use cdnl::util::prng::Rng;
use cdnl::util::prop::check;

/// Random removal sequences keep every Mask view consistent.
#[test]
fn prop_mask_removal_invariants() {
    check(
        0xA11CE,
        60,
        |r| {
            let size = r.usize_below(300) + 2;
            let removals = r.usize_below(size.min(64));
            (size, removals)
        },
        |&(size, removals)| {
            let mut rng = Rng::new(size as u64 * 31 + removals as u64);
            let mut m = Mask::full(size);
            for _ in 0..removals {
                if m.count() == 0 {
                    break;
                }
                let pick = m.sample_present(&mut rng, 1)[0];
                m.remove(pick).map_err(|e| e.to_string())?;
            }
            m.check_invariants().map_err(|e| e.to_string())?;
            let dense_count = m.dense().iter().filter(|&&v| v == 1.0).count();
            if dense_count != m.count() {
                return Err(format!("dense {} != count {}", dense_count, m.count()));
            }
            Ok(())
        },
    );
}

/// sample_present never returns absent or duplicate indices.
#[test]
fn prop_mask_sampling_sound() {
    check(
        0xBEEF,
        60,
        |r| {
            let size = r.usize_below(200) + 10;
            let removed = r.usize_below(size / 2);
            let k = r.usize_below(size - removed - 1) + 1;
            (size, (removed, k))
        },
        |&(size, (removed, k))| {
            let mut rng = Rng::new(size as u64 ^ 0x9E37);
            let mut m = Mask::full(size);
            for i in 0..removed {
                m.remove(i).unwrap();
            }
            let s = m.sample_present(&mut rng, k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            if set.len() != k {
                return Err(format!("duplicates in sample {s:?}"));
            }
            for &i in &s {
                if !m.is_present(i) {
                    return Err(format!("sampled absent index {i}"));
                }
            }
            Ok(())
        },
    );
}

/// hypothesis_into equals clone+apply_removal (pure vs destructive paths).
#[test]
fn prop_hypothesis_matches_apply() {
    check(
        0xCAFE,
        60,
        |r| {
            let size = r.usize_below(150) + 5;
            let k = r.usize_below(size.min(20)) + 1;
            (size, k)
        },
        |&(size, k)| {
            let mut rng = Rng::new(size as u64 * 7919 + k as u64);
            let mut base = Mask::full(size);
            // Remove a random prefix to make the present set non-trivial.
            for i in 0..size / 3 {
                base.remove(i).unwrap();
            }
            if k > base.count() {
                return Ok(());
            }
            let removed = base.sample_present(&mut rng, k);
            let mut scratch = Vec::new();
            base.hypothesis_into(&removed, &mut scratch);
            let mut applied = base.clone();
            applied.apply_removal(&removed).unwrap();
            if scratch != applied.dense() {
                return Err("hypothesis dense != applied dense".into());
            }
            Ok(())
        },
    );
}

/// MaskDelta apply+revert restores the base mask EXACTLY: dense values,
/// the present set, and the position index. Observable from outside via
/// dense(), the invariant checker, and — the part that actually matters
/// for determinism — identical sampling behavior after the round trip.
#[test]
fn prop_mask_delta_roundtrip_exact() {
    check(
        0xDE17A,
        60,
        |r| {
            let size = r.usize_below(200) + 8;
            let pre_removed = r.usize_below(size / 2);
            let k = r.usize_below((size - pre_removed).min(24)) + 1;
            (size, (pre_removed, k))
        },
        |&(size, (pre_removed, k))| {
            let mut rng = Rng::new(size as u64 * 131 + k as u64);
            let mut base = Mask::full(size);
            for _ in 0..pre_removed {
                let pick = base.sample_present(&mut rng, 1)[0];
                base.remove(pick).map_err(|e| e.to_string())?;
            }
            if k > base.count() {
                return Ok(());
            }
            let delta = MaskDelta::new(base.sample_present(&mut rng, k));
            let dense0 = base.dense().to_vec();
            let mut m = base.clone();
            let undo = m.apply_delta(&delta).map_err(|e| e.to_string())?;
            if m.count() != base.count() - delta.len() {
                return Err(format!("count {} after removing {}", m.count(), delta.len()));
            }
            for &i in delta.indices() {
                if m.is_present(i) {
                    return Err(format!("{i} still present after apply"));
                }
            }
            m.check_invariants().map_err(|e| e.to_string())?;
            m.revert_delta(&delta, undo).map_err(|e| e.to_string())?;
            m.check_invariants().map_err(|e| e.to_string())?;
            if m.dense() != dense0.as_slice() {
                return Err("dense values differ after revert".into());
            }
            // Present-set ORDER must be restored exactly, or trial sampling
            // would diverge after a revert; identical draws prove it.
            for probe in 0..3u64 {
                let draw = base.count().min(5).max(1);
                let a = base.sample_present(&mut Rng::new(0x5EED + probe), draw);
                let b = m.sample_present(&mut Rng::new(0x5EED + probe), draw);
                if a != b {
                    return Err(format!("sampling diverged after revert: {a:?} vs {b:?}"));
                }
            }
            Ok(())
        },
    );
}

/// first_dirty_layer always matches a brute-force scan over mask_layers.
#[test]
fn prop_mask_delta_first_dirty_layer_matches_brute_force() {
    use cdnl::runtime::manifest::{ModelInfo, PackEntry};
    check(
        0xD1127,
        60,
        |r| {
            let layers = r.usize_below(6) + 1;
            let sizes: Vec<usize> = (0..layers).map(|_| r.usize_below(40) + 1).collect();
            let k = r.usize_below(8) + 1;
            (sizes, k)
        },
        |&(ref sizes, k)| {
            let mut off = 0usize;
            let mask_layers: Vec<PackEntry> = sizes
                .iter()
                .enumerate()
                .map(|(i, &s)| {
                    let e = PackEntry {
                        name: format!("l{i}"),
                        shape: vec![s],
                        offset: off,
                        size: s,
                    };
                    off += s;
                    e
                })
                .collect();
            let info = ModelInfo {
                key: "t".into(),
                backbone: "resnet".into(),
                num_classes: 2,
                image_size: 4,
                channels: 3,
                poly: false,
                param_size: 1,
                mask_size: off,
                mask_layers,
                param_entries: vec![],
                artifacts: Default::default(),
            };
            let mut rng = Rng::new(off as u64 * 17 + k as u64);
            let mask = Mask::full(off);
            let delta = MaskDelta::new(mask.sample_present(&mut rng, k.min(off)));
            // Brute force: the smallest layer index containing any removed
            // index, scanning the whole mask_layers table.
            let brute = delta
                .indices()
                .iter()
                .map(|&i| {
                    info.mask_layers
                        .iter()
                        .position(|e| i >= e.offset && i < e.offset + e.size)
                        .expect("index outside every layer")
                })
                .min()
                .unwrap_or(info.mask_layers.len());
            if delta.first_dirty_layer(&info) != brute {
                return Err(format!(
                    "first_dirty_layer {} != brute force {brute} for {:?}",
                    delta.first_dirty_layer(&info),
                    delta.indices()
                ));
            }
            Ok(())
        },
    );
}

/// Budget allocation is exact, capped, and monotone in sensitivity.
#[test]
fn prop_allocation_exact_and_capped() {
    check(
        0xD00D,
        80,
        |r| {
            let n = r.usize_below(12) + 1;
            let sizes: Vec<usize> = (0..n).map(|_| r.usize_below(500) + 1).collect();
            let sens: Vec<usize> = (0..n).map(|_| r.usize_below(1000)).collect();
            let total: usize = sizes.iter().sum();
            let budget = r.usize_below(total + 1);
            (sizes, (sens, budget))
        },
        |&(ref sizes, (ref sens, budget))| {
            let sens_f: Vec<f64> = sens.iter().map(|&s| s as f64 / 100.0).collect();
            let alloc = allocate_budget(&sens_f, sizes, budget);
            if alloc.iter().sum::<usize>() != budget {
                return Err(format!("sum {} != budget {budget}", alloc.iter().sum::<usize>()));
            }
            for (a, s) in alloc.iter().zip(sizes) {
                if a > s {
                    return Err(format!("alloc {a} > size {s}"));
                }
            }
            Ok(())
        },
    );
}

/// top_k_mask always hits the budget exactly and keeps the largest scores.
#[test]
fn prop_top_k_exact() {
    check(
        0xF00D,
        80,
        |r| {
            let n = r.usize_below(200) + 1;
            let k = r.usize_below(n + 1);
            (n, k)
        },
        |&(n, k)| {
            let mut rng = Rng::new(n as u64 * 13 + k as u64);
            let scores: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let m = top_k_mask(&scores, k);
            if m.count() != k {
                return Err(format!("count {} != k {k}", m.count()));
            }
            // Every kept score >= every dropped score.
            let kept_min = (0..n)
                .filter(|&i| m.is_present(i))
                .map(|i| scores[i])
                .fold(f32::INFINITY, f32::min);
            let dropped_max = (0..n)
                .filter(|&i| !m.is_present(i))
                .map(|i| scores[i])
                .fold(f32::NEG_INFINITY, f32::max);
            if k > 0 && k < n && kept_min < dropped_max {
                return Err(format!("kept min {kept_min} < dropped max {dropped_max}"));
            }
            Ok(())
        },
    );
}

/// Containment is 1.0 against a superset and multiplicative removals only
/// lower it against unrelated masks.
#[test]
fn prop_containment_bounds() {
    check(
        0x10,
        60,
        |r| {
            let size = r.usize_below(100) + 4;
            let k = r.usize_below(size / 2) + 1;
            (size, k)
        },
        |&(size, k)| {
            let mut rng = Rng::new(size as u64 + (k as u64) << 3);
            let full = Mask::full(size);
            let mut sub = full.clone();
            let rem = sub.sample_present(&mut rng, k);
            sub.apply_removal(&rem).unwrap();
            let c = sub.containment(&full);
            if (c - 1.0).abs() > 1e-12 {
                return Err(format!("subset containment {c} != 1"));
            }
            let c2 = full.containment(&sub);
            let want = sub.count() as f64 / full.count() as f64;
            if (c2 - want).abs() > 1e-12 {
                return Err(format!("superset containment {c2} != {want}"));
            }
            Ok(())
        },
    );
}

/// JSON writer output re-parses to the same structure (fuzzed trees).
#[test]
fn prop_json_roundtrip() {
    fn gen_json(r: &mut Rng, depth: usize) -> json::Json {
        match if depth == 0 { r.usize_below(3) } else { r.usize_below(5) } {
            0 => json::Json::num((r.usize_below(100000) as f64) / 10.0),
            1 => json::Json::str(&format!("s{}", r.usize_below(1000))),
            2 => json::Json::Bool(r.f32() > 0.5),
            3 => json::Json::arr((0..r.usize_below(4)).map(|_| gen_json(r, depth - 1))),
            _ => {
                let n = r.usize_below(4);
                json::Json::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), gen_json(r, depth - 1)))
                        .collect(),
                )
            }
        }
    }
    let mut rng = Rng::new(0x15);
    for _ in 0..100 {
        let v = gen_json(&mut rng, 3);
        let text = v.to_string();
        let back = json::parse(&text).unwrap_or_else(|e| panic!("reparse {text}: {e}"));
        assert_eq!(back.to_string(), text, "unstable roundtrip for {text}");
    }
}

/// Config apply() accepts exactly its documented keys (round-trip fuzz on
/// numeric fields).
#[test]
fn prop_config_numeric_fields_roundtrip() {
    check(
        0x31337,
        50,
        |r| (r.usize_below(500) + 1, r.usize_below(100) + 1),
        |&(drc, rt)| {
            let mut e = cdnl::config::Experiment::default();
            e.apply("bcd.drc", &drc.to_string()).map_err(|x| x)?;
            e.apply("bcd.rt", &rt.to_string()).map_err(|x| x)?;
            if e.bcd.drc != drc || e.bcd.rt != rt {
                return Err("numeric field did not round-trip".into());
            }
            Ok(())
        },
    );
}

/// Per-channel MaskDelta round trip on the real conv topologies
/// (DESIGN.md §12): apply + revert restores the mask exactly over the
/// [C]-shaped mask layers of `resnet18_*` / `wrn22_*`, and
/// first_dirty_layer agrees with a brute-force scan of the conv layer
/// table — including deltas that straddle residual-block boundaries.
#[test]
fn prop_conv_mask_delta_roundtrip_and_dirty_layer() {
    use cdnl::runtime::{Backend, RefBackend};
    let be = RefBackend::standard();
    let keys = ["resnet18_16x16_c10", "wrn22_16x16_c10"];
    let infos: Vec<_> = keys.iter().map(|k| be.model(k).unwrap().clone()).collect();
    check(
        0xC04D,
        60,
        |r| {
            let which = r.usize_below(2);
            let pre = r.usize_below(60);
            let k = r.usize_below(24) + 1;
            (which, (pre, k))
        },
        |&(which, (pre, k))| {
            let info = &infos[which];
            let mut rng = Rng::new(pre as u64 * 131 + k as u64);
            let mut base = Mask::full(info.mask_size);
            for _ in 0..pre {
                let pick = base.sample_present(&mut rng, 1)[0];
                base.remove(pick).map_err(|e| e.to_string())?;
            }
            let delta = MaskDelta::new(base.sample_present(&mut rng, k));
            // Brute-force dirty layer over the conv per-channel layer table.
            let brute = delta
                .indices()
                .iter()
                .map(|&i| info.layer_of(i))
                .min()
                .unwrap_or(info.mask_layers.len());
            if delta.first_dirty_layer(info) != brute {
                return Err(format!(
                    "first_dirty_layer {} != brute {brute}",
                    delta.first_dirty_layer(info)
                ));
            }
            let dense0 = base.dense().to_vec();
            let mut m = base.clone();
            let undo = m.apply_delta(&delta).map_err(|e| e.to_string())?;
            m.check_invariants().map_err(|e| e.to_string())?;
            m.revert_delta(&delta, undo).map_err(|e| e.to_string())?;
            m.check_invariants().map_err(|e| e.to_string())?;
            if m.dense() != dense0.as_slice() {
                return Err("dense differs after conv delta revert".into());
            }
            Ok(())
        },
    );
}

/// Dirty-layer classification against residual-block boundaries: a delta
/// whose indices all lie in layers *after* boundary `b`'s layer must be
/// resumable from `b` (first_dirty_layer > segment_layer(b)), and a delta
/// touching the boundary layer itself must not (staged-execution routing,
/// DESIGN.md §8/§12).
#[test]
fn prop_conv_dirty_layer_vs_block_boundaries() {
    use cdnl::runtime::{Backend, RefBackend};
    let be = RefBackend::standard();
    let keys = ["resnet18_16x16_c10", "wrn22_16x16_c10_poly"];
    check(
        0xB0D1,
        60,
        |r| {
            let which = r.usize_below(2);
            let seg = r.usize_below(6);
            (which, seg)
        },
        |&(which, seg)| {
            let key = keys[which];
            let info = be.model(key).map_err(|e| e.to_string())?.clone();
            let segs = be.segments(key);
            if segs == 0 {
                return Err("conv model reports no segments".into());
            }
            let seg = seg % segs;
            let bl = be.segment_layer(key, seg);
            if bl + 1 >= info.mask_layers.len() {
                return Err(format!("boundary layer {bl} leaves no suffix"));
            }
            // Delta entirely past the boundary: first index of layer bl+1.
            let past = MaskDelta::new(vec![info.mask_layers[bl + 1].offset]);
            if past.first_dirty_layer(&info) <= bl {
                return Err("suffix delta classified dirty at/before boundary".into());
            }
            // Delta touching the boundary layer itself: last index of bl.
            let e = &info.mask_layers[bl];
            let on = MaskDelta::new(vec![e.offset + e.size - 1, info.mask_layers[bl + 1].offset]);
            if on.first_dirty_layer(&info) != bl {
                return Err(format!(
                    "boundary-touching delta dirty at {} != {bl}",
                    on.first_dirty_layer(&info)
                ));
            }
            Ok(())
        },
    );
}

/// Conv kernel padding/stride shape invariants on ragged spatial dims:
/// output dims are `ceil(in/stride)`, and convolving all-ones input with
/// all-ones weights makes every output element equal `cin` times its
/// in-bounds tap count — which pins the 'SAME' pad split (odd extra on the
/// trailing edge) exactly. dinput is input-shaped and dweight accumulates.
#[test]
fn prop_conv_same_padding_shapes() {
    use cdnl::runtime::kernels::{
        conv2d_same_dinput, conv2d_same_dweight, conv2d_same_into, conv_out_dim, same_pad_before,
    };
    check(
        0x5A4E,
        60,
        |r| {
            let h = r.usize_below(9) + 3; // 3..=11, odd and even
            let w = r.usize_below(9) + 3;
            let stride = r.usize_below(2) + 1;
            let k = 1 + 2 * r.usize_below(2); // 1 or 3
            (h, (w, (stride, k)))
        },
        |&(h, (w, (stride, k)))| {
            let (n, cin, cout) = (2usize, 3usize, 2usize);
            let (oh, ow) = (conv_out_dim(h, stride), conv_out_dim(w, stride));
            if oh != h.div_ceil(stride) || ow != w.div_ceil(stride) {
                return Err(format!("out dims ({oh},{ow}) != ceil division"));
            }
            let (py, px) = (same_pad_before(h, k, stride), same_pad_before(w, k, stride));
            if py >= k.max(1) || px >= k.max(1) {
                return Err(format!("pad ({py},{px}) >= kernel {k}"));
            }
            let x = vec![1.0f32; n * cin * h * w];
            let wts = vec![1.0f32; cout * cin * k * k];
            let mut out = Vec::new();
            conv2d_same_into(&x, &wts, n, cin, h, w, cout, k, stride, &mut out);
            if out.len() != n * cout * oh * ow {
                return Err(format!("conv out len {} != {}", out.len(), n * cout * oh * ow));
            }
            // Ones-in/ones-weights oracle: output = cin * (in-bounds taps).
            for oy in 0..oh {
                for ox in 0..ow {
                    let taps_y = (0..k)
                        .filter(|ky| {
                            let iy = (oy * stride + ky) as isize - py as isize;
                            iy >= 0 && (iy as usize) < h
                        })
                        .count();
                    let taps_x = (0..k)
                        .filter(|kx| {
                            let ix = (ox * stride + kx) as isize - px as isize;
                            ix >= 0 && (ix as usize) < w
                        })
                        .count();
                    let want = (cin * taps_y * taps_x) as f32;
                    let got = out[oy * ow + ox]; // n=0, cout=0 plane
                    if got != want {
                        return Err(format!("taps at ({oy},{ox}): {got} != {want}"));
                    }
                }
            }
            let dx = conv2d_same_dinput(&out, &wts, n, cin, h, w, cout, k, stride);
            if dx.len() != x.len() {
                return Err(format!("dinput len {} != input {}", dx.len(), x.len()));
            }
            // dweight accumulates: a second call exactly doubles the buffer.
            let mut dw = vec![0.0f32; wts.len()];
            conv2d_same_dweight(&x, &out, &mut dw, n, cin, h, w, cout, k, stride);
            let once = dw.clone();
            conv2d_same_dweight(&x, &out, &mut dw, n, cin, h, w, cout, k, stride);
            for (a, b) in dw.iter().zip(&once) {
                if *a != 2.0 * *b {
                    return Err("dweight does not accumulate additively".into());
                }
            }
            Ok(())
        },
    );
}

/// im2col/col2im are adjoint linear maps (DESIGN.md §13): for random
/// shapes (including stride 2 and 1x1 kernels), `⟨im2col(x), p⟩ ==
/// ⟨x, col2im(p)⟩` up to f64 summation error, and on integer-valued
/// inputs the roundtrip `col2im(im2col(x))` exactly multiplies each
/// element by its in-bounds tap count (repeated integer adds are exact
/// in f32 at these sizes).
#[test]
fn prop_im2col_col2im_adjoint_and_roundtrip() {
    use cdnl::runtime::lowering::{col2im, im2col_t};
    check(
        0x1A2C,
        60,
        |r| {
            let cin = r.usize_below(3) + 1;
            let h = r.usize_below(9) + 1; // 1..=9: degenerate dims included
            let w = r.usize_below(9) + 1;
            let stride = r.usize_below(2) + 1;
            let k = 1 + 2 * r.usize_below(2); // 1 or 3
            (cin, (h, (w, (stride, k))))
        },
        |&(cin, (h, (w, (stride, k))))| {
            let mut rng = Rng::new((cin * h * w * stride * k) as u64 ^ 0xADA0);
            let x: Vec<f32> = (0..cin * h * w).map(|_| rng.normal()).collect();
            let mut pt = Vec::new();
            im2col_t(&x, cin, h, w, k, stride, &mut pt);
            let p: Vec<f32> = (0..pt.len()).map(|_| rng.normal()).collect();
            let lhs: f64 = pt.iter().zip(&p).map(|(&a, &b)| a as f64 * b as f64).sum();
            let mut back = vec![0.0f32; x.len()];
            col2im(&p, cin, h, w, k, stride, &mut back);
            let rhs: f64 = x.iter().zip(&back).map(|(&a, &b)| a as f64 * b as f64).sum();
            let scale = 1.0f64.max(lhs.abs()).max(rhs.abs());
            if (lhs - rhs).abs() > 1e-4 * scale {
                return Err(format!("adjoint broken: ⟨Px,p⟩={lhs} vs ⟨x,P*p⟩={rhs}"));
            }
            // Integer roundtrip: each element times its tap count, exactly.
            let xi: Vec<f32> = (0..cin * h * w).map(|i| (i % 7 + 1) as f32).collect();
            let mut pti = Vec::new();
            im2col_t(&xi, cin, h, w, k, stride, &mut pti);
            let mut got = vec![0.0f32; xi.len()];
            col2im(&pti, cin, h, w, k, stride, &mut got);
            let ones = vec![1.0f32; xi.len()];
            let mut pt1 = Vec::new();
            im2col_t(&ones, cin, h, w, k, stride, &mut pt1);
            let mut taps = vec![0.0f32; xi.len()];
            col2im(&pt1, cin, h, w, k, stride, &mut taps);
            for i in 0..xi.len() {
                if got[i] != taps[i] * xi[i] {
                    return Err(format!(
                        "roundtrip at {i}: {} != {} taps x {}",
                        got[i], taps[i], xi[i]
                    ));
                }
            }
            Ok(())
        },
    );
}

/// The GEMM-lowered conv kernels are bit-identical to the retained direct
/// loops on random shapes — forward, dinput, and dweight (which must also
/// continue an existing accumulation, not overwrite it). This is the §13
/// replay contract as a property, beyond the fixed shape battery in the
/// kernel unit tests.
#[test]
fn prop_conv_lowering_bitwise_equals_direct() {
    use cdnl::runtime::kernels::{
        conv2d_same_dinput_direct, conv2d_same_dweight_direct, conv2d_same_direct_into,
        conv_out_dim,
    };
    use cdnl::runtime::lowering::{
        conv2d_lowered_dinput, conv2d_lowered_dweight, conv2d_lowered_into, Scratch,
    };
    check(
        0xB17E,
        40,
        |r| {
            let n = r.usize_below(2) + 1;
            let cin = r.usize_below(3) + 1;
            let h = r.usize_below(7) + 1;
            let w = r.usize_below(7) + 1;
            let cout = r.usize_below(3) + 1;
            let stride = r.usize_below(2) + 1;
            let k = 1 + 2 * r.usize_below(2); // 1 or 3
            (n, (cin, (h, (w, (cout, (stride, k))))))
        },
        |&(n, (cin, (h, (w, (cout, (stride, k))))))| {
            let mut rng = Rng::new((n * cin * h * w * cout * stride * k) as u64 ^ 0x10E3);
            let mut s = Scratch::new();
            // Exact zeros sprinkled in: they exercise the GEMM's zero-skip
            // and the padding-tap ±0.0 argument, the two places the term
            // sets differ between routes.
            let x: Vec<f32> = (0..n * cin * h * w)
                .map(|i| if i % 5 == 0 { 0.0 } else { rng.normal() })
                .collect();
            let wt: Vec<f32> = (0..cout * cin * k * k)
                .map(|i| if i % 7 == 0 { 0.0 } else { rng.normal() })
                .collect();
            let mut want = Vec::new();
            conv2d_same_direct_into(&x, &wt, n, cin, h, w, cout, k, stride, &mut want);
            let mut got = Vec::new();
            conv2d_lowered_into(&x, &wt, n, cin, h, w, cout, k, stride, &mut got, &mut s);
            if got != want {
                return Err("lowered forward != direct bitwise".into());
            }
            let (oh, ow) = (conv_out_dim(h, stride), conv_out_dim(w, stride));
            let dy: Vec<f32> = (0..n * cout * oh * ow)
                .map(|i| if i % 6 == 0 { 0.0 } else { rng.normal() })
                .collect();
            let want_dx = conv2d_same_dinput_direct(&dy, &wt, n, cin, h, w, cout, k, stride);
            let got_dx = conv2d_lowered_dinput(&dy, &wt, n, cin, h, w, cout, k, stride, &mut s);
            if got_dx != want_dx {
                return Err("lowered dinput != direct bitwise".into());
            }
            let prior: Vec<f32> = (0..wt.len()).map(|_| rng.normal()).collect();
            let mut want_dw = prior.clone();
            conv2d_same_dweight_direct(&x, &dy, &mut want_dw, n, cin, h, w, cout, k, stride);
            let mut got_dw = prior;
            conv2d_lowered_dweight(&x, &dy, &mut got_dw, n, cin, h, w, cout, k, stride, &mut s);
            if got_dw != want_dw {
                return Err("lowered dweight != direct bitwise".into());
            }
            Ok(())
        },
    );
}

/// Removing a whole layer then checking histogram slots zero out.
#[test]
fn prop_layer_histogram_consistent() {
    use cdnl::runtime::manifest::{ModelInfo, PackEntry};
    check(
        0x77,
        40,
        |r| {
            let layers = r.usize_below(6) + 1;
            let sizes: Vec<usize> = (0..layers).map(|_| r.usize_below(50) + 1).collect();
            (sizes, 0usize)
        },
        |&(ref sizes, _)| {
            let mut off = 0;
            let mask_layers: Vec<PackEntry> = sizes
                .iter()
                .enumerate()
                .map(|(i, &s)| {
                    let e = PackEntry {
                        name: format!("l{i}"),
                        shape: vec![s],
                        offset: off,
                        size: s,
                    };
                    off += s;
                    e
                })
                .collect();
            let info = ModelInfo {
                key: "t".into(),
                backbone: "resnet".into(),
                num_classes: 2,
                image_size: 4,
                channels: 3,
                poly: false,
                param_size: 1,
                mask_size: off,
                mask_layers,
                param_entries: vec![],
                artifacts: Default::default(),
            };
            let mut m = Mask::full(off);
            let hist0 = m.layer_histogram(&info);
            if hist0 != *sizes {
                return Err(format!("full histogram {hist0:?} != sizes {sizes:?}"));
            }
            let victim = sizes.len() / 2;
            m.remove_layer(&info, victim);
            let hist = m.layer_histogram(&info);
            if hist[victim] != 0 {
                return Err(format!("layer {victim} not emptied: {hist:?}"));
            }
            let expect: usize = sizes.iter().sum::<usize>() - sizes[victim];
            if m.count() != expect {
                return Err(format!("count {} != {expect}", m.count()));
            }
            Ok(())
        },
    );
}

/// PI online-round count is monotone non-increasing as the mask gets
/// sparser (DESIGN.md §14): removing ReLUs can only empty layers, never
/// activate one, so `trace::simulate`'s round count — `2·active + 2` —
/// never goes up along any removal trajectory.
#[test]
fn prop_pi_rounds_monotone_under_sparsity() {
    use cdnl::pi::{simulate, LAN};
    use cdnl::runtime::{Backend, RefBackend};
    let be = RefBackend::standard();
    let keys = ["resnet18_16x16_c10", "wrn22_16x16_c10"];
    let infos: Vec<_> = keys.iter().map(|k| be.model(k).unwrap().clone()).collect();
    check(
        0x5E21E,
        40,
        |r| {
            let which = r.usize_below(2);
            let steps = r.usize_below(12) + 2;
            let chunk = r.usize_below(40) + 1;
            (which, (steps, chunk))
        },
        |&(which, (steps, chunk))| {
            let info = &infos[which];
            let mut rng = Rng::new(steps as u64 * 8191 + chunk as u64);
            let mut mask = Mask::full(info.mask_size);
            let mut prev = simulate(info, &mask, &LAN);
            for _ in 0..steps {
                let k = chunk.min(mask.count());
                if k == 0 {
                    break;
                }
                let doomed = mask.sample_present(&mut rng, k);
                mask.apply_removal(&doomed).map_err(|e| e.to_string())?;
                let tr = simulate(info, &mask, &LAN);
                if tr.rounds > prev.rounds {
                    return Err(format!(
                        "rounds grew under sparsity: {} -> {} at count {}",
                        prev.rounds,
                        tr.rounds,
                        mask.count()
                    ));
                }
                if tr.relu_rounds() > prev.relu_rounds() {
                    return Err("relu_rounds grew under sparsity".into());
                }
                prev = tr;
            }
            Ok(())
        },
    );
}

/// A fully linearized network (every ReLU removed) has ZERO ReLU-phase
/// rounds under every protocol: the online phase collapses to the input
/// upload + result download pair, and no garbled-circuit bytes move.
#[test]
fn prop_pi_fully_linearized_zero_relu_rounds() {
    use cdnl::pi::{simulate, LAN, MOBILE, WAN};
    use cdnl::runtime::{Backend, RefBackend};
    let be = RefBackend::standard();
    let keys = ["resnet18_16x16_c10", "wrn22_16x16_c10"];
    let infos: Vec<_> = keys.iter().map(|k| be.model(k).unwrap().clone()).collect();
    check(
        0x0F00D,
        30,
        |r| (r.usize_below(2), r.usize_below(3)),
        |&(which, p)| {
            let info = &infos[which];
            let proto = [&LAN, &WAN, &MOBILE][p];
            let mut mask = Mask::full(info.mask_size);
            let all: Vec<usize> = (0..info.mask_size).collect();
            mask.apply_removal(&all).map_err(|e| e.to_string())?;
            let tr = simulate(info, &mask, proto);
            if tr.relu_rounds() != 0 {
                return Err(format!("{} relu rounds on a linear network", tr.relu_rounds()));
            }
            if tr.rounds != 2 {
                return Err(format!("linear network took {} rounds, want 2", tr.rounds));
            }
            if tr.gc_bytes != 0 {
                return Err(format!("{} GC bytes moved with zero ReLUs", tr.gc_bytes));
            }
            Ok(())
        },
    );
}

/// At 1 client x 1 request the serving simulator degenerates to a single
/// replay of the `pi::trace` message script: per-direction byte totals
/// and the online-round count match `simulate` exactly, for any protocol,
/// arrival rate, seed, and mask sparsity.
#[test]
fn prop_pi_serve_single_client_conserves_trace() {
    use cdnl::pi::serve::{serve, ServeConfig};
    use cdnl::pi::{simulate, LAN, MOBILE, WAN};
    use cdnl::runtime::{Backend, RefBackend};
    let be = RefBackend::standard();
    let keys = ["resnet18_16x16_c10", "wrn22_16x16_c10"];
    let infos: Vec<_> = keys.iter().map(|k| be.model(k).unwrap().clone()).collect();
    check(
        0x1C0DE,
        30,
        |r| {
            let which = r.usize_below(2);
            let p = r.usize_below(3);
            let removed = r.usize_below(400);
            let rate_x10 = r.usize_below(500) + 1; // 0.1 .. 50.0 req/s
            let seed = r.usize_below(1 << 16) as u64;
            (which, (p, (removed, (rate_x10, seed))))
        },
        |&(which, (p, (removed, (rate_x10, seed))))| {
            let info = &infos[which];
            let proto = [&LAN, &WAN, &MOBILE][p];
            let mut rng = Rng::new(seed ^ 0x5EED);
            let mut mask = Mask::full(info.mask_size);
            let k = removed.min(info.mask_size);
            if k > 0 {
                let doomed = mask.sample_present(&mut rng, k);
                mask.apply_removal(&doomed).map_err(|e| e.to_string())?;
            }
            let cfg = ServeConfig {
                clients: 1,
                requests: 1,
                arrival_rate: rate_x10 as f64 / 10.0,
                batch_window: 1,
                prep_ahead: 1,
                seed,
            };
            let r = serve(info, &mask, proto, &cfg).map_err(|e| e.to_string())?;
            let tr = simulate(info, &mask, proto);
            if r.completed != 1 {
                return Err(format!("{} completions, want 1", r.completed));
            }
            if r.up_bytes != tr.up_bytes() as usize {
                return Err(format!("up {} != trace {}", r.up_bytes, tr.up_bytes()));
            }
            if r.down_bytes != tr.down_bytes() as usize {
                return Err(format!("down {} != trace {}", r.down_bytes, tr.down_bytes()));
            }
            if r.online_rounds != tr.rounds {
                return Err(format!("rounds {} != trace {}", r.online_rounds, tr.rounds));
            }
            Ok(())
        },
    );
}
