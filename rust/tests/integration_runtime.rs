//! Runtime integration: HLO-text artifacts load, compile and execute with
//! correct semantics through the PJRT CPU client.
//!
//! One #[test] running staged checks sequentially — a PJRT client per test
//! thread is wasteful. Requires `--features pjrt` (compiled out otherwise)
//! and `make artifacts`; skips (with a message) when artifacts/ is absent.
//! The backend-agnostic twin of this test lives in
//! `integration_reference.rs` and always runs.

#![cfg(feature = "pjrt")]

use cdnl::model::Mask;
use cdnl::runtime::engine::Engine;
use cdnl::runtime::session::Session;
use cdnl::runtime::{Backend, HostArg};
use cdnl::tensor::{Tensor, TensorI32};
use std::path::Path;

const MODEL: &str = "resnet_16x16_c10";

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        None
    }
}

#[test]
fn runtime_end_to_end() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("SKIP: run `make artifacts` first");
        return;
    };
    let engine = Engine::new(dir).expect("engine");
    let sess = Session::new(&engine, MODEL).expect("session");
    let info = sess.info();
    let batch = sess.batch;

    // --- manifest sanity --------------------------------------------------
    assert!(info.param_size > 0 && info.mask_size > 0);
    assert_eq!(info.num_classes, 10);
    assert!(Session::new(&engine, "no_such_model").is_err());

    // --- init: deterministic in the seed, seed-sensitive -------------------
    let p1 = sess.init(7).expect("init");
    let p2 = sess.init(7).expect("init");
    let p3 = sess.init(8).expect("init");
    assert_eq!(p1.data, p2.data, "init must be deterministic");
    assert_ne!(p1.data, p3.data, "different seeds must differ");
    assert_eq!(p1.len(), info.param_size);
    assert!(p1.data.iter().all(|v| v.is_finite()));

    // --- forward: shape + mask sensitivity ---------------------------------
    let full = vec![1.0f32; info.mask_size];
    let zero = vec![0.0f32; info.mask_size];
    let mut x = Tensor::zeros(vec![batch, info.channels, info.image_size, info.image_size]);
    // Deterministic pseudo-images.
    for (i, v) in x.data.iter_mut().enumerate() {
        *v = ((i % 37) as f32 - 18.0) / 18.0;
    }
    let logits_full = sess.forward(&p1, &full, &x).expect("forward");
    assert_eq!(logits_full.shape, vec![batch, info.num_classes]);
    let logits_lin = sess.forward(&p1, &zero, &x).expect("forward zero-mask");
    assert_ne!(
        logits_full.data, logits_lin.data,
        "linearizing all ReLUs must change the output"
    );
    // Forward is pure: same inputs, same outputs.
    let logits_again = sess.forward(&p1, &full, &x).expect("forward repeat");
    assert_eq!(logits_full.data, logits_again.data);

    // --- eval_batch agrees with forward-side argmax -------------------------
    let y = TensorI32::new(vec![batch], (0..batch).map(|i| (i % 10) as i32).collect());
    let out = sess.eval_batch(&p1, &full, &x, &y).expect("eval");
    let preds = logits_full.argmax_rows().unwrap();
    let want: f32 = preds
        .iter()
        .zip(&y.data)
        .filter(|(p, &t)| **p == t as usize)
        .count() as f32;
    assert_eq!(out.correct, want, "eval_batch correct-count mismatch");
    assert!(out.loss > 0.0 && out.loss.is_finite());

    // --- literal path == buffer path ----------------------------------------
    let pbuf = engine.upload_f32(&p1.data, &p1.shape).expect("upload p");
    let mbuf = engine.upload_f32(&full, &[full.len()]).expect("upload m");
    let (xbuf, ybuf) = sess.upload_batch(&x, &y).expect("upload batch");
    let out_b = sess.eval_batch_b(&pbuf, &mbuf, &xbuf, &ybuf).expect("eval_b");
    assert_eq!(out.correct, out_b.correct, "buffer path diverges from literal path");
    assert!((out.loss - out_b.loss).abs() < 1e-5);

    // --- input validation errors are readable, not aborts -------------------
    let bad = Tensor::zeros(vec![3]);
    let err = match engine.call(MODEL, "forward", &[HostArg::F32(&bad)]) {
        Ok(_) => panic!("arity error not detected"),
        Err(e) => e.to_string(),
    };
    assert!(err.contains("inputs"), "unhelpful arity error: {err}");

    // --- masks: partial linearization moves logits monotonically-ish --------
    // (not a strict property, but removing *some* ReLUs must produce output
    // between "no change" and "all removed" in the sense of being different
    // from both with overwhelming probability)
    let mut half = Mask::full(info.mask_size);
    for i in 0..info.mask_size / 2 {
        half.remove(i).unwrap();
    }
    let logits_half = sess.forward(&p1, half.dense(), &x).expect("half");
    assert_ne!(logits_half.data, logits_full.data);
    assert_ne!(logits_half.data, logits_lin.data);

    // --- stats accounting ----------------------------------------------------
    let stats = engine.stats();
    let fwd_stats = stats.get(&format!("{MODEL}:forward")).expect("forward stats");
    assert_eq!(fwd_stats.calls, 4);
    assert!(fwd_stats.compile_secs > 0.0);
    let eval_stats = stats.get(&format!("{MODEL}:eval_batch")).expect("eval stats");
    assert_eq!(eval_stats.calls, 2);
}
