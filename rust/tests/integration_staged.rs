//! Staged execution (prefix-activation reuse) and batched multi-trial
//! scoring — the incremental-vs-full determinism contract, end-to-end on
//! the reference backend (DESIGN.md §8, §11).
//!
//! The acceptance bar: the same BCD configuration run with the prefix
//! cache disabled (`bcd.cache_mb = 0`, every trial a full forward) and
//! enabled (`> 0`, staged forwards where the delta allows), across worker
//! counts AND hypothesis-slab widths (`bcd.trial_batch`), must produce
//! identical `ScanOutcome`s, `IterRecord`s, and run-manifest fingerprints.
//! Debug builds additionally check every staged/batched batch against a
//! full forward inside the evaluator itself (release builds do the same
//! under `bcd.verify_staged`).

use cdnl::config::{BcdConfig, Experiment, Granularity};
use cdnl::coordinator::bcd::run_bcd;
use cdnl::coordinator::eval::{EvalOpts, Evaluator, TrialEval};
use cdnl::coordinator::trials::{scan_trials, BlockSampler, ScanOutcome};
use cdnl::data::{synth, Dataset};
use cdnl::model::MaskDelta;
use cdnl::runstore::RunManifest;
use cdnl::runtime::{RefBackend, Session};
use cdnl::util::prng::Rng;

const MODEL: &str = "resnet_16x16_c10";

fn backend() -> RefBackend {
    RefBackend::standard()
}

fn small_synth10() -> Dataset {
    let (train, _) = synth::generate(&synth::SynthSpec {
        train_n: 96,
        test_n: 16,
        ..synth::SYNTH10
    });
    train
}

fn scan_with(cache_mb: usize, workers: usize, drc: usize, rt: usize, adt: f64) -> ScanOutcome {
    scan_with_batch(cache_mb, workers, 1, drc, rt, adt)
}

fn scan_with_batch(
    cache_mb: usize,
    workers: usize,
    trial_batch: usize,
    drc: usize,
    rt: usize,
    adt: f64,
) -> ScanOutcome {
    let be = backend();
    let sess = Session::new(&be, MODEL).unwrap();
    let ds = small_synth10();
    let st = sess.init_state(42).unwrap();
    let ev = Evaluator::with_opts(
        &sess,
        &ds,
        2,
        EvalOpts {
            cache_bytes: cache_mb * (1 << 20),
            trial_batch,
            verify_staged: false,
            verify_lowering: false,
        },
    )
    .unwrap();
    let params = ev.upload_params(&st.params).unwrap();
    let base = ev.accuracy(&params, st.mask.dense()).unwrap();
    let sampler = BlockSampler::new(Granularity::Pixel, sess.info());
    let mut rng = Rng::new(0xFACE);
    scan_trials(&ev, &params, &st.mask, &sampler, drc, rt, adt, base, &mut rng, workers).unwrap()
}

#[test]
fn scan_outcome_identical_with_and_without_cache() {
    // Low DRC maximizes staged-path coverage (hypotheses confined to mask
    // layer 1); the higher-DRC and early-accept configs exercise fallback
    // and the bound/accept interplay.
    for &(drc, rt, adt) in &[(1usize, 10usize, -1000.0f64), (4, 8, 0.5), (24, 8, 1000.0)] {
        let reference = scan_with(0, 1, drc, rt, adt);
        for &cache in &[0usize, 16] {
            for &w in &[1usize, 4] {
                let out = scan_with(cache, w, drc, rt, adt);
                assert_eq!(
                    reference, out,
                    "scan diverged at cache={cache} workers={w} drc={drc} adt={adt}"
                );
            }
        }
    }
}

#[test]
fn scan_outcome_identical_across_trial_batch_widths() {
    // The tentpole contract of DESIGN.md §11: the hypothesis-slab width is
    // pure throughput. The grid covers remainder slabs (rt = 10 does not
    // divide by 4, and 32 exceeds the whole hypothesis set), early accepts
    // landing mid-slab (adt = 1000 accepts the first scored trial), bound
    // cuts inside a slab (adt = 0.5 keeps a live floor), and the staged /
    // full route split at each cache setting.
    for &(drc, rt, adt) in &[(1usize, 10usize, -1000.0f64), (4, 8, 0.5), (2, 10, 1000.0)] {
        let reference = scan_with_batch(0, 1, 1, drc, rt, adt);
        for &tb in &[1usize, 4, 32] {
            for &cache in &[0usize, 16] {
                for &w in &[1usize, 4] {
                    let out = scan_with_batch(cache, w, tb, drc, rt, adt);
                    assert_eq!(
                        reference, out,
                        "scan diverged at trial_batch={tb} cache={cache} workers={w} \
                         drc={drc} adt={adt}"
                    );
                }
            }
        }
    }
}

/// One scan on a conv topology (DESIGN.md §12): multi-segment boundary
/// table, image-shaped prefix entries, per-channel deltas. Returns the
/// outcome plus the staged-trial counter so callers can assert the staged
/// route actually ran (an all-full-forward pass would vacuously "agree").
fn conv_scan(model: &str, cache_mb: usize, workers: usize, trial_batch: usize) -> (ScanOutcome, usize) {
    let be = backend();
    let sess = Session::new(&be, model).unwrap();
    let ds = small_synth10();
    let st = sess.init_state(11).unwrap();
    let ev = Evaluator::with_opts(
        &sess,
        &ds,
        2,
        EvalOpts {
            cache_bytes: cache_mb * (1 << 20),
            trial_batch,
            verify_staged: true,
            verify_lowering: true,
        },
    )
    .unwrap();
    let params = ev.upload_params(&st.params).unwrap();
    let base = ev.accuracy(&params, st.mask.dense()).unwrap();
    let sampler = BlockSampler::new(Granularity::Pixel, sess.info());
    let mut rng = Rng::new(0xC0FE);
    // DRC 1: single-channel deltas land in deep layers most of the time, so
    // the enabled-cache runs exercise resume-from-boundary on real residual
    // blocks (verify_staged cross-checks every such batch internally).
    let out =
        scan_trials(&ev, &params, &st.mask, &sampler, 1, 8, 0.3, base, &mut rng, workers).unwrap();
    let (_, staged_trials, _, _, _) = ev.batch_counters();
    (out, staged_trials)
}

#[test]
fn conv_scan_outcome_identical_across_cache_workers_and_batch() {
    // Satellite of the conv-backend tentpole: ScanOutcome identity on a
    // residual topology across trial_batch {1,32} x cache {0,16MB} x
    // workers {1,4}, against the cache-off sequential width-1 reference.
    let (reference, ref_staged) = conv_scan("resnet18_16x16_c10", 0, 1, 1);
    assert_eq!(ref_staged, 0, "cache-off reference must not stage");
    let mut staged_total = 0usize;
    for &tb in &[1usize, 32] {
        for &cache in &[0usize, 16] {
            for &w in &[1usize, 4] {
                let (out, staged) = conv_scan("resnet18_16x16_c10", cache, w, tb);
                assert_eq!(
                    reference, out,
                    "conv scan diverged at trial_batch={tb} cache={cache} workers={w}"
                );
                if cache > 0 {
                    staged_total += staged;
                }
            }
        }
    }
    assert!(staged_total > 0, "no trial took the staged route on the conv model");

    // WRN residual topology: same contract, spot-checked at the widest
    // slab / most parallel corner.
    let (wrn_ref, _) = conv_scan("wrn22_16x16_c10", 0, 1, 1);
    let (wrn_out, wrn_staged) = conv_scan("wrn22_16x16_c10", 16, 4, 32);
    assert_eq!(wrn_ref, wrn_out, "wrn scan diverged at cache=16 workers=4 trial_batch=32");
    assert!(wrn_staged > 0, "wrn run with cache on must stage some trials");
}

#[test]
fn conv_run_manifest_fingerprint_semantics() {
    // Conv experiments keep the same fingerprint discipline: throughput
    // knobs are identity-free, while the backbone and the model.* sizing
    // keys are semantic.
    let mut a = Experiment::default();
    a.apply("backbone", "resnet18").unwrap();
    a.apply("bcd.cache_mb", "0").unwrap();
    a.apply("bcd.trial_batch", "1").unwrap();
    a.apply("bcd.workers", "1").unwrap();
    let mut b = Experiment::default();
    b.apply("backbone", "resnet18").unwrap();
    b.apply("bcd.cache_mb", "16").unwrap();
    b.apply("bcd.trial_batch", "32").unwrap();
    b.apply("bcd.workers", "4").unwrap();
    let ma = RunManifest::new("bcd", &a, "reference", 200, 100);
    let mb = RunManifest::new("bcd", &b, "reference", 200, 100);
    assert_eq!(ma.config_fingerprint, mb.config_fingerprint);
    let mut c = Experiment::default();
    c.apply("backbone", "wrn22").unwrap();
    let mc = RunManifest::new("bcd", &c, "reference", 200, 100);
    assert_ne!(ma.config_fingerprint, mc.config_fingerprint, "backbone is semantic");
    let mut d = Experiment::default();
    d.apply("backbone", "resnet18").unwrap();
    d.apply("model.conv_base", "16").unwrap();
    let md = RunManifest::new("bcd", &d, "reference", 200, 100);
    assert_ne!(ma.config_fingerprint, md.config_fingerprint, "model sizing is semantic");
}

#[test]
fn bcd_bit_identical_across_cache_and_workers() {
    let be = backend();
    let sess = Session::new(&be, MODEL).unwrap();
    let ds = small_synth10();
    let total = sess.init_state(1).unwrap().budget();
    let target = total - 60;

    let run = |cache_mb: usize, workers: usize, trial_batch: usize| {
        let mut st = sess.init_state(1).unwrap();
        let cfg = BcdConfig {
            drc: 12, // small DRC: many hypotheses stay inside mask layer 1
            rt: 6,
            adt: 0.3,
            finetune_steps: 2,
            finetune_lr: 1e-3,
            proxy_batches: 2,
            seed: 7,
            workers,
            cache_mb,
            trial_batch,
            ..Default::default()
        };
        let out = run_bcd(&sess, &mut st, &ds, target, &cfg, 0).unwrap();
        (st, out)
    };
    // Ground truth: cache disabled, sequential scan, slab width 1.
    let (st0, out0) = run(0, 1, 1);
    for &(cache, workers, trial_batch) in &[
        (16usize, 1usize, 1usize),
        (0, 4, 1),
        (16, 4, 1),
        (16, 1, 4),
        (16, 4, 32),
        (0, 1, 8),
    ] {
        let (st, out) = run(cache, workers, trial_batch);
        assert_eq!(
            st0.mask.dense(),
            st.mask.dense(),
            "mask diverged (cache={cache}, workers={workers}, trial_batch={trial_batch})"
        );
        assert_eq!(
            st0.params.data, st.params.data,
            "params diverged (cache={cache}, workers={workers}, trial_batch={trial_batch})"
        );
        assert_eq!(out0.iterations.len(), out.iterations.len());
        for (a, b) in out0.iterations.iter().zip(&out.iterations) {
            assert_eq!(a.t, b.t);
            assert_eq!(a.budget_after, b.budget_after);
            assert_eq!(a.base_acc, b.base_acc);
            assert_eq!(a.chosen_dacc, b.chosen_dacc);
            assert_eq!(a.trials_evaluated, b.trials_evaluated);
            assert_eq!(a.trials_bounded, b.trials_bounded);
            assert_eq!(a.early_accept, b.early_accept);
            assert_eq!(a.finetune.steps, b.finetune.steps);
            assert_eq!(a.finetune.first_loss, b.finetune.first_loss);
            assert_eq!(a.finetune.last_loss, b.finetune.last_loss);
            assert_eq!(a.finetune.mean_acc, b.finetune.mean_acc);
        }
    }
}

#[test]
fn run_manifest_fingerprint_ignores_cache_and_workers() {
    let mut a = Experiment::default();
    a.apply("bcd.cache_mb", "0").unwrap();
    a.apply("bcd.workers", "1").unwrap();
    a.apply("bcd.trial_batch", "1").unwrap();
    a.apply("bcd.verify_staged", "false").unwrap();
    let mut b = Experiment::default();
    b.apply("bcd.cache_mb", "128").unwrap();
    b.apply("bcd.workers", "4").unwrap();
    b.apply("bcd.trial_batch", "32").unwrap();
    b.apply("bcd.verify_staged", "true").unwrap();
    let ma = RunManifest::new("bcd", &a, "reference", 200, 100);
    let mb = RunManifest::new("bcd", &b, "reference", 200, 100);
    assert_eq!(
        ma.config_fingerprint, mb.config_fingerprint,
        "cache_mb/workers/trial_batch/verify_staged are throughput knobs and \
         must not shift run identity"
    );
    // A semantic knob still moves the fingerprint.
    let mut c = Experiment::default();
    c.apply("bcd.rt", "99").unwrap();
    let mc = RunManifest::new("bcd", &c, "reference", 200, 100);
    assert_ne!(mc.config_fingerprint, ma.config_fingerprint);
}

#[test]
fn staged_partial_batch_and_direct_delta_scoring() {
    let be = backend();
    let sess = Session::new(&be, MODEL).unwrap();
    // 21 examples with batch 16: the second batch carries a padded tail,
    // so the staged path must also reproduce the valid-prefix rescoring.
    let mut rng = Rng::new(3);
    let n = 21usize;
    let ie = 3 * 16 * 16;
    let ds = Dataset {
        name: "tiny".into(),
        num_classes: 10,
        channels: 3,
        image_size: 16,
        images: (0..n * ie).map(|_| rng.normal()).collect(),
        labels: (0..n).map(|i| (i % 10) as i32).collect(),
    };
    let st = sess.init_state(5).unwrap();
    let ev_full = Evaluator::new(&sess, &ds, usize::MAX).unwrap();
    let ev = Evaluator::with_cache(&sess, &ds, usize::MAX, 16).unwrap();
    assert!(ev.staged_enabled());
    assert!(!ev_full.staged_enabled());
    let params = ev.upload_params(&st.params).unwrap();
    ev.begin_iteration(&st.mask).unwrap();

    // Layer-1-only deltas take the staged path; a delta touching layer 0
    // (anywhere) falls back to full forwards. All must score identically.
    let l1 = sess.info().mask_layers[1].offset;
    let mut scratch = Vec::new();
    let deltas = [
        MaskDelta::new(vec![l1, l1 + 3, l1 + 10]),
        MaskDelta::new(vec![l1 + 1]),
        MaskDelta::new(vec![0, 5]),
        MaskDelta::new(vec![l1 - 1, l1 + 1]),
    ];
    for delta in &deltas {
        let staged = ev
            .eval_trial_delta(&params, &st.mask, delta, 0.0, &mut scratch)
            .unwrap();
        st.mask.hypothesis_into(delta.indices(), &mut scratch);
        let full = ev_full.eval_trial(&params, &scratch, 0.0).unwrap();
        assert_eq!(staged, full, "delta {:?}", delta.indices());
    }

    // The batched slab path must route the same mixed delta set (2 staged +
    // 2 full hypotheses -> one slab per route) through the padded-tail
    // rescoring with identical results, with verification on.
    let ev_b = Evaluator::with_opts(
        &sess,
        &ds,
        usize::MAX,
        EvalOpts {
            cache_bytes: 16 << 20,
            trial_batch: 4,
            verify_staged: true,
            verify_lowering: true,
        },
    )
    .unwrap();
    let params_b = ev_b.upload_params(&st.params).unwrap();
    ev_b.begin_iteration(&st.mask).unwrap();
    let slab = ev_b
        .eval_trial_slab(&params_b, &st.mask, &deltas, 0.0, &mut scratch)
        .unwrap();
    for (delta, got) in deltas.iter().zip(&slab) {
        st.mask.hypothesis_into(delta.indices(), &mut scratch);
        let full = ev_full.eval_trial(&params_b, &scratch, 0.0).unwrap();
        assert_eq!(*got, full, "slab result for delta {:?}", delta.indices());
    }
    let (slabs, staged_trials, full_trials, _, _) = ev_b.batch_counters();
    assert_eq!(
        (slabs, staged_trials, full_trials),
        (2, 2, 2),
        "expected one staged slab of 2 and one full slab of 2"
    );
    let (hits, misses, _) = ev.cache_counters();
    assert!(misses >= 2, "staged deltas must have populated the cache (misses={misses})");
    assert!(hits >= 2, "the second staged delta must hit the cache (hits={hits})");

    // The early-exit bound cuts identically on the staged path.
    let delta = MaskDelta::new(vec![l1 + 2]);
    let cut = ev
        .eval_trial_delta(&params, &st.mask, &delta, 200.0, &mut scratch)
        .unwrap();
    assert_eq!(cut, TrialEval::Bounded, "unreachable floor must bound");
}

#[test]
fn prefix_cache_lru_eviction_keeps_results_exact() {
    let be = backend();
    let sess = Session::new(&be, MODEL).unwrap();
    let ds = small_synth10(); // 96 examples -> 6 full batches of 16
    let st = sess.init_state(9).unwrap();
    // Budget of exactly ONE boundary-0 entry; with 4 eval batches rotating
    // through it, the cache thrashes — results must not care.
    let entry = 4 * sess.batch * sess.info().mask_layers[0].size;
    let ev = Evaluator::with_cache_bytes(&sess, &ds, 4, entry).unwrap();
    assert!(ev.staged_enabled());
    let ev_full = Evaluator::new(&sess, &ds, 4).unwrap();
    let params = ev.upload_params(&st.params).unwrap();
    ev.begin_iteration(&st.mask).unwrap();
    let l1 = sess.info().mask_layers[1].offset;
    let mut scratch = Vec::new();
    for k in 0..3 {
        let delta = MaskDelta::new(vec![l1 + k]);
        let staged = ev
            .eval_trial_delta(&params, &st.mask, &delta, 0.0, &mut scratch)
            .unwrap();
        st.mask.hypothesis_into(delta.indices(), &mut scratch);
        let full = ev_full.eval_trial(&params, &scratch, 0.0).unwrap();
        assert_eq!(staged, full, "eviction must never change results (k={k})");
    }
    let (hits, misses, evictions) = ev.cache_counters();
    assert_eq!(hits, 0, "capacity 1 with 4 rotating batches can never hit");
    assert!(
        evictions >= 3,
        "expected LRU thrashing: {evictions} evictions ({misses} misses)"
    );
    // A budget too small for even one entry disables staging cleanly.
    let tiny = Evaluator::with_cache_bytes(&sess, &ds, 4, entry - 1).unwrap();
    assert!(!tiny.staged_enabled());
}
