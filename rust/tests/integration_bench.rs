//! Bench-subsystem integration (DESIGN.md §9): registry-driven smoke run
//! on the reference backend, bit-identical serde round trips, comparator
//! gate semantics, and the committed-baseline contract the CI gate
//! enforces (`cdnl bench run --tier smoke && cdnl bench compare --gate`).

use cdnl::bench::report::kind;
use cdnl::bench::{self, compare_reports, BenchReport, Status, Thresholds};
use cdnl::runtime::RefBackend;
use cdnl::util::serde as sd;
use std::path::{Path, PathBuf};

fn run_smoke() -> BenchReport {
    let be = RefBackend::standard();
    let def = bench::find("smoke").expect("smoke is registered");
    bench::run_bench(def, &be).expect("smoke bench runs on the reference backend")
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("cdnl_bench_it_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn smoke_report_roundtrips_bit_identically_through_serde() {
    let report = run_smoke();
    assert_eq!(report.bench, "smoke");
    assert_eq!(report.tier, "smoke");
    assert_eq!(report.backend, "reference");
    assert!(report.num_metrics() > 12, "smoke must cover every model");

    // String round trip: parse back and re-serialize byte-identically.
    let text = sd::to_string_pretty(&report);
    let back: BenchReport = sd::from_str(&text).unwrap();
    assert_eq!(back, report);
    assert_eq!(sd::to_string_pretty(&back), text, "canonical serialization");

    // File round trip through save/load (atomic write path).
    let dir = tmp_dir("roundtrip");
    let path = bench::report_path(&dir, "smoke");
    report.save(&path).unwrap();
    let loaded = BenchReport::load(&path).unwrap();
    assert_eq!(loaded, report);
    // No temp residue from the atomic write.
    let names: Vec<_> = std::fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().file_name().into_string().unwrap())
        .collect();
    assert!(names.iter().all(|n| !n.ends_with(".tmp")), "temp residue: {names:?}");
}

#[test]
fn self_compare_passes_the_gate() {
    let report = run_smoke();
    let out = compare_reports(&report, &report.clone(), &Thresholds::default(), false);
    assert!(out.passed(), "a report must gate green against itself:\n{}", out.table());
    assert!(out.host_match && out.config_match);
    assert!(out.diffs.iter().all(|d| d.status == Status::Pass));
}

#[test]
fn perturbed_baseline_fails_the_gate() {
    let report = run_smoke();

    // A drifted count metric must regress...
    let mut drifted = report.clone();
    let m = drifted.cases[0]
        .metrics
        .iter_mut()
        .find(|m| m.kind == kind::COUNT)
        .expect("smoke records count metrics");
    m.value += 1.0;
    let out = compare_reports(&report, &drifted, &Thresholds::default(), false);
    assert_eq!(out.failures(), 1, "{}", out.table());

    // ...and a metric missing from the report must fail, while extra
    // report-side metrics only inform.
    let mut truncated = report.clone();
    let dropped = truncated.cases[0].metrics.remove(0);
    let out = compare_reports(&truncated, &report, &Thresholds::default(), false);
    assert_eq!(out.failures(), 1);
    let miss = out
        .diffs
        .iter()
        .find(|d| d.status == Status::Missing)
        .expect("dropped metric must surface as Missing");
    assert_eq!(miss.name, dropped.name);
    let reverse = compare_reports(&report, &truncated, &Thresholds::default(), false);
    assert!(reverse.passed(), "new coverage must not fail the gate");
    assert!(reverse.diffs.iter().any(|d| d.status == Status::New));
}

#[test]
fn committed_smoke_baseline_gates_green() {
    // The acceptance contract: a fresh `bench run --tier smoke` must
    // compare clean against the baseline committed at the repository root.
    // Counts gate on every host; timing metrics in the baseline (if any)
    // gate only when the host fingerprint matches, exactly as in CI.
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_smoke.json");
    let baseline = BenchReport::load(&path)
        .expect("committed BENCH_smoke.json must parse (regenerate via `cdnl bench run smoke`)");
    assert_eq!(baseline.bench, "smoke");
    assert_eq!(baseline.backend, "reference");
    let live = run_smoke();
    let out = compare_reports(&live, &baseline, &Thresholds::default(), false);
    assert!(
        out.passed(),
        "live smoke run regressed against the committed baseline:\n{}",
        out.table()
    );
    // The baseline's structural contract must actually be exercised.
    assert!(
        out.diffs.iter().filter(|d| d.kind == kind::COUNT && d.status == Status::Pass).count()
            >= 12,
        "expected the per-model count contract to be compared:\n{}",
        out.table()
    );
}

#[test]
fn committed_serve_baseline_gates_green() {
    // Same contract for the serve tier: a fresh `bench run --tier serve`
    // must compare clean against the committed BENCH_serve.json. The
    // baseline carries only the float-independent structural counts
    // (completions, rounds, bytes, jobs); percentiles and throughput show
    // up report-side only, which the comparator treats as informational.
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_serve.json");
    let baseline = BenchReport::load(&path)
        .expect("committed BENCH_serve.json must parse (regenerate via `cdnl bench run serve`)");
    assert_eq!(baseline.bench, "serve");
    assert_eq!(baseline.tier, "serve");
    assert_eq!(baseline.backend, "reference");
    let be = RefBackend::standard();
    let def = bench::find("serve").expect("serve is registered");
    let live = bench::run_bench(def, &be).expect("serve bench runs on the reference backend");
    let out = compare_reports(&live, &baseline, &Thresholds::default(), false);
    assert!(
        out.passed(),
        "live serve run regressed against the committed baseline:\n{}",
        out.table()
    );
    // 12 cases (2 families x 3 budgets x 2 protocols) x 9 gated counts.
    assert!(
        out.diffs.iter().filter(|d| d.kind == kind::COUNT && d.status == Status::Pass).count()
            >= 108,
        "expected the full serve count contract to be compared:\n{}",
        out.table()
    );
}

#[test]
fn markdown_and_table_render_for_ci_summary() {
    let report = run_smoke();
    let out = compare_reports(&report, &report.clone(), &Thresholds::default(), false);
    let md = out.markdown();
    assert!(md.contains("### bench `smoke`") && md.contains("PASS"), "{md}");
    assert!(out.table().contains("manifest/models"), "{}", out.table());
}
