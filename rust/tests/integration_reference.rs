//! Coordinator integration on the pure-Rust reference backend — no
//! artifacts, no PJRT, always runs (tests + CI).
//!
//! Covers the backend-agnostic contract end-to-end: session semantics,
//! evaluator partial-batch accounting, the deterministic parallel trial
//! scan (bit-identical outcome for every worker count), and Algorithm 2
//! invariants through `run_bcd`.

use cdnl::config::{BcdConfig, Granularity};
use cdnl::coordinator::bcd::run_bcd;
use cdnl::coordinator::eval::Evaluator;
use cdnl::coordinator::trials::{scan_trials, BlockSampler, ScanOutcome};
use cdnl::data::{synth, Dataset};
use cdnl::runtime::{open_backend, Backend, RefBackend, Session};
use cdnl::tensor::TensorI32;
use cdnl::util::prng::Rng;

const MODEL: &str = "resnet_16x16_c10";

fn backend() -> RefBackend {
    RefBackend::standard()
}

fn small_synth10() -> Dataset {
    let (train, _) = synth::generate(&synth::SynthSpec {
        train_n: 96,
        test_n: 16,
        ..synth::SYNTH10
    });
    train
}

#[test]
fn session_semantics() {
    let be = backend();
    let sess = Session::new(&be, MODEL).unwrap();
    let info = sess.info();
    assert!(info.param_size > 0 && info.mask_size > 0);
    assert_eq!(info.num_classes, 10);
    assert!(Session::new(&be, "no_such_model").is_err());

    // init: deterministic in the seed, seed-sensitive.
    let p1 = sess.init(7).unwrap();
    let p2 = sess.init(7).unwrap();
    let p3 = sess.init(8).unwrap();
    assert_eq!(p1.data, p2.data);
    assert_ne!(p1.data, p3.data);

    // Host path and buffer path agree exactly.
    let ds = small_synth10();
    let (x, y) = ds.batch_at(0, sess.batch);
    let mask = vec![1.0f32; sess.info().mask_size];
    let host = sess.eval_batch(&p1, &mask, &x, &y).unwrap();
    let pbuf = sess.upload_f32(&p1.data, &p1.shape).unwrap();
    let mbuf = sess.upload_f32(&mask, &[mask.len()]).unwrap();
    let (xbuf, ybuf) = sess.upload_batch(&x, &y).unwrap();
    let dev = sess.eval_batch_b(&pbuf, &mbuf, &xbuf, &ybuf).unwrap();
    assert_eq!(host.correct, dev.correct);
    assert!((host.loss - dev.loss).abs() < 1e-6);

    // eval_batch agrees with forward-side argmax.
    let logits = sess.forward(&p1, &mask, &x).unwrap();
    let preds = logits.argmax_rows().unwrap();
    let want = preds
        .iter()
        .zip(&y.data)
        .filter(|(p, &t)| **p == t as usize)
        .count() as f32;
    assert_eq!(host.correct, want);

    // Stats were recorded per entry point.
    let stats = be.stats();
    assert!(stats.get(&format!("{MODEL}:eval_batch")).is_some());
    assert!(be.stats_table().contains("eval_batch"));
}

#[test]
fn evaluator_partial_batch_accounting() {
    let be = backend();
    let sess = Session::new(&be, MODEL).unwrap();
    // 21 examples with batch 16: the second batch holds only 5 real
    // examples; the wrap-padded tail must count for nothing.
    let mut rng = Rng::new(3);
    let n = 21usize;
    let ie = 3 * 16 * 16;
    let ds = Dataset {
        name: "tiny".into(),
        num_classes: 10,
        channels: 3,
        image_size: 16,
        images: (0..n * ie).map(|_| rng.normal()).collect(),
        labels: (0..n).map(|i| (i % 10) as i32).collect(),
    };
    let ev = Evaluator::new(&sess, &ds, usize::MAX).unwrap();
    assert_eq!(ev.num_batches(), 2);
    assert_eq!(
        ev.num_examples(),
        n,
        "padded tail must be excluded from the denominator"
    );
    assert_ne!(ev.num_examples(), ev.num_batches() * sess.batch);

    let st = sess.init_state(1).unwrap();
    let params = ev.upload_params(&st.params).unwrap();
    let acc = ev.accuracy(&params, st.mask.dense()).unwrap();
    assert!((0.0..=100.0).contains(&acc));
    // Accuracy is a multiple of 1/21, not of 1/32: exactly n examples scored.
    let counts = acc / 100.0 * n as f64;
    assert!(
        (counts - counts.round()).abs() < 1e-9,
        "accuracy {acc} is not a whole count over {n} examples"
    );

    // Bound soundness on the partial-batch evaluator.
    let kept = ev
        .accuracy_bounded(&params, st.mask.dense(), (acc - 1.0).max(0.0))
        .unwrap();
    assert_eq!(kept, Some(acc), "bound below truth must return the value");
    let cut = ev.accuracy_bounded(&params, st.mask.dense(), 100.1).unwrap();
    assert_eq!(cut, None, "unreachable bound must cut");

    // Weighted mean loss is finite and positive.
    let (loss, acc2) = ev.loss_accuracy(&params, st.mask.dense()).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((acc - acc2).abs() < 1e-9);
}

fn scan_with_workers(workers: usize, rt: usize, adt: f64) -> ScanOutcome {
    let be = backend();
    let sess = Session::new(&be, MODEL).unwrap();
    let ds = small_synth10();
    let st = sess.init_state(42).unwrap();
    let ev = Evaluator::new(&sess, &ds, 2).unwrap();
    let params = ev.upload_params(&st.params).unwrap();
    let base = ev.accuracy(&params, st.mask.dense()).unwrap();
    let sampler = BlockSampler::new(Granularity::Pixel, sess.info());
    let mut rng = Rng::new(0xD00D);
    scan_trials(&ev, &params, &st.mask, &sampler, 24, rt, adt, base, &mut rng, workers).unwrap()
}

#[test]
fn scan_outcome_identical_across_worker_counts() {
    // No early accept (unreachable ADT): every hypothesis gets scored.
    let seq = scan_with_workers(1, 8, -1000.0);
    assert!(!seq.early_accept);
    assert!(seq.evaluated >= 1 && seq.evaluated <= 8);
    for w in [2, 4, 8] {
        let par = scan_with_workers(w, 8, -1000.0);
        assert_eq!(seq, par, "workers={w} diverged from sequential scan");
    }
    // Early accept (generous ADT): parallel runs must stop at the same
    // trial and return the same incumbent.
    let seq = scan_with_workers(1, 8, 1000.0);
    assert!(seq.early_accept, "ADT=1000%% must accept immediately");
    assert_eq!(seq.evaluated, 1);
    for w in [2, 4, 8] {
        let par = scan_with_workers(w, 8, 1000.0);
        assert_eq!(seq, par, "workers={w} diverged under early accept");
    }
    // A realistic tolerance exercises the bound + accept interplay.
    let seq = scan_with_workers(1, 10, 0.5);
    for w in [3, 7] {
        let par = scan_with_workers(w, 10, 0.5);
        assert_eq!(seq, par, "workers={w} diverged at ADT=0.5");
    }
}

#[test]
fn bcd_invariants_end_to_end() {
    let be = backend();
    let sess = Session::new(&be, MODEL).unwrap();
    let ds = small_synth10();
    let mut st = sess.init_state(42).unwrap();
    let total = st.budget();

    let cfg = BcdConfig {
        drc: 32,
        rt: 3,
        adt: 0.5,
        finetune_steps: 2,
        finetune_lr: 1e-3,
        proxy_batches: 2,
        seed: 0xB0B,
        workers: 2,
        ..Default::default()
    };
    // A target that does NOT divide evenly by DRC: 3 full steps + remainder.
    let target = total - 3 * 32 - 7;
    let before = st.mask.clone();
    let out = run_bcd(&sess, &mut st, &ds, target, &cfg, 1).unwrap();

    assert_eq!(st.budget(), target, "BCD must land exactly on the target");
    assert_eq!(out.final_budget, target);
    assert_eq!(out.iterations.len(), 4, "ceil((3*32+7)/32) = 4 iterations");
    assert_eq!(out.iterations.last().unwrap().budget_after, target);
    // Sparse-by-design: the final mask is a strict subset of the start mask.
    assert_eq!(st.mask.containment(&before), 1.0);
    st.mask.check_invariants().unwrap();
    let mut prev = total;
    for rec in &out.iterations {
        assert!(rec.budget_after < prev, "budget did not decrease at t={}", rec.t);
        assert!(rec.trials_evaluated >= 1 && rec.trials_evaluated <= cfg.rt);
        prev = rec.budget_after;
    }
    assert_eq!(out.snapshots.len(), 4);
    for w in out.snapshots.windows(2) {
        assert!(w[1].0 < w[0].0);
        assert_eq!(w[1].1.containment(&w[0].1), 1.0);
    }

    // Error paths.
    assert!(run_bcd(&sess, &mut st, &ds, target + 10, &cfg, 0).is_err());
    let bad = BcdConfig { drc: 0, ..cfg.clone() };
    assert!(run_bcd(&sess, &mut st, &ds, 10, &bad, 0).is_err());
}

#[test]
fn bcd_replays_identically_across_worker_counts() {
    let be = backend();
    let sess = Session::new(&be, MODEL).unwrap();
    let ds = small_synth10();
    let total = sess.init_state(1).unwrap().budget();
    let target = total - 80;

    let run = |workers: usize| {
        let mut st = sess.init_state(1).unwrap();
        let cfg = BcdConfig {
            drc: 24,
            rt: 4,
            adt: 0.3,
            finetune_steps: 2,
            finetune_lr: 1e-3,
            proxy_batches: 2,
            seed: 7,
            workers,
            ..Default::default()
        };
        let out = run_bcd(&sess, &mut st, &ds, target, &cfg, 0).unwrap();
        (st, out)
    };
    let (st_a, out_a) = run(1);
    let (st_b, out_b) = run(4);
    assert_eq!(st_a.mask.dense(), st_b.mask.dense(), "masks diverged across worker counts");
    assert_eq!(st_a.params.data, st_b.params.data, "params diverged across worker counts");
    assert_eq!(out_a.iterations.len(), out_b.iterations.len());
    for (ra, rb) in out_a.iterations.iter().zip(&out_b.iterations) {
        assert_eq!(ra.budget_after, rb.budget_after);
        assert_eq!(ra.chosen_dacc, rb.chosen_dacc);
        assert_eq!(ra.trials_evaluated, rb.trials_evaluated);
        assert_eq!(ra.trials_bounded, rb.trials_bounded);
        assert_eq!(ra.early_accept, rb.early_accept);
    }
}

#[test]
fn open_backend_serves_all_model_keys() {
    let be = open_backend(std::path::Path::new("artifacts_that_do_not_exist"), "auto").unwrap();
    assert_eq!(be.name(), "reference");
    for key in ["resnet_16x16_c10", "wrn_32x32_c20", "resnet_16x16_c20_poly"] {
        let sess = Session::new(be.as_ref(), key).unwrap();
        let p = sess.init(1).unwrap();
        assert_eq!(p.len(), sess.info().param_size, "{key}");
    }
    // A poly model must actually run a train step (exercises the quadratic
    // branch gradient).
    let sess = Session::new(be.as_ref(), "resnet_16x16_c20_poly").unwrap();
    let mut st = sess.init_state(2).unwrap();
    let (train, _) = synth::generate(&synth::SynthSpec {
        train_n: 32,
        test_n: 8,
        ..synth::SYNTH100
    });
    let (x, y) = train.batch_at(0, sess.batch);
    let out = sess.train_step(&mut st, &x, &y, 1e-3).unwrap();
    assert!(out.loss.is_finite());

    // kd_step runs with teacher logits.
    let y2 = TensorI32::new(vec![sess.batch], vec![0; sess.batch]);
    let t_logits = sess.forward(&st.params, st.mask.dense(), &x).unwrap();
    let kd = sess.kd_step(&mut st, &x, &y2, &t_logits, 1e-3, 2.0).unwrap();
    assert!(kd.is_finite());
}
