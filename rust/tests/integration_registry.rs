//! Method-registry integration on the pure-Rust reference backend
//! (DESIGN.md §10): dispatch parity — every method produces bit-identical
//! `ModelState` and outcome fields through the old-style direct `run_*`
//! call and the registry path — plus chain semantics: `snl+bcd` reproduces
//! the hard-coded `Pipeline::snl_ref -> bcd_from` staging exactly, with
//! per-stage provenance and a manifest-ready typed outcome trail.

use cdnl::config::Experiment;
use cdnl::coordinator::bcd::run_bcd;
use cdnl::data::{synth, Dataset};
use cdnl::methods::autorep::run_autorep;
use cdnl::methods::deepreduce::run_deepreduce;
use cdnl::methods::registry::{
    self, AutorepSummary, BcdSummary, ChainSpec, DeepReduceSummary, Method, MethodCtx,
    MethodOutcome, RecordSink, SenetSummary, SnlSummary,
};
use cdnl::methods::senet::run_senet;
use cdnl::methods::snl::run_snl;
use cdnl::model::ModelState;
use cdnl::pipeline::Pipeline;
use cdnl::runstore::RunManifest;
use cdnl::runtime::{RefBackend, Session};
use cdnl::util::serde as sd;

const MODEL: &str = "resnet_16x16_c10";
const MODEL_POLY: &str = "resnet_16x16_c10_poly";

/// Tiny-but-real schedules (shared with the smoke bench's registry
/// contract via `bench::setup` so the two cannot drift): sub-second runs
/// that still exercise each method's full control flow. drc=32 gives BCD
/// a multi-sweep trajectory here.
fn tiny_exp() -> Experiment {
    cdnl::bench::setup::tiny_method_experiment(32)
}

fn small_synth10() -> Dataset {
    synth::generate(&synth::SynthSpec { train_n: 96, test_n: 16, ..synth::SYNTH10 }).0
}

fn assert_states_identical(a: &ModelState, b: &ModelState, what: &str) {
    assert_eq!(a.mask.dense(), b.mask.dense(), "{what}: masks diverged");
    assert_eq!(a.params.data, b.params.data, "{what}: params diverged");
    assert_eq!(a.mom.data, b.mom.data, "{what}: momentum diverged");
}

#[test]
fn registry_dispatch_is_bit_identical_to_direct_calls() {
    let be = RefBackend::standard();
    let sess = Session::new(&be, MODEL).unwrap();
    let sess_poly = Session::new(&be, MODEL_POLY).unwrap();
    let ds = small_synth10();
    let exp = tiny_exp();
    let sink = RecordSink::default();
    let total = sess.info().total_relus();
    let target = total - 64;

    // Each block: the pre-registry direct call and the registry path run on
    // identical fresh states; states must match bit for bit and the typed
    // outcome must carry exactly the direct outcome's fields.

    // snl
    let mut a = sess.init_state(5).unwrap();
    let direct = run_snl(&sess, &mut a, &ds, target, &exp.snl, 0).unwrap();
    let mut b = sess.init_state(5).unwrap();
    let ctx = MethodCtx::new(&sess, &ds, &exp, &sink);
    let out = registry::find("snl").unwrap().run(&ctx, &mut b, target).unwrap();
    assert_states_identical(&a, &b, "snl");
    assert_eq!(out, MethodOutcome::Snl(SnlSummary::from_outcome(&direct)));

    // bcd
    let mut a = sess.init_state(6).unwrap();
    let direct = run_bcd(&sess, &mut a, &ds, target, &exp.bcd, 0).unwrap();
    let mut b = sess.init_state(6).unwrap();
    let ctx = MethodCtx::new(&sess, &ds, &exp, &sink);
    let out = registry::find("bcd").unwrap().run(&ctx, &mut b, target).unwrap();
    assert_states_identical(&a, &b, "bcd");
    assert_eq!(out, MethodOutcome::Bcd(BcdSummary::from_outcome(&direct)));

    // autorep (poly variant; base config comes from exp.snl either way)
    let mut a = sess_poly.init_state(7).unwrap();
    let direct =
        run_autorep(&sess_poly, &mut a, &ds, target, &exp.snl, &exp.autorep).unwrap();
    let mut b = sess_poly.init_state(7).unwrap();
    let ctx = MethodCtx::new(&sess_poly, &ds, &exp, &sink);
    let out = registry::find("autorep").unwrap().run(&ctx, &mut b, target).unwrap();
    assert_states_identical(&a, &b, "autorep");
    assert_eq!(out, MethodOutcome::Autorep(AutorepSummary::from_outcome(&direct)));

    // senet
    let mut a = sess.init_state(8).unwrap();
    let direct = run_senet(&sess, &mut a, &ds, target, &exp.senet).unwrap();
    let mut b = sess.init_state(8).unwrap();
    let ctx = MethodCtx::new(&sess, &ds, &exp, &sink);
    let out = registry::find("senet").unwrap().run(&ctx, &mut b, target).unwrap();
    assert_states_identical(&a, &b, "senet");
    assert_eq!(out, MethodOutcome::Senet(SenetSummary::from_outcome(&direct)));

    // deepreduce
    let mut a = sess.init_state(9).unwrap();
    let direct = run_deepreduce(&sess, &mut a, &ds, target, &exp.deepreduce).unwrap();
    let mut b = sess.init_state(9).unwrap();
    let ctx = MethodCtx::new(&sess, &ds, &exp, &sink);
    let out = registry::find("deepreduce").unwrap().run(&ctx, &mut b, target).unwrap();
    assert_states_identical(&a, &b, "deepreduce");
    assert_eq!(
        out,
        MethodOutcome::Deepreduce(DeepReduceSummary::from_outcome(&direct, a.budget()))
    );

    // No method pushed stage records on its own (chains do that).
    assert!(sink.lock().unwrap().is_empty());
}

#[test]
fn chain_snl_bcd_reproduces_pipeline_staging() {
    let be = RefBackend::standard();
    let mut exp = tiny_exp();
    exp.train.steps = 8;
    exp.train.warmup_steps = 2;
    exp.out_dir = std::env::temp_dir()
        .join(format!("cdnl_it_registry_chain_{}", std::process::id()))
        .display()
        .to_string();
    let _ = std::fs::remove_dir_all(&exp.out_dir);
    let pl = Pipeline::new(&be, exp).unwrap();
    let total = pl.sess.info().total_relus();
    let (b_ref, b_target) = (total - 40, total - 72);

    // The hard-coded staging protocol (paper Tables 4/5)...
    let reference = pl.snl_ref(b_ref).unwrap();
    let (want, want_out) = pl.bcd_from(&reference, b_target).unwrap();
    pl.take_stages(); // drop the staging provenance of the reference path

    // ...must be exactly what the user-specifiable chain produces.
    let spec = ChainSpec::parse("snl+bcd").unwrap();
    let (got, outs) = pl.run_chain(&spec, None, &[b_ref, b_target]).unwrap();
    assert_states_identical(&got, &want, "snl+bcd chain vs snl_ref->bcd_from");
    assert_eq!(outs.len(), 2);
    assert_eq!(outs[0].method(), "snl");
    assert_eq!(outs[0].final_budget(), b_ref);
    assert_eq!(outs[1], MethodOutcome::Bcd(BcdSummary::from_outcome(&want_out)));

    // Per-stage provenance landed in the pipeline sink, in order.
    let stages = pl.take_stages();
    let chain_stages: Vec<&str> = stages
        .iter()
        .filter(|s| s.stage.starts_with("chain:"))
        .map(|s| s.stage.as_str())
        .collect();
    assert_eq!(chain_stages, vec!["chain:snl", "chain:bcd"]);
    let bcd_stage = stages.iter().find(|s| s.stage == "chain:bcd").unwrap();
    assert_eq!(bcd_stage.budget, b_target);

    // A chain manifest carries the typed outcome trail and round-trips.
    let mut m = RunManifest::new(&spec.name(), &pl.exp, "reference", total, b_target);
    m.outcomes = Some(outs);
    let text = sd::to_string_pretty(&m);
    let back: RunManifest = sd::from_str(&text).unwrap();
    assert_eq!(back.method, "snl+bcd");
    assert_eq!(back.outcomes, m.outcomes);
    assert_eq!(back.experiment().unwrap().fingerprint(), m.config_fingerprint);
}

#[test]
fn budget_validation_and_spec_errors_surface() {
    let be = RefBackend::standard();
    let sess = Session::new(&be, MODEL).unwrap();
    let ds = small_synth10();
    let exp = tiny_exp();
    let sink = RecordSink::default();
    let ctx = MethodCtx::new(&sess, &ds, &exp, &sink);
    let mut st = sess.init_state(1).unwrap();
    let total = st.budget();

    // A chain with the wrong number of budgets is rejected up front.
    let spec = ChainSpec::parse("snl+bcd").unwrap();
    let err = format!("{:#}", spec.run(&ctx, &mut st, &[total - 10]).unwrap_err());
    assert!(err.contains("2 stages"), "{err}");

    // Stage-level validation propagates (target >= current budget).
    let single = ChainSpec::parse("snl").unwrap();
    assert!(single.run(&ctx, &mut st, &[total + 1]).is_err());

    // AutoReP through the registry still refuses non-poly sessions.
    let err = format!(
        "{:#}",
        registry::find("autorep").unwrap().run(&ctx, &mut st, total - 10).unwrap_err()
    );
    assert!(err.contains("poly"), "{err}");
}
