//! Finite-difference gradient checks for the conv reference backend
//! (DESIGN.md §12): every hand-written backward pass in
//! `runtime::kernels` — and its GEMM lowering in `runtime::lowering`
//! (§13) — is validated against a central difference of its forward,
//! plus an end-to-end spot check through `ConvPlan::backward`.
//!
//! Method: probe loss `L = Σ_i probe_i · out_i` with a fixed random probe
//! vector, accumulated in f64. The analytic gradient is the op's backward
//! applied to `dy = probe`; the numeric gradient is the central difference
//! `(L(θ+ε) − L(θ−ε)) / 2ε` per coordinate.
//!
//! Tolerance rationale (per-op rationale inline at each check):
//! - All forwards run in f32, so each loss evaluation carries ≈1e-7·|out|
//!   rounding noise; dividing by 2ε turns that into ≈1e-7/ε absolute error
//!   on the numeric gradient. ε = 5e-3 keeps it near 2e-5.
//! - Truncation error is O(ε²·f‴). Conv / residual-add / GAP / eval-mode BN
//!   are *linear* in every checked argument, so truncation is exactly zero
//!   and only rounding remains. Train-mode BN and the masked activation are
//!   smooth nonlinearities with O(1) third derivatives at our operating
//!   points, giving ≈2.5e-5 truncation.
//! - Both error sources sit two orders below the 1e-3 relative tolerance;
//!   a 1e-2 denominator floor keeps near-zero gradients from inflating the
//!   relative error into noise.

use cdnl::runtime::convnet::{ConvPlan, ConvSpec, Family};
use cdnl::runtime::kernels::{
    add_into, bn_backward_eval, bn_backward_train, bn_eval_into, bn_train_into, conv2d_same_dinput,
    conv2d_same_dweight, conv2d_same_into, dact_channel, gap_back, gap_into, mask_act_channel_into,
    softmax_ce_batch,
};
use cdnl::util::prng::Rng;

const EPS: f32 = 5e-3;
const TOL: f64 = 1e-3;

/// Relative error with a denominator floor (tiny gradients compare in
/// absolute terms at scale 1e-2).
fn rel_err(ad: f64, fd: f64) -> f64 {
    (ad - fd).abs() / ad.abs().max(fd.abs()).max(1e-2)
}

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal()).collect()
}

/// Probe loss `Σ probe_i · out_i` in f64.
fn probe_loss(out: &[f32], probe: &[f32]) -> f64 {
    out.iter().zip(probe).map(|(&o, &p)| o as f64 * p as f64).sum()
}

/// Central difference of `f` w.r.t. coordinate `i` of `theta`.
fn central_diff<F: FnMut(&[f32]) -> f64>(theta: &mut Vec<f32>, i: usize, mut f: F) -> f64 {
    let orig = theta[i];
    theta[i] = orig + EPS;
    let lp = f(theta);
    theta[i] = orig - EPS;
    let lm = f(theta);
    theta[i] = orig;
    (lp - lm) / (2.0 * EPS as f64)
}

fn assert_grads_match(analytic: &[f32], label: &str, mut numeric: impl FnMut(usize) -> f64) {
    for i in 0..analytic.len() {
        let ad = analytic[i] as f64;
        let fd = numeric(i);
        let e = rel_err(ad, fd);
        assert!(e <= TOL, "{label}[{i}]: analytic {ad} vs numeric {fd} (rel err {e:.2e})");
    }
}

/// conv2d: linear in both input and weights ⇒ zero truncation error; only
/// f32 rounding (≈2e-5 absolute) remains, far inside 1e-3. Checked at
/// stride 1 and stride 2 on an odd (ragged) spatial dim so the asymmetric
/// 'SAME' padding path is differentiated too.
#[test]
fn conv2d_input_and_weight_grads() {
    let (n, cin, h, wd, cout, k) = (2, 3, 5, 5, 4, 3);
    for stride in [1usize, 2] {
        let mut rng = Rng::new(0xC0DE + stride as u64);
        let mut x = randn(&mut rng, n * cin * h * wd);
        let mut w = randn(&mut rng, cout * cin * k * k);
        let oh = h.div_ceil(stride);
        let probe = randn(&mut rng, n * cout * oh * oh);

        // Analytic: backward with dy = probe.
        let dx = conv2d_same_dinput(&probe, &w, n, cin, h, wd, cout, k, stride);
        let mut dw = vec![0.0f32; w.len()];
        conv2d_same_dweight(&x, &probe, &mut dw, n, cin, h, wd, cout, k, stride);

        let mut out = Vec::new();
        let w_fixed = w.clone();
        assert_grads_match(&dx, &format!("conv s{stride} dx"), |i| {
            central_diff(&mut x, i, |xs| {
                conv2d_same_into(xs, &w_fixed, n, cin, h, wd, cout, k, stride, &mut out);
                probe_loss(&out, &probe)
            })
        });
        let x_fixed = x.clone();
        assert_grads_match(&dw, &format!("conv s{stride} dw"), |i| {
            central_diff(&mut w, i, |ws| {
                conv2d_same_into(&x_fixed, ws, n, cin, h, wd, cout, k, stride, &mut out);
                probe_loss(&out, &probe)
            })
        });
    }
}

/// The GEMM-lowered conv backward kernels (DESIGN.md §13) differentiated
/// directly: `lowering::conv2d_lowered_dinput`/`_dweight` against central
/// differences of the lowered forward. The check above already covers
/// these routes implicitly (the public `conv2d_same_*` wrappers lower by
/// default), but this pins them without the dispatch in the loop — it
/// would catch a drift even if the lowered and direct routes drifted
/// together — and adds 1x1 kernels and stride-2 shapes the battery above
/// does not differentiate. Same linearity argument: zero truncation,
/// rounding only, far inside 1e-3.
#[test]
fn conv2d_lowered_backward_grads() {
    use cdnl::runtime::lowering::{
        conv2d_lowered_dinput, conv2d_lowered_dweight, conv2d_lowered_into, Scratch,
    };
    for (k, stride) in [(1usize, 1usize), (1, 2), (3, 2)] {
        let (n, cin, h, wd, cout) = (2, 2, 5, 4, 3);
        let mut rng = Rng::new(0x10E4 + (k * 10 + stride) as u64);
        let mut s = Scratch::new();
        let mut x = randn(&mut rng, n * cin * h * wd);
        let mut w = randn(&mut rng, cout * cin * k * k);
        let (oh, ow) = (h.div_ceil(stride), wd.div_ceil(stride));
        let probe = randn(&mut rng, n * cout * oh * ow);

        let dx = conv2d_lowered_dinput(&probe, &w, n, cin, h, wd, cout, k, stride, &mut s);
        let mut dw = vec![0.0f32; w.len()];
        conv2d_lowered_dweight(&x, &probe, &mut dw, n, cin, h, wd, cout, k, stride, &mut s);

        let mut out = Vec::new();
        let w_fixed = w.clone();
        assert_grads_match(&dx, &format!("lowered conv k{k} s{stride} dx"), |i| {
            central_diff(&mut x, i, |xs| {
                conv2d_lowered_into(xs, &w_fixed, n, cin, h, wd, cout, k, stride, &mut out, &mut s);
                probe_loss(&out, &probe)
            })
        });
        let x_fixed = x.clone();
        assert_grads_match(&dw, &format!("lowered conv k{k} s{stride} dw"), |i| {
            central_diff(&mut w, i, |ws| {
                conv2d_lowered_into(&x_fixed, ws, n, cin, h, wd, cout, k, stride, &mut out, &mut s);
                probe_loss(&out, &probe)
            })
        });
    }
}

/// Train-mode BN: the batch mean/var couple every element of a channel, and
/// 1/√(var+ε) is smooth with O(1) derivatives for var ≈ 1, so truncation is
/// ≈ ε²·f‴/6 ≈ 2.5e-5 — well inside 1e-3. Gradients w.r.t. x, γ, β all
/// flow through the same cache.
#[test]
fn batchnorm_train_grads() {
    let (n, c, hw) = (3, 4, 6);
    let mut rng = Rng::new(0xB41);
    let mut x = randn(&mut rng, n * c * hw);
    let mut gamma: Vec<f32> = (0..c).map(|_| 1.0 + 0.3 * rng.normal()).collect();
    let mut beta = randn(&mut rng, c);
    let probe = randn(&mut rng, n * c * hw);

    let mut out = Vec::new();
    let cache = bn_train_into(&x, &gamma, &beta, n, c, hw, &mut out);
    let mut dgamma = vec![0.0f32; c];
    let mut dbeta = vec![0.0f32; c];
    let dx = bn_backward_train(&cache, &gamma, &probe, &mut dgamma, &mut dbeta, n, c, hw);

    let (g0, b0) = (gamma.clone(), beta.clone());
    assert_grads_match(&dx, "bn-train dx", |i| {
        central_diff(&mut x, i, |xs| {
            bn_train_into(xs, &g0, &b0, n, c, hw, &mut out);
            probe_loss(&out, &probe)
        })
    });
    let x0 = x.clone();
    assert_grads_match(&dgamma, "bn-train dgamma", |i| {
        central_diff(&mut gamma, i, |gs| {
            bn_train_into(&x0, gs, &b0, n, c, hw, &mut out);
            probe_loss(&out, &probe)
        })
    });
    assert_grads_match(&dbeta, "bn-train dbeta", |i| {
        central_diff(&mut beta, i, |bs| {
            bn_train_into(&x0, &g0, bs, n, c, hw, &mut out);
            probe_loss(&out, &probe)
        })
    });
}

/// Eval-mode BN: with running stats frozen the op is an affine per-element
/// map — linear in x, γ, β ⇒ zero truncation; rounding only. This is the
/// mode every scoring path uses (DESIGN.md §12 determinism contract).
#[test]
fn batchnorm_eval_grads() {
    let (n, c, hw) = (2, 3, 5);
    let mut rng = Rng::new(0xB42);
    let mut x = randn(&mut rng, n * c * hw);
    let mut gamma: Vec<f32> = (0..c).map(|_| 1.0 + 0.3 * rng.normal()).collect();
    let mut beta = randn(&mut rng, c);
    let rmean = randn(&mut rng, c);
    let rvar: Vec<f32> = (0..c).map(|_| 0.5 + rng.f32()).collect();
    let probe = randn(&mut rng, n * c * hw);

    let mut dgamma = vec![0.0f32; c];
    let mut dbeta = vec![0.0f32; c];
    let dx =
        bn_backward_eval(&x, &gamma, &rmean, &rvar, &probe, &mut dgamma, &mut dbeta, n, c, hw);

    let mut out = Vec::new();
    let (g0, b0) = (gamma.clone(), beta.clone());
    assert_grads_match(&dx, "bn-eval dx", |i| {
        central_diff(&mut x, i, |xs| {
            bn_eval_into(xs, &g0, &b0, &rmean, &rvar, n, c, hw, &mut out);
            probe_loss(&out, &probe)
        })
    });
    let x0 = x.clone();
    assert_grads_match(&dgamma, "bn-eval dgamma", |i| {
        central_diff(&mut gamma, i, |gs| {
            bn_eval_into(&x0, gs, &b0, &rmean, &rvar, n, c, hw, &mut out);
            probe_loss(&out, &probe)
        })
    });
    assert_grads_match(&dbeta, "bn-eval dbeta", |i| {
        central_diff(&mut beta, i, |bs| {
            bn_eval_into(&x0, &g0, bs, &rmean, &rvar, n, c, hw, &mut out);
            probe_loss(&out, &probe)
        })
    });
}

/// Residual add `a += b`: the identity-gradient op. Linear ⇒ exact; both
/// summands receive dy unchanged, which the check confirms per coordinate.
#[test]
fn residual_add_grads() {
    let m = 24;
    let mut rng = Rng::new(0xADD);
    let mut a = randn(&mut rng, m);
    let mut b = randn(&mut rng, m);
    let probe = randn(&mut rng, m);

    // add_into's backward is pass-through: da = db = dy.
    let run = |av: &[f32], bv: &[f32]| {
        let mut s = av.to_vec();
        add_into(&mut s, bv);
        probe_loss(&s, &probe)
    };
    let b0 = b.clone();
    assert_grads_match(&probe, "add da", |i| central_diff(&mut a, i, |av| run(av, &b0)));
    let a0 = a.clone();
    assert_grads_match(&probe, "add db", |i| central_diff(&mut b, i, |bv| run(&a0, bv)));
}

/// Global average pooling: linear (each input contributes 1/hw to one
/// output) ⇒ exact up to rounding. `gap_back` must spread dy/hw uniformly.
#[test]
fn gap_grads() {
    let (n, c, hw) = (2, 3, 16);
    let mut rng = Rng::new(0x6A9);
    let mut x = randn(&mut rng, n * c * hw);
    let probe = randn(&mut rng, n * c);

    let dx = gap_back(&probe, n, c, hw);
    let mut out = Vec::new();
    assert_grads_match(&dx, "gap dx", |i| {
        central_diff(&mut x, i, |xs| {
            gap_into(xs, n, c, hw, &mut out);
            probe_loss(&out, &probe)
        })
    });
}

/// Per-channel masked activation `a = m·relu(z) + (1−m)·g(z)`: linear in m
/// (exact), piecewise-smooth in z. The relu kink at z = 0 breaks central
/// differences, so test inputs are pushed ≥ 0.1 away from zero — ε = 5e-3
/// cannot cross the kink and both branches stay smooth. Checked for
/// g(z) = z and the AutoReP quadratic, at fractional mask values so both
/// activation terms contribute.
#[test]
fn masked_activation_channel_grads() {
    let (n, c, hw) = (2, 4, 9);
    for poly in [false, true] {
        let mut rng = Rng::new(0xAC7 + poly as u64);
        let mut z: Vec<f32> = (0..n * c * hw)
            .map(|_| {
                let v = rng.normal();
                v + 0.1f32.copysign(v) // keep |z| ≥ 0.1: off the relu kink
            })
            .collect();
        let mut mask: Vec<f32> = (0..c).map(|_| rng.f32()).collect();
        let probe = randn(&mut rng, n * c * hw);

        let (dmask, dz) = dact_channel(&z, &mask, &probe, n, c, hw, poly);

        let mut a = Vec::new();
        let m0 = mask.clone();
        assert_grads_match(&dz, &format!("act(poly={poly}) dz"), |i| {
            central_diff(&mut z, i, |zs| {
                mask_act_channel_into(zs, &m0, n, c, hw, poly, &mut a);
                probe_loss(&a, &probe)
            })
        });
        let z0 = z.clone();
        assert_grads_match(&dmask, &format!("act(poly={poly}) dmask"), |i| {
            central_diff(&mut mask, i, |ms| {
                mask_act_channel_into(&z0, ms, n, c, hw, poly, &mut a);
                probe_loss(&a, &probe)
            })
        });
    }
}

/// End-to-end spot check: `ConvPlan::backward` against a central difference
/// of the full train-mode forward + softmax CE on a tiny ResNet.
///
/// To make finite differences trustworthy through a deep composition the
/// network is configured fully smooth: poly = true and mask = 0, so every
/// activation is the quadratic g(z) (no relu kinks anywhere — the relu
/// branch is already covered per-op above). Tolerance is relaxed to 2e-2
/// with a 0.05 floor: ε-noise compounds across ~10 f32 layers and the CE
/// log-sum-exp, and sampled coordinates with |grad| ≈ 1e-2 sit close to
/// the noise floor of the difference quotient.
#[test]
fn convplan_end_to_end_grads() {
    let spec = ConvSpec {
        key: "gradcheck_tiny".into(),
        family: Family::Resnet,
        num_classes: 3,
        image_size: 8,
        channels: 3,
        poly: true,
        base: 4,
        widen: 2,
        blocks: 1,
        bn_momentum: 0.1,
    };
    let plan = ConvPlan::build(&spec);
    let n = 2;
    let mut rng = Rng::new(0xE2E);
    let x: Vec<f32> = (0..n * 3 * 64).map(|_| 0.5 * rng.normal()).collect();
    let y: Vec<i32> = vec![0, 2];
    let mut params = plan.init_params(7);
    let mut mask = vec![0.0f32; plan.mask_size]; // all-linear: smooth everywhere

    let loss_of = |p: &[f32], m: &[f32]| -> f64 {
        let (logits, _) = plan.forward_train(p, m, &x, n);
        softmax_ce_batch(&logits, &y, 3, None).0 as f64
    };

    let (logits, tape) = plan.forward_train(&params, &mask, &x, n);
    let mut dlogits = vec![0.0f32; n * 3];
    let loss0 = softmax_ce_batch(&logits, &y, 3, Some(&mut dlogits)).0;
    assert!(loss0.is_finite());
    let (dparams, dmask) = plan.backward(&params, &mask, &tape, &dlogits, n);

    // Sample coordinates across entry kinds: conv weights, BN affine rows,
    // head weights/bias. Running-stat rows are skipped — they don't enter
    // the train-mode forward, so both gradients are identically zero.
    let mut coords: Vec<usize> = Vec::new();
    for e in &plan.param_entries {
        if e.name.ends_with(".w") || e.name == "head.b" {
            coords.extend((0..e.size).step_by((e.size / 4).max(1)).map(|i| e.offset + i));
        } else {
            // BN entry [4, C]: rows 0/1 are γ/β (differentiated).
            let c = e.shape[1];
            coords.push(e.offset); // γ[0]
            coords.push(e.offset + c); // β[0]
        }
    }
    let m0 = mask.clone();
    for &i in &coords {
        let ad = dparams[i] as f64;
        let fd = central_diff(&mut params, i, |p| loss_of(p, &m0));
        let e = (ad - fd).abs() / ad.abs().max(fd.abs()).max(0.05);
        assert!(e <= 2e-2, "e2e dparams[{i}]: analytic {ad} vs numeric {fd} (rel err {e:.2e})");
    }
    let p0 = params.clone();
    for i in (0..plan.mask_size).step_by((plan.mask_size / 8).max(1)) {
        let ad = dmask[i] as f64;
        let fd = central_diff(&mut mask, i, |m| loss_of(&p0, m));
        let e = (ad - fd).abs() / ad.abs().max(fd.abs()).max(0.05);
        assert!(e <= 2e-2, "e2e dmask[{i}]: analytic {ad} vs numeric {fd} (rel err {e:.2e})");
    }
}
