//! Deterministic pseudo-random number generation (substrate; no `rand`).
//!
//! `SplitMix64` seeds `Xoshiro256++` — the standard pairing. Every stochastic
//! decision in the coordinator (trial sampling, dataset generation, batch
//! shuffling) flows through [`Rng`], so whole experiments replay bit-exactly
//! from a single seed.

/// SplitMix64: used to expand a single `u64` seed into a full generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seeded generator; distinct seeds give independent streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream (for per-component seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Snapshot the raw generator state — the run-store checkpoints this so
    /// an interrupted run resumes its random stream mid-sequence, bit-exact.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Self::state`] snapshot. Unlike
    /// [`Self::new`], no SplitMix64 expansion runs: the stream continues
    /// exactly where the snapshot was taken.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)` without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    pub fn usize_below(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Standard normal via Box-Muller (cached second sample omitted: the
    /// callers are not throughput-bound on gaussians).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)`.
    ///
    /// Uses Floyd's algorithm: O(k) expected time and exactly `k` entries of
    /// auxiliary state, independent of `n` — this is the BCD trial sampler,
    /// on the hot path (DESIGN.md §7: no per-trial O(n) allocation).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut chosen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.usize_below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize_below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn state_snapshot_resumes_mid_stream() {
        let mut a = Rng::new(99);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let tail: Vec<u64> = (0..50).map(|_| a.next_u64()).collect();
        let mut b = Rng::from_state(snap);
        let replay: Vec<u64> = (0..50).map(|_| b.next_u64()).collect();
        assert_eq!(tail, replay, "restored stream diverged");
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            let k = r.usize_below(50) + 1;
            let n = k + r.usize_below(100);
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates in {s:?}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn sample_indices_full_range() {
        let mut r = Rng::new(9);
        let mut s = r.sample_indices(10, 10);
        s.sort_unstable();
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
