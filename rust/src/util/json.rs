//! Minimal JSON parser + writer (substrate; no `serde` in the vendor set).
//!
//! Parses `artifacts/manifest.json`, experiment configs, and serializes
//! result records. Full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bool, null); numbers are held as `f64` which is exact for every
//! integer the manifest can contain (< 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // -- typed accessors (fail loudly: manifest shape errors are bugs) ------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn expect(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("json: missing key {key:?} in {self:.60?}"))
    }

    pub fn as_f64(&self) -> f64 {
        match self {
            Json::Num(n) => *n,
            _ => panic!("json: expected number, got {self:.60?}"),
        }
    }

    pub fn as_usize(&self) -> usize {
        self.as_f64() as usize
    }

    pub fn as_i64(&self) -> i64 {
        self.as_f64() as i64
    }

    pub fn as_str(&self) -> &str {
        match self {
            Json::Str(s) => s,
            _ => panic!("json: expected string, got {self:.60?}"),
        }
    }

    pub fn as_bool(&self) -> bool {
        match self {
            Json::Bool(b) => *b,
            _ => panic!("json: expected bool, got {self:.60?}"),
        }
    }

    pub fn as_arr(&self) -> &[Json] {
        match self {
            Json::Arr(a) => a,
            _ => panic!("json: expected array, got {self:.60?}"),
        }
    }

    pub fn as_obj(&self) -> &BTreeMap<String, Json> {
        match self {
            Json::Obj(m) => m,
            _ => panic!("json: expected object, got {self:.60?}"),
        }
    }

    /// Convenience: `[1, 2, 3]` -> `vec![1, 2, 3]`.
    pub fn as_usize_vec(&self) -> Vec<usize> {
        self.as_arr().iter().map(|v| v.as_usize()).collect()
    }

    // -- constructors for the writer ----------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // -- serialization -------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Indented serialization (2 spaces) — manifests meant for humans
    /// (`run.json`) use this; machine interchange stays compact.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(a) if !a.is_empty() => {
                out.push_str("[\n");
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            other => other.write(out),
        }
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns an error string with byte position on
/// malformed input.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|b| b as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                other => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}, found {:?}",
                        self.i,
                        other.map(|b| b as char)
                    ))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5]).map_err(
                                    |_| "bad \\u escape".to_string(),
                                )?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte safe).
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| "invalid utf-8 in string".to_string())?;
                    s.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalar() {
        for s in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = parse(s).unwrap();
            assert_eq!(parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.expect("a").as_arr().len(), 3);
        assert_eq!(v.expect("a").as_arr()[2].expect("b").as_str(), "x\ny");
        assert_eq!(*v.expect("c"), Json::Null);
    }

    #[test]
    fn unicode_escape_and_multibyte() {
        let v = parse(r#""éé""#).unwrap();
        assert_eq!(v.as_str(), "éé");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"abc").is_err());
    }

    #[test]
    fn writer_escapes() {
        let v = Json::str("a\"b\\c\nd");
        assert_eq!(parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integers_written_exactly() {
        let v = Json::num(176402.0);
        assert_eq!(v.to_string(), "176402");
    }

    #[test]
    fn pretty_roundtrips_and_indents() {
        let v = parse(r#"{"a": [1, {"b": []}], "c": {}, "d": "x"}"#).unwrap();
        let pretty = v.to_string_pretty();
        assert_eq!(parse(&pretty).unwrap(), v, "pretty output must reparse");
        assert!(pretty.contains("\n  \"a\": ["), "expected 2-space indent: {pretty}");
        assert!(pretty.contains("\"b\": []"), "empty containers stay inline");
    }

    #[test]
    fn usize_vec() {
        let v = parse("[8, 16, 16]").unwrap();
        assert_eq!(v.as_usize_vec(), vec![8, 16, 16]);
    }
}
