//! Substrate utilities built from scratch for the offline environment:
//! PRNG, JSON (+ a serde-compatible typed layer), CLI parsing, logging, and
//! a mini property-testing harness. See DESIGN.md §0 for why these are
//! hand-rolled (vendor set has no rand/serde/clap/tracing/proptest).

pub mod bench;
pub mod cli;
pub mod json;
pub mod logging;
pub mod prng;
pub mod prop;
pub mod serde;

/// Format a ReLU count the way the paper does: `6K`, `59.1K`, `570K`.
pub fn fmt_relu_count(n: usize) -> String {
    if n >= 1000 {
        let k = n as f64 / 1000.0;
        if (k - k.round()).abs() < 1e-9 {
            format!("{}K", k.round() as usize)
        } else {
            format!("{k:.1}K")
        }
    } else {
        format!("{n}")
    }
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Percentile (nearest-rank) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[idx.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relu_count_formatting() {
        assert_eq!(fmt_relu_count(6000), "6K");
        assert_eq!(fmt_relu_count(59_100), "59.1K");
        assert_eq!(fmt_relu_count(570_000), "570K");
        assert_eq!(fmt_relu_count(123), "123");
    }

    #[test]
    fn stats() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
    }
}
