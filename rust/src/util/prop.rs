//! Mini property-testing harness (substrate; `proptest` is not in the
//! offline vendor set — documented substitution, DESIGN.md §0).
//!
//! `check(seed, cases, gen, prop)` draws `cases` random inputs and asserts
//! the property on each; on failure it performs greedy input shrinking (if
//! the generator supports it via [`Shrink`]) and reports the minimal
//! counterexample with the seed needed to replay it.

use super::prng::Rng;

/// Types that can propose smaller versions of themselves for shrinking.
pub trait Shrink: Sized {
    /// Candidate strictly-smaller inputs, most aggressive first.
    fn shrink(&self) -> Vec<Self> {
        Vec::new()
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<usize> {
        if *self == 0 {
            return vec![];
        }
        let mut c = vec![0, self / 2];
        if *self > 1 {
            c.push(self - 1);
        }
        c.dedup();
        c
    }
}

impl Shrink for f32 {
    fn shrink(&self) -> Vec<f32> {
        if *self == 0.0 {
            return vec![];
        }
        vec![0.0, self / 2.0]
    }
}

impl<T: Shrink + Clone> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        // Halve the vector, drop one element, shrink one element.
        out.push(self[..self.len() / 2].to_vec());
        if self.len() > 1 {
            out.push(self[1..].to_vec());
        }
        if let Some(first) = self.first() {
            for s in first.shrink() {
                let mut v = self.clone();
                v[0] = s;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink + Clone, B: Shrink + Clone> Shrink for (A, B) {
    fn shrink(&self) -> Vec<(A, B)> {
        let mut out: Vec<(A, B)> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run `prop` on `cases` inputs drawn by `gen`; panic with the (shrunk)
/// counterexample on the first failure.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink + Clone + std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            let minimal = shrink_failure(input, &prop);
            panic!(
                "property failed (seed={seed}, case={case}): {msg}\n  minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_failure<T, P>(mut input: T, prop: &P) -> T
where
    T: Shrink + Clone + std::fmt::Debug,
    P: Fn(&T) -> Result<(), String>,
{
    // Greedy descent, bounded so pathological shrinkers terminate.
    for _ in 0..64 {
        let mut advanced = false;
        for cand in input.shrink() {
            if prop(&cand).is_err() {
                input = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    input
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 200, |r| r.usize_below(100), |&n| {
            if n < 100 {
                Ok(())
            } else {
                Err(format!("{n} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_counterexample() {
        check(2, 200, |r| r.usize_below(100), |&n| {
            if n < 50 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }

    #[test]
    fn shrinker_minimizes() {
        // The minimal usize failing "n < 50" under our shrinker is 50.
        let min = shrink_failure(97usize, &|&n: &usize| {
            if n < 50 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
        assert_eq!(min, 50);
    }

    #[test]
    fn vec_shrinker_shrinks_length() {
        let min = shrink_failure(vec![5usize; 16], &|v: &Vec<usize>| {
            if v.len() < 3 {
                Ok(())
            } else {
                Err("len".into())
            }
        });
        assert!(min.len() >= 3 && min.len() <= 4, "{min:?}");
    }
}
