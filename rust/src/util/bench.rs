//! Timing harness for `benches/` (criterion substitute — DESIGN.md §0):
//! warmup, N timed samples, mean/p50/p95, paper-style row printing.

use super::{mean, percentile};
use std::time::Instant;

/// Timing summary of one benchmarked operation.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples_ms: Vec<f64>,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

impl BenchResult {
    pub fn row(&self) -> Vec<String> {
        vec![
            self.name.clone(),
            format!("{:.3}", self.mean_ms),
            format!("{:.3}", self.p50_ms),
            format!("{:.3}", self.p95_ms),
            format!("{}", self.samples_ms.len()),
        ]
    }
}

/// Time `f` for `iters` samples after `warmup` unrecorded runs.
pub fn time<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1000.0);
    }
    summarize(name, samples)
}

/// Build a result from externally-collected millisecond samples.
pub fn summarize(name: &str, samples_ms: Vec<f64>) -> BenchResult {
    BenchResult {
        name: name.to_string(),
        mean_ms: mean(&samples_ms),
        p50_ms: percentile(&samples_ms, 50.0),
        p95_ms: percentile(&samples_ms, 95.0),
        samples_ms,
    }
}

/// Print a block of results as a fixed-width table.
pub fn print_results(title: &str, results: &[BenchResult]) {
    let rows: Vec<Vec<String>> = results.iter().map(|r| r.row()).collect();
    crate::metrics::print_table(
        title,
        &["operation", "mean[ms]", "p50[ms]", "p95[ms]", "n"],
        &rows,
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_collects_samples() {
        let r = time("noop", 1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.samples_ms.len(), 5);
        assert!(r.mean_ms >= 0.0);
        assert!(r.p95_ms >= r.p50_ms);
    }

    #[test]
    fn summarize_stats() {
        let r = summarize("x", vec![1.0, 2.0, 3.0, 4.0]);
        assert!((r.mean_ms - 2.5).abs() < 1e-9);
        assert_eq!(r.p50_ms, 3.0); // nearest-rank
    }
}
