//! Tiny argument parser (substrate; no `clap` in the vendor set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional args
//! and subcommands. Unknown flags fail with a usage hint — typos in
//! experiment parameters must never run the wrong experiment silently.

use std::collections::BTreeMap;

/// Parsed command line: subcommand + flags + positionals.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. `bool_flags` lists flags that take no value.
    pub fn parse(argv: &[String], bool_flags: &[&str]) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if bool_flags.contains(&body) {
                    out.bools.push(body.to_string());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("flag --{body} expects a value"))?;
                    out.flags.insert(body.to_string(), v.clone());
                }
            } else if out.subcommand.is_none() && out.flags.is_empty() && out.positional.is_empty()
            {
                out.subcommand = Some(a.clone());
            } else {
                out.positional.push(a.clone());
            }
        }
        Ok(out)
    }

    pub fn parse_env(bool_flags: &[&str]) -> Result<Args, String> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv, bool_flags)
    }

    pub fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key) || self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: bad usize {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: bad u64 {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key}: bad f64 {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f32(&self, key: &str, default: f32) -> f32 {
        self.get_f64(key, default as f64) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = Args::parse(&v(&["bcd", "--budget", "1000", "--quiet", "pos1"]), &["quiet"])
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("bcd"));
        assert_eq!(a.get_usize("budget", 0), 1000);
        assert!(a.has("quiet"));
        assert_eq!(a.positional, v(&["pos1"]));
    }

    #[test]
    fn eq_form() {
        let a = Args::parse(&v(&["--lr=0.01"]), &[]).unwrap();
        assert_eq!(a.get_f64("lr", 0.0), 0.01);
    }

    #[test]
    fn missing_value_errors() {
        assert!(Args::parse(&v(&["--budget"]), &[]).is_err());
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&v(&[]), &[]).unwrap();
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
    }
}
