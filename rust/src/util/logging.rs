//! Leveled stderr logger (substrate; no `tracing` in the vendor set).
//!
//! `CDNL_LOG=debug|info|warn|error` controls verbosity (default `info`).
//! Timestamps are seconds since process start — enough to read schedule
//! behaviour off a log without a wallclock dependency.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Initialize from `CDNL_LOG`; idempotent.
pub fn init() {
    START.get_or_init(Instant::now);
    let lvl = match std::env::var("CDNL_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        Ok("error") => Level::Error,
        _ => Level::Info,
    };
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn set_level(lvl: Level) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    lvl as u8 >= LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, module: &str, msg: &str) {
    if !enabled(lvl) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let tag = match lvl {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    eprintln!("[{t:9.3} {tag} {module}] {msg}");
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*)) };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*)) };
}

#[macro_export]
macro_rules! warnlog {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*)) };
}

#[macro_export]
macro_rules! errorlog {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_ordered() {
        assert!(Level::Debug < Level::Info);
        assert!(Level::Info < Level::Warn);
        assert!(Level::Warn < Level::Error);
    }

    #[test]
    fn enabled_respects_level() {
        init();
        set_level(Level::Warn);
        assert!(!enabled(Level::Info));
        assert!(enabled(Level::Error));
        set_level(Level::Info);
    }
}
