//! serde-compatible serialization over [`crate::util::json::Json`].
//!
//! The offline vendor set has no `serde`/`serde_json` (DESIGN.md §0), so
//! this module provides the same *shape* the ecosystem expects: a
//! [`Serialize`] and a [`Deserialize`] trait, [`to_string`] /
//! [`to_string_pretty`] / [`from_str`] free functions mirroring
//! `serde_json`, and the [`crate::derive_serde!`] macro standing in for
//! `#[derive(Serialize, Deserialize)]` on plain structs. Typed manifests
//! (the artifact manifest, the run-store's `run.json`) build on this layer
//! instead of walking raw [`Json`] trees; swapping in the real crates later
//! is a mechanical change confined to this module.
//!
//! Semantics follow serde_json where it matters:
//! - unknown object keys are ignored on deserialization;
//! - a missing key deserializes as [`Json::Null`], so `Option<T>` fields
//!   absorb absent keys as `None`;
//! - errors carry a `key: expected ...` breadcrumb path.
//!
//! Numbers ride on `f64` (exact for integers `< 2^53`, far beyond any count
//! this crate stores). Full-range `u64` values — RNG states, seeds — must
//! NOT be stored as numbers; use [`HexU64`], which serializes as a hex
//! string.

use super::json::{self, Json};
use std::collections::BTreeMap;

/// A value that can render itself as a [`Json`] tree.
pub trait Serialize {
    fn serialize(&self) -> Json;
}

/// A value that can be reconstructed from a [`Json`] tree.
pub trait Deserialize: Sized {
    fn deserialize(v: &Json) -> Result<Self, String>;
}

/// Serialize to a compact JSON document (serde_json::to_string analog).
pub fn to_string<T: Serialize>(value: &T) -> String {
    value.serialize().to_string()
}

/// Serialize to an indented JSON document (serde_json::to_string_pretty
/// analog) — the run-store manifests use this so `run.json` stays
/// greppable and diffable.
pub fn to_string_pretty<T: Serialize>(value: &T) -> String {
    value.serialize().to_string_pretty()
}

/// Parse a JSON document into `T` (serde_json::from_str analog).
pub fn from_str<T: Deserialize>(text: &str) -> Result<T, String> {
    let v = json::parse(text)?;
    T::deserialize(&v)
}

/// Extract + deserialize one object field, with the key on the error path.
/// Missing keys yield [`Json::Null`] so `Option<T>` fields default to
/// `None` (the serde `#[serde(default)]` behavior this layer bakes in).
pub fn field<T: Deserialize>(v: &Json, key: &str) -> Result<T, String> {
    let item = match v {
        Json::Obj(m) => m.get(key),
        other => return Err(format!("{key}: expected object, got {other:.40?}")),
    };
    T::deserialize(item.unwrap_or(&Json::Null)).map_err(|e| format!("{key}: {e}"))
}

/// Implement [`Serialize`] + [`Deserialize`] for an existing plain struct —
/// the stand-in for `#[derive(Serialize, Deserialize)]` (DESIGN.md §0).
/// List every field; types are inferred from the struct definition:
///
/// ```
/// use cdnl::derive_serde;
/// pub struct Point { pub x: f64, pub y: f64 }
/// derive_serde!(Point { x, y });
/// let p: Point = cdnl::util::serde::from_str(r#"{"x": 1, "y": 2}"#).unwrap();
/// assert_eq!(p.y, 2.0);
/// ```
#[macro_export]
macro_rules! derive_serde {
    ($name:ident { $($field:ident),* $(,)? }) => {
        impl $crate::util::serde::Serialize for $name {
            fn serialize(&self) -> $crate::util::json::Json {
                let mut m = ::std::collections::BTreeMap::new();
                $(
                    m.insert(
                        stringify!($field).to_string(),
                        $crate::util::serde::Serialize::serialize(&self.$field),
                    );
                )*
                $crate::util::json::Json::Obj(m)
            }
        }
        impl $crate::util::serde::Deserialize for $name {
            fn deserialize(
                v: &$crate::util::json::Json,
            ) -> ::std::result::Result<Self, ::std::string::String> {
                ::std::result::Result::Ok($name {
                    $($field: $crate::util::serde::field(v, stringify!($field))?,)*
                })
            }
        }
    };
}

// ---- primitive impls -------------------------------------------------------

impl Serialize for bool {
    fn serialize(&self) -> Json {
        Json::Bool(*self)
    }
}

impl Deserialize for bool {
    fn deserialize(v: &Json) -> Result<Self, String> {
        match v {
            Json::Bool(b) => Ok(*b),
            other => Err(format!("expected bool, got {other:.40?}")),
        }
    }
}

impl Serialize for String {
    fn serialize(&self) -> Json {
        Json::Str(self.clone())
    }
}

impl Deserialize for String {
    fn deserialize(v: &Json) -> Result<Self, String> {
        match v {
            Json::Str(s) => Ok(s.clone()),
            other => Err(format!("expected string, got {other:.40?}")),
        }
    }
}

impl Serialize for f64 {
    fn serialize(&self) -> Json {
        Json::Num(*self)
    }
}

impl Deserialize for f64 {
    fn deserialize(v: &Json) -> Result<Self, String> {
        match v {
            Json::Num(n) => Ok(*n),
            other => Err(format!("expected number, got {other:.40?}")),
        }
    }
}

impl Serialize for f32 {
    fn serialize(&self) -> Json {
        Json::Num(*self as f64)
    }
}

impl Deserialize for f32 {
    fn deserialize(v: &Json) -> Result<Self, String> {
        f64::deserialize(v).map(|n| n as f32)
    }
}

impl Serialize for usize {
    fn serialize(&self) -> Json {
        debug_assert!(*self < (1usize << 53), "usize {self} exceeds exact f64 range");
        Json::Num(*self as f64)
    }
}

impl Deserialize for usize {
    fn deserialize(v: &Json) -> Result<Self, String> {
        let n = f64::deserialize(v)?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(format!("expected unsigned integer, got {n}"));
        }
        Ok(n as usize)
    }
}

/// A `u64` carried as a hex *string* in JSON, because JSON numbers round
/// through `f64` and lose bits above 2^53. RNG states and seeds use this.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct HexU64(pub u64);

impl Serialize for HexU64 {
    fn serialize(&self) -> Json {
        Json::Str(format!("{:016x}", self.0))
    }
}

impl Deserialize for HexU64 {
    fn deserialize(v: &Json) -> Result<Self, String> {
        let s = String::deserialize(v)?;
        u64::from_str_radix(&s, 16)
            .map(HexU64)
            .map_err(|_| format!("expected hex u64, got {s:?}"))
    }
}

/// Pack an RNG state for a manifest (see [`crate::util::prng::Rng::state`]).
pub fn hex_state(s: [u64; 4]) -> Vec<HexU64> {
    s.iter().map(|&w| HexU64(w)).collect()
}

/// Unpack an RNG state from a manifest.
pub fn unhex_state(v: &[HexU64]) -> Result<[u64; 4], String> {
    if v.len() != 4 {
        return Err(format!("expected 4 RNG state words, got {}", v.len()));
    }
    Ok([v[0].0, v[1].0, v[2].0, v[3].0])
}

// ---- container impls -------------------------------------------------------

impl<T: Serialize> Serialize for Vec<T> {
    fn serialize(&self) -> Json {
        Json::Arr(self.iter().map(Serialize::serialize).collect())
    }
}

impl<T: Deserialize> Deserialize for Vec<T> {
    fn deserialize(v: &Json) -> Result<Self, String> {
        match v {
            Json::Arr(items) => items
                .iter()
                .enumerate()
                .map(|(i, item)| T::deserialize(item).map_err(|e| format!("[{i}]: {e}")))
                .collect(),
            other => Err(format!("expected array, got {other:.40?}")),
        }
    }
}

impl<T: Serialize> Serialize for Option<T> {
    fn serialize(&self) -> Json {
        match self {
            Some(v) => v.serialize(),
            None => Json::Null,
        }
    }
}

impl<T: Deserialize> Deserialize for Option<T> {
    fn deserialize(v: &Json) -> Result<Self, String> {
        match v {
            Json::Null => Ok(None),
            other => T::deserialize(other).map(Some),
        }
    }
}

impl<T: Serialize> Serialize for BTreeMap<String, T> {
    fn serialize(&self) -> Json {
        Json::Obj(self.iter().map(|(k, v)| (k.clone(), v.serialize())).collect())
    }
}

impl<T: Deserialize> Deserialize for BTreeMap<String, T> {
    fn deserialize(v: &Json) -> Result<Self, String> {
        match v {
            Json::Obj(m) => m
                .iter()
                .map(|(k, item)| {
                    T::deserialize(item)
                        .map(|t| (k.clone(), t))
                        .map_err(|e| format!("{k}: {e}"))
                })
                .collect(),
            other => Err(format!("expected object, got {other:.40?}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Clone, Debug, PartialEq)]
    struct Inner {
        label: String,
        vals: Vec<usize>,
    }
    derive_serde!(Inner { label, vals });

    #[derive(Clone, Debug, PartialEq)]
    struct Outer {
        flag: bool,
        ratio: f64,
        inner: Inner,
        maybe: Option<String>,
        map: BTreeMap<String, f32>,
        words: Vec<HexU64>,
    }
    derive_serde!(Outer { flag, ratio, inner, maybe, map, words });

    fn sample() -> Outer {
        let mut map = BTreeMap::new();
        map.insert("a".to_string(), 0.5f32);
        Outer {
            flag: true,
            ratio: 2.25,
            inner: Inner { label: "x\ny".into(), vals: vec![1, 2, 3] },
            maybe: None,
            map,
            words: hex_state([u64::MAX, 0, 1, 0xDEADBEEFDEADBEEF]),
        }
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let v = sample();
        for text in [to_string(&v), to_string_pretty(&v)] {
            let back: Outer = from_str(&text).unwrap();
            assert_eq!(back, v);
        }
    }

    #[test]
    fn hex_u64_exact_at_full_range() {
        // 2^53-adjacent and full-range values survive exactly (a plain JSON
        // number would not).
        let v = sample();
        let back: Outer = from_str(&to_string(&v)).unwrap();
        assert_eq!(unhex_state(&back.words).unwrap(), [u64::MAX, 0, 1, 0xDEADBEEFDEADBEEF]);
        assert!(unhex_state(&back.words[..3]).is_err());
    }

    #[test]
    fn missing_key_is_none_extra_key_ignored() {
        let text = r#"{"flag": false, "ratio": 1, "inner": {"label": "l", "vals": []},
                       "map": {}, "words": [], "unknown_extra": 42}"#;
        let v: Outer = from_str(text).unwrap();
        assert_eq!(v.maybe, None);
        assert!(!v.flag);
    }

    #[test]
    fn errors_carry_key_path() {
        let text = r#"{"flag": false, "ratio": "nope", "inner": {"label": "l", "vals": []},
                       "map": {}, "words": []}"#;
        let err = from_str::<Outer>(text).unwrap_err();
        assert!(err.contains("ratio"), "error lacks key path: {err}");
        // Nested path: bad element inside inner.vals.
        let text = r#"{"flag": false, "ratio": 1, "inner": {"label": "l", "vals": [1, "x"]},
                       "map": {}, "words": []}"#;
        let err = from_str::<Outer>(text).unwrap_err();
        assert!(err.contains("inner") && err.contains("[1]"), "bad path: {err}");
    }

    #[test]
    fn non_integer_usize_rejected() {
        assert!(usize::deserialize(&Json::Num(1.5)).is_err());
        assert!(usize::deserialize(&Json::Num(-2.0)).is_err());
        assert_eq!(usize::deserialize(&Json::Num(7.0)).unwrap(), 7);
    }

    #[test]
    fn hex_u64_round_trips_across_the_2_53_boundary() {
        // 2^53 is where f64 loses integer exactness — exactly why u64s ride
        // the wire as hex strings instead of JSON numbers. Every boundary
        // neighbor must round-trip to the same bits.
        const P53: u64 = 1 << 53;
        for v in [P53 - 1, P53, P53 + 1, P53 + 2, u64::MAX - 1, u64::MAX, 0, 1] {
            let json = HexU64(v).serialize();
            let back = HexU64::deserialize(&json).unwrap();
            assert_eq!(back.0, v, "HexU64 must be exact at {v}");
        }
        // The f64 path genuinely cannot represent 2^53 + 1 (it rounds to
        // 2^53) — demonstrating the failure HexU64 exists to avoid.
        assert_eq!((P53 + 1) as f64 as u64, P53);
    }

    #[test]
    fn hex_u64_rejects_malformed_strings() {
        // Empty, non-hex, overflowing (2^64) and negative spellings all fail;
        // numbers are not accepted in place of hex strings.
        for bad in ["", "xyz", "g000000000000000", "10000000000000000", "-1"] {
            assert!(
                HexU64::deserialize(&Json::Str(bad.to_string())).is_err(),
                "must reject {bad:?}"
            );
        }
        assert!(HexU64::deserialize(&Json::Num(12.0)).is_err(), "numbers are not hex words");
    }
}
