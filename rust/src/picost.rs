//! Deprecated shim over [`crate::pi`] (kept so pre-PR-9 callers compile).
//!
//! The closed-form PI cost model lives in [`crate::pi::analytic`] now,
//! and the bare `lan()`/`wan()` constructors became the named
//! [`crate::pi::protocol`] registry (`pi::find("lan")`, `--proto lan`,
//! the `pi.protocol` config key). This module re-exports the types at
//! their old paths and wraps the old free functions with deprecation
//! notes; new code should import from `crate::pi`.

pub use crate::pi::{CostReport, Protocol};

use crate::model::Mask;
use crate::runtime::manifest::ModelInfo;

#[deprecated(note = "use crate::pi::LAN or crate::pi::find(\"lan\")")]
pub fn lan() -> Protocol {
    crate::pi::LAN.clone()
}

#[deprecated(note = "use crate::pi::WAN or crate::pi::find(\"wan\")")]
pub fn wan() -> Protocol {
    crate::pi::WAN.clone()
}

#[deprecated(note = "use crate::pi::estimate_macs")]
pub fn estimate_macs(info: &ModelInfo) -> f64 {
    crate::pi::estimate_macs(info)
}

#[deprecated(note = "use crate::pi::estimate")]
pub fn estimate(
    info: &ModelInfo,
    relus: usize,
    active_layers: usize,
    proto: &Protocol,
) -> CostReport {
    crate::pi::estimate(info, relus, active_layers, proto)
}

#[deprecated(note = "use crate::pi::estimate_state (or the pi::CostModel trait)")]
pub fn estimate_state(info: &ModelInfo, mask: &Mask, proto: &Protocol) -> CostReport {
    crate::pi::estimate_state(info, mask, proto)
}

#[cfg(test)]
mod tests {
    // The PR 9 compatibility contract: every pre-PR-9 call shape still
    // compiles and routes to the same numbers as the pi:: registry.
    #![allow(deprecated)]
    use super::*;

    #[test]
    fn old_paths_still_compile_and_agree() {
        assert_eq!(lan(), crate::pi::LAN);
        assert_eq!(wan(), crate::pi::WAN);
        let _: Protocol = lan();
    }
}
