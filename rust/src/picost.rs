//! Private-Inference cost model — why ReLU budgets matter at all.
//!
//! The paper's motivation (after DELPHI, GAZELLE): in hybrid HE/MPC
//! protocols, *linear* layers run under additively-homomorphic encryption
//! or pre-shared Beaver triples, while each *ReLU* needs a garbled-circuit
//! (GC) evaluation costing kilobytes of online communication. ReLU count
//! therefore dominates online latency. This module turns a (model, mask)
//! pair into estimated online bytes/latency so experiments can report the
//! PI-latency implication of every budget.
//!
//! Constants follow the DELPHI paper's reported costs (~2 KB and ~88 us
//! of compute per ReLU online with garbled circuits); they are estimates
//! and clearly labelled as such in reports.
//!
//! # Where the constants come from
//!
//! - `gc_bytes_per_relu = 2048`: DELPHI (Mishra et al., USENIX Security
//!   2020) reports ~2 KB of online garbled-circuit communication per ReLU;
//!   the PI baselines reproduced here budget against the same figure —
//!   see DeepReDuce (Jha et al. 2021, <https://arxiv.org/pdf/2103.01396>)
//!   and SNL (Cho et al. 2022, <https://arxiv.org/pdf/2202.02340>), both
//!   abstracted in PAPERS.md, which motivate ReLU count as *the* PI cost
//!   driver.
//! - `gc_secs_per_relu = 88e-6`: DELPHI's reported per-ReLU online GC
//!   compute on commodity CPUs.
//! - `bandwidth` / `rtt`: 1 Gbit/s + 0.5 ms ([`lan`]) and 100 Mbit/s +
//!   40 ms ([`wan`]) — the two deployment points the PI literature
//!   conventionally reports (e.g. SENet, Kundu et al. 2023,
//!   <https://arxiv.org/pdf/2301.09254>).
//! - `he_macs_per_sec = 5e8`: order-of-magnitude additively-homomorphic
//!   MAC throughput for the linear layers; linear cost is reported for
//!   context only and never dominates at the budgets studied.
//!
//! Each masked layer costs one HE↔GC share-translation round trip, which
//! is why `round_secs` scales with *active* layer count, not ReLU count.

use crate::runtime::manifest::ModelInfo;

/// Network + crypto cost constants for one deployment scenario.
#[derive(Clone, Debug)]
pub struct Protocol {
    pub name: &'static str,
    /// Online GC bytes exchanged per ReLU evaluation.
    pub gc_bytes_per_relu: f64,
    /// Local GC compute time per ReLU [s].
    pub gc_secs_per_relu: f64,
    /// Link bandwidth [bytes/s].
    pub bandwidth: f64,
    /// Round-trip time [s]; each masked layer costs one round of
    /// share-translation between the HE and GC domains.
    pub rtt: f64,
    /// Homomorphic MAC throughput for linear layers [MACs/s].
    pub he_macs_per_sec: f64,
}

/// 1 Gbit/s, 0.5 ms RTT — same-datacenter deployment.
pub fn lan() -> Protocol {
    Protocol {
        name: "LAN",
        gc_bytes_per_relu: 2048.0,
        gc_secs_per_relu: 88e-6,
        bandwidth: 125e6,
        rtt: 0.5e-3,
        he_macs_per_sec: 5e8,
    }
}

/// 100 Mbit/s, 40 ms RTT — client-to-cloud deployment.
pub fn wan() -> Protocol {
    Protocol {
        name: "WAN",
        gc_bytes_per_relu: 2048.0,
        gc_secs_per_relu: 88e-6,
        bandwidth: 12.5e6,
        rtt: 40e-3,
        he_macs_per_sec: 5e8,
    }
}

/// Estimated online cost of one private inference.
#[derive(Clone, Debug)]
pub struct CostReport {
    pub protocol: &'static str,
    pub relus: usize,
    pub macs: f64,
    pub online_bytes: f64,
    /// Communication + GC compute for the non-linear layers [s].
    pub relu_secs: f64,
    /// HE evaluation of the linear layers [s].
    pub linear_secs: f64,
    /// Round-trip latency across active masked layers [s].
    pub round_secs: f64,
    pub total_secs: f64,
}

/// Estimate multiply-accumulate count of the network from the manifest's
/// mask-layer table: each activation layer `[C, H, W]` is preceded by a
/// 3x3 conv from the previous layer's channel count (stem: input channels),
/// plus a final dense head. An analytic estimate — good to ~2x, which is
/// enough for relative PI-latency comparisons.
pub fn estimate_macs(info: &ModelInfo) -> f64 {
    let mut macs = 0.0f64;
    let mut prev_c = info.channels as f64;
    for e in &info.mask_layers {
        let (c, h, w) = (e.shape[0] as f64, e.shape[1] as f64, e.shape[2] as f64);
        macs += c * h * w * prev_c * 9.0;
        prev_c = c;
    }
    macs += prev_c * info.num_classes as f64; // head
    macs
}

/// Online-phase cost for a network with `relus` active ReLUs. Each mask
/// layer that still holds a ReLU costs one GC exchange = two direction
/// flips (tables down, re-shares up); the input/logit share transfers add
/// two endpoint rounds. This matches [`crate::protosim`]'s message walk.
pub fn estimate(info: &ModelInfo, relus: usize, active_layers: usize, proto: &Protocol) -> CostReport {
    let macs = estimate_macs(info);
    let online_bytes = relus as f64 * proto.gc_bytes_per_relu;
    let relu_secs = online_bytes / proto.bandwidth + relus as f64 * proto.gc_secs_per_relu;
    let linear_secs = macs / proto.he_macs_per_sec;
    let round_secs = (2 * active_layers + 2) as f64 * proto.rtt;
    CostReport {
        protocol: proto.name,
        relus,
        macs,
        online_bytes,
        relu_secs,
        linear_secs,
        round_secs,
        total_secs: relu_secs + linear_secs + round_secs,
    }
}

/// Convenience over a model state: counts active layers from the mask.
pub fn estimate_state(
    info: &ModelInfo,
    mask: &crate::model::Mask,
    proto: &Protocol,
) -> CostReport {
    let hist = mask.layer_histogram(info);
    let active = hist.iter().filter(|&&h| h > 0).count();
    estimate(info, mask.count(), active, proto)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::PackEntry;

    fn fake_info() -> ModelInfo {
        ModelInfo {
            key: "m".into(),
            backbone: "resnet".into(),
            num_classes: 10,
            image_size: 8,
            channels: 3,
            poly: false,
            param_size: 1,
            mask_size: 128 + 64,
            mask_layers: vec![
                PackEntry { name: "a".into(), shape: vec![2, 8, 8], offset: 0, size: 128 },
                PackEntry { name: "b".into(), shape: vec![4, 4, 4], offset: 128, size: 64 },
            ],
            param_entries: vec![],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn macs_analytic() {
        // conv1: 2*8*8*3*9 = 3456 ; conv2: 4*4*4*2*9 = 1152 ; head 4*10=40.
        assert_eq!(estimate_macs(&fake_info()), 3456.0 + 1152.0 + 40.0);
    }

    #[test]
    fn fewer_relus_cheaper() {
        let info = fake_info();
        let p = lan();
        let full = estimate(&info, 192, 2, &p);
        let half = estimate(&info, 96, 2, &p);
        assert!(half.total_secs < full.total_secs);
        assert_eq!(half.linear_secs, full.linear_secs, "linear part unaffected");
    }

    #[test]
    fn wan_dominated_by_comms() {
        let info = fake_info();
        let r = estimate(&info, 10_000, 2, &wan());
        assert!(r.relu_secs > r.linear_secs);
    }

    #[test]
    fn empty_layers_drop_rounds() {
        let info = fake_info();
        let mut m = crate::model::Mask::full(192);
        m.remove_layer(&info, 1);
        let r = estimate_state(&info, &m, &lan());
        assert_eq!(r.relus, 128);
        let full = estimate_state(&info, &crate::model::Mask::full(192), &lan());
        assert!(r.round_secs < full.round_secs);
    }
}
