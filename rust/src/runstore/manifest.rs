//! `run.json` — the versioned, serde-backed manifest of one experiment run.
//!
//! One manifest fully describes a run: identity (method, model, dataset,
//! config fingerprint), the exact config dump needed to reconstruct the
//! [`crate::config::Experiment`], stage provenance (which zoo checkpoints
//! fed it), and — for BCD — the per-sweep trace plus the resume cursor
//! (RNG states as hex, sweep count, starting budget). The manifest is
//! rewritten atomically after every sweep, so at any kill point the
//! directory holds a consistent `(run.json, sweep_<n>.cdnl)` pair.

use crate::bench::report::BenchReport;
use crate::config::Experiment;
use crate::coordinator::bcd::{BcdCursor, IterRecord, SweepEvent};
use crate::coordinator::finetune::FinetuneStats;
use crate::derive_serde;
use crate::methods::MethodOutcome;
use crate::runtime::backend::CallStats;
use crate::util::serde::{hex_state, unhex_state, HexU64};
use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// On-disk format version; [`crate::runstore::RunDir::load`] rejects
/// anything else (bump on breaking schema changes).
pub const RUN_FORMAT: usize = 1;

/// `status` values. Plain strings on disk; a killed process simply leaves
/// `RUNNING` behind, which is what makes a run recognizably resumable.
pub const RUNNING: &str = "running";
pub const COMPLETE: &str = "complete";
pub const FAILED: &str = "failed";

/// Seconds since the unix epoch (manifest timestamps).
pub fn now_unix() -> usize {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as usize)
        .unwrap_or(0)
}

/// Provenance of one pipeline stage that fed this run (zoo access).
#[derive(Clone, Debug, PartialEq)]
pub struct StageRecord {
    /// Stage name: `baseline`, `snl_ref`, `autorep_ref`, `bcd`, ...
    pub stage: String,
    /// Checkpoint path the stage was loaded from / saved to.
    pub path: String,
    /// ReLU budget of the stage's output state.
    pub budget: usize,
    /// True when served from the zoo cache, false when built this run.
    pub cached: bool,
    pub wall_secs: f64,
}
derive_serde!(StageRecord { stage, path, budget, cached, wall_secs });

/// One completed BCD sweep — [`IterRecord`] plus the removed-index trace
/// (which makes every intermediate mask reconstructable from the reference
/// checkpoint alone).
#[derive(Clone, Debug, PartialEq)]
pub struct IterTrace {
    pub t: usize,
    pub budget_after: usize,
    pub base_acc: f64,
    pub chosen_dacc: f64,
    pub trials_evaluated: usize,
    pub trials_bounded: usize,
    pub early_accept: bool,
    pub ft_steps: usize,
    pub ft_first_loss: f32,
    pub ft_last_loss: f32,
    pub ft_mean_acc: f64,
    pub wall_ms: f64,
    /// Flat ReLU indices removed by this sweep (sorted).
    pub removed: Vec<usize>,
}
derive_serde!(IterTrace {
    t,
    budget_after,
    base_acc,
    chosen_dacc,
    trials_evaluated,
    trials_bounded,
    early_accept,
    ft_steps,
    ft_first_loss,
    ft_last_loss,
    ft_mean_acc,
    wall_ms,
    removed,
});

impl IterTrace {
    pub fn from_event(ev: &SweepEvent) -> IterTrace {
        let r = ev.record;
        IterTrace {
            t: r.t,
            budget_after: r.budget_after,
            base_acc: r.base_acc,
            chosen_dacc: r.chosen_dacc,
            trials_evaluated: r.trials_evaluated,
            trials_bounded: r.trials_bounded,
            early_accept: r.early_accept,
            ft_steps: r.finetune.steps,
            ft_first_loss: r.finetune.first_loss,
            ft_last_loss: r.finetune.last_loss,
            ft_mean_acc: r.finetune.mean_acc,
            wall_ms: r.wall_ms,
            removed: ev.removed.to_vec(),
        }
    }

    /// Back to the in-memory record — used to reconstruct a full
    /// [`crate::coordinator::bcd::BcdOutcome`] across an interruption.
    pub fn to_record(&self) -> IterRecord {
        IterRecord {
            t: self.t,
            budget_after: self.budget_after,
            base_acc: self.base_acc,
            chosen_dacc: self.chosen_dacc,
            trials_evaluated: self.trials_evaluated,
            trials_bounded: self.trials_bounded,
            early_accept: self.early_accept,
            finetune: FinetuneStats {
                steps: self.ft_steps,
                first_loss: self.ft_first_loss,
                last_loss: self.ft_last_loss,
                mean_acc: self.ft_mean_acc,
            },
            wall_ms: self.wall_ms,
        }
    }
}

/// BCD progress: the resume cursor + the full sweep trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BcdProgress {
    pub sweeps_done: usize,
    /// Trial-sampling RNG state after the last completed sweep (hex words —
    /// JSON numbers cannot carry full-range u64).
    pub rng: Vec<HexU64>,
    /// Finetune-batch RNG state after the last completed sweep.
    pub ft_rng: Vec<HexU64>,
    pub iterations: Vec<IterTrace>,
}
derive_serde!(BcdProgress { sweeps_done, rng, ft_rng, iterations });

impl BcdProgress {
    /// The [`BcdCursor`] to hand `run_bcd_resumable`. `b_ref` is the run's
    /// starting budget (the manifest's `b_start`).
    pub fn cursor(&self, b_ref: usize) -> Result<BcdCursor> {
        Ok(BcdCursor {
            sweeps_done: self.sweeps_done,
            b_ref,
            rng: unhex_state(&self.rng).map_err(|e| anyhow!("bcd.rng: {e}"))?,
            ft_rng: unhex_state(&self.ft_rng).map_err(|e| anyhow!("bcd.ft_rng: {e}"))?,
        })
    }

    /// Record a sweep event (cursor overwrite + trace append).
    pub fn update(&mut self, ev: &SweepEvent) {
        self.sweeps_done = ev.cursor.sweeps_done;
        self.rng = hex_state(ev.cursor.rng);
        self.ft_rng = hex_state(ev.cursor.ft_rng);
        self.iterations.push(IterTrace::from_event(ev));
    }
}

/// On-disk snapshot of one entry point's backend statistics — the document
/// dual of [`CallStats`] (`calls` rides as a JSON number; per-entry-point
/// call counts sit far below 2^53).
#[derive(Clone, Debug, PartialEq)]
pub struct CallStatsDoc {
    pub calls: usize,
    pub total_secs: f64,
    pub compile_secs: f64,
}
derive_serde!(CallStatsDoc { calls, total_secs, compile_secs });

/// Snapshot a backend stats map for a run manifest, so `cdnl runs show`
/// can replay per-entry-point timings (and the `prefix_cache:*` counters)
/// long after the recording process exited.
pub fn stats_snapshot(stats: &BTreeMap<String, CallStats>) -> BTreeMap<String, CallStatsDoc> {
    stats
        .iter()
        .map(|(k, s)| {
            (
                k.clone(),
                CallStatsDoc {
                    calls: s.calls as usize,
                    total_secs: s.total_secs,
                    compile_secs: s.compile_secs,
                },
            )
        })
        .collect()
}

/// Digest provenance of one CAS blob a run depends on (published params,
/// checkpoints, zoo stages — see [`crate::cas`]). `cdnl runs gc` treats
/// every blob referenced by a surviving manifest as live.
#[derive(Clone, Debug, PartialEq)]
pub struct BlobRef {
    /// Human-readable role, e.g. `params_sweep3`.
    pub name: String,
    /// FNV-256 content digest (64 hex chars) — the CAS key.
    pub digest: String,
    /// Blob size in bytes.
    pub bytes: usize,
}
derive_serde!(BlobRef { name, digest, bytes });

/// Final result summary, filled when a run completes.
#[derive(Clone, Debug, PartialEq)]
pub struct RunResult {
    pub final_budget: usize,
    pub acc_before: f64,
    pub acc_after: f64,
    /// BCD runs: total sweep-loop time summed across sessions (comparable
    /// between interrupted and uninterrupted runs). Other methods: whole
    /// command wall time.
    pub wall_secs: f64,
}
derive_serde!(RunResult { final_budget, acc_before, acc_after, wall_secs });

/// The `run.json` document.
#[derive(Clone, Debug)]
pub struct RunManifest {
    pub format: usize,
    pub run_id: String,
    /// `bcd`, `snl`, `autorep`, `senet`, `deepreduce`, `train`.
    pub method: String,
    pub status: String,
    pub backend: String,
    pub model_key: String,
    pub dataset: String,
    pub config_fingerprint: String,
    /// Canonical key=value dump ([`Experiment::dump`]); re-`apply`ing it
    /// onto a default experiment reconstructs this run's configuration.
    pub config: BTreeMap<String, String>,
    pub created_unix: usize,
    pub updated_unix: usize,
    /// Budget at run start (for BCD this is `B_ref`, the schedule anchor).
    pub b_start: usize,
    pub b_target: usize,
    pub stages: Vec<StageRecord>,
    pub bcd: Option<BcdProgress>,
    /// Typed per-stage outcomes from the method registry
    /// ([`crate::methods::registry`]): one entry for a single-method run,
    /// one per stage for a chain (`snl+bcd`), in execution order — how
    /// `cdnl runs show` prints method-specific detail for every method.
    /// `None` on manifests written before this field existed (format 1
    /// stays readable).
    pub outcomes: Option<Vec<MethodOutcome>>,
    pub result: Option<RunResult>,
    /// Per-entry-point backend statistics at seal time (including the
    /// staged-execution `prefix_cache:*` counters). `None` on manifests
    /// written before this field existed — format 1 stays readable.
    pub stats: Option<BTreeMap<String, CallStatsDoc>>,
    /// For `method == "bench"` runs sealed via `cdnl bench run --record`:
    /// the full benchmark report, so the perf trajectory lives in the
    /// run-store next to the experiments it describes. `None` everywhere
    /// else (and on pre-bench manifests — format 1 stays readable).
    pub bench: Option<BenchReport>,
    /// Sealed by `cdnl serve <run-id> --record`: the fleet-scale serving
    /// report ([`crate::pi::serve`]) priced under the run's `pi.protocol`,
    /// so a linearized model's deployment cost lives next to the run that
    /// produced it. `None` everywhere else (and on pre-serve manifests —
    /// format 1 stays readable).
    pub serve: Option<crate::pi::ServeReport>,
    /// CAS blob-digest provenance for distributed runs (see
    /// [`crate::dist`]): every blob this run published or depends on.
    /// `runs gc` keeps referenced blobs alive. `None` on local runs and on
    /// pre-dist manifests — format 1 stays readable.
    pub blobs: Option<Vec<BlobRef>>,
}
derive_serde!(RunManifest {
    format,
    run_id,
    method,
    status,
    backend,
    model_key,
    dataset,
    config_fingerprint,
    config,
    created_unix,
    updated_unix,
    b_start,
    b_target,
    stages,
    bcd,
    outcomes,
    result,
    stats,
    bench,
    serve,
    blobs,
});

impl RunManifest {
    /// Fresh `running` manifest for a method run. `run_id` is assigned by
    /// [`crate::runstore::RunStore::create`].
    pub fn new(
        method: &str,
        exp: &Experiment,
        backend: &str,
        b_start: usize,
        b_target: usize,
    ) -> RunManifest {
        let now = now_unix();
        RunManifest {
            format: RUN_FORMAT,
            run_id: String::new(),
            method: method.to_string(),
            status: RUNNING.to_string(),
            backend: backend.to_string(),
            model_key: exp.model_key(),
            dataset: exp.dataset.clone(),
            config_fingerprint: exp.fingerprint(),
            config: exp.dump(),
            created_unix: now,
            updated_unix: now,
            b_start,
            b_target,
            stages: Vec::new(),
            bcd: None,
            outcomes: None,
            result: None,
            stats: None,
            bench: None,
            serve: None,
            blobs: None,
        }
    }

    /// A run is resumable when it never reached a terminal success state.
    pub fn resumable(&self) -> bool {
        self.method == "bcd" && self.status != COMPLETE
    }

    /// Rebuild the [`Experiment`] this run was configured with. A
    /// fingerprint drift (new config keys with changed defaults since the
    /// run was recorded) is logged, not fatal: the recorded keys still
    /// apply verbatim.
    pub fn experiment(&self) -> Result<Experiment> {
        let mut exp = Experiment::default();
        for (k, v) in &self.config {
            exp.apply(k, v)
                .map_err(|e| anyhow!("run {}: config {k}={v}: {e}", self.run_id))?;
        }
        if exp.fingerprint() != self.config_fingerprint {
            crate::warnlog!(
                "run {}: config fingerprint drifted ({} recorded, {} reconstructed) — defaults added since recording?",
                self.run_id,
                self.config_fingerprint,
                exp.fingerprint()
            );
        }
        Ok(exp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::serde as sd;

    fn sample() -> RunManifest {
        let exp = Experiment::default();
        let mut m = RunManifest::new("bcd", &exp, "reference", 2000, 1000);
        m.run_id = "bcd-resnet_16x16_c10-00000000-1".into();
        m.stages.push(StageRecord {
            stage: "snl_ref".into(),
            path: "results/zoo/reference/x.cdnl".into(),
            budget: 2000,
            cached: true,
            wall_secs: 0.1,
        });
        m.bcd = Some(BcdProgress {
            sweeps_done: 2,
            rng: hex_state([u64::MAX, 1, 2, 3]),
            ft_rng: hex_state([4, 5, 6, u64::MAX - 1]),
            iterations: vec![IterTrace {
                t: 1,
                budget_after: 1900,
                base_acc: 51.25,
                chosen_dacc: 0.5,
                trials_evaluated: 7,
                trials_bounded: 3,
                early_accept: false,
                ft_steps: 4,
                ft_first_loss: 2.5,
                ft_last_loss: 2.25,
                ft_mean_acc: 50.0,
                wall_ms: 12.5,
                removed: vec![3, 14, 15],
            }],
        });
        m
    }

    #[test]
    fn manifest_roundtrips_bit_exact() {
        let mut m = sample();
        let mut stats = std::collections::BTreeMap::new();
        stats.insert(
            "m:eval_batch".to_string(),
            CallStats { calls: 42, total_secs: 1.5, compile_secs: 0.0 },
        );
        stats.insert(
            "prefix_cache:hit".to_string(),
            CallStats { calls: 7, total_secs: 0.0, compile_secs: 0.0 },
        );
        m.stats = Some(stats_snapshot(&stats));
        let text = sd::to_string_pretty(&m);
        let back: RunManifest = sd::from_str(&text).unwrap();
        assert_eq!(back.run_id, m.run_id);
        assert_eq!(back.config, m.config);
        assert_eq!(back.stages, m.stages);
        assert_eq!(back.bcd, m.bcd);
        assert_eq!(back.result, m.result);
        assert_eq!(back.stats, m.stats);
        assert_eq!(back.stats.as_ref().unwrap()["prefix_cache:hit"].calls, 7);
        // Full-range RNG words survive the JSON round trip exactly.
        let cur = back.bcd.as_ref().unwrap().cursor(m.b_start).unwrap();
        assert_eq!(cur.rng, [u64::MAX, 1, 2, 3]);
        assert_eq!(cur.b_ref, 2000);
        assert_eq!(cur.sweeps_done, 2);
    }

    #[test]
    fn manifest_without_stats_field_still_parses() {
        // Pre-stats format-1 documents lack the key entirely; it must
        // deserialize as None, not fail.
        let m = sample();
        let text = sd::to_string_pretty(&m).replace("\"stats\"", "\"stats_from_the_future\"");
        let back: RunManifest = sd::from_str(&text).unwrap();
        assert_eq!(back.stats, None);
        assert_eq!(back.run_id, m.run_id);
    }

    #[test]
    fn method_configs_and_outcomes_ride_the_manifest() {
        // The ISSUE 5 provenance bug: autorep/senet/deepreduce configs used
        // to be built from Default::default() at the call site, invisible
        // to manifests. Now they live in Experiment, so the recorded config
        // dump carries them and `experiment()` reconstructs them exactly.
        let mut exp = Experiment::default();
        exp.apply("senet.kd_steps", "7").unwrap();
        exp.apply("autorep.hysteresis", "0.4").unwrap();
        exp.apply("deepreduce.seed", "123").unwrap();
        let mut m = RunManifest::new("senet", &exp, "reference", 384, 200);
        assert_eq!(m.config.get("senet.kd_steps").unwrap(), "7");
        assert_eq!(m.config.get("autorep.hysteresis").unwrap(), "0.4");
        assert_eq!(m.config.get("deepreduce.seed").unwrap(), "123");
        let back = m.experiment().unwrap();
        assert_eq!(back.senet.kd_steps, 7);
        assert_eq!(back.deepreduce.seed, 123);
        assert_eq!(back.fingerprint(), m.config_fingerprint);

        // Typed outcomes round-trip through run.json; old manifests
        // without the key still parse (None).
        m.outcomes = Some(vec![MethodOutcome::Senet(
            crate::methods::registry::SenetSummary {
                sensitivity: vec![1.5, 0.5],
                allocation: vec![150, 50],
                kd_first_loss: 2.0,
                kd_last_loss: 1.5,
                final_budget: 200,
            },
        )]);
        let text = sd::to_string_pretty(&m);
        let back: RunManifest = sd::from_str(&text).unwrap();
        assert_eq!(back.outcomes, m.outcomes);
        let stripped = text.replace("\"outcomes\"", "\"outcomes_from_the_future\"");
        let old: RunManifest = sd::from_str(&stripped).unwrap();
        assert_eq!(old.outcomes, None);
    }

    #[test]
    fn serve_report_rides_the_manifest() {
        // `cdnl serve --record` seals a ServeReport; it must round-trip,
        // and pre-serve format-1 documents (no key) must parse as None.
        let mut m = sample();
        m.serve = Some(crate::pi::ServeReport {
            protocol: "lan".into(),
            clients: 2,
            requests: 3,
            completed: 6,
            relus: 488,
            active_layers: 17,
            rounds_per_inference: 36,
            online_rounds: 216,
            up_bytes: 30144,
            down_bytes: 5996784,
            gemm_jobs: 102,
            gemm_batches: 60,
            prep_completed: 6,
            events: 1000,
            p50_ms: 1.5,
            p95_ms: 2.5,
            p99_ms: 3.0,
            mean_ms: 1.75,
            makespan_secs: 0.5,
            throughput_rps: 12.0,
        });
        let text = sd::to_string_pretty(&m);
        let back: RunManifest = sd::from_str(&text).unwrap();
        assert_eq!(back.serve, m.serve);
        let stripped = text.replace("\"serve\"", "\"serve_from_the_future\"");
        let old: RunManifest = sd::from_str(&stripped).unwrap();
        assert_eq!(old.serve, None);
    }

    #[test]
    fn blob_provenance_rides_the_manifest() {
        // Distributed runs record CAS digests; old manifests (no key) parse
        // as None — format 1 stays readable.
        let mut m = sample();
        m.blobs = Some(vec![BlobRef {
            name: "params_sweep1".into(),
            digest: "ab".repeat(32),
            bytes: 4096,
        }]);
        let text = sd::to_string_pretty(&m);
        let back: RunManifest = sd::from_str(&text).unwrap();
        assert_eq!(back.blobs, m.blobs);
        let stripped = text.replace("\"blobs\"", "\"blobs_from_the_future\"");
        let old: RunManifest = sd::from_str(&stripped).unwrap();
        assert_eq!(old.blobs, None);
    }

    #[test]
    fn experiment_reconstructs() {
        let m = sample();
        let exp = m.experiment().unwrap();
        assert_eq!(exp.dataset, "synth10");
        assert_eq!(exp.fingerprint(), m.config_fingerprint);
    }

    #[test]
    fn iter_trace_record_roundtrip() {
        let tr = sample().bcd.unwrap().iterations[0].clone();
        let rec = tr.to_record();
        assert_eq!(rec.t, 1);
        assert_eq!(rec.finetune.steps, 4);
        assert_eq!(rec.budget_after, tr.budget_after);
    }
}
