//! Resumable experiment run-store (DESIGN.md §6).
//!
//! BCD linearization runs are long-lived discrete searches (hundreds of
//! coordinate sweeps); before this subsystem a crash or preemption lost
//! everything except what the model zoo happened to cache. The run-store
//! gives every experiment run a directory under `<out_dir>/runs/<run_id>/`:
//!
//! ```text
//! runs/bcd-resnet_16x16_c10-5fa3c1d2-1/
//!   run.json          versioned serde manifest: config dump + fingerprint,
//!                     backend, stage provenance, per-sweep BCD trace,
//!                     resume cursor (RNG states), timings, result
//!   ref.cdnl          the state the run started from (checkpoint)
//!   sweep_<n>.cdnl    state after the last completed sweep (rolling)
//! ```
//!
//! `run.json` and every checkpoint are written **atomically**
//! (write-to-temp + rename) and the manifest is only advanced *after* its
//! sweep checkpoint exists, so a kill at any instant leaves a consistent
//! pair on disk. `cdnl runs resume <id>` rebuilds the experiment from the
//! config dump, loads the checkpoint, restores both RNG streams from the
//! cursor, and continues — bit-identical to an uninterrupted run (verified
//! in `rust/tests/integration_runstore.rs`).
//!
//! The CLI surface is `cdnl runs list|show|resume|gc`.

pub mod manifest;

pub use manifest::{
    stats_snapshot, BcdProgress, BlobRef, CallStatsDoc, IterTrace, RunManifest, RunResult,
    StageRecord, COMPLETE, FAILED, RUNNING, RUN_FORMAT,
};

use crate::coordinator::bcd::SweepEvent;
use crate::model::ModelState;
use crate::runtime::manifest::ModelInfo;
use crate::util::serde as sd;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

/// Typed, actionable errors for operations that need a run in a particular
/// state (`cdnl serve <run-id>`, `cdnl runs resume <id>`). Each message
/// names the run's actual status and the command that would move it along —
/// callers (and tests) can also `downcast_ref::<RunStateError>()` instead
/// of string-matching.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RunStateError {
    /// `runs resume` on a run that already finished.
    AlreadyComplete { run_id: String },
    /// An operation needing a sealed (`complete`) run found another status.
    NotComplete { run_id: String, status: String, needed_by: String },
    /// A run whose manifest lacks the sealed payload (final mask trace /
    /// result summary) the operation needs.
    MissingResult { run_id: String, status: String, needed_by: String },
}

impl std::fmt::Display for RunStateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RunStateError::AlreadyComplete { run_id } => write!(
                f,
                "run {run_id} is already complete — nothing to resume \
                 (inspect it with `cdnl runs show {run_id}`)"
            ),
            RunStateError::NotComplete { run_id, status, needed_by } => write!(
                f,
                "run {run_id} has status {status:?}, but {needed_by} needs a complete run — \
                 finish it with `cdnl runs resume {run_id}`"
            ),
            RunStateError::MissingResult { run_id, status, needed_by } => write!(
                f,
                "run {run_id} (status {status:?}) has no sealed result/final mask in its \
                 manifest, which {needed_by} needs — re-record it (or resume with \
                 `cdnl runs resume {run_id}` if it is a bcd run)"
            ),
        }
    }
}

impl std::error::Error for RunStateError {}

/// Write `bytes` to `path` atomically: temp file in the same directory,
/// then rename (rename is atomic on POSIX within a filesystem).
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let dir = path.parent().ok_or_else(|| anyhow!("{path:?} has no parent"))?;
    std::fs::create_dir_all(dir)?;
    let tmp = dir.join(format!(
        ".{}.tmp",
        path.file_name().and_then(|n| n.to_str()).unwrap_or("runstore")
    ));
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    Ok(())
}

/// Atomic [`ModelState::save`]: serialize to a temp sibling, then rename.
pub fn save_state_atomic(st: &ModelState, path: &Path) -> Result<()> {
    let dir = path.parent().ok_or_else(|| anyhow!("{path:?} has no parent"))?;
    std::fs::create_dir_all(dir)?;
    let tmp = dir.join(format!(
        ".{}.tmp",
        path.file_name().and_then(|n| n.to_str()).unwrap_or("state")
    ));
    st.save(&tmp)?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
    Ok(())
}

/// One run's directory + its (in-memory) manifest.
#[derive(Debug)]
pub struct RunDir {
    pub dir: PathBuf,
    pub manifest: RunManifest,
}

impl RunDir {
    /// Load `<dir>/run.json`, rejecting unknown format versions.
    pub fn load(dir: PathBuf) -> Result<RunDir> {
        let path = dir.join("run.json");
        let text =
            std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
        let m: RunManifest =
            sd::from_str(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        if m.format != RUN_FORMAT {
            bail!(
                "{path:?}: run format {} unsupported (this build reads format {RUN_FORMAT})",
                m.format
            );
        }
        Ok(RunDir { dir, manifest: m })
    }

    /// Atomically persist the manifest (bumps `updated_unix`).
    pub fn save(&mut self) -> Result<()> {
        self.manifest.updated_unix = manifest::now_unix();
        let text = sd::to_string_pretty(&self.manifest);
        write_atomic(&self.dir.join("run.json"), text.as_bytes())
    }

    /// Checkpoint of the state the run started from.
    pub fn ref_state_path(&self) -> PathBuf {
        self.dir.join("ref.cdnl")
    }

    /// Checkpoint written after sweep `t`.
    pub fn sweep_state_path(&self, t: usize) -> PathBuf {
        self.dir.join(format!("sweep_{t}.cdnl"))
    }

    /// The checkpoint a resume should start from: the last completed
    /// sweep's state, or the reference state when no sweep finished.
    pub fn resume_state_path(&self) -> PathBuf {
        match &self.manifest.bcd {
            Some(p) if p.sweeps_done > 0 => self.sweep_state_path(p.sweeps_done),
            _ => self.ref_state_path(),
        }
    }

    /// Load the resume checkpoint, validated against the model `info` and
    /// the manifest's recorded progress (a half-written directory — e.g. a
    /// checkpoint ahead of the manifest — is detected here, not silently
    /// resumed into a diverged trajectory).
    pub fn load_resume_state(&self, info: &ModelInfo) -> Result<ModelState> {
        let path = self.resume_state_path();
        let st = ModelState::load(&path, info)
            .with_context(|| format!("run {}: loading {path:?}", self.manifest.run_id))?;
        let expect = match &self.manifest.bcd {
            Some(p) if p.sweeps_done > 0 => p
                .iterations
                .last()
                .map(|it| it.budget_after)
                .unwrap_or(self.manifest.b_start),
            _ => self.manifest.b_start,
        };
        if st.budget() != expect {
            bail!(
                "run {}: checkpoint budget {} does not match manifest ({expect}) — \
                 the run directory is inconsistent",
                self.manifest.run_id,
                st.budget()
            );
        }
        Ok(st)
    }
}

/// A directory of runs: `<root>/<run_id>/run.json`.
#[derive(Clone, Debug)]
pub struct RunStore {
    root: PathBuf,
}

impl RunStore {
    /// Open (lazily creating) a store rooted at `root`.
    pub fn open(root: impl Into<PathBuf>) -> RunStore {
        RunStore { root: root.into() }
    }

    /// The conventional store for an experiment: `<out_dir>/runs`.
    pub fn for_experiment(exp: &crate::config::Experiment) -> RunStore {
        RunStore::open(Path::new(&exp.out_dir).join("runs"))
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// Allocate a run directory for `m` (assigning a unique, readable
    /// `run_id`) and write the initial manifest.
    pub fn create(&self, mut m: RunManifest) -> Result<RunDir> {
        std::fs::create_dir_all(&self.root)?;
        let base = format!("{}-{}-{}", m.method, m.model_key, &m.config_fingerprint[..8]);
        let mut n = 1usize;
        let (run_id, dir) = loop {
            let id = format!("{base}-{n}");
            let dir = self.root.join(&id);
            if !dir.exists() {
                break (id, dir);
            }
            n += 1;
        };
        std::fs::create_dir_all(&dir)?;
        m.run_id = run_id;
        let mut rd = RunDir { dir, manifest: m };
        rd.save()?;
        Ok(rd)
    }

    /// Load one run by id.
    pub fn get(&self, run_id: &str) -> Result<RunDir> {
        let dir = self.root.join(run_id);
        if !dir.join("run.json").exists() {
            bail!(
                "no run {run_id:?} under {:?} (try `cdnl runs list`)",
                self.root
            );
        }
        RunDir::load(dir)
    }

    /// All runs, newest first (by creation time). Unreadable or
    /// foreign-format directories are skipped with a warning.
    pub fn list(&self) -> Result<Vec<RunManifest>> {
        let mut out = Vec::new();
        let entries = match std::fs::read_dir(&self.root) {
            Ok(e) => e,
            Err(_) => return Ok(out), // no store yet == no runs
        };
        for entry in entries {
            let entry = entry?;
            if !entry.path().join("run.json").exists() {
                continue;
            }
            match RunDir::load(entry.path()) {
                Ok(rd) => out.push(rd.manifest),
                Err(e) => crate::warnlog!("runstore: skipping {:?}: {e:#}", entry.path()),
            }
        }
        // Same-second creations (common for back-to-back CLI runs) tie on
        // created_unix; the numeric id suffix breaks the tie newest-first,
        // so `gc --keep N` never favors an older run over a newer one.
        fn id_seq(id: &str) -> usize {
            id.rsplit('-').next().and_then(|s| s.parse().ok()).unwrap_or(0)
        }
        out.sort_by(|a, b| {
            b.created_unix
                .cmp(&a.created_unix)
                .then_with(|| id_seq(&b.run_id).cmp(&id_seq(&a.run_id)))
                .then_with(|| b.run_id.cmp(&a.run_id))
        });
        Ok(out)
    }

    /// The run ids [`Self::gc`] would remove, without touching the disk —
    /// the `cdnl runs gc --dry-run` preview. Terminal runs (`complete` /
    /// `failed`) beyond the `keep` most recent are reclaimable; `all` also
    /// marks non-terminal (resumable) runs.
    pub fn gc_candidates(&self, keep: usize, all: bool) -> Result<Vec<String>> {
        let runs = self.list()?; // newest first
        let mut doomed = Vec::new();
        let mut kept_terminal = 0usize;
        for m in runs {
            let terminal = m.status == COMPLETE || m.status == FAILED;
            let reclaim = if terminal {
                kept_terminal += 1;
                kept_terminal > keep
            } else {
                all
            };
            if reclaim {
                doomed.push(m.run_id);
            }
        }
        Ok(doomed)
    }

    /// Garbage-collect run directories (the policy of
    /// [`Self::gc_candidates`], applied). Returns the removed ids.
    pub fn gc(&self, keep: usize, all: bool) -> Result<Vec<String>> {
        let removed = self.gc_candidates(keep, all)?;
        for id in &removed {
            std::fs::remove_dir_all(self.root.join(id))
                .with_context(|| format!("removing run {id}"))?;
        }
        Ok(removed)
    }

    /// Every CAS digest referenced by a manifest that would *survive*
    /// removal of the `doomed` run ids — the live set [`crate::cas::CasStore::gc`]
    /// must spare. Unioning over surviving manifests (rather than
    /// subtracting doomed ones) means a blob shared between a doomed and a
    /// live run is always kept.
    pub fn live_blob_digests(&self, doomed: &[String]) -> Result<BTreeSet<String>> {
        let mut live = BTreeSet::new();
        for m in self.list()? {
            if doomed.contains(&m.run_id) {
                continue;
            }
            for b in m.blobs.iter().flatten() {
                live.insert(b.digest.clone());
            }
        }
        Ok(live)
    }
}

/// Sweep-by-sweep persister: wire [`BcdRecorder::observe`] into
/// [`crate::coordinator::bcd::run_bcd_resumable`]'s sweep hook and every
/// completed sweep becomes durable.
///
/// Write order per sweep (crash-safe at every point):
/// 1. `sweep_<t>.cdnl` — post-sweep state, atomic;
/// 2. `run.json` — cursor + trace advanced to `t`, atomic;
/// 3. `sweep_<t-1>.cdnl` removed (the manifest no longer references it).
///
/// A kill between (1) and (2) leaves the manifest at `t-1` with both
/// checkpoints present — resume reads `sweep_<t-1>` and replays sweep `t`
/// identically, overwriting the orphan.
pub struct BcdRecorder<'a> {
    run: &'a mut RunDir,
}

impl<'a> BcdRecorder<'a> {
    pub fn new(run: &'a mut RunDir) -> BcdRecorder<'a> {
        BcdRecorder { run }
    }

    /// Persist one completed sweep.
    pub fn observe(&mut self, ev: &SweepEvent) -> Result<()> {
        let t = ev.cursor.sweeps_done;
        save_state_atomic(ev.state, &self.run.sweep_state_path(t))?;
        self.run
            .manifest
            .bcd
            .get_or_insert_with(BcdProgress::default)
            .update(ev);
        self.run.save()?;
        if t > 1 {
            // Best-effort: the manifest now points past the previous sweep.
            let _ = std::fs::remove_file(self.run.sweep_state_path(t - 1));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Experiment;

    fn tmp_store(tag: &str) -> RunStore {
        let dir = std::env::temp_dir().join(format!("cdnl_runstore_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        RunStore::open(dir)
    }

    fn bcd_manifest(exp: &Experiment) -> RunManifest {
        RunManifest::new("bcd", exp, "reference", 200, 100)
    }

    #[test]
    fn create_get_list_assign_unique_ids() {
        let store = tmp_store("ids");
        let exp = Experiment::default();
        let a = store.create(bcd_manifest(&exp)).unwrap();
        let b = store.create(bcd_manifest(&exp)).unwrap();
        assert_ne!(a.manifest.run_id, b.manifest.run_id);
        assert!(a.manifest.run_id.starts_with("bcd-resnet_16x16_c10-"));
        let got = store.get(&a.manifest.run_id).unwrap();
        assert_eq!(got.manifest.b_target, 100);
        assert_eq!(got.manifest.status, RUNNING);
        assert_eq!(store.list().unwrap().len(), 2);
        assert!(store.get("nope").is_err());
    }

    #[test]
    fn save_is_atomic_and_versioned() {
        let store = tmp_store("atomic");
        let exp = Experiment::default();
        let m = RunManifest::new("snl", &exp, "reference", 300, 50);
        let mut rd = store.create(m).unwrap();
        rd.manifest.status = COMPLETE.to_string();
        rd.save().unwrap();
        // No temp residue, and the file reparses.
        let names: Vec<_> = std::fs::read_dir(&rd.dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .collect();
        assert!(names.iter().all(|n| !n.ends_with(".tmp")), "temp residue: {names:?}");
        assert_eq!(store.get(&rd.manifest.run_id).unwrap().manifest.status, COMPLETE);
        // Foreign format versions are rejected, not misread.
        let text = std::fs::read_to_string(rd.dir.join("run.json")).unwrap();
        std::fs::write(rd.dir.join("run.json"), text.replace("\"format\": 1", "\"format\": 99"))
            .unwrap();
        let err = format!("{:#}", store.get(&rd.manifest.run_id).unwrap_err());
        assert!(err.contains("format 99"), "bad error: {err}");
    }

    #[test]
    fn gc_keeps_recent_and_spares_resumable() {
        let store = tmp_store("gc");
        let exp = Experiment::default();
        let mut ids = Vec::new();
        for i in 0..4 {
            let mut rd = store.create(bcd_manifest(&exp)).unwrap();
            // Identical created_unix (the back-to-back CLI case): ordering
            // must fall back to the numeric id suffix, newest first.
            rd.manifest.created_unix = 1000;
            if i < 3 {
                rd.manifest.status = COMPLETE.to_string();
            }
            rd.save().unwrap();
            ids.push(rd.manifest.run_id);
        }
        let listed = store.list().unwrap();
        assert_eq!(listed[0].run_id, ids[3], "suffix tie-break must put newest first");
        // Dry run: candidates are reported but nothing is deleted.
        let preview = store.gc_candidates(1, false).unwrap();
        assert_eq!(preview.len(), 2);
        assert_eq!(store.list().unwrap().len(), 4, "dry run must not delete");
        // keep=1: of the 3 terminal runs the newest survives; the running
        // run (ids[3]) is spared. The real gc removes exactly the preview.
        let removed = store.gc(1, false).unwrap();
        assert_eq!(removed, preview, "gc must remove exactly what the dry run listed");
        assert_eq!(removed.len(), 2);
        assert!(!removed.contains(&ids[3]), "gc removed a resumable run");
        assert!(!removed.contains(&ids[2]), "gc removed the newest terminal run");
        // --all takes the resumable one too.
        let removed = store.gc(0, true).unwrap();
        assert!(removed.contains(&ids[3]));
        assert_eq!(store.list().unwrap().len(), 0);
    }

    #[test]
    fn live_blob_digests_spare_surviving_manifests() {
        let store = tmp_store("liveblobs");
        let exp = Experiment::default();
        let blob = |name: &str, digest: &str| BlobRef {
            name: name.to_string(),
            digest: digest.to_string(),
            bytes: 4,
        };
        let mut a = store.create(bcd_manifest(&exp)).unwrap();
        a.manifest.blobs = Some(vec![blob("params_sweep1", "aa"), blob("params_sweep2", "bb")]);
        a.save().unwrap();
        let mut b = store.create(bcd_manifest(&exp)).unwrap();
        // "bb" is shared between the doomed run (a) and the survivor (b):
        // it must stay live.
        b.manifest.blobs = Some(vec![blob("params_sweep1", "bb"), blob("params_sweep2", "cc")]);
        b.save().unwrap();
        let c = store.create(bcd_manifest(&exp)).unwrap(); // no blobs field at all
        let live = store.live_blob_digests(&[a.manifest.run_id.clone()]).unwrap();
        assert_eq!(
            live.iter().cloned().collect::<Vec<_>>(),
            vec!["bb".to_string(), "cc".to_string()]
        );
        // Nothing doomed: everything referenced anywhere is live.
        let live = store.live_blob_digests(&[]).unwrap();
        assert_eq!(live.len(), 3);
        // Everything doomed: nothing is live.
        let doomed = vec![a.manifest.run_id, b.manifest.run_id, c.manifest.run_id];
        assert!(store.live_blob_digests(&doomed).unwrap().is_empty());
    }

    #[test]
    fn run_state_errors_are_typed_and_actionable() {
        let err: anyhow::Error = RunStateError::NotComplete {
            run_id: "bcd-x-1".into(),
            status: RUNNING.into(),
            needed_by: "`cdnl serve`".into(),
        }
        .into();
        let msg = format!("{err:#}");
        assert!(msg.contains("bcd-x-1") && msg.contains("running"), "bad message: {msg}");
        assert!(msg.contains("cdnl runs resume bcd-x-1"), "must name the fix: {msg}");
        // Callers can match on the type instead of the message.
        match err.downcast_ref::<RunStateError>() {
            Some(RunStateError::NotComplete { status, .. }) => assert_eq!(status, RUNNING),
            other => panic!("wrong downcast: {other:?}"),
        }
        let msg = RunStateError::AlreadyComplete { run_id: "r7".into() }.to_string();
        assert!(msg.contains("already complete") && msg.contains("runs show r7"), "{msg}");
        let msg = RunStateError::MissingResult {
            run_id: "r8".into(),
            status: COMPLETE.into(),
            needed_by: "`cdnl serve`".into(),
        }
        .to_string();
        assert!(msg.contains("no sealed result"), "{msg}");
    }
}
