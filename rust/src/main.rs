//! `cdnl` — the CDNL experiment launcher.
//!
//! Subcommands:
//!   info         manifest summary: models, ReLU counts (Table 1), artifacts
//!   train        train a full-ReLU baseline and checkpoint it
//!   snl          SNL linearization down to --budget
//!   bcd          Block Coordinate Descent down to --budget (the paper)
//!   autorep      AutoReP polynomial replacement down to --budget
//!   senet        SENet sensitivity allocation + KD down to --budget
//!   deepreduce   DeepReDuce layer dropping down to --budget
//!   eval         evaluate a checkpoint on its dataset's test split
//!   picost       PI online-cost estimate of a checkpoint (LAN + WAN)
//!
//! Shared flags: --dataset synth10|synth100|synthtiny  --backbone resnet|wrn
//! --poly  --preset quick|full  --set k=v[,k=v...]  --artifacts DIR
//! --backend auto|pjrt|reference  --out DIR  --ckpt FILE  --ref-budget N
//! --budget N  --verbose
//!
//! Examples:
//!   cdnl train --dataset synth10
//!   cdnl bcd --dataset synth10 --budget 1000 --ref-budget 2000
//!   cdnl picost --ckpt results/resnet_16x16_c10__synth10_bcd_b1000.cdnl

use anyhow::{anyhow, bail, Context, Result};
use cdnl::config::{preset, reference_budget, Experiment};
use cdnl::coordinator::bcd::run_bcd;
use cdnl::coordinator::eval::test_accuracy;
use cdnl::methods::autorep::{run_autorep, AutorepConfig};
use cdnl::methods::deepreduce::{run_deepreduce, DeepReduceConfig};
use cdnl::methods::senet::{run_senet, SenetConfig};
use cdnl::methods::snl::run_snl;
use cdnl::model::ModelState;
use cdnl::pipeline::Pipeline;
use cdnl::runtime::{open_backend, Backend};
use cdnl::util::cli::Args;
use cdnl::util::{fmt_relu_count, logging};
use std::path::{Path, PathBuf};

const USAGE: &str = "usage: cdnl <info|train|snl|bcd|autorep|senet|deepreduce|eval|picost> [flags]
  see rust/src/main.rs header or README.md for flag documentation";

fn main() {
    logging::init();
    if let Err(e) = run() {
        eprintln!("cdnl: error: {e:#}");
        std::process::exit(1);
    }
}

fn build_experiment(args: &Args) -> Result<Experiment> {
    let mut exp = Experiment::default();
    if let Some(p) = args.get("preset") {
        let kv = preset(p).ok_or_else(|| anyhow!("unknown preset {p:?}"))?;
        for (k, v) in kv {
            exp.apply(&k, &v).map_err(|e| anyhow!(e))?;
        }
    }
    if let Some(f) = args.get("config") {
        let text = std::fs::read_to_string(f).with_context(|| format!("reading {f}"))?;
        exp.apply_file(&text).map_err(|e| anyhow!(e))?;
    }
    exp.apply_args(args).map_err(|e| anyhow!(e))?;
    if let Some(a) = args.get("artifacts") {
        exp.artifacts_dir = a.to_string();
    }
    if let Some(o) = args.get("out") {
        exp.out_dir = o.to_string();
    }
    Ok(exp)
}

fn run() -> Result<()> {
    let args = Args::parse_env(&["poly", "verbose", "stats", "quiet", "simulate"])
        .map_err(|e| anyhow!(e))?;
    if args.has("verbose") {
        logging::set_level(logging::Level::Debug);
    }
    if args.has("quiet") {
        logging::set_level(logging::Level::Error);
    }
    let sub = args.subcommand.clone().ok_or_else(|| anyhow!(USAGE))?;
    let exp = build_experiment(&args)?;
    let backend = open_backend(
        Path::new(&exp.artifacts_dir),
        args.get_or("backend", "auto"),
    )?;
    let engine: &dyn Backend = backend.as_ref();

    match sub.as_str() {
        "info" => cmd_info(engine, &args),
        "train" => cmd_train(engine, exp),
        "eval" => cmd_eval(engine, exp, &args),
        "picost" => cmd_picost(engine, exp, &args),
        "snl" | "bcd" | "autorep" | "senet" | "deepreduce" => {
            cmd_method(&sub, engine, exp, &args)
        }
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

/// `cdnl info`: manifest summary — the runtime's view of Table 1.
fn cmd_info(engine: &dyn Backend, args: &Args) -> Result<()> {
    println!("backend: {}", engine.name());
    let mut rows = Vec::new();
    for (key, m) in &engine.manifest().models {
        rows.push(vec![
            key.clone(),
            m.backbone.clone(),
            format!("{}x{}", m.image_size, m.image_size),
            m.num_classes.to_string(),
            if m.poly { "poly" } else { "identity" }.to_string(),
            m.param_size.to_string(),
            fmt_relu_count(m.mask_size),
            m.mask_layers.len().to_string(),
            m.artifacts.len().to_string(),
        ]);
    }
    cdnl::metrics::print_table(
        "Artifact manifest (paper Table 1 analog: total ReLUs per variant)",
        &["model", "backbone", "input", "classes", "repl", "params", "ReLUs", "layers", "fns"],
        &rows,
    );
    if args.has("stats") {
        println!("\n{}", engine.stats_table());
    }
    Ok(())
}

/// `cdnl train`: full-ReLU baseline (cached in the zoo) + test accuracy.
fn cmd_train(engine: &dyn Backend, exp: Experiment) -> Result<()> {
    let pl = Pipeline::new(engine, exp)?;
    let st = pl.baseline()?;
    let acc = pl.test_acc(&st)?;
    println!(
        "baseline {}: budget={} test_acc={acc:.2}%",
        pl.sess.key,
        fmt_relu_count(st.budget())
    );
    Ok(())
}

/// Resolve the starting state for a method run: --ckpt wins, else the SNL
/// (or AutoReP for poly) reference at --ref-budget, else the baseline.
fn starting_state(pl: &Pipeline, args: &Args) -> Result<ModelState> {
    if let Some(ck) = args.get("ckpt") {
        return ModelState::load(Path::new(ck), pl.sess.info());
    }
    if let Some(bref) = args.get("ref-budget") {
        let bref: usize = bref.parse().map_err(|_| anyhow!("--ref-budget: bad value"))?;
        return if pl.sess.info().poly {
            pl.autorep_ref(bref)
        } else {
            pl.snl_ref(bref)
        };
    }
    pl.baseline()
}

/// Shared driver for the five reduction methods.
fn cmd_method(method: &str, engine: &dyn Backend, exp: Experiment, args: &Args) -> Result<()> {
    let budget = args
        .get("budget")
        .ok_or_else(|| anyhow!("--budget is required for {method}"))?
        .parse::<usize>()
        .map_err(|_| anyhow!("--budget: bad value"))?;
    let pl = Pipeline::new(engine, exp)?;
    let mut st = if method == "bcd" && args.get("ckpt").is_none() && args.get("ref-budget").is_none()
    {
        // Paper protocol: BCD starts from an SNL reference (Table 4 rule).
        let total = pl.sess.info().total_relus();
        let bref = reference_budget(total, budget);
        if pl.sess.info().poly {
            pl.autorep_ref(bref)?
        } else {
            pl.snl_ref(bref)?
        }
    } else {
        starting_state(&pl, args)?
    };
    let before_acc = pl.test_acc(&st)?;
    let b0 = st.budget();

    let t0 = std::time::Instant::now();
    match method {
        "bcd" => {
            let out = run_bcd(&pl.sess, &mut st, &pl.train_ds, budget, &pl.exp.bcd, 0)?;
            println!(
                "bcd: {} iterations, {} trials total ({} bounded early)",
                out.iterations.len(),
                out.total_trials(),
                out.iterations.iter().map(|r| r.trials_bounded).sum::<usize>()
            );
        }
        "snl" => {
            let out = run_snl(&pl.sess, &mut st, &pl.train_ds, budget, &pl.exp.snl, 0)?;
            println!(
                "snl: {} steps, {} lambda updates",
                out.steps_run,
                out.kappa_updates.len()
            );
        }
        "autorep" => {
            let cfg = AutorepConfig { base: pl.exp.snl.clone(), ..Default::default() };
            let out = run_autorep(&pl.sess, &mut st, &pl.train_ds, budget, &cfg)?;
            println!("autorep: {} steps", out.steps_run);
        }
        "senet" => {
            let cfg = SenetConfig::default();
            let out = run_senet(&pl.sess, &mut st, &pl.train_ds, budget, &cfg)?;
            println!(
                "senet: kd loss {:.3} -> {:.3}",
                out.kd_first_loss, out.kd_last_loss
            );
        }
        "deepreduce" => {
            let cfg = DeepReduceConfig::default();
            let out = run_deepreduce(&pl.sess, &mut st, &pl.train_ds, budget, &cfg)?;
            println!(
                "deepreduce: dropped layers {:?}, partial {:?}",
                out.dropped_layers, out.partial_layer
            );
        }
        _ => unreachable!(),
    }
    let secs = t0.elapsed().as_secs_f64();
    let after_acc = pl.test_acc(&st)?;
    println!(
        "{method} {}: {} -> {} ReLUs  test_acc {before_acc:.2}% -> {after_acc:.2}%  ({secs:.1}s)",
        pl.sess.key,
        fmt_relu_count(b0),
        fmt_relu_count(st.budget()),
    );

    let out_path = args
        .get("save")
        .map(PathBuf::from)
        .unwrap_or_else(|| {
            PathBuf::from(&pl.exp.out_dir).join(format!(
                "{}__{}_{}_b{}.cdnl",
                pl.sess.key, pl.exp.dataset, method, budget
            ))
        });
    st.save(&out_path)?;
    println!("saved {}", out_path.display());
    if args.has("stats") {
        println!("\n{}", engine.stats_table());
    }
    Ok(())
}

/// `cdnl eval`: test accuracy + per-layer ReLU distribution of a checkpoint.
fn cmd_eval(engine: &dyn Backend, exp: Experiment, args: &Args) -> Result<()> {
    let pl = Pipeline::new(engine, exp)?;
    let st = starting_state(&pl, args)?;
    let acc = test_accuracy(&pl.sess, &st, &pl.test_ds)?;
    println!(
        "{}: budget={} ({} of {} ReLUs) test_acc={acc:.2}%",
        pl.sess.key,
        fmt_relu_count(st.budget()),
        st.budget(),
        pl.sess.info().total_relus()
    );
    let hist = st.mask.layer_histogram(pl.sess.info());
    let rows: Vec<Vec<String>> = pl
        .sess
        .info()
        .mask_layers
        .iter()
        .zip(&hist)
        .enumerate()
        .map(|(l, (e, &h))| {
            vec![
                l.to_string(),
                e.name.clone(),
                format!("{:?}", e.shape),
                h.to_string(),
                e.size.to_string(),
                format!("{:.1}%", 100.0 * h as f64 / e.size as f64),
            ]
        })
        .collect();
    cdnl::metrics::print_table(
        "ReLU distribution across layers (paper Fig. 7)",
        &["#", "layer", "shape", "kept", "total", "kept%"],
        &rows,
    );
    Ok(())
}

/// `cdnl picost`: PI online-cost estimate under LAN and WAN protocols.
fn cmd_picost(engine: &dyn Backend, exp: Experiment, args: &Args) -> Result<()> {
    let pl = Pipeline::new(engine, exp)?;
    let st = starting_state(&pl, args)?;
    let info = pl.sess.info();
    let mut rows = Vec::new();
    for proto in [cdnl::picost::lan(), cdnl::picost::wan()] {
        let r = cdnl::picost::estimate_state(info, &st.mask, &proto);
        rows.push(vec![
            r.protocol.to_string(),
            fmt_relu_count(r.relus),
            format!("{:.1}", r.online_bytes / 1e6),
            format!("{:.1}", 1e3 * r.relu_secs),
            format!("{:.1}", 1e3 * r.linear_secs),
            format!("{:.1}", 1e3 * r.round_secs),
            format!("{:.1}", 1e3 * r.total_secs),
        ]);
    }
    cdnl::metrics::print_table(
        &format!(
            "Estimated PI online cost for {} at {} ReLUs (constants per DELPHI; estimates)",
            pl.sess.key,
            fmt_relu_count(st.budget())
        ),
        &["protocol", "ReLUs", "comm[MB]", "relu[ms]", "linear[ms]", "rounds[ms]", "total[ms]"],
        &rows,
    );

    if args.has("simulate") {
        // Protocol-level walk: per-message trace + analytic cross-check.
        let mut rows = Vec::new();
        for proto in [cdnl::picost::lan(), cdnl::picost::wan()] {
            let tr = cdnl::protosim::simulate(info, &st.mask, &proto);
            let (analytic, simulated) = cdnl::protosim::compare(info, &st.mask, &proto);
            rows.push(vec![
                proto.name.to_string(),
                tr.messages.len().to_string(),
                tr.rounds.to_string(),
                format!("{:.2}", tr.gc_bytes as f64 / 1e6),
                format!("{:.3}", tr.share_bytes as f64 / 1e6),
                format!("{:.1}", 1e3 * simulated),
                format!("{:.1}", 1e3 * analytic),
            ]);
        }
        cdnl::metrics::print_table(
            "Simulated DELPHI-style online phase (protosim) vs analytic model",
            &["protocol", "msgs", "rounds", "gc[MB]", "shares[MB]", "sim[ms]", "analytic[ms]"],
            &rows,
        );
    }
    Ok(())
}
