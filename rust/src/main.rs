//! `cdnl` — the CDNL experiment launcher.
//!
//! Subcommands:
//!   info         manifest summary: models, ReLU counts (Table 1), artifacts
//!   train        train a full-ReLU baseline and checkpoint it
//!   run          run one method, or a `+`-chain of methods, through the
//!                method registry (DESIGN.md §10):
//!                  run bcd --budget 1000          the paper's Algorithm 2
//!                  run snl+bcd --budgets 2000,1000 Tables 4/5: BCD on top
//!                                                  of an SNL reference
//!                  run senet+bcd --budgets ...     any composition works
//!                BCD runs are recorded in the run-store by default
//!                (resumable after a crash); --no-record opts out. Other
//!                methods and chains get write-once manifests with typed
//!                per-stage outcomes and provenance.
//!   methods      the method registry:
//!                  methods list         registered methods, config-key
//!                                       slices, per-method fingerprints
//!   snl | bcd | autorep | senet | deepreduce
//!                deprecated aliases for `cdnl run <method>`
//!   eval         evaluate a checkpoint on its dataset's test split
//!   picost       per-inference PI online-cost estimate of a checkpoint,
//!                under every registered protocol (or one via --proto)
//!   serve        fleet-scale PI serving simulation (DESIGN.md §14):
//!                price a finished run's final mask (`cdnl serve
//!                <run-id>`) or a checkpoint (`--ckpt`) under the
//!                experiment's `pi.*` fleet shape; --record seals the
//!                report into the run manifest
//!   coordinate   a BCD run whose trial scan is served to HTTP workers
//!                (DESIGN.md §15): `coordinate --listen HOST:PORT
//!                --budget N` records a resumable run exactly like `cdnl
//!                run bcd` (`--resume RUN_ID` continues one); workers may
//!                join, die and rejoin freely — the outcome is
//!                bit-identical to a local run
//!   worker       join a coordinator: `worker --connect HOST:PORT [--id
//!                NAME] [--poll-ms N]`; cold-starts from the
//!                coordinator's /config and CAS params digest, scores
//!                leased trial slabs until the coordinator shuts down
//!   cas          the content-addressed blob store under <out>/cas
//!                (DESIGN.md §15; digests verified on write AND read):
//!                  cas put <file>              store, print digest
//!                  cas get <digest> --save F   fetch + verify
//!                  cas verify [<digest>]       re-hash all (or one)
//!                  cas gc [--dry-run]          remove blobs no run
//!                                              manifest references
//!   bench        the benchmark registry (DESIGN.md §9):
//!                  bench list           every registered benchmark + tier
//!                  bench run <name>     run one benchmark, write
//!                                       results/bench/BENCH_<name>.json
//!                  bench run --tier t   run a whole tier
//!                                       (smoke|paper|perf|serve)
//!                  bench compare [<report> <baseline>] [--gate] [--md FILE]
//!                                       diff reports against committed
//!                                       baselines; --gate exits nonzero on
//!                                       regression (the CI contract)
//!   runs         the experiment run-store:
//!                  runs list [--method m] [--status s]
//!                                       runs under <out>/runs, filterable
//!                                       by registry method name and by
//!                                       running|complete|failed
//!                  runs show <id>       manifest, stages, typed outcomes,
//!                                       sweep trace, recorded stats
//!                  runs resume <id>     continue an interrupted BCD run
//!                  runs gc [--keep N] [--all] [--dry-run]
//!                                       delete old run directories and the
//!                                       CAS blobs only they referenced
//!                                       (--dry-run previews both, deletes
//!                                       nothing; blobs referenced by any
//!                                       surviving manifest are never
//!                                       collected)
//!
//! Shared flags: --dataset synth10|synth100|synthtiny  --backbone resnet|wrn
//! --poly  --preset quick|full  --set k=v[,k=v...]  --artifacts DIR
//! --backend auto|pjrt|reference  --out DIR  --ckpt FILE  --ref-budget N
//! --budget N  --budgets b1,b2,...  --proto lan|wan|mobile  --verbose
//! --no-record  --listen HOST:PORT  --connect HOST:PORT  --lease-ms N
//! --poll-ms N  --id NAME
//!
//! Examples:
//!   cdnl train --dataset synth10
//!   cdnl run bcd --dataset synth10 --budget 1000 --ref-budget 2000
//!   cdnl run snl+bcd --budgets 2000,1000
//!   cdnl runs resume bcd-resnet_16x16_c10-5fa3c1d2-1
//!   cdnl coordinate --listen 127.0.0.1:7070 --budget 1000
//!   cdnl worker --connect 127.0.0.1:7070
//!   cdnl picost --ckpt results/resnet_16x16_c10__synth10_bcd_b1000.cdnl
//!   cdnl serve bcd-resnet_16x16_c10-5fa3c1d2-1 --proto wan --record

use anyhow::{anyhow, bail, Context, Result};
use cdnl::config::{preset, reference_budget, Experiment};
use cdnl::coordinator::eval::test_accuracy;
use cdnl::methods::registry::{self, BcdSummary, ChainSpec, Method, MethodOutcome};
use cdnl::model::ModelState;
use cdnl::pipeline::Pipeline;
use cdnl::runstore::{RunDir, RunResult, RunStateError, RunStore, COMPLETE, FAILED, RUNNING};
use cdnl::runtime::{open_backend_with, Backend};
use cdnl::util::cli::Args;
use cdnl::util::{fmt_relu_count, logging};
use std::path::{Path, PathBuf};

const USAGE: &str = "usage: cdnl <info|train|run|methods|eval|picost|serve|coordinate|worker|cas|bench|runs> [flags]
  (cdnl <method> is a deprecated alias for cdnl run <method>)
  see rust/src/main.rs header or README.md for flag documentation";

fn main() {
    logging::init();
    if let Err(e) = run() {
        eprintln!("cdnl: error: {e:#}");
        std::process::exit(1);
    }
}

fn build_experiment(args: &Args) -> Result<Experiment> {
    let mut exp = Experiment::default();
    if let Some(p) = args.get("preset") {
        let kv = preset(p).ok_or_else(|| anyhow!("unknown preset {p:?}"))?;
        for (k, v) in kv {
            exp.apply(&k, &v).map_err(|e| anyhow!(e))?;
        }
    }
    if let Some(f) = args.get("config") {
        let text = std::fs::read_to_string(f).with_context(|| format!("reading {f}"))?;
        exp.apply_file(&text).map_err(|e| anyhow!(e))?;
    }
    exp.apply_args(args).map_err(|e| anyhow!(e))?;
    if let Some(a) = args.get("artifacts") {
        exp.artifacts_dir = a.to_string();
    }
    if let Some(o) = args.get("out") {
        exp.out_dir = o.to_string();
    }
    Ok(exp)
}

fn run() -> Result<()> {
    let bools = [
        "poly", "verbose", "stats", "quiet", "simulate", "no-record", "all", "dry-run", "gate",
        "record", "strict-host",
    ];
    let args = Args::parse_env(&bools).map_err(|e| anyhow!(e))?;
    if args.has("verbose") {
        logging::set_level(logging::Level::Debug);
    }
    if args.has("quiet") {
        logging::set_level(logging::Level::Error);
    }
    let sub = args.subcommand.clone().ok_or_else(|| anyhow!(USAGE))?;
    let exp = build_experiment(&args)?;
    if sub == "runs" {
        // The run-store carries its own backend + config; don't open one here.
        return cmd_runs(&args, exp);
    }
    if sub == "bench" {
        // `bench list`/`bench compare` are pure file operations; `bench run`
        // opens its backend itself.
        return cmd_bench(&args, exp);
    }
    if sub == "methods" {
        // Pure registry introspection; no backend needed.
        return cmd_methods(&args, &exp);
    }
    if sub == "cas" {
        // Pure blob-store file operations; no backend needed.
        return cmd_cas(&args, &exp);
    }
    if sub == "serve" {
        // A run-id serve rebuilds the run's own recorded experiment and
        // backend (like `runs resume`), so it opens its backend itself.
        return cmd_serve(&args, exp);
    }
    let backend = open_backend_with(
        Path::new(&exp.artifacts_dir),
        args.get_or("backend", "auto"),
        &exp.model,
    )?;
    let engine: &dyn Backend = backend.as_ref();

    match sub.as_str() {
        "info" => cmd_info(engine, &args),
        "train" => cmd_train(engine, exp),
        "eval" => cmd_eval(engine, exp, &args),
        "picost" => cmd_picost(engine, exp, &args),
        "coordinate" => cmd_coordinate(engine, exp, &args),
        "worker" => cmd_worker(engine, &args),
        "run" => {
            let spec = args.positional.first().cloned().ok_or_else(|| {
                anyhow!(
                    "usage: cdnl run <method|chain> --budget N | --budgets b1,b2,...\n  registered methods: {}",
                    registry::names().join(", ")
                )
            })?;
            cmd_run(&spec, engine, exp, &args)
        }
        // Deprecated aliases: `cdnl bcd ...` == `cdnl run bcd ...`.
        name if registry::find(name).is_ok() => {
            eprintln!("note: `cdnl {name}` is a deprecated alias for `cdnl run {name}`");
            cmd_run(name, engine, exp, &args)
        }
        other => bail!(
            "unknown subcommand {other:?} (registered methods: {}; see `cdnl methods list`)\n{USAGE}",
            registry::names().join(", ")
        ),
    }
}

/// `cdnl info`: manifest summary — the runtime's view of Table 1.
fn cmd_info(engine: &dyn Backend, args: &Args) -> Result<()> {
    println!("backend: {}", engine.name());
    let mut rows = Vec::new();
    for (key, m) in &engine.manifest().models {
        rows.push(vec![
            key.clone(),
            m.backbone.clone(),
            format!("{}x{}", m.image_size, m.image_size),
            m.num_classes.to_string(),
            if m.poly { "poly" } else { "identity" }.to_string(),
            m.param_size.to_string(),
            fmt_relu_count(m.mask_size),
            m.mask_layers.len().to_string(),
            m.artifacts.len().to_string(),
        ]);
    }
    cdnl::metrics::print_table(
        "Artifact manifest (paper Table 1 analog: total ReLUs per variant)",
        &["model", "backbone", "input", "classes", "repl", "params", "ReLUs", "layers", "fns"],
        &rows,
    );
    if args.has("stats") {
        println!("\n{}", engine.stats_table());
    }
    Ok(())
}

/// `cdnl train`: full-ReLU baseline (cached in the zoo) + test accuracy.
fn cmd_train(engine: &dyn Backend, exp: Experiment) -> Result<()> {
    let pl = Pipeline::new(engine, exp)?;
    let st = pl.baseline()?;
    let acc = pl.test_acc(&st)?;
    println!(
        "baseline {}: budget={} test_acc={acc:.2}%",
        pl.sess.key,
        fmt_relu_count(st.budget())
    );
    Ok(())
}

/// Resolve the starting state for a method run: --ckpt wins, else the SNL
/// (or AutoReP for poly) reference at --ref-budget, else the baseline.
fn starting_state(pl: &Pipeline, args: &Args) -> Result<ModelState> {
    if let Some(ck) = args.get("ckpt") {
        return ModelState::load(Path::new(ck), pl.sess.info());
    }
    if let Some(bref) = args.get("ref-budget") {
        let bref: usize = bref.parse().map_err(|_| anyhow!("--ref-budget: bad value"))?;
        return if pl.sess.info().poly {
            pl.autorep_ref(bref)
        } else {
            pl.snl_ref(bref)
        };
    }
    pl.baseline()
}

/// Stage budgets for a parsed spec: `--budgets b1,b2,...` (one per stage,
/// chains) or `--budget N` (single methods).
fn parse_budgets(spec: &ChainSpec, args: &Args) -> Result<Vec<usize>> {
    if let Some(list) = args.get("budgets") {
        let v: Vec<usize> = list
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<usize>()
                    .map_err(|_| anyhow!("--budgets: bad value {:?}", s.trim()))
            })
            .collect::<Result<_>>()?;
        if v.len() != spec.stages.len() {
            bail!(
                "{} has {} stage(s); --budgets gave {} value(s)",
                spec.name(),
                spec.stages.len(),
                v.len()
            );
        }
        // Each stage reduces further; catch a mis-ordered list before any
        // expensive stage runs (mid-chain it would fail after minutes of
        // work, with nothing recorded).
        if v.windows(2).any(|w| w[1] >= w[0]) {
            bail!("--budgets must be strictly decreasing, got {list}");
        }
        return Ok(v);
    }
    if let Some(b) = args.get("budget") {
        if spec.is_chain() {
            bail!(
                "chain {}: use --budgets b1,b2,... (one target per stage)",
                spec.name()
            );
        }
        return Ok(vec![b.parse().map_err(|_| anyhow!("--budget: bad value"))?]);
    }
    bail!(
        "--budget (or --budgets for chains) is required for {}",
        spec.name()
    )
}

/// `cdnl run <method|chain>`: the registry-dispatched execution driver.
fn cmd_run(spec_str: &str, engine: &dyn Backend, exp: Experiment, args: &Args) -> Result<()> {
    let spec = ChainSpec::parse(spec_str)?;
    let budgets = parse_budgets(&spec, args)?;
    let pl = Pipeline::new(engine, exp)?;
    if spec.is_chain() {
        cmd_run_chain(&spec, engine, &pl, &budgets, args)
    } else {
        cmd_run_single(spec.stages[0], engine, &pl, budgets[0], args)
    }
}

/// One method through the registry. BCD keeps its specialized sweep-level
/// recording (resumable); everything else gets a write-once manifest with
/// the typed outcome embedded.
fn cmd_run_single(
    method: &'static dyn Method,
    engine: &dyn Backend,
    pl: &Pipeline,
    budget: usize,
    args: &Args,
) -> Result<()> {
    let mut st = if method.name() == "bcd"
        && args.get("ckpt").is_none()
        && args.get("ref-budget").is_none()
    {
        // Paper protocol: BCD starts from an SNL reference (Table 4 rule).
        let total = pl.sess.info().total_relus();
        let bref = reference_budget(total, budget);
        if pl.sess.info().poly {
            pl.autorep_ref(bref)?
        } else {
            pl.snl_ref(bref)?
        }
    } else {
        starting_state(pl, args)?
    };
    let before_acc = pl.test_acc(&st)?;
    let b0 = st.budget();

    let t0 = std::time::Instant::now();
    let mut recorded: Option<RunDir> = None;
    let mut sweep_secs: Option<f64> = None;
    let outcome: MethodOutcome = if method.name() == "bcd" && !args.has("no-record") {
        let store = RunStore::for_experiment(&pl.exp);
        let (out, run) = pl.bcd_record(&store, &mut st, budget)?;
        recorded = Some(run);
        sweep_secs = Some(out.iterations.iter().map(|r| r.wall_ms).sum::<f64>() / 1e3);
        MethodOutcome::Bcd(BcdSummary::from_outcome(&out))
    } else {
        method.run(&pl.ctx(), &mut st, budget)?
    };
    println!("{}", outcome.describe());
    let secs = t0.elapsed().as_secs_f64();
    let after_acc = pl.test_acc(&st)?;
    println!(
        "{} {}: {} -> {} ReLUs  test_acc {before_acc:.2}% -> {after_acc:.2}%  ({secs:.1}s)",
        method.name(),
        pl.sess.key,
        fmt_relu_count(b0),
        fmt_relu_count(st.budget()),
    );
    let result = RunResult {
        final_budget: st.budget(),
        acc_before: before_acc,
        acc_after: after_acc,
        // BCD runs record sweep-loop time (comparable across interrupted
        // and uninterrupted runs); other methods record command time.
        wall_secs: sweep_secs.unwrap_or(secs),
    };
    if let Some(mut run) = recorded {
        seal_complete(&mut run.manifest, vec![outcome], result, engine);
        run.save()?;
        println!("run recorded: {} ({})", run.manifest.run_id, run.dir.display());
    } else if !args.has("no-record") {
        // Non-BCD methods are minutes, not hours: a write-once manifest
        // (identity, config, provenance, typed outcome, result) without
        // sweep-level resume.
        let store = RunStore::for_experiment(&pl.exp);
        let mut m =
            cdnl::runstore::RunManifest::new(method.name(), &pl.exp, engine.name(), b0, budget);
        m.stages = pl.take_stages();
        seal_complete(&mut m, vec![outcome], result, engine);
        let run = store.create(m)?;
        println!("run recorded: {} ({})", run.manifest.run_id, run.dir.display());
    }

    save_and_report(pl, &st, method.name(), budget, engine, args)
}

/// Shared terminal fields of every sealed run manifest: status, typed
/// outcomes, result, and the backend stats snapshot (incl. prefix_cache,
/// trial_batch and conv_lowering counters) so `runs show` can replay them after this
/// process is gone.
fn seal_complete(
    m: &mut cdnl::runstore::RunManifest,
    outcomes: Vec<MethodOutcome>,
    result: RunResult,
    engine: &dyn Backend,
) {
    m.status = COMPLETE.to_string();
    m.outcomes = Some(outcomes);
    m.result = Some(result);
    m.stats = Some(cdnl::runstore::stats_snapshot(&engine.stats()));
}

/// A multi-stage chain (`snl+bcd`): stages run through the registry on one
/// state, one sealed manifest with per-stage provenance + typed outcomes.
fn cmd_run_chain(
    spec: &ChainSpec,
    engine: &dyn Backend,
    pl: &Pipeline,
    budgets: &[usize],
    args: &Args,
) -> Result<()> {
    if args.get("ref-budget").is_some() {
        bail!(
            "--ref-budget does not apply to chains; make the reference a stage \
             (e.g. `cdnl run snl+bcd --budgets <bref>,<btarget>`)"
        );
    }
    let st0 = match args.get("ckpt") {
        Some(ck) => ModelState::load(Path::new(ck), pl.sess.info())?,
        None => pl.baseline()?,
    };
    let before_acc = pl.test_acc(&st0)?;
    let b0 = st0.budget();
    let chain = spec.name();
    let b_target = *budgets.last().expect("parse_budgets guarantees non-empty");

    // Create the manifest BEFORE any stage runs (status `running`): a
    // mid-chain stage error seals it `failed` below, and a crash leaves
    // `running` behind — either way the run is visible in `runs list`
    // with the provenance of every completed stage, instead of hours of
    // work vanishing without a trace. (Chains stay write-once: only
    // single `cdnl run bcd` checkpoints per sweep for resume.)
    let mut recorded: Option<RunDir> = if args.has("no-record") {
        None
    } else {
        let store = RunStore::for_experiment(&pl.exp);
        let mut m =
            cdnl::runstore::RunManifest::new(&chain, &pl.exp, engine.name(), b0, b_target);
        m.stages = pl.take_stages();
        Some(store.create(m)?)
    };

    let t0 = std::time::Instant::now();
    let (st, outs) = match pl.run_chain(spec, Some(st0), budgets) {
        Ok(ok) => ok,
        Err(e) => {
            if let Some(run) = recorded.as_mut() {
                run.manifest.status = FAILED.to_string();
                // Provenance of the stages that did complete.
                run.manifest.stages.extend(pl.take_stages());
                if let Err(save_err) = run.save() {
                    eprintln!(
                        "cdnl: warning: could not mark {} failed: {save_err:#}",
                        run.manifest.run_id
                    );
                } else {
                    eprintln!("run marked failed: {}", run.manifest.run_id);
                }
            }
            return Err(e);
        }
    };
    let secs = t0.elapsed().as_secs_f64();
    for out in &outs {
        println!("stage {}", out.describe());
    }
    let after_acc = pl.test_acc(&st)?;
    println!(
        "{chain} {}: {} -> {} ReLUs  test_acc {before_acc:.2}% -> {after_acc:.2}%  ({secs:.1}s)",
        pl.sess.key,
        fmt_relu_count(b0),
        fmt_relu_count(st.budget()),
    );
    if let Some(mut run) = recorded {
        run.manifest.stages.extend(pl.take_stages());
        let result = RunResult {
            final_budget: st.budget(),
            acc_before: before_acc,
            acc_after: after_acc,
            wall_secs: secs,
        };
        seal_complete(&mut run.manifest, outs, result, engine);
        run.save()?;
        println!("run recorded: {} ({})", run.manifest.run_id, run.dir.display());
    }

    save_and_report(pl, &st, &chain, b_target, engine, args)
}

/// Common epilogue of every `cdnl run`: checkpoint + optional stats table.
fn save_and_report(
    pl: &Pipeline,
    st: &ModelState,
    method: &str,
    budget: usize,
    engine: &dyn Backend,
    args: &Args,
) -> Result<()> {
    let out_path = args
        .get("save")
        .map(PathBuf::from)
        .unwrap_or_else(|| default_ckpt_path(&pl.exp, &pl.sess.key, method, budget));
    st.save(&out_path)?;
    println!("saved {}", out_path.display());
    if args.has("stats") {
        println!("\n{}", engine.stats_table());
    }
    Ok(())
}

/// `cdnl methods list`: the registry, its config-key slices, and the
/// per-method config fingerprints of the current experiment overlay.
fn cmd_methods(args: &Args, exp: &Experiment) -> Result<()> {
    let action = args.positional.first().map(|s| s.as_str()).unwrap_or("list");
    match action {
        "list" => {
            let rows: Vec<Vec<String>> = registry::registry()
                .iter()
                .map(|m| {
                    vec![
                        m.name().to_string(),
                        m.config_prefixes().join(" "),
                        m.config_fingerprint(exp),
                        m.describe().to_string(),
                    ]
                })
                .collect();
            cdnl::metrics::print_table(
                "Registered methods (cdnl run <name> | <a>+<b> chains; configs ride Experiment)",
                &["name", "config keys", "fingerprint", "description"],
                &rows,
            );
            Ok(())
        }
        other => bail!("unknown methods action {other:?}\nusage: cdnl methods list"),
    }
}

/// `<out>/<model>__<dataset>_<method>_b<budget>.cdnl` — shared by fresh
/// runs and `runs resume` so a resumed run lands in the same place.
fn default_ckpt_path(exp: &Experiment, model_key: &str, method: &str, budget: usize) -> PathBuf {
    PathBuf::from(&exp.out_dir).join(format!(
        "{}__{}_{}_b{}.cdnl",
        model_key, exp.dataset, method, budget
    ))
}

/// `cdnl eval`: test accuracy + per-layer ReLU distribution of a checkpoint.
fn cmd_eval(engine: &dyn Backend, exp: Experiment, args: &Args) -> Result<()> {
    let pl = Pipeline::new(engine, exp)?;
    let st = starting_state(&pl, args)?;
    let acc = test_accuracy(&pl.sess, &st, &pl.test_ds)?;
    println!(
        "{}: budget={} ({} of {} ReLUs) test_acc={acc:.2}%",
        pl.sess.key,
        fmt_relu_count(st.budget()),
        st.budget(),
        pl.sess.info().total_relus()
    );
    let hist = st.mask.layer_histogram(pl.sess.info());
    let rows: Vec<Vec<String>> = pl
        .sess
        .info()
        .mask_layers
        .iter()
        .zip(&hist)
        .enumerate()
        .map(|(l, (e, &h))| {
            vec![
                l.to_string(),
                e.name.clone(),
                format!("{:?}", e.shape),
                h.to_string(),
                e.size.to_string(),
                format!("{:.1}%", 100.0 * h as f64 / e.size as f64),
            ]
        })
        .collect();
    cdnl::metrics::print_table(
        "ReLU distribution across layers (paper Fig. 7)",
        &["#", "layer", "shape", "kept", "total", "kept%"],
        &rows,
    );
    Ok(())
}

/// Resolve `--proto`: one named [`cdnl::pi::Protocol`], or (default) the
/// whole registry, for side-by-side tables.
fn protocols(args: &Args) -> Result<Vec<&'static cdnl::pi::Protocol>> {
    match args.get("proto") {
        Some(name) => Ok(vec![cdnl::pi::find(name).ok_or_else(|| {
            anyhow!(
                "--proto: unknown protocol {name:?} (known: {})",
                cdnl::pi::names().join("|")
            )
        })?]),
        None => Ok(cdnl::pi::registry().to_vec()),
    }
}

/// `cdnl picost`: per-inference PI online-cost estimate under every
/// registered protocol (or one, via --proto).
fn cmd_picost(engine: &dyn Backend, exp: Experiment, args: &Args) -> Result<()> {
    let protos = protocols(args)?;
    let pl = Pipeline::new(engine, exp)?;
    let st = starting_state(&pl, args)?;
    let info = pl.sess.info();
    let mut rows = Vec::new();
    for proto in &protos {
        let r = cdnl::pi::estimate_state(info, &st.mask, proto);
        rows.push(vec![
            r.protocol.to_string(),
            fmt_relu_count(r.relus),
            format!("{:.1}", r.online_bytes / 1e6),
            format!("{:.1}", 1e3 * r.relu_secs),
            format!("{:.1}", 1e3 * r.linear_secs),
            format!("{:.1}", 1e3 * r.round_secs),
            format!("{:.1}", 1e3 * r.total_secs),
        ]);
    }
    cdnl::metrics::print_table(
        &format!(
            "Estimated PI online cost for {} at {} ReLUs (constants per DELPHI; estimates)",
            pl.sess.key,
            fmt_relu_count(st.budget())
        ),
        &["protocol", "ReLUs", "comm[MB]", "relu[ms]", "linear[ms]", "rounds[ms]", "total[ms]"],
        &rows,
    );

    if args.has("simulate") {
        // Protocol-level walk: per-message trace + analytic cross-check.
        let mut rows = Vec::new();
        for proto in &protos {
            let tr = cdnl::pi::simulate(info, &st.mask, proto);
            let (analytic, simulated) = cdnl::pi::compare(info, &st.mask, proto);
            rows.push(vec![
                proto.name.to_string(),
                tr.messages.len().to_string(),
                tr.rounds.to_string(),
                format!("{:.2}", tr.gc_bytes as f64 / 1e6),
                format!("{:.3}", tr.share_bytes as f64 / 1e6),
                format!("{:.1}", 1e3 * simulated),
                format!("{:.1}", 1e3 * analytic),
            ]);
        }
        cdnl::metrics::print_table(
            "Simulated DELPHI-style online phase (pi::trace) vs analytic model",
            &["protocol", "msgs", "rounds", "gc[MB]", "shares[MB]", "sim[ms]", "analytic[ms]"],
            &rows,
        );
    }
    Ok(())
}

/// `cdnl serve <run-id> | --ckpt FILE`: fleet-scale serving simulation of
/// a finished run's (or checkpoint's) mask under the experiment's `pi.*`
/// fleet shape (DESIGN.md §14).
fn cmd_serve(args: &Args, exp: Experiment) -> Result<()> {
    let protos = protocols(args)?;
    if let Some(id) = args.positional.first().cloned() {
        return serve_run(args, &exp, &id, &protos);
    }
    let Some(ck) = args.get("ckpt").map(str::to_string) else {
        bail!(
            "usage: cdnl serve <run-id> [--proto p] [--record]\n       \
             cdnl serve --ckpt FILE [--proto p]"
        );
    };
    let backend = open_backend_with(
        Path::new(&exp.artifacts_dir),
        args.get_or("backend", "auto"),
        &exp.model,
    )?;
    let pl = Pipeline::new(backend.as_ref(), exp)?;
    let st = ModelState::load(Path::new(&ck), pl.sess.info())?;
    let cfg = cdnl::pi::ServeConfig::from_experiment(&pl.exp);
    serve_tables(pl.sess.info(), &st, &cfg, &protos, &pl.sess.key)
}

/// Serve a recorded run: rebuild its experiment (like `runs resume`), load
/// its final state, and price the mask under the serving simulator.
/// `--record` seals the report — priced under the experiment's
/// `pi.protocol` — into the run manifest.
fn serve_run(
    args: &Args,
    exp: &Experiment,
    id: &str,
    protos: &[&'static cdnl::pi::Protocol],
) -> Result<()> {
    let store = RunStore::for_experiment(exp);
    let mut run = store.get(id)?;
    // Typed state checks before any backend open: serving prices the sealed
    // final mask, which only a complete run with a recorded result carries.
    if run.manifest.status != COMPLETE {
        return Err(RunStateError::NotComplete {
            run_id: run.manifest.run_id.clone(),
            status: run.manifest.status.clone(),
            needed_by: "`cdnl serve`".into(),
        }
        .into());
    }
    if run.manifest.bcd.is_none() && run.manifest.result.is_none() {
        return Err(RunStateError::MissingResult {
            run_id: run.manifest.run_id.clone(),
            status: run.manifest.status.clone(),
            needed_by: "`cdnl serve`".into(),
        }
        .into());
    }
    let mut rexp = run.manifest.experiment()?;
    // Paths may legitimately differ from record time; CLI overrides win,
    // matching the fingerprint's path-independence.
    if let Some(a) = args.get("artifacts") {
        rexp.artifacts_dir = a.to_string();
    }
    if let Some(o) = args.get("out") {
        rexp.out_dir = o.to_string();
    }
    let backend_name = args
        .get("backend")
        .unwrap_or(run.manifest.backend.as_str())
        .to_string();
    let backend = open_backend_with(Path::new(&rexp.artifacts_dir), &backend_name, &rexp.model)?;
    let info = backend.model(&run.manifest.model_key)?;
    // BCD runs checkpoint inside the run directory (the resume state IS
    // the final state once complete); other methods leave their final
    // checkpoint at the shared default path.
    let st = if run.manifest.bcd.is_some() {
        run.load_resume_state(info)?
    } else {
        let p = default_ckpt_path(
            &rexp,
            &run.manifest.model_key,
            &run.manifest.method,
            run.manifest.b_target,
        );
        ModelState::load(&p, info)?
    };
    let cfg = cdnl::pi::ServeConfig::from_experiment(&rexp);
    println!(
        "serving run {} ({} at {} ReLUs)",
        run.manifest.run_id,
        run.manifest.model_key,
        fmt_relu_count(st.budget())
    );
    serve_tables(info, &st, &cfg, protos, &run.manifest.model_key)?;
    if args.has("record") {
        let proto = cdnl::pi::find(&rexp.pi.protocol)
            .ok_or_else(|| anyhow!("run {}: unknown pi.protocol {:?}", id, rexp.pi.protocol))?;
        run.manifest.serve = Some(cdnl::pi::serve::serve(info, &st.mask, proto, &cfg)?);
        run.save()?;
        println!("serve report ({}) recorded in {}", proto.name, run.manifest.run_id);
    }
    Ok(())
}

/// Shared `cdnl serve` output: the fleet table under each protocol plus
/// the per-inference [`cdnl::pi::CostModel`] cross-check.
fn serve_tables(
    info: &cdnl::runtime::manifest::ModelInfo,
    st: &ModelState,
    cfg: &cdnl::pi::ServeConfig,
    protos: &[&'static cdnl::pi::Protocol],
    key: &str,
) -> Result<()> {
    let mut rows = Vec::new();
    for proto in protos {
        let r = cdnl::pi::serve::serve(info, &st.mask, proto, cfg)?;
        rows.push(vec![
            r.protocol.clone(),
            r.completed.to_string(),
            r.online_rounds.to_string(),
            format!("{:.2}", (r.up_bytes + r.down_bytes) as f64 / 1e6),
            format!("{}/{}", r.gemm_batches, r.gemm_jobs),
            format!("{:.1}", r.p50_ms),
            format!("{:.1}", r.p95_ms),
            format!("{:.1}", r.p99_ms),
            format!("{:.2}", r.throughput_rps),
        ]);
    }
    cdnl::metrics::print_table(
        &format!(
            "Simulated PI serving for {key} at {} ReLUs: {} clients x {} requests \
             (window {}, prep-ahead {}, seed {})",
            fmt_relu_count(st.budget()),
            cfg.clients,
            cfg.requests,
            cfg.batch_window,
            cfg.prep_ahead,
            cfg.seed
        ),
        &[
            "protocol", "done", "rounds", "comm[MB]", "batch/jobs", "p50[ms]", "p95[ms]",
            "p99[ms]", "rps",
        ],
        &rows,
    );
    // Per-inference cross-check: every registered cost model, side by
    // side. Counts agree by construction; latency is each model's own.
    let mut rows = Vec::new();
    for proto in protos {
        for model in cdnl::pi::cost_models() {
            let c = model.price(info, &st.mask, proto);
            rows.push(vec![
                c.protocol.to_string(),
                c.model.to_string(),
                fmt_relu_count(c.relus),
                c.active_layers.to_string(),
                c.rounds.to_string(),
                format!("{:.3}", (c.up_bytes + c.down_bytes) as f64 / 1e6),
                format!("{:.1}", 1e3 * c.latency_secs),
            ]);
        }
    }
    cdnl::metrics::print_table(
        "Per-inference cost models (pi::CostModel)",
        &["protocol", "model", "ReLUs", "layers", "rounds", "comm[MB]", "latency[ms]"],
        &rows,
    );
    Ok(())
}

// ---- the distributed-scan surface ------------------------------------------

/// `cdnl coordinate --listen <addr>`: a BCD run whose hypothesis scan is
/// served to HTTP workers (DESIGN.md §15). Recording, resume cursors and
/// the final outcome are identical to `cdnl run bcd` — the scan substrate
/// is the only difference.
fn cmd_coordinate(engine: &dyn Backend, exp: Experiment, args: &Args) -> Result<()> {
    let listen = args
        .get("listen")
        .ok_or_else(|| {
            anyhow!(
                "usage: cdnl coordinate --listen HOST:PORT --budget N \
                 [--resume RUN_ID] [--lease-ms N]"
            )
        })?
        .to_string();
    let lease_ms = args.get_usize("lease-ms", cdnl::dist::DEFAULT_LEASE_MS as usize) as u64;
    let pl = Pipeline::new(engine, exp)?;
    let store = RunStore::for_experiment(&pl.exp);
    let hello = cdnl::dist::HelloDoc::for_experiment(&pl.exp, engine.name());
    let cas = cdnl::cas::CasStore::for_experiment(&pl.exp);
    let srv = cdnl::dist::ScanServer::start(listen.as_str(), &hello, cas)?;
    println!(
        "coordinating on {} (model {}, config {}) — join with `cdnl worker --connect {}`",
        srv.addr(),
        pl.sess.key,
        hello.fingerprint,
        srv.addr()
    );

    let mut scan = cdnl::dist::dist_scanner(&srv, &pl.exp.bcd, lease_ms);
    let (st, out, mut run) = if let Some(id) = args.get("resume") {
        pl.bcd_resume_with(store.get(id)?, &mut scan)?
    } else {
        let budget: usize = args
            .get("budget")
            .ok_or_else(|| anyhow!("--budget N (or --resume RUN_ID) is required"))?
            .parse()
            .map_err(|_| anyhow!("--budget: bad value"))?;
        // Paper protocol: BCD starts from an SNL/AutoReP reference unless
        // --ckpt / --ref-budget say otherwise (same rule as `cdnl run bcd`).
        let mut st = if args.get("ckpt").is_none() && args.get("ref-budget").is_none() {
            let bref = reference_budget(pl.sess.info().total_relus(), budget);
            if pl.sess.info().poly {
                pl.autorep_ref(bref)?
            } else {
                pl.snl_ref(bref)?
            }
        } else {
            starting_state(&pl, args)?
        };
        let (out, run) = pl.bcd_record_with(&store, &mut st, budget, &mut scan)?;
        (st, out, run)
    };

    // Blob provenance: every params blob published this session joins the
    // manifest, so `cdnl runs gc` keeps the CAS objects it references.
    let mut blobs = run.manifest.blobs.take().unwrap_or_default();
    blobs.extend(srv.take_blobs());
    run.manifest.blobs = Some(blobs);
    run.save()?;
    srv.shutdown();
    // Give polling workers a beat to observe the shutdown document before
    // the listener drops.
    std::thread::sleep(std::time::Duration::from_millis(200));
    let stats = srv.stats();
    let after_acc = pl.test_acc(&st)?;
    println!(
        "bcd (distributed) {}: {} iterations, {} -> {} ReLUs  test_acc {after_acc:.2}%",
        run.manifest.run_id,
        out.iterations.len(),
        fmt_relu_count(run.manifest.b_start),
        fmt_relu_count(st.budget()),
    );
    println!(
        "scan totals: {} slab(s) claimed, {} lease(s) re-issued, {} duplicate completion(s), \
         {} slab(s) merged",
        stats.claims_issued,
        stats.leases_reissued,
        stats.duplicate_completions,
        stats.completed_slabs
    );
    let out_path = default_ckpt_path(&pl.exp, &pl.sess.key, "bcd", run.manifest.b_target);
    st.save(&out_path)?;
    println!("saved {}", out_path.display());
    Ok(())
}

/// `cdnl worker --connect <addr>`: score leased trial slabs for a
/// coordinator until it shuts the scan down. All experiment config comes
/// from the coordinator's `/config` (cross-checked by fingerprint); only
/// backend/artifact flags apply locally.
fn cmd_worker(engine: &dyn Backend, args: &Args) -> Result<()> {
    let connect = args.get("connect").ok_or_else(|| {
        anyhow!("usage: cdnl worker --connect HOST:PORT [--id NAME] [--poll-ms N]")
    })?;
    let mut opts = cdnl::dist::WorkerOpts::default();
    if let Some(id) = args.get("id") {
        opts.id = id.to_string();
    }
    opts.poll_ms = args.get_usize("poll-ms", opts.poll_ms as usize) as u64;
    let summary = cdnl::dist::run_worker(connect, engine, &opts)?;
    println!(
        "worker {} done: {} slab(s), {} trial(s) across {} scan(s)",
        opts.id, summary.slabs, summary.trials, summary.scans
    );
    Ok(())
}

/// `cdnl cas <put|get|verify|gc>`: the content-addressed blob store that
/// backs distributed cold-starts (`<out>/cas`, DESIGN.md §15).
fn cmd_cas(args: &Args, exp: &Experiment) -> Result<()> {
    let cas = cdnl::cas::CasStore::for_experiment(exp);
    let action = args.positional.first().map(|s| s.as_str()).unwrap_or("");
    match action {
        "put" => {
            let file = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("usage: cdnl cas put <file>"))?;
            let put = cas.put_file(Path::new(file.as_str()))?;
            println!(
                "{}  {} bytes{}",
                put.digest,
                put.bytes,
                if put.existed { "  (already stored)" } else { "" }
            );
            Ok(())
        }
        "get" => {
            let digest = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("usage: cdnl cas get <digest> --save FILE"))?;
            let save = args
                .get("save")
                .ok_or_else(|| anyhow!("cas get: --save FILE is required"))?;
            let bytes = cas.get(digest)?; // re-hashes the stream on read
            std::fs::write(save, &bytes).with_context(|| format!("writing {save}"))?;
            println!("{digest}  {} bytes -> {save}", bytes.len());
            Ok(())
        }
        "verify" => {
            let digests = match args.positional.get(1) {
                Some(d) => vec![d.clone()],
                None => cas.list()?,
            };
            let mut bad = 0usize;
            for d in &digests {
                // verify: Ok(true) intact, Ok(false) absent, Err corrupt.
                let status = match cas.verify(d) {
                    Ok(true) => "ok     ",
                    Ok(false) => "MISSING",
                    Err(_) => "CORRUPT",
                };
                println!("{status}  {d}");
                bad += usize::from(status != "ok     ");
            }
            println!("{} object(s) checked, {bad} corrupt/missing", digests.len());
            if bad > 0 {
                bail!("{bad} object(s) failed verification");
            }
            Ok(())
        }
        "gc" => {
            // A blob is live iff some run manifest's provenance references
            // it — the run store is the source of truth.
            let live = RunStore::for_experiment(exp).live_blob_digests(&[])?;
            let dry = args.has("dry-run");
            let removed = cas.gc(&live, dry)?;
            for d in &removed {
                println!("{} {d}", if dry { "would remove" } else { "removed" });
            }
            println!(
                "{} blob(s) {}, {} live",
                removed.len(),
                if dry { "reclaimable (dry run — nothing deleted)" } else { "removed" },
                live.len()
            );
            Ok(())
        }
        other => bail!("unknown cas action {other:?}\nusage: cdnl cas <put|get|verify|gc>"),
    }
}

// ---- the benchmark surface -------------------------------------------------

/// `cdnl bench <list|run|compare>` (DESIGN.md §9).
fn cmd_bench(args: &Args, exp: Experiment) -> Result<()> {
    let action = args.positional.first().map(|s| s.as_str()).unwrap_or("list");
    match action {
        "list" => bench_list(args),
        "run" => bench_run(args, exp),
        "compare" => bench_compare(args),
        other => bail!("unknown bench action {other:?}\nusage: cdnl bench <list|run|compare>"),
    }
}

fn bench_baseline_dir(args: &Args) -> PathBuf {
    // Committed baselines live at the repository root by convention.
    PathBuf::from(args.get_or("baseline-dir", "."))
}

fn bench_report_dir(args: &Args) -> PathBuf {
    args.get("report-dir")
        .map(PathBuf::from)
        .unwrap_or_else(cdnl::bench::default_report_dir)
}

fn bench_list(args: &Args) -> Result<()> {
    let baseline_dir = bench_baseline_dir(args);
    let rows: Vec<Vec<String>> = cdnl::bench::registry()
        .iter()
        .map(|d| {
            let has_baseline = cdnl::bench::report_path(&baseline_dir, d.name).exists();
            vec![
                d.name.to_string(),
                d.tier.name().to_string(),
                d.paper.to_string(),
                if has_baseline { "yes" } else { "" }.to_string(),
                d.title.to_string(),
            ]
        })
        .collect();
    cdnl::metrics::print_table(
        "Registered benchmarks (cdnl bench run <name> | --tier <tier>)",
        &["name", "tier", "paper", "baseline", "title"],
        &rows,
    );
    Ok(())
}

fn bench_run(args: &Args, exp: Experiment) -> Result<()> {
    let defs: Vec<&'static cdnl::bench::BenchDef> =
        if let Some(name) = args.positional.get(1) {
            vec![cdnl::bench::find(name)?]
        } else if let Some(t) = args.get("tier") {
            let tier = cdnl::bench::Tier::parse(t)
                .ok_or_else(|| anyhow!("--tier: expected smoke|paper|perf|serve, got {t:?}"))?;
            cdnl::bench::by_tier(tier)
        } else {
            bail!("usage: cdnl bench run <name> | cdnl bench run --tier smoke|paper|perf|serve");
        };
    let backend = open_backend_with(
        Path::new(&exp.artifacts_dir),
        args.get_or("backend", "auto"),
        &exp.model,
    )?;
    println!("backend: {}", backend.name());
    let report_dir = bench_report_dir(args);
    for def in defs {
        let report = cdnl::bench::run_and_save(def, backend.as_ref(), &report_dir)?;
        if args.has("record") {
            // Seal the report into the run-store like any other run, so the
            // perf trajectory lives next to the experiments it describes.
            let store = RunStore::for_experiment(&exp);
            let mut m =
                cdnl::runstore::RunManifest::new("bench", &exp, backend.name(), 0, 0);
            m.status = COMPLETE.to_string();
            m.bench = Some(report);
            let run = store.create(m)?;
            println!("run recorded: {} ({})", run.manifest.run_id, run.dir.display());
        }
    }
    Ok(())
}

fn bench_compare(args: &Args) -> Result<()> {
    let th = cdnl::bench::Thresholds::default();
    let strict = args.has("strict-host");
    let mut outcomes = Vec::new();
    if let Some(rp) = args.positional.get(1) {
        // Explicit pair: `cdnl bench compare <report> <baseline>`.
        let bp = args
            .positional
            .get(2)
            .ok_or_else(|| anyhow!("usage: cdnl bench compare <report> <baseline>"))?;
        let report = cdnl::bench::BenchReport::load(Path::new(rp.as_str()))?;
        let baseline = cdnl::bench::BenchReport::load(Path::new(bp.as_str()))?;
        outcomes.push(cdnl::bench::compare_reports(&report, &baseline, &th, strict));
    } else {
        // Gate mode: every committed baseline must have a fresh report.
        let baseline_dir = bench_baseline_dir(args);
        let report_dir = bench_report_dir(args);
        let mut names: Vec<String> = std::fs::read_dir(&baseline_dir)
            .with_context(|| format!("reading baseline dir {baseline_dir:?}"))?
            .filter_map(|e| e.ok())
            .filter_map(|e| e.file_name().into_string().ok())
            .filter(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
            .collect();
        names.sort();
        if names.is_empty() {
            println!(
                "no committed BENCH_*.json baselines under {baseline_dir:?} — nothing to gate"
            );
            return Ok(());
        }
        for name in names {
            let baseline = cdnl::bench::BenchReport::load(&baseline_dir.join(&name))?;
            let rp = report_dir.join(&name);
            if !rp.exists() {
                bail!(
                    "baseline {name} has no fresh report at {rp:?} — run `cdnl bench run {}` first",
                    baseline.bench
                );
            }
            let report = cdnl::bench::BenchReport::load(&rp)?;
            outcomes.push(cdnl::bench::compare_reports(&report, &baseline, &th, strict));
        }
    }

    let mut failures = 0usize;
    let mut md = String::new();
    for out in &outcomes {
        println!("{}", out.table());
        md.push_str(&out.markdown());
        md.push('\n');
        failures += out.failures();
    }
    if let Some(md_path) = args.get("md") {
        // Append, matching $GITHUB_STEP_SUMMARY semantics.
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(md_path)
            .with_context(|| format!("opening {md_path:?}"))?;
        f.write_all(md.as_bytes())?;
    }
    if args.has("gate") && failures > 0 {
        bail!("bench gate failed: {failures} regressed/missing metric(s)");
    }
    Ok(())
}

// ---- the run-store surface -------------------------------------------------

/// `cdnl runs <list|show|resume|gc>`.
fn cmd_runs(args: &Args, exp: Experiment) -> Result<()> {
    let store = RunStore::for_experiment(&exp);
    let action = args.positional.first().map(|s| s.as_str()).unwrap_or("list");
    match action {
        "list" => runs_list(&store, args),
        "show" => runs_show(&store, runs_id_arg(args)?),
        "resume" => runs_resume(&store, runs_id_arg(args)?, args),
        "gc" => runs_gc(&store, &exp, args),
        other => bail!("unknown runs action {other:?}\nusage: cdnl runs <list|show|resume|gc>"),
    }
}

fn runs_id_arg(args: &Args) -> Result<&str> {
    args.positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or_else(|| anyhow!("usage: cdnl runs <show|resume> <run-id>"))
}

fn fmt_age(now: usize, then: usize) -> String {
    let secs = now.saturating_sub(then);
    match secs {
        0..=119 => format!("{secs}s"),
        120..=7199 => format!("{}m", secs / 60),
        7200..=172_799 => format!("{}h", secs / 3600),
        _ => format!("{}d", secs / 86_400),
    }
}

fn runs_list(store: &RunStore, args: &Args) -> Result<()> {
    // --method validates against the method registry ("snl", "snl+bcd",
    // ...) so a typo fails loudly instead of silently matching nothing;
    // the non-method manifest kinds recorded by other subcommands pass.
    let method = match args.get("method") {
        Some(name) if matches!(name, "bench" | "train") => Some(name.to_string()),
        // Filter on the canonical spec string, so non-canonical spellings
        // ("snl+", " snl + bcd ") match the manifests they mean instead of
        // silently matching nothing.
        Some(name) => Some(ChainSpec::parse(name)?.name()),
        None => None,
    };
    let status = match args.get("status") {
        Some(s) if matches!(s, RUNNING | COMPLETE | FAILED) => Some(s.to_string()),
        Some(s) => bail!("--status: expected running|complete|failed, got {s:?}"),
        None => None,
    };
    let mut runs = store.list()?;
    runs.retain(|m| {
        let method_ok = match &method {
            Some(f) => &m.method == f,
            None => true,
        };
        let status_ok = match &status {
            Some(f) => &m.status == f,
            None => true,
        };
        method_ok && status_ok
    });
    if runs.is_empty() {
        println!("no matching runs under {:?}", store.root());
        return Ok(());
    }
    let now = cdnl::runstore::manifest::now_unix();
    let rows: Vec<Vec<String>> = runs
        .iter()
        .map(|m| {
            let sweeps = m.bcd.as_ref().map(|p| p.sweeps_done).unwrap_or(0);
            let progress = match &m.bcd {
                Some(p) if !p.iterations.is_empty() => format!(
                    "{} -> {}",
                    fmt_relu_count(m.b_start),
                    fmt_relu_count(p.iterations.last().expect("non-empty").budget_after)
                ),
                _ => fmt_relu_count(m.b_start),
            };
            vec![
                m.run_id.clone(),
                m.method.clone(),
                m.dataset.clone(),
                m.backend.clone(),
                m.status.clone(),
                sweeps.to_string(),
                progress,
                fmt_relu_count(m.b_target),
                fmt_age(now, m.updated_unix),
            ]
        })
        .collect();
    cdnl::metrics::print_table(
        &format!("Runs in {:?} (newest first)", store.root()),
        &["id", "method", "dataset", "backend", "status", "sweeps", "budget", "target", "age"],
        &rows,
    );
    Ok(())
}

fn runs_show(store: &RunStore, id: &str) -> Result<()> {
    let run = store.get(id)?;
    let m = &run.manifest;
    println!("run       {}", m.run_id);
    println!("method    {} on {} ({} backend)", m.method, m.model_key, m.backend);
    println!("dataset   {}", m.dataset);
    println!("status    {}", m.status);
    println!("config    fingerprint {}", m.config_fingerprint);
    println!(
        "budget    {} -> {} target",
        fmt_relu_count(m.b_start),
        fmt_relu_count(m.b_target)
    );
    if let Some(r) = &m.result {
        println!(
            "result    {} ReLUs, test_acc {:.2}% -> {:.2}%  ({:.1}s)",
            fmt_relu_count(r.final_budget),
            r.acc_before,
            r.acc_after,
            r.wall_secs
        );
    }
    if let Some(outs) = &m.outcomes {
        // Typed per-stage outcomes from the method registry: one line per
        // stage, method-specific detail for every method (not just BCD).
        for o in outs {
            println!("outcome   {}", o.describe());
        }
    }
    if let Some(b) = &m.bench {
        println!(
            "bench     {} ({} tier, {} mode): {} cases, {} metrics, {:.1}s on {}",
            b.bench,
            b.tier,
            if b.full_mode { "full" } else { "quick" },
            b.cases.len(),
            b.num_metrics(),
            b.wall_secs,
            b.host.fingerprint()
        );
    }
    if let Some(s) = &m.serve {
        println!(
            "serve     {} on {} clients x {} requests: {} inferences, \
             p50 {:.1}ms p95 {:.1}ms p99 {:.1}ms, {:.2} inf/s",
            s.protocol, s.clients, s.requests, s.completed, s.p50_ms, s.p95_ms, s.p99_ms,
            s.throughput_rps
        );
    }
    if !m.stages.is_empty() {
        let rows: Vec<Vec<String>> = m
            .stages
            .iter()
            .map(|s| {
                vec![
                    s.stage.clone(),
                    fmt_relu_count(s.budget),
                    if s.cached { "cache" } else { "built" }.to_string(),
                    format!("{:.1}s", s.wall_secs),
                    s.path.clone(),
                ]
            })
            .collect();
        cdnl::metrics::print_table(
            "Stage provenance",
            &["stage", "budget", "source", "wall", "path"],
            &rows,
        );
    }
    if let Some(p) = &m.bcd {
        println!("\nbcd progress: {} sweeps done", p.sweeps_done);
        let tail = p.iterations.iter().rev().take(10).rev();
        let rows: Vec<Vec<String>> = tail
            .map(|it| {
                vec![
                    it.t.to_string(),
                    it.budget_after.to_string(),
                    format!("{:.2}", it.base_acc),
                    format!("{:+.2}", it.chosen_dacc),
                    format!("{}/{}", it.trials_evaluated, it.trials_bounded),
                    if it.early_accept { "yes" } else { "" }.to_string(),
                    it.removed.len().to_string(),
                    format!("{:.0}ms", it.wall_ms),
                ]
            })
            .collect();
        cdnl::metrics::print_table(
            "Sweep trace (last 10)",
            &["t", "budget", "base%", "dAcc", "trials/bnd", "early", "removed", "wall"],
            &rows,
        );
    }
    if let Some(stats) = &m.stats {
        if !stats.is_empty() {
            // Re-inflate the snapshot and reuse the one stats renderer
            // (same table as `--stats`, compile column included).
            let rows: std::collections::BTreeMap<String, cdnl::runtime::CallStats> = stats
                .iter()
                .map(|(k, s)| {
                    (
                        k.clone(),
                        cdnl::runtime::CallStats {
                            calls: s.calls as u64,
                            total_secs: s.total_secs,
                            compile_secs: s.compile_secs,
                        },
                    )
                })
                .collect();
            println!(
                "\nBackend stats at seal time (incl. prefix-cache, \
                 trial-batch and conv-lowering counters):"
            );
            print!("{}", cdnl::runtime::backend::format_stats_table(&rows));
        }
    }
    Ok(())
}

fn runs_resume(store: &RunStore, id: &str, args: &Args) -> Result<()> {
    let run = store.get(id)?;
    // Cheap validation first — before any backend open or dataset eval.
    if run.manifest.method != "bcd" {
        bail!(
            "run {} is a {:?} run; only bcd runs checkpoint per sweep (re-run it instead)",
            run.manifest.run_id,
            run.manifest.method
        );
    }
    if run.manifest.status == COMPLETE {
        return Err(RunStateError::AlreadyComplete { run_id: run.manifest.run_id.clone() }.into());
    }
    let mut rexp = run.manifest.experiment()?;
    // Paths may legitimately differ from when the run was recorded (moved
    // output tree, different artifact mount) — CLI overrides win, matching
    // the fingerprint's path-independence.
    if let Some(a) = args.get("artifacts") {
        rexp.artifacts_dir = a.to_string();
    }
    if let Some(o) = args.get("out") {
        rexp.out_dir = o.to_string();
    }
    // The manifest knows which backend produced the run; --backend overrides
    // (at your own risk — numerics differ across backends).
    let backend_name = args
        .get("backend")
        .unwrap_or(run.manifest.backend.as_str())
        .to_string();
    let backend = open_backend_with(Path::new(&rexp.artifacts_dir), &backend_name, &rexp.model)?;
    let pl = Pipeline::new(backend.as_ref(), rexp)?;

    let t0 = std::time::Instant::now();
    let (st, out, mut run) = pl.bcd_resume(run)?;
    let secs = t0.elapsed().as_secs_f64();
    // Accuracy bracket: the state the run started from vs the final state.
    let ref_st = ModelState::load(&run.ref_state_path(), pl.sess.info())?;
    let acc_before = test_accuracy(&pl.sess, &ref_st, &pl.test_ds)?;
    let after_acc = pl.test_acc(&st)?;
    println!(
        "bcd (resumed) {}: {} iterations total, {} -> {} ReLUs  test_acc {acc_before:.2}% -> {after_acc:.2}%  ({secs:.1}s this session)",
        run.manifest.run_id,
        out.iterations.len(),
        fmt_relu_count(run.manifest.b_start),
        fmt_relu_count(st.budget()),
    );
    run.manifest.result = Some(RunResult {
        final_budget: st.budget(),
        acc_before,
        acc_after: after_acc,
        // Sweep-loop time across all sessions — same basis as a fresh
        // recorded bcd run (see cmd_method).
        wall_secs: out.iterations.iter().map(|r| r.wall_ms).sum::<f64>() / 1e3,
    });
    run.manifest.stats = Some(cdnl::runstore::stats_snapshot(&backend.stats()));
    run.save()?;

    let out_path = default_ckpt_path(&pl.exp, &pl.sess.key, "bcd", run.manifest.b_target);
    st.save(&out_path)?;
    println!("saved {}", out_path.display());
    Ok(())
}

fn runs_gc(store: &RunStore, exp: &Experiment, args: &Args) -> Result<()> {
    let keep = args.get_usize("keep", 3);
    let dry = args.has("dry-run");
    let doomed = store.gc_candidates(keep, args.has("all"))?;
    // Blob liveness is decided by the manifests that SURVIVE this gc,
    // computed before anything is deleted: a blob referenced by any
    // surviving run — even one shared with a doomed run — is never
    // collected.
    let live = store.live_blob_digests(&doomed)?;
    let cas = cdnl::cas::CasStore::for_experiment(exp);
    if dry {
        // Preview mode for the only destructive CLI verb: list what gc
        // would reclaim (run directories AND blobs), touch nothing.
        for id in &doomed {
            println!("would remove {id}");
        }
        let blobs = cas.gc(&live, true)?;
        for d in &blobs {
            println!("would remove blob {d}");
        }
        if doomed.is_empty() && blobs.is_empty() {
            println!("nothing to remove (kept the {keep} most recent terminal runs)");
        } else {
            println!(
                "{} run(s) and {} blob(s) reclaimable (dry run — nothing deleted)",
                doomed.len(),
                blobs.len()
            );
        }
        return Ok(());
    }
    // Run directories first, blobs second: a crash between the two leaves
    // unreferenced blobs (reclaimed by the next gc), never a manifest
    // pointing at a deleted blob.
    let removed = store.gc(keep, args.has("all"))?;
    for id in &removed {
        println!("removed {id}");
    }
    let blobs = cas.gc(&live, false)?;
    for d in &blobs {
        println!("removed blob {d}");
    }
    if removed.is_empty() && blobs.is_empty() {
        println!("nothing to remove (kept the {keep} most recent terminal runs)");
    } else {
        println!("{} run(s) and {} blob(s) removed", removed.len(), blobs.len());
    }
    Ok(())
}
