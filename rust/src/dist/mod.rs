//! Distributed trial scan over HTTP (DESIGN.md §15).
//!
//! Scales the BCD hypothesis scan past one machine with a dependency-free
//! coordinator/worker protocol over `std::net`:
//!
//! - [`http`] — minimal HTTP/1.1 framing: one request per connection,
//!   exact `Content-Length` bodies, strict parse errors.
//! - [`wire`] — the typed JSON messages (`/config`, `/scan`, `/claim`,
//!   `/complete`), bit-exact across the float round trip.
//! - [`coordinator`] — the lease layer over the local scan's
//!   claim-slab semantics plus the [`ScanServer`]; [`dist_scanner`] plugs
//!   into [`crate::coordinator::bcd::run_bcd_resumable_with`], so a
//!   distributed run checkpoints and resumes from the same `run.json`
//!   cursors as a local one.
//! - [`worker`] — the stateless scoring loop: cold-start by config
//!   fingerprint and CAS params digest, claim, score, post.
//!
//! The contract: the merged [`crate::coordinator::trials::ScanOutcome`] is
//! **bit-identical** to a single-machine scan for any worker membership,
//! join/leave timing, or duplicate completion. Workers may die holding
//! leases (re-issued after a timeout), rejoin mid-scan, or double-post
//! (first write wins) — `rust/tests/integration_dist.rs` injects all three
//! and asserts bit-identity of the full BCD run.
//!
//! Exercised from the CLI as `cdnl coordinate --listen <addr>` plus one or
//! more `cdnl worker --connect <addr>` processes (see the README
//! "Distributed" quickstart).

pub mod coordinator;
pub mod http;
pub mod wire;
pub mod worker;

pub use coordinator::{dist_scanner, LeaseStats, LeasedScan, ScanServer, DEFAULT_LEASE_MS};
pub use wire::{HelloDoc, ScanDoc, WIRE_FORMAT};
pub use worker::{run_worker, WorkerOpts, WorkerSummary};
