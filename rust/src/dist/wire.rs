//! Typed wire messages for the coordinator/worker protocol.
//!
//! All messages are JSON over the [`super::http`] framing, (de)serialized
//! through the crate's hand-rolled serde layer ([`crate::util::serde`]).
//! Floats survive the wire bit-exactly: Rust's `f64` Display emits the
//! shortest round-trippable decimal and `parse::<f64>()` is correctly
//! rounded, so accuracies and per-batch correct counts deserialize to the
//! same bits the worker computed — a precondition for the bit-identical
//! replay merge (DESIGN.md §15).
//!
//! The JSON parser rejects trailing garbage, so a stream that concatenates
//! two documents (e.g. duplicate claim replies smashed into one body) fails
//! loudly instead of silently taking the first.

use crate::coordinator::eval::TrialEval;
use crate::derive_serde;
use std::collections::BTreeMap;

/// `GET /config` — everything a cold worker needs to reconstruct the
/// coordinator's experiment: backend name, model key, dataset, the full
/// semantic config dump, and its fingerprint (the worker recomputes and
/// cross-checks before scoring anything).
#[derive(Clone, Debug, PartialEq)]
pub struct HelloDoc {
    pub format: usize,
    pub backend: String,
    pub model_key: String,
    pub dataset: String,
    pub fingerprint: String,
    pub config: BTreeMap<String, String>,
}
derive_serde!(HelloDoc { format, backend, model_key, dataset, fingerprint, config });

/// Wire format version for [`HelloDoc::format`].
pub const WIRE_FORMAT: usize = 1;

impl HelloDoc {
    /// The hello document for one experiment served by `backend`.
    pub fn for_experiment(exp: &crate::config::Experiment, backend: &str) -> HelloDoc {
        HelloDoc {
            format: WIRE_FORMAT,
            backend: backend.to_string(),
            model_key: exp.model_key(),
            dataset: exp.dataset.clone(),
            fingerprint: exp.fingerprint(),
            config: exp.dump(),
        }
    }
}

/// `GET /scan` — the current scan job, or an idle/shutdown marker.
/// `state` is `"scan"` (fields below are live), `"idle"` (between sweeps),
/// or `"shutdown"` (workers should exit).
#[derive(Clone, Debug, PartialEq)]
pub struct ScanDoc {
    pub state: String,
    pub scan: usize,
    pub mask_size: usize,
    pub mask_removed: Vec<usize>,
    pub params_digest: String,
    pub params_len: usize,
    pub base_acc: f64,
    pub adt: f64,
    pub slab_max: usize,
    pub hyps: Vec<Vec<usize>>,
}
derive_serde!(ScanDoc {
    state,
    scan,
    mask_size,
    mask_removed,
    params_digest,
    params_len,
    base_acc,
    adt,
    slab_max,
    hyps,
});

impl ScanDoc {
    pub fn idle(state: &str) -> ScanDoc {
        ScanDoc {
            state: state.to_string(),
            scan: 0,
            mask_size: 0,
            mask_removed: Vec::new(),
            params_digest: String::new(),
            params_len: 0,
            base_acc: 0.0,
            adt: 0.0,
            slab_max: 0,
            hyps: Vec::new(),
        }
    }
}

/// `POST /claim` request: which worker asks, for which scan generation.
#[derive(Clone, Debug, PartialEq)]
pub struct ClaimRequest {
    pub worker: String,
    pub scan: usize,
}
derive_serde!(ClaimRequest { worker, scan });

/// One granted slab: trials `start..start+len`, scored against `floor`
/// (the branch-and-bound accuracy floor at grant time).
#[derive(Clone, Debug, PartialEq)]
pub struct SlabGrant {
    pub start: usize,
    pub len: usize,
    pub floor: f64,
}
derive_serde!(SlabGrant { start, len, floor });

/// `POST /claim` reply. `slab: None` with `done: false` means nothing is
/// claimable *right now* (outstanding leases may still expire) — retry
/// after `retry_ms`. `done: true` means the scan generation is finished.
#[derive(Clone, Debug, PartialEq)]
pub struct ClaimReply {
    pub scan: usize,
    pub slab: Option<SlabGrant>,
    pub done: bool,
    pub retry_ms: usize,
}
derive_serde!(ClaimReply { scan, slab, done, retry_ms });

/// One trial result on the wire. `bounded: true` means branch-and-bound cut
/// the trial (no score); otherwise `acc`/`corrects` carry the full
/// [`TrialEval::Scored`] payload.
#[derive(Clone, Debug, PartialEq)]
pub struct WireEval {
    pub bounded: bool,
    pub acc: f64,
    pub corrects: Vec<f64>,
}
derive_serde!(WireEval { bounded, acc, corrects });

impl WireEval {
    pub fn from_eval(ev: &TrialEval) -> WireEval {
        match ev {
            TrialEval::Bounded => {
                WireEval { bounded: true, acc: 0.0, corrects: Vec::new() }
            }
            TrialEval::Scored { acc, batch_corrects } => WireEval {
                bounded: false,
                acc: *acc,
                corrects: batch_corrects.clone(),
            },
        }
    }

    pub fn into_eval(self) -> TrialEval {
        if self.bounded {
            TrialEval::Bounded
        } else {
            TrialEval::Scored { acc: self.acc, batch_corrects: self.corrects }
        }
    }
}

/// `POST /complete` request: the scored slab starting at `start`.
#[derive(Clone, Debug, PartialEq)]
pub struct CompleteRequest {
    pub worker: String,
    pub scan: usize,
    pub start: usize,
    pub evals: Vec<WireEval>,
}
derive_serde!(CompleteRequest { worker, scan, start, evals });

/// `POST /complete` reply. A duplicate completion (slab already merged,
/// e.g. from a zombie worker whose lease was re-issued) is acknowledged
/// with `accepted: false, duplicate: true` — idempotent, never an error.
#[derive(Clone, Debug, PartialEq)]
pub struct CompleteReply {
    pub accepted: bool,
    pub duplicate: bool,
}
derive_serde!(CompleteReply { accepted, duplicate });

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::serde::{from_str, to_string};

    fn sample_reply() -> ClaimReply {
        ClaimReply {
            scan: 3,
            slab: Some(SlabGrant { start: 8, len: 4, floor: 71.25 }),
            done: false,
            retry_ms: 50,
        }
    }

    #[test]
    fn claim_roundtrip() {
        let r = sample_reply();
        let back: ClaimReply = from_str(&to_string(&r)).unwrap();
        assert_eq!(back, r);
        // No-grant reply keeps slab as None.
        let none = ClaimReply { scan: 3, slab: None, done: true, retry_ms: 0 };
        let back: ClaimReply = from_str(&to_string(&none)).unwrap();
        assert_eq!(back, none);
    }

    #[test]
    fn eval_roundtrip_is_bit_exact() {
        // Adversarial floats: values with no short decimal representation.
        let ev = TrialEval::Scored {
            acc: 0.1 + 0.2, // 0.30000000000000004
            batch_corrects: vec![1.0 / 3.0, f64::MIN_POSITIVE, 123456789.000000123],
        };
        let req = CompleteRequest {
            worker: "w1".into(),
            scan: 1,
            start: 0,
            evals: vec![WireEval::from_eval(&ev), WireEval::from_eval(&TrialEval::Bounded)],
        };
        let back: CompleteRequest = from_str(&to_string(&req)).unwrap();
        assert_eq!(back.evals[0].clone().into_eval(), ev, "floats must round-trip bit-exactly");
        assert_eq!(back.evals[1].clone().into_eval(), TrialEval::Bounded);
    }

    #[test]
    fn scan_doc_roundtrip() {
        let doc = ScanDoc {
            state: "scan".into(),
            scan: 2,
            mask_size: 100,
            mask_removed: vec![3, 17],
            params_digest: "ab".repeat(32),
            params_len: 1234,
            base_acc: 81.5,
            adt: 0.5,
            slab_max: 8,
            hyps: vec![vec![1, 2], vec![3]],
        };
        let back: ScanDoc = from_str(&to_string(&doc)).unwrap();
        assert_eq!(back, doc);
        assert_eq!(ScanDoc::idle("idle").state, "idle");
    }

    #[test]
    fn truncated_json_is_rejected() {
        let full = to_string(&sample_reply());
        let cut = &full[..full.len() - 5];
        assert!(from_str::<ClaimReply>(cut).is_err(), "truncated doc must not parse");
    }

    #[test]
    fn concatenated_replies_are_rejected() {
        // Two claim replies smashed into one body (e.g. a duplicated reply on
        // a confused stream): the parser rejects trailing garbage rather than
        // silently taking the first document.
        let one = to_string(&sample_reply());
        let doubled = format!("{one}{one}");
        let err = from_str::<ClaimReply>(&doubled).unwrap_err();
        assert!(err.contains("trailing garbage"), "got: {err}");
    }

    #[test]
    fn wrong_typed_fields_are_rejected() {
        let err = from_str::<ClaimRequest>(r#"{"worker": 7, "scan": 0}"#).unwrap_err();
        assert!(err.contains("worker"), "error should name the field: {err}");
        let err =
            from_str::<CompleteRequest>(r#"{"worker": "w", "scan": 1, "start": -3, "evals": []}"#)
                .unwrap_err();
        assert!(err.contains("start"), "error should name the field: {err}");
    }
}
