//! Dependency-free HTTP/1.1 framing for the dist protocol.
//!
//! Just enough of the protocol for coordinator/worker exchange on a trusted
//! network: one request per connection (`Connection: close` semantics),
//! bodies framed by an exact `Content-Length`, and a serial accept loop.
//! Parsing is strict by design — anything malformed (missing or non-numeric
//! content-length, truncated body, oversized body) is a typed error rather
//! than a best-effort read, because wire corruption must never masquerade
//! as an empty result.

use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Hard cap on request/response bodies (params blobs dominate; 64 MiB is
/// ~16M f32 parameters, far above any model in the zoo).
pub const MAX_BODY: usize = 64 << 20;

/// Per-stream read/write timeout; a stalled peer cannot wedge the accept loop.
const IO_TIMEOUT: Duration = Duration::from_secs(5);

#[derive(Clone, Debug, PartialEq)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

#[derive(Clone, Debug, PartialEq)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
}

impl Response {
    pub fn json(body: impl Into<Vec<u8>>) -> Response {
        Response { status: 200, content_type: "application/json", body: body.into() }
    }

    pub fn binary(body: Vec<u8>) -> Response {
        Response { status: 200, content_type: "application/octet-stream", body }
    }

    pub fn error(status: u16, msg: &str) -> Response {
        Response { status, content_type: "text/plain", body: msg.as_bytes().to_vec() }
    }
}

fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        409 => "Conflict",
        _ => "Internal Server Error",
    }
}

/// Read header lines up to the blank separator, returning the start line and
/// the parsed `Content-Length` (0 when absent).
fn read_head(r: &mut impl BufRead) -> Result<(String, usize)> {
    let mut start = String::new();
    if r.read_line(&mut start)? == 0 {
        bail!("connection closed before request line");
    }
    let start = start.trim_end().to_string();
    let mut content_length = 0usize;
    loop {
        let mut line = String::new();
        if r.read_line(&mut line)? == 0 {
            bail!("connection closed inside headers");
        }
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            if name.eq_ignore_ascii_case("content-length") {
                content_length = value
                    .trim()
                    .parse::<usize>()
                    .with_context(|| format!("bad content-length {:?}", value.trim()))?;
            }
        }
    }
    if content_length > MAX_BODY {
        bail!("content-length {content_length} exceeds limit {MAX_BODY}");
    }
    Ok((start, content_length))
}

/// Read exactly `len` body bytes; a short read is a hard error ("truncated
/// body"), never silently padded or trimmed.
fn read_body(r: &mut impl BufRead, len: usize) -> Result<Vec<u8>> {
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)
        .map_err(|e| anyhow::anyhow!("truncated body (wanted {len} bytes): {e}"))?;
    Ok(body)
}

/// Parse one HTTP/1.1 request from a buffered stream.
pub fn read_request(r: &mut impl BufRead) -> Result<Request> {
    let (start, len) = read_head(r)?;
    let mut parts = start.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    if method.is_empty() || path.is_empty() {
        bail!("malformed request line {start:?}");
    }
    let body = read_body(r, len)?;
    Ok(Request { method, path, body })
}

/// Parse one HTTP/1.1 response from a buffered stream.
pub fn read_response(r: &mut impl BufRead) -> Result<Response> {
    let (start, len) = read_head(r)?;
    let status = start
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse::<u16>().ok())
        .with_context(|| format!("malformed status line {start:?}"))?;
    let body = read_body(r, len)?;
    Ok(Response { status, content_type: "application/octet-stream", body })
}

pub fn write_request(w: &mut impl Write, method: &str, path: &str, body: &[u8]) -> Result<()> {
    write!(
        w,
        "{method} {path} HTTP/1.1\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    w.write_all(body)?;
    w.flush()?;
    Ok(())
}

pub fn write_response(w: &mut impl Write, resp: &Response) -> Result<()> {
    write!(
        w,
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        resp.status,
        status_text(resp.status),
        resp.content_type,
        resp.body.len()
    )?;
    w.write_all(&resp.body)?;
    w.flush()?;
    Ok(())
}

/// One round trip against `addr`: connect, send, read the reply. Non-2xx
/// replies become errors carrying the server's message body.
pub fn http_call(addr: &str, method: &str, path: &str, body: &[u8]) -> Result<Vec<u8>> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let mut w = stream.try_clone()?;
    write_request(&mut w, method, path, body)?;
    let resp = read_response(&mut BufReader::new(stream))
        .with_context(|| format!("{method} {path} on {addr}"))?;
    if resp.status != 200 {
        bail!(
            "{method} {path} on {addr}: HTTP {} — {}",
            resp.status,
            String::from_utf8_lossy(&resp.body)
        );
    }
    Ok(resp.body)
}

pub fn http_get(addr: &str, path: &str) -> Result<Vec<u8>> {
    http_call(addr, "GET", path, &[])
}

pub fn http_post(addr: &str, path: &str, body: &[u8]) -> Result<Vec<u8>> {
    http_call(addr, "POST", path, body)
}

/// A minimal single-threaded HTTP server: a background accept loop that
/// hands each request to `handler`. Requests are served serially — the
/// handler owns all shared state behind its own locks, and the claim/
/// complete endpoints are cheap (the expensive work happens on workers).
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    pub fn start(
        bind: impl ToSocketAddrs,
        handler: Arc<dyn Fn(&Request) -> Response + Send + Sync>,
    ) -> Result<Server> {
        let listener = TcpListener::bind(bind).context("dist: bind listener")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let thread = std::thread::spawn(move || {
            for stream in listener.incoming() {
                if stop2.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let _ = serve_one(stream, handler.as_ref());
            }
        });
        Ok(Server { addr, stop, thread: Some(thread) })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop. A self-connection wakes the blocking `accept`
    /// so the thread observes the flag promptly.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

fn serve_one(stream: TcpStream, handler: &(dyn Fn(&Request) -> Response)) -> Result<()> {
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let mut w = stream.try_clone()?;
    let resp = match read_request(&mut BufReader::new(stream)) {
        Ok(req) => handler(&req),
        Err(e) => Response::error(400, &format!("bad request: {e:#}")),
    };
    write_response(&mut w, &resp)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn raw_request(head: &str, body: &[u8]) -> Vec<u8> {
        let mut v = head.as_bytes().to_vec();
        v.extend_from_slice(body);
        v
    }

    #[test]
    fn request_roundtrip() {
        let mut buf = Vec::new();
        write_request(&mut buf, "POST", "/claim", b"{\"worker\":\"w0\"}").unwrap();
        let req = read_request(&mut Cursor::new(buf)).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/claim");
        assert_eq!(req.body, b"{\"worker\":\"w0\"}");
    }

    #[test]
    fn response_roundtrip() {
        let mut buf = Vec::new();
        write_response(&mut buf, &Response::json(b"{}".to_vec())).unwrap();
        let resp = read_response(&mut Cursor::new(buf)).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body, b"{}");
    }

    #[test]
    fn truncated_body_is_rejected() {
        // Content-Length promises 10 bytes, the stream carries 4.
        let raw = raw_request("POST /claim HTTP/1.1\r\nContent-Length: 10\r\n\r\n", b"{\"a\"");
        let err = read_request(&mut Cursor::new(raw)).unwrap_err().to_string();
        assert!(err.contains("truncated body"), "got: {err}");
    }

    #[test]
    fn bad_content_length_is_rejected() {
        let raw = raw_request("POST /claim HTTP/1.1\r\nContent-Length: banana\r\n\r\n", b"");
        let err = format!("{:#}", read_request(&mut Cursor::new(raw)).unwrap_err());
        assert!(err.contains("bad content-length"), "got: {err}");
    }

    #[test]
    fn oversized_content_length_is_rejected() {
        let head = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY + 1);
        let err = read_request(&mut Cursor::new(head.into_bytes())).unwrap_err().to_string();
        assert!(err.contains("exceeds limit"), "got: {err}");
    }

    #[test]
    fn server_serves_and_stops() {
        let mut srv = Server::start(
            "127.0.0.1:0",
            Arc::new(|req: &Request| {
                if req.path == "/echo" {
                    Response::json(req.body.clone())
                } else {
                    Response::error(404, "no such route")
                }
            }),
        )
        .unwrap();
        let addr = srv.addr().to_string();
        assert_eq!(http_post(&addr, "/echo", b"ping").unwrap(), b"ping");
        let err = http_get(&addr, "/missing").unwrap_err().to_string();
        assert!(err.contains("404"), "got: {err}");
        srv.stop();
    }
}
