//! Coordinator side of the distributed trial scan (DESIGN.md §15).
//!
//! The coordinator runs the BCD outer loop exactly as a local run does —
//! same RNG streams, same checkpoint cadence, same `run.json` cursors — but
//! each iteration's hypothesis scoring is served to remote workers over
//! HTTP instead of a local thread pool:
//!
//! 1. [`crate::coordinator::trials::draw_hypotheses`] draws the sweep's
//!    hypotheses (consuming identical RNG state to a local scan), the
//!    current params are published to the CAS by digest, and a
//!    [`ScanDoc`] is installed as the active job.
//! 2. Workers poll `/scan`, cold-start from the params digest, and claim
//!    contiguous slabs via `/claim` — granted by the *same*
//!    [`ScanState::claim_slab`] the local pool uses, wrapped in a lease
//!    layer ([`LeasedScan`]): a claim not completed within the lease
//!    timeout is re-issued to the next asking worker, and duplicate
//!    completions (a presumed-dead worker posting late) are idempotently
//!    ignored, first write wins.
//! 3. When every slab is completed the coordinator runs the sequential
//!    replay merge ([`crate::coordinator::trials::replay_merge`]) over the
//!    recorded results. The merge re-derives every bound/accept decision
//!    from recorded per-batch corrects, which is why the outcome is
//!    bit-identical for ANY worker membership, join/leave timing, or
//!    duplicate completion — the full argument lives in DESIGN.md §15.

use crate::cas::CasStore;
use crate::config::BcdConfig;
use crate::coordinator::bcd::{as_scanner, ScanArgs};
use crate::coordinator::eval::TrialEval;
use crate::coordinator::trials::{draw_hypotheses, replay_merge, ScanOutcome, ScanState};
use crate::dist::http::{Request, Response, Server};
use crate::dist::wire::{
    ClaimReply, ClaimRequest, CompleteReply, CompleteRequest, HelloDoc, ScanDoc, SlabGrant,
    WireEval,
};
use crate::runstore::BlobRef;
use crate::util::prng::Rng;
use crate::util::serde::{from_str, to_string};
use anyhow::{ensure, Context, Result};
use std::collections::BTreeMap;
use std::net::ToSocketAddrs;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Default lease timeout: a slab not completed within this window is
/// assumed lost and re-issued on the next claim.
pub const DEFAULT_LEASE_MS: u64 = 10_000;

/// Suggested worker back-off when a claim returns no slab but the scan is
/// not done (outstanding leases may still expire).
const RETRY_MS: usize = 50;

/// Counters over the lease protocol — exact by construction, so the smoke
/// bench gates on them (`BENCH_smoke.json`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LeaseStats {
    /// Slab grants handed out (fresh + re-issued).
    pub claims_issued: usize,
    /// Grants that re-issued an expired lease.
    pub leases_reissued: usize,
    /// Completions for already-completed slabs (idempotently ignored).
    pub duplicate_completions: usize,
    /// Slabs merged (each slab exactly once, first write wins).
    pub completed_slabs: usize,
}

impl LeaseStats {
    pub fn add(&mut self, other: &LeaseStats) {
        self.claims_issued += other.claims_issued;
        self.leases_reissued += other.leases_reissued;
        self.duplicate_completions += other.duplicate_completions;
        self.completed_slabs += other.completed_slabs;
    }
}

/// One outstanding slab grant.
#[derive(Clone, Debug)]
struct Lease {
    len: usize,
    worker: String,
    issued_ms: u64,
}

/// [`ScanState`]'s in-order claim semantics wrapped in a lease layer for
/// remote workers: grants are leased, idempotent, and re-issuable on worker
/// death. Time is an explicit `now_ms` parameter so the protocol is exactly
/// unit-testable (the smoke bench drives a full kill/re-issue/duplicate
/// schedule with pinned clocks).
pub struct LeasedScan {
    state: ScanState,
    base_acc: f64,
    adt: f64,
    lease_timeout_ms: u64,
    /// Outstanding leases keyed by slab start (sorted — expired leases are
    /// re-issued lowest-start first, matching in-order claiming).
    leases: BTreeMap<usize, Lease>,
    stats: LeaseStats,
}

impl LeasedScan {
    pub fn new(n: usize, base_acc: f64, adt: f64, lease_timeout_ms: u64) -> LeasedScan {
        LeasedScan {
            state: ScanState::new(n),
            base_acc,
            adt,
            lease_timeout_ms,
            leases: BTreeMap::new(),
            stats: LeaseStats::default(),
        }
    }

    /// Best completed accuracy strictly below `start` — the bound floor a
    /// re-issued slab is scored against. Recomputing at re-issue time is
    /// safe: any floor derived from completed lower-index results is ≤ the
    /// merge-time incumbent floor, so runtime cuts stay a subset of merge
    /// cuts (DESIGN.md §15).
    fn floor_below(&self, start: usize) -> f64 {
        let mut floor = 0.0f64;
        for r in &self.state.results[..start] {
            if let Some(TrialEval::Scored { acc, .. }) = r {
                floor = floor.max(*acc);
            }
        }
        floor
    }

    /// Grant a slab to `worker`: the lowest-start expired lease if any,
    /// otherwise the next in-order slab of up to `slab_max` trials.
    pub fn claim(&mut self, worker: &str, slab_max: usize, now_ms: u64) -> Option<SlabGrant> {
        let expired = self
            .leases
            .iter()
            .find(|(_, l)| now_ms.saturating_sub(l.issued_ms) >= self.lease_timeout_ms)
            .map(|(&start, l)| (start, l.len));
        if let Some((start, len)) = expired {
            let floor = self.floor_below(start);
            self.leases
                .insert(start, Lease { len, worker: worker.to_string(), issued_ms: now_ms });
            self.stats.leases_reissued += 1;
            self.stats.claims_issued += 1;
            return Some(SlabGrant { start, len, floor });
        }
        let (start, len, floor) = self.state.claim_slab(slab_max.max(1))?;
        self.leases
            .insert(start, Lease { len, worker: worker.to_string(), issued_ms: now_ms });
        self.stats.claims_issued += 1;
        Some(SlabGrant { start, len, floor })
    }

    /// Record a completed slab. First write wins: a completion for a slab
    /// that already holds results (a zombie worker posting after its lease
    /// was re-issued and completed by someone else) is counted and ignored.
    /// Returns `true` when the completion was a duplicate.
    pub fn complete(&mut self, start: usize, evals: Vec<TrialEval>) -> bool {
        let n = self.state.results.len();
        if start >= n || start + evals.len() > n || evals.is_empty() {
            self.stats.duplicate_completions += 1; // malformed ≙ ignored
            return true;
        }
        if self.state.results[start].is_some() {
            self.stats.duplicate_completions += 1;
            return true;
        }
        for (off, ev) in evals.into_iter().enumerate() {
            let i = start + off;
            if let TrialEval::Scored { acc, .. } = &ev {
                if self.base_acc - acc < self.adt {
                    // Same accept propagation as the local scan's Phase 2.
                    self.state.stop_at = Some(self.state.stop_at.map_or(i, |s| s.min(i)));
                }
            }
            self.state.results[i] = Some(ev);
        }
        self.leases.remove(&start);
        self.stats.completed_slabs += 1;
        false
    }

    /// True when nothing is claimable and no lease is outstanding — the
    /// exact analog of the local pool's "claim loop exhausted and every
    /// worker joined".
    pub fn done(&self) -> bool {
        if !self.leases.is_empty() {
            return false;
        }
        let n = self.state.results.len();
        self.state.next >= n || self.state.stop_at.is_some_and(|stop| self.state.next > stop)
    }

    pub fn stats(&self) -> &LeaseStats {
        &self.stats
    }

    pub fn into_results(self) -> (Vec<Option<TrialEval>>, LeaseStats) {
        (self.state.results, self.stats)
    }
}

/// The active scan job behind the HTTP handler.
struct Job {
    scan_id: usize,
    doc_json: String,
    slab_max: usize,
    scan: LeasedScan,
}

/// Handler-shared coordinator state.
struct Inner {
    job: Option<Job>,
    shutdown: bool,
    total: LeaseStats,
    blobs: Vec<BlobRef>,
}

struct Shared {
    inner: Mutex<Inner>,
    cv: Condvar,
    hello_json: String,
    cas: CasStore,
    epoch: Instant,
}

impl Shared {
    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }
}

/// The coordinator's HTTP face: serves `/config`, `/scan`, `/claim`,
/// `/complete`, `/cas/<digest>` and `/health` to workers, and hands
/// completed scans back to [`dist_scanner`].
pub struct ScanServer {
    http: Server,
    shared: Arc<Shared>,
}

impl ScanServer {
    pub fn start(bind: impl ToSocketAddrs, hello: &HelloDoc, cas: CasStore) -> Result<ScanServer> {
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner {
                job: None,
                shutdown: false,
                total: LeaseStats::default(),
                blobs: Vec::new(),
            }),
            cv: Condvar::new(),
            hello_json: to_string(hello),
            cas,
            epoch: Instant::now(),
        });
        let s2 = Arc::clone(&shared);
        let http = Server::start(bind, Arc::new(move |req: &Request| route(&s2, req)))?;
        Ok(ScanServer { http, shared })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.http.addr()
    }

    /// Publish a named blob to the CAS, recording digest provenance for the
    /// run manifest (see [`Self::take_blobs`]).
    pub fn put_blob(&self, name: &str, bytes: &[u8]) -> Result<BlobRef> {
        let put = self.shared.cas.put_bytes(bytes)?;
        let blob = BlobRef {
            name: name.to_string(),
            digest: put.digest,
            bytes: put.bytes as usize,
        };
        let mut g = self.shared.inner.lock().unwrap();
        if !g.blobs.iter().any(|b| b.digest == blob.digest) {
            g.blobs.push(blob.clone());
        }
        Ok(blob)
    }

    /// Drain the blob provenance recorded so far (stored into
    /// `run.json` so `runs gc` can keep referenced blobs alive).
    pub fn take_blobs(&self) -> Vec<BlobRef> {
        std::mem::take(&mut self.shared.inner.lock().unwrap().blobs)
    }

    /// Lease/merge counters accumulated over all completed scans.
    pub fn stats(&self) -> LeaseStats {
        self.shared.inner.lock().unwrap().total.clone()
    }

    /// Flip `/scan` to the shutdown document so polling workers exit. The
    /// server keeps answering until the `ScanServer` is dropped, giving
    /// workers a window to observe the state.
    pub fn shutdown(&self) {
        self.shared.inner.lock().unwrap().shutdown = true;
    }

    /// Install `doc` (whose `hyps` has `n` entries) as the active job and
    /// block until every slab is completed; returns the per-trial results
    /// in index order plus this scan's lease stats.
    pub fn run_scan(
        &self,
        doc: &ScanDoc,
        lease_timeout_ms: u64,
    ) -> Result<(Vec<Option<TrialEval>>, LeaseStats)> {
        let n = doc.hyps.len();
        let mut g = self.shared.inner.lock().unwrap();
        ensure!(g.job.is_none(), "dist: a scan job is already active");
        ensure!(!g.shutdown, "dist: coordinator is shutting down");
        g.job = Some(Job {
            scan_id: doc.scan,
            doc_json: to_string(doc),
            slab_max: doc.slab_max,
            scan: LeasedScan::new(n, doc.base_acc, doc.adt, lease_timeout_ms),
        });
        while !g.job.as_ref().expect("installed above").scan.done() {
            // The timeout is a liveness backstop only — completions notify.
            let (g2, _) = self
                .shared
                .cv
                .wait_timeout(g, Duration::from_millis(50))
                .unwrap();
            g = g2;
        }
        let job = g.job.take().expect("checked in loop");
        let (results, stats) = job.scan.into_results();
        g.total.add(&stats);
        Ok((results, stats))
    }
}

/// Dispatch one worker request against the shared coordinator state.
fn route(sh: &Shared, req: &Request) -> Response {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/health") => Response::json(b"{\"ok\": true}".to_vec()),
        ("GET", "/config") => Response::json(sh.hello_json.as_bytes().to_vec()),
        ("GET", "/scan") => {
            let g = sh.inner.lock().unwrap();
            if g.shutdown {
                Response::json(to_string(&ScanDoc::idle("shutdown")).into_bytes())
            } else if let Some(job) = &g.job {
                Response::json(job.doc_json.clone().into_bytes())
            } else {
                Response::json(to_string(&ScanDoc::idle("idle")).into_bytes())
            }
        }
        ("POST", "/claim") => match from_str::<ClaimRequest>(
            &String::from_utf8_lossy(&req.body),
        ) {
            Ok(creq) => {
                let now = sh.now_ms();
                let mut g = sh.inner.lock().unwrap();
                let reply = match &mut g.job {
                    Some(job) if job.scan_id == creq.scan => {
                        let slab_max = job.slab_max;
                        match job.scan.claim(&creq.worker, slab_max, now) {
                            Some(grant) => ClaimReply {
                                scan: creq.scan,
                                slab: Some(grant),
                                done: false,
                                retry_ms: RETRY_MS,
                            },
                            None => ClaimReply {
                                scan: creq.scan,
                                slab: None,
                                done: job.scan.done(),
                                retry_ms: RETRY_MS,
                            },
                        }
                    }
                    // Stale or unknown scan generation: that scan is over.
                    _ => ClaimReply { scan: creq.scan, slab: None, done: true, retry_ms: RETRY_MS },
                };
                Response::json(to_string(&reply).into_bytes())
            }
            Err(e) => Response::error(400, &format!("bad claim: {e}")),
        },
        ("POST", "/complete") => match from_str::<CompleteRequest>(
            &String::from_utf8_lossy(&req.body),
        ) {
            Ok(creq) => {
                let mut g = sh.inner.lock().unwrap();
                let reply = match &mut g.job {
                    Some(job) if job.scan_id == creq.scan => {
                        let evals: Vec<TrialEval> =
                            creq.evals.into_iter().map(WireEval::into_eval).collect();
                        let duplicate = job.scan.complete(creq.start, evals);
                        if job.scan.done() {
                            sh.cv.notify_all();
                        }
                        CompleteReply { accepted: !duplicate, duplicate }
                    }
                    _ => CompleteReply { accepted: false, duplicate: true },
                };
                Response::json(to_string(&reply).into_bytes())
            }
            Err(e) => Response::error(400, &format!("bad complete: {e}")),
        },
        ("GET", path) if path.starts_with("/cas/") => {
            let digest = &path["/cas/".len()..];
            if !crate::cas::valid_digest(digest) {
                return Response::error(400, "malformed digest");
            }
            if !sh.cas.contains(digest) {
                return Response::error(404, &format!("no blob {digest}"));
            }
            match sh.cas.get(digest) {
                Ok(bytes) => Response::binary(bytes),
                Err(e) => Response::error(500, &format!("{e:#}")),
            }
        }
        (m, p) => Response::error(404, &format!("no route {m} {p}")),
    }
}

/// A [`crate::coordinator::bcd::TrialScanner`] that serves each iteration's
/// scan to remote workers via `srv`: draw hypotheses (identical RNG
/// consumption to the local scan), publish params to the CAS by digest,
/// install the scan job, wait for workers, replay-merge. Plugged into
/// [`crate::coordinator::bcd::run_bcd_resumable_with`], the surrounding BCD
/// run checkpoints and resumes exactly like a local one.
pub fn dist_scanner<'a>(
    srv: &'a ScanServer,
    cfg: &'a BcdConfig,
    lease_timeout_ms: u64,
) -> impl FnMut(&ScanArgs, &mut Rng) -> Result<ScanOutcome> + 'a {
    as_scanner(move |a: &ScanArgs, rng: &mut Rng| {
        let hyps = draw_hypotheses(a.mask, a.sampler, a.drc, cfg.rt, rng);
        let mut bytes = Vec::with_capacity(a.params_host.data.len() * 4);
        for f in &a.params_host.data {
            bytes.extend_from_slice(&f.to_le_bytes());
        }
        let blob = srv
            .put_blob(&format!("params_sweep{}", a.sweep), &bytes)
            .context("dist: publish params")?;
        let dense = a.mask.dense();
        let mask_removed: Vec<usize> =
            (0..dense.len()).filter(|&i| dense[i] == 0.0).collect();
        let doc = ScanDoc {
            state: "scan".to_string(),
            scan: a.sweep,
            mask_size: a.mask.size(),
            mask_removed,
            params_digest: blob.digest,
            params_len: a.params_host.data.len(),
            base_acc: a.base_acc,
            adt: cfg.adt,
            slab_max: a.ev.slab_width(),
            hyps: hyps.iter().map(|d| d.indices().to_vec()).collect(),
        };
        let (results, stats) = srv.run_scan(&doc, lease_timeout_ms)?;
        crate::info!(
            "dist: sweep {} scored by workers ({} slabs, {} claims, {} reissued, {} dup)",
            a.sweep,
            stats.completed_slabs,
            stats.claims_issued,
            stats.leases_reissued,
            stats.duplicate_completions
        );
        Ok(replay_merge(&hyps, results, a.base_acc, cfg.adt, |corrects, floor| {
            a.ev.would_bound(corrects, floor)
        }))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scored(acc: f64) -> TrialEval {
        TrialEval::Scored { acc, batch_corrects: vec![acc] }
    }

    #[test]
    fn lease_reissue_after_timeout_lowest_start_first() {
        // 10 trials, slabs of 4, 100 ms leases.
        let mut ls = LeasedScan::new(10, 80.0, 0.5, 100);
        let a = ls.claim("a", 4, 0).unwrap();
        let b = ls.claim("b", 4, 0).unwrap();
        assert_eq!((a.start, a.len), (0, 4));
        assert_eq!((b.start, b.len), (4, 4));
        // Nothing expired yet: next claim gets the in-order tail.
        let c = ls.claim("c", 4, 50).unwrap();
        assert_eq!((c.start, c.len), (8, 2));
        assert!(ls.claim("d", 4, 50).is_none(), "all slabs leased");
        // Workers a and b die; at t=200 both leases are expired — re-issue
        // lowest start first, original length preserved.
        let r1 = ls.claim("d", 4, 200).unwrap();
        assert_eq!((r1.start, r1.len), (0, 4));
        let r2 = ls.claim("e", 4, 200).unwrap();
        assert_eq!((r2.start, r2.len), (4, 4));
        assert_eq!(ls.stats().leases_reissued, 2);
        assert_eq!(ls.stats().claims_issued, 5);
    }

    #[test]
    fn reissued_floor_uses_completed_lower_results() {
        let mut ls = LeasedScan::new(6, 80.0, 0.5, 100);
        let a = ls.claim("a", 2, 0).unwrap(); // 0..2
        let _b = ls.claim("b", 2, 0).unwrap(); // 2..4
        assert_eq!(a.floor, 0.0);
        assert!(!ls.complete(0, vec![scored(70.0), scored(72.0)]));
        // b dies; the re-issue at t=200 sees the completed floor below 2.
        let r = ls.claim("c", 2, 200).unwrap();
        assert_eq!((r.start, r.floor), (2, 72.0));
    }

    #[test]
    fn duplicate_completion_is_ignored_first_write_wins() {
        let mut ls = LeasedScan::new(4, 80.0, 0.5, 100);
        let _a = ls.claim("a", 4, 0).unwrap();
        assert!(!ls.complete(0, vec![scored(70.0), scored(71.0), scored(72.0), scored(73.0)]));
        // Zombie posts different numbers: ignored, counted, results frozen.
        assert!(ls.complete(0, vec![scored(1.0), scored(2.0), scored(3.0), scored(4.0)]));
        let (results, stats) = ls.into_results();
        assert_eq!(results[0], Some(scored(70.0)));
        assert_eq!(stats.duplicate_completions, 1);
        assert_eq!(stats.completed_slabs, 1);
    }

    #[test]
    fn accept_sets_stop_and_done_requires_empty_leases() {
        let mut ls = LeasedScan::new(10, 80.0, 0.5, 100);
        let _a = ls.claim("a", 4, 0).unwrap(); // 0..4
        let _b = ls.claim("b", 4, 0).unwrap(); // 4..8
        // b completes with an accept at index 5 (dacc 0.2 < adt 0.5).
        assert!(!ls.complete(4, vec![scored(70.0), scored(79.8), scored(71.0), scored(72.0)]));
        // No slab beyond the accept is claimable, but a's lease is live.
        assert!(ls.claim("c", 4, 10).is_none());
        assert!(!ls.done(), "outstanding lease blocks done");
        assert!(!ls.complete(0, vec![scored(60.0), scored(61.0), scored(62.0), scored(63.0)]));
        assert!(ls.done());
    }
}
