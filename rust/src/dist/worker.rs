//! Worker side of the distributed trial scan.
//!
//! A worker is stateless between scans: it cold-starts from the
//! coordinator's `/config` document (rebuilding the experiment from the
//! config dump and cross-checking the fingerprint), scores with its local
//! [`Backend`], and fetches model params by digest from the coordinator's
//! CAS — verifying the streaming checksum after download, so a corrupted
//! transfer can never be scored. Trial slabs are claimed over `/claim` and
//! posted back over `/complete`; the lease layer on the coordinator makes
//! every step idempotent, so a worker may die, rejoin, or double-post at
//! any point without affecting the merged outcome (DESIGN.md §15).
//!
//! [`WorkerOpts`] carries fault-injection knobs (`max_slabs`,
//! `die_after_claim`, `duplicate_completions`) used by the loopback
//! integration test to prove exactly that.

use crate::cas::digest_hex;
use crate::config::Experiment;
use crate::coordinator::eval::{EvalOpts, Evaluator};
use crate::data::synth;
use crate::dist::http::{http_get, http_post};
use crate::dist::wire::{
    ClaimReply, ClaimRequest, CompleteReply, CompleteRequest, HelloDoc, ScanDoc, WireEval,
    WIRE_FORMAT,
};
use crate::model::{Mask, MaskDelta};
use crate::runtime::backend::Backend;
use crate::runtime::session::Session;
use crate::tensor::Tensor;
use crate::util::serde::{from_str, to_string, Deserialize, Serialize};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::time::Duration;

/// Worker identity, pacing, and fault-injection knobs.
#[derive(Clone, Debug)]
pub struct WorkerOpts {
    /// Worker name echoed in claims (diagnostics only — the protocol is
    /// membership-agnostic).
    pub id: String,
    /// `/scan` poll interval while idle.
    pub poll_ms: u64,
    /// Fault injection: exit cleanly after completing this many slabs
    /// (simulates a worker leaving mid-scan).
    pub max_slabs: Option<usize>,
    /// Fault injection: claim the N-th slab and exit WITHOUT completing it
    /// (simulates a worker dying with a lease held — the coordinator must
    /// re-issue it after the lease timeout).
    pub die_after_claim: Option<usize>,
    /// Fault injection: post every completion twice (simulates a zombie's
    /// duplicate; the coordinator must ignore the second, first write wins).
    pub duplicate_completions: bool,
}

impl Default for WorkerOpts {
    fn default() -> Self {
        WorkerOpts {
            id: format!("worker-{}", std::process::id()),
            poll_ms: 50,
            max_slabs: None,
            die_after_claim: None,
            duplicate_completions: false,
        }
    }
}

/// What a worker did before exiting (for logs and test assertions).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerSummary {
    /// Slabs completed (posted back).
    pub slabs: usize,
    /// Trials scored across those slabs.
    pub trials: usize,
    /// Distinct scan generations this worker contributed to.
    pub scans: usize,
}

fn get_json<T: Deserialize>(addr: &str, path: &str) -> Result<T> {
    let body = http_get(addr, path)?;
    let text = std::str::from_utf8(&body).context("non-UTF8 reply")?;
    from_str(text).map_err(|e| anyhow!("GET {path}: bad reply: {e}"))
}

fn post_json<Q: Serialize, R: Deserialize>(addr: &str, path: &str, req: &Q) -> Result<R> {
    let body = http_post(addr, path, to_string(req).as_bytes())?;
    let text = std::str::from_utf8(&body).context("non-UTF8 reply")?;
    from_str(text).map_err(|e| anyhow!("POST {path}: bad reply: {e}"))
}

/// Join the coordinator at `connect` and score scans until it announces
/// shutdown (or a fault-injection knob fires). The worker's backend must
/// match the coordinator's — numerics from different backends must never
/// mix inside one scan.
pub fn run_worker(connect: &str, backend: &dyn Backend, opts: &WorkerOpts) -> Result<WorkerSummary> {
    let hello: HelloDoc = get_json(connect, "/config")?;
    ensure!(
        hello.format == WIRE_FORMAT,
        "dist: coordinator speaks wire format {}, this worker {}",
        hello.format,
        WIRE_FORMAT
    );
    ensure!(
        hello.backend == backend.name(),
        "dist: coordinator runs backend {:?}, this worker {:?} — refusing to mix numerics",
        hello.backend,
        backend.name()
    );
    // Rebuild the experiment from the coordinator's config dump and prove
    // we understood every semantic key by recomputing the fingerprint.
    let mut exp = Experiment::default();
    for (k, v) in &hello.config {
        exp.apply(k, v).map_err(|e| anyhow!("dist: coordinator config: {e}"))?;
    }
    ensure!(
        exp.fingerprint() == hello.fingerprint,
        "dist: config fingerprint mismatch (coordinator {}, rebuilt {}) — version skew?",
        hello.fingerprint,
        exp.fingerprint()
    );
    ensure!(
        exp.model_key() == hello.model_key,
        "dist: model key mismatch (coordinator {:?}, rebuilt {:?})",
        hello.model_key,
        exp.model_key()
    );
    let sess = Session::new(backend, &hello.model_key)?;
    let spec = synth::by_name(&exp.dataset)
        .ok_or_else(|| anyhow!("dist: unknown dataset {:?}", exp.dataset))?;
    let (train_ds, _test_ds) = synth::generate(spec);
    let ev = Evaluator::with_opts(
        &sess,
        &train_ds,
        exp.bcd.proxy_batches,
        EvalOpts {
            cache_bytes: exp.bcd.cache_mb.saturating_mul(1 << 20),
            trial_batch: exp.bcd.trial_batch,
            verify_staged: exp.bcd.verify_staged,
            verify_lowering: exp.bcd.verify_lowering,
        },
    )?;
    crate::info!(
        "dist: {} joined {connect} (backend {}, model {}, fingerprint {})",
        opts.id,
        hello.backend,
        hello.model_key,
        hello.fingerprint
    );

    let mut summary = WorkerSummary::default();
    let mut last_scan = 0usize;
    let mut claims_granted = 0usize;
    // Params cache: consecutive polls of one sweep reuse the download.
    let mut cached_params: Option<(String, crate::runtime::backend::DeviceBuf)> = None;
    loop {
        let doc: ScanDoc = get_json(connect, "/scan")?;
        match doc.state.as_str() {
            "shutdown" => break,
            "scan" if doc.scan != last_scan => {}
            _ => {
                std::thread::sleep(Duration::from_millis(opts.poll_ms));
                continue;
            }
        }

        // Cold-start this sweep: params by digest (verified), mask, hyps.
        let stale =
            cached_params.as_ref().map(|(d, _)| *d != doc.params_digest).unwrap_or(true);
        if stale {
            let bytes = http_get(connect, &format!("/cas/{}", doc.params_digest))?;
            ensure!(
                digest_hex(&bytes) == doc.params_digest,
                "dist: params blob failed checksum after download"
            );
            ensure!(
                bytes.len() == doc.params_len * 4,
                "dist: params blob is {} bytes, expected {}",
                bytes.len(),
                doc.params_len * 4
            );
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let buf = ev.upload_params(&Tensor::new(vec![doc.params_len], data))?;
            cached_params = Some((doc.params_digest.clone(), buf));
        }
        let params = &cached_params.as_ref().expect("cached above").1;
        let mut mask = Mask::full(doc.mask_size);
        mask.apply_removal(&doc.mask_removed)?;
        let hyps: Vec<MaskDelta> =
            doc.hyps.iter().map(|ix| MaskDelta::new(ix.clone())).collect();
        ev.begin_iteration(&mask)?;

        let mut scratch: Vec<f32> = Vec::with_capacity(mask.size());
        loop {
            let reply: ClaimReply = post_json(
                connect,
                "/claim",
                &ClaimRequest { worker: opts.id.clone(), scan: doc.scan },
            )?;
            let Some(grant) = reply.slab else {
                if reply.done {
                    break;
                }
                std::thread::sleep(Duration::from_millis(reply.retry_ms as u64));
                continue;
            };
            claims_granted += 1;
            if opts.die_after_claim == Some(claims_granted) {
                // Simulated death: the lease dangles until it expires.
                crate::info!("dist: {} dying with lease {}..{} held", opts.id, grant.start, grant.start + grant.len);
                return Ok(summary);
            }
            let evals = ev.eval_trial_slab(
                params,
                &mask,
                &hyps[grant.start..grant.start + grant.len],
                grant.floor,
                &mut scratch,
            )?;
            let creq = CompleteRequest {
                worker: opts.id.clone(),
                scan: doc.scan,
                start: grant.start,
                evals: evals.iter().map(WireEval::from_eval).collect(),
            };
            let posted: CompleteReply = post_json(connect, "/complete", &creq)?;
            if opts.duplicate_completions {
                let dup: CompleteReply = post_json(connect, "/complete", &creq)?;
                if posted.accepted && !dup.duplicate {
                    bail!("dist: coordinator accepted a duplicate completion");
                }
            }
            summary.slabs += 1;
            summary.trials += grant.len;
            if opts.max_slabs == Some(summary.slabs) {
                crate::info!("dist: {} leaving after {} slabs", opts.id, summary.slabs);
                return Ok(summary);
            }
        }
        ev.flush_cache_stats();
        last_scan = doc.scan;
        summary.scans += 1;
    }
    crate::info!(
        "dist: {} exiting after {} scans / {} slabs / {} trials",
        opts.id,
        summary.scans,
        summary.slabs,
        summary.trials
    );
    Ok(summary)
}
