//! Checkpoint cache ("model zoo"): benches and examples share expensive
//! intermediate models (trained baselines, SNL reference models) instead of
//! re-training them per run.

use super::state::ModelState;
use crate::runtime::manifest::ModelInfo;
use anyhow::Result;
use std::path::{Path, PathBuf};

/// Path of the cached checkpoint for (model, tag).
pub fn cache_path(dir: &Path, info: &ModelInfo, tag: &str) -> PathBuf {
    dir.join(format!("{}__{}.cdnl", info.key, tag))
}

/// Load the checkpoint `(info, tag)` from `dir`, or `build` + save it.
///
/// The tag must encode everything the build depends on (dataset, budgets,
/// seeds) — the cache trusts it blindly.
pub fn cached<F>(dir: &Path, info: &ModelInfo, tag: &str, build: F) -> Result<ModelState>
where
    F: FnOnce() -> Result<ModelState>,
{
    let path = cache_path(dir, info, tag);
    if path.exists() {
        match ModelState::load(&path, info) {
            Ok(st) => {
                crate::info!("zoo: loaded {path:?} (budget {})", st.budget());
                return Ok(st);
            }
            Err(e) => {
                crate::warnlog!("zoo: stale checkpoint {path:?} ({e}); rebuilding");
            }
        }
    }
    let st = build()?;
    st.save(&path)?;
    crate::info!("zoo: built + saved {path:?} (budget {})", st.budget());
    Ok(st)
}
