//! Checkpoint cache ("model zoo"): benches and examples share expensive
//! intermediate models (trained baselines, SNL reference models) instead of
//! re-training them per run.
//!
//! [`cached_traced`] additionally reports *where* a state came from (path,
//! hit/built, wall time) so the pipeline can record stage provenance into
//! the run-store manifest ([`crate::runstore`]).

use super::state::ModelState;
use crate::runtime::manifest::ModelInfo;
use anyhow::Result;
use std::path::{Path, PathBuf};

/// Provenance of one zoo access: where the checkpoint lives and whether it
/// was served from cache or built by the closure.
#[derive(Clone, Debug)]
pub struct CacheInfo {
    pub path: PathBuf,
    /// True when the checkpoint was loaded, false when it was built+saved.
    pub hit: bool,
    /// Wall-clock of the access (load or build+save) in seconds.
    pub wall_secs: f64,
}

/// Path of the cached checkpoint for (model, tag).
pub fn cache_path(dir: &Path, info: &ModelInfo, tag: &str) -> PathBuf {
    dir.join(format!("{}__{}.cdnl", info.key, tag))
}

/// Load the checkpoint `(info, tag)` from `dir`, or `build` + save it.
///
/// The tag must encode everything the build depends on (dataset, budgets,
/// seeds) — the cache trusts it blindly.
pub fn cached<F>(dir: &Path, info: &ModelInfo, tag: &str, build: F) -> Result<ModelState>
where
    F: FnOnce() -> Result<ModelState>,
{
    cached_traced(dir, info, tag, build).map(|(st, _)| st)
}

/// [`cached`] with provenance: returns the state plus a [`CacheInfo`]
/// describing how it was obtained.
pub fn cached_traced<F>(
    dir: &Path,
    info: &ModelInfo,
    tag: &str,
    build: F,
) -> Result<(ModelState, CacheInfo)>
where
    F: FnOnce() -> Result<ModelState>,
{
    let path = cache_path(dir, info, tag);
    let t0 = std::time::Instant::now();
    if path.exists() {
        match ModelState::load(&path, info) {
            Ok(st) => {
                crate::info!("zoo: loaded {path:?} (budget {})", st.budget());
                let wall_secs = t0.elapsed().as_secs_f64();
                return Ok((st, CacheInfo { path, hit: true, wall_secs }));
            }
            Err(e) => {
                crate::warnlog!("zoo: stale checkpoint {path:?} ({e}); rebuilding");
            }
        }
    }
    let st = build()?;
    st.save(&path)?;
    crate::info!("zoo: built + saved {path:?} (budget {})", st.budget());
    let wall_secs = t0.elapsed().as_secs_f64();
    Ok((st, CacheInfo { path, hit: false, wall_secs }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Backend, RefBackend};

    /// Deprecated model keys (`resnet_*`, `wrn_*`) alias to the renamed MLP
    /// models (`mlp_*`, `mlpw_*`). The zoo keys checkpoints off `info.key`,
    /// so a lookup through either name must land on the SAME cache file —
    /// otherwise an alias-addressed run would retrain a model the canonical
    /// name already cached.
    #[test]
    fn alias_and_canonical_keys_share_cache_path() {
        let be = RefBackend::standard();
        let via_alias = be.model("resnet_16x16_c10").unwrap().clone();
        let canonical = be.model("mlp_16x16_c10").unwrap().clone();
        assert_eq!(via_alias.key, "mlp_16x16_c10");
        let dir = Path::new("/tmp/zoo");
        assert_eq!(
            cache_path(dir, &via_alias, "base"),
            cache_path(dir, &canonical, "base"),
        );
        assert_eq!(
            cache_path(dir, &canonical, "base"),
            Path::new("/tmp/zoo/mlp_16x16_c10__base.cdnl")
        );
        // Distinct tags keep distinct checkpoints.
        assert_ne!(cache_path(dir, &canonical, "base"), cache_path(dir, &canonical, "snl"));
        // Conv models key the same way (no alias involved).
        let conv = be.model("resnet18_16x16_c10").unwrap();
        assert_eq!(
            cache_path(dir, conv, "base"),
            Path::new("/tmp/zoo/resnet18_16x16_c10__base.cdnl")
        );
    }
}
