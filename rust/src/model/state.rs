//! Full model state (params + momentum + mask) and checkpoint I/O.
//!
//! Checkpoints are a tiny self-describing binary format (`.cdnl`): magic,
//! model key, named f32 sections. Hand-rolled because the vendor set has no
//! serde — DESIGN.md §0.

use super::mask::Mask;
use crate::runtime::manifest::ModelInfo;
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"CDNLCKP1";

/// Everything the coordinator owns about one network instance.
#[derive(Clone, Debug)]
pub struct ModelState {
    pub model_key: String,
    pub params: Tensor,
    pub mom: Tensor,
    pub mask: Mask,
}

impl ModelState {
    /// Fresh state: zero momentum, full-ReLU mask, params from `init`.
    pub fn new(info: &ModelInfo, params: Tensor) -> ModelState {
        assert_eq!(params.len(), info.param_size, "param vector size mismatch");
        ModelState {
            model_key: info.key.clone(),
            mom: Tensor::zeros(vec![info.param_size]),
            mask: Mask::full(info.mask_size),
            params,
        }
    }

    /// Reset optimizer momentum (done between training phases: the paper
    /// restarts SGD with a fresh cosine schedule per finetune run).
    pub fn reset_momentum(&mut self) {
        self.mom = Tensor::zeros(vec![self.mom.len()]);
    }

    /// Current ReLU budget `||m||_0`.
    pub fn budget(&self) -> usize {
        self.mask.count()
    }

    // ---- checkpoint I/O ---------------------------------------------------

    /// Serialize to `<path>` (creates parent dirs).
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(
            std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?,
        );
        f.write_all(MAGIC)?;
        write_str(&mut f, &self.model_key)?;
        write_f32s(&mut f, &self.params.data)?;
        write_f32s(&mut f, &self.mom.data)?;
        write_f32s(&mut f, self.mask.dense())?;
        Ok(())
    }

    /// Load and validate against the manifest `info`.
    pub fn load(path: &Path, info: &ModelInfo) -> Result<ModelState> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?}: not a CDNL checkpoint");
        }
        let key = read_str(&mut f)?;
        if key != info.key {
            bail!("{path:?}: checkpoint is for model {key:?}, expected {:?}", info.key);
        }
        let params = read_f32s(&mut f)?;
        let mom = read_f32s(&mut f)?;
        let mask = read_f32s(&mut f)?;
        if params.len() != info.param_size || mask.len() != info.mask_size {
            bail!(
                "{path:?}: sizes {}/{} do not match manifest {}/{}",
                params.len(),
                mask.len(),
                info.param_size,
                info.mask_size
            );
        }
        Ok(ModelState {
            model_key: key,
            params: Tensor::new(vec![params.len()], params),
            mom: Tensor::new(vec![mom.len()], mom),
            mask: Mask::from_dense(&mask),
        })
    }
}

fn write_str<W: Write>(w: &mut W, s: &str) -> Result<()> {
    w.write_all(&(s.len() as u32).to_le_bytes())?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

fn read_str<R: Read>(r: &mut R) -> Result<String> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let mut buf = vec![0u8; u32::from_le_bytes(len) as usize];
    r.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> Result<()> {
    w.write_all(&(xs.len() as u64).to_le_bytes())?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f32s<R: Read>(r: &mut R) -> Result<Vec<f32>> {
    let mut len = [0u8; 8];
    r.read_exact(&mut len)?;
    let n = u64::from_le_bytes(len) as usize;
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::PackEntry;

    fn fake_info() -> ModelInfo {
        ModelInfo {
            key: "m1".into(),
            backbone: "resnet".into(),
            num_classes: 2,
            image_size: 4,
            channels: 3,
            poly: false,
            param_size: 7,
            mask_size: 5,
            mask_layers: vec![PackEntry {
                name: "a".into(),
                shape: vec![5],
                offset: 0,
                size: 5,
            }],
            param_entries: vec![],
            artifacts: Default::default(),
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let info = fake_info();
        let mut st = ModelState::new(&info, Tensor::new(vec![7], (0..7).map(|i| i as f32).collect()));
        st.mask.remove(3).unwrap();
        st.mom.data[0] = 2.5;
        let path = std::env::temp_dir().join("cdnl_state_test/ck.cdnl");
        st.save(&path).unwrap();
        let back = ModelState::load(&path, &info).unwrap();
        assert_eq!(back.params, st.params);
        assert_eq!(back.mom.data[0], 2.5);
        assert_eq!(back.budget(), 4);
        assert!(!back.mask.is_present(3));
    }

    #[test]
    fn conv_model_roundtrip() {
        // Checkpoints for the conv topologies (DESIGN.md §12) exercise the
        // real manifest sizes (param vector includes BN running stats) and
        // the per-channel mask layout.
        use crate::runtime::{Backend, RefBackend};
        let be = RefBackend::standard();
        let info = be.model("resnet18_16x16_c10").unwrap().clone();
        let mut st = ModelState::new(&info, Tensor::zeros(vec![info.param_size]));
        st.mask.remove(487).unwrap(); // last per-channel mask slot
        let path = std::env::temp_dir().join("cdnl_state_test/conv.cdnl");
        st.save(&path).unwrap();
        let back = ModelState::load(&path, &info).unwrap();
        assert_eq!(back.params.len(), info.param_size);
        assert_eq!(back.budget(), info.mask_size - 1);
        assert!(!back.mask.is_present(487));
    }

    #[test]
    fn wrong_model_key_rejected() {
        let info = fake_info();
        let st = ModelState::new(&info, Tensor::zeros(vec![7]));
        let path = std::env::temp_dir().join("cdnl_state_test/ck2.cdnl");
        st.save(&path).unwrap();
        let mut other = fake_info();
        other.key = "different".into();
        assert!(ModelState::load(&path, &other).is_err());
    }

    #[test]
    fn garbage_file_rejected() {
        let path = std::env::temp_dir().join("cdnl_state_test/garbage.cdnl");
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(ModelState::load(&path, &fake_info()).is_err());
    }
}
