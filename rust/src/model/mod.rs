//! Model-side state owned by the coordinator: binary ReLU masks, parameter
//! bundles, checkpoints, and the model zoo cache.
//!
//! The paper's object of study is the binary mask `m` over all ReLU
//! locations of a network ([`mask::Mask`]); everything else (weights,
//! momentum) is an opaque flat vector whose layout is dictated by the
//! artifact manifest.

pub mod mask;
pub mod state;
pub mod zoo;

pub use mask::{DeltaUndo, Mask, MaskDelta};
pub use state::ModelState;
