//! Binary ReLU masks — the paper's optimization variable `m`.
//!
//! A [`Mask`] is a flat 0/1 vector over every ReLU location of a model
//! (layout given by the manifest's `mask_layers` table), plus a maintained
//! *present set* so the BCD trial sampler draws `DRC` distinct present
//! ReLUs in O(DRC) with no per-trial scan of the full vector (§Perf).

use crate::runtime::manifest::ModelInfo;
use crate::tensor::Tensor;
use crate::util::prng::Rng;
use anyhow::{bail, Result};

/// Binary mask over all ReLU locations with O(1) removal and O(k) sampling.
#[derive(Clone, Debug)]
pub struct Mask {
    /// Dense 0.0/1.0 values, ready to ship to the artifact boundary.
    data: Vec<f32>,
    /// Flat indices currently 1, in arbitrary order.
    present: Vec<u32>,
    /// `pos[i]` = index of `i` inside `present` (u32::MAX when absent).
    pos: Vec<u32>,
}

impl Mask {
    /// All-ones mask (the full-ReLU network).
    pub fn full(size: usize) -> Mask {
        Mask {
            data: vec![1.0; size],
            present: (0..size as u32).collect(),
            pos: (0..size as u32).collect(),
        }
    }

    /// Mask from dense 0/1 values (e.g. a thresholded SNL alpha vector).
    pub fn from_dense(values: &[f32]) -> Mask {
        let mut m = Mask {
            data: vec![0.0; values.len()],
            present: Vec::new(),
            pos: vec![u32::MAX; values.len()],
        };
        for (i, &v) in values.iter().enumerate() {
            if v != 0.0 {
                m.data[i] = 1.0;
                m.pos[i] = m.present.len() as u32;
                m.present.push(i as u32);
            }
        }
        m
    }

    /// Total ReLU locations (present + removed).
    pub fn size(&self) -> usize {
        self.data.len()
    }

    /// `||m||_0` — the current ReLU budget.
    pub fn count(&self) -> usize {
        self.present.len()
    }

    pub fn is_present(&self, i: usize) -> bool {
        self.pos[i] != u32::MAX
    }

    /// Dense values (a `[M]` f32 view for the artifact boundary).
    pub fn dense(&self) -> &[f32] {
        &self.data
    }

    /// Copy out as a host tensor.
    pub fn to_tensor(&self) -> Tensor {
        Tensor::new(vec![self.data.len()], self.data.clone())
    }

    /// Remove one present ReLU. Returns an error if already removed —
    /// the BCD invariant is that ReLUs are never revisited.
    pub fn remove(&mut self, i: usize) -> Result<()> {
        let p = self.pos[i];
        if p == u32::MAX {
            bail!("mask: index {i} already removed");
        }
        let last = *self.present.last().unwrap();
        self.present.swap_remove(p as usize);
        if (p as usize) < self.present.len() {
            self.pos[last as usize] = p;
        }
        self.pos[i] = u32::MAX;
        self.data[i] = 0.0;
        Ok(())
    }

    /// Sample `k` distinct *present* flat indices (the BCD trial draw).
    pub fn sample_present(&self, rng: &mut Rng, k: usize) -> Vec<usize> {
        assert!(
            k <= self.present.len(),
            "sample_present: k={k} > present={}",
            self.present.len()
        );
        rng.sample_indices(self.present.len(), k)
            .into_iter()
            .map(|j| self.present[j] as usize)
            .collect()
    }

    /// Dense copy with `removed` additionally zeroed (a trial hypothesis).
    /// Does not mutate `self`; the caller reuses `scratch` across trials so
    /// the hot loop performs no allocation (§Perf).
    pub fn hypothesis_into(&self, removed: &[usize], scratch: &mut Vec<f32>) {
        scratch.clear();
        scratch.extend_from_slice(&self.data);
        for &i in removed {
            debug_assert!(self.is_present(i), "hypothesis removes absent ReLU {i}");
            scratch[i] = 0.0;
        }
    }

    /// Apply an accepted trial: permanently remove all `removed` indices.
    pub fn apply_removal(&mut self, removed: &[usize]) -> Result<()> {
        for &i in removed {
            self.remove(i)?;
        }
        Ok(())
    }

    /// `||m_self ⊙ m_other||_0 / ||m_self||_0` — the paper's (asymmetric)
    /// IoU score between a smaller-budget mask and a larger one (Fig. 6).
    pub fn containment(&self, other: &Mask) -> f64 {
        assert_eq!(self.size(), other.size());
        if self.count() == 0 {
            return 1.0;
        }
        let inter = self
            .present
            .iter()
            .filter(|&&i| other.is_present(i as usize))
            .count();
        inter as f64 / self.count() as f64
    }

    /// Per-layer present-ReLU counts (Fig. 7 distributions).
    pub fn layer_histogram(&self, info: &ModelInfo) -> Vec<usize> {
        let mut h = vec![0usize; info.mask_layers.len()];
        for &i in &self.present {
            h[info.layer_of(i as usize)] += 1;
        }
        h
    }

    /// Remove every ReLU of layer `l` (DeepReDuce layer-granularity action).
    pub fn remove_layer(&mut self, info: &ModelInfo, l: usize) -> usize {
        let e = &info.mask_layers[l];
        let mut removed = 0;
        for i in e.offset..e.offset + e.size {
            if self.is_present(i) {
                self.remove(i).unwrap();
                removed += 1;
            }
        }
        removed
    }

    /// Internal consistency check (used by tests and debug assertions).
    pub fn check_invariants(&self) -> Result<()> {
        let mut seen = vec![false; self.size()];
        for (p, &i) in self.present.iter().enumerate() {
            let i = i as usize;
            if seen[i] {
                bail!("present contains {i} twice");
            }
            seen[i] = true;
            if self.pos[i] != p as u32 {
                bail!("pos[{i}]={} but present[{p}]={i}", self.pos[i]);
            }
            if self.data[i] != 1.0 {
                bail!("present index {i} has dense value {}", self.data[i]);
            }
        }
        for i in 0..self.size() {
            if !seen[i] {
                if self.pos[i] != u32::MAX {
                    bail!("absent index {i} has pos {}", self.pos[i]);
                }
                if self.data[i] != 0.0 {
                    bail!("absent index {i} has dense value {}", self.data[i]);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_mask_counts() {
        let m = Mask::full(10);
        assert_eq!(m.count(), 10);
        assert_eq!(m.size(), 10);
        assert!(m.is_present(9));
        m.check_invariants().unwrap();
    }

    #[test]
    fn remove_updates_all_views() {
        let mut m = Mask::full(5);
        m.remove(2).unwrap();
        assert_eq!(m.count(), 4);
        assert!(!m.is_present(2));
        assert_eq!(m.dense()[2], 0.0);
        assert!(m.remove(2).is_err(), "double removal must fail");
        m.check_invariants().unwrap();
    }

    #[test]
    fn from_dense_roundtrip() {
        let m = Mask::from_dense(&[1.0, 0.0, 1.0, 0.0]);
        assert_eq!(m.count(), 2);
        assert!(m.is_present(0) && m.is_present(2));
        m.check_invariants().unwrap();
    }

    #[test]
    fn hypothesis_does_not_mutate() {
        let m = Mask::full(6);
        let mut scratch = Vec::new();
        m.hypothesis_into(&[1, 4], &mut scratch);
        assert_eq!(scratch, vec![1.0, 0.0, 1.0, 1.0, 0.0, 1.0]);
        assert_eq!(m.count(), 6);
    }

    #[test]
    fn sampling_only_present() {
        let mut rng = Rng::new(1);
        let mut m = Mask::full(50);
        for i in 0..25 {
            m.remove(i * 2).unwrap(); // remove evens
        }
        for _ in 0..100 {
            for i in m.sample_present(&mut rng, 10) {
                assert!(i % 2 == 1, "sampled removed index {i}");
            }
        }
    }

    #[test]
    fn containment_score() {
        let big = Mask::full(8);
        let mut small = Mask::full(8);
        small.apply_removal(&[0, 1]).unwrap();
        assert_eq!(small.containment(&big), 1.0);
        assert_eq!(big.containment(&small), 6.0 / 8.0);
    }

    #[test]
    fn mass_removal_invariants_hold() {
        let mut rng = Rng::new(3);
        let mut m = Mask::full(200);
        while m.count() > 50 {
            let r = m.sample_present(&mut rng, 10);
            m.apply_removal(&r).unwrap();
            m.check_invariants().unwrap();
        }
        assert_eq!(m.count(), 50);
    }
}
