//! Binary ReLU masks — the paper's optimization variable `m`.
//!
//! A [`Mask`] is a flat 0/1 vector over every ReLU location of a model
//! (layout given by the manifest's `mask_layers` table), plus a maintained
//! *present set* so the BCD trial sampler draws `DRC` distinct present
//! ReLUs in O(DRC) with no per-trial scan of the full vector (§Perf).

use crate::runtime::manifest::ModelInfo;
use crate::tensor::Tensor;
use crate::util::prng::Rng;
use anyhow::{bail, Result};

/// Binary mask over all ReLU locations with O(1) removal and O(k) sampling.
#[derive(Clone, Debug)]
pub struct Mask {
    /// Dense 0.0/1.0 values, ready to ship to the artifact boundary.
    data: Vec<f32>,
    /// Flat indices currently 1, in arbitrary order.
    present: Vec<u32>,
    /// `pos[i]` = index of `i` inside `present` (u32::MAX when absent).
    pos: Vec<u32>,
}

impl Mask {
    /// All-ones mask (the full-ReLU network).
    pub fn full(size: usize) -> Mask {
        Mask {
            data: vec![1.0; size],
            present: (0..size as u32).collect(),
            pos: (0..size as u32).collect(),
        }
    }

    /// Mask from dense 0/1 values (e.g. a thresholded SNL alpha vector).
    pub fn from_dense(values: &[f32]) -> Mask {
        let mut m = Mask {
            data: vec![0.0; values.len()],
            present: Vec::new(),
            pos: vec![u32::MAX; values.len()],
        };
        for (i, &v) in values.iter().enumerate() {
            if v != 0.0 {
                m.data[i] = 1.0;
                m.pos[i] = m.present.len() as u32;
                m.present.push(i as u32);
            }
        }
        m
    }

    /// Total ReLU locations (present + removed).
    pub fn size(&self) -> usize {
        self.data.len()
    }

    /// `||m||_0` — the current ReLU budget.
    pub fn count(&self) -> usize {
        self.present.len()
    }

    pub fn is_present(&self, i: usize) -> bool {
        self.pos[i] != u32::MAX
    }

    /// Dense values (a `[M]` f32 view for the artifact boundary).
    pub fn dense(&self) -> &[f32] {
        &self.data
    }

    /// Copy out as a host tensor.
    pub fn to_tensor(&self) -> Tensor {
        Tensor::new(vec![self.data.len()], self.data.clone())
    }

    /// Remove one present ReLU. Returns an error if already removed —
    /// the BCD invariant is that ReLUs are never revisited.
    pub fn remove(&mut self, i: usize) -> Result<()> {
        let p = self.pos[i];
        if p == u32::MAX {
            bail!("mask: index {i} already removed");
        }
        let last = *self.present.last().unwrap();
        self.present.swap_remove(p as usize);
        if (p as usize) < self.present.len() {
            self.pos[last as usize] = p;
        }
        self.pos[i] = u32::MAX;
        self.data[i] = 0.0;
        Ok(())
    }

    /// Sample `k` distinct *present* flat indices (the BCD trial draw).
    pub fn sample_present(&self, rng: &mut Rng, k: usize) -> Vec<usize> {
        assert!(
            k <= self.present.len(),
            "sample_present: k={k} > present={}",
            self.present.len()
        );
        rng.sample_indices(self.present.len(), k)
            .into_iter()
            .map(|j| self.present[j] as usize)
            .collect()
    }

    /// Dense copy with `removed` additionally zeroed (a trial hypothesis).
    /// Does not mutate `self`; the caller reuses `scratch` across trials so
    /// the hot loop performs no allocation (§Perf).
    pub fn hypothesis_into(&self, removed: &[usize], scratch: &mut Vec<f32>) {
        scratch.clear();
        scratch.extend_from_slice(&self.data);
        for &i in removed {
            debug_assert!(self.is_present(i), "hypothesis removes absent ReLU {i}");
            scratch[i] = 0.0;
        }
    }

    /// Apply an accepted trial: permanently remove all `removed` indices.
    pub fn apply_removal(&mut self, removed: &[usize]) -> Result<()> {
        for &i in removed {
            self.remove(i)?;
        }
        Ok(())
    }

    /// `||m_self ⊙ m_other||_0 / ||m_self||_0` — the paper's (asymmetric)
    /// IoU score between a smaller-budget mask and a larger one (Fig. 6).
    pub fn containment(&self, other: &Mask) -> f64 {
        assert_eq!(self.size(), other.size());
        if self.count() == 0 {
            return 1.0;
        }
        let inter = self
            .present
            .iter()
            .filter(|&&i| other.is_present(i as usize))
            .count();
        inter as f64 / self.count() as f64
    }

    /// Per-layer present-ReLU counts (Fig. 7 distributions).
    pub fn layer_histogram(&self, info: &ModelInfo) -> Vec<usize> {
        let mut h = vec![0usize; info.mask_layers.len()];
        for &i in &self.present {
            h[info.layer_of(i as usize)] += 1;
        }
        h
    }

    /// Remove every ReLU of layer `l` (DeepReDuce layer-granularity action).
    pub fn remove_layer(&mut self, info: &ModelInfo, l: usize) -> usize {
        let e = &info.mask_layers[l];
        let mut removed = 0;
        for i in e.offset..e.offset + e.size {
            if self.is_present(i) {
                self.remove(i).unwrap();
                removed += 1;
            }
        }
        removed
    }

    /// Apply a sparse [`MaskDelta`], returning the undo token that lets
    /// [`Self::revert_delta`] restore this mask *exactly* — dense values,
    /// present-set order, and position index all return to their pre-apply
    /// state, so RNG-driven sampling after a revert replays identically.
    pub fn apply_delta(&mut self, delta: &MaskDelta) -> Result<DeltaUndo> {
        let mut positions = Vec::with_capacity(delta.removed.len());
        for &i in &delta.removed {
            if self.pos[i] == u32::MAX {
                // Roll back what we already removed before reporting.
                let partial = DeltaUndo { positions };
                let done = partial.positions.len();
                self.undo_removals(&delta.removed[..done], partial)?;
                bail!("mask delta: index {i} already removed");
            }
            positions.push(self.pos[i]);
            self.remove(i)?;
        }
        Ok(DeltaUndo { positions })
    }

    /// Revert a previous [`Self::apply_delta`] with its undo token. The
    /// token must come from the matching apply on this mask, with no other
    /// mutations in between.
    pub fn revert_delta(&mut self, delta: &MaskDelta, undo: DeltaUndo) -> Result<()> {
        if undo.positions.len() != delta.removed.len() {
            bail!(
                "mask delta: undo token covers {} removals, delta has {}",
                undo.positions.len(),
                delta.removed.len()
            );
        }
        self.undo_removals(&delta.removed, undo)
    }

    /// Undo `removed[..]` (each paired with its recorded position), newest
    /// first — the exact inverse of the swap-removes [`Self::remove`] did.
    fn undo_removals(&mut self, removed: &[usize], undo: DeltaUndo) -> Result<()> {
        for (&i, &p) in removed.iter().zip(&undo.positions).rev() {
            if self.pos[i] != u32::MAX {
                bail!("mask delta: cannot restore {i}: still present");
            }
            let p = p as usize;
            if p > self.present.len() {
                bail!("mask delta: undo position {p} out of range");
            }
            if p == self.present.len() {
                // The removal popped `i` off the tail directly.
                self.present.push(i as u32);
            } else {
                // The removal moved the then-last element into slot `p`;
                // send it back to the tail and reseat `i`.
                let moved = self.present[p];
                self.present.push(moved);
                self.pos[moved as usize] = (self.present.len() - 1) as u32;
                self.present[p] = i as u32;
            }
            self.pos[i] = p as u32;
            self.data[i] = 1.0;
        }
        Ok(())
    }

    /// Internal consistency check (used by tests and debug assertions).
    pub fn check_invariants(&self) -> Result<()> {
        let mut seen = vec![false; self.size()];
        for (p, &i) in self.present.iter().enumerate() {
            let i = i as usize;
            if seen[i] {
                bail!("present contains {i} twice");
            }
            seen[i] = true;
            if self.pos[i] != p as u32 {
                bail!("pos[{i}]={} but present[{p}]={i}", self.pos[i]);
            }
            if self.data[i] != 1.0 {
                bail!("present index {i} has dense value {}", self.data[i]);
            }
        }
        for i in 0..self.size() {
            if !seen[i] {
                if self.pos[i] != u32::MAX {
                    bail!("absent index {i} has pos {}", self.pos[i]);
                }
                if self.data[i] != 0.0 {
                    bail!("absent index {i} has dense value {}", self.data[i]);
                }
            }
        }
        Ok(())
    }
}

/// A sparse difference against an iteration's base mask: the (sorted,
/// distinct) flat ReLU indices a trial hypothesis removes.
///
/// The staged-execution hot path (DESIGN.md §8) routes on this instead of a
/// dense hypothesis vector: [`Self::first_dirty_layer`] says where the
/// hypothesis starts to differ from the base mask, so every layer before it
/// can be served from the prefix-activation cache.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MaskDelta {
    /// Removed flat indices, ascending and distinct.
    removed: Vec<usize>,
}

/// Opaque undo token returned by [`Mask::apply_delta`]: the present-set
/// position of each removed index at removal time.
#[derive(Clone, Debug)]
pub struct DeltaUndo {
    positions: Vec<u32>,
}

impl MaskDelta {
    /// Build from removal indices (sorted and deduplicated here).
    pub fn new(mut removed: Vec<usize>) -> MaskDelta {
        removed.sort_unstable();
        removed.dedup();
        MaskDelta { removed }
    }

    /// The removal indices, ascending.
    pub fn indices(&self) -> &[usize] {
        &self.removed
    }

    pub fn len(&self) -> usize {
        self.removed.len()
    }

    pub fn is_empty(&self) -> bool {
        self.removed.is_empty()
    }

    /// Index of the first mask layer this delta touches, per the manifest's
    /// `mask_layers` table. Layers are offset-ordered and the indices are
    /// sorted, so this is `layer_of` the smallest removed index. An empty
    /// delta returns `mask_layers.len()` ("dirty past the last layer"), the
    /// identity under prefix reuse: everything can be served from cache.
    pub fn first_dirty_layer(&self, info: &ModelInfo) -> usize {
        match self.removed.first() {
            Some(&i) => info.layer_of(i),
            None => info.mask_layers.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_mask_counts() {
        let m = Mask::full(10);
        assert_eq!(m.count(), 10);
        assert_eq!(m.size(), 10);
        assert!(m.is_present(9));
        m.check_invariants().unwrap();
    }

    #[test]
    fn remove_updates_all_views() {
        let mut m = Mask::full(5);
        m.remove(2).unwrap();
        assert_eq!(m.count(), 4);
        assert!(!m.is_present(2));
        assert_eq!(m.dense()[2], 0.0);
        assert!(m.remove(2).is_err(), "double removal must fail");
        m.check_invariants().unwrap();
    }

    #[test]
    fn from_dense_roundtrip() {
        let m = Mask::from_dense(&[1.0, 0.0, 1.0, 0.0]);
        assert_eq!(m.count(), 2);
        assert!(m.is_present(0) && m.is_present(2));
        m.check_invariants().unwrap();
    }

    #[test]
    fn hypothesis_does_not_mutate() {
        let m = Mask::full(6);
        let mut scratch = Vec::new();
        m.hypothesis_into(&[1, 4], &mut scratch);
        assert_eq!(scratch, vec![1.0, 0.0, 1.0, 1.0, 0.0, 1.0]);
        assert_eq!(m.count(), 6);
    }

    #[test]
    fn sampling_only_present() {
        let mut rng = Rng::new(1);
        let mut m = Mask::full(50);
        for i in 0..25 {
            m.remove(i * 2).unwrap(); // remove evens
        }
        for _ in 0..100 {
            for i in m.sample_present(&mut rng, 10) {
                assert!(i % 2 == 1, "sampled removed index {i}");
            }
        }
    }

    #[test]
    fn containment_score() {
        let big = Mask::full(8);
        let mut small = Mask::full(8);
        small.apply_removal(&[0, 1]).unwrap();
        assert_eq!(small.containment(&big), 1.0);
        assert_eq!(big.containment(&small), 6.0 / 8.0);
    }

    #[test]
    fn delta_apply_revert_restores_exactly() {
        let mut rng = Rng::new(11);
        let mut base = Mask::full(40);
        for i in 0..10 {
            base.remove(i * 3).unwrap(); // non-trivial present ordering
        }
        let removed = base.sample_present(&mut rng, 7);
        let delta = MaskDelta::new(removed);
        let (data0, present0, pos0) = (base.data.clone(), base.present.clone(), base.pos.clone());
        let undo = base.apply_delta(&delta).unwrap();
        assert_eq!(base.count(), present0.len() - 7);
        for &i in delta.indices() {
            assert!(!base.is_present(i));
        }
        base.check_invariants().unwrap();
        base.revert_delta(&delta, undo).unwrap();
        // Exact restoration: dense values, present ORDER, and pos index.
        assert_eq!(base.data, data0);
        assert_eq!(base.present, present0);
        assert_eq!(base.pos, pos0);
    }

    #[test]
    fn delta_rejects_absent_index_and_rolls_back() {
        let mut m = Mask::full(10);
        m.remove(4).unwrap();
        let snapshot = m.present.clone();
        // 4 is already removed: apply must fail and leave m untouched.
        let delta = MaskDelta::new(vec![2, 4, 7]);
        assert!(m.apply_delta(&delta).is_err());
        assert_eq!(m.present, snapshot, "failed apply must roll back");
        m.check_invariants().unwrap();
        // Mismatched undo token is rejected.
        let d2 = MaskDelta::new(vec![2]);
        let undo = m.apply_delta(&d2).unwrap();
        assert!(m.revert_delta(&MaskDelta::new(vec![2, 7]), undo.clone()).is_err());
        m.revert_delta(&d2, undo).unwrap();
        m.check_invariants().unwrap();
    }

    #[test]
    fn delta_first_dirty_layer() {
        use crate::runtime::manifest::PackEntry;
        let info = ModelInfo {
            key: "t".into(),
            backbone: "resnet".into(),
            num_classes: 2,
            image_size: 4,
            channels: 3,
            poly: false,
            param_size: 1,
            mask_size: 22,
            mask_layers: vec![
                PackEntry { name: "a".into(), shape: vec![16], offset: 0, size: 16 },
                PackEntry { name: "b".into(), shape: vec![6], offset: 16, size: 6 },
            ],
            param_entries: vec![],
            artifacts: Default::default(),
        };
        assert_eq!(MaskDelta::new(vec![17, 20]).first_dirty_layer(&info), 1);
        assert_eq!(MaskDelta::new(vec![20, 3]).first_dirty_layer(&info), 0);
        assert_eq!(MaskDelta::new(vec![15]).first_dirty_layer(&info), 0);
        assert_eq!(MaskDelta::new(vec![16]).first_dirty_layer(&info), 1);
        assert_eq!(MaskDelta::new(vec![]).first_dirty_layer(&info), 2);
        // new() sorts and dedups.
        let d = MaskDelta::new(vec![9, 2, 9, 5]);
        assert_eq!(d.indices(), &[2, 5, 9]);
        assert_eq!(d.len(), 3);
        assert!(!d.is_empty());
    }

    #[test]
    fn mass_removal_invariants_hold() {
        let mut rng = Rng::new(3);
        let mut m = Mask::full(200);
        while m.count() > 50 {
            let r = m.sample_present(&mut rng, 10);
            m.apply_removal(&r).unwrap();
            m.check_invariants().unwrap();
        }
        assert_eq!(m.count(), 50);
    }
}
