//! Experiment configuration: the paper's hyperparameters, scaled presets,
//! and a `key=value` config-file / CLI overlay system.
//!
//! Paper hyperparameters (ResNet18): DRC=100, ADT=0.3%, RT=50, finetune 20
//! epochs (5 for TinyImageNet), SGD lr 1e-3 cosine. WRN uses ADT=0.1, Adam
//! 3.5e-5 (we substitute SGD-momentum at our scale — DESIGN.md §0).
//! Budgets scale by ~1/29 (the ReLU-count ratio of the scaled backbones).

use crate::util::cli::Args;
use std::collections::BTreeMap;

/// Schedule for the Delta ReLU Count across BCD iterations.
///
/// The paper uses a constant DRC and names a DRC *scheduler* as the natural
/// extension ("a straightforward extension of our method would be to
/// implement a scheduler for the ReLU decrease parameter"); both decaying
/// variants are implemented here and ablated by `bench_ablations`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DrcSchedule {
    /// The paper's setting: the same DRC every iteration.
    Constant,
    /// Linear decay from `drc` down to `drc_final` over the whole run —
    /// coarse steps far from the target, fine steps near it.
    Linear,
    /// Cosine decay from `drc` to `drc_final` (smooth version of Linear).
    Cosine,
}

impl DrcSchedule {
    pub fn parse(s: &str) -> Option<DrcSchedule> {
        match s {
            "constant" => Some(DrcSchedule::Constant),
            "linear" => Some(DrcSchedule::Linear),
            "cosine" => Some(DrcSchedule::Cosine),
            _ => None,
        }
    }

    /// Canonical name, the inverse of [`Self::parse`] (config dumps).
    pub fn name(&self) -> &'static str {
        match self {
            DrcSchedule::Constant => "constant",
            DrcSchedule::Linear => "linear",
            DrcSchedule::Cosine => "cosine",
        }
    }

    /// DRC for the current state: `done` of `total` ReLUs already removed.
    pub fn drc_at(&self, drc0: usize, drc_final: usize, done: usize, total: usize) -> usize {
        let t = if total == 0 { 0.0 } else { done as f64 / total as f64 };
        let lo = drc_final.min(drc0) as f64;
        let hi = drc0 as f64;
        let v = match self {
            DrcSchedule::Constant => hi,
            DrcSchedule::Linear => hi + (lo - hi) * t,
            DrcSchedule::Cosine => lo + (hi - lo) * 0.5 * (1.0 + (std::f64::consts::PI * t).cos()),
        };
        (v.round() as usize).max(1)
    }
}

/// Coordinate-block granularity for the trial sampler.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Granularity {
    /// The paper's setting: each coordinate is one ReLU (pixel) location.
    Pixel,
    /// Whole channels (H*W ReLUs at once) — DeepReDuce-style coarse blocks
    /// inside the BCD loop; ablated by `bench_ablations`.
    Channel,
}

impl Granularity {
    pub fn parse(s: &str) -> Option<Granularity> {
        match s {
            "pixel" => Some(Granularity::Pixel),
            "channel" => Some(Granularity::Channel),
            _ => None,
        }
    }

    /// Canonical name, the inverse of [`Self::parse`] (config dumps).
    pub fn name(&self) -> &'static str {
        match self {
            Granularity::Pixel => "pixel",
            Granularity::Channel => "channel",
        }
    }
}

/// Hyperparameters of the BCD optimizer (Algorithm 2).
#[derive(Clone, Debug, PartialEq)]
pub struct BcdConfig {
    /// Delta ReLU Count: ReLUs removed per coordinate-descent iteration
    /// (the schedule's starting value).
    pub drc: usize,
    /// Final DRC for decaying schedules (ignored by Constant).
    pub drc_final: usize,
    /// DRC schedule across the run.
    pub drc_schedule: DrcSchedule,
    /// Trial-block granularity.
    pub granularity: Granularity,
    /// Random Trials per iteration (upper bound).
    pub rt: usize,
    /// Accuracy Degradation Tolerance, in accuracy *percent* (0.3 = 0.3%).
    pub adt: f64,
    /// Finetune steps after each accepted reduction ("epochs" at paper
    /// scale; steps at ours).
    pub finetune_steps: usize,
    /// Initial finetune learning rate (cosine-annealed per finetune run).
    pub finetune_lr: f32,
    /// Number of train batches used as the accuracy proxy in trials.
    pub proxy_batches: usize,
    /// RNG seed for trial sampling.
    pub seed: u64,
    /// Worker threads for the parallel trial scan; 0 = available
    /// parallelism. The scan outcome is identical for every worker count
    /// (deterministic merge), so this is purely a throughput knob.
    pub workers: usize,
    /// Prefix-activation cache budget in MiB for staged trial execution
    /// (DESIGN.md §8); 0 disables the cache and every trial runs full
    /// forwards. Staged scoring is bit-identical to full scoring, so —
    /// like `workers` — this is purely a throughput knob.
    pub cache_mb: usize,
    /// Hypothesis-slab width for batched multi-trial scoring (DESIGN.md
    /// §11): up to this many trial masks are scored per forward, sharing
    /// the mask-independent affines. Clamped to the backend's
    /// `multi_width` (1 on PJRT = score singly). Batched scoring is
    /// bit-identical per hypothesis, so this too is purely a throughput
    /// knob.
    pub trial_batch: usize,
    /// Verify every staged/batched trial score against its own full
    /// forward, in release builds too (debug builds always check). A CI
    /// knob: scoring runs roughly double, mismatches abort the run.
    pub verify_staged: bool,
    /// Verify every lowered conv kernel call against the retained direct
    /// loop, in release builds too (debug builds always check). Same CI
    /// idiom as `verify_staged`, one level down: conv kernels run roughly
    /// double, mismatches abort the run (DESIGN.md §13).
    pub verify_lowering: bool,
}

impl Default for BcdConfig {
    fn default() -> Self {
        Self {
            drc: 100,
            drc_final: 25,
            drc_schedule: DrcSchedule::Constant,
            granularity: Granularity::Pixel,
            rt: 50,
            adt: 0.3,
            finetune_steps: 40,
            finetune_lr: 1e-2,
            proxy_batches: 2,
            seed: 0xC0DE,
            workers: 0,
            cache_mb: 64,
            trial_batch: 16,
            verify_staged: false,
            verify_lowering: false,
        }
    }
}

impl BcdConfig {
    /// Resolve the `workers` knob: 0 means all available parallelism.
    pub fn effective_workers(&self) -> usize {
        if self.workers == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.workers
        }
    }
}

/// Hyperparameters of the SNL baseline (Cho et al. 2022b).
#[derive(Clone, Debug, PartialEq)]
pub struct SnlConfig {
    /// Initial lasso coefficient (lambda_0).
    pub lambda0: f32,
    /// Multiplicative lambda correction when reduction stalls (Fig. 9/10).
    pub kappa: f32,
    /// Checks the budget must stall before kappa fires. Alphas clipped at
    /// 1.0 need ~threshold/(alpha_lr*lambda) steps before ANY crossing can
    /// happen; without patience kappa compounds through that latency and
    /// the budget cliffs to zero in one check.
    pub stall_patience: usize,
    /// Threshold for binarizing alphas.
    pub threshold: f32,
    /// Training steps per lambda-schedule check.
    pub steps_per_check: usize,
    /// Max selective-training steps.
    pub max_steps: usize,
    /// Learning rate for the selective phase (weights).
    pub lr: f32,
    /// Alpha learning rate: much larger than `lr` so the CE gradient can
    /// differentiate ReLU importance within our compressed step budget
    /// (see python/compile/model.py fn_snl_step).
    pub alpha_lr: f32,
    /// Finetune steps after hard thresholding.
    pub finetune_steps: usize,
    pub finetune_lr: f32,
    pub seed: u64,
}

impl Default for SnlConfig {
    fn default() -> Self {
        Self {
            lambda0: 4e-3,
            kappa: 1.25,
            stall_patience: 3,
            threshold: 0.5,
            steps_per_check: 5,
            max_steps: 600,
            lr: 1e-2,
            alpha_lr: 1.0,
            finetune_steps: 60,
            finetune_lr: 5e-3,
            seed: 0x51E7,
        }
    }
}

/// AutoReP-specific knobs (Peng et al. 2023) layered on the shared
/// selective-training base. The base hyperparameters come from
/// [`Experiment::snl`] at run time — AutoReP is SNL's training loop with a
/// polynomial replacement function and a hysteresis-stabilized indicator —
/// so only the genuinely AutoReP-specific knob lives here.
#[derive(Clone, Debug, PartialEq)]
pub struct AutorepConfig {
    /// Full hysteresis band width around `snl.threshold`: an indicator
    /// flips only when its score exits `threshold ± hysteresis/2`.
    pub hysteresis: f32,
}

impl Default for AutorepConfig {
    fn default() -> Self {
        AutorepConfig { hysteresis: 0.2 }
    }
}

/// SENet hyperparameters (Kundu et al. 2023).
#[derive(Clone, Debug, PartialEq)]
pub struct SenetConfig {
    /// Proxy batches for sensitivity measurement and trial scoring.
    pub proxy_batches: usize,
    /// Within-layer keep-set candidates tried per layer.
    pub layer_trials: usize,
    /// KD finetune steps / lr / temperature.
    pub kd_steps: usize,
    pub kd_lr: f32,
    pub kd_temp: f32,
    pub seed: u64,
}

impl Default for SenetConfig {
    fn default() -> Self {
        SenetConfig {
            proxy_batches: 2,
            layer_trials: 4,
            kd_steps: 60,
            kd_lr: 5e-3,
            kd_temp: 4.0,
            seed: 0x5E9E,
        }
    }
}

/// DeepReDuce hyperparameters (Jha et al. 2021).
#[derive(Clone, Debug, PartialEq)]
pub struct DeepReduceConfig {
    pub proxy_batches: usize,
    pub finetune_steps: usize,
    pub finetune_lr: f32,
    pub seed: u64,
}

impl Default for DeepReduceConfig {
    fn default() -> Self {
        DeepReduceConfig {
            proxy_batches: 2,
            finetune_steps: 60,
            finetune_lr: 5e-3,
            seed: 0xDEE9,
        }
    }
}

/// Private-Inference serving knobs (DESIGN.md §14): the deployment
/// protocol every `cdnl picost`/`cdnl serve` table defaults to, plus the
/// fleet shape fed to [`crate::pi::serve`]. Semantic: every field changes
/// the serving workload (and hence every serve-tier report), so all
/// participate in the fingerprint.
#[derive(Clone, Debug, PartialEq)]
pub struct PiConfig {
    /// Named deployment protocol from the [`crate::pi::protocol`]
    /// registry: lan | wan | mobile.
    pub protocol: String,
    /// Concurrent clients in the simulated fleet.
    pub clients: usize,
    /// Mean arrivals per second per client (Poisson process).
    pub arrival_rate: f64,
    /// Inferences each client requests.
    pub requests: usize,
    /// Max GEMM jobs the server aggregates into one batched linear pass.
    pub batch_window: usize,
    /// Preprocessing lookahead: garbling may run at most this many
    /// requests ahead of arrivals.
    pub prep_ahead: usize,
    /// Seed for the arrival process.
    pub seed: u64,
}

impl Default for PiConfig {
    fn default() -> Self {
        PiConfig {
            protocol: "lan".into(),
            clients: 64,
            arrival_rate: 1.0,
            requests: 8,
            batch_window: 8,
            prep_ahead: 4,
            seed: 0x5EED,
        }
    }
}

/// Sizing of the reference backend's conv/residual topologies
/// (`resnet18_*` / `wrn22_*` — DESIGN.md §12). Semantic: every field
/// changes model numerics, so all participate in the fingerprint.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Stem width. ResNet stage widths are `conv_base * [1,2,4,8]`; WRN
    /// group widths are `conv_base/2 * conv_widen * [1,2,4]`.
    pub conv_base: usize,
    /// WRN widening factor (ignored by the ResNet family).
    pub conv_widen: usize,
    /// Residual blocks per stage/group.
    pub conv_blocks: usize,
    /// Batchnorm running-stat EMA rate used by the training steps.
    pub bn_momentum: f32,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig { conv_base: 8, conv_widen: 4, conv_blocks: 2, bn_momentum: 0.1 }
    }
}

/// Baseline (full-ReLU) training schedule.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    pub warmup_steps: usize,
    pub batch: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { steps: 300, lr: 2e-2, warmup_steps: 20, batch: 128, seed: 0x7EA1 }
    }
}

/// One fully-specified experiment.
#[derive(Clone, Debug)]
pub struct Experiment {
    /// Dataset name: synth10 | synth100 | synthtiny.
    pub dataset: String,
    /// Backbone: resnet | wrn.
    pub backbone: String,
    /// AutoReP-style polynomial replacement instead of identity.
    pub poly: bool,
    pub model: ModelConfig,
    pub train: TrainConfig,
    pub bcd: BcdConfig,
    pub snl: SnlConfig,
    pub autorep: AutorepConfig,
    pub senet: SenetConfig,
    pub deepreduce: DeepReduceConfig,
    pub pi: PiConfig,
    /// Where checkpoints/results are written.
    pub out_dir: String,
    pub artifacts_dir: String,
}

impl Default for Experiment {
    fn default() -> Self {
        Self {
            dataset: "synth10".into(),
            backbone: "resnet".into(),
            poly: false,
            model: ModelConfig::default(),
            train: TrainConfig::default(),
            bcd: BcdConfig::default(),
            snl: SnlConfig::default(),
            autorep: AutorepConfig::default(),
            senet: SenetConfig::default(),
            deepreduce: DeepReduceConfig::default(),
            pi: PiConfig::default(),
            out_dir: "results".into(),
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl Experiment {
    /// The manifest model key for this experiment (see aot.py).
    pub fn model_key(&self) -> String {
        let size = if self.dataset == "synthtiny" { 32 } else { 16 };
        let classes = if self.dataset == "synth10" { 10 } else { 20 };
        let p = if self.poly { "_poly" } else { "" };
        format!("{}_{}x{}_c{}{}", self.backbone, size, size, classes, p)
    }

    /// Apply `key=value` overrides (from file lines or CLI).
    pub fn apply(&mut self, key: &str, value: &str) -> Result<(), String> {
        let bad = |k: &str, v: &str| format!("config: bad value {v:?} for {k}");
        macro_rules! p {
            ($v:expr) => {
                $v.parse().map_err(|_| bad(key, value))?
            };
        }
        match key {
            "dataset" => self.dataset = value.to_string(),
            "backbone" => self.backbone = value.to_string(),
            "poly" => self.poly = p!(value),
            "out_dir" => self.out_dir = value.to_string(),
            "artifacts_dir" => self.artifacts_dir = value.to_string(),
            "model.conv_base" => self.model.conv_base = p!(value),
            "model.conv_widen" => self.model.conv_widen = p!(value),
            "model.conv_blocks" => self.model.conv_blocks = p!(value),
            "model.bn_momentum" => self.model.bn_momentum = p!(value),
            "train.steps" => self.train.steps = p!(value),
            "train.lr" => self.train.lr = p!(value),
            "train.warmup_steps" => self.train.warmup_steps = p!(value),
            "train.seed" => self.train.seed = p!(value),
            "bcd.drc" => self.bcd.drc = p!(value),
            "bcd.drc_final" => self.bcd.drc_final = p!(value),
            "bcd.drc_schedule" => {
                self.bcd.drc_schedule =
                    DrcSchedule::parse(value).ok_or_else(|| bad(key, value))?
            }
            "bcd.granularity" => {
                self.bcd.granularity =
                    Granularity::parse(value).ok_or_else(|| bad(key, value))?
            }
            "bcd.rt" => self.bcd.rt = p!(value),
            "bcd.adt" => self.bcd.adt = p!(value),
            "bcd.finetune_steps" => self.bcd.finetune_steps = p!(value),
            "bcd.finetune_lr" => self.bcd.finetune_lr = p!(value),
            "bcd.proxy_batches" => self.bcd.proxy_batches = p!(value),
            "bcd.seed" => self.bcd.seed = p!(value),
            "bcd.workers" => self.bcd.workers = p!(value),
            "bcd.cache_mb" => self.bcd.cache_mb = p!(value),
            "bcd.trial_batch" => self.bcd.trial_batch = p!(value),
            "bcd.verify_staged" => self.bcd.verify_staged = p!(value),
            "bcd.verify_lowering" => self.bcd.verify_lowering = p!(value),
            "snl.lambda0" => self.snl.lambda0 = p!(value),
            "snl.kappa" => self.snl.kappa = p!(value),
            "snl.stall_patience" => self.snl.stall_patience = p!(value),
            "snl.alpha_lr" => self.snl.alpha_lr = p!(value),
            "snl.threshold" => self.snl.threshold = p!(value),
            "snl.max_steps" => self.snl.max_steps = p!(value),
            "snl.steps_per_check" => self.snl.steps_per_check = p!(value),
            "snl.lr" => self.snl.lr = p!(value),
            "snl.finetune_steps" => self.snl.finetune_steps = p!(value),
            "snl.finetune_lr" => self.snl.finetune_lr = p!(value),
            "snl.seed" => self.snl.seed = p!(value),
            "autorep.hysteresis" => self.autorep.hysteresis = p!(value),
            "senet.proxy_batches" => self.senet.proxy_batches = p!(value),
            "senet.layer_trials" => self.senet.layer_trials = p!(value),
            "senet.kd_steps" => self.senet.kd_steps = p!(value),
            "senet.kd_lr" => self.senet.kd_lr = p!(value),
            "senet.kd_temp" => self.senet.kd_temp = p!(value),
            "senet.seed" => self.senet.seed = p!(value),
            "deepreduce.proxy_batches" => self.deepreduce.proxy_batches = p!(value),
            "deepreduce.finetune_steps" => self.deepreduce.finetune_steps = p!(value),
            "deepreduce.finetune_lr" => self.deepreduce.finetune_lr = p!(value),
            "deepreduce.seed" => self.deepreduce.seed = p!(value),
            "pi.protocol" => {
                crate::pi::protocol::find(value).ok_or_else(|| {
                    format!(
                        "config: unknown protocol {value:?} for pi.protocol (known: {})",
                        crate::pi::protocol::names().join("|")
                    )
                })?;
                self.pi.protocol = value.to_ascii_lowercase();
            }
            "pi.clients" => self.pi.clients = p!(value),
            "pi.arrival_rate" => {
                let r: f64 = p!(value);
                if !(r.is_finite() && r > 0.0) {
                    return Err(bad(key, value));
                }
                self.pi.arrival_rate = r;
            }
            "pi.requests" => self.pi.requests = p!(value),
            "pi.batch_window" => self.pi.batch_window = p!(value),
            "pi.prep_ahead" => self.pi.prep_ahead = p!(value),
            "pi.seed" => self.pi.seed = p!(value),
            _ => return Err(format!("config: unknown key {key:?}")),
        }
        Ok(())
    }

    /// Parse a config file: `key = value` lines, `#` comments.
    pub fn apply_file(&mut self, text: &str) -> Result<(), String> {
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("config line {}: expected key = value", lineno + 1))?;
            self.apply(k.trim(), v.trim())
                .map_err(|e| format!("line {}: {e}", lineno + 1))?;
        }
        Ok(())
    }

    /// Canonical `key -> value` dump of every setting [`Self::apply`]
    /// accepts. `apply`ing the dump onto a default [`Experiment`]
    /// reconstructs this one exactly — the run-store records it in
    /// `run.json` so `cdnl runs resume` rebuilds the experiment without any
    /// out-of-band state, and fingerprints it for cache identity.
    pub fn dump(&self) -> BTreeMap<String, String> {
        let mut m = BTreeMap::new();
        let mut put = |k: &str, v: String| {
            m.insert(k.to_string(), v);
        };
        put("dataset", self.dataset.clone());
        put("backbone", self.backbone.clone());
        put("poly", self.poly.to_string());
        put("out_dir", self.out_dir.clone());
        put("artifacts_dir", self.artifacts_dir.clone());
        put("model.conv_base", self.model.conv_base.to_string());
        put("model.conv_widen", self.model.conv_widen.to_string());
        put("model.conv_blocks", self.model.conv_blocks.to_string());
        put("model.bn_momentum", self.model.bn_momentum.to_string());
        put("train.steps", self.train.steps.to_string());
        put("train.lr", self.train.lr.to_string());
        put("train.warmup_steps", self.train.warmup_steps.to_string());
        put("train.seed", self.train.seed.to_string());
        put("bcd.drc", self.bcd.drc.to_string());
        put("bcd.drc_final", self.bcd.drc_final.to_string());
        put("bcd.drc_schedule", self.bcd.drc_schedule.name().to_string());
        put("bcd.granularity", self.bcd.granularity.name().to_string());
        put("bcd.rt", self.bcd.rt.to_string());
        put("bcd.adt", self.bcd.adt.to_string());
        put("bcd.finetune_steps", self.bcd.finetune_steps.to_string());
        put("bcd.finetune_lr", self.bcd.finetune_lr.to_string());
        put("bcd.proxy_batches", self.bcd.proxy_batches.to_string());
        put("bcd.seed", self.bcd.seed.to_string());
        put("bcd.workers", self.bcd.workers.to_string());
        put("bcd.cache_mb", self.bcd.cache_mb.to_string());
        put("bcd.trial_batch", self.bcd.trial_batch.to_string());
        put("bcd.verify_staged", self.bcd.verify_staged.to_string());
        put("bcd.verify_lowering", self.bcd.verify_lowering.to_string());
        put("snl.lambda0", self.snl.lambda0.to_string());
        put("snl.kappa", self.snl.kappa.to_string());
        put("snl.stall_patience", self.snl.stall_patience.to_string());
        put("snl.alpha_lr", self.snl.alpha_lr.to_string());
        put("snl.threshold", self.snl.threshold.to_string());
        put("snl.max_steps", self.snl.max_steps.to_string());
        put("snl.steps_per_check", self.snl.steps_per_check.to_string());
        put("snl.lr", self.snl.lr.to_string());
        put("snl.finetune_steps", self.snl.finetune_steps.to_string());
        put("snl.finetune_lr", self.snl.finetune_lr.to_string());
        put("snl.seed", self.snl.seed.to_string());
        put("autorep.hysteresis", self.autorep.hysteresis.to_string());
        put("senet.proxy_batches", self.senet.proxy_batches.to_string());
        put("senet.layer_trials", self.senet.layer_trials.to_string());
        put("senet.kd_steps", self.senet.kd_steps.to_string());
        put("senet.kd_lr", self.senet.kd_lr.to_string());
        put("senet.kd_temp", self.senet.kd_temp.to_string());
        put("senet.seed", self.senet.seed.to_string());
        put("deepreduce.proxy_batches", self.deepreduce.proxy_batches.to_string());
        put("deepreduce.finetune_steps", self.deepreduce.finetune_steps.to_string());
        put("deepreduce.finetune_lr", self.deepreduce.finetune_lr.to_string());
        put("deepreduce.seed", self.deepreduce.seed.to_string());
        put("pi.protocol", self.pi.protocol.clone());
        put("pi.clients", self.pi.clients.to_string());
        put("pi.arrival_rate", self.pi.arrival_rate.to_string());
        put("pi.requests", self.pi.requests.to_string());
        put("pi.batch_window", self.pi.batch_window.to_string());
        put("pi.prep_ahead", self.pi.prep_ahead.to_string());
        put("pi.seed", self.pi.seed.to_string());
        m
    }

    /// FNV-1a 64 fingerprint of the canonical dump, as 16 hex chars. Two
    /// experiments with equal fingerprints produce identical results: keys
    /// that cannot change numerics (paths, `bcd.workers` — the scan is
    /// worker-count invariant — `bcd.cache_mb` and `bcd.trial_batch` —
    /// staged and batched scoring are bit-identical to full scoring — and
    /// `bcd.verify_staged` and `bcd.verify_lowering`, pure cross-checks)
    /// are excluded, so moving an output directory, rescaling the thread
    /// pool, or resizing the prefix cache or trial slab does not orphan a
    /// resumable run.
    pub fn fingerprint(&self) -> String {
        const NON_SEMANTIC: [&str; 7] = [
            "out_dir",
            "artifacts_dir",
            "bcd.workers",
            "bcd.cache_mb",
            "bcd.trial_batch",
            "bcd.verify_staged",
            "bcd.verify_lowering",
        ];
        let mut dump = self.dump();
        dump.retain(|k, _| !NON_SEMANTIC.contains(&k.as_str()));
        fingerprint_pairs(&dump)
    }

    /// Overlay CLI flags of the form `--set key=value` (repeatable via
    /// comma) plus first-class flags (--dataset, --backbone, ...).
    pub fn apply_args(&mut self, args: &Args) -> Result<(), String> {
        if let Some(d) = args.get("dataset") {
            self.dataset = d.to_string();
        }
        if let Some(b) = args.get("backbone") {
            self.backbone = b.to_string();
        }
        if args.has("poly") {
            self.poly = true;
        }
        if let Some(sets) = args.get("set") {
            for kv in sets.split(',') {
                let (k, v) = kv
                    .split_once('=')
                    .ok_or_else(|| format!("--set: expected key=value, got {kv:?}"))?;
                self.apply(k.trim(), v.trim())?;
            }
        }
        Ok(())
    }
}

/// FNV-1a 64 over canonical `key=value\n` lines, as 16 hex chars — the
/// shared fingerprint primitive behind [`Experiment::fingerprint`] and the
/// per-method `Method::config_fingerprint` hooks
/// ([`crate::methods::registry`]).
pub fn fingerprint_pairs(pairs: &BTreeMap<String, String>) -> String {
    let mut h: u64 = 0xcbf29ce484222325;
    for (k, v) in pairs {
        for b in k.bytes().chain([b'='].into_iter()).chain(v.bytes()).chain([b'\n']) {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    format!("{h:016x}")
}

/// Paper Table 4 analog: reference budgets per (dataset, target budget),
/// scaled by the backbone's ReLU count ratio. Returns `B_ref` for a target.
pub fn reference_budget(total_relus: usize, target: usize) -> usize {
    // Paper rule (ResNet18/CIFAR): targets < 30K start from 30K; targets
    // >= 100K start from 200K; TinyImageNet uses ~1.2-1.5x the target.
    // We generalize: B_ref = min(total, max(2 * target, target + 500)).
    let bref = (2 * target).max(target + 500);
    bref.min(total_relus)
}

/// Named preset table — the per-figure/table experiment grids used by the
/// benches (quick mode). Keys are bench ids ("table2", "fig5", ...).
pub fn preset(name: &str) -> Option<BTreeMap<String, String>> {
    let mut m = BTreeMap::new();
    match name {
        "quick" => {
            m.insert("train.steps".into(), "120".into());
            m.insert("snl.max_steps".into(), "200".into());
            m.insert("bcd.rt".into(), "12".into());
            m.insert("bcd.finetune_steps".into(), "16".into());
            m.insert("snl.finetune_steps".into(), "24".into());
        }
        "full" => {
            m.insert("train.steps".into(), "300".into());
            m.insert("snl.max_steps".into(), "600".into());
            m.insert("bcd.rt".into(), "50".into());
            m.insert("bcd.finetune_steps".into(), "40".into());
            m.insert("snl.finetune_steps".into(), "60".into());
        }
        _ => return None,
    }
    Some(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_key_mapping() {
        let mut e = Experiment::default();
        assert_eq!(e.model_key(), "resnet_16x16_c10");
        e.dataset = "synth100".into();
        assert_eq!(e.model_key(), "resnet_16x16_c20");
        e.dataset = "synthtiny".into();
        e.backbone = "wrn".into();
        assert_eq!(e.model_key(), "wrn_32x32_c20");
        e.dataset = "synth100".into();
        e.poly = true;
        assert_eq!(e.model_key(), "wrn_16x16_c20_poly");
        // Conv backbones compose the same way (DESIGN.md §12).
        e.backbone = "resnet18".into();
        assert_eq!(e.model_key(), "resnet18_16x16_c20_poly");
        e.backbone = "wrn22".into();
        e.dataset = "synthtiny".into();
        assert_eq!(e.model_key(), "wrn22_32x32_c20_poly");
    }

    #[test]
    fn apply_and_file() {
        let mut e = Experiment::default();
        e.apply_file("bcd.drc = 50\n# comment\nsnl.kappa = 1.5\nbcd.workers = 3\n").unwrap();
        assert_eq!(e.bcd.drc, 50);
        assert!((e.snl.kappa - 1.5).abs() < 1e-6);
        assert_eq!(e.bcd.workers, 3);
        assert_eq!(e.bcd.effective_workers(), 3);
        e.bcd.workers = 0;
        assert!(e.bcd.effective_workers() >= 1, "auto must resolve to >= 1");
    }

    #[test]
    fn dump_reconstructs_and_fingerprints() {
        let mut e = Experiment::default();
        e.apply("bcd.drc", "77").unwrap();
        e.apply("snl.kappa", "1.75").unwrap();
        e.apply("dataset", "synth100").unwrap();
        e.apply("bcd.drc_schedule", "cosine").unwrap();
        // Re-applying the dump onto a default reconstructs the experiment.
        let mut back = Experiment::default();
        for (k, v) in e.dump() {
            back.apply(&k, &v).unwrap_or_else(|err| panic!("dump key {k}: {err}"));
        }
        assert_eq!(back.dump(), e.dump());
        assert_eq!(back.fingerprint(), e.fingerprint());
        // Semantic changes move the fingerprint; non-semantic ones don't.
        let fp = e.fingerprint();
        e.bcd.workers = 9;
        e.out_dir = "elsewhere".into();
        e.bcd.cache_mb = 0;
        e.bcd.trial_batch = 1;
        e.bcd.verify_staged = true;
        e.bcd.verify_lowering = true;
        assert_eq!(
            e.fingerprint(),
            fp,
            "workers/out_dir/cache_mb/trial_batch/verify knobs must not shift identity"
        );
        e.bcd.rt = 99;
        assert_ne!(e.fingerprint(), fp, "rt change must shift identity");
    }

    #[test]
    fn cache_mb_knob_applies() {
        let mut e = Experiment::default();
        assert_eq!(e.bcd.cache_mb, 64, "staged execution on by default");
        e.apply("bcd.cache_mb", "0").unwrap();
        assert_eq!(e.bcd.cache_mb, 0);
        assert!(e.apply("bcd.cache_mb", "lots").is_err());
        assert_eq!(e.dump().get("bcd.cache_mb").unwrap(), "0");
    }

    #[test]
    fn trial_batch_and_verify_knobs_apply() {
        let mut e = Experiment::default();
        assert_eq!(e.bcd.trial_batch, 16, "batched scoring on by default");
        assert!(!e.bcd.verify_staged, "verification is opt-in");
        assert!(!e.bcd.verify_lowering, "lowering verification is opt-in");
        e.apply("bcd.trial_batch", "32").unwrap();
        assert_eq!(e.bcd.trial_batch, 32);
        e.apply("bcd.verify_staged", "true").unwrap();
        assert!(e.bcd.verify_staged);
        e.apply("bcd.verify_lowering", "true").unwrap();
        assert!(e.bcd.verify_lowering);
        assert!(e.apply("bcd.trial_batch", "wide").is_err());
        assert!(e.apply("bcd.verify_staged", "maybe").is_err());
        assert!(e.apply("bcd.verify_lowering", "maybe").is_err());
        assert_eq!(e.dump().get("bcd.trial_batch").unwrap(), "32");
        assert_eq!(e.dump().get("bcd.verify_staged").unwrap(), "true");
        assert_eq!(e.dump().get("bcd.verify_lowering").unwrap(), "true");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut e = Experiment::default();
        assert!(e.apply("bcd.typo", "3").is_err());
    }

    /// Every field of every method config must shift the experiment
    /// fingerprint — the reproducibility guarantee behind the run-store:
    /// a manifest's `config_fingerprint` changes whenever any setting that
    /// can move numerics changes (ISSUE 5's config-provenance bug).
    fn assert_fingerprint_sensitive(keys: &[(&str, &str)]) {
        for (k, v) in keys {
            let mut e = Experiment::default();
            let fp = e.fingerprint();
            assert_ne!(
                e.dump().get(*k).map(|s| s.as_str()),
                Some(*v),
                "test value for {k} must differ from the default"
            );
            e.apply(k, v).unwrap_or_else(|err| panic!("{k}: {err}"));
            assert_ne!(e.fingerprint(), fp, "{k} change must shift the fingerprint");
            // And the dump round-trips the change.
            let mut back = Experiment::default();
            for (dk, dv) in e.dump() {
                back.apply(&dk, &dv).unwrap();
            }
            assert_eq!(back.fingerprint(), e.fingerprint(), "{k} dump roundtrip");
        }
    }

    #[test]
    fn autorep_config_fingerprint_coverage() {
        assert_eq!(AutorepConfig::default().hysteresis, 0.2);
        assert_fingerprint_sensitive(&[("autorep.hysteresis", "0.35")]);
    }

    #[test]
    fn senet_config_fingerprint_coverage() {
        let d = SenetConfig::default();
        assert_eq!((d.proxy_batches, d.layer_trials, d.kd_steps), (2, 4, 60));
        assert_fingerprint_sensitive(&[
            ("senet.proxy_batches", "3"),
            ("senet.layer_trials", "7"),
            ("senet.kd_steps", "11"),
            ("senet.kd_lr", "0.001"),
            ("senet.kd_temp", "2.5"),
            ("senet.seed", "99"),
        ]);
    }

    #[test]
    fn deepreduce_config_fingerprint_coverage() {
        let d = DeepReduceConfig::default();
        assert_eq!((d.proxy_batches, d.finetune_steps), (2, 60));
        assert_fingerprint_sensitive(&[
            ("deepreduce.proxy_batches", "3"),
            ("deepreduce.finetune_steps", "11"),
            ("deepreduce.finetune_lr", "0.001"),
            ("deepreduce.seed", "99"),
        ]);
    }

    #[test]
    fn pi_config_fingerprint_coverage() {
        let d = PiConfig::default();
        assert_eq!(d.protocol, "lan");
        assert_eq!(
            (d.clients, d.requests, d.batch_window, d.prep_ahead, d.seed),
            (64, 8, 8, 4, 0x5EED)
        );
        assert!((d.arrival_rate - 1.0).abs() < 1e-12);
        assert_fingerprint_sensitive(&[
            ("pi.protocol", "wan"),
            ("pi.clients", "128"),
            ("pi.arrival_rate", "2.5"),
            ("pi.requests", "4"),
            ("pi.batch_window", "16"),
            ("pi.prep_ahead", "2"),
            ("pi.seed", "7"),
        ]);
        // The protocol key is validated against the pi::protocol registry
        // and canonicalized, and arrival rates must be positive and finite.
        let mut e = Experiment::default();
        assert!(e.apply("pi.protocol", "dialup").is_err());
        e.apply("pi.protocol", "MOBILE").unwrap();
        assert_eq!(e.pi.protocol, "mobile");
        assert!(e.apply("pi.arrival_rate", "0").is_err());
        assert!(e.apply("pi.arrival_rate", "-1").is_err());
        assert!(e.apply("pi.arrival_rate", "inf").is_err());
    }

    #[test]
    fn model_config_fingerprint_coverage() {
        let d = ModelConfig::default();
        assert_eq!((d.conv_base, d.conv_widen, d.conv_blocks), (8, 4, 2));
        assert!((d.bn_momentum - 0.1).abs() < 1e-9);
        assert_fingerprint_sensitive(&[
            ("model.conv_base", "16"),
            ("model.conv_widen", "2"),
            ("model.conv_blocks", "3"),
            ("model.bn_momentum", "0.05"),
        ]);
    }

    #[test]
    fn reference_budget_rules() {
        assert_eq!(reference_budget(17408, 1000), 2000);
        assert_eq!(reference_budget(17408, 100), 600);
        assert_eq!(reference_budget(17408, 16000), 17408); // capped at total
    }

    #[test]
    fn drc_schedules() {
        // Constant ignores progress.
        assert_eq!(DrcSchedule::Constant.drc_at(100, 25, 0, 1000), 100);
        assert_eq!(DrcSchedule::Constant.drc_at(100, 25, 999, 1000), 100);
        // Linear interpolates from drc0 to drc_final.
        assert_eq!(DrcSchedule::Linear.drc_at(100, 20, 0, 1000), 100);
        assert_eq!(DrcSchedule::Linear.drc_at(100, 20, 500, 1000), 60);
        assert_eq!(DrcSchedule::Linear.drc_at(100, 20, 1000, 1000), 20);
        // Cosine hits the endpoints and stays within [lo, hi].
        assert_eq!(DrcSchedule::Cosine.drc_at(100, 20, 0, 1000), 100);
        assert_eq!(DrcSchedule::Cosine.drc_at(100, 20, 1000, 1000), 20);
        for done in (0..=1000).step_by(100) {
            let v = DrcSchedule::Cosine.drc_at(100, 20, done, 1000);
            assert!((20..=100).contains(&v), "cosine out of range: {v}");
        }
        // Never returns zero, even for degenerate inputs.
        assert_eq!(DrcSchedule::Linear.drc_at(1, 0, 1, 1), 1);
    }

    #[test]
    fn schedule_and_granularity_parse() {
        assert_eq!(DrcSchedule::parse("cosine"), Some(DrcSchedule::Cosine));
        assert_eq!(DrcSchedule::parse("bogus"), None);
        assert_eq!(Granularity::parse("channel"), Some(Granularity::Channel));
        assert_eq!(Granularity::parse("bogus"), None);
        let mut e = Experiment::default();
        e.apply("bcd.drc_schedule", "linear").unwrap();
        e.apply("bcd.granularity", "channel").unwrap();
        assert_eq!(e.bcd.drc_schedule, DrcSchedule::Linear);
        assert_eq!(e.bcd.granularity, Granularity::Channel);
        assert!(e.apply("bcd.drc_schedule", "nope").is_err());
    }

    #[test]
    fn presets_parse() {
        let mut e = Experiment::default();
        for (k, v) in preset("quick").unwrap() {
            e.apply(&k, &v).unwrap();
        }
        assert_eq!(e.bcd.rt, 12);
    }
}
