//! Host-side tensors (plus `xla::Literal` conversions under `pjrt`).
//!
//! The coordinator only ever needs two dtypes at the backend boundary
//! (f32 data, i32 labels/seeds), so [`Tensor`] is an f32 container with an
//! explicit shape plus a thin i32 variant. Everything heavier (matmuls,
//! convs) lives behind the [`crate::runtime::Backend`] boundary.

use anyhow::{bail, Result};

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} != data len {}",
            data.len()
        );
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn ones(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Self { shape, data: vec![1.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![1], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Convert to an `xla::Literal` with this tensor's shape.
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }

    /// Read a Literal back into a host tensor (f32 only).
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = lit.to_vec::<f32>()?;
        Ok(Tensor::new(if dims.is_empty() { vec![1] } else { dims }, data))
    }

    /// Scalar readout for loss/correct outputs (rank-0 or single element).
    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on non-scalar {:?}", self.shape);
        self.data[0]
    }

    /// Row-wise argmax of a `[B, K]` tensor.
    pub fn argmax_rows(&self) -> Result<Vec<usize>> {
        if self.shape.len() != 2 {
            bail!("argmax_rows expects rank-2, got {:?}", self.shape);
        }
        let (b, k) = (self.shape[0], self.shape[1]);
        Ok((0..b)
            .map(|i| {
                let row = &self.data[i * k..(i + 1) * k];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap_or(0)
            })
            .collect())
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            0.0
        } else {
            self.data.iter().sum::<f32>() / self.data.len() as f32
        }
    }
}

/// Dense row-major i32 tensor (labels, seeds).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl TensorI32 {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape, data }
    }

    pub fn scalar(v: i32) -> Self {
        Self { shape: vec![1], data: vec![v] }
    }

    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_product_enforced() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic]
    fn bad_shape_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn argmax() {
        let t = Tensor::new(vec![2, 3], vec![0.1, 0.9, 0.0, 5.0, -1.0, 2.0]);
        assert_eq!(t.argmax_rows().unwrap(), vec![1, 0]);
    }

    #[test]
    fn zeros_ones_scalar() {
        assert_eq!(Tensor::zeros(vec![4]).data, vec![0.0; 4]);
        assert_eq!(Tensor::ones(vec![2, 2]).data, vec![1.0; 4]);
        assert_eq!(Tensor::scalar(2.5).item(), 2.5);
    }

    #[test]
    fn mean_of_empty_is_zero() {
        assert_eq!(Tensor::new(vec![0], vec![]).mean(), 0.0);
    }

    #[test]
    fn rank4_nchw_batches() {
        // Conv batches cross the backend boundary as [N, C, H, W] tensors
        // (flat row-major data — the layout DESIGN.md §12 assumes).
        let t = Tensor::zeros(vec![2, 3, 4, 4]);
        assert_eq!(t.len(), 96);
        assert!(Tensor::new(vec![2, 3, 4, 4], vec![0.0; 96]).argmax_rows().is_err());
    }
}
