//! The unified method API (DESIGN.md §10): one typed entry point for every
//! linearization method, with chainable stages and full config provenance.
//!
//! Before this module the five methods had five bespoke `run_*` signatures
//! dispatched by a string `match` in `main.rs`, and three of the five
//! method configs were built from `Default::default()` at the call site —
//! invisible to [`Experiment::dump`]/[`Experiment::fingerprint`] and
//! therefore to run manifests. The [`Method`] trait closes both holes:
//!
//! - every method runs through `Method::run(ctx, state, budget)` over a
//!   [`MethodCtx`] (session + dataset + experiment + provenance sink), and
//!   its hyperparameters live in [`Experiment`] (`snl.*`, `bcd.*`,
//!   `autorep.*`, `senet.*`, `deepreduce.*`), so a run manifest's config
//!   dump reconstructs *exactly* what ran;
//! - `Method::run` returns a typed, serde-backed [`MethodOutcome`] that
//!   serializes into `run.json`, so `cdnl runs show` prints method-specific
//!   detail for every method, not just BCD;
//! - [`ChainSpec`] composes registered methods into the paper's staging
//!   protocols (`snl+bcd` is Tables 4/5 and Fig. 4's "ours on top of a
//!   reference") as user-specifiable scenarios, one [`StageRecord`] of
//!   provenance per stage.
//!
//! The registry impls are thin: each delegates to the same public `run_*`
//! function the pre-registry call sites used, so registry dispatch is
//! bit-identical to a direct call (`rust/tests/integration_registry.rs`
//! asserts it method by method).

use crate::config::{fingerprint_pairs, Experiment};
use crate::coordinator::bcd::{run_bcd, BcdOutcome};
use crate::data::Dataset;
use crate::derive_serde;
use crate::methods::autorep::{run_autorep, AutorepOutcome};
use crate::methods::deepreduce::{run_deepreduce, DeepReduceOutcome};
use crate::methods::senet::{run_senet, SenetOutcome};
use crate::methods::snl::{run_snl, SnlOutcome};
use crate::model::ModelState;
use crate::runstore::StageRecord;
use crate::runtime::session::Session;
use crate::util::json::Json;
use crate::util::serde as sd;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// Per-stage provenance sink: chain execution appends one [`StageRecord`]
/// per completed stage; the pipeline appends one per zoo access. A run
/// manifest drains the sink at seal time (`Pipeline::take_stages`).
pub type RecordSink = Mutex<Vec<StageRecord>>;

/// Everything a method needs to run, bundled so every method shares one
/// signature: the typed backend session, the training dataset, the full
/// experiment config (each method reads its own `Experiment` slice), and
/// the stage-provenance sink.
pub struct MethodCtx<'a> {
    pub sess: &'a Session<'a>,
    pub train_ds: &'a Dataset,
    pub exp: &'a Experiment,
    pub stages: &'a RecordSink,
}

impl<'a> MethodCtx<'a> {
    pub fn new(
        sess: &'a Session<'a>,
        train_ds: &'a Dataset,
        exp: &'a Experiment,
        stages: &'a RecordSink,
    ) -> MethodCtx<'a> {
        MethodCtx { sess, train_ds, exp, stages }
    }
}

/// One linearization method, registered in [`registry`].
///
/// Implementations delegate to the method's public `run_*` function with
/// configs read from `ctx.exp`, so the registry path and a direct call are
/// bit-identical. The trait is object-safe; `Sync` lets the registry hand
/// out `&'static dyn Method` across the parallel bench/test harnesses.
pub trait Method: Sync {
    /// Registry name — the CLI spelling (`cdnl run <name>`).
    fn name(&self) -> &'static str;

    /// One-line description for `cdnl methods list`.
    fn describe(&self) -> &'static str;

    /// Key prefixes of this method's slice of [`Experiment::dump`] — the
    /// settings that determine its numerics.
    fn config_prefixes(&self) -> &'static [&'static str];

    /// Run the method on `st` down to `budget` ReLUs, mutating it.
    fn run(&self, ctx: &MethodCtx, st: &mut ModelState, budget: usize)
        -> Result<MethodOutcome>;

    /// The method-relevant subset of the experiment's canonical dump
    /// (what a manifest must carry for this method to be reproducible).
    fn config_dump(&self, exp: &Experiment) -> BTreeMap<String, String> {
        exp.dump()
            .into_iter()
            .filter(|(k, _)| self.config_prefixes().iter().any(|p| k.starts_with(p)))
            .collect()
    }

    /// FNV-1a 64 fingerprint of [`Method::config_dump`]: changes exactly
    /// when a setting this method reads changes.
    fn config_fingerprint(&self, exp: &Experiment) -> String {
        fingerprint_pairs(&self.config_dump(exp))
    }
}

// ---- typed outcomes --------------------------------------------------------

/// Serializable summary of one SNL run (trace-level data — snapshots and
/// per-alpha trajectories — stays in [`SnlOutcome`]; manifests carry the
/// schedule facts Figs. 9/10 gate on).
#[derive(Clone, Debug, PartialEq)]
pub struct SnlSummary {
    pub steps_run: usize,
    /// Steps at which λ ← κ·λ fired.
    pub kappa_updates: Vec<usize>,
    pub final_budget: usize,
}
derive_serde!(SnlSummary { steps_run, kappa_updates, final_budget });

impl SnlSummary {
    pub fn from_outcome(o: &SnlOutcome) -> SnlSummary {
        SnlSummary {
            steps_run: o.steps_run,
            kappa_updates: o.kappa_updates.clone(),
            final_budget: o.final_budget,
        }
    }
}

/// Serializable summary of one AutoReP run.
#[derive(Clone, Debug, PartialEq)]
pub struct AutorepSummary {
    pub steps_run: usize,
    pub kappa_updates: Vec<usize>,
    /// Total indicator flips across checks (the hysteresis metric).
    pub total_flips: usize,
    pub final_budget: usize,
}
derive_serde!(AutorepSummary { steps_run, kappa_updates, total_flips, final_budget });

impl AutorepSummary {
    pub fn from_outcome(o: &AutorepOutcome) -> AutorepSummary {
        AutorepSummary {
            steps_run: o.steps_run,
            kappa_updates: o.kappa_updates.clone(),
            total_flips: o.flips_trace.iter().map(|&(_, f)| f).sum(),
            final_budget: o.final_budget,
        }
    }
}

/// Serializable summary of one SENet run.
#[derive(Clone, Debug, PartialEq)]
pub struct SenetSummary {
    /// Per-layer accuracy sensitivity, as measured.
    pub sensitivity: Vec<f64>,
    /// Per-layer ReLU allocation (sums to the target budget).
    pub allocation: Vec<usize>,
    pub kd_first_loss: f32,
    pub kd_last_loss: f32,
    pub final_budget: usize,
}
derive_serde!(SenetSummary {
    sensitivity,
    allocation,
    kd_first_loss,
    kd_last_loss,
    final_budget,
});

impl SenetSummary {
    pub fn from_outcome(o: &SenetOutcome) -> SenetSummary {
        SenetSummary {
            sensitivity: o.sensitivity.clone(),
            allocation: o.allocation.clone(),
            kd_first_loss: o.kd_first_loss,
            kd_last_loss: o.kd_last_loss,
            final_budget: o.allocation.iter().sum(),
        }
    }
}

/// Serializable summary of one DeepReDuce run.
#[derive(Clone, Debug, PartialEq)]
pub struct DeepReduceSummary {
    /// Layers fully linearized, in drop order.
    pub dropped_layers: Vec<usize>,
    /// Layer partially dropped to land exactly on the budget (if any).
    pub partial_layer: Option<usize>,
    pub final_budget: usize,
}
derive_serde!(DeepReduceSummary { dropped_layers, partial_layer, final_budget });

impl DeepReduceSummary {
    pub fn from_outcome(o: &DeepReduceOutcome, final_budget: usize) -> DeepReduceSummary {
        DeepReduceSummary {
            dropped_layers: o.dropped_layers.clone(),
            partial_layer: o.partial_layer,
            final_budget,
        }
    }
}

/// Serializable summary of one BCD run (the full per-sweep trace rides the
/// manifest separately as [`crate::runstore::BcdProgress`] for recorded
/// runs; this is the cross-method summary shape).
#[derive(Clone, Debug, PartialEq)]
pub struct BcdSummary {
    pub sweeps: usize,
    pub trials_evaluated: usize,
    pub trials_bounded: usize,
    pub early_accepts: usize,
    pub final_budget: usize,
}
derive_serde!(BcdSummary {
    sweeps,
    trials_evaluated,
    trials_bounded,
    early_accepts,
    final_budget,
});

impl BcdSummary {
    pub fn from_outcome(o: &BcdOutcome) -> BcdSummary {
        BcdSummary {
            sweeps: o.iterations.len(),
            trials_evaluated: o.total_trials(),
            trials_bounded: o.iterations.iter().map(|r| r.trials_bounded).sum(),
            early_accepts: o.iterations.iter().filter(|r| r.early_accept).count(),
            final_budget: o.final_budget,
        }
    }
}

/// Typed outcome of one method run — the serde-backed enum a
/// [`crate::runstore::RunManifest`] embeds (`outcomes`), one variant per
/// registered method. On disk it is a single-key object tagged by the
/// method name: `{"snl": {...}}`.
#[derive(Clone, Debug, PartialEq)]
pub enum MethodOutcome {
    Snl(SnlSummary),
    Bcd(BcdSummary),
    Autorep(AutorepSummary),
    Senet(SenetSummary),
    Deepreduce(DeepReduceSummary),
}

impl MethodOutcome {
    /// The registry name of the method that produced this outcome.
    pub fn method(&self) -> &'static str {
        match self {
            MethodOutcome::Snl(_) => "snl",
            MethodOutcome::Bcd(_) => "bcd",
            MethodOutcome::Autorep(_) => "autorep",
            MethodOutcome::Senet(_) => "senet",
            MethodOutcome::Deepreduce(_) => "deepreduce",
        }
    }

    /// ReLU budget the run landed on.
    pub fn final_budget(&self) -> usize {
        match self {
            MethodOutcome::Snl(s) => s.final_budget,
            MethodOutcome::Bcd(s) => s.final_budget,
            MethodOutcome::Autorep(s) => s.final_budget,
            MethodOutcome::Senet(s) => s.final_budget,
            MethodOutcome::Deepreduce(s) => s.final_budget,
        }
    }

    /// One-line human summary (the CLI epilogue and `cdnl runs show`).
    pub fn describe(&self) -> String {
        match self {
            MethodOutcome::Snl(s) => format!(
                "snl: {} steps, {} lambda updates -> {} ReLUs",
                s.steps_run,
                s.kappa_updates.len(),
                s.final_budget
            ),
            MethodOutcome::Bcd(s) => format!(
                "bcd: {} iterations, {} trials total ({} bounded early, {} early-accepted)",
                s.sweeps, s.trials_evaluated, s.trials_bounded, s.early_accepts
            ),
            MethodOutcome::Autorep(s) => format!(
                "autorep: {} steps, {} indicator flips -> {} ReLUs",
                s.steps_run, s.total_flips, s.final_budget
            ),
            MethodOutcome::Senet(s) => format!(
                "senet: kd loss {:.3} -> {:.3} across {} layers",
                s.kd_first_loss,
                s.kd_last_loss,
                s.allocation.len()
            ),
            MethodOutcome::Deepreduce(s) => format!(
                "deepreduce: dropped layers {:?}, partial {:?}",
                s.dropped_layers, s.partial_layer
            ),
        }
    }
}

impl sd::Serialize for MethodOutcome {
    fn serialize(&self) -> Json {
        let (tag, inner) = match self {
            MethodOutcome::Snl(s) => ("snl", s.serialize()),
            MethodOutcome::Bcd(s) => ("bcd", s.serialize()),
            MethodOutcome::Autorep(s) => ("autorep", s.serialize()),
            MethodOutcome::Senet(s) => ("senet", s.serialize()),
            MethodOutcome::Deepreduce(s) => ("deepreduce", s.serialize()),
        };
        let mut m = BTreeMap::new();
        m.insert(tag.to_string(), inner);
        Json::Obj(m)
    }
}

impl sd::Deserialize for MethodOutcome {
    fn deserialize(v: &Json) -> Result<Self, String> {
        let m = match v {
            Json::Obj(m) if m.len() == 1 => m,
            other => {
                return Err(format!(
                    "expected single-key method-outcome object, got {other:.40?}"
                ))
            }
        };
        let (tag, inner) = m.iter().next().expect("len checked above");
        let err = |e: String| format!("{tag}: {e}");
        match tag.as_str() {
            "snl" => sd::Deserialize::deserialize(inner).map(MethodOutcome::Snl).map_err(err),
            "bcd" => sd::Deserialize::deserialize(inner).map(MethodOutcome::Bcd).map_err(err),
            "autorep" => {
                sd::Deserialize::deserialize(inner).map(MethodOutcome::Autorep).map_err(err)
            }
            "senet" => {
                sd::Deserialize::deserialize(inner).map(MethodOutcome::Senet).map_err(err)
            }
            "deepreduce" => sd::Deserialize::deserialize(inner)
                .map(MethodOutcome::Deepreduce)
                .map_err(err),
            other => Err(format!("unknown method-outcome tag {other:?}")),
        }
    }
}

// ---- the five registered methods -------------------------------------------

struct SnlMethod;

impl Method for SnlMethod {
    fn name(&self) -> &'static str {
        "snl"
    }

    fn describe(&self) -> &'static str {
        "Selective Network Linearization: soft alpha masks under CE + lambda*||a||_1 (Cho et al. 2022)"
    }

    fn config_prefixes(&self) -> &'static [&'static str] {
        &["snl."]
    }

    fn run(
        &self,
        ctx: &MethodCtx,
        st: &mut ModelState,
        budget: usize,
    ) -> Result<MethodOutcome> {
        let out = run_snl(ctx.sess, st, ctx.train_ds, budget, &ctx.exp.snl, 0)?;
        Ok(MethodOutcome::Snl(SnlSummary::from_outcome(&out)))
    }
}

struct BcdMethod;

impl Method for BcdMethod {
    fn name(&self) -> &'static str {
        "bcd"
    }

    fn describe(&self) -> &'static str {
        "Block Coordinate Descent over binary ReLU masks — the paper's Algorithm 2"
    }

    fn config_prefixes(&self) -> &'static [&'static str] {
        &["bcd."]
    }

    fn run(
        &self,
        ctx: &MethodCtx,
        st: &mut ModelState,
        budget: usize,
    ) -> Result<MethodOutcome> {
        let out = run_bcd(ctx.sess, st, ctx.train_ds, budget, &ctx.exp.bcd, 0)?;
        Ok(MethodOutcome::Bcd(BcdSummary::from_outcome(&out)))
    }
}

struct AutorepMethod;

impl Method for AutorepMethod {
    fn name(&self) -> &'static str {
        "autorep"
    }

    fn describe(&self) -> &'static str {
        "AutoReP polynomial ReLU replacement with a hysteresis indicator (Peng et al. 2023; *_poly models)"
    }

    fn config_prefixes(&self) -> &'static [&'static str] {
        // AutoReP trains on the shared selective base (exp.snl) plus its
        // own hysteresis band — both determine its numerics.
        &["snl.", "autorep."]
    }

    fn run(
        &self,
        ctx: &MethodCtx,
        st: &mut ModelState,
        budget: usize,
    ) -> Result<MethodOutcome> {
        let out =
            run_autorep(ctx.sess, st, ctx.train_ds, budget, &ctx.exp.snl, &ctx.exp.autorep)?;
        Ok(MethodOutcome::Autorep(AutorepSummary::from_outcome(&out)))
    }
}

struct SenetMethod;

impl Method for SenetMethod {
    fn name(&self) -> &'static str {
        "senet"
    }

    fn describe(&self) -> &'static str {
        "SENet sensitivity-driven budget allocation + KD finetune (Kundu et al. 2023)"
    }

    fn config_prefixes(&self) -> &'static [&'static str] {
        &["senet."]
    }

    fn run(
        &self,
        ctx: &MethodCtx,
        st: &mut ModelState,
        budget: usize,
    ) -> Result<MethodOutcome> {
        let out = run_senet(ctx.sess, st, ctx.train_ds, budget, &ctx.exp.senet)?;
        Ok(MethodOutcome::Senet(SenetSummary::from_outcome(&out)))
    }
}

struct DeepreduceMethod;

impl Method for DeepreduceMethod {
    fn name(&self) -> &'static str {
        "deepreduce"
    }

    fn describe(&self) -> &'static str {
        "DeepReDuce layer-granularity ReLU dropping by sensitivity order (Jha et al. 2021)"
    }

    fn config_prefixes(&self) -> &'static [&'static str] {
        &["deepreduce."]
    }

    fn run(
        &self,
        ctx: &MethodCtx,
        st: &mut ModelState,
        budget: usize,
    ) -> Result<MethodOutcome> {
        let out = run_deepreduce(ctx.sess, st, ctx.train_ds, budget, &ctx.exp.deepreduce)?;
        Ok(MethodOutcome::Deepreduce(DeepReduceSummary::from_outcome(&out, st.budget())))
    }
}

// ---- the registry ----------------------------------------------------------

static REGISTRY: [&dyn Method; 5] =
    [&SnlMethod, &BcdMethod, &AutorepMethod, &SenetMethod, &DeepreduceMethod];

/// Every registered method, in CLI documentation order.
pub fn registry() -> &'static [&'static dyn Method] {
    &REGISTRY
}

/// Registered method names, registry order.
pub fn names() -> Vec<&'static str> {
    registry().iter().map(|m| m.name()).collect()
}

/// Look up one method by registry name; the error lists what is registered
/// (the CLI's unknown-method message — no more `unreachable!()` arms).
pub fn find(name: &str) -> Result<&'static dyn Method> {
    registry()
        .iter()
        .copied()
        .find(|m| m.name() == name)
        .ok_or_else(|| {
            anyhow!("unknown method {name:?} (registered: {})", names().join(", "))
        })
}

// ---- chains ----------------------------------------------------------------

/// A parsed method chain: one or more registered methods executed in
/// sequence on the same [`ModelState`], each stage reducing to its own
/// budget. `cdnl run snl+bcd --budgets 15000,12000` is the paper's
/// Tables 4/5 protocol (BCD on top of an SNL reference); `senet+bcd`,
/// `deepreduce+bcd`, or any other composition is the same one-liner.
pub struct ChainSpec {
    pub stages: Vec<&'static dyn Method>,
}

impl ChainSpec {
    /// Parse a `+`-separated spec (`"snl+bcd"`); every component must be a
    /// registered method name.
    pub fn parse(spec: &str) -> Result<ChainSpec> {
        let names: Vec<&str> =
            spec.split('+').map(str::trim).filter(|s| !s.is_empty()).collect();
        if names.is_empty() {
            bail!("empty method spec (registered: {})", names_joined());
        }
        let mut stages = Vec::with_capacity(names.len());
        for n in names {
            stages.push(find(n)?);
        }
        Ok(ChainSpec { stages })
    }

    /// Canonical spec string (`"snl+bcd"`), the inverse of [`Self::parse`].
    pub fn name(&self) -> String {
        self.stages.iter().map(|m| m.name()).collect::<Vec<_>>().join("+")
    }

    /// More than one stage?
    pub fn is_chain(&self) -> bool {
        self.stages.len() > 1
    }

    /// Execute the stages in order on `st` — `budgets[i]` is stage `i`'s
    /// target. Appends one `chain:<method>` [`StageRecord`] per completed
    /// stage to the ctx sink (sealed into the run manifest) and returns the
    /// per-stage typed outcomes.
    pub fn run(
        &self,
        ctx: &MethodCtx,
        st: &mut ModelState,
        budgets: &[usize],
    ) -> Result<Vec<MethodOutcome>> {
        if budgets.len() != self.stages.len() {
            bail!(
                "chain {} has {} stages but {} budget(s) were given (--budgets b1,b2,...)",
                self.name(),
                self.stages.len(),
                budgets.len()
            );
        }
        let mut outs = Vec::with_capacity(self.stages.len());
        for (i, (m, &b)) in self.stages.iter().zip(budgets).enumerate() {
            let t0 = std::time::Instant::now();
            let out = m.run(ctx, st, b)?;
            crate::info!(
                "chain stage {}/{} ({}): -> {} ReLUs ({:.1}s)",
                i + 1,
                self.stages.len(),
                m.name(),
                st.budget(),
                t0.elapsed().as_secs_f64()
            );
            ctx.stages.lock().unwrap().push(StageRecord {
                stage: format!("chain:{}", m.name()),
                // The stage index, not a checkpoint path: intermediate chain
                // states live only in memory. Unique per stage so the
                // provenance dedup (keyed on stage+path) keeps repeated
                // methods (`bcd+bcd`) as distinct records.
                path: format!("#{}", i + 1),
                budget: st.budget(),
                cached: false,
                wall_secs: t0.elapsed().as_secs_f64(),
            });
            outs.push(out);
        }
        Ok(outs)
    }
}

fn names_joined() -> String {
    names().join(", ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_names_unique_and_findable() {
        let mut seen = std::collections::HashSet::new();
        for m in registry() {
            assert!(seen.insert(m.name()), "duplicate method {}", m.name());
            assert!(find(m.name()).is_ok());
            assert!(!m.describe().is_empty());
            assert!(!m.config_prefixes().is_empty());
        }
        assert_eq!(registry().len(), 5);
        let err = format!("{:#}", find("nope").unwrap_err());
        assert!(err.contains("snl") && err.contains("deepreduce"), "{err}");
    }

    #[test]
    fn chain_parse_roundtrip_and_errors() {
        let c = ChainSpec::parse("snl+bcd").unwrap();
        assert_eq!(c.name(), "snl+bcd");
        assert!(c.is_chain());
        let single = ChainSpec::parse("senet").unwrap();
        assert!(!single.is_chain());
        assert_eq!(single.name(), "senet");
        let err = format!("{:#}", ChainSpec::parse("snl+bogus").unwrap_err());
        assert!(err.contains("registered:"), "{err}");
        assert!(ChainSpec::parse("++").is_err());
    }

    #[test]
    fn config_dump_slices_by_prefix() {
        let exp = Experiment::default();
        let snl = find("snl").unwrap();
        let dump = snl.config_dump(&exp);
        assert!(dump.keys().all(|k| k.starts_with("snl.")));
        assert!(dump.contains_key("snl.lambda0"));
        // AutoReP's slice spans the shared selective base + its own band.
        let arp = find("autorep").unwrap();
        let dump = arp.config_dump(&exp);
        assert!(dump.contains_key("autorep.hysteresis"));
        assert!(dump.contains_key("snl.kappa"));
        assert!(!dump.contains_key("bcd.drc"));
    }

    #[test]
    fn config_fingerprint_moves_with_owned_keys_only() {
        let snl = find("snl").unwrap();
        let bcd = find("bcd").unwrap();
        let mut exp = Experiment::default();
        let fp_snl = snl.config_fingerprint(&exp);
        let fp_bcd = bcd.config_fingerprint(&exp);
        exp.apply("snl.kappa", "1.75").unwrap();
        assert_ne!(snl.config_fingerprint(&exp), fp_snl);
        assert_eq!(bcd.config_fingerprint(&exp), fp_bcd, "bcd must ignore snl.* changes");
        exp.apply("bcd.rt", "99").unwrap();
        assert_ne!(bcd.config_fingerprint(&exp), fp_bcd);
    }

    #[test]
    fn outcome_serde_roundtrips_every_variant() {
        let outcomes = vec![
            MethodOutcome::Snl(SnlSummary {
                steps_run: 40,
                kappa_updates: vec![5, 15],
                final_budget: 300,
            }),
            MethodOutcome::Bcd(BcdSummary {
                sweeps: 3,
                trials_evaluated: 21,
                trials_bounded: 4,
                early_accepts: 1,
                final_budget: 256,
            }),
            MethodOutcome::Autorep(AutorepSummary {
                steps_run: 16,
                kappa_updates: vec![],
                total_flips: 9,
                final_budget: 200,
            }),
            MethodOutcome::Senet(SenetSummary {
                sensitivity: vec![0.5, 0.25],
                allocation: vec![120, 80],
                kd_first_loss: 2.5,
                kd_last_loss: 2.25,
                final_budget: 200,
            }),
            MethodOutcome::Deepreduce(DeepReduceSummary {
                dropped_layers: vec![1],
                partial_layer: Some(0),
                final_budget: 128,
            }),
            MethodOutcome::Deepreduce(DeepReduceSummary {
                dropped_layers: vec![],
                partial_layer: None,
                final_budget: 64,
            }),
        ];
        for o in outcomes {
            let text = sd::to_string(&o);
            let back: MethodOutcome = sd::from_str(&text).unwrap();
            assert_eq!(back, o, "roundtrip failed for {}", o.method());
            assert!(text.contains(o.method()), "tag missing in {text}");
            assert!(!o.describe().is_empty());
        }
        // Unknown tags and malformed shapes are rejected, not misread.
        assert!(sd::from_str::<MethodOutcome>(r#"{"warp": {}}"#).is_err());
        assert!(sd::from_str::<MethodOutcome>(r#"{"snl": {}, "bcd": {}}"#).is_err());
        assert!(sd::from_str::<MethodOutcome>("42").is_err());
    }

    #[test]
    fn outcome_accessors() {
        let o = MethodOutcome::Bcd(BcdSummary {
            sweeps: 2,
            trials_evaluated: 10,
            trials_bounded: 1,
            early_accepts: 0,
            final_budget: 77,
        });
        assert_eq!(o.method(), "bcd");
        assert_eq!(o.final_budget(), 77);
        assert!(o.describe().starts_with("bcd:"));
    }
}
