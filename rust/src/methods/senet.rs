//! SENet (Kundu et al. 2023): sensitivity-driven per-layer ReLU budget
//! allocation followed by knowledge-distillation finetune.
//!
//! Substitutions at our scale (DESIGN.md §0): the within-layer selection —
//! which the paper drives by post-ReLU activation mismatch against the
//! full-ReLU teacher — becomes a best-of-N trial search per layer, and the
//! PRAM activation-matching loss becomes logit distillation (the compiled
//! `kd_step`). The structure (sensitivity → allocation → distillation) is
//! the paper's.
//!
//! Reference: Kundu, Lu, Zhang, Liu, Beerel, *Learning to Linearize Deep
//! Neural Networks for Secure and Efficient Private Inference*, ICLR 2023
//! — <https://arxiv.org/pdf/2301.09254> (abstract in PAPERS.md).

use crate::coordinator::eval::Evaluator;
use crate::coordinator::finetune::cosine_lr;
use crate::data::{Batcher, Dataset};
use crate::methods::layer_sensitivity;
use crate::model::{Mask, ModelState};
use crate::runtime::session::Session;
use crate::util::prng::Rng;
use anyhow::{bail, Result};

// The config lives in `crate::config` with every other method config, so
// it rides `Experiment::dump`/`fingerprint` and run manifests; re-exported
// here next to the run function.
pub use crate::config::SenetConfig;

/// Outcome of a SENet run.
#[derive(Clone, Debug, Default)]
pub struct SenetOutcome {
    pub sensitivity: Vec<f64>,
    pub allocation: Vec<usize>,
    pub kd_first_loss: f32,
    pub kd_last_loss: f32,
}

/// Allocate `budget` ReLUs across layers proportionally to
/// `sensitivity[l] * size[l]`, capped at each layer's size, redistributing
/// overflow; exact to the unit.
pub fn allocate_budget(sensitivity: &[f64], sizes: &[usize], budget: usize) -> Vec<usize> {
    assert_eq!(sensitivity.len(), sizes.len());
    let total: usize = sizes.iter().sum();
    assert!(budget <= total, "budget {budget} > total ReLUs {total}");
    let mut alloc = vec![0usize; sizes.len()];
    let mut remaining = budget;
    let mut open: Vec<usize> = (0..sizes.len()).collect();
    // Iteratively hand out proportional shares; layers that saturate leave
    // the pool and their share is redistributed.
    while remaining > 0 && !open.is_empty() {
        let weights: Vec<f64> = open
            .iter()
            .map(|&l| (sensitivity[l].max(1e-6)) * (sizes[l] - alloc[l]) as f64)
            .collect();
        let wsum: f64 = weights.iter().sum();
        let mut progressed = false;
        let mut next_open = Vec::with_capacity(open.len());
        for (&l, &w) in open.iter().zip(&weights) {
            let share = ((remaining as f64) * w / wsum).floor() as usize;
            let grant = share.min(sizes[l] - alloc[l]).min(remaining);
            if grant > 0 {
                alloc[l] += grant;
                remaining -= grant;
                progressed = true;
            }
            if alloc[l] < sizes[l] {
                next_open.push(l);
            }
        }
        if !progressed {
            // Flooring starved everyone: hand out single units, heaviest first.
            let mut by_weight: Vec<(usize, f64)> =
                open.iter().copied().zip(weights.iter().copied()).collect();
            by_weight.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            for (l, _) in by_weight {
                if remaining == 0 {
                    break;
                }
                if alloc[l] < sizes[l] {
                    alloc[l] += 1;
                    remaining -= 1;
                }
            }
        }
        open = next_open;
    }
    debug_assert_eq!(alloc.iter().sum::<usize>(), budget);
    alloc
}

/// Run SENet on `st` down to `b_target` ReLUs, mutating it.
pub fn run_senet(
    sess: &Session,
    st: &mut ModelState,
    ds: &Dataset,
    b_target: usize,
    cfg: &SenetConfig,
) -> Result<SenetOutcome> {
    if b_target >= st.budget() {
        bail!("SENet: target {b_target} >= current budget {}", st.budget());
    }
    let info = sess.info();
    let mut rng = Rng::new(cfg.seed);
    let ev = Evaluator::new(sess, ds, cfg.proxy_batches)?;

    // 1. ReLU sensitivity per layer.
    let sens = layer_sensitivity(sess, &ev, st)?;

    // 2. Budget allocation across layers.
    let sizes: Vec<usize> = info.mask_layers.iter().map(|e| e.size).collect();
    let alloc = allocate_budget(&sens, &sizes, b_target);

    // 3. Within-layer keep-set: best of `layer_trials` random candidates,
    //    scored jointly with previously-fixed layers.
    let params = ev.upload_params(&st.params)?;
    let mut dense = vec![0.0f32; info.mask_size];
    for (l, entry) in info.mask_layers.iter().enumerate() {
        let keep = alloc[l];
        if keep == 0 {
            continue;
        }
        if keep == entry.size {
            for i in entry.offset..entry.offset + entry.size {
                dense[i] = 1.0;
            }
            continue;
        }
        let mut best: Option<(f64, Vec<usize>)> = None;
        for _ in 0..cfg.layer_trials.max(1) {
            let cand: Vec<usize> = rng
                .sample_indices(entry.size, keep)
                .into_iter()
                .map(|j| entry.offset + j)
                .collect();
            for &i in &cand {
                dense[i] = 1.0;
            }
            let acc = ev.accuracy(&params, &dense)?;
            for &i in &cand {
                dense[i] = 0.0;
            }
            if best.as_ref().map(|(a, _)| acc > *a).unwrap_or(true) {
                best = Some((acc, cand));
            }
        }
        for i in best.expect("layer_trials >= 1").1 {
            dense[i] = 1.0;
        }
    }
    st.mask = Mask::from_dense(&dense);
    debug_assert_eq!(st.budget(), b_target);

    // 4. KD finetune: teacher logits come from the pre-reduction weights
    //    with the full-ReLU mask, computed per batch via `forward`.
    let teacher_params = st.params.clone();
    let full_mask = vec![1.0f32; info.mask_size];
    st.reset_momentum();
    let mut batcher = Batcher::new(ds, sess.batch, &mut rng);
    let mut out = SenetOutcome {
        sensitivity: sens,
        allocation: alloc,
        ..Default::default()
    };
    for step in 0..cfg.kd_steps {
        let (x, y) = batcher.next_batch(&mut rng);
        let t_logits = sess.forward(&teacher_params, &full_mask, &x)?;
        let lr = cosine_lr(cfg.kd_lr, step, cfg.kd_steps);
        let loss = sess.kd_step(st, &x, &y, &t_logits, lr, cfg.kd_temp)?;
        if step == 0 {
            out.kd_first_loss = loss;
        }
        out.kd_last_loss = loss;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocation_exact_and_capped() {
        let alloc = allocate_budget(&[1.0, 0.5, 2.0], &[10, 10, 4], 12);
        assert_eq!(alloc.iter().sum::<usize>(), 12);
        assert!(alloc[2] <= 4);
        // Most sensitive (per unit) layer should not be starved.
        assert!(alloc[2] > 0);
    }

    #[test]
    fn allocation_full_budget() {
        let alloc = allocate_budget(&[0.1, 0.2], &[5, 7], 12);
        assert_eq!(alloc, vec![5, 7]);
    }

    #[test]
    fn allocation_zero_budget() {
        assert_eq!(allocate_budget(&[1.0, 1.0], &[5, 5], 0), vec![0, 0]);
    }

    #[test]
    fn allocation_zero_sensitivity_still_exact() {
        let alloc = allocate_budget(&[0.0, 0.0, 0.0], &[8, 8, 8], 10);
        assert_eq!(alloc.iter().sum::<usize>(), 10);
    }

    #[test]
    fn higher_sensitivity_gets_more() {
        let alloc = allocate_budget(&[5.0, 0.1], &[100, 100], 50);
        assert!(alloc[0] > alloc[1], "{alloc:?}");
    }
}
