//! DeepReDuce (Jha et al. 2021): manual, layer-granularity ReLU reduction.
//!
//! The original characterizes ReLU criticality per stage and drops whole
//! ReLU layers in increasing order of importance, finetuning after. We
//! drive the drop order by measured layer sensitivity (shared with SENet)
//! instead of hand analysis — the same coarse-granularity policy, made
//! reproducible. The final layer is partially dropped to land exactly on
//! the budget.
//!
//! Reference: Jha, Ghodsi, Garg, Reagen, *DeepReDuce: ReLU Reduction for
//! Fast Private Inference*, ICML 2021 —
//! <https://arxiv.org/pdf/2103.01396> (abstract in PAPERS.md).

use crate::coordinator::eval::Evaluator;
use crate::coordinator::finetune::finetune;
use crate::data::Dataset;
use crate::methods::layer_sensitivity;
use crate::model::ModelState;
use crate::runtime::session::Session;
use crate::util::prng::Rng;
use anyhow::{bail, Result};

// The config lives in `crate::config` with every other method config, so
// it rides `Experiment::dump`/`fingerprint` and run manifests; re-exported
// here next to the run function.
pub use crate::config::DeepReduceConfig;

/// Outcome of one DeepReDuce run.
#[derive(Clone, Debug, Default)]
pub struct DeepReduceOutcome {
    /// Layers fully linearized, in drop order.
    pub dropped_layers: Vec<usize>,
    /// Layer partially dropped to hit the budget exactly (if any).
    pub partial_layer: Option<usize>,
}

/// Run DeepReDuce on `st` down to `b_target` ReLUs, mutating it.
pub fn run_deepreduce(
    sess: &Session,
    st: &mut ModelState,
    ds: &Dataset,
    b_target: usize,
    cfg: &DeepReduceConfig,
) -> Result<DeepReduceOutcome> {
    if b_target >= st.budget() {
        bail!("DeepReDuce: target {b_target} >= current budget {}", st.budget());
    }
    let info = sess.info();
    let mut rng = Rng::new(cfg.seed);
    let ev = Evaluator::new(sess, ds, cfg.proxy_batches)?;
    let sens = layer_sensitivity(sess, &ev, st)?;

    // Drop whole layers, least sensitive first.
    let mut order: Vec<usize> = (0..info.mask_layers.len()).collect();
    order.sort_by(|&a, &b| sens[a].partial_cmp(&sens[b]).unwrap());

    let mut out = DeepReduceOutcome::default();
    for l in order {
        if st.budget() <= b_target {
            break;
        }
        let layer_present: usize = {
            let e = &info.mask_layers[l];
            (e.offset..e.offset + e.size).filter(|&i| st.mask.is_present(i)).count()
        };
        if layer_present == 0 {
            continue;
        }
        if st.budget() - layer_present >= b_target {
            st.mask.remove_layer(info, l);
            out.dropped_layers.push(l);
        } else {
            // Partial drop: remove a random subset of this layer to land
            // exactly on the budget (the paper's finest manual granularity
            // is channel/layer; random within-layer is the neutral choice).
            let excess = st.budget() - b_target;
            let e = &info.mask_layers[l];
            let present: Vec<usize> = (e.offset..e.offset + e.size)
                .filter(|&i| st.mask.is_present(i))
                .collect();
            let drop: Vec<usize> = rng
                .sample_indices(present.len(), excess)
                .into_iter()
                .map(|j| present[j])
                .collect();
            st.mask.apply_removal(&drop)?;
            out.partial_layer = Some(l);
        }
    }
    debug_assert_eq!(st.budget(), b_target);

    let mut ft_rng = rng.fork(0xD4);
    finetune(sess, st, ds, cfg.finetune_steps, cfg.finetune_lr, &mut ft_rng)?;
    Ok(out)
}
