//! Selective Network Linearization (Cho et al. 2022b) — the paper's main
//! baseline *and* the reference-model producer BCD starts from.
//!
//! Training alternates compiled `snl_step` calls (CE + λ·||α||₁, projected
//! to α ∈ [0,1]) with an L3-owned λ schedule: when the thresholded budget
//! stalls, λ ← κ·λ (the mechanism the paper's Fig. 9/10 debug section
//! analyses). The run records everything those figures need: λ trace,
//! budget-vs-step trace, mask snapshots (IoU dynamics, Fig. 6) and sampled
//! α trajectories (Fig. 11).
//!
//! Reference: Cho, Joshi, Garg, Reagen, Hegde, *Selective Network
//! Linearization for Efficient Private Inference*, ICML 2022 —
//! <https://arxiv.org/pdf/2202.02340> (abstract in PAPERS.md).

use crate::config::SnlConfig;
use crate::coordinator::finetune::finetune;
use crate::data::{Batcher, Dataset};
use crate::methods::top_k_mask;
use crate::model::{Mask, ModelState};
use crate::runtime::session::Session;
use crate::util::prng::Rng;
use anyhow::{bail, Result};

/// Full trace of one SNL run (everything Figs. 6/9/10/11 consume).
#[derive(Clone, Debug, Default)]
pub struct SnlOutcome {
    pub steps_run: usize,
    /// (step, λ) at every schedule check.
    pub lambda_trace: Vec<(usize, f32)>,
    /// (step, thresholded budget) at every schedule check (Fig. 10a).
    pub budget_trace: Vec<(usize, usize)>,
    /// Steps at which λ ← κ·λ fired (Fig. 10b's counter).
    pub kappa_updates: Vec<usize>,
    /// Binarized mask snapshots at every check (Fig. 6 IoU dynamics).
    pub snapshots: Vec<(usize, Mask)>,
    /// Trajectories of `track_alphas` randomly-chosen α entries (Fig. 11):
    /// `alpha_traces[k]` = that α's value at every check.
    pub alpha_indices: Vec<usize>,
    pub alpha_traces: Vec<Vec<f32>>,
    /// Final budget after hard thresholding.
    pub final_budget: usize,
}

/// Run SNL on `st` down to `b_target` ReLUs, mutating it.
///
/// On return `st.mask` is binary with exactly `b_target` present ReLUs and
/// the weights have been finetuned under the binarized mask (the paper's
/// "hard thresholding + finetune" stage).
pub fn run_snl(
    sess: &Session,
    st: &mut ModelState,
    ds: &Dataset,
    b_target: usize,
    cfg: &SnlConfig,
    track_alphas: usize,
) -> Result<SnlOutcome> {
    if b_target >= st.budget() {
        bail!("SNL: target {b_target} >= current budget {}", st.budget());
    }
    let mut rng = Rng::new(cfg.seed);
    let mut batcher = Batcher::new(ds, sess.batch, &mut rng);

    // Alphas start at the current (binary) mask: present ReLUs at 1.0,
    // removed at 0.0. Projected SGD keeps them in [0, 1].
    let mut alphas = st.mask.to_tensor();
    let mut lam = cfg.lambda0;
    let mut out = SnlOutcome::default();

    // Pick alpha entries to trace (Fig. 11) among initially-present ones.
    if track_alphas > 0 {
        let present: Vec<usize> =
            (0..alphas.len()).filter(|&i| alphas.data[i] > 0.5).collect();
        let k = track_alphas.min(present.len());
        out.alpha_indices = rng
            .sample_indices(present.len(), k)
            .into_iter()
            .map(|j| present[j])
            .collect();
        out.alpha_traces = vec![Vec::new(); k];
    }

    let mut last_budget = usize::MAX;
    let mut stalled = 0usize;
    for step in 0..cfg.max_steps {
        let (x, y) = batcher.next_batch(&mut rng);
        sess.snl_step(
            &mut st.params,
            &mut st.mom,
            &mut alphas,
            &x,
            &y,
            cfg.lr,
            cfg.alpha_lr,
            lam,
        )?;
        out.steps_run = step + 1;

        if (step + 1) % cfg.steps_per_check != 0 {
            continue;
        }
        let budget = alphas.data.iter().filter(|&&a| a >= cfg.threshold).count();
        out.lambda_trace.push((step + 1, lam));
        out.budget_trace.push((step + 1, budget));
        out.snapshots.push((
            budget,
            Mask::from_dense(
                &alphas
                    .data
                    .iter()
                    .map(|&a| if a >= cfg.threshold { 1.0 } else { 0.0 })
                    .collect::<Vec<f32>>(),
            ),
        ));
        for (k, &i) in out.alpha_indices.iter().enumerate() {
            out.alpha_traces[k].push(alphas.data[i]);
        }
        crate::debug!("snl step {}: budget={budget} lam={lam:.2e}", step + 1);

        if budget <= b_target {
            break; // reached the target budget
        }
        if budget >= last_budget {
            stalled += 1;
            if stalled >= cfg.stall_patience {
                // Reduction stalled: crank the lasso coefficient (Fig. 9/10).
                lam *= cfg.kappa;
                out.kappa_updates.push(step + 1);
                stalled = 0;
            }
        } else {
            stalled = 0;
        }
        last_budget = budget;
    }

    // Hard thresholding: keep exactly the top-B_target alphas. (A fixed 0.5
    // threshold can over/under-shoot; top-k guarantees the budget, and is
    // how SNL's official code meets exact budgets.)
    st.mask = top_k_mask(&alphas.data, b_target);
    out.final_budget = st.mask.count();

    // Finetune under the binarized mask to recover the thresholding loss.
    let mut ft_rng = rng.fork(0x57E9);
    finetune(sess, st, ds, cfg.finetune_steps, cfg.finetune_lr, &mut ft_rng)?;
    Ok(out)
}

/// Containment-IoU between consecutive snapshot masks (Fig. 6a series).
pub fn consecutive_iou(snapshots: &[(usize, Mask)]) -> Vec<f64> {
    snapshots
        .windows(2)
        .map(|w| {
            let (_, ref larger) = w[0]; // budgets shrink over time
            let (_, ref smaller) = w[1];
            smaller.containment(larger)
        })
        .collect()
}
