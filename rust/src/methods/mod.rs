//! Baseline ReLU-reduction methods the paper compares against (and composes
//! with):
//!
//! - [`snl`] — Selective Network Linearization (Cho et al. 2022b): soft
//!   alpha masks trained under `CE + λ·||α||₁` with the λ←κ·λ schedule,
//!   hard-thresholded then finetuned.
//! - [`autorep`] — AutoReP (Peng et al. 2023): quadratic-polynomial ReLU
//!   replacement with a trainable indicator stabilized by hysteresis.
//! - [`senet`] — SENet (Kundu et al. 2023): per-layer ReLU-sensitivity
//!   budget allocation + knowledge-distillation finetune.
//! - [`deepreduce`] — DeepReDuce (Jha et al. 2021): manual layer-granularity
//!   ReLU dropping by sensitivity order.
//!
//! All methods mutate a [`crate::model::ModelState`] toward a target ReLU
//! budget; the paper's BCD ([`crate::coordinator::bcd`]) can then run *on
//! top of* any of their outputs (paper Fig. 4).
//!
//! Every method (the four baselines plus BCD itself) is registered in
//! [`registry`] behind the [`Method`] trait — one typed `run(ctx, state,
//! budget) -> MethodOutcome` entry point with per-method config slices of
//! [`crate::config::Experiment`] and chainable stages ([`ChainSpec`],
//! e.g. `snl+bcd`). See DESIGN.md §10.
//!
//! # References (see PAPERS.md for the retrieved abstracts)
//!
//! - Cho, Joshi, Garg, Reagen, Hegde, *Selective Network Linearization for
//!   Efficient Private Inference*, ICML 2022 —
//!   <https://arxiv.org/pdf/2202.02340>
//! - Kundu, Lu, Zhang, Liu, Beerel, *Learning to Linearize Deep Neural
//!   Networks for Secure and Efficient Private Inference* (SENet),
//!   ICLR 2023 — <https://arxiv.org/pdf/2301.09254>
//! - Jha, Ghodsi, Garg, Reagen, *DeepReDuce: ReLU Reduction for Fast
//!   Private Inference*, ICML 2021 — <https://arxiv.org/pdf/2103.01396>
//! - Peng et al., *AutoReP: Automatic ReLU Replacement for Fast Private
//!   Network Inference*, ICCV 2023 — not in the retrieved set; the closest
//!   retrieved relative is Kundu et al., *Making Models Shallow Again*
//!   — <https://arxiv.org/pdf/2304.13274>

pub mod autorep;
pub mod deepreduce;
pub mod registry;
pub mod senet;
pub mod snl;

pub use registry::{ChainSpec, Method, MethodCtx, MethodOutcome};

use crate::coordinator::eval::Evaluator;
use crate::model::{Mask, ModelState};
use crate::runtime::session::Session;
use anyhow::Result;

/// Per-layer accuracy sensitivity: proxy-accuracy drop when the layer's
/// ReLUs are all removed (shared by SENet and DeepReDuce).
pub fn layer_sensitivity(
    sess: &Session,
    ev: &Evaluator,
    st: &ModelState,
) -> Result<Vec<f64>> {
    let info = sess.info();
    let params = ev.upload_params(&st.params)?;
    let base = ev.accuracy(&params, st.mask.dense())?;
    let mut sens = Vec::with_capacity(info.mask_layers.len());
    for l in 0..info.mask_layers.len() {
        let mut m = st.mask.clone();
        m.remove_layer(info, l);
        let acc = ev.accuracy(&params, m.dense())?;
        sens.push((base - acc).max(0.0));
    }
    Ok(sens)
}

/// Binarize a soft score vector to exactly `budget` ones by keeping the
/// top-`budget` scores (used by SNL/AutoReP final hard thresholding —
/// guarantees the target is met exactly, unlike a fixed 0.5 threshold).
pub fn top_k_mask(scores: &[f32], budget: usize) -> Mask {
    assert!(budget <= scores.len());
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal));
    let mut dense = vec![0.0f32; scores.len()];
    for &i in idx.iter().take(budget) {
        dense[i] = 1.0;
    }
    Mask::from_dense(&dense)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top_k_keeps_largest() {
        let m = top_k_mask(&[0.1, 0.9, 0.5, 0.7], 2);
        assert_eq!(m.count(), 2);
        assert!(m.is_present(1) && m.is_present(3));
    }

    #[test]
    fn top_k_zero_and_full() {
        assert_eq!(top_k_mask(&[0.3, 0.4], 0).count(), 0);
        assert_eq!(top_k_mask(&[0.3, 0.4], 2).count(), 2);
    }
}
