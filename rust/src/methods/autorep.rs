//! AutoReP (Peng et al. 2023): replace selected ReLUs with learnable
//! quadratic polynomials instead of the identity.
//!
//! Two differences from SNL: (1) the replacement function — this method
//! runs on the `*_poly` model variants whose masked activation computes
//! `m·ReLU(x) + (1−m)·(c₂x² + c₁x + c₀)` with learnable per-layer
//! coefficients (the L1 `masked_poly` Pallas kernel); (2) the indicator is
//! stabilized by a **hysteresis loop**: a ReLU's binary state only flips
//! when its score crosses `threshold ± hysteresis/2`, which damps the
//! oscillation the paper's Discussion section attributes to plain SGD
//! indicators.
//!
//! Reference: Peng et al., *AutoReP: Automatic ReLU Replacement for Fast
//! Private Network Inference*, ICCV 2023 (not in the PAPERS.md retrieved
//! set; the closest retrieved relative on learned non-linearity reduction
//! is Kundu et al., *Making Models Shallow Again* —
//! <https://arxiv.org/pdf/2304.13274>).

use crate::config::SnlConfig;
use crate::coordinator::finetune::finetune;
use crate::data::{Batcher, Dataset};
use crate::methods::top_k_mask;
use crate::model::ModelState;
use crate::runtime::session::Session;
use crate::util::prng::Rng;
use anyhow::{bail, Result};

// The config lives in `crate::config` with every other method config, so
// it rides `Experiment::dump`/`fingerprint` and run manifests; re-exported
// here next to the run function.
pub use crate::config::AutorepConfig;

/// Trace of one AutoReP run.
#[derive(Clone, Debug, Default)]
pub struct AutorepOutcome {
    pub steps_run: usize,
    pub budget_trace: Vec<(usize, usize)>,
    /// Indicator flips per check — the stability metric hysteresis improves.
    pub flips_trace: Vec<(usize, usize)>,
    pub kappa_updates: Vec<usize>,
    pub final_budget: usize,
}

/// Run AutoReP on `st` (which must belong to a `*_poly` model variant)
/// down to `b_target` ReLUs. `base` is the shared selective-training
/// schedule (an [`Experiment`](crate::config::Experiment) passes its
/// `snl` config); `cfg` carries the AutoReP-specific hysteresis band.
pub fn run_autorep(
    sess: &Session,
    st: &mut ModelState,
    ds: &Dataset,
    b_target: usize,
    base: &SnlConfig,
    cfg: &AutorepConfig,
) -> Result<AutorepOutcome> {
    if !sess.info().poly {
        bail!("AutoReP requires a *_poly model variant, got {}", sess.key);
    }
    if b_target >= st.budget() {
        bail!("AutoReP: target {b_target} >= current budget {}", st.budget());
    }
    let mut rng = Rng::new(base.seed);
    let mut batcher = Batcher::new(ds, sess.batch, &mut rng);

    let mut alphas = st.mask.to_tensor();
    // The hysteresis indicator state starts at the current binary mask.
    let mut indicator: Vec<bool> = st.mask.dense().iter().map(|&v| v > 0.5).collect();
    let (t_lo, t_hi) = (
        base.threshold - cfg.hysteresis / 2.0,
        base.threshold + cfg.hysteresis / 2.0,
    );

    let mut lam = base.lambda0;
    let mut out = AutorepOutcome::default();
    let mut last_budget = usize::MAX;
    let mut stalled = 0usize;

    for step in 0..base.max_steps {
        let (x, y) = batcher.next_batch(&mut rng);
        // The same selective step; the poly replacement lives inside the
        // compiled graph (alphas gate ReLU vs learnable quadratic).
        sess.snl_step(
            &mut st.params,
            &mut st.mom,
            &mut alphas,
            &x,
            &y,
            base.lr,
            base.alpha_lr,
            lam,
        )?;
        out.steps_run = step + 1;

        if (step + 1) % base.steps_per_check != 0 {
            continue;
        }
        // Hysteresis update: flip only on band exit.
        let mut flips = 0usize;
        for (i, ind) in indicator.iter_mut().enumerate() {
            let a = alphas.data[i];
            let next = if *ind { a >= t_lo } else { a > t_hi };
            if next != *ind {
                flips += 1;
                *ind = next;
            }
        }
        let budget = indicator.iter().filter(|&&b| b).count();
        out.budget_trace.push((step + 1, budget));
        out.flips_trace.push((step + 1, flips));
        crate::debug!(
            "autorep step {}: budget={budget} flips={flips} lam={lam:.2e}",
            step + 1
        );

        if budget <= b_target {
            break;
        }
        if budget >= last_budget {
            stalled += 1;
            if stalled >= base.stall_patience {
                lam *= base.kappa;
                out.kappa_updates.push(step + 1);
                stalled = 0;
            }
        } else {
            stalled = 0;
        }
        last_budget = budget;
    }

    // Final selection honors the hysteresis indicator where it is decisive
    // and breaks ties by alpha magnitude — exactly b_target ReLUs survive.
    let scores: Vec<f32> = alphas
        .data
        .iter()
        .zip(&indicator)
        .map(|(&a, &ind)| if ind { 1.0 + a } else { a })
        .collect();
    st.mask = top_k_mask(&scores, b_target);
    out.final_budget = st.mask.count();

    let mut ft_rng = rng.fork(0xA9E9);
    finetune(sess, st, ds, base.finetune_steps, base.finetune_lr, &mut ft_rng)?;
    Ok(out)
}

/// Count indicator flips a plain (hysteresis-free) threshold would produce
/// on the same alpha trace — the ablation quantifying what hysteresis buys.
pub fn flips_without_hysteresis(alpha_checks: &[Vec<f32>], threshold: f32) -> usize {
    let mut flips = 0;
    for w in alpha_checks.windows(2) {
        flips += w[0]
            .iter()
            .zip(&w[1])
            .filter(|(&a, &b)| (a >= threshold) != (b >= threshold))
            .count();
    }
    flips
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_threshold_flip_count() {
        let checks = vec![vec![0.4, 0.6], vec![0.6, 0.4], vec![0.4, 0.6]];
        // Both entries flip at both transitions.
        assert_eq!(flips_without_hysteresis(&checks, 0.5), 4);
    }

    #[test]
    fn default_config_band_is_sane() {
        let c = AutorepConfig::default();
        assert!(c.hysteresis > 0.0 && c.hysteresis < 1.0);
    }
}
