//! Shared numeric kernels of the reference backend (DESIGN.md §11).
//!
//! Every dense-math loop of [`crate::runtime::reference`] lives here —
//! forward GEMMs, the fused masked activation, the scoring epilogue, and
//! the backward helpers — so the single-trial path, the batched
//! multi-hypothesis path and the training entry points all run the *same*
//! floating-point code. The bit-identical staged/batched scoring contract
//! (DESIGN.md §8) then holds by construction: there is one summation order
//! and one epilogue, not two implementations kept in sync by hand.
//!
//! # Determinism discipline
//!
//! f32 addition is not associative, so every kernel here preserves the
//! accumulation order of the naive triple loop it replaced:
//!
//! - [`gemm_bias_into`] accumulates each output element over the input
//!   index `i` in ascending order, one add per `i` (with the `x[i] != 0`
//!   skip — skipping an exact-zero term never changes the sum). Blocking
//!   tiles the *output* dimension ([`GEMM_TILE_J`]) and the inner loop is
//!   unrolled [`GEMM_UNROLL`]-wide across *independent* output elements;
//!   neither reorders any single element's additions.
//! - [`dinput`]'s dot products stay strictly sequential: splitting a
//!   serial reduction into unrolled partial sums would change its bits.
//! - [`softmax_ce_batch`] accumulates the softmax denominator in
//!   ascending class order — the same sequence the materialized
//!   `exps.iter().sum()` of the scalar implementation used — whether or
//!   not the gradient is requested, so scoring-only calls (the trial hot
//!   path) and training calls produce identical losses.

// Index-heavy numeric kernels: explicit loops over computed flat offsets
// read better than iterator chains here.
#![allow(clippy::needless_range_loop)]

/// Inner-loop unroll width of [`gemm_bias_into`] / [`matgrad`] (the
/// `axpy` over independent output elements).
pub const GEMM_UNROLL: usize = 8;

/// Output-dimension tile of [`gemm_bias_into`]: the `z` tile stays hot in
/// L1 across the whole input sweep while `w` streams through once.
pub const GEMM_TILE_J: usize = 256;

/// `y[j] += a * x[j]` over independent elements, manually unrolled
/// [`GEMM_UNROLL`]-wide. Each `y[j]` receives exactly one add, so the
/// per-element accumulation order of any caller loop is untouched.
#[inline]
fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut xc = x.chunks_exact(GEMM_UNROLL);
    let mut yc = y.chunks_exact_mut(GEMM_UNROLL);
    for (xs, ys) in (&mut xc).zip(&mut yc) {
        ys[0] += a * xs[0];
        ys[1] += a * xs[1];
        ys[2] += a * xs[2];
        ys[3] += a * xs[3];
        ys[4] += a * xs[4];
        ys[5] += a * xs[5];
        ys[6] += a * xs[6];
        ys[7] += a * xs[7];
    }
    for (ys, &xs) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *ys += a * xs;
    }
}

/// `z = x @ w + b` for row-major `x [bsz, d_in]`, `w [d_in, d_out]`,
/// writing into `z` (cleared and resized — callers on the batched hot
/// path reuse one buffer across hypotheses instead of allocating).
///
/// Accumulation order per output element: `i` ascending, one add per
/// nonzero `x[i]` — bit-identical to the naive loop (see module docs).
pub fn gemm_bias_into(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    bsz: usize,
    d_in: usize,
    d_out: usize,
    z: &mut Vec<f32>,
) {
    debug_assert_eq!(x.len(), bsz * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(bias.len(), d_out);
    z.clear();
    z.resize(bsz * d_out, 0.0);
    for bi in 0..bsz {
        let xr = &x[bi * d_in..(bi + 1) * d_in];
        let zr = &mut z[bi * d_out..(bi + 1) * d_out];
        zr.copy_from_slice(bias);
        let mut j0 = 0;
        while j0 < d_out {
            let j1 = (j0 + GEMM_TILE_J).min(d_out);
            let zt = &mut zr[j0..j1];
            for (i, &xv) in xr.iter().enumerate() {
                // Exact zeros are common (ReLU outputs feeding the next
                // layer); skipping them adds nothing to any sum.
                if xv != 0.0 {
                    axpy(xv, &w[i * d_out + j0..i * d_out + j1], zt);
                }
            }
            j0 = j1;
        }
    }
}

/// Allocating convenience wrapper over [`gemm_bias_into`].
pub fn gemm_bias(x: &[f32], w: &[f32], bias: &[f32], bsz: usize, d_in: usize, d_out: usize) -> Vec<f32> {
    let mut z = Vec::new();
    gemm_bias_into(x, w, bias, bsz, d_in, d_out, &mut z);
    z
}

/// The non-ReLU branch `g` taken where the mask is 0: identity in the
/// paper setting, the AutoReP quadratic for `_poly` variants.
pub fn g(z: f32, poly: bool) -> f32 {
    if poly {
        0.25 * z * z + 0.5 * z
    } else {
        z
    }
}

pub fn g_prime(z: f32, poly: bool) -> f32 {
    if poly {
        0.5 * z + 0.5
    } else {
        1.0
    }
}

/// Fused masked activation `a = m*relu(z) + (1-m)*g(z)` per unit (mask is
/// per-unit, broadcast over the batch), written into a reusable buffer —
/// the per-hypothesis step of the batched trial path.
pub fn mask_act_into(z: &[f32], mask: &[f32], bsz: usize, d: usize, poly: bool, a: &mut Vec<f32>) {
    debug_assert_eq!(z.len(), bsz * d);
    debug_assert_eq!(mask.len(), d);
    a.clear();
    a.reserve(z.len());
    for bi in 0..bsz {
        let zr = &z[bi * d..(bi + 1) * d];
        for (j, &zv) in zr.iter().enumerate() {
            let m = mask[j];
            a.push(m * zv.max(0.0) + (1.0 - m) * g(zv, poly));
        }
    }
}

/// Allocating convenience wrapper over [`mask_act_into`].
pub fn mask_act(z: &[f32], mask: &[f32], bsz: usize, d: usize, poly: bool) -> Vec<f32> {
    let mut a = Vec::new();
    mask_act_into(z, mask, bsz, d, poly, &mut a);
    a
}

/// The scoring epilogue: mean cross-entropy + argmax-correct count for
/// logits `[bsz, k]`, optionally also writing `dL/dlogits` (training
/// callers). Argmax ties resolve to the highest index, matching
/// [`crate::tensor::Tensor::argmax_rows`].
///
/// This is the ONE epilogue of every scoring path — `eval_batch`,
/// `eval_from`, both batched multi variants, and the training steps — so
/// full, staged and batched trial scores agree bit for bit. The
/// scoring-only mode (`dlogits = None`) allocates nothing and computes
/// the exact same loss: the denominator accumulates in ascending class
/// order either way.
pub fn softmax_ce_batch(
    logits: &[f32],
    y: &[i32],
    k: usize,
    mut dlogits: Option<&mut [f32]>,
) -> (f32, usize) {
    let bsz = y.len();
    debug_assert_eq!(logits.len(), bsz * k);
    let mut loss = 0.0f32;
    let mut correct = 0usize;
    for bi in 0..bsz {
        let row = &logits[bi * k..(bi + 1) * k];
        let mut am = 0usize;
        let mut max = f32::NEG_INFINITY;
        for (j, &v) in row.iter().enumerate() {
            if v >= max {
                max = v;
                am = j;
            }
        }
        let target = y[bi] as usize % k;
        if am == target {
            correct += 1;
        }
        let mut denom = 0.0f32;
        let mut e_target = 0.0f32;
        match dlogits.as_deref_mut() {
            Some(d) => {
                let dr = &mut d[bi * k..(bi + 1) * k];
                for (j, &v) in row.iter().enumerate() {
                    let e = (v - max).exp();
                    dr[j] = e;
                    denom += e;
                    if j == target {
                        e_target = e;
                    }
                }
                for (j, dj) in dr.iter_mut().enumerate() {
                    let pj = *dj / denom;
                    *dj = (pj - if j == target { 1.0 } else { 0.0 }) / bsz as f32;
                }
            }
            None => {
                for (j, &v) in row.iter().enumerate() {
                    let e = (v - max).exp();
                    denom += e;
                    if j == target {
                        e_target = e;
                    }
                }
            }
        }
        loss -= (e_target / denom).max(1e-12).ln();
    }
    (loss / bsz as f32, correct)
}

/// [`softmax_ce_batch`] with the gradient materialized — the training
/// entry points' calling convention.
pub fn softmax_ce(logits: &[f32], y: &[i32], k: usize) -> (f32, usize, Vec<f32>) {
    let mut dlogits = vec![0.0f32; logits.len()];
    let (loss, correct) = softmax_ce_batch(logits, y, k, Some(&mut dlogits));
    (loss, correct, dlogits)
}

/// Temperature softmax of one row (knowledge distillation).
pub fn softmax_t(row: &[f32], temp: f32) -> Vec<f32> {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = row.iter().map(|&v| ((v - max) / temp).exp()).collect();
    let denom: f32 = exps.iter().sum();
    exps.into_iter().map(|e| e / denom).collect()
}

/// Accumulate `dw += x^T dz` and `db += colsum(dz)`. Per `dw` element:
/// one add per batch row, `bi` ascending (the unrolled `axpy` spans
/// independent elements only).
#[allow(clippy::too_many_arguments)]
pub fn matgrad(
    x: &[f32],
    dz: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    bsz: usize,
    d_in: usize,
    d_out: usize,
) {
    for bi in 0..bsz {
        let xr = &x[bi * d_in..(bi + 1) * d_in];
        let dzr = &dz[bi * d_out..(bi + 1) * d_out];
        for (j, &dv) in dzr.iter().enumerate() {
            db[j] += dv;
        }
        for (i, &xv) in xr.iter().enumerate() {
            if xv != 0.0 {
                axpy(xv, dzr, &mut dw[i * d_out..(i + 1) * d_out]);
            }
        }
    }
}

/// `dx = dz @ w^T`. Each output is a serial dot product and stays
/// strictly sequential — unrolling a reduction would change its bits.
pub fn dinput(dz: &[f32], w: &[f32], bsz: usize, d_in: usize, d_out: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; bsz * d_in];
    for bi in 0..bsz {
        let dzr = &dz[bi * d_out..(bi + 1) * d_out];
        let dxr = &mut dx[bi * d_in..(bi + 1) * d_in];
        for (i, dxi) in dxr.iter_mut().enumerate() {
            let wr = &w[i * d_out..(i + 1) * d_out];
            let mut acc = 0.0f32;
            for (&dv, &wv) in dzr.iter().zip(wr) {
                acc += dv * wv;
            }
            *dxi = acc;
        }
    }
    dx
}

/// Backprop through the masked activation: returns (`dL/dmask` per unit,
/// `dL/dz`).
pub fn dact(
    z: &[f32],
    mask: &[f32],
    da: &[f32],
    bsz: usize,
    d: usize,
    poly: bool,
) -> (Vec<f32>, Vec<f32>) {
    let mut dmask = vec![0.0f32; d];
    let mut dz = vec![0.0f32; z.len()];
    for bi in 0..bsz {
        for j in 0..d {
            let idx = bi * d + j;
            let zv = z[idx];
            let m = mask[j];
            let relu_grad = if zv > 0.0 { 1.0 } else { 0.0 };
            dz[idx] = da[idx] * (m * relu_grad + (1.0 - m) * g_prime(zv, poly));
            dmask[j] += da[idx] * (zv.max(0.0) - g(zv, poly));
        }
    }
    (dmask, dz)
}

/// SGD with momentum: `mom = mu*mom + g; p -= lr*mom`.
pub fn sgd_momentum(p: &[f32], mom: &[f32], grad: &[f32], lr: f32, mu: f32) -> (Vec<f32>, Vec<f32>) {
    let mut new_p = Vec::with_capacity(p.len());
    let mut new_mom = Vec::with_capacity(mom.len());
    for i in 0..p.len() {
        let m = mu * mom[i] + grad[i];
        new_mom.push(m);
        new_p.push(p[i] - lr * m);
    }
    (new_p, new_mom)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// The pre-kernel naive affine, kept verbatim as the bit-level oracle.
    fn naive_affine(x: &[f32], w: &[f32], b: &[f32], bsz: usize, d_in: usize, d_out: usize) -> Vec<f32> {
        let mut z = vec![0.0f32; bsz * d_out];
        for bi in 0..bsz {
            let xr = &x[bi * d_in..(bi + 1) * d_in];
            let zr = &mut z[bi * d_out..(bi + 1) * d_out];
            zr.copy_from_slice(b);
            for (i, &xv) in xr.iter().enumerate() {
                if xv != 0.0 {
                    let wr = &w[i * d_out..(i + 1) * d_out];
                    for (zj, &wj) in zr.iter_mut().zip(wr) {
                        *zj += xv * wj;
                    }
                }
            }
        }
        z
    }

    fn pseudo(rng: &mut Rng, n: usize, zero_every: usize) -> Vec<f32> {
        (0..n)
            .map(|i| {
                if zero_every > 0 && i % zero_every == 0 {
                    0.0
                } else {
                    rng.normal()
                }
            })
            .collect()
    }

    #[test]
    fn blocked_gemm_matches_naive_bitwise_on_ragged_shapes() {
        let mut rng = Rng::new(0xB10C);
        // Shapes straddling the unroll (8) and tile (256) boundaries,
        // including the degenerate 1s and a >1-tile output.
        for &(bsz, d_in, d_out) in &[
            (1usize, 1usize, 1usize),
            (2, 5, 3),
            (3, 8, 8),
            (1, 13, 7),
            (4, 9, 17),
            (2, 31, 255),
            (2, 7, 256),
            (1, 10, 259),
            (5, 16, 300),
        ] {
            let x = pseudo(&mut rng, bsz * d_in, 3);
            let w = pseudo(&mut rng, d_in * d_out, 0);
            let b = pseudo(&mut rng, d_out, 0);
            let want = naive_affine(&x, &w, &b, bsz, d_in, d_out);
            let got = gemm_bias(&x, &w, &b, bsz, d_in, d_out);
            assert_eq!(got, want, "bsz={bsz} d_in={d_in} d_out={d_out}");
            // The reusable-buffer entry point clears stale contents.
            let mut z = vec![9.0f32; 3];
            gemm_bias_into(&x, &w, &b, bsz, d_in, d_out, &mut z);
            assert_eq!(z, want);
        }
    }

    #[test]
    fn fused_mask_act_matches_scalar_formula() {
        let mut rng = Rng::new(0xAC7);
        let (bsz, d) = (3usize, 11usize);
        let z = pseudo(&mut rng, bsz * d, 4);
        let mask: Vec<f32> = (0..d).map(|j| [0.0, 1.0, 0.5][j % 3]).collect();
        for poly in [false, true] {
            let a = mask_act(&z, &mask, bsz, d, poly);
            for bi in 0..bsz {
                for j in 0..d {
                    let zv = z[bi * d + j];
                    let m = mask[j];
                    let want = m * zv.max(0.0) + (1.0 - m) * g(zv, poly);
                    assert_eq!(a[bi * d + j], want, "bi={bi} j={j} poly={poly}");
                }
            }
            // Buffer reuse across hypotheses must fully overwrite.
            let mut buf = vec![7.0f32; 2];
            mask_act_into(&z, &mask, bsz, d, poly, &mut buf);
            assert_eq!(buf, a);
        }
    }

    #[test]
    fn score_only_epilogue_matches_gradient_epilogue_bitwise() {
        let mut rng = Rng::new(0xCE0);
        let (bsz, k) = (5usize, 7usize);
        let logits = pseudo(&mut rng, bsz * k, 0);
        let y: Vec<i32> = (0..bsz as i32).collect();
        let (l_full, c_full, d) = softmax_ce(&logits, &y, k);
        let (l_score, c_score) = softmax_ce_batch(&logits, &y, k, None);
        assert_eq!(l_full, l_score, "loss must not depend on gradient materialization");
        assert_eq!(c_full, c_score);
        assert_eq!(d.len(), logits.len());
        // Gradient rows sum to ~0 (softmax minus one-hot, mean-reduced).
        for bi in 0..bsz {
            let s: f32 = d[bi * k..(bi + 1) * k].iter().sum();
            assert!(s.abs() < 1e-6, "row {bi} gradient sum {s}");
        }
    }

    #[test]
    fn epilogue_argmax_ties_resolve_to_highest_index() {
        // Two equal maxima: the argmax must pick the higher index (the
        // Tensor::argmax_rows convention the replay merge relies on).
        let logits = vec![1.0f32, 3.0, 3.0, 0.0];
        let (_, c_hi) = softmax_ce_batch(&logits, &[2], 4, None);
        assert_eq!(c_hi, 1, "tie must resolve to index 2");
        let (_, c_lo) = softmax_ce_batch(&logits, &[1], 4, None);
        assert_eq!(c_lo, 0);
    }

    #[test]
    fn matgrad_and_dinput_match_naive_bitwise() {
        let mut rng = Rng::new(0x9AD);
        let (bsz, d_in, d_out) = (3usize, 10usize, 9usize);
        let x = pseudo(&mut rng, bsz * d_in, 3);
        let dz = pseudo(&mut rng, bsz * d_out, 0);
        let w = pseudo(&mut rng, d_in * d_out, 0);
        // Naive matgrad oracle.
        let mut dw_want = vec![0.0f32; d_in * d_out];
        let mut db_want = vec![0.0f32; d_out];
        for bi in 0..bsz {
            let xr = &x[bi * d_in..(bi + 1) * d_in];
            let dzr = &dz[bi * d_out..(bi + 1) * d_out];
            for (j, &dv) in dzr.iter().enumerate() {
                db_want[j] += dv;
            }
            for (i, &xv) in xr.iter().enumerate() {
                if xv != 0.0 {
                    for (j, &dv) in dzr.iter().enumerate() {
                        dw_want[i * d_out + j] += xv * dv;
                    }
                }
            }
        }
        let mut dw = vec![0.0f32; d_in * d_out];
        let mut db = vec![0.0f32; d_out];
        matgrad(&x, &dz, &mut dw, &mut db, bsz, d_in, d_out);
        assert_eq!(dw, dw_want);
        assert_eq!(db, db_want);

        let dx = dinput(&dz, &w, bsz, d_in, d_out);
        for bi in 0..bsz {
            for i in 0..d_in {
                let mut acc = 0.0f32;
                for j in 0..d_out {
                    acc += dz[bi * d_out + j] * w[i * d_out + j];
                }
                assert_eq!(dx[bi * d_in + i], acc, "bi={bi} i={i}");
            }
        }
    }
}
