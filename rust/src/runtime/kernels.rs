//! Shared numeric kernels of the reference backend (DESIGN.md §11).
//!
//! Every dense-math loop of [`crate::runtime::reference`] lives here —
//! forward GEMMs, the fused masked activation, the scoring epilogue, and
//! the backward helpers — so the single-trial path, the batched
//! multi-hypothesis path and the training entry points all run the *same*
//! floating-point code. The bit-identical staged/batched scoring contract
//! (DESIGN.md §8) then holds by construction: there is one summation order
//! and one epilogue, not two implementations kept in sync by hand.
//!
//! # Determinism discipline
//!
//! f32 addition is not associative, so every kernel here preserves the
//! accumulation order of the naive triple loop it replaced:
//!
//! - [`gemm_bias_into`] accumulates each output element over the input
//!   index `i` in ascending order, one add per `i` (with the `x[i] != 0`
//!   skip — skipping an exact-zero term never changes the sum). Blocking
//!   tiles the *output* dimension ([`GEMM_TILE_J`]) and the inner loop is
//!   unrolled [`GEMM_UNROLL`]-wide across *independent* output elements;
//!   neither reorders any single element's additions.
//! - [`dinput`]'s dot products stay strictly sequential: splitting a
//!   serial reduction into unrolled partial sums would change its bits.
//! - [`softmax_ce_batch`] accumulates the softmax denominator in
//!   ascending class order — the same sequence the materialized
//!   `exps.iter().sum()` of the scalar implementation used — whether or
//!   not the gradient is requested, so scoring-only calls (the trial hot
//!   path) and training calls produce identical losses.
//! - The conv/batchnorm family ([`conv2d_same_into`] and friends,
//!   DESIGN.md §12) keeps the naive loop order too: each conv output
//!   accumulates over `(ci, ky, kx)` ascending. The conv entry points
//!   route through the GEMM lowering in [`crate::runtime::lowering`]
//!   (DESIGN.md §13), which replays that exact order per element; the
//!   direct 7-deep loops are retained here as `conv2d_same_*direct*`
//!   oracles, cross-checked bitwise in debug builds and under the
//!   non-semantic `bcd.verify_lowering` knob. The direct loops *skip*
//!   out-of-bounds padding taps while the lowering materializes them as
//!   exact 0.0 — both conventions produce identical bits (§13's ±0.0
//!   argument). Every batchnorm / GAP / per-channel-mask reduction runs
//!   strictly sequentially in `(n, y, x)` ascending order.

// Index-heavy numeric kernels: explicit loops over computed flat offsets
// read better than iterator chains here.
#![allow(clippy::needless_range_loop)]

use super::lowering::{self, Scratch};

/// Inner-loop unroll width of [`gemm_bias_into`] / [`matgrad`] (the
/// `axpy` over independent output elements).
pub const GEMM_UNROLL: usize = 8;

/// Output-dimension tile of [`gemm_bias_into`]: the `z` tile stays hot in
/// L1 across the whole input sweep while `w` streams through once.
pub const GEMM_TILE_J: usize = 256;

/// `y[j] += a * x[j]` over independent elements, manually unrolled
/// [`GEMM_UNROLL`]-wide. Each `y[j]` receives exactly one add, so the
/// per-element accumulation order of any caller loop is untouched.
#[inline]
fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    let mut xc = x.chunks_exact(GEMM_UNROLL);
    let mut yc = y.chunks_exact_mut(GEMM_UNROLL);
    for (xs, ys) in (&mut xc).zip(&mut yc) {
        ys[0] += a * xs[0];
        ys[1] += a * xs[1];
        ys[2] += a * xs[2];
        ys[3] += a * xs[3];
        ys[4] += a * xs[4];
        ys[5] += a * xs[5];
        ys[6] += a * xs[6];
        ys[7] += a * xs[7];
    }
    for (ys, &xs) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *ys += a * xs;
    }
}

/// `z = x @ w + b` for row-major `x [bsz, d_in]`, `w [d_in, d_out]`,
/// writing into `z` (cleared and resized — callers on the batched hot
/// path reuse one buffer across hypotheses instead of allocating).
///
/// Accumulation order per output element: `i` ascending, one add per
/// nonzero `x[i]` — bit-identical to the naive loop (see module docs).
pub fn gemm_bias_into(
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    bsz: usize,
    d_in: usize,
    d_out: usize,
    z: &mut Vec<f32>,
) {
    debug_assert_eq!(x.len(), bsz * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(bias.len(), d_out);
    z.clear();
    z.resize(bsz * d_out, 0.0);
    for bi in 0..bsz {
        let xr = &x[bi * d_in..(bi + 1) * d_in];
        let zr = &mut z[bi * d_out..(bi + 1) * d_out];
        zr.copy_from_slice(bias);
        let mut j0 = 0;
        while j0 < d_out {
            let j1 = (j0 + GEMM_TILE_J).min(d_out);
            let zt = &mut zr[j0..j1];
            for (i, &xv) in xr.iter().enumerate() {
                // Exact zeros are common (ReLU outputs feeding the next
                // layer); skipping them adds nothing to any sum.
                if xv != 0.0 {
                    axpy(xv, &w[i * d_out + j0..i * d_out + j1], zt);
                }
            }
            j0 = j1;
        }
    }
}

/// Allocating convenience wrapper over [`gemm_bias_into`].
pub fn gemm_bias(x: &[f32], w: &[f32], bias: &[f32], bsz: usize, d_in: usize, d_out: usize) -> Vec<f32> {
    let mut z = Vec::new();
    gemm_bias_into(x, w, bias, bsz, d_in, d_out, &mut z);
    z
}

/// `z += x @ w`, accumulating into the caller's pre-initialized `z` —
/// each output element's left fold simply *continues* from the value
/// already there. Same tiling, unroll and `x[i] != 0` skip as
/// [`gemm_bias_into`], so per output element the adds run over `i`
/// ascending, one per nonzero `x[i]`. The conv lowering (DESIGN.md §13)
/// builds on this: seeding `z` with zeros reproduces `gemm_bias_into`
/// with a zero bias bit for bit, and chaining calls over images replays
/// a flat batch-major reduction.
pub fn gemm_acc_into(x: &[f32], w: &[f32], bsz: usize, d_in: usize, d_out: usize, z: &mut [f32]) {
    debug_assert_eq!(x.len(), bsz * d_in);
    debug_assert_eq!(w.len(), d_in * d_out);
    debug_assert_eq!(z.len(), bsz * d_out);
    for bi in 0..bsz {
        let xr = &x[bi * d_in..(bi + 1) * d_in];
        let zr = &mut z[bi * d_out..(bi + 1) * d_out];
        let mut j0 = 0;
        while j0 < d_out {
            let j1 = (j0 + GEMM_TILE_J).min(d_out);
            let zt = &mut zr[j0..j1];
            for (i, &xv) in xr.iter().enumerate() {
                if xv != 0.0 {
                    axpy(xv, &w[i * d_out + j0..i * d_out + j1], zt);
                }
            }
            j0 = j1;
        }
    }
}

/// The non-ReLU branch `g` taken where the mask is 0: identity in the
/// paper setting, the AutoReP quadratic for `_poly` variants.
pub fn g(z: f32, poly: bool) -> f32 {
    if poly {
        0.25 * z * z + 0.5 * z
    } else {
        z
    }
}

pub fn g_prime(z: f32, poly: bool) -> f32 {
    if poly {
        0.5 * z + 0.5
    } else {
        1.0
    }
}

/// Fused masked activation `a = m*relu(z) + (1-m)*g(z)` per unit (mask is
/// per-unit, broadcast over the batch), written into a reusable buffer —
/// the per-hypothesis step of the batched trial path.
pub fn mask_act_into(z: &[f32], mask: &[f32], bsz: usize, d: usize, poly: bool, a: &mut Vec<f32>) {
    debug_assert_eq!(z.len(), bsz * d);
    debug_assert_eq!(mask.len(), d);
    a.clear();
    a.reserve(z.len());
    for bi in 0..bsz {
        let zr = &z[bi * d..(bi + 1) * d];
        for (j, &zv) in zr.iter().enumerate() {
            let m = mask[j];
            a.push(m * zv.max(0.0) + (1.0 - m) * g(zv, poly));
        }
    }
}

/// Allocating convenience wrapper over [`mask_act_into`].
pub fn mask_act(z: &[f32], mask: &[f32], bsz: usize, d: usize, poly: bool) -> Vec<f32> {
    let mut a = Vec::new();
    mask_act_into(z, mask, bsz, d, poly, &mut a);
    a
}

/// The scoring epilogue: mean cross-entropy + argmax-correct count for
/// logits `[bsz, k]`, optionally also writing `dL/dlogits` (training
/// callers). Argmax ties resolve to the highest index, matching
/// [`crate::tensor::Tensor::argmax_rows`].
///
/// This is the ONE epilogue of every scoring path — `eval_batch`,
/// `eval_from`, both batched multi variants, and the training steps — so
/// full, staged and batched trial scores agree bit for bit. The
/// scoring-only mode (`dlogits = None`) allocates nothing and computes
/// the exact same loss: the denominator accumulates in ascending class
/// order either way.
pub fn softmax_ce_batch(
    logits: &[f32],
    y: &[i32],
    k: usize,
    mut dlogits: Option<&mut [f32]>,
) -> (f32, usize) {
    let bsz = y.len();
    debug_assert_eq!(logits.len(), bsz * k);
    let mut loss = 0.0f32;
    let mut correct = 0usize;
    for bi in 0..bsz {
        let row = &logits[bi * k..(bi + 1) * k];
        let mut am = 0usize;
        let mut max = f32::NEG_INFINITY;
        for (j, &v) in row.iter().enumerate() {
            if v >= max {
                max = v;
                am = j;
            }
        }
        let target = y[bi] as usize % k;
        if am == target {
            correct += 1;
        }
        let mut denom = 0.0f32;
        let mut e_target = 0.0f32;
        match dlogits.as_deref_mut() {
            Some(d) => {
                let dr = &mut d[bi * k..(bi + 1) * k];
                for (j, &v) in row.iter().enumerate() {
                    let e = (v - max).exp();
                    dr[j] = e;
                    denom += e;
                    if j == target {
                        e_target = e;
                    }
                }
                for (j, dj) in dr.iter_mut().enumerate() {
                    let pj = *dj / denom;
                    *dj = (pj - if j == target { 1.0 } else { 0.0 }) / bsz as f32;
                }
            }
            None => {
                for (j, &v) in row.iter().enumerate() {
                    let e = (v - max).exp();
                    denom += e;
                    if j == target {
                        e_target = e;
                    }
                }
            }
        }
        loss -= (e_target / denom).max(1e-12).ln();
    }
    (loss / bsz as f32, correct)
}

/// [`softmax_ce_batch`] with the gradient materialized — the training
/// entry points' calling convention.
pub fn softmax_ce(logits: &[f32], y: &[i32], k: usize) -> (f32, usize, Vec<f32>) {
    let mut dlogits = vec![0.0f32; logits.len()];
    let (loss, correct) = softmax_ce_batch(logits, y, k, Some(&mut dlogits));
    (loss, correct, dlogits)
}

/// Temperature softmax of one row (knowledge distillation).
pub fn softmax_t(row: &[f32], temp: f32) -> Vec<f32> {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = row.iter().map(|&v| ((v - max) / temp).exp()).collect();
    let denom: f32 = exps.iter().sum();
    exps.into_iter().map(|e| e / denom).collect()
}

/// Accumulate `dw += x^T dz` and `db += colsum(dz)`. Per `dw` element:
/// one add per batch row, `bi` ascending (the unrolled `axpy` spans
/// independent elements only).
#[allow(clippy::too_many_arguments)]
pub fn matgrad(
    x: &[f32],
    dz: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    bsz: usize,
    d_in: usize,
    d_out: usize,
) {
    for bi in 0..bsz {
        let xr = &x[bi * d_in..(bi + 1) * d_in];
        let dzr = &dz[bi * d_out..(bi + 1) * d_out];
        for (j, &dv) in dzr.iter().enumerate() {
            db[j] += dv;
        }
        for (i, &xv) in xr.iter().enumerate() {
            if xv != 0.0 {
                axpy(xv, dzr, &mut dw[i * d_out..(i + 1) * d_out]);
            }
        }
    }
}

/// `dx = dz @ w^T`. Each output is a serial dot product and stays
/// strictly sequential — unrolling a reduction would change its bits.
pub fn dinput(dz: &[f32], w: &[f32], bsz: usize, d_in: usize, d_out: usize) -> Vec<f32> {
    let mut dx = vec![0.0f32; bsz * d_in];
    for bi in 0..bsz {
        let dzr = &dz[bi * d_out..(bi + 1) * d_out];
        let dxr = &mut dx[bi * d_in..(bi + 1) * d_in];
        for (i, dxi) in dxr.iter_mut().enumerate() {
            let wr = &w[i * d_out..(i + 1) * d_out];
            let mut acc = 0.0f32;
            for (&dv, &wv) in dzr.iter().zip(wr) {
                acc += dv * wv;
            }
            *dxi = acc;
        }
    }
    dx
}

/// Backprop through the masked activation: returns (`dL/dmask` per unit,
/// `dL/dz`).
pub fn dact(
    z: &[f32],
    mask: &[f32],
    da: &[f32],
    bsz: usize,
    d: usize,
    poly: bool,
) -> (Vec<f32>, Vec<f32>) {
    let mut dmask = vec![0.0f32; d];
    let mut dz = vec![0.0f32; z.len()];
    for bi in 0..bsz {
        for j in 0..d {
            let idx = bi * d + j;
            let zv = z[idx];
            let m = mask[j];
            let relu_grad = if zv > 0.0 { 1.0 } else { 0.0 };
            dz[idx] = da[idx] * (m * relu_grad + (1.0 - m) * g_prime(zv, poly));
            dmask[j] += da[idx] * (zv.max(0.0) - g(zv, poly));
        }
    }
    (dmask, dz)
}

/// SGD with momentum: `mom = mu*mom + g; p -= lr*mom`.
pub fn sgd_momentum(p: &[f32], mom: &[f32], grad: &[f32], lr: f32, mu: f32) -> (Vec<f32>, Vec<f32>) {
    let mut new_p = Vec::with_capacity(p.len());
    let mut new_mom = Vec::with_capacity(mom.len());
    for i in 0..p.len() {
        let m = mu * mom[i] + grad[i];
        new_mom.push(m);
        new_p.push(p[i] - lr * m);
    }
    (new_p, new_mom)
}

// ---------------------------------------------------------------------------
// Convolutional kernel family (DESIGN.md §12). NCHW activations, OIHW
// weights, 'SAME' padding, no conv bias (a batchnorm always follows).
// ---------------------------------------------------------------------------

/// Numerical-stability epsilon added to the batchnorm variance before the
/// square root (the usual 1e-5 of the framework defaults).
pub const BN_EPS: f32 = 1e-5;

/// Output spatial extent of a 'SAME'-padded convolution: `ceil(in/stride)`.
pub fn conv_out_dim(in_dim: usize, stride: usize) -> usize {
    in_dim.div_ceil(stride)
}

/// Leading (top/left) padding of a 'SAME' convolution. TensorFlow's
/// convention: `total = max((out-1)*stride + k - in, 0)`, split with the
/// odd extra row/column on the *trailing* edge — so a 3x3 stride-2 conv
/// on an even input pads 0 on top and 1 on the bottom.
pub fn same_pad_before(in_dim: usize, k: usize, stride: usize) -> usize {
    let out = conv_out_dim(in_dim, stride);
    ((out - 1) * stride + k).saturating_sub(in_dim) / 2
}

/// 2-D convolution: `x [n, cin, h, w]` (NCHW) with weights
/// `w [cout, cin, k, k]` (OIHW), 'SAME' padding, square stride, no bias,
/// written into a reusable buffer (the staged trial path calls this per
/// hypothesis). Runs the GEMM lowering (DESIGN.md §13), which is
/// bit-identical to [`conv2d_same_direct_into`]; this wrapper borrows
/// the thread's scratch arena — scratched eval paths call
/// [`conv2d_same_into_s`] with their own arena instead.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same_into(
    x: &[f32],
    w: &[f32],
    n: usize,
    cin: usize,
    h: usize,
    wd: usize,
    cout: usize,
    k: usize,
    stride: usize,
    out: &mut Vec<f32>,
) {
    lowering::with_scratch(|s| conv2d_same_into_s(x, w, n, cin, h, wd, cout, k, stride, out, s));
}

/// [`conv2d_same_into`] with an explicit scratch arena. Dispatches to the
/// lowered kernel (or the direct loop when the bench's direct-mode
/// switch is set) and, in debug builds or under `bcd.verify_lowering`,
/// re-runs the direct loop and hard-asserts bitwise equality.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same_into_s(
    x: &[f32],
    w: &[f32],
    n: usize,
    cin: usize,
    h: usize,
    wd: usize,
    cout: usize,
    k: usize,
    stride: usize,
    out: &mut Vec<f32>,
    s: &mut Scratch,
) {
    if lowering::conv_direct_enabled() {
        return conv2d_same_direct_into(x, w, n, cin, h, wd, cout, k, stride, out);
    }
    lowering::conv2d_lowered_into(x, w, n, cin, h, wd, cout, k, stride, out, s);
    if lowering::verify_lowering_enabled() {
        let mut want = Vec::new();
        conv2d_same_direct_into(x, w, n, cin, h, wd, cout, k, stride, &mut want);
        assert!(
            out[..] == want[..],
            "conv2d_same lowering diverged from the direct kernel \
             (n={n} cin={cin} h={h} wd={wd} cout={cout} k={k} stride={stride})"
        );
    }
}

/// The retained direct 7-deep conv loop — the pre-lowering kernel, kept
/// verbatim as the `bcd.verify_lowering` oracle and the perf bench
/// baseline.
///
/// Accumulation order per output element: `(ci, ky, kx)` ascending, one
/// add per *in-bounds* tap; padding taps are skipped. The lowering adds
/// them as exact 0.0 instead — identical bits either way (DESIGN.md §13).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same_direct_into(
    x: &[f32],
    w: &[f32],
    n: usize,
    cin: usize,
    h: usize,
    wd: usize,
    cout: usize,
    k: usize,
    stride: usize,
    out: &mut Vec<f32>,
) {
    let (oh, ow) = (conv_out_dim(h, stride), conv_out_dim(wd, stride));
    let (py, px) = (same_pad_before(h, k, stride), same_pad_before(wd, k, stride));
    debug_assert_eq!(x.len(), n * cin * h * wd);
    debug_assert_eq!(w.len(), cout * cin * k * k);
    out.clear();
    out.reserve(n * cout * oh * ow);
    for ni in 0..n {
        for co in 0..cout {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for ci in 0..cin {
                        let xc = &x[(ni * cin + ci) * h * wd..(ni * cin + ci + 1) * h * wd];
                        let wc = &w[(co * cin + ci) * k * k..(co * cin + ci + 1) * k * k];
                        for ky in 0..k {
                            let iy = oy * stride + ky;
                            if iy < py || iy - py >= h {
                                continue;
                            }
                            let xr = &xc[(iy - py) * wd..(iy - py + 1) * wd];
                            for kx in 0..k {
                                let ix = ox * stride + kx;
                                if ix < px || ix - px >= wd {
                                    continue;
                                }
                                acc += xr[ix - px] * wc[ky * k + kx];
                            }
                        }
                    }
                    out.push(acc);
                }
            }
        }
    }
}

/// `dL/dx` of [`conv2d_same_into`], via the GEMM lowering (bit-identical
/// to [`conv2d_same_dinput_direct`]; cross-checked like the forward).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same_dinput(
    dy: &[f32],
    w: &[f32],
    n: usize,
    cin: usize,
    h: usize,
    wd: usize,
    cout: usize,
    k: usize,
    stride: usize,
) -> Vec<f32> {
    lowering::with_scratch(|s| conv2d_same_dinput_s(dy, w, n, cin, h, wd, cout, k, stride, s))
}

/// [`conv2d_same_dinput`] with an explicit scratch arena.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same_dinput_s(
    dy: &[f32],
    w: &[f32],
    n: usize,
    cin: usize,
    h: usize,
    wd: usize,
    cout: usize,
    k: usize,
    stride: usize,
    s: &mut Scratch,
) -> Vec<f32> {
    if lowering::conv_direct_enabled() {
        return conv2d_same_dinput_direct(dy, w, n, cin, h, wd, cout, k, stride);
    }
    let dx = lowering::conv2d_lowered_dinput(dy, w, n, cin, h, wd, cout, k, stride, s);
    if lowering::verify_lowering_enabled() {
        let want = conv2d_same_dinput_direct(dy, w, n, cin, h, wd, cout, k, stride);
        assert!(
            dx == want,
            "conv2d_same dinput lowering diverged from the direct kernel \
             (n={n} cin={cin} h={h} wd={wd} cout={cout} k={k} stride={stride})"
        );
    }
    dx
}

/// The retained direct `dinput` loop (oracle / bench baseline). Each
/// input element's gradient is a serial reduction over `(co, ky, kx)`
/// ascending; taps whose output position falls off the grid or between
/// strides are skipped, mirroring the forward tap-skip.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same_dinput_direct(
    dy: &[f32],
    w: &[f32],
    n: usize,
    cin: usize,
    h: usize,
    wd: usize,
    cout: usize,
    k: usize,
    stride: usize,
) -> Vec<f32> {
    let (oh, ow) = (conv_out_dim(h, stride), conv_out_dim(wd, stride));
    let (py, px) = (same_pad_before(h, k, stride), same_pad_before(wd, k, stride));
    debug_assert_eq!(dy.len(), n * cout * oh * ow);
    debug_assert_eq!(w.len(), cout * cin * k * k);
    let mut dx = vec![0.0f32; n * cin * h * wd];
    for ni in 0..n {
        for ci in 0..cin {
            for iy in 0..h {
                for ix in 0..wd {
                    let mut acc = 0.0f32;
                    for co in 0..cout {
                        let dyc = &dy[(ni * cout + co) * oh * ow..(ni * cout + co + 1) * oh * ow];
                        let wc = &w[(co * cin + ci) * k * k..(co * cin + ci + 1) * k * k];
                        for ky in 0..k {
                            // Invert iy = oy*stride + ky - py for oy.
                            if iy + py < ky || (iy + py - ky) % stride != 0 {
                                continue;
                            }
                            let oy = (iy + py - ky) / stride;
                            if oy >= oh {
                                continue;
                            }
                            for kx in 0..k {
                                if ix + px < kx || (ix + px - kx) % stride != 0 {
                                    continue;
                                }
                                let ox = (ix + px - kx) / stride;
                                if ox >= ow {
                                    continue;
                                }
                                acc += dyc[oy * ow + ox] * wc[ky * k + kx];
                            }
                        }
                    }
                    dx[((ni * cin + ci) * h + iy) * wd + ix] = acc;
                }
            }
        }
    }
    dx
}

/// Accumulate `dL/dw` of [`conv2d_same_into`] into `dw`, via the GEMM
/// lowering (bit-identical to [`conv2d_same_dweight_direct`];
/// cross-checked like the forward).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same_dweight(
    x: &[f32],
    dy: &[f32],
    dw: &mut [f32],
    n: usize,
    cin: usize,
    h: usize,
    wd: usize,
    cout: usize,
    k: usize,
    stride: usize,
) {
    lowering::with_scratch(|s| conv2d_same_dweight_s(x, dy, dw, n, cin, h, wd, cout, k, stride, s));
}

/// [`conv2d_same_dweight`] with an explicit scratch arena.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same_dweight_s(
    x: &[f32],
    dy: &[f32],
    dw: &mut [f32],
    n: usize,
    cin: usize,
    h: usize,
    wd: usize,
    cout: usize,
    k: usize,
    stride: usize,
    s: &mut Scratch,
) {
    if lowering::conv_direct_enabled() {
        return conv2d_same_dweight_direct(x, dy, dw, n, cin, h, wd, cout, k, stride);
    }
    // Both paths *accumulate* into dw, so the oracle starts from the
    // same pre-call contents.
    let pre = lowering::verify_lowering_enabled().then(|| dw.to_vec());
    lowering::conv2d_lowered_dweight(x, dy, dw, n, cin, h, wd, cout, k, stride, s);
    if let Some(mut want) = pre {
        conv2d_same_dweight_direct(x, dy, &mut want, n, cin, h, wd, cout, k, stride);
        assert!(
            dw[..] == want[..],
            "conv2d_same dweight lowering diverged from the direct kernel \
             (n={n} cin={cin} h={h} wd={wd} cout={cout} k={k} stride={stride})"
        );
    }
}

/// The retained direct `dweight` loop (oracle / bench baseline): one add
/// per weight element — the local reduction runs over `(n, oy, ox)`
/// ascending, skipping padding taps, then lands in the caller's gradient
/// buffer.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_same_dweight_direct(
    x: &[f32],
    dy: &[f32],
    dw: &mut [f32],
    n: usize,
    cin: usize,
    h: usize,
    wd: usize,
    cout: usize,
    k: usize,
    stride: usize,
) {
    let (oh, ow) = (conv_out_dim(h, stride), conv_out_dim(wd, stride));
    let (py, px) = (same_pad_before(h, k, stride), same_pad_before(wd, k, stride));
    debug_assert_eq!(x.len(), n * cin * h * wd);
    debug_assert_eq!(dy.len(), n * cout * oh * ow);
    debug_assert_eq!(dw.len(), cout * cin * k * k);
    for co in 0..cout {
        for ci in 0..cin {
            for ky in 0..k {
                for kx in 0..k {
                    let mut acc = 0.0f32;
                    for ni in 0..n {
                        let xc = &x[(ni * cin + ci) * h * wd..(ni * cin + ci + 1) * h * wd];
                        let dyc = &dy[(ni * cout + co) * oh * ow..(ni * cout + co + 1) * oh * ow];
                        for oy in 0..oh {
                            let iy = oy * stride + ky;
                            if iy < py || iy - py >= h {
                                continue;
                            }
                            for ox in 0..ow {
                                let ix = ox * stride + kx;
                                if ix < px || ix - px >= wd {
                                    continue;
                                }
                                acc += xc[(iy - py) * wd + ix - px] * dyc[oy * ow + ox];
                            }
                        }
                    }
                    dw[(co * cin + ci) * k * k + ky * k + kx] += acc;
                }
            }
        }
    }
}

/// Per-channel statistics the batchnorm training forward captures for its
/// backward pass.
pub struct BnCache {
    /// The batchnorm *input* (backward recomputes x̂ from it).
    pub x: Vec<f32>,
    /// Per-channel batch mean over `(n, h, w)`.
    pub mean: Vec<f32>,
    /// Per-channel *biased* batch variance.
    pub var: Vec<f32>,
}

/// Batchnorm inference forward: normalize `x [n, c, hw]` with *running*
/// statistics — a purely per-element map, so each example's output is
/// independent of batch composition. That property is what makes the
/// staged/batched scoring paths and tail padding safe on conv nets, and
/// why every scoring path runs batchnorm in eval mode (DESIGN.md §12).
#[allow(clippy::too_many_arguments)]
pub fn bn_eval_into(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    rmean: &[f32],
    rvar: &[f32],
    n: usize,
    c: usize,
    hw: usize,
    out: &mut Vec<f32>,
) {
    debug_assert_eq!(x.len(), n * c * hw);
    out.clear();
    out.reserve(x.len());
    for ni in 0..n {
        for ci in 0..c {
            let inv = 1.0 / (rvar[ci] + BN_EPS).sqrt();
            let (g, b, m) = (gamma[ci], beta[ci], rmean[ci]);
            let xc = &x[(ni * c + ci) * hw..(ni * c + ci + 1) * hw];
            for &xv in xc {
                out.push(g * ((xv - m) * inv) + b);
            }
        }
    }
}

/// Batchnorm training forward: per-channel batch mean and biased variance
/// over `(n, h, w)` — both reductions strictly sequential in `(ni, i)`
/// ascending order — then the same normalize map as eval mode, using the
/// batch statistics.
pub fn bn_train_into(
    x: &[f32],
    gamma: &[f32],
    beta: &[f32],
    n: usize,
    c: usize,
    hw: usize,
    out: &mut Vec<f32>,
) -> BnCache {
    debug_assert_eq!(x.len(), n * c * hw);
    let m = (n * hw) as f32;
    let mut mean = vec![0.0f32; c];
    let mut var = vec![0.0f32; c];
    for ci in 0..c {
        let mut acc = 0.0f32;
        for ni in 0..n {
            let xc = &x[(ni * c + ci) * hw..(ni * c + ci + 1) * hw];
            for &xv in xc {
                acc += xv;
            }
        }
        mean[ci] = acc / m;
        let mut vacc = 0.0f32;
        for ni in 0..n {
            let xc = &x[(ni * c + ci) * hw..(ni * c + ci + 1) * hw];
            for &xv in xc {
                let d = xv - mean[ci];
                vacc += d * d;
            }
        }
        var[ci] = vacc / m;
    }
    out.clear();
    out.reserve(x.len());
    for ni in 0..n {
        for ci in 0..c {
            let inv = 1.0 / (var[ci] + BN_EPS).sqrt();
            let (g, b, mu) = (gamma[ci], beta[ci], mean[ci]);
            let xc = &x[(ni * c + ci) * hw..(ni * c + ci + 1) * hw];
            for &xv in xc {
                out.push(g * ((xv - mu) * inv) + b);
            }
        }
    }
    BnCache { x: x.to_vec(), mean, var }
}

/// Batchnorm training backward. Per channel, the two reductions (`Σdy`
/// and `Σdy·x̂`) run sequentially in `(ni, i)` order; `dgamma`/`dbeta`
/// receive one add per channel into the caller's gradient buffers, and
/// the returned `dx` carries the full dependence through the batch mean
/// and variance:
/// `dx = γ/σ · (dy − Σdy/m − x̂·(Σdy·x̂)/m)`.
#[allow(clippy::too_many_arguments)]
pub fn bn_backward_train(
    cache: &BnCache,
    gamma: &[f32],
    dy: &[f32],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
    n: usize,
    c: usize,
    hw: usize,
) -> Vec<f32> {
    debug_assert_eq!(dy.len(), n * c * hw);
    let m = (n * hw) as f32;
    let mut dx = vec![0.0f32; dy.len()];
    for ci in 0..c {
        let inv = 1.0 / (cache.var[ci] + BN_EPS).sqrt();
        let mu = cache.mean[ci];
        let mut s_dy = 0.0f32;
        let mut s_dyxh = 0.0f32;
        for ni in 0..n {
            let off = (ni * c + ci) * hw;
            for i in 0..hw {
                let d = dy[off + i];
                s_dy += d;
                s_dyxh += d * ((cache.x[off + i] - mu) * inv);
            }
        }
        dbeta[ci] += s_dy;
        dgamma[ci] += s_dyxh;
        let g = gamma[ci];
        for ni in 0..n {
            let off = (ni * c + ci) * hw;
            for i in 0..hw {
                let xhat = (cache.x[off + i] - mu) * inv;
                dx[off + i] = g * inv * (dy[off + i] - s_dy / m - xhat * (s_dyxh / m));
            }
        }
    }
    dx
}

/// Batchnorm inference-mode backward: the running statistics are
/// constants, so `dx = dy·γ/σ` elementwise, while `dγ = Σdy·x̂` and
/// `dβ = Σdy` reduce sequentially in `(ni, i)` order per channel.
#[allow(clippy::too_many_arguments)]
pub fn bn_backward_eval(
    x: &[f32],
    gamma: &[f32],
    rmean: &[f32],
    rvar: &[f32],
    dy: &[f32],
    dgamma: &mut [f32],
    dbeta: &mut [f32],
    n: usize,
    c: usize,
    hw: usize,
) -> Vec<f32> {
    debug_assert_eq!(x.len(), n * c * hw);
    debug_assert_eq!(dy.len(), n * c * hw);
    let mut dx = vec![0.0f32; dy.len()];
    for ci in 0..c {
        let inv = 1.0 / (rvar[ci] + BN_EPS).sqrt();
        let mu = rmean[ci];
        let g = gamma[ci];
        let mut s_dy = 0.0f32;
        let mut s_dyxh = 0.0f32;
        for ni in 0..n {
            let off = (ni * c + ci) * hw;
            for i in 0..hw {
                let d = dy[off + i];
                s_dy += d;
                s_dyxh += d * ((x[off + i] - mu) * inv);
                dx[off + i] = d * g * inv;
            }
        }
        dbeta[ci] += s_dy;
        dgamma[ci] += s_dyxh;
    }
    dx
}

/// [`mask_act_into`] with a *per-channel* mask broadcast over the batch
/// and spatial dims — the conv topologies' masked activation. One mask
/// coordinate gates a whole channel (DESIGN.md §12).
pub fn mask_act_channel_into(
    z: &[f32],
    mask: &[f32],
    n: usize,
    c: usize,
    hw: usize,
    poly: bool,
    a: &mut Vec<f32>,
) {
    debug_assert_eq!(z.len(), n * c * hw);
    debug_assert_eq!(mask.len(), c);
    a.clear();
    a.reserve(z.len());
    for ni in 0..n {
        for ci in 0..c {
            let m = mask[ci];
            let zc = &z[(ni * c + ci) * hw..(ni * c + ci + 1) * hw];
            for &zv in zc {
                a.push(m * zv.max(0.0) + (1.0 - m) * g(zv, poly));
            }
        }
    }
}

/// Backprop through the per-channel masked activation: returns
/// (`dL/dmask` per *channel*, `dL/dz`). Each channel's `dmask` reduction
/// runs sequentially in `(ni, i)` ascending order.
#[allow(clippy::too_many_arguments)]
pub fn dact_channel(
    z: &[f32],
    mask: &[f32],
    da: &[f32],
    n: usize,
    c: usize,
    hw: usize,
    poly: bool,
) -> (Vec<f32>, Vec<f32>) {
    debug_assert_eq!(z.len(), n * c * hw);
    debug_assert_eq!(mask.len(), c);
    let mut dmask = vec![0.0f32; c];
    let mut dz = vec![0.0f32; z.len()];
    for ni in 0..n {
        for ci in 0..c {
            let m = mask[ci];
            let off = (ni * c + ci) * hw;
            for i in 0..hw {
                let zv = z[off + i];
                let relu_grad = if zv > 0.0 { 1.0 } else { 0.0 };
                dz[off + i] = da[off + i] * (m * relu_grad + (1.0 - m) * g_prime(zv, poly));
                dmask[ci] += da[off + i] * (zv.max(0.0) - g(zv, poly));
            }
        }
    }
    (dmask, dz)
}

/// Global average pooling `[n, c, hw] -> [n, c]`: per output a serial sum
/// over the spatial extent in ascending order, then one divide.
pub fn gap_into(x: &[f32], n: usize, c: usize, hw: usize, out: &mut Vec<f32>) {
    debug_assert_eq!(x.len(), n * c * hw);
    out.clear();
    out.reserve(n * c);
    for ni in 0..n {
        for ci in 0..c {
            let xc = &x[(ni * c + ci) * hw..(ni * c + ci + 1) * hw];
            let mut acc = 0.0f32;
            for &v in xc {
                acc += v;
            }
            out.push(acc / hw as f32);
        }
    }
}

/// GAP backward: spreads `dy/hw` uniformly over each pooled window.
pub fn gap_back(dy: &[f32], n: usize, c: usize, hw: usize) -> Vec<f32> {
    debug_assert_eq!(dy.len(), n * c);
    let mut dx = vec![0.0f32; n * c * hw];
    for ni in 0..n {
        for ci in 0..c {
            let d = dy[ni * c + ci] / hw as f32;
            for v in &mut dx[(ni * c + ci) * hw..(ni * c + ci + 1) * hw] {
                *v = d;
            }
        }
    }
    dx
}

/// Elementwise residual add `a += b` — one add per element, so both the
/// forward skip connection and its (pass-through) backward keep every
/// element's accumulation order trivial.
pub fn add_into(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (av, &bv) in a.iter_mut().zip(b) {
        *av += bv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    /// The pre-kernel naive affine, kept verbatim as the bit-level oracle.
    fn naive_affine(x: &[f32], w: &[f32], b: &[f32], bsz: usize, d_in: usize, d_out: usize) -> Vec<f32> {
        let mut z = vec![0.0f32; bsz * d_out];
        for bi in 0..bsz {
            let xr = &x[bi * d_in..(bi + 1) * d_in];
            let zr = &mut z[bi * d_out..(bi + 1) * d_out];
            zr.copy_from_slice(b);
            for (i, &xv) in xr.iter().enumerate() {
                if xv != 0.0 {
                    let wr = &w[i * d_out..(i + 1) * d_out];
                    for (zj, &wj) in zr.iter_mut().zip(wr) {
                        *zj += xv * wj;
                    }
                }
            }
        }
        z
    }

    fn pseudo(rng: &mut Rng, n: usize, zero_every: usize) -> Vec<f32> {
        (0..n)
            .map(|i| {
                if zero_every > 0 && i % zero_every == 0 {
                    0.0
                } else {
                    rng.normal()
                }
            })
            .collect()
    }

    #[test]
    fn blocked_gemm_matches_naive_bitwise_on_ragged_shapes() {
        let mut rng = Rng::new(0xB10C);
        // Shapes straddling the unroll (8) and tile (256) boundaries,
        // including the degenerate 1s and a >1-tile output.
        for &(bsz, d_in, d_out) in &[
            (1usize, 1usize, 1usize),
            (2, 5, 3),
            (3, 8, 8),
            (1, 13, 7),
            (4, 9, 17),
            (2, 31, 255),
            (2, 7, 256),
            (1, 10, 259),
            (5, 16, 300),
        ] {
            let x = pseudo(&mut rng, bsz * d_in, 3);
            let w = pseudo(&mut rng, d_in * d_out, 0);
            let b = pseudo(&mut rng, d_out, 0);
            let want = naive_affine(&x, &w, &b, bsz, d_in, d_out);
            let got = gemm_bias(&x, &w, &b, bsz, d_in, d_out);
            assert_eq!(got, want, "bsz={bsz} d_in={d_in} d_out={d_out}");
            // The reusable-buffer entry point clears stale contents.
            let mut z = vec![9.0f32; 3];
            gemm_bias_into(&x, &w, &b, bsz, d_in, d_out, &mut z);
            assert_eq!(z, want);
        }
    }

    #[test]
    fn fused_mask_act_matches_scalar_formula() {
        let mut rng = Rng::new(0xAC7);
        let (bsz, d) = (3usize, 11usize);
        let z = pseudo(&mut rng, bsz * d, 4);
        let mask: Vec<f32> = (0..d).map(|j| [0.0, 1.0, 0.5][j % 3]).collect();
        for poly in [false, true] {
            let a = mask_act(&z, &mask, bsz, d, poly);
            for bi in 0..bsz {
                for j in 0..d {
                    let zv = z[bi * d + j];
                    let m = mask[j];
                    let want = m * zv.max(0.0) + (1.0 - m) * g(zv, poly);
                    assert_eq!(a[bi * d + j], want, "bi={bi} j={j} poly={poly}");
                }
            }
            // Buffer reuse across hypotheses must fully overwrite.
            let mut buf = vec![7.0f32; 2];
            mask_act_into(&z, &mask, bsz, d, poly, &mut buf);
            assert_eq!(buf, a);
        }
    }

    #[test]
    fn score_only_epilogue_matches_gradient_epilogue_bitwise() {
        let mut rng = Rng::new(0xCE0);
        let (bsz, k) = (5usize, 7usize);
        let logits = pseudo(&mut rng, bsz * k, 0);
        let y: Vec<i32> = (0..bsz as i32).collect();
        let (l_full, c_full, d) = softmax_ce(&logits, &y, k);
        let (l_score, c_score) = softmax_ce_batch(&logits, &y, k, None);
        assert_eq!(l_full, l_score, "loss must not depend on gradient materialization");
        assert_eq!(c_full, c_score);
        assert_eq!(d.len(), logits.len());
        // Gradient rows sum to ~0 (softmax minus one-hot, mean-reduced).
        for bi in 0..bsz {
            let s: f32 = d[bi * k..(bi + 1) * k].iter().sum();
            assert!(s.abs() < 1e-6, "row {bi} gradient sum {s}");
        }
    }

    #[test]
    fn epilogue_argmax_ties_resolve_to_highest_index() {
        // Two equal maxima: the argmax must pick the higher index (the
        // Tensor::argmax_rows convention the replay merge relies on).
        let logits = vec![1.0f32, 3.0, 3.0, 0.0];
        let (_, c_hi) = softmax_ce_batch(&logits, &[2], 4, None);
        assert_eq!(c_hi, 1, "tie must resolve to index 2");
        let (_, c_lo) = softmax_ce_batch(&logits, &[1], 4, None);
        assert_eq!(c_lo, 0);
    }

    #[test]
    fn matgrad_and_dinput_match_naive_bitwise() {
        let mut rng = Rng::new(0x9AD);
        let (bsz, d_in, d_out) = (3usize, 10usize, 9usize);
        let x = pseudo(&mut rng, bsz * d_in, 3);
        let dz = pseudo(&mut rng, bsz * d_out, 0);
        let w = pseudo(&mut rng, d_in * d_out, 0);
        // Naive matgrad oracle.
        let mut dw_want = vec![0.0f32; d_in * d_out];
        let mut db_want = vec![0.0f32; d_out];
        for bi in 0..bsz {
            let xr = &x[bi * d_in..(bi + 1) * d_in];
            let dzr = &dz[bi * d_out..(bi + 1) * d_out];
            for (j, &dv) in dzr.iter().enumerate() {
                db_want[j] += dv;
            }
            for (i, &xv) in xr.iter().enumerate() {
                if xv != 0.0 {
                    for (j, &dv) in dzr.iter().enumerate() {
                        dw_want[i * d_out + j] += xv * dv;
                    }
                }
            }
        }
        let mut dw = vec![0.0f32; d_in * d_out];
        let mut db = vec![0.0f32; d_out];
        matgrad(&x, &dz, &mut dw, &mut db, bsz, d_in, d_out);
        assert_eq!(dw, dw_want);
        assert_eq!(db, db_want);

        let dx = dinput(&dz, &w, bsz, d_in, d_out);
        for bi in 0..bsz {
            for i in 0..d_in {
                let mut acc = 0.0f32;
                for j in 0..d_out {
                    acc += dz[bi * d_out + j] * w[i * d_out + j];
                }
                assert_eq!(dx[bi * d_in + i], acc, "bi={bi} i={i}");
            }
        }
    }

    #[test]
    fn same_padding_dims_match_the_tf_convention() {
        // (in, k, stride) -> (out, pad_before); the odd extra row pads
        // the trailing edge, so even-input stride-2 pads 0 on top.
        for &(i, k, s, out, pad) in &[
            (16usize, 3usize, 1usize, 16usize, 1usize),
            (16, 3, 2, 8, 0),
            (15, 3, 2, 8, 1),
            (5, 3, 2, 3, 1),
            (16, 1, 1, 16, 0),
            (16, 1, 2, 8, 0),
            (1, 3, 1, 1, 1),
        ] {
            assert_eq!(conv_out_dim(i, s), out, "in={i} k={k} s={s}");
            assert_eq!(same_pad_before(i, k, s), pad, "in={i} k={k} s={s}");
        }
    }

    /// Conv oracle that materializes the zero-padded image and sums every
    /// tap. Padding taps contribute exact ±0.0, so its values equal the
    /// tap-skipping kernel's (`==` treats -0.0 == 0.0).
    #[allow(clippy::too_many_arguments)]
    fn naive_conv_same(
        x: &[f32],
        w: &[f32],
        n: usize,
        cin: usize,
        h: usize,
        wd: usize,
        cout: usize,
        k: usize,
        stride: usize,
    ) -> Vec<f32> {
        let (oh, ow) = (conv_out_dim(h, stride), conv_out_dim(wd, stride));
        let (py, px) = (same_pad_before(h, k, stride), same_pad_before(wd, k, stride));
        let (ph, pw) = (h + k, wd + k);
        let mut padded = vec![0.0f32; n * cin * ph * pw];
        for ni in 0..n {
            for ci in 0..cin {
                for y in 0..h {
                    for xx in 0..wd {
                        padded[((ni * cin + ci) * ph + y + py) * pw + xx + px] =
                            x[((ni * cin + ci) * h + y) * wd + xx];
                    }
                }
            }
        }
        let mut out = vec![0.0f32; n * cout * oh * ow];
        for ni in 0..n {
            for co in 0..cout {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut acc = 0.0f32;
                        for ci in 0..cin {
                            for ky in 0..k {
                                for kx in 0..k {
                                    acc += padded
                                        [((ni * cin + ci) * ph + oy * stride + ky) * pw + ox * stride + kx]
                                        * w[((co * cin + ci) * k + ky) * k + kx];
                                }
                            }
                        }
                        out[((ni * cout + co) * oh + oy) * ow + ox] = acc;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn conv2d_same_matches_padded_oracle_on_ragged_shapes() {
        let mut rng = Rng::new(0xC0A1);
        // Ragged spatial dims, both kernel sizes the topologies use (1, 3)
        // and both strides (1, 2), including the degenerate 1x1 image.
        for &(n, cin, h, wd, cout, k, stride) in &[
            (1usize, 1usize, 1usize, 1usize, 1usize, 1usize, 1usize),
            (2, 3, 5, 7, 4, 3, 1),
            (1, 2, 4, 4, 3, 3, 2),
            (2, 1, 5, 7, 2, 3, 2),
            (1, 3, 16, 16, 4, 1, 2),
            (1, 2, 7, 5, 3, 1, 1),
            (1, 1, 1, 1, 2, 3, 2),
        ] {
            let x = pseudo(&mut rng, n * cin * h * wd, 5);
            let w = pseudo(&mut rng, cout * cin * k * k, 0);
            let want = naive_conv_same(&x, &w, n, cin, h, wd, cout, k, stride);
            let mut got = vec![9.0f32; 3];
            conv2d_same_into(&x, &w, n, cin, h, wd, cout, k, stride, &mut got);
            assert_eq!(got, want, "n={n} cin={cin} h={h} wd={wd} cout={cout} k={k} s={stride}");
        }
    }

    #[test]
    fn conv_backward_kernels_satisfy_the_adjoint_identity() {
        // ⟨dy, conv(x, w)⟩ = ⟨dinput(dy, w), x⟩ = ⟨dweight(x, dy), w⟩ —
        // exact in ℝ by linearity in x resp. w, so any padding/stride
        // index-mapping mismatch between forward and backward breaks it.
        // The three sides sum in different orders, hence a (tight, f64)
        // tolerance compare; the semantics pin for training is the
        // finite-difference battery in tests/grad_check.rs.
        let mut rng = Rng::new(0xC0B2);
        for &(n, cin, h, wd, cout, k, stride) in &[
            (2usize, 2usize, 5usize, 7usize, 3usize, 3usize, 1usize),
            (1, 3, 4, 4, 2, 3, 2),
            (2, 2, 5, 5, 4, 1, 2),
        ] {
            let x = pseudo(&mut rng, n * cin * h * wd, 5);
            let w = pseudo(&mut rng, cout * cin * k * k, 0);
            let (oh, ow) = (conv_out_dim(h, stride), conv_out_dim(wd, stride));
            let dy = pseudo(&mut rng, n * cout * oh * ow, 0);
            let mut y = Vec::new();
            conv2d_same_into(&x, &w, n, cin, h, wd, cout, k, stride, &mut y);
            let dx = conv2d_same_dinput(&dy, &w, n, cin, h, wd, cout, k, stride);
            let mut dw = vec![0.0f32; w.len()];
            conv2d_same_dweight(&x, &dy, &mut dw, n, cin, h, wd, cout, k, stride);
            let dot = |a: &[f32], b: &[f32]| -> f64 {
                a.iter().zip(b).map(|(&p, &q)| p as f64 * q as f64).sum()
            };
            let lhs = dot(&dy, &y);
            let scale = lhs.abs().max(1.0);
            assert!(
                (lhs - dot(&dx, &x)).abs() / scale < 1e-4,
                "dinput adjoint: k={k} s={stride}"
            );
            assert!(
                (lhs - dot(&dw, &w)).abs() / scale < 1e-4,
                "dweight adjoint: k={k} s={stride}"
            );
        }
    }

    #[test]
    fn bn_eval_matches_scalar_formula_and_is_per_example() {
        let mut rng = Rng::new(0xB9E1);
        let (n, c, hw) = (3usize, 4usize, 6usize);
        let x = pseudo(&mut rng, n * c * hw, 4);
        let gamma = pseudo(&mut rng, c, 0);
        let beta = pseudo(&mut rng, c, 0);
        let rmean = pseudo(&mut rng, c, 0);
        let rvar: Vec<f32> = (0..c).map(|_| rng.f32() + 0.5).collect();
        let mut y = Vec::new();
        bn_eval_into(&x, &gamma, &beta, &rmean, &rvar, n, c, hw, &mut y);
        for ni in 0..n {
            for ci in 0..c {
                let inv = 1.0 / (rvar[ci] + BN_EPS).sqrt();
                for i in 0..hw {
                    let idx = (ni * c + ci) * hw + i;
                    let want = gamma[ci] * ((x[idx] - rmean[ci]) * inv) + beta[ci];
                    assert_eq!(y[idx], want, "ni={ni} ci={ci} i={i}");
                }
            }
        }
        // Eval mode is a per-element map: running just the first example
        // reproduces its outputs bit for bit regardless of the rest of
        // the batch — the property tail padding relies on.
        let mut y1 = Vec::new();
        bn_eval_into(&x[..c * hw], &gamma, &beta, &rmean, &rvar, 1, c, hw, &mut y1);
        assert_eq!(y1, y[..c * hw]);
    }

    #[test]
    fn bn_train_forward_backward_match_statistics_oracle() {
        let mut rng = Rng::new(0xB9E2);
        let (n, c, hw) = (4usize, 3usize, 5usize);
        let x = pseudo(&mut rng, n * c * hw, 4);
        let gamma: Vec<f32> = (0..c).map(|_| rng.f32() + 0.5).collect();
        let beta = pseudo(&mut rng, c, 0);
        let mut y = Vec::new();
        let cache = bn_train_into(&x, &gamma, &beta, n, c, hw, &mut y);
        let m = (n * hw) as f32;
        for ci in 0..c {
            // Same-order sequential oracle for the channel statistics.
            let mut s = 0.0f32;
            for ni in 0..n {
                for i in 0..hw {
                    s += x[(ni * c + ci) * hw + i];
                }
            }
            let mean = s / m;
            assert_eq!(cache.mean[ci], mean);
            let mut v = 0.0f32;
            for ni in 0..n {
                for i in 0..hw {
                    let d = x[(ni * c + ci) * hw + i] - mean;
                    v += d * d;
                }
            }
            assert_eq!(cache.var[ci], v / m);
            // The normalized channel has mean β (up to fp roundoff).
            let mut ys = 0.0f32;
            for ni in 0..n {
                for i in 0..hw {
                    ys += y[(ni * c + ci) * hw + i];
                }
            }
            assert!((ys / m - beta[ci]).abs() < 1e-5, "channel {ci} mean");
        }
        // Backward: dβ/dγ are the two sequential reductions, and dx is
        // orthogonal to both 1 and x̂ per channel (the projection the
        // mean/variance terms implement).
        let dy = pseudo(&mut rng, x.len(), 0);
        let mut dgamma = vec![0.0f32; c];
        let mut dbeta = vec![0.0f32; c];
        let dx = bn_backward_train(&cache, &gamma, &dy, &mut dgamma, &mut dbeta, n, c, hw);
        for ci in 0..c {
            let inv = 1.0 / (cache.var[ci] + BN_EPS).sqrt();
            let (mut s_dy, mut s_dyxh) = (0.0f32, 0.0f32);
            let (mut o_one, mut o_xhat) = (0.0f64, 0.0f64);
            for ni in 0..n {
                for i in 0..hw {
                    let idx = (ni * c + ci) * hw + i;
                    let xhat = (x[idx] - cache.mean[ci]) * inv;
                    s_dy += dy[idx];
                    s_dyxh += dy[idx] * xhat;
                    o_one += dx[idx] as f64;
                    o_xhat += dx[idx] as f64 * xhat as f64;
                }
            }
            assert_eq!(dbeta[ci], s_dy);
            assert_eq!(dgamma[ci], s_dyxh);
            assert!(o_one.abs() < 1e-3, "channel {ci}: ⟨dx, 1⟩ = {o_one}");
            assert!(o_xhat.abs() < 1e-3, "channel {ci}: ⟨dx, x̂⟩ = {o_xhat}");
        }
        // Eval-mode backward: dx is the plain chain rule through the
        // constant running stats.
        let rvar: Vec<f32> = (0..c).map(|_| rng.f32() + 0.5).collect();
        let rmean = pseudo(&mut rng, c, 0);
        let mut dg2 = vec![0.0f32; c];
        let mut db2 = vec![0.0f32; c];
        let dx_eval = bn_backward_eval(&x, &gamma, &rmean, &rvar, &dy, &mut dg2, &mut db2, n, c, hw);
        for ci in 0..c {
            let inv = 1.0 / (rvar[ci] + BN_EPS).sqrt();
            for ni in 0..n {
                for i in 0..hw {
                    let idx = (ni * c + ci) * hw + i;
                    assert_eq!(dx_eval[idx], dy[idx] * gamma[ci] * inv);
                }
            }
        }
    }

    #[test]
    fn per_channel_mask_kernels_match_per_unit_kernels_on_expanded_masks() {
        // A per-channel mask is the per-unit kernel applied to the mask
        // expanded across the spatial extent: a (the activations) and dz
        // run element-identical arithmetic, so they match bitwise; dmask
        // reduces in a different order (per unit vs per channel), so the
        // channel sums compare at tolerance.
        let mut rng = Rng::new(0xCA4E);
        let (n, c, hw) = (2usize, 3usize, 5usize);
        let z = pseudo(&mut rng, n * c * hw, 4);
        let da = pseudo(&mut rng, n * c * hw, 0);
        let mask: Vec<f32> = (0..c).map(|j| [0.0, 1.0, 0.5][j % 3]).collect();
        let expanded: Vec<f32> = (0..c * hw).map(|u| mask[u / hw]).collect();
        for poly in [false, true] {
            let mut a_ch = Vec::new();
            mask_act_channel_into(&z, &mask, n, c, hw, poly, &mut a_ch);
            let a_unit = mask_act(&z, &expanded, n, c * hw, poly);
            assert_eq!(a_ch, a_unit, "poly={poly}");
            let (dmask_ch, dz_ch) = dact_channel(&z, &mask, &da, n, c, hw, poly);
            let (dmask_unit, dz_unit) = dact(&z, &expanded, &da, n, c * hw, poly);
            assert_eq!(dz_ch, dz_unit, "poly={poly}");
            for ci in 0..c {
                let want: f32 = dmask_unit[ci * hw..(ci + 1) * hw].iter().sum();
                assert!(
                    (dmask_ch[ci] - want).abs() < 1e-4,
                    "poly={poly} ci={ci}: {} vs {want}",
                    dmask_ch[ci]
                );
            }
        }
    }

    #[test]
    fn gap_and_residual_add_match_oracles() {
        let mut rng = Rng::new(0x6A9);
        let (n, c, hw) = (2usize, 3usize, 7usize);
        let x = pseudo(&mut rng, n * c * hw, 0);
        let mut p = Vec::new();
        gap_into(&x, n, c, hw, &mut p);
        for ni in 0..n {
            for ci in 0..c {
                let mut acc = 0.0f32;
                for i in 0..hw {
                    acc += x[(ni * c + ci) * hw + i];
                }
                assert_eq!(p[ni * c + ci], acc / hw as f32);
            }
        }
        let dy = pseudo(&mut rng, n * c, 0);
        let dx = gap_back(&dy, n, c, hw);
        for ni in 0..n {
            for ci in 0..c {
                for i in 0..hw {
                    assert_eq!(dx[(ni * c + ci) * hw + i], dy[ni * c + ci] / hw as f32);
                }
            }
        }
        let mut a = pseudo(&mut rng, 9, 0);
        let b = pseudo(&mut rng, 9, 0);
        let want: Vec<f32> = a.iter().zip(&b).map(|(&p, &q)| p + q).collect();
        add_into(&mut a, &b);
        assert_eq!(a, want);
    }
}
