//! GEMM lowering of the convolution kernels (DESIGN.md §13).
//!
//! The direct conv loops in [`super::kernels`] are 7-deep nests; every
//! BCD trial scan on a conv family spends nearly all of its time there.
//! This module lowers all three conv kernels onto the blocked GEMM that
//! already makes the MLP scan fast: an [`im2col_t`] patch matrix per
//! image, multiplied by the weight pack via [`super::kernels::gemm_acc_into`]
//! (same tile/unroll structure as `gemm_bias_into`).
//!
//! # Bit-identity with the direct loops
//!
//! The lowering preserves the direct kernels' accumulation order *bit for
//! bit*, so the DESIGN.md §8 replay-merge contract is untouched:
//!
//! * **Order.** The patch matrix rows are laid out in `(ci, ky, kx)`
//!   ascending order — the GEMM's sequential accumulation over `d_in`
//!   then replays the direct forward's exact `ci→ky→kx` float order per
//!   output element. The backward patch matrices use `(co, ky, kx)` rows
//!   (`dinput`) and a per-image left fold chained through the accumulator
//!   (`dweight`), replaying those kernels' orders the same way.
//! * **±0.0 terms.** The direct kernels *skip* padding taps while the
//!   patch matrix materializes them as exact `0.0`; conversely the GEMM
//!   skips exact-zero multiplier entries the direct loops add. Both
//!   differences only add or drop `±0.0` terms, and an f32 accumulator
//!   that starts at `+0.0` can never become `-0.0` under round-to-nearest
//!   (zero-sum cancellation yields `+0.0`, and `+0.0 + ±0.0 = +0.0`), so
//!   `acc + ±0.0 == acc` bitwise at every step. Dropping or inserting
//!   such terms therefore never changes any output bit.
//!
//! The direct loops are retained in [`super::kernels`] as oracles behind
//! the non-semantic `bcd.verify_lowering` cross-check knob (same idiom as
//! `bcd.verify_staged`), plus a direct-mode switch the perf bench uses to
//! time the two paths against each other.
//!
//! # Scratch arena
//!
//! [`Scratch`] is a free-list of `Vec<f32>` buffers so patch matrices,
//! GEMM outputs and BN temporaries reuse capacity across layers and
//! trials instead of allocating per call. One arena lives per thread
//! ([`with_scratch`]); the eval paths of `convnet.rs` / `reference.rs`
//! thread `&mut Scratch` explicitly so a whole forward shares one pool.
//!
//! Float-independent counters (`conv_lowering:{im2col_calls, im2col_bytes,
//! scratch_hits, slab_patch_reuse}`) ride [`drain_tallies`] into the
//! backend's `StatsRecorder` and from there into `run.json`.

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};

use super::kernels::{conv_out_dim, gemm_acc_into, same_pad_before};

// ---------------------------------------------------------------------------
// Knobs (non-semantic: both paths are bit-identical by construction).
// ---------------------------------------------------------------------------

/// `bcd.verify_lowering`: when set, every lowered conv kernel re-runs the
/// retained direct loop and hard-asserts bitwise equality.
static VERIFY_LOWERING: AtomicBool = AtomicBool::new(false);

/// Route the conv wrappers to the retained direct loops instead of the
/// lowering — the perf bench's baseline switch.
static CONV_DIRECT: AtomicBool = AtomicBool::new(false);

pub fn set_verify_lowering(on: bool) {
    VERIFY_LOWERING.store(on, Relaxed);
}

/// Cross-check in release under `bcd.verify_lowering`, and always in
/// debug builds (the `verify_staged` idiom).
pub fn verify_lowering_enabled() -> bool {
    VERIFY_LOWERING.load(Relaxed) || cfg!(debug_assertions)
}

pub fn set_conv_direct(on: bool) {
    CONV_DIRECT.store(on, Relaxed);
}

pub fn conv_direct_enabled() -> bool {
    CONV_DIRECT.load(Relaxed)
}

// ---------------------------------------------------------------------------
// Float-independent tallies. Per-thread (the conv work of one backend
// call never leaves the calling thread): each worker drains its own
// tallies at the end of the call and flushes the deltas into the shared
// StatsRecorder, matching the `trial_batch:*` counter idiom — and exact
// counts stay deterministic under parallel tests and benches.
// ---------------------------------------------------------------------------

/// Snapshot of the lowering counters since the last drain.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LoweringTallies {
    /// Patch-matrix builds (forward and backward).
    pub im2col_calls: u64,
    /// Bytes written into patch matrices.
    pub im2col_bytes: u64,
    /// [`Scratch::take`] calls served from pooled capacity.
    pub scratch_hits: u64,
    /// Hypotheses that reused a slab-shared prefix (stem conv / resumed
    /// block) instead of recomputing it.
    pub slab_patch_reuse: u64,
}

thread_local! {
    static TALLIES: Cell<LoweringTallies> = const { Cell::new(LoweringTallies {
        im2col_calls: 0,
        im2col_bytes: 0,
        scratch_hits: 0,
        slab_patch_reuse: 0,
    }) };
}

fn bump_tallies(f: impl FnOnce(&mut LoweringTallies)) {
    TALLIES.with(|c| {
        let mut t = c.get();
        f(&mut t);
        c.set(t);
    });
}

fn note_im2col(floats: usize) {
    bump_tallies(|t| {
        t.im2col_calls += 1;
        t.im2col_bytes += 4 * floats as u64;
    });
}

/// Record `n` hypotheses served by one slab-shared prefix computation.
pub fn note_slab_reuse(n: u64) {
    bump_tallies(|t| t.slab_patch_reuse += n);
}

/// Read-and-reset this thread's lowering counters.
pub fn drain_tallies() -> LoweringTallies {
    TALLIES.with(|c| c.replace(LoweringTallies::default()))
}

// ---------------------------------------------------------------------------
// Scratch arena.
// ---------------------------------------------------------------------------

/// A free-list of `f32` buffers. [`Scratch::take`] pops a cleared buffer
/// (or creates one), [`Scratch::put`] returns it; capacity survives the
/// round trip, so steady-state eval loops stop allocating entirely.
#[derive(Default)]
pub struct Scratch {
    pool: Vec<Vec<f32>>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Pop a cleared buffer from the pool (a reuse "hit" when it carries
    /// capacity from a previous round) or create an empty one.
    pub fn take(&mut self) -> Vec<f32> {
        match self.pool.pop() {
            Some(v) => {
                debug_assert!(v.is_empty());
                if v.capacity() > 0 {
                    bump_tallies(|t| t.scratch_hits += 1);
                }
                v
            }
            None => Vec::new(),
        }
    }

    /// Return a buffer to the pool (contents cleared, capacity kept).
    pub fn put(&mut self, mut v: Vec<f32>) {
        v.clear();
        self.pool.push(v);
    }
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::new());
}

/// Run `f` with this thread's scratch arena. Re-entrant calls (a public
/// kernel wrapper invoked from inside an already-scratched eval path)
/// fall back to a fresh arena instead of panicking on the borrow — they
/// only lose reuse, never correctness.
pub fn with_scratch<R>(f: impl FnOnce(&mut Scratch) -> R) -> R {
    SCRATCH.with(|cell| match cell.try_borrow_mut() {
        Ok(mut s) => f(&mut s),
        Err(_) => f(&mut Scratch::new()),
    })
}

// ---------------------------------------------------------------------------
// Patch matrices.
// ---------------------------------------------------------------------------

/// Transposed im2col of one NCHW image: `pt [cin*k*k, oh*ow]` with row
/// `(ci*k + ky)*k + kx` — `(ci, ky, kx)` ascending, the direct forward's
/// reduction order — and column `(oy, ox)`. Out-of-bounds padding taps
/// are exact `0.0` entries (see the module docs for why that is
/// bit-neutral).
pub fn im2col_t(x_img: &[f32], cin: usize, h: usize, wd: usize, k: usize, stride: usize, pt: &mut Vec<f32>) {
    let (oh, ow) = (conv_out_dim(h, stride), conv_out_dim(wd, stride));
    let (py, px) = (same_pad_before(h, k, stride), same_pad_before(wd, k, stride));
    let ohw = oh * ow;
    debug_assert_eq!(x_img.len(), cin * h * wd);
    pt.clear();
    pt.resize(cin * k * k * ohw, 0.0);
    for ci in 0..cin {
        let xc = &x_img[ci * h * wd..(ci + 1) * h * wd];
        for ky in 0..k {
            for kx in 0..k {
                let row = ((ci * k + ky) * k + kx) * ohw;
                for oy in 0..oh {
                    let iy = oy * stride + ky;
                    if iy < py || iy - py >= h {
                        continue; // padding row: stays 0.0
                    }
                    let xr = &xc[(iy - py) * wd..(iy - py + 1) * wd];
                    let pr = &mut pt[row + oy * ow..row + (oy + 1) * ow];
                    for (ox, pv) in pr.iter_mut().enumerate() {
                        let ix = ox * stride + kx;
                        if ix < px || ix - px >= wd {
                            continue; // padding column: stays 0.0
                        }
                        *pv = xr[ix - px];
                    }
                }
            }
        }
    }
    note_im2col(pt.len());
}

/// Adjoint of [`im2col_t`]: scatter-add `pt [cin*k*k, oh*ow]` back onto
/// the image, `x_acc[ci, iy, ix] += pt[(ci,ky,kx), (oy,ox)]` over every
/// in-bounds tap. Property tests pin `⟨im2col(x), p⟩ = ⟨x, col2im(p)⟩`
/// and the tap-count roundtrip; the production `dinput` route instead
/// uses [`im2col_back_t`], whose flat per-element fold replays the direct
/// kernel's `(co, ky, kx)` order exactly (a col2im scatter would sum the
/// same taps in a different tree).
pub fn col2im(pt: &[f32], cin: usize, h: usize, wd: usize, k: usize, stride: usize, x_acc: &mut [f32]) {
    let (oh, ow) = (conv_out_dim(h, stride), conv_out_dim(wd, stride));
    let (py, px) = (same_pad_before(h, k, stride), same_pad_before(wd, k, stride));
    let ohw = oh * ow;
    debug_assert_eq!(pt.len(), cin * k * k * ohw);
    debug_assert_eq!(x_acc.len(), cin * h * wd);
    for ci in 0..cin {
        for ky in 0..k {
            for kx in 0..k {
                let row = ((ci * k + ky) * k + kx) * ohw;
                for oy in 0..oh {
                    let iy = oy * stride + ky;
                    if iy < py || iy - py >= h {
                        continue;
                    }
                    for ox in 0..ow {
                        let ix = ox * stride + kx;
                        if ix < px || ix - px >= wd {
                            continue;
                        }
                        x_acc[(ci * h + iy - py) * wd + ix - px] += pt[row + oy * ow + ox];
                    }
                }
            }
        }
    }
}

/// Transposed patch matrix of one *output-gradient* image for the
/// `dinput` lowering: `pt [cout*k*k, h*wd]` with row `(co*k + ky)*k + kx`
/// — the direct `dinput` kernel's `(co, ky, kx)` reduction order — and
/// column `(iy, ix)`. Entry = `dy[co, oy, ox]` where
/// `oy = (iy + py - ky)/stride` (and likewise for `ox`) lands on the
/// output grid; taps that fall off the grid or between strides stay
/// exact `0.0`, mirroring the direct kernel's skips.
pub fn im2col_back_t(
    dy_img: &[f32],
    cout: usize,
    h: usize,
    wd: usize,
    k: usize,
    stride: usize,
    pt: &mut Vec<f32>,
) {
    let (oh, ow) = (conv_out_dim(h, stride), conv_out_dim(wd, stride));
    let (py, px) = (same_pad_before(h, k, stride), same_pad_before(wd, k, stride));
    let hw = h * wd;
    debug_assert_eq!(dy_img.len(), cout * oh * ow);
    pt.clear();
    pt.resize(cout * k * k * hw, 0.0);
    for co in 0..cout {
        let dyc = &dy_img[co * oh * ow..(co + 1) * oh * ow];
        for ky in 0..k {
            for kx in 0..k {
                let row = ((co * k + ky) * k + kx) * hw;
                for iy in 0..h {
                    if iy + py < ky || (iy + py - ky) % stride != 0 {
                        continue;
                    }
                    let oy = (iy + py - ky) / stride;
                    if oy >= oh {
                        continue;
                    }
                    for ix in 0..wd {
                        if ix + px < kx || (ix + px - kx) % stride != 0 {
                            continue;
                        }
                        let ox = (ix + px - kx) / stride;
                        if ox >= ow {
                            continue;
                        }
                        pt[row + iy * wd + ix] = dyc[oy * ow + ox];
                    }
                }
            }
        }
    }
    note_im2col(pt.len());
}

fn transpose_into(src: &[f32], rows: usize, cols: usize, dst: &mut Vec<f32>) {
    debug_assert_eq!(src.len(), rows * cols);
    dst.clear();
    dst.resize(rows * cols, 0.0);
    for r in 0..rows {
        for (c, &v) in src[r * cols..(r + 1) * cols].iter().enumerate() {
            dst[c * rows + r] = v;
        }
    }
}

// ---------------------------------------------------------------------------
// Lowered kernels. All three are bit-identical to the direct loops in
// `kernels.rs` (module docs); the public entry points there cross-check
// that claim under `bcd.verify_lowering` / debug builds.
// ---------------------------------------------------------------------------

/// GEMM-lowered [`super::kernels::conv2d_same_into`]: per image, one
/// [`im2col_t`] patch matrix multiplied by the OIHW weight pack
/// (`[cout, cin*k*k]` as GEMM rows). The GEMM's `d_in` sweep replays the
/// direct `ci→ky→kx` accumulation order, and the output lands directly
/// in NCHW order — no epilogue transpose.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_lowered_into(
    x: &[f32],
    w: &[f32],
    n: usize,
    cin: usize,
    h: usize,
    wd: usize,
    cout: usize,
    k: usize,
    stride: usize,
    out: &mut Vec<f32>,
    s: &mut Scratch,
) {
    let (oh, ow) = (conv_out_dim(h, stride), conv_out_dim(wd, stride));
    let ohw = oh * ow;
    let ckk = cin * k * k;
    debug_assert_eq!(x.len(), n * cin * h * wd);
    debug_assert_eq!(w.len(), cout * ckk);
    out.clear();
    out.resize(n * cout * ohw, 0.0);
    let mut pt = s.take();
    for ni in 0..n {
        im2col_t(&x[ni * cin * h * wd..(ni + 1) * cin * h * wd], cin, h, wd, k, stride, &mut pt);
        gemm_acc_into(w, &pt, cout, ckk, ohw, &mut out[ni * cout * ohw..(ni + 1) * cout * ohw]);
    }
    s.put(pt);
}

/// GEMM-lowered [`super::kernels::conv2d_same_dinput`]: the transposed
/// convolution as a GEMM — a flipped weight matrix
/// `wflip [cin, cout*k*k]` (a pure permutation of the OIHW pack) times
/// the [`im2col_back_t`] patch matrix of each gradient image. Each input
/// element's fold runs over `(co, ky, kx)` ascending, exactly the direct
/// kernel's order.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_lowered_dinput(
    dy: &[f32],
    w: &[f32],
    n: usize,
    cin: usize,
    h: usize,
    wd: usize,
    cout: usize,
    k: usize,
    stride: usize,
    s: &mut Scratch,
) -> Vec<f32> {
    let (oh, ow) = (conv_out_dim(h, stride), conv_out_dim(wd, stride));
    let hw = h * wd;
    let ckk_b = cout * k * k;
    debug_assert_eq!(dy.len(), n * cout * oh * ow);
    debug_assert_eq!(w.len(), cout * cin * k * k);
    let mut wflip = s.take();
    wflip.resize(cin * ckk_b, 0.0);
    for ci in 0..cin {
        for co in 0..cout {
            for ky in 0..k {
                for kx in 0..k {
                    wflip[ci * ckk_b + (co * k + ky) * k + kx] = w[((co * cin + ci) * k + ky) * k + kx];
                }
            }
        }
    }
    let mut dx = vec![0.0f32; n * cin * hw];
    let mut pt = s.take();
    for ni in 0..n {
        im2col_back_t(&dy[ni * cout * oh * ow..(ni + 1) * cout * oh * ow], cout, h, wd, k, stride, &mut pt);
        gemm_acc_into(&wflip, &pt, cin, ckk_b, hw, &mut dx[ni * cin * hw..(ni + 1) * cin * hw]);
    }
    s.put(pt);
    s.put(wflip);
    dx
}

/// GEMM-lowered [`super::kernels::conv2d_same_dweight`]: the
/// patch-matrix-transpose route — per image, `dy_img [cout, oh*ow]` times
/// the *transposed* forward patch matrix `[oh*ow, cin*k*k]`, accumulating
/// image after image into one running buffer. Because
/// [`super::kernels::gemm_acc_into`] continues each output element's left
/// fold from its current value, chaining the images replays the direct
/// kernel's flat `(n, oy, ox)` reduction exactly; the result lands in
/// `dw` with one add per element, as before.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_lowered_dweight(
    x: &[f32],
    dy: &[f32],
    dw: &mut [f32],
    n: usize,
    cin: usize,
    h: usize,
    wd: usize,
    cout: usize,
    k: usize,
    stride: usize,
    s: &mut Scratch,
) {
    let (oh, ow) = (conv_out_dim(h, stride), conv_out_dim(wd, stride));
    let ohw = oh * ow;
    let ckk = cin * k * k;
    debug_assert_eq!(x.len(), n * cin * h * wd);
    debug_assert_eq!(dy.len(), n * cout * ohw);
    debug_assert_eq!(dw.len(), cout * ckk);
    let mut acc = s.take();
    acc.resize(cout * ckk, 0.0);
    let mut pt = s.take();
    let mut p = s.take();
    for ni in 0..n {
        im2col_t(&x[ni * cin * h * wd..(ni + 1) * cin * h * wd], cin, h, wd, k, stride, &mut pt);
        transpose_into(&pt, ckk, ohw, &mut p);
        gemm_acc_into(&dy[ni * cout * ohw..(ni + 1) * cout * ohw], &p, cout, ohw, ckk, &mut acc);
    }
    for (d, &a) in dw.iter_mut().zip(acc.iter()) {
        *d += a;
    }
    s.put(p);
    s.put(pt);
    s.put(acc);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn scratch_pool_reuses_capacity_and_counts_hits() {
        drain_tallies();
        let mut s = Scratch::new();
        let mut a = s.take(); // fresh: no capacity, no hit
        a.resize(128, 1.0);
        s.put(a);
        let b = s.take(); // pooled: cleared but capacitied
        assert!(b.is_empty());
        assert!(b.capacity() >= 128);
        let t = drain_tallies();
        assert_eq!(t.scratch_hits, 1);
    }

    #[test]
    fn im2col_rows_follow_ci_ky_kx_order_with_zero_padding() {
        // 1 channel, 2x2 image, k=3 s=1 => oh=ow=2, pad 1: row (ky,kx)
        // holds the input shifted by the tap offset, zeros off the edge.
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let mut pt = Vec::new();
        im2col_t(&x, 1, 2, 2, 3, 1, &mut pt);
        assert_eq!(pt.len(), 9 * 4);
        // Center tap (ky=1, kx=1) is the identity row.
        assert_eq!(&pt[4 * 4..5 * 4], &x);
        // Top-left tap (ky=0, kx=0) sees the input shifted down-right:
        // only output (1,1) has an in-bounds tap, namely x[0,0].
        assert_eq!(&pt[0..4], &[0.0, 0.0, 0.0, 1.0]);
        // Bottom-right tap (ky=2, kx=2): only output (0,0) in-bounds.
        assert_eq!(&pt[8 * 4..9 * 4], &[4.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn col2im_roundtrip_counts_taps() {
        // col2im(im2col(x)) multiplies each input element by the number
        // of output taps that read it; with integer inputs the repeated
        // adds are exact, so the quotient recovers the tap count.
        let (cin, h, wd, k, stride) = (2usize, 3usize, 4usize, 3usize, 1usize);
        let x: Vec<f32> = (0..cin * h * wd).map(|i| (i % 5 + 1) as f32).collect();
        let mut pt = Vec::new();
        im2col_t(&x, cin, h, wd, k, stride, &mut pt);
        let mut back = vec![0.0f32; x.len()];
        col2im(&pt, cin, h, wd, k, stride, &mut back);
        let ones = vec![1.0f32; x.len()];
        let mut ptc = Vec::new();
        im2col_t(&ones, cin, h, wd, k, stride, &mut ptc);
        let mut counts = vec![0.0f32; x.len()];
        col2im(&ptc, cin, h, wd, k, stride, &mut counts);
        for i in 0..x.len() {
            assert!(counts[i] >= 1.0, "every element is read at least once");
            assert_eq!(back[i], counts[i] * x[i], "i={i}");
        }
    }

    #[test]
    fn lowering_tallies_count_im2col_calls_and_bytes() {
        drain_tallies();
        let x = vec![1.0f32; 2 * 4 * 4];
        let mut pt = Vec::new();
        im2col_t(&x, 2, 4, 4, 3, 1, &mut pt);
        im2col_t(&x, 2, 4, 4, 3, 2, &mut pt);
        let t = drain_tallies();
        assert_eq!(t.im2col_calls, 2);
        // s=1: [2*9, 16]; s=2: [2*9, 4] — 4 bytes per float.
        assert_eq!(t.im2col_bytes, 4 * (18 * 16 + 18 * 4) as u64);
        assert_eq!(drain_tallies(), LoweringTallies::default(), "drain resets");
    }

    #[test]
    fn lowered_forward_matches_direct_bitwise_on_ragged_shapes() {
        use crate::runtime::kernels::conv2d_same_direct_into;
        let mut rng = Rng::new(0x10E1);
        let mut s = Scratch::new();
        for &(n, cin, h, wd, cout, k, stride) in &[
            (1usize, 1usize, 1usize, 1usize, 1usize, 1usize, 1usize),
            (2, 3, 5, 7, 4, 3, 1),
            (1, 2, 4, 4, 3, 3, 2),
            (2, 1, 5, 7, 2, 3, 2),
            (1, 3, 16, 16, 4, 1, 2),
            (1, 2, 7, 5, 3, 1, 1),
            (1, 1, 1, 1, 2, 3, 2),
        ] {
            let x: Vec<f32> = (0..n * cin * h * wd)
                .map(|i| if i % 5 == 0 { 0.0 } else { rng.normal() })
                .collect();
            let w: Vec<f32> = (0..cout * cin * k * k)
                .map(|i| if i % 7 == 0 { 0.0 } else { rng.normal() })
                .collect();
            let mut want = Vec::new();
            conv2d_same_direct_into(&x, &w, n, cin, h, wd, cout, k, stride, &mut want);
            let mut got = vec![9.0f32; 3];
            conv2d_lowered_into(&x, &w, n, cin, h, wd, cout, k, stride, &mut got, &mut s);
            assert_eq!(got, want, "n={n} cin={cin} h={h} wd={wd} cout={cout} k={k} s={stride}");
        }
    }

    #[test]
    fn lowered_backward_kernels_match_direct_bitwise() {
        use crate::runtime::kernels::{conv2d_same_dinput_direct, conv2d_same_dweight_direct, conv_out_dim};
        let mut rng = Rng::new(0x10E2);
        let mut s = Scratch::new();
        for &(n, cin, h, wd, cout, k, stride) in &[
            (2usize, 2usize, 5usize, 7usize, 3usize, 3usize, 1usize),
            (1, 3, 4, 4, 2, 3, 2),
            (2, 2, 5, 5, 4, 1, 2),
            (1, 1, 3, 3, 1, 3, 1),
        ] {
            let x: Vec<f32> = (0..n * cin * h * wd)
                .map(|i| if i % 4 == 0 { 0.0 } else { rng.normal() })
                .collect();
            let w: Vec<f32> = (0..cout * cin * k * k).map(|_| rng.normal()).collect();
            let (oh, ow) = (conv_out_dim(h, stride), conv_out_dim(wd, stride));
            let dy: Vec<f32> = (0..n * cout * oh * ow)
                .map(|i| if i % 6 == 0 { 0.0 } else { rng.normal() })
                .collect();
            let want_dx = conv2d_same_dinput_direct(&dy, &w, n, cin, h, wd, cout, k, stride);
            let got_dx = conv2d_lowered_dinput(&dy, &w, n, cin, h, wd, cout, k, stride, &mut s);
            assert_eq!(got_dx, want_dx, "dinput k={k} s={stride}");
            // dweight accumulates: seed both with the same nonzero prior.
            let prior: Vec<f32> = (0..w.len()).map(|_| rng.normal()).collect();
            let mut want_dw = prior.clone();
            conv2d_same_dweight_direct(&x, &dy, &mut want_dw, n, cin, h, wd, cout, k, stride);
            let mut got_dw = prior;
            conv2d_lowered_dweight(&x, &dy, &mut got_dw, n, cin, h, wd, cout, k, stride, &mut s);
            assert_eq!(got_dw, want_dw, "dweight k={k} s={stride}");
        }
    }
}
