//! `artifacts/manifest.json` — the interchange contract written by aot.py.
//!
//! The manifest is the ONLY source of shape knowledge on the rust side:
//! parameter-vector length, mask-layer table (name/shape/offset), and the
//! input/output specs of every compiled entry point.

use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One named tensor in a flat pack (mirror of python spec.Entry).
#[derive(Clone, Debug, PartialEq)]
pub struct PackEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// Input/output slot of a compiled artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct SlotSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One compiled entry point (e.g. `train_step`) of one model variant.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub file: PathBuf,
    pub inputs: Vec<SlotSpec>,
    pub outputs: Vec<SlotSpec>,
}

/// One model variant (backbone x input shape x classes x replacement).
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub key: String,
    pub backbone: String,
    pub num_classes: usize,
    pub image_size: usize,
    pub channels: usize,
    pub poly: bool,
    pub param_size: usize,
    pub mask_size: usize,
    /// Masked activation layers in network order; offsets index the flat
    /// mask vector (== the paper's global ReLU pool).
    pub mask_layers: Vec<PackEntry>,
    pub param_entries: Vec<PackEntry>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl ModelInfo {
    /// Total ReLU locations (paper Table 1 row for this variant).
    pub fn total_relus(&self) -> usize {
        self.mask_size
    }

    /// Layer index containing flat mask index `i`.
    pub fn layer_of(&self, i: usize) -> usize {
        debug_assert!(i < self.mask_size);
        // Layers are ordered by offset; binary search the containing one.
        match self
            .mask_layers
            .binary_search_by(|e| e.offset.cmp(&i))
        {
            Ok(l) => l,
            Err(ins) => ins - 1,
        }
    }

    pub fn artifact(&self, fn_name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(fn_name)
            .ok_or_else(|| anyhow!("model {}: no artifact {fn_name:?}", self.key))
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub kernel_impl: String,
    pub models: BTreeMap<String, ModelInfo>,
    pub dir: PathBuf,
}

fn parse_entries(v: &Json) -> Vec<PackEntry> {
    v.as_arr()
        .iter()
        .map(|e| PackEntry {
            name: e.expect("name").as_str().to_string(),
            shape: e.expect("shape").as_usize_vec(),
            offset: e.expect("offset").as_usize(),
            size: e.expect("size").as_usize(),
        })
        .collect()
}

fn parse_slots(v: &Json) -> Vec<SlotSpec> {
    v.as_arr()
        .iter()
        .map(|s| SlotSpec {
            name: s.expect("name").as_str().to_string(),
            shape: s.expect("shape").as_usize_vec(),
            dtype: s.expect("dtype").as_str().to_string(),
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let root = json::parse(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;

        let mut models = BTreeMap::new();
        for (key, m) in root.expect("models").as_obj() {
            let mut artifacts = BTreeMap::new();
            for (fname, a) in m.expect("artifacts").as_obj() {
                artifacts.insert(
                    fname.clone(),
                    ArtifactInfo {
                        file: dir.join(a.expect("file").as_str()),
                        inputs: parse_slots(a.expect("inputs")),
                        outputs: parse_slots(a.expect("outputs")),
                    },
                );
            }
            let info = ModelInfo {
                key: key.clone(),
                backbone: m.expect("backbone").as_str().to_string(),
                num_classes: m.expect("num_classes").as_usize(),
                image_size: m.expect("image_size").as_usize(),
                channels: m.expect("channels").as_usize(),
                poly: m.expect("poly").as_bool(),
                param_size: m.expect("param_size").as_usize(),
                mask_size: m.expect("mask_size").as_usize(),
                mask_layers: parse_entries(m.expect("mask_layers")),
                param_entries: parse_entries(m.expect("param_entries")),
                artifacts,
            };
            Self::validate(&info)?;
            models.insert(key.clone(), info);
        }
        Ok(Manifest {
            batch: root.expect("batch").as_usize(),
            kernel_impl: root.expect("kernel_impl").as_str().to_string(),
            models,
            dir: dir.to_path_buf(),
        })
    }

    fn validate(info: &ModelInfo) -> Result<()> {
        // Mask layers must tile [0, mask_size) exactly, in order.
        let mut expect_off = 0usize;
        for l in &info.mask_layers {
            if l.offset != expect_off {
                bail!(
                    "model {}: mask layer {} offset {} != expected {}",
                    info.key,
                    l.name,
                    l.offset,
                    expect_off
                );
            }
            if l.shape.iter().product::<usize>() != l.size {
                bail!("model {}: mask layer {} shape/size mismatch", info.key, l.name);
            }
            expect_off += l.size;
        }
        if expect_off != info.mask_size {
            bail!(
                "model {}: mask layers cover {} of {} entries",
                info.key,
                expect_off,
                info.mask_size
            );
        }
        Ok(())
    }

    pub fn model(&self, key: &str) -> Result<&ModelInfo> {
        self.models.get(key).ok_or_else(|| {
            anyhow!(
                "manifest has no model {key:?} (available: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest_json() -> String {
        r#"{
 "format": 1, "batch": 4, "kernel_impl": "pallas", "jax_version": "t",
 "models": {
  "m1": {
   "key": "m1", "backbone": "resnet", "num_classes": 2, "image_size": 4,
   "channels": 3, "poly": false, "param_size": 10, "mask_size": 6,
   "mask_layers": [
     {"name": "a", "shape": [1, 2, 2], "offset": 0, "size": 4},
     {"name": "b", "shape": [2, 1, 1], "offset": 4, "size": 2}
   ],
   "param_entries": [{"name": "w", "shape": [10], "offset": 0, "size": 10}],
   "artifacts": {
     "forward": {"file": "m1__forward.hlo.txt",
       "inputs": [{"name": "params", "shape": [10], "dtype": "float32"}],
       "outputs": [{"name": "logits", "shape": [4, 2], "dtype": "float32"}]}
   }
  }
 }
}"#
        .to_string()
    }

    #[test]
    fn parses_and_validates() {
        let dir = std::env::temp_dir().join("cdnl_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), fake_manifest_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.batch, 4);
        let info = m.model("m1").unwrap();
        assert_eq!(info.total_relus(), 6);
        assert_eq!(info.layer_of(0), 0);
        assert_eq!(info.layer_of(3), 0);
        assert_eq!(info.layer_of(4), 1);
        assert_eq!(info.layer_of(5), 1);
        assert!(info.artifact("forward").is_ok());
        assert!(info.artifact("nope").is_err());
        assert!(m.model("zz").is_err());
    }

    #[test]
    fn rejects_gappy_layers() {
        let bad = fake_manifest_json().replace("\"offset\": 4", "\"offset\": 5");
        let dir = std::env::temp_dir().join("cdnl_manifest_test_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), bad).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
