//! `artifacts/manifest.json` — the interchange contract written by aot.py.
//!
//! The manifest is the ONLY source of shape knowledge on the rust side:
//! parameter-vector length, mask-layer table (name/shape/offset), and the
//! input/output specs of every compiled entry point.
//!
//! Parsing goes through the typed serde layer
//! ([`crate::util::serde`] + [`crate::derive_serde!`]): the on-disk schema
//! is described by *document* structs (`ManifestDoc`, `ModelDoc`,
//! `ArtifactDoc`) that deserialize field-by-field, then convert into the
//! runtime types below (resolving artifact paths against the manifest
//! directory and validating the mask-layer tiling).

use crate::derive_serde;
use crate::util::serde as sd;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One named tensor in a flat pack (mirror of python spec.Entry).
#[derive(Clone, Debug, PartialEq)]
pub struct PackEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}
derive_serde!(PackEntry { name, shape, offset, size });

/// Input/output slot of a compiled artifact.
#[derive(Clone, Debug, PartialEq)]
pub struct SlotSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}
derive_serde!(SlotSpec { name, shape, dtype });

/// One compiled entry point (e.g. `train_step`) of one model variant.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub file: PathBuf,
    pub inputs: Vec<SlotSpec>,
    pub outputs: Vec<SlotSpec>,
}

/// One model variant (backbone x input shape x classes x replacement).
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub key: String,
    pub backbone: String,
    pub num_classes: usize,
    pub image_size: usize,
    pub channels: usize,
    pub poly: bool,
    pub param_size: usize,
    pub mask_size: usize,
    /// Masked activation layers in network order; offsets index the flat
    /// mask vector (== the paper's global ReLU pool).
    pub mask_layers: Vec<PackEntry>,
    pub param_entries: Vec<PackEntry>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl ModelInfo {
    /// Total ReLU locations (paper Table 1 row for this variant).
    pub fn total_relus(&self) -> usize {
        self.mask_size
    }

    /// Layer index containing flat mask index `i`.
    pub fn layer_of(&self, i: usize) -> usize {
        debug_assert!(i < self.mask_size);
        // Layers are ordered by offset; binary search the containing one.
        match self
            .mask_layers
            .binary_search_by(|e| e.offset.cmp(&i))
        {
            Ok(l) => l,
            Err(ins) => ins - 1,
        }
    }

    pub fn artifact(&self, fn_name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(fn_name)
            .ok_or_else(|| anyhow!("model {}: no artifact {fn_name:?}", self.key))
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub batch: usize,
    pub kernel_impl: String,
    pub models: BTreeMap<String, ModelInfo>,
    pub dir: PathBuf,
}

// ---- on-disk schema (document structs, serde-deserialized) ----------------

/// Disk shape of one artifact entry: the `file` is a path *relative to the
/// manifest directory* until [`Manifest::load`] resolves it.
struct ArtifactDoc {
    file: String,
    inputs: Vec<SlotSpec>,
    outputs: Vec<SlotSpec>,
}
derive_serde!(ArtifactDoc { file, inputs, outputs });

struct ModelDoc {
    backbone: String,
    num_classes: usize,
    image_size: usize,
    channels: usize,
    poly: bool,
    param_size: usize,
    mask_size: usize,
    mask_layers: Vec<PackEntry>,
    param_entries: Vec<PackEntry>,
    artifacts: BTreeMap<String, ArtifactDoc>,
}
derive_serde!(ModelDoc {
    backbone,
    num_classes,
    image_size,
    channels,
    poly,
    param_size,
    mask_size,
    mask_layers,
    param_entries,
    artifacts,
});

struct ManifestDoc {
    batch: usize,
    kernel_impl: String,
    models: BTreeMap<String, ModelDoc>,
}
derive_serde!(ManifestDoc { batch, kernel_impl, models });

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let doc: ManifestDoc =
            sd::from_str(&text).map_err(|e| anyhow!("parsing {path:?}: {e}"))?;

        let mut models = BTreeMap::new();
        for (key, m) in doc.models {
            let artifacts = m
                .artifacts
                .into_iter()
                .map(|(fname, a)| {
                    (
                        fname,
                        ArtifactInfo {
                            file: dir.join(a.file),
                            inputs: a.inputs,
                            outputs: a.outputs,
                        },
                    )
                })
                .collect();
            let info = ModelInfo {
                key: key.clone(),
                backbone: m.backbone,
                num_classes: m.num_classes,
                image_size: m.image_size,
                channels: m.channels,
                poly: m.poly,
                param_size: m.param_size,
                mask_size: m.mask_size,
                mask_layers: m.mask_layers,
                param_entries: m.param_entries,
                artifacts,
            };
            Self::validate(&info)?;
            models.insert(key, info);
        }
        Ok(Manifest {
            batch: doc.batch,
            kernel_impl: doc.kernel_impl,
            models,
            dir: dir.to_path_buf(),
        })
    }

    fn validate(info: &ModelInfo) -> Result<()> {
        // Mask layers must tile [0, mask_size) exactly, in order.
        let mut expect_off = 0usize;
        for l in &info.mask_layers {
            if l.offset != expect_off {
                bail!(
                    "model {}: mask layer {} offset {} != expected {}",
                    info.key,
                    l.name,
                    l.offset,
                    expect_off
                );
            }
            if l.shape.iter().product::<usize>() != l.size {
                bail!("model {}: mask layer {} shape/size mismatch", info.key, l.name);
            }
            expect_off += l.size;
        }
        if expect_off != info.mask_size {
            bail!(
                "model {}: mask layers cover {} of {} entries",
                info.key,
                expect_off,
                info.mask_size
            );
        }
        Ok(())
    }

    pub fn model(&self, key: &str) -> Result<&ModelInfo> {
        self.models.get(key).ok_or_else(|| {
            anyhow!(
                "manifest has no model {key:?} (available: {:?})",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_manifest_json() -> String {
        r#"{
 "format": 1, "batch": 4, "kernel_impl": "pallas", "jax_version": "t",
 "models": {
  "m1": {
   "key": "m1", "backbone": "resnet", "num_classes": 2, "image_size": 4,
   "channels": 3, "poly": false, "param_size": 10, "mask_size": 6,
   "mask_layers": [
     {"name": "a", "shape": [1, 2, 2], "offset": 0, "size": 4},
     {"name": "b", "shape": [2, 1, 1], "offset": 4, "size": 2}
   ],
   "param_entries": [{"name": "w", "shape": [10], "offset": 0, "size": 10}],
   "artifacts": {
     "forward": {"file": "m1__forward.hlo.txt",
       "inputs": [{"name": "params", "shape": [10], "dtype": "float32"}],
       "outputs": [{"name": "logits", "shape": [4, 2], "dtype": "float32"}]}
   }
  }
 }
}"#
        .to_string()
    }

    #[test]
    fn parses_and_validates() {
        let dir = std::env::temp_dir().join("cdnl_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), fake_manifest_json()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.batch, 4);
        let info = m.model("m1").unwrap();
        assert_eq!(info.total_relus(), 6);
        assert_eq!(info.layer_of(0), 0);
        assert_eq!(info.layer_of(3), 0);
        assert_eq!(info.layer_of(4), 1);
        assert_eq!(info.layer_of(5), 1);
        assert!(info.artifact("forward").is_ok());
        assert!(info.artifact("nope").is_err());
        assert!(m.model("zz").is_err());
        // Artifact paths are resolved against the manifest directory.
        assert_eq!(
            info.artifact("forward").unwrap().file,
            dir.join("m1__forward.hlo.txt")
        );
        assert_eq!(info.artifact("forward").unwrap().inputs[0].dtype, "float32");
    }

    #[test]
    fn rejects_gappy_layers() {
        let bad = fake_manifest_json().replace("\"offset\": 4", "\"offset\": 5");
        let dir = std::env::temp_dir().join("cdnl_manifest_test_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), bad).unwrap();
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn schema_error_names_the_field() {
        let bad = fake_manifest_json().replace("\"mask_size\": 6", "\"mask_size\": \"six\"");
        let dir = std::env::temp_dir().join("cdnl_manifest_test_field");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), bad).unwrap();
        let err = format!("{:#}", Manifest::load(&dir).unwrap_err());
        assert!(err.contains("mask_size"), "error lacks field path: {err}");
    }

    #[test]
    fn pack_entry_serde_roundtrip() {
        let e = PackEntry { name: "w".into(), shape: vec![2, 3], offset: 4, size: 6 };
        let back: PackEntry = sd::from_str(&sd::to_string(&e)).unwrap();
        assert_eq!(back, e);
    }
}
