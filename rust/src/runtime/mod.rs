//! AOT runtime: PJRT client wrapper over `artifacts/*.hlo.txt`.
//!
//! `xla` crate flow: `PjRtClient::cpu()` -> `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute`
//! (adapted from /opt/xla-example/load_hlo). The [`manifest`] module parses
//! the interchange contract written by `python/compile/aot.py`; [`engine`]
//! owns the client + executable cache; [`session`] adds buffer-resident
//! model state for the hot path (§Perf).

pub mod engine;
pub mod manifest;
pub mod session;

pub use engine::Engine;
pub use manifest::{Manifest, ModelInfo};
pub use session::Session;
