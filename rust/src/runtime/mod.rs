//! Multi-backend runtime.
//!
//! The [`backend::Backend`] trait is the execution seam: the coordinator
//! sees host tensors and opaque [`backend::DeviceBuf`] handles only.
//! Implementations:
//!
//! - [`engine::Engine`] (`--features pjrt`) — the PJRT CPU client over AOT
//!   HLO-text artifacts (`artifacts/*.hlo.txt`), flow adapted from
//!   /opt/xla-example/load_hlo. [`manifest`] parses the interchange contract
//!   written by `python/compile/aot.py`.
//! - [`reference::RefBackend`] — a pure-Rust backend with hand-written
//!   autodiff; runs the full coordinator (BCD + baselines) with no
//!   artifacts or native deps, for tests/CI and as a template for future
//!   backends. It serves masked-activation MLP stand-ins (`mlp_*`) and the
//!   paper's conv/residual topologies ([`convnet`]: `resnet18_*`, `wrn22_*`
//!   — DESIGN.md §12).
//!
//! [`session::Session`] adds the typed entry-point API both share. All
//! backends are `Send + Sync` so the BCD trial scan can fan out across
//! threads. [`kernels`] holds the shared dense-math kernels (blocked GEMM,
//! fused mask-apply, scoring epilogue) that both the single-trial and the
//! batched multi-hypothesis reference paths run, so the bit-identity
//! contract of DESIGN.md §8/§11 holds by construction. [`lowering`]
//! rides the conv kernels on that same GEMM via im2col (DESIGN.md §13)
//! and owns the zero-alloc [`lowering::Scratch`] arena.

pub mod backend;
pub mod convnet;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod kernels;
pub mod lowering;
pub mod manifest;
pub mod reference;
pub mod session;

pub use backend::{Backend, CallStats, DeviceBuf, HostArg};
#[cfg(feature = "pjrt")]
pub use engine::Engine;
pub use manifest::{Manifest, ModelInfo};
pub use reference::RefBackend;
pub use session::Session;

use anyhow::Result;
use std::path::Path;

#[cfg(feature = "pjrt")]
const HAVE_PJRT: bool = true;
#[cfg(not(feature = "pjrt"))]
const HAVE_PJRT: bool = false;

#[cfg(feature = "pjrt")]
fn open_pjrt(artifacts_dir: &Path) -> Result<Box<dyn Backend>> {
    Ok(Box::new(engine::Engine::new(artifacts_dir)?))
}

#[cfg(not(feature = "pjrt"))]
fn open_pjrt(_artifacts_dir: &Path) -> Result<Box<dyn Backend>> {
    anyhow::bail!(
        "backend \"pjrt\" requires building with `--features pjrt` (and a vendored xla crate; see Cargo.toml)"
    )
}

/// Open an execution backend by name at the default conv-model sizing.
///
/// - `"pjrt"` — the PJRT engine over `artifacts_dir` (needs the feature).
/// - `"reference"` — the pure-Rust reference backend (always available).
/// - `"auto"` — PJRT when compiled in *and* artifacts exist, else reference.
pub fn open_backend(artifacts_dir: &Path, kind: &str) -> Result<Box<dyn Backend>> {
    open_backend_with(artifacts_dir, kind, &crate::config::ModelConfig::default())
}

/// [`open_backend`] with explicit conv-model sizing (the `model.*` config
/// keys). Only the reference backend consumes the sizing — PJRT artifacts
/// carry their own compiled shapes.
pub fn open_backend_with(
    artifacts_dir: &Path,
    kind: &str,
    model: &crate::config::ModelConfig,
) -> Result<Box<dyn Backend>> {
    match kind {
        "pjrt" => open_pjrt(artifacts_dir),
        "reference" => Ok(Box::new(RefBackend::standard_with(model))),
        "auto" => {
            if HAVE_PJRT && artifacts_dir.join("manifest.json").exists() {
                open_pjrt(artifacts_dir)
            } else {
                crate::info!(
                    "runtime: using reference backend ({})",
                    if HAVE_PJRT { "no artifacts found" } else { "built without pjrt" }
                );
                Ok(Box::new(RefBackend::standard_with(model)))
            }
        }
        other => anyhow::bail!("unknown backend {other:?} (expected auto|pjrt|reference)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_reference_and_auto() {
        let be = open_backend(Path::new("/nonexistent"), "reference").unwrap();
        assert_eq!(be.name(), "reference");
        // auto falls back to reference when there are no artifacts.
        let be = open_backend(Path::new("/nonexistent"), "auto").unwrap();
        assert_eq!(be.name(), "reference");
        assert!(open_backend(Path::new("."), "bogus").is_err());
    }
}
