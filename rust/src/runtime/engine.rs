//! PJRT execution engine: load HLO-text artifacts, compile once, execute.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute`. The
//! engine caches compiled executables per (model, entry point) so each
//! artifact pays its XLA compile exactly once per process.

use super::manifest::{ArtifactInfo, Manifest, ModelInfo};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

/// Cumulative execution statistics (per entry point), for §Perf.
#[derive(Clone, Debug, Default)]
pub struct CallStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

/// The runtime engine: one PJRT CPU client + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
    stats: RefCell<BTreeMap<String, CallStats>>,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::info!(
            "engine: platform={} devices={} models={}",
            client.platform_name(),
            client.device_count(),
            manifest.models.len()
        );
        Ok(Engine {
            client,
            manifest,
            executables: RefCell::new(HashMap::new()),
            stats: RefCell::new(BTreeMap::new()),
        })
    }

    pub fn model(&self, key: &str) -> Result<&ModelInfo> {
        self.manifest.model(key)
    }

    /// Compile (or fetch cached) the executable for `model_key:fn_name`.
    pub fn executable(
        &self,
        model_key: &str,
        fn_name: &str,
    ) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        let cache_key = format!("{model_key}:{fn_name}");
        if let Some(e) = self.executables.borrow().get(&cache_key) {
            return Ok(e.clone());
        }
        let info = self.manifest.model(model_key)?.artifact(fn_name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&info.file)
            .with_context(|| format!("loading HLO text {:?}", info.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {cache_key}"))?;
        let dt = t0.elapsed().as_secs_f64();
        crate::debug!("compiled {cache_key} in {dt:.2}s");
        self.stats
            .borrow_mut()
            .entry(cache_key.clone())
            .or_default()
            .compile_secs += dt;
        let rc = std::rc::Rc::new(exe);
        self.executables.borrow_mut().insert(cache_key, rc.clone());
        Ok(rc)
    }

    /// Execute an entry point with literal inputs; returns the decomposed
    /// output tuple (artifacts are lowered with `return_tuple=True`).
    pub fn call(
        &self,
        model_key: &str,
        fn_name: &str,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let info = self.manifest.model(model_key)?.artifact(fn_name)?;
        self.check_inputs(model_key, fn_name, info, inputs)?;
        let exe = self.executable(model_key, fn_name)?;
        let t0 = Instant::now();
        let result = exe.execute::<xla::Literal>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(format!("{model_key}:{fn_name}")).or_default();
        s.calls += 1;
        s.total_secs += t0.elapsed().as_secs_f64();
        Ok(outs)
    }

    /// Shape-check inputs against the manifest before dispatch: a wrong
    /// tensor must fail with a readable message, not an XLA abort.
    fn check_inputs(
        &self,
        model_key: &str,
        fn_name: &str,
        info: &ArtifactInfo,
        inputs: &[xla::Literal],
    ) -> Result<()> {
        if inputs.len() != info.inputs.len() {
            bail!(
                "{model_key}:{fn_name}: got {} inputs, artifact expects {} ({:?})",
                inputs.len(),
                info.inputs.len(),
                info.inputs.iter().map(|s| s.name.as_str()).collect::<Vec<_>>()
            );
        }
        for (lit, spec) in inputs.iter().zip(&info.inputs) {
            let got = lit.element_count();
            let want: usize = spec.shape.iter().product();
            if got != want {
                bail!(
                    "{model_key}:{fn_name}: input {:?} has {} elements, expects {:?} ({} elements)",
                    spec.name,
                    got,
                    spec.shape,
                    want
                );
            }
        }
        Ok(())
    }

    /// Upload an f32 tensor to the default device (for input caching across
    /// calls: params during the BCD trial loop, proxy eval batches — §Perf).
    ///
    /// Uses `buffer_from_host_buffer` (synchronous `kImmutableOnlyDuringCall`
    /// copy), NOT `buffer_from_host_literal`: the TFRT CPU client copies
    /// literals *asynchronously*, so a literal dropped right after the call
    /// is a use-after-free that aborts with a size-check failure.
    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload an i32 tensor (labels) to the default device.
    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Buffer-input variant of [`Engine::call`]: every input is already
    /// device-resident, so the per-call host→device conversion is limited
    /// to whatever the caller actually changed. Shape checking happened
    /// when the cached buffers were built.
    pub fn call_b(
        &self,
        model_key: &str,
        fn_name: &str,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(model_key, fn_name)?;
        let t0 = Instant::now();
        let result = exe.execute_b::<&xla::PjRtBuffer>(inputs)?;
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        let mut stats = self.stats.borrow_mut();
        let s = stats.entry(format!("{model_key}:{fn_name}")).or_default();
        s.calls += 1;
        s.total_secs += t0.elapsed().as_secs_f64();
        Ok(outs)
    }

    /// Convenience: call with host tensors, returning host tensors.
    pub fn call_tensors(
        &self,
        model_key: &str,
        fn_name: &str,
        inputs: &[&dyn ToLiteral],
    ) -> Result<Vec<Tensor>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        let outs = self.call(model_key, fn_name, &lits)?;
        outs.iter().map(|l| Tensor::from_literal(l)).collect()
    }

    /// Snapshot of per-entry-point execution statistics.
    pub fn stats(&self) -> BTreeMap<String, CallStats> {
        self.stats.borrow().clone()
    }

    /// Pretty statistics table (used by `cdnl info --stats` and benches).
    pub fn stats_table(&self) -> String {
        let mut out = String::from(
            "entry point                              calls   total[s]  mean[ms]  compile[s]\n",
        );
        for (k, s) in self.stats.borrow().iter() {
            let mean_ms = if s.calls > 0 {
                1000.0 * s.total_secs / s.calls as f64
            } else {
                0.0
            };
            out.push_str(&format!(
                "{k:40} {calls:6} {total:9.2} {mean:9.2} {comp:10.2}\n",
                k = k,
                calls = s.calls,
                total = s.total_secs,
                mean = mean_ms,
                comp = s.compile_secs,
            ));
        }
        out
    }
}

/// Anything convertible to an `xla::Literal` (host tensors of both dtypes).
pub trait ToLiteral {
    fn to_literal(&self) -> Result<xla::Literal>;
}

impl ToLiteral for Tensor {
    fn to_literal(&self) -> Result<xla::Literal> {
        Tensor::to_literal(self)
    }
}

impl ToLiteral for crate::tensor::TensorI32 {
    fn to_literal(&self) -> Result<xla::Literal> {
        crate::tensor::TensorI32::to_literal(self)
    }
}
