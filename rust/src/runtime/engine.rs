//! PJRT execution engine: load HLO-text artifacts, compile once, execute.
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! -> `XlaComputation::from_proto` -> `client.compile` -> `execute`. The
//! engine caches compiled executables per (model, entry point) so each
//! artifact pays its XLA compile exactly once per process.
//!
//! The engine implements [`Backend`] and is `Send + Sync`: the executable
//! cache sits behind a `Mutex`, call statistics behind the shared
//! [`StatsRecorder`], and device buffers travel as opaque [`DeviceBuf`]
//! handles so the parallel trial scan can share one engine across workers.
//!
//! Only compiled with `--features pjrt` (the `xla` crate is not in the
//! offline vendor set; see Cargo.toml).

use super::backend::{Backend, CallStats, DeviceBuf, HostArg, StatsRecorder};
use super::manifest::{ArtifactInfo, Manifest, ModelInfo};
use crate::tensor::Tensor;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Device-buffer payload of the PJRT engine.
///
/// SAFETY: this relies on the PJRT C API's documented thread-safety
/// contract — `PJRT_Buffer` handles are immutable once created, and buffer
/// creation, execution, and destruction may be invoked from any thread (the
/// TFRT CPU client synchronizes internally). The Rust-side `!Send`/`!Sync`
/// on xla-rs types is the blanket raw-pointer default, not a statement
/// about the runtime. If a vendored xla-rs build ever wraps handles in
/// thread-affine state, run with `bcd.workers = 1` (the scan result is
/// identical at any worker count) — the parallel scan concurrently
/// uploads trial masks and drops them from worker threads.
pub(crate) struct PjrtBuf(pub(crate) xla::PjRtBuffer);
unsafe impl Send for PjrtBuf {}
unsafe impl Sync for PjrtBuf {}

/// The runtime engine: one PJRT CPU client + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    stats: StatsRecorder,
}

// SAFETY: the PJRT CPU client is internally synchronized — compilation,
// buffer creation and execution are safe from multiple threads per the PJRT
// C API contract (see the PjrtBuf note above for the same caveat about
// vendored builds); all interior mutability on the Rust side is behind
// Mutex/StatsRecorder.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create a CPU engine over an artifacts directory.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::info!(
            "engine: platform={} devices={} models={}",
            client.platform_name(),
            client.device_count(),
            manifest.models.len()
        );
        Ok(Engine {
            client,
            manifest,
            executables: Mutex::new(HashMap::new()),
            stats: StatsRecorder::new(),
        })
    }

    pub fn model(&self, key: &str) -> Result<&ModelInfo> {
        self.manifest.model(key)
    }

    /// Compile (or fetch cached) the executable for `model_key:fn_name`.
    pub fn executable(
        &self,
        model_key: &str,
        fn_name: &str,
    ) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let cache_key = format!("{model_key}:{fn_name}");
        if let Some(e) = self.executables.lock().unwrap().get(&cache_key) {
            return Ok(e.clone());
        }
        let info = self.manifest.model(model_key)?.artifact(fn_name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&info.file)
            .with_context(|| format!("loading HLO text {:?}", info.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {cache_key}"))?;
        let dt = t0.elapsed().as_secs_f64();
        crate::debug!("compiled {cache_key} in {dt:.2}s");
        self.stats.add_compile(&cache_key, dt);
        let rc = Arc::new(exe);
        // A racing thread may have compiled concurrently; keep the first.
        let mut cache = self.executables.lock().unwrap();
        Ok(cache.entry(cache_key).or_insert(rc).clone())
    }

    /// Decompose the executable output into host tensors (artifacts are
    /// lowered with `return_tuple=True`).
    fn decompose(result: Vec<Vec<xla::PjRtBuffer>>) -> Result<Vec<Tensor>> {
        let tuple = result[0][0].to_literal_sync()?;
        let outs = tuple.to_tuple()?;
        outs.iter().map(Tensor::from_literal).collect()
    }

    /// Shape-check inputs against the manifest before dispatch: a wrong
    /// tensor must fail with a readable message, not an XLA abort.
    fn check_inputs(
        &self,
        model_key: &str,
        fn_name: &str,
        info: &ArtifactInfo,
        inputs: &[HostArg],
    ) -> Result<()> {
        if inputs.len() != info.inputs.len() {
            bail!(
                "{model_key}:{fn_name}: got {} inputs, artifact expects {} ({:?})",
                inputs.len(),
                info.inputs.len(),
                info.inputs.iter().map(|s| s.name.as_str()).collect::<Vec<_>>()
            );
        }
        for (arg, spec) in inputs.iter().zip(&info.inputs) {
            let got = arg.element_count();
            let want: usize = spec.shape.iter().product();
            if got != want {
                bail!(
                    "{model_key}:{fn_name}: input {:?} has {} elements, expects {:?} ({} elements)",
                    spec.name,
                    got,
                    spec.shape,
                    want
                );
            }
        }
        Ok(())
    }
}

impl Backend for Engine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Upload an f32 tensor to the default device (for input caching across
    /// calls: params during the BCD trial loop, proxy eval batches — §Perf).
    ///
    /// Uses `buffer_from_host_buffer` (synchronous `kImmutableOnlyDuringCall`
    /// copy), NOT `buffer_from_host_literal`: the TFRT CPU client copies
    /// literals *asynchronously*, so a literal dropped right after the call
    /// is a use-after-free that aborts with a size-check failure.
    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<DeviceBuf> {
        Ok(DeviceBuf::new(PjrtBuf(
            self.client.buffer_from_host_buffer(data, dims, None)?,
        )))
    }

    /// Upload an i32 tensor (labels) to the default device.
    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<DeviceBuf> {
        Ok(DeviceBuf::new(PjrtBuf(
            self.client.buffer_from_host_buffer(data, dims, None)?,
        )))
    }

    /// Execute an entry point with host inputs.
    fn call(&self, model_key: &str, fn_name: &str, inputs: &[HostArg]) -> Result<Vec<Tensor>> {
        let info = self.manifest.model(model_key)?.artifact(fn_name)?;
        self.check_inputs(model_key, fn_name, info, inputs)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|a| match a {
                HostArg::F32(t) => t.to_literal(),
                HostArg::I32(t) => t.to_literal(),
            })
            .collect::<Result<_>>()?;
        let exe = self.executable(model_key, fn_name)?;
        self.stats.timed(&format!("{model_key}:{fn_name}"), || {
            Self::decompose(exe.execute::<xla::Literal>(&lits)?)
        })
    }

    /// Device-buffer variant of [`Backend::call`]: every input is already
    /// device-resident, so the per-call host→device conversion is limited
    /// to whatever the caller actually changed. Shape checking happened
    /// when the cached buffers were built.
    fn call_b(&self, model_key: &str, fn_name: &str, inputs: &[&DeviceBuf]) -> Result<Vec<Tensor>> {
        let mut bufs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        for b in inputs {
            bufs.push(&b.downcast::<PjrtBuf>()?.0);
        }
        let exe = self.executable(model_key, fn_name)?;
        self.stats.timed(&format!("{model_key}:{fn_name}"), || {
            Self::decompose(exe.execute_b::<&xla::PjRtBuffer>(&bufs)?)
        })
    }

    /// Staged execution is deliberately unsupported here: an AOT HLO
    /// artifact is one opaque executable with no addressable layer
    /// boundaries. Returning 0 is the graceful full-forward fallback — the
    /// evaluator sees it and routes every trial through `eval_batch`
    /// (DESIGN.md §8), so PJRT runs behave exactly as before the staged
    /// refactor. (Per-boundary artifacts would need aot.py to emit prefix/
    /// suffix entry points; see ROADMAP.)
    fn segments(&self, _model_key: &str) -> usize {
        0
    }

    /// Batched multi-hypothesis scoring is likewise unsupported: the AOT
    /// executables have no hypothesis axis in their input signatures, so
    /// this engine reports slab width 1 and the evaluator scores trials one
    /// full forward at a time (DESIGN.md §11).
    fn multi_width(&self, _model_key: &str) -> usize {
        1
    }

    fn bump_stat(&self, key: &str, n: u64) {
        self.stats.bump(key, n)
    }

    /// Snapshot of per-entry-point execution statistics.
    fn stats(&self) -> BTreeMap<String, CallStats> {
        self.stats.snapshot()
    }
}
