//! The execution-backend abstraction.
//!
//! [`Backend`] is the seam between the L3 coordinator and whatever actually
//! runs the network: the PJRT engine over AOT HLO artifacts
//! ([`crate::runtime::engine::Engine`], behind the `pjrt` feature) or the
//! pure-Rust [`crate::runtime::reference::RefBackend`] that needs no
//! artifacts at all. Coordinator code only ever sees host [`Tensor`]s and
//! opaque [`DeviceBuf`] handles, so no backend type leaks upward.
//!
//! Backends are `Send + Sync`: the parallel BCD trial scan
//! ([`crate::coordinator::trials::scan_trials`]) shares one backend across a
//! scoped worker pool.
//!
//! Backends that know their model's layer structure can additionally opt
//! into **staged execution** ([`Backend::segments`] /
//! [`Backend::forward_prefix`] / [`Backend::forward_from`] /
//! [`Backend::eval_from`]): a trial whose mask differs from the iteration's
//! base mask only from layer `l` onward resumes from a cached boundary
//! activation instead of re-running the whole network, bit-identically to a
//! full forward (DESIGN.md §8).

use crate::runtime::manifest::{Manifest, ModelInfo};
use crate::tensor::{Tensor, TensorI32};
use anyhow::{anyhow, Result};
use std::any::Any;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

/// Cumulative execution statistics (per entry point), for §Perf.
#[derive(Clone, Debug, Default)]
pub struct CallStats {
    pub calls: u64,
    pub total_secs: f64,
    pub compile_secs: f64,
}

/// An opaque device-resident buffer owned by some backend.
///
/// Backends wrap their native handle (a PJRT buffer, a host vector, ...) and
/// downcast it back on use. Handles are `Send + Sync` so cached evaluation
/// batches can be shared across scan workers.
pub struct DeviceBuf {
    inner: Box<dyn Any + Send + Sync>,
}

impl DeviceBuf {
    pub fn new<T: Any + Send + Sync>(inner: T) -> DeviceBuf {
        DeviceBuf { inner: Box::new(inner) }
    }

    /// View the native handle; fails when the buffer belongs to a different
    /// backend (e.g. a reference-backend buffer handed to the PJRT engine).
    pub fn downcast<T: Any>(&self) -> Result<&T> {
        self.inner
            .downcast_ref::<T>()
            .ok_or_else(|| anyhow!("DeviceBuf: handle belongs to a different backend"))
    }
}

/// A device-resident slab of `n` dense mask rows of `width` f32s each,
/// laid out row-major along the **hypothesis axis** (DESIGN.md §11): row
/// `h` holds hypothesis `h`'s mask values. For the batched-full API the
/// width is the whole dense mask; for the batched-staged API it is the
/// mask suffix after the resume boundary (the same slice
/// [`Backend::forward_from`] takes for a single hypothesis).
pub struct MaskSlab {
    /// The uploaded `[n, width]` f32 buffer.
    pub buf: DeviceBuf,
    pub n: usize,
    pub width: usize,
}

/// A borrowed host-side argument at the call boundary (the only two dtypes
/// the artifact interface uses: f32 data, i32 labels/seeds).
#[derive(Clone, Copy, Debug)]
pub enum HostArg<'a> {
    F32(&'a Tensor),
    I32(&'a TensorI32),
}

impl HostArg<'_> {
    pub fn element_count(&self) -> usize {
        match self {
            HostArg::F32(t) => t.data.len(),
            HostArg::I32(t) => t.data.len(),
        }
    }
}

/// An execution backend: runs a model's entry points on host or device
/// inputs and hands back host tensors.
///
/// Entry-point names and signatures follow the artifact contract written by
/// `python/compile/aot.py` (`init`, `forward`, `eval_batch`, `train_step`,
/// `snl_step`, `kd_step`); outputs are always f32 tensors.
pub trait Backend: Send + Sync {
    /// Short backend identifier ("pjrt", "reference"), used for logs and to
    /// namespace the model-zoo cache.
    fn name(&self) -> &'static str;

    /// The model table this backend serves (shape + layer layout source of
    /// truth; for the reference backend it is synthesized, not loaded).
    fn manifest(&self) -> &Manifest;

    fn model(&self, key: &str) -> Result<&ModelInfo> {
        self.manifest().model(key)
    }

    /// The fixed batch size every batched entry point was built for.
    fn batch(&self) -> usize {
        self.manifest().batch
    }

    /// Upload an f32 tensor for reuse across many calls (params during the
    /// BCD trial loop, proxy eval batches — §Perf).
    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<DeviceBuf>;

    /// Upload an i32 tensor (labels).
    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<DeviceBuf>;

    /// Execute an entry point on host inputs.
    fn call(&self, model_key: &str, fn_name: &str, inputs: &[HostArg]) -> Result<Vec<Tensor>>;

    /// Execute an entry point on device-resident inputs (the trial hot
    /// path: every input was uploaded once and is re-used across calls).
    fn call_b(&self, model_key: &str, fn_name: &str, inputs: &[&DeviceBuf]) -> Result<Vec<Tensor>>;

    // ---- staged execution (DESIGN.md §8) ----------------------------------
    //
    // A backend that knows its model's layer structure can resume a forward
    // pass from a cached intermediate activation instead of re-running the
    // whole network. Boundary `b` caches an activation that has consumed
    // mask layers `0..=segment_layer(b)` (manifest `mask_layers` order) and
    // nothing after; a hypothesis whose first dirty layer is `l >= 1` can
    // resume from any boundary with `segment_layer(b) < l`, feeding the
    // mask suffix that starts at layer `segment_layer(b) + 1`. For the MLP
    // reference `segment_layer(b) == b` (each boundary is mask layer `b`'s
    // own output); conv topologies map boundaries to residual-block
    // outputs, which fold in *two* mask layers per block. The incremental
    // results must be **bit-identical** to a full forward — the
    // replay-merge determinism contract of the trial scan depends on it.

    /// Number of resumable segment boundaries for `model_key`. `0` (the
    /// default) means staged execution is unsupported and callers must fall
    /// back to full forwards — the graceful degradation path the PJRT
    /// engine takes, since an AOT HLO artifact is one opaque executable.
    fn segments(&self, _model_key: &str) -> usize {
        0
    }

    /// Deepest mask-layer index folded into boundary `segment`'s cached
    /// activation (see the module section comment). Must be strictly
    /// increasing in `segment`. The default — boundary `b` is mask layer
    /// `b`'s output — matches the MLP reference layout; backends with
    /// coarser resume points (conv residual blocks) override it.
    fn segment_layer(&self, _model_key: &str, segment: usize) -> usize {
        segment
    }

    /// Compute the boundary-`segment` activations of one batch under
    /// (params, mask). The returned handle is only meaningful to this
    /// backend's [`Backend::forward_from`] / [`Backend::eval_from`].
    fn forward_prefix(
        &self,
        model_key: &str,
        segment: usize,
        _params: &DeviceBuf,
        _mask: &DeviceBuf,
        _x: &DeviceBuf,
    ) -> Result<DeviceBuf> {
        Err(anyhow!(
            "backend {}: staged execution unsupported ({model_key}:forward_prefix@{segment})",
            self.name()
        ))
    }

    /// Resume the forward pass from boundary `segment`: `acts` comes from
    /// [`Backend::forward_prefix`], `mask_suffix` covers the mask entries
    /// of every layer *after* `segment` (`mask[mask_layers[segment + 1]
    /// .offset..]`). Returns logits `[B, K]`, bit-identical to a full
    /// forward whose mask agrees with the prefix that produced `acts`.
    fn forward_from(
        &self,
        model_key: &str,
        segment: usize,
        _acts: &DeviceBuf,
        _params: &DeviceBuf,
        _mask_suffix: &DeviceBuf,
    ) -> Result<Tensor> {
        Err(anyhow!(
            "backend {}: staged execution unsupported ({model_key}:forward_from@{segment})",
            self.name()
        ))
    }

    /// [`Backend::forward_from`] fused with the `eval_batch` epilogue:
    /// returns `[loss, correct]` scalars computed by the exact same scoring
    /// code as `eval_batch`, so incremental and full trial scoring agree
    /// bit for bit.
    fn eval_from(
        &self,
        model_key: &str,
        segment: usize,
        _acts: &DeviceBuf,
        _params: &DeviceBuf,
        _mask_suffix: &DeviceBuf,
        _y: &DeviceBuf,
    ) -> Result<Vec<Tensor>> {
        Err(anyhow!(
            "backend {}: staged execution unsupported ({model_key}:eval_from@{segment})",
            self.name()
        ))
    }

    // ---- batched multi-hypothesis scoring (DESIGN.md §11) -----------------
    //
    // One BCD iteration scores RT hypotheses that differ from the base mask
    // at only DRC indices. A backend can score a slab of B hypotheses per
    // forward, sharing every mask-independent computation (the affine
    // pre-activations) across the hypothesis axis and applying per-
    // hypothesis masks only where they act. Results must be bit-identical,
    // per hypothesis, to the corresponding single-hypothesis call — the
    // replay-merge contract extends across the hypothesis axis.
    //
    // `live[h] == false` marks a hypothesis already cut by the scan bound:
    // the backend skips its per-hypothesis work and returns `None` for it.

    /// Maximum hypothesis-slab width this backend accepts for `model_key`.
    /// `1` (the default) means the batched API is unsupported and callers
    /// score hypotheses one at a time — the PJRT engine's answer, since an
    /// AOT HLO artifact has no hypothesis axis.
    fn multi_width(&self, _model_key: &str) -> usize {
        1
    }

    /// `eval_batch` over a hypothesis slab of **full dense masks**: returns
    /// `(loss, correct)` per live hypothesis, each bit-identical to the
    /// single-mask `eval_batch` on that row.
    fn eval_batch_multi(
        &self,
        model_key: &str,
        _params: &DeviceBuf,
        _masks: &MaskSlab,
        _x: &DeviceBuf,
        _y: &DeviceBuf,
        _live: &[bool],
    ) -> Result<Vec<Option<(f32, f32)>>> {
        Err(anyhow!(
            "backend {}: batched scoring unsupported ({model_key}:eval_batch_multi)",
            self.name()
        ))
    }

    /// `forward` over a hypothesis slab of full dense masks: logits
    /// `[B, K]` per live hypothesis.
    fn forward_multi(
        &self,
        model_key: &str,
        _params: &DeviceBuf,
        _masks: &MaskSlab,
        _x: &DeviceBuf,
        _live: &[bool],
    ) -> Result<Vec<Option<Tensor>>> {
        Err(anyhow!(
            "backend {}: batched scoring unsupported ({model_key}:forward_multi)",
            self.name()
        ))
    }

    /// [`Backend::forward_from`] over a hypothesis slab of **mask
    /// suffixes** (each row as that method's `mask_suffix`), resuming every
    /// hypothesis from the same cached boundary activation.
    fn forward_from_multi(
        &self,
        model_key: &str,
        segment: usize,
        _acts: &DeviceBuf,
        _params: &DeviceBuf,
        _mask_suffixes: &MaskSlab,
        _live: &[bool],
    ) -> Result<Vec<Option<Tensor>>> {
        Err(anyhow!(
            "backend {}: batched scoring unsupported ({model_key}:forward_from_multi@{segment})",
            self.name()
        ))
    }

    /// [`Backend::eval_from`] over a hypothesis slab of mask suffixes:
    /// `(loss, correct)` per live hypothesis via the one shared scoring
    /// epilogue.
    fn eval_from_multi(
        &self,
        model_key: &str,
        segment: usize,
        _acts: &DeviceBuf,
        _params: &DeviceBuf,
        _mask_suffixes: &MaskSlab,
        _y: &DeviceBuf,
        _live: &[bool],
    ) -> Result<Vec<Option<(f32, f32)>>> {
        Err(anyhow!(
            "backend {}: batched scoring unsupported ({model_key}:eval_from_multi@{segment})",
            self.name()
        ))
    }

    /// Size in bytes of one cached boundary-`segment` activation for a
    /// batch of `batch` examples — the evaluator's cache accounting for
    /// handles this backend returns from [`Backend::forward_prefix`]. The
    /// default assumes one f32 per unit of the boundary's mask layer
    /// ([`Backend::segment_layer`]; the reference MLP layout); a backend
    /// whose handles carry more (spatial feature maps, pre-activations,
    /// padding, wider dtypes) must override so `bcd.cache_mb` keeps
    /// meaning bytes.
    fn prefix_entry_bytes(&self, model_key: &str, segment: usize, batch: usize) -> usize {
        let layer = self.segment_layer(model_key, segment);
        self.model(model_key)
            .ok()
            .and_then(|m| m.mask_layers.get(layer))
            .map(|e| 4 * batch * e.size)
            .unwrap_or(0)
    }

    /// Bump a named counter in this backend's statistics (prefix-cache
    /// hits/misses/evictions and friends — §Perf). Default: no-op.
    fn bump_stat(&self, _key: &str, _n: u64) {}

    /// Snapshot of per-entry-point execution statistics.
    fn stats(&self) -> BTreeMap<String, CallStats>;

    /// Pretty statistics table (used by `cdnl info --stats` and benches).
    fn stats_table(&self) -> String {
        format_stats_table(&self.stats())
    }
}

/// Render a stats map as the fixed-width table both backends share.
pub fn format_stats_table(stats: &BTreeMap<String, CallStats>) -> String {
    let mut out = String::from(
        "entry point                              calls   total[s]  mean[ms]  compile[s]\n",
    );
    for (k, s) in stats {
        let mean_ms = if s.calls > 0 {
            1000.0 * s.total_secs / s.calls as f64
        } else {
            0.0
        };
        out.push_str(&format!(
            "{k:40} {calls:6} {total:9.2} {mean:9.2} {comp:10.2}\n",
            calls = s.calls,
            total = s.total_secs,
            mean = mean_ms,
            comp = s.compile_secs,
        ));
    }
    out
}

/// Thread-safe per-entry-point stats accumulator shared by every backend —
/// the single implementation of the record-keeping that used to be
/// duplicated between `Engine::call` and `Engine::call_b`.
#[derive(Default)]
pub struct StatsRecorder {
    stats: Mutex<BTreeMap<String, CallStats>>,
}

impl StatsRecorder {
    pub fn new() -> StatsRecorder {
        StatsRecorder::default()
    }

    /// Run `f`, crediting its wall time (and one call) to `key`.
    pub fn timed<T>(&self, key: &str, f: impl FnOnce() -> Result<T>) -> Result<T> {
        let t0 = Instant::now();
        let out = f()?;
        let dt = t0.elapsed().as_secs_f64();
        let mut stats = self.stats.lock().unwrap();
        let s = stats.entry(key.to_string()).or_default();
        s.calls += 1;
        s.total_secs += dt;
        Ok(out)
    }

    /// Credit one-time compile/setup seconds to `key`.
    pub fn add_compile(&self, key: &str, secs: f64) {
        let mut stats = self.stats.lock().unwrap();
        stats.entry(key.to_string()).or_default().compile_secs += secs;
    }

    /// Bump a pure counter by `n` (no wall time): cache hit/miss/eviction
    /// tallies ride in `calls` with zero seconds.
    pub fn bump(&self, key: &str, n: u64) {
        let mut stats = self.stats.lock().unwrap();
        stats.entry(key.to_string()).or_default().calls += n;
    }

    pub fn snapshot(&self) -> BTreeMap<String, CallStats> {
        self.stats.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_buf_downcast() {
        let b = DeviceBuf::new(vec![1.0f32, 2.0]);
        assert_eq!(b.downcast::<Vec<f32>>().unwrap(), &vec![1.0, 2.0]);
        assert!(b.downcast::<Vec<i32>>().is_err());
    }

    #[test]
    fn stats_recorder_accumulates() {
        let r = StatsRecorder::new();
        let v: i32 = r.timed("m:f", || Ok(3)).unwrap();
        assert_eq!(v, 3);
        let _ = r.timed("m:f", || Ok(())).unwrap();
        r.add_compile("m:f", 1.5);
        let snap = r.snapshot();
        let s = snap.get("m:f").unwrap();
        assert_eq!(s.calls, 2);
        assert!(s.compile_secs > 1.0);
        assert!(format_stats_table(&snap).contains("m:f"));
    }

    #[test]
    fn bump_counts_without_time() {
        let r = StatsRecorder::new();
        r.bump("prefix_cache:hit", 3);
        r.bump("prefix_cache:hit", 2);
        let snap = r.snapshot();
        let s = snap.get("prefix_cache:hit").unwrap();
        assert_eq!(s.calls, 5);
        assert_eq!(s.total_secs, 0.0);
    }

    #[test]
    fn staged_execution_defaults_are_unsupported() {
        // A minimal backend relying on every staged-execution default.
        struct Stub(Manifest);
        impl Backend for Stub {
            fn name(&self) -> &'static str {
                "stub"
            }
            fn manifest(&self) -> &Manifest {
                &self.0
            }
            fn upload_f32(&self, d: &[f32], _dims: &[usize]) -> Result<DeviceBuf> {
                Ok(DeviceBuf::new(d.to_vec()))
            }
            fn upload_i32(&self, d: &[i32], _dims: &[usize]) -> Result<DeviceBuf> {
                Ok(DeviceBuf::new(d.to_vec()))
            }
            fn call(&self, _m: &str, _f: &str, _i: &[HostArg]) -> Result<Vec<Tensor>> {
                Ok(vec![])
            }
            fn call_b(&self, _m: &str, _f: &str, _i: &[&DeviceBuf]) -> Result<Vec<Tensor>> {
                Ok(vec![])
            }
            fn stats(&self) -> BTreeMap<String, CallStats> {
                BTreeMap::new()
            }
        }
        let stub = Stub(Manifest {
            batch: 1,
            kernel_impl: "stub".into(),
            models: BTreeMap::new(),
            dir: std::path::PathBuf::new(),
        });
        assert_eq!(stub.segments("m"), 0);
        let buf = stub.upload_f32(&[1.0], &[1]).unwrap();
        let err = stub.forward_prefix("m", 0, &buf, &buf, &buf).unwrap_err();
        assert!(err.to_string().contains("unsupported"), "{err}");
        assert!(stub.forward_from("m", 0, &buf, &buf, &buf).is_err());
        assert!(stub.eval_from("m", 0, &buf, &buf, &buf, &buf).is_err());
        stub.bump_stat("x", 1); // default no-op must not panic

        // Batched multi-hypothesis defaults: width 1, every method errors.
        assert_eq!(stub.multi_width("m"), 1);
        let slab = MaskSlab {
            buf: stub.upload_f32(&[1.0], &[1]).unwrap(),
            n: 1,
            width: 1,
        };
        let live = [true];
        let err = stub
            .eval_batch_multi("m", &buf, &slab, &buf, &buf, &live)
            .unwrap_err();
        assert!(err.to_string().contains("batched scoring unsupported"), "{err}");
        assert!(stub.forward_multi("m", &buf, &slab, &buf, &live).is_err());
        assert!(stub
            .forward_from_multi("m", 0, &buf, &buf, &slab, &live)
            .is_err());
        assert!(stub
            .eval_from_multi("m", 0, &buf, &buf, &slab, &buf, &live)
            .is_err());
    }

    #[test]
    fn failed_call_not_counted() {
        let r = StatsRecorder::new();
        let out: Result<()> = r.timed("m:g", || Err(anyhow!("boom")));
        assert!(out.is_err());
        assert!(r.snapshot().get("m:g").is_none());
    }
}
