//! Typed session over one model variant of a [`Backend`].
//!
//! [`Session`] maps the entry points (`init`, `forward`, `eval_batch`,
//! `train_step`, `snl_step`, `kd_step`) to rust signatures so coordinator
//! code never touches raw backend calls, and brokers the device-buffer
//! cache for inputs that stay constant across many calls (§Perf: the BCD
//! trial loop re-sends only the trial mask). A `Session` is `Sync` — the
//! parallel trial scan shares one across its worker pool.

use super::backend::{Backend, DeviceBuf, HostArg, MaskSlab};
use super::manifest::ModelInfo;
use crate::model::ModelState;
use crate::tensor::{Tensor, TensorI32};
use anyhow::{Context, Result};

/// Output of one SGD/finetune step.
#[derive(Clone, Copy, Debug)]
pub struct StepOut {
    pub loss: f32,
    /// Correct predictions in this batch (absolute count).
    pub correct: f32,
}

/// A typed handle on one model variant (`model_key`) of a [`Backend`].
pub struct Session<'e> {
    pub backend: &'e dyn Backend,
    pub key: String,
    pub batch: usize,
}

impl<'e> Session<'e> {
    pub fn new(backend: &'e dyn Backend, model_key: &str) -> Result<Session<'e>> {
        let _ = backend.model(model_key)?; // fail fast on unknown keys
        Ok(Session { backend, key: model_key.to_string(), batch: backend.batch() })
    }

    pub fn info(&self) -> &ModelInfo {
        self.backend.model(&self.key).expect("validated in new()")
    }

    /// Deterministic parameter initialization (entry point `init`).
    pub fn init(&self, seed: i32) -> Result<Tensor> {
        let seed = TensorI32::scalar(seed);
        let mut outs = self.backend.call(&self.key, "init", &[HostArg::I32(&seed)])?;
        Ok(outs.remove(0))
    }

    /// Fresh [`ModelState`] from a seed.
    pub fn init_state(&self, seed: i32) -> Result<ModelState> {
        Ok(ModelState::new(self.info(), self.init(seed)?))
    }

    /// Forward pass -> logits `[B, K]`.
    pub fn forward(&self, params: &Tensor, mask: &[f32], x: &Tensor) -> Result<Tensor> {
        let mask = Tensor::new(vec![mask.len()], mask.to_vec());
        let mut outs = self.backend.call(
            &self.key,
            "forward",
            &[HostArg::F32(params), HostArg::F32(&mask), HostArg::F32(x)],
        )?;
        Ok(outs.remove(0))
    }

    /// Buffer-input forward (used for exact scoring of the final partial
    /// evaluation batch): all inputs are cached device buffers.
    pub fn forward_b(
        &self,
        params: &DeviceBuf,
        mask: &DeviceBuf,
        x: &DeviceBuf,
    ) -> Result<Tensor> {
        let mut outs = self.backend.call_b(&self.key, "forward", &[params, mask, x])?;
        Ok(outs.remove(0))
    }

    /// Loss + correct-count on one batch (entry point `eval_batch`).
    pub fn eval_batch(
        &self,
        params: &Tensor,
        mask: &[f32],
        x: &Tensor,
        y: &TensorI32,
    ) -> Result<StepOut> {
        let mask = Tensor::new(vec![mask.len()], mask.to_vec());
        let outs = self.backend.call(
            &self.key,
            "eval_batch",
            &[HostArg::F32(params), HostArg::F32(&mask), HostArg::F32(x), HostArg::I32(y)],
        )?;
        Ok(StepOut { loss: outs[0].item(), correct: outs[1].item() })
    }

    /// Buffer-input eval (the BCD trial hot path): `params`, `x`, `y` are
    /// cached device buffers; only the trial mask is uploaded per call.
    pub fn eval_batch_b(
        &self,
        params: &DeviceBuf,
        mask: &DeviceBuf,
        x: &DeviceBuf,
        y: &DeviceBuf,
    ) -> Result<StepOut> {
        let outs = self
            .backend
            .call_b(&self.key, "eval_batch", &[params, mask, x, y])?;
        Ok(StepOut { loss: outs[0].item(), correct: outs[1].item() })
    }

    /// Number of resumable segment boundaries of this model's forward pass
    /// (0 = the backend only runs full forwards; see
    /// [`crate::runtime::backend::Backend::segments`]).
    pub fn segments(&self) -> usize {
        self.backend.segments(&self.key)
    }

    /// Boundary-`segment` activations of one cached batch under
    /// (params, mask) — the prefix the staged trial path caches and reuses
    /// (DESIGN.md §8).
    pub fn forward_prefix_b(
        &self,
        segment: usize,
        params: &DeviceBuf,
        mask: &DeviceBuf,
        x: &DeviceBuf,
    ) -> Result<DeviceBuf> {
        self.backend.forward_prefix(&self.key, segment, params, mask, x)
    }

    /// Resume a forward pass from boundary `segment` -> logits `[B, K]`.
    /// `mask_suffix` covers the mask layers after the boundary.
    pub fn forward_from_b(
        &self,
        segment: usize,
        acts: &DeviceBuf,
        params: &DeviceBuf,
        mask_suffix: &DeviceBuf,
    ) -> Result<Tensor> {
        self.backend.forward_from(&self.key, segment, acts, params, mask_suffix)
    }

    /// Resume + score one batch from boundary `segment` (the staged twin of
    /// [`Self::eval_batch_b`], bit-identical by contract).
    pub fn eval_from_b(
        &self,
        segment: usize,
        acts: &DeviceBuf,
        params: &DeviceBuf,
        mask_suffix: &DeviceBuf,
        y: &DeviceBuf,
    ) -> Result<StepOut> {
        let outs = self
            .backend
            .eval_from(&self.key, segment, acts, params, mask_suffix, y)?;
        Ok(StepOut { loss: outs[0].item(), correct: outs[1].item() })
    }

    /// Maximum hypothesis-slab width the backend accepts for this model
    /// (1 = batched multi-hypothesis scoring unsupported; see
    /// [`crate::runtime::backend::Backend::multi_width`]).
    pub fn multi_width(&self) -> usize {
        self.backend.multi_width(&self.key)
    }

    /// Score a slab of full dense-mask hypotheses on one cached batch:
    /// per live hypothesis, bit-identical to [`Self::eval_batch_b`] on
    /// that row (DESIGN.md §11).
    pub fn eval_batch_multi_b(
        &self,
        params: &DeviceBuf,
        masks: &MaskSlab,
        x: &DeviceBuf,
        y: &DeviceBuf,
        live: &[bool],
    ) -> Result<Vec<Option<StepOut>>> {
        let outs = self
            .backend
            .eval_batch_multi(&self.key, params, masks, x, y, live)?;
        Ok(outs
            .into_iter()
            .map(|o| o.map(|(loss, correct)| StepOut { loss, correct }))
            .collect())
    }

    /// Forward a slab of full dense-mask hypotheses -> logits per live
    /// hypothesis (exact rescoring of partial batches on the slab path).
    pub fn forward_multi_b(
        &self,
        params: &DeviceBuf,
        masks: &MaskSlab,
        x: &DeviceBuf,
        live: &[bool],
    ) -> Result<Vec<Option<Tensor>>> {
        self.backend.forward_multi(&self.key, params, masks, x, live)
    }

    /// Resume a slab of mask-suffix hypotheses from boundary `segment` ->
    /// logits per live hypothesis.
    pub fn forward_from_multi_b(
        &self,
        segment: usize,
        acts: &DeviceBuf,
        params: &DeviceBuf,
        mask_suffixes: &MaskSlab,
        live: &[bool],
    ) -> Result<Vec<Option<Tensor>>> {
        self.backend
            .forward_from_multi(&self.key, segment, acts, params, mask_suffixes, live)
    }

    /// Resume + score a slab of mask-suffix hypotheses from boundary
    /// `segment` (the slab twin of [`Self::eval_from_b`]).
    pub fn eval_from_multi_b(
        &self,
        segment: usize,
        acts: &DeviceBuf,
        params: &DeviceBuf,
        mask_suffixes: &MaskSlab,
        y: &DeviceBuf,
        live: &[bool],
    ) -> Result<Vec<Option<StepOut>>> {
        let outs = self
            .backend
            .eval_from_multi(&self.key, segment, acts, params, mask_suffixes, y, live)?;
        Ok(outs
            .into_iter()
            .map(|o| o.map(|(loss, correct)| StepOut { loss, correct }))
            .collect())
    }

    /// Upload a flat f32 slice as a device buffer.
    pub fn upload_f32(&self, data: &[f32], shape: &[usize]) -> Result<DeviceBuf> {
        self.backend.upload_f32(data, shape)
    }

    /// Upload a host tensor pair (x, y) as device buffers.
    pub fn upload_batch(&self, x: &Tensor, y: &TensorI32) -> Result<(DeviceBuf, DeviceBuf)> {
        Ok((
            self.backend.upload_f32(&x.data, &x.shape)?,
            self.backend.upload_i32(&y.data, &y.shape)?,
        ))
    }

    /// One SGD-momentum step; updates `st.params` / `st.mom` in place.
    pub fn train_step(
        &self,
        st: &mut ModelState,
        x: &Tensor,
        y: &TensorI32,
        lr: f32,
    ) -> Result<StepOut> {
        let mask = st.mask.to_tensor();
        let lr = Tensor::scalar(lr);
        let mut outs = self
            .backend
            .call(
                &self.key,
                "train_step",
                &[
                    HostArg::F32(&st.params),
                    HostArg::F32(&st.mom),
                    HostArg::F32(&mask),
                    HostArg::F32(x),
                    HostArg::I32(y),
                    HostArg::F32(&lr),
                ],
            )
            .context("train_step")?;
        let out = StepOut { loss: outs[2].item(), correct: outs[3].item() };
        st.mom = outs.swap_remove(1);
        st.params = outs.swap_remove(0);
        Ok(out)
    }

    /// One selective (SNL) step: trains weights AND soft alphas under
    /// `CE + lam * ||alpha||_1`; updates `params`, `mom`, `alphas` in place.
    /// `alpha_lr` is the separate alpha step size (see fn_snl_step in
    /// python/compile/model.py for why it must exceed the weight lr at our
    /// compressed step budget).
    #[allow(clippy::too_many_arguments)]
    pub fn snl_step(
        &self,
        params: &mut Tensor,
        mom: &mut Tensor,
        alphas: &mut Tensor,
        x: &Tensor,
        y: &TensorI32,
        lr: f32,
        alpha_lr: f32,
        lam: f32,
    ) -> Result<f32> {
        let lr = Tensor::scalar(lr);
        let alpha_lr = Tensor::scalar(alpha_lr);
        let lam = Tensor::scalar(lam);
        let mut outs = self
            .backend
            .call(
                &self.key,
                "snl_step",
                &[
                    HostArg::F32(params),
                    HostArg::F32(mom),
                    HostArg::F32(alphas),
                    HostArg::F32(x),
                    HostArg::I32(y),
                    HostArg::F32(&lr),
                    HostArg::F32(&alpha_lr),
                    HostArg::F32(&lam),
                ],
            )
            .context("snl_step")?;
        let loss = outs[3].item();
        *alphas = outs.swap_remove(2);
        *mom = outs.swap_remove(1);
        *params = outs.swap_remove(0);
        Ok(loss)
    }

    /// One knowledge-distillation step (SENet finetune), teacher logits in.
    pub fn kd_step(
        &self,
        st: &mut ModelState,
        x: &Tensor,
        y: &TensorI32,
        t_logits: &Tensor,
        lr: f32,
        temp: f32,
    ) -> Result<f32> {
        let mask = st.mask.to_tensor();
        let lr = Tensor::scalar(lr);
        let temp = Tensor::scalar(temp);
        let mut outs = self
            .backend
            .call(
                &self.key,
                "kd_step",
                &[
                    HostArg::F32(&st.params),
                    HostArg::F32(&st.mom),
                    HostArg::F32(&mask),
                    HostArg::F32(x),
                    HostArg::I32(y),
                    HostArg::F32(t_logits),
                    HostArg::F32(&lr),
                    HostArg::F32(&temp),
                ],
            )
            .context("kd_step")?;
        let loss = outs[2].item();
        st.mom = outs.swap_remove(1);
        st.params = outs.swap_remove(0);
        Ok(loss)
    }
}
