//! Typed session over one model variant's artifact set.
//!
//! [`Session`] maps the manifest entry points (`init`, `forward`,
//! `eval_batch`, `train_step`, `snl_step`, `kd_step`) to rust signatures so
//! coordinator code never touches raw literals, and owns the device-buffer
//! cache for inputs that stay constant across many calls (§Perf: the BCD
//! trial loop re-sends only the trial mask).

use super::engine::Engine;
use super::manifest::ModelInfo;
use crate::model::ModelState;
use crate::tensor::{Tensor, TensorI32};
use anyhow::{Context, Result};

/// Output of one SGD/finetune step.
#[derive(Clone, Copy, Debug)]
pub struct StepOut {
    pub loss: f32,
    /// Correct predictions in this batch (absolute count).
    pub correct: f32,
}

/// A typed handle on one model variant (`model_key`) of an [`Engine`].
pub struct Session<'e> {
    pub engine: &'e Engine,
    pub key: String,
    pub batch: usize,
}

impl<'e> Session<'e> {
    pub fn new(engine: &'e Engine, model_key: &str) -> Result<Session<'e>> {
        let _ = engine.model(model_key)?; // fail fast on unknown keys
        Ok(Session { engine, key: model_key.to_string(), batch: engine.manifest.batch })
    }

    pub fn info(&self) -> &ModelInfo {
        self.engine.model(&self.key).expect("validated in new()")
    }

    /// Deterministic parameter initialization (artifact `init`).
    pub fn init(&self, seed: i32) -> Result<Tensor> {
        let outs = self.engine.call(
            &self.key,
            "init",
            &[TensorI32::scalar(seed).to_literal()?],
        )?;
        Tensor::from_literal(&outs[0])
    }

    /// Fresh [`ModelState`] from a seed.
    pub fn init_state(&self, seed: i32) -> Result<ModelState> {
        Ok(ModelState::new(self.info(), self.init(seed)?))
    }

    /// Forward pass -> logits `[B, K]`.
    pub fn forward(&self, params: &Tensor, mask: &[f32], x: &Tensor) -> Result<Tensor> {
        let outs = self.engine.call(
            &self.key,
            "forward",
            &[
                params.to_literal()?,
                Tensor::new(vec![mask.len()], mask.to_vec()).to_literal()?,
                x.to_literal()?,
            ],
        )?;
        Tensor::from_literal(&outs[0])
    }

    /// Loss + correct-count on one batch (artifact `eval_batch`).
    pub fn eval_batch(
        &self,
        params: &Tensor,
        mask: &[f32],
        x: &Tensor,
        y: &TensorI32,
    ) -> Result<StepOut> {
        let outs = self.engine.call(
            &self.key,
            "eval_batch",
            &[
                params.to_literal()?,
                Tensor::new(vec![mask.len()], mask.to_vec()).to_literal()?,
                x.to_literal()?,
                y.to_literal()?,
            ],
        )?;
        Ok(StepOut {
            loss: Tensor::from_literal(&outs[0])?.item(),
            correct: Tensor::from_literal(&outs[1])?.item(),
        })
    }

    /// Buffer-input eval (the BCD trial hot path): `params`, `x`, `y` are
    /// cached device buffers; only the trial mask is uploaded per call.
    pub fn eval_batch_b(
        &self,
        params: &xla::PjRtBuffer,
        mask: &xla::PjRtBuffer,
        x: &xla::PjRtBuffer,
        y: &xla::PjRtBuffer,
    ) -> Result<StepOut> {
        let outs = self
            .engine
            .call_b(&self.key, "eval_batch", &[params, mask, x, y])?;
        Ok(StepOut {
            loss: Tensor::from_literal(&outs[0])?.item(),
            correct: Tensor::from_literal(&outs[1])?.item(),
        })
    }

    /// Upload a flat f32 slice as a device buffer.
    pub fn upload_f32(&self, data: &[f32], shape: &[usize]) -> Result<xla::PjRtBuffer> {
        self.engine.upload_f32(data, shape)
    }

    /// Upload a host tensor pair (x, y) as device buffers.
    pub fn upload_batch(
        &self,
        x: &Tensor,
        y: &TensorI32,
    ) -> Result<(xla::PjRtBuffer, xla::PjRtBuffer)> {
        Ok((
            self.engine.upload_f32(&x.data, &x.shape)?,
            self.engine.upload_i32(&y.data, &y.shape)?,
        ))
    }

    /// One SGD-momentum step; updates `st.params` / `st.mom` in place.
    pub fn train_step(
        &self,
        st: &mut ModelState,
        x: &Tensor,
        y: &TensorI32,
        lr: f32,
    ) -> Result<StepOut> {
        let outs = self
            .engine
            .call(
                &self.key,
                "train_step",
                &[
                    st.params.to_literal()?,
                    st.mom.to_literal()?,
                    st.mask.to_tensor().to_literal()?,
                    x.to_literal()?,
                    y.to_literal()?,
                    Tensor::scalar(lr).to_literal()?,
                ],
            )
            .context("train_step")?;
        st.params = Tensor::from_literal(&outs[0])?;
        st.mom = Tensor::from_literal(&outs[1])?;
        Ok(StepOut {
            loss: Tensor::from_literal(&outs[2])?.item(),
            correct: Tensor::from_literal(&outs[3])?.item(),
        })
    }

    /// One selective (SNL) step: trains weights AND soft alphas under
    /// `CE + lam * ||alpha||_1`; updates `params`, `mom`, `alphas` in place.
    /// `alpha_lr` is the separate alpha step size (see fn_snl_step in
    /// python/compile/model.py for why it must exceed the weight lr at our
    /// compressed step budget).
    #[allow(clippy::too_many_arguments)]
    pub fn snl_step(
        &self,
        params: &mut Tensor,
        mom: &mut Tensor,
        alphas: &mut Tensor,
        x: &Tensor,
        y: &TensorI32,
        lr: f32,
        alpha_lr: f32,
        lam: f32,
    ) -> Result<f32> {
        let outs = self
            .engine
            .call(
                &self.key,
                "snl_step",
                &[
                    params.to_literal()?,
                    mom.to_literal()?,
                    alphas.to_literal()?,
                    x.to_literal()?,
                    y.to_literal()?,
                    Tensor::scalar(lr).to_literal()?,
                    Tensor::scalar(alpha_lr).to_literal()?,
                    Tensor::scalar(lam).to_literal()?,
                ],
            )
            .context("snl_step")?;
        *params = Tensor::from_literal(&outs[0])?;
        *mom = Tensor::from_literal(&outs[1])?;
        *alphas = Tensor::from_literal(&outs[2])?;
        Ok(Tensor::from_literal(&outs[3])?.item())
    }

    /// One knowledge-distillation step (SENet finetune), teacher logits in.
    pub fn kd_step(
        &self,
        st: &mut ModelState,
        x: &Tensor,
        y: &TensorI32,
        t_logits: &Tensor,
        lr: f32,
        temp: f32,
    ) -> Result<f32> {
        let outs = self
            .engine
            .call(
                &self.key,
                "kd_step",
                &[
                    st.params.to_literal()?,
                    st.mom.to_literal()?,
                    st.mask.to_tensor().to_literal()?,
                    x.to_literal()?,
                    y.to_literal()?,
                    t_logits.to_literal()?,
                    Tensor::scalar(lr).to_literal()?,
                    Tensor::scalar(temp).to_literal()?,
                ],
            )
            .context("kd_step")?;
        st.params = Tensor::from_literal(&outs[0])?;
        st.mom = Tensor::from_literal(&outs[1])?;
        Ok(Tensor::from_literal(&outs[2])?.item())
    }
}
