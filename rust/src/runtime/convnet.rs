//! Compiled conv/residual topologies for the reference backend
//! (DESIGN.md §12).
//!
//! Ports the model specs in `python/compile/models/{resnet,wideresnet}.py`
//! to the pure-Rust runtime: a [`ConvSpec`] names a family + shape knobs,
//! [`ConvPlan::build`] compiles it into a flat parameter pack (conv weights
//! OIHW, batchnorm as `[gamma, beta, running_mean, running_var]` rows, head
//! GEMM), per-channel mask layers (one coordinate per feature-map channel —
//! the paper's channel granularity), and the block-boundary resume points
//! the staged trial path caches (§8).
//!
//! Two families:
//!
//! * [`Family::Resnet`] — post-activation ResNet-18-style: stem conv3x3 +
//!   bn + act, four stages of residual blocks (`conv3x3 → bn → act →
//!   conv3x3 → bn`, 1x1 conv + bn projection on shape change, act after
//!   the add), GAP, linear head. With 2 blocks per stage this is the
//!   paper's ResNet-18 layer count (17 masked activation layers).
//! * [`Family::Wrn`] — pre-activation WideResNet-style: bare stem conv,
//!   three groups of pre-act blocks (`bn → act → conv3x3 → bn → act →
//!   conv3x3`, 1x1 projection of the *activated* input on shape change),
//!   final bn + act, GAP, head (13 masked activation layers).
//!
//! Everything here routes through the deterministic kernels in
//! [`super::kernels`]; scoring paths run batchnorm in eval mode (running
//! stats — per-example independence is what makes staged resume and tail
//! padding safe), training steps run it in train mode with hand-written
//! backward and update the running stats after SGD.

use super::kernels::{
    add_into, bn_backward_train, bn_eval_into, bn_train_into, conv2d_same_dinput,
    conv2d_same_dweight, conv2d_same_into, conv2d_same_into_s, conv_out_dim, dact_channel,
    gap_back, gap_into, gemm_bias_into, mask_act_channel_into, BnCache,
};
use super::lowering::{with_scratch, Scratch};
use super::manifest::PackEntry;
use crate::util::prng::Rng;

/// Init-stream namespace for conv params (distinct from every other seed
/// stream in the repo; the MLP reference uses the same constant with its
/// own draw order, so param vectors still differ).
const INIT_SALT: u64 = 0x5EED_BACC_E17D_0001;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Family {
    Resnet,
    Wrn,
}

/// Shape recipe for one conv model variant.
#[derive(Clone, Debug)]
pub struct ConvSpec {
    pub key: String,
    pub family: Family,
    pub num_classes: usize,
    pub image_size: usize,
    pub channels: usize,
    pub poly: bool,
    /// Stem width; ResNet stage widths are `base * [1,2,4,8]`, WRN group
    /// widths are `base/2 * widen * [1,2,4]`.
    pub base: usize,
    /// WRN widening factor (ignored by ResNet).
    pub widen: usize,
    /// Residual blocks per stage/group.
    pub blocks: usize,
    /// Running-stat EMA rate used by the training steps.
    pub bn_momentum: f32,
}

/// How one parameter-pack entry is initialized (aligned with
/// `param_entries`; the RNG draws run in entry order, batchnorm and bias
/// constants consume no draws).
#[derive(Clone, Copy, Debug)]
enum InitKind {
    /// He-normal conv weight: `N(0, 2/fan_in)`.
    He { fan_in: usize },
    /// Batchnorm row `[gamma=1, beta=0, running_mean=0, running_var=1]`.
    Bn,
    /// Head weight: `N(0, 1/d_in)`.
    Head { d_in: usize },
    /// Zero (head bias).
    Zero,
}

/// One compiled residual block: channel/spatial geometry plus offsets into
/// the parameter pack and mask-layer indices.
#[derive(Clone, Debug)]
pub struct BlockPlan {
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    pub side_in: usize,
    pub side_out: usize,
    /// Param offsets. ResNet order: conv1, bn1, conv2, bn2[, proj, bnp].
    /// WRN order: bn1, conv1, bn2, conv2[, proj] (pre-act; proj has no bn).
    pub conv1: usize,
    pub bn1: usize,
    pub conv2: usize,
    pub bn2: usize,
    pub proj: Option<usize>,
    pub bnp: Option<usize>,
    /// Mask-layer indices of the block's two activations.
    pub act1_layer: usize,
    pub act2_layer: usize,
}

/// A compiled conv topology: geometry, pack layout, boundaries.
#[derive(Clone, Debug)]
pub struct ConvPlan {
    pub key: String,
    pub family: Family,
    pub num_classes: usize,
    pub image_size: usize,
    pub channels: usize,
    pub poly: bool,
    pub bn_momentum: f32,
    pub stem_conv: usize,
    pub stem_bn: Option<usize>,
    pub stem_c: usize,
    pub final_bn: Option<usize>,
    pub head_w: usize,
    pub head_b: usize,
    /// Channels entering global average pooling.
    pub feat_c: usize,
    /// Spatial side at the pooling stage.
    pub feat_side: usize,
    pub blocks: Vec<BlockPlan>,
    pub param_size: usize,
    pub mask_size: usize,
    pub mask_layers: Vec<PackEntry>,
    pub param_entries: Vec<PackEntry>,
    init_kinds: Vec<InitKind>,
    /// `boundary_layers[b]` = deepest mask layer consumed by the cached
    /// activation of resume boundary `b` (strictly increasing). A
    /// hypothesis whose first dirty layer is `l` may resume from any
    /// boundary with `boundary_layers[b] < l`.
    pub boundary_layers: Vec<usize>,
    /// Blocks already folded into boundary `b`'s cached activation
    /// (resume runs `blocks[boundary_blocks[b]..]`).
    pub boundary_blocks: Vec<usize>,
    /// Floats per example in boundary `b`'s cached activation.
    pub boundary_entry: Vec<usize>,
}

struct PackBuilder {
    entries: Vec<PackEntry>,
    kinds: Vec<InitKind>,
    off: usize,
}

impl PackBuilder {
    fn new() -> Self {
        PackBuilder { entries: Vec::new(), kinds: Vec::new(), off: 0 }
    }

    fn push(&mut self, name: String, shape: Vec<usize>, kind: InitKind) -> usize {
        let size: usize = shape.iter().product();
        let off = self.off;
        self.entries.push(PackEntry { name, shape, offset: off, size });
        self.kinds.push(kind);
        self.off += size;
        off
    }
}

fn bn4(params: &[f32], off: usize, c: usize) -> (&[f32], &[f32], &[f32], &[f32]) {
    (
        &params[off..off + c],
        &params[off + c..off + 2 * c],
        &params[off + 2 * c..off + 3 * c],
        &params[off + 3 * c..off + 4 * c],
    )
}

fn layer_slice<'a>(mask: &'a [f32], e: &PackEntry) -> &'a [f32] {
    &mask[e.offset..e.offset + e.size]
}

impl ConvPlan {
    pub fn build(spec: &ConvSpec) -> ConvPlan {
        match spec.family {
            Family::Resnet => {
                assert!(spec.image_size % 8 == 0, "resnet downsamples 8x");
            }
            Family::Wrn => {
                assert!(spec.image_size % 4 == 0, "wrn downsamples 4x");
                assert!(spec.base % 2 == 0, "wrn widths are base/2 * widen * mult");
            }
        }
        let mut pb = PackBuilder::new();
        let mut mask_layers: Vec<PackEntry> = Vec::new();
        let mut moff = 0usize;
        let mut push_mask = |name: String, c: usize, moff: &mut usize| {
            mask_layers.push(PackEntry { name, shape: vec![c], offset: *moff, size: c });
            *moff += c;
        };

        let stem_c = spec.base;
        let stem_conv = pb.push(
            "stem.conv.w".into(),
            vec![stem_c, spec.channels, 3, 3],
            InitKind::He { fan_in: spec.channels * 9 },
        );
        let stem_bn = match spec.family {
            Family::Resnet => {
                let off = pb.push("stem.bn".into(), vec![4, stem_c], InitKind::Bn);
                push_mask("stem.act".into(), stem_c, &mut moff);
                Some(off)
            }
            Family::Wrn => None,
        };

        let (tag, mults): (&str, &[usize]) = match spec.family {
            Family::Resnet => ("s", &[1, 2, 4, 8]),
            Family::Wrn => ("g", &[1, 2, 4]),
        };
        let mut blocks = Vec::new();
        let mut boundary_layers = Vec::new();
        let mut boundary_blocks = Vec::new();
        let mut boundary_entry = Vec::new();
        if spec.family == Family::Resnet {
            // Boundary 0: the stem activation (mask layer 0).
            boundary_layers.push(0);
            boundary_blocks.push(0);
            boundary_entry.push(stem_c * spec.image_size * spec.image_size);
        }
        let mut cin = stem_c;
        let mut side = spec.image_size;
        let mut layer = usize::from(spec.family == Family::Resnet);
        for (si, &mult) in mults.iter().enumerate() {
            let cout = match spec.family {
                Family::Resnet => spec.base * mult,
                Family::Wrn => spec.base / 2 * spec.widen * mult,
            };
            for bi in 0..spec.blocks {
                let stride = if si > 0 && bi == 0 { 2 } else { 1 };
                let side_in = side;
                let side_out = conv_out_dim(side_in, stride);
                let needs_proj = stride != 1 || cin != cout;
                let n = |part: &str| format!("{tag}{si}.b{bi}.{part}");
                let (conv1, bn1, conv2, bn2, proj, bnp) = match spec.family {
                    Family::Resnet => {
                        let conv1 = pb.push(n("conv1.w"), vec![cout, cin, 3, 3], InitKind::He { fan_in: cin * 9 });
                        let bn1 = pb.push(n("bn1"), vec![4, cout], InitKind::Bn);
                        let conv2 = pb.push(n("conv2.w"), vec![cout, cout, 3, 3], InitKind::He { fan_in: cout * 9 });
                        let bn2 = pb.push(n("bn2"), vec![4, cout], InitKind::Bn);
                        let (proj, bnp) = if needs_proj {
                            (
                                Some(pb.push(n("proj.w"), vec![cout, cin, 1, 1], InitKind::He { fan_in: cin })),
                                Some(pb.push(n("bnp"), vec![4, cout], InitKind::Bn)),
                            )
                        } else {
                            (None, None)
                        };
                        (conv1, bn1, conv2, bn2, proj, bnp)
                    }
                    Family::Wrn => {
                        let bn1 = pb.push(n("bn1"), vec![4, cin], InitKind::Bn);
                        let conv1 = pb.push(n("conv1.w"), vec![cout, cin, 3, 3], InitKind::He { fan_in: cin * 9 });
                        let bn2 = pb.push(n("bn2"), vec![4, cout], InitKind::Bn);
                        let conv2 = pb.push(n("conv2.w"), vec![cout, cout, 3, 3], InitKind::He { fan_in: cout * 9 });
                        let proj = if needs_proj {
                            Some(pb.push(n("proj.w"), vec![cout, cin, 1, 1], InitKind::He { fan_in: cin }))
                        } else {
                            None
                        };
                        (conv1, bn1, conv2, bn2, proj, None)
                    }
                };
                let act1_c = match spec.family {
                    Family::Resnet => cout,
                    Family::Wrn => cin,
                };
                let act1_layer = layer;
                push_mask(n("act1"), act1_c, &mut moff);
                let act2_layer = layer + 1;
                push_mask(n("act2"), cout, &mut moff);
                layer += 2;
                blocks.push(BlockPlan {
                    cin,
                    cout,
                    stride,
                    side_in,
                    side_out,
                    conv1,
                    bn1,
                    conv2,
                    bn2,
                    proj,
                    bnp,
                    act1_layer,
                    act2_layer,
                });
                cin = cout;
                side = side_out;
            }
        }
        let feat_c = cin;
        let feat_side = side;
        // Block-output boundaries. ResNet: the block output *is* its act2
        // layer, so the last block's output consumes the final mask layer
        // and can never be resumed past — skip it. WRN: the final bn+act
        // layer comes after the last block, so every block output is a
        // usable boundary.
        let usable = match spec.family {
            Family::Resnet => blocks.len().saturating_sub(1),
            Family::Wrn => blocks.len(),
        };
        for (i, bp) in blocks.iter().take(usable).enumerate() {
            boundary_layers.push(bp.act2_layer);
            boundary_blocks.push(i + 1);
            boundary_entry.push(bp.cout * bp.side_out * bp.side_out);
        }
        let final_bn = match spec.family {
            Family::Wrn => {
                let off = pb.push("final.bn".into(), vec![4, feat_c], InitKind::Bn);
                push_mask("final.act".into(), feat_c, &mut moff);
                Some(off)
            }
            Family::Resnet => None,
        };
        let head_w = pb.push(
            "head.w".into(),
            vec![feat_c, spec.num_classes],
            InitKind::Head { d_in: feat_c },
        );
        let head_b = pb.push("head.b".into(), vec![spec.num_classes], InitKind::Zero);

        ConvPlan {
            key: spec.key.clone(),
            family: spec.family,
            num_classes: spec.num_classes,
            image_size: spec.image_size,
            channels: spec.channels,
            poly: spec.poly,
            bn_momentum: spec.bn_momentum,
            stem_conv,
            stem_bn,
            stem_c,
            final_bn,
            head_w,
            head_b,
            feat_c,
            feat_side,
            blocks,
            param_size: pb.off,
            mask_size: moff,
            mask_layers,
            param_entries: pb.entries,
            init_kinds: pb.kinds,
            boundary_layers,
            boundary_blocks,
            boundary_entry,
        }
    }

    /// Number of staged resume boundaries (`Backend::segments`).
    pub fn segment_count(&self) -> usize {
        self.boundary_layers.len()
    }

    /// Deterministic parameter init: He-normal conv weights and a
    /// `N(0, 1/d_in)` head drawn sequentially in pack order from a seed
    /// stream salted with [`INIT_SALT`]; batchnorm rows and the head bias
    /// are constants and consume no draws.
    pub fn init_params(&self, seed: i32) -> Vec<f32> {
        let mut rng = Rng::new((seed as u32 as u64) ^ INIT_SALT);
        let mut p = Vec::with_capacity(self.param_size);
        for (e, kind) in self.param_entries.iter().zip(&self.init_kinds) {
            match *kind {
                InitKind::He { fan_in } => {
                    let std = (2.0 / fan_in as f32).sqrt();
                    for _ in 0..e.size {
                        p.push(rng.normal() * std);
                    }
                }
                InitKind::Bn => {
                    let c = e.size / 4;
                    p.extend(std::iter::repeat(1.0).take(c)); // gamma
                    p.extend(std::iter::repeat(0.0).take(c)); // beta
                    p.extend(std::iter::repeat(0.0).take(c)); // running mean
                    p.extend(std::iter::repeat(1.0).take(c)); // running var
                }
                InitKind::Head { d_in } => {
                    let std = (1.0 / d_in as f32).sqrt();
                    for _ in 0..e.size {
                        p.push(rng.normal() * std);
                    }
                }
                InitKind::Zero => p.extend(std::iter::repeat(0.0).take(e.size)),
            }
        }
        debug_assert_eq!(p.len(), self.param_size);
        p
    }

    // -- Eval-mode forward (every scoring path) -----------------------------
    //
    // Batchnorm uses running stats, so each example's output is independent
    // of batch composition, and `forward_eval` / `forward_prefix` +
    // `forward_from` call the exact same block functions in the same order —
    // staged resume is bit-identical to the full forward by construction.
    //
    // Every path is scratch-threaded (`_s` suffix, DESIGN.md §13): all
    // intermediates come from the [`Scratch`] arena and go back as soon as
    // the next op has consumed them, so a trial scan stops allocating after
    // the first forward. Each block additionally splits into a
    // mask-independent prologue ([`Self::block_pre_s`] -> [`BlockShared`])
    // and a mask-dependent remainder ([`Self::block_post_s`]); the slab
    // paths in `reference.rs` compute the prologue once per `trial_batch`
    // hypotheses. `block_eval_s` is defined as pre + post, so the shared
    // and unshared routes are the same float program by construction.

    /// Mask-independent stem: conv (+ bn for post-act families). The
    /// result depends only on params and input, so one call feeds every
    /// hypothesis of a full-forward slab.
    pub fn stem_pre_s(&self, params: &[f32], x: &[f32], n: usize, s: &mut Scratch) -> Vec<f32> {
        let side = self.image_size;
        let hw = side * side;
        let w = &params[self.stem_conv..self.stem_conv + self.stem_c * self.channels * 9];
        let mut c0 = s.take();
        conv2d_same_into_s(x, w, n, self.channels, side, side, self.stem_c, 3, 1, &mut c0, s);
        match self.stem_bn {
            Some(off) => {
                let (g, b, rm, rv) = bn4(params, off, self.stem_c);
                let mut z = s.take();
                bn_eval_into(&c0, g, b, rm, rv, n, self.stem_c, hw, &mut z);
                s.put(c0);
                z
            }
            None => c0,
        }
    }

    /// Mask-independent block prologue: everything up to (and excluding)
    /// the first mask application. ResNet: conv1 + bn1 and, when present,
    /// the projection branch. WRN (pre-act): bn1 only — the projection
    /// consumes the *activated* input and stays in the postlude.
    fn block_pre_s(&self, bp: &BlockPlan, params: &[f32], x: &[f32], n: usize, s: &mut Scratch) -> BlockShared {
        let (hw_in, hw_out) = (bp.side_in * bp.side_in, bp.side_out * bp.side_out);
        match self.family {
            Family::Resnet => {
                let w1 = &params[bp.conv1..bp.conv1 + bp.cout * bp.cin * 9];
                let mut c1 = s.take();
                conv2d_same_into_s(x, w1, n, bp.cin, bp.side_in, bp.side_in, bp.cout, 3, bp.stride, &mut c1, s);
                let (g1, be1, rm1, rv1) = bn4(params, bp.bn1, bp.cout);
                let mut z1 = s.take();
                bn_eval_into(&c1, g1, be1, rm1, rv1, n, bp.cout, hw_out, &mut z1);
                let skip = match (bp.proj, bp.bnp) {
                    (Some(pw), Some(pb)) => {
                        let wp = &params[pw..pw + bp.cout * bp.cin];
                        conv2d_same_into_s(x, wp, n, bp.cin, bp.side_in, bp.side_in, bp.cout, 1, bp.stride, &mut c1, s);
                        let (gp, bep, rmp, rvp) = bn4(params, pb, bp.cout);
                        let mut zp = s.take();
                        bn_eval_into(&c1, gp, bep, rmp, rvp, n, bp.cout, hw_out, &mut zp);
                        Some(zp)
                    }
                    _ => None,
                };
                s.put(c1);
                BlockShared { z1, skip }
            }
            Family::Wrn => {
                let (g1, be1, rm1, rv1) = bn4(params, bp.bn1, bp.cin);
                let mut z1 = s.take();
                bn_eval_into(x, g1, be1, rm1, rv1, n, bp.cin, hw_in, &mut z1);
                BlockShared { z1, skip: None }
            }
        }
    }

    /// Mask-dependent block remainder, from a [`BlockShared`] prologue and
    /// the block input `x` (needed by identity skips and WRN projections).
    fn block_post_s(
        &self,
        bp: &BlockPlan,
        params: &[f32],
        mask: &[f32],
        x: &[f32],
        shared: &BlockShared,
        n: usize,
        s: &mut Scratch,
    ) -> Vec<f32> {
        let (hw_in, hw_out) = (bp.side_in * bp.side_in, bp.side_out * bp.side_out);
        let w2 = &params[bp.conv2..bp.conv2 + bp.cout * bp.cout * 9];
        let m1 = layer_slice(mask, &self.mask_layers[bp.act1_layer]);
        let m2 = layer_slice(mask, &self.mask_layers[bp.act2_layer]);
        match self.family {
            Family::Resnet => {
                let mut a1 = s.take();
                mask_act_channel_into(&shared.z1, m1, n, bp.cout, hw_out, self.poly, &mut a1);
                let mut c2 = s.take();
                conv2d_same_into_s(&a1, w2, n, bp.cout, bp.side_out, bp.side_out, bp.cout, 3, 1, &mut c2, s);
                s.put(a1);
                let (g2, be2, rm2, rv2) = bn4(params, bp.bn2, bp.cout);
                let mut sum = s.take();
                bn_eval_into(&c2, g2, be2, rm2, rv2, n, bp.cout, hw_out, &mut sum);
                s.put(c2);
                match &shared.skip {
                    Some(zp) => add_into(&mut sum, zp),
                    None => add_into(&mut sum, x),
                }
                let mut out = s.take();
                mask_act_channel_into(&sum, m2, n, bp.cout, hw_out, self.poly, &mut out);
                s.put(sum);
                out
            }
            Family::Wrn => {
                let w1 = &params[bp.conv1..bp.conv1 + bp.cout * bp.cin * 9];
                let mut y = s.take();
                mask_act_channel_into(&shared.z1, m1, n, bp.cin, hw_in, self.poly, &mut y);
                let mut c1 = s.take();
                conv2d_same_into_s(&y, w1, n, bp.cin, bp.side_in, bp.side_in, bp.cout, 3, bp.stride, &mut c1, s);
                let (g2, be2, rm2, rv2) = bn4(params, bp.bn2, bp.cout);
                let mut z2 = s.take();
                bn_eval_into(&c1, g2, be2, rm2, rv2, n, bp.cout, hw_out, &mut z2);
                let mut h2 = s.take();
                mask_act_channel_into(&z2, m2, n, bp.cout, hw_out, self.poly, &mut h2);
                s.put(z2);
                let mut out = s.take();
                conv2d_same_into_s(&h2, w2, n, bp.cout, bp.side_out, bp.side_out, bp.cout, 3, 1, &mut out, s);
                s.put(h2);
                match bp.proj {
                    Some(pw) => {
                        let wp = &params[pw..pw + bp.cout * bp.cin];
                        // The projection reads the activated input; reuse
                        // c1's capacity for it.
                        conv2d_same_into_s(&y, wp, n, bp.cin, bp.side_in, bp.side_in, bp.cout, 1, bp.stride, &mut c1, s);
                        add_into(&mut out, &c1);
                    }
                    None => add_into(&mut out, x),
                }
                s.put(c1);
                s.put(y);
                out
            }
        }
    }

    /// One full block under one mask: prologue + remainder.
    fn block_eval_s(&self, bp: &BlockPlan, params: &[f32], mask: &[f32], x: &[f32], n: usize, s: &mut Scratch) -> Vec<f32> {
        let shared = self.block_pre_s(bp, params, x, n, s);
        let out = self.block_post_s(bp, params, mask, x, &shared, n, s);
        shared.release(s);
        out
    }

    /// Final bn/act (WRN), GAP, linear head -> logits `[n, k]`.
    fn head_eval_s(&self, params: &[f32], mask: &[f32], x: &[f32], n: usize, s: &mut Scratch) -> Vec<f32> {
        let hw = self.feat_side * self.feat_side;
        let mut feats = s.take();
        match self.final_bn {
            Some(off) => {
                let (g, b, rm, rv) = bn4(params, off, self.feat_c);
                let mut z = s.take();
                bn_eval_into(x, g, b, rm, rv, n, self.feat_c, hw, &mut z);
                let ml = layer_slice(mask, self.mask_layers.last().expect("wrn has layers"));
                let mut a = s.take();
                mask_act_channel_into(&z, ml, n, self.feat_c, hw, self.poly, &mut a);
                s.put(z);
                gap_into(&a, n, self.feat_c, hw, &mut feats);
                s.put(a);
            }
            None => gap_into(x, n, self.feat_c, hw, &mut feats),
        }
        let wh = &params[self.head_w..self.head_w + self.feat_c * self.num_classes];
        let bh = &params[self.head_b..self.head_b + self.num_classes];
        let mut logits = s.take();
        gemm_bias_into(&feats, wh, bh, n, self.feat_c, self.num_classes, &mut logits);
        s.put(feats);
        logits
    }

    /// Stem mask/act (post-act families) then `blocks[..upto]`, off an
    /// already-computed [`Self::stem_pre_s`] tensor.
    fn run_blocks_from_stem_s(&self, upto: usize, params: &[f32], mask: &[f32], stem_pre: &[f32], n: usize, s: &mut Scratch) -> Vec<f32> {
        let (mut cur, start) = match self.stem_bn {
            Some(_) => {
                let hw = self.image_size * self.image_size;
                let m0 = layer_slice(mask, &self.mask_layers[0]);
                let mut a = s.take();
                mask_act_channel_into(stem_pre, m0, n, self.stem_c, hw, self.poly, &mut a);
                (a, 0)
            }
            None => {
                // A bare stem has no mask layer, so block 0 reads the
                // (possibly slab-shared) stem tensor in place. Bare-stem
                // families never place a boundary before block 1.
                debug_assert!(upto >= 1);
                (self.block_eval_s(&self.blocks[0], params, mask, stem_pre, n, s), 1)
            }
        };
        for bp in &self.blocks[start..upto] {
            let next = self.block_eval_s(bp, params, mask, &cur, n, s);
            s.put(std::mem::replace(&mut cur, next));
        }
        cur
    }

    /// Full eval-mode forward -> logits `[n, k]`.
    pub fn forward_eval(&self, params: &[f32], mask: &[f32], x: &[f32], n: usize) -> Vec<f32> {
        with_scratch(|s| self.forward_eval_s(params, mask, x, n, s))
    }

    /// [`Self::forward_eval`] with an explicit scratch arena.
    pub fn forward_eval_s(&self, params: &[f32], mask: &[f32], x: &[f32], n: usize, s: &mut Scratch) -> Vec<f32> {
        let pre = self.stem_pre_s(params, x, n, s);
        let logits = self.forward_eval_with_stem_s(&pre, params, mask, n, s);
        s.put(pre);
        logits
    }

    /// Full forward off a shared [`Self::stem_pre_s`] tensor — the
    /// full-slab fast path: one stem conv (and one im2col of the input
    /// images) feeds the whole hypothesis batch.
    pub fn forward_eval_with_stem_s(&self, stem_pre: &[f32], params: &[f32], mask: &[f32], n: usize, s: &mut Scratch) -> Vec<f32> {
        let cur = self.run_blocks_from_stem_s(self.blocks.len(), params, mask, stem_pre, n, s);
        let logits = self.head_eval_s(params, mask, &cur, n, s);
        s.put(cur);
        logits
    }

    /// Boundary-`segment` activations of the eval-mode forward (the tensor
    /// the staged trial path caches).
    pub fn forward_prefix(&self, segment: usize, params: &[f32], mask: &[f32], x: &[f32], n: usize) -> Vec<f32> {
        with_scratch(|s| self.forward_prefix_s(segment, params, mask, x, n, s))
    }

    /// [`Self::forward_prefix`] with an explicit scratch arena.
    pub fn forward_prefix_s(&self, segment: usize, params: &[f32], mask: &[f32], x: &[f32], n: usize, s: &mut Scratch) -> Vec<f32> {
        let pre = self.stem_pre_s(params, x, n, s);
        let cur = self.run_blocks_from_stem_s(self.boundary_blocks[segment], params, mask, &pre, n, s);
        s.put(pre);
        cur
    }

    /// Mask offset where boundary `segment`'s suffix starts (the first
    /// layer NOT folded into the cached activation).
    pub fn suffix_offset(&self, segment: usize) -> usize {
        self.mask_layers[self.boundary_layers[segment] + 1].offset
    }

    /// Mask-independent prologue of the first block after boundary
    /// `segment`, shared across a resume slab's hypotheses. `None` when
    /// every block is already folded into the boundary (WRN's last
    /// boundary) and resume is head-only.
    pub fn resume_shared_s(&self, segment: usize, acts: &[f32], params: &[f32], n: usize, s: &mut Scratch) -> Option<BlockShared> {
        let bi = self.boundary_blocks[segment];
        self.blocks.get(bi).map(|bp| self.block_pre_s(bp, params, acts, n, s))
    }

    /// Resume from boundary `segment`: `mask_suffix` covers mask layers
    /// after the boundary; the prefix positions of the reconstructed
    /// full-size mask are zero-filled and never read, so this is
    /// bit-identical to [`Self::forward_eval`] under the same full mask.
    pub fn forward_from(&self, segment: usize, acts: &[f32], params: &[f32], mask_suffix: &[f32], n: usize) -> Vec<f32> {
        with_scratch(|s| self.forward_from_s(segment, acts, params, mask_suffix, n, s))
    }

    /// [`Self::forward_from`] with an explicit scratch arena. Defined as
    /// prologue + [`Self::forward_from_with_shared_s`], so the slab-shared
    /// route is the same float program as the single-trial one.
    pub fn forward_from_s(&self, segment: usize, acts: &[f32], params: &[f32], mask_suffix: &[f32], n: usize, s: &mut Scratch) -> Vec<f32> {
        let shared = self.resume_shared_s(segment, acts, params, n, s);
        let logits = self.forward_from_with_shared_s(segment, acts, shared.as_ref(), params, mask_suffix, n, s);
        if let Some(sh) = shared {
            sh.release(s);
        }
        logits
    }

    /// Resume off a shared first-block prologue — the resume-slab fast
    /// path: the prologue (and the im2col of the cached boundary
    /// activation inside it) is computed once per slab.
    pub fn forward_from_with_shared_s(
        &self,
        segment: usize,
        acts: &[f32],
        shared: Option<&BlockShared>,
        params: &[f32],
        mask_suffix: &[f32],
        n: usize,
        s: &mut Scratch,
    ) -> Vec<f32> {
        let off = self.suffix_offset(segment);
        let mut full = s.take();
        full.resize(self.mask_size, 0.0);
        full[off..].copy_from_slice(mask_suffix);
        let bi = self.boundary_blocks[segment];
        let logits = match shared {
            Some(sh) => {
                let mut cur = self.block_post_s(&self.blocks[bi], params, &full, acts, sh, n, s);
                for bp in &self.blocks[bi + 1..] {
                    let next = self.block_eval_s(bp, params, &full, &cur, n, s);
                    s.put(std::mem::replace(&mut cur, next));
                }
                let logits = self.head_eval_s(params, &full, &cur, n, s);
                s.put(cur);
                logits
            }
            None => {
                debug_assert_eq!(bi, self.blocks.len());
                self.head_eval_s(params, &full, acts, n, s)
            }
        };
        s.put(full);
        logits
    }

    // -- Train-mode forward/backward (train_step / snl_step / kd_step) ------

    fn bn1_c(&self, bp: &BlockPlan) -> usize {
        match self.family {
            Family::Resnet => bp.cout,
            Family::Wrn => bp.cin,
        }
    }

    fn block_train(&self, bp: &BlockPlan, params: &[f32], mask: &[f32], x_in: Vec<f32>, n: usize) -> (Vec<f32>, BlockTape) {
        let (hw_in, hw_out) = (bp.side_in * bp.side_in, bp.side_out * bp.side_out);
        let w1 = &params[bp.conv1..bp.conv1 + bp.cout * bp.cin * 9];
        let w2 = &params[bp.conv2..bp.conv2 + bp.cout * bp.cout * 9];
        let m1 = layer_slice(mask, &self.mask_layers[bp.act1_layer]);
        let m2 = layer_slice(mask, &self.mask_layers[bp.act2_layer]);
        match self.family {
            Family::Resnet => {
                let mut c1 = Vec::new();
                conv2d_same_into(&x_in, w1, n, bp.cin, bp.side_in, bp.side_in, bp.cout, 3, bp.stride, &mut c1);
                let mut z1 = Vec::new();
                let bn1 = bn_train_into(&c1, &params[bp.bn1..bp.bn1 + bp.cout], &params[bp.bn1 + bp.cout..bp.bn1 + 2 * bp.cout], n, bp.cout, hw_out, &mut z1);
                let mut a1 = Vec::new();
                mask_act_channel_into(&z1, m1, n, bp.cout, hw_out, self.poly, &mut a1);
                let mut c2 = Vec::new();
                conv2d_same_into(&a1, w2, n, bp.cout, bp.side_out, bp.side_out, bp.cout, 3, 1, &mut c2);
                let mut z2 = Vec::new();
                let bn2 = bn_train_into(&c2, &params[bp.bn2..bp.bn2 + bp.cout], &params[bp.bn2 + bp.cout..bp.bn2 + 2 * bp.cout], n, bp.cout, hw_out, &mut z2);
                let (skip, bnp) = match (bp.proj, bp.bnp) {
                    (Some(pw), Some(pb)) => {
                        let wp = &params[pw..pw + bp.cout * bp.cin];
                        let mut cp = Vec::new();
                        conv2d_same_into(&x_in, wp, n, bp.cin, bp.side_in, bp.side_in, bp.cout, 1, bp.stride, &mut cp);
                        let mut zp = Vec::new();
                        let cache = bn_train_into(&cp, &params[pb..pb + bp.cout], &params[pb + bp.cout..pb + 2 * bp.cout], n, bp.cout, hw_out, &mut zp);
                        (zp, Some(cache))
                    }
                    _ => (x_in.clone(), None),
                };
                add_into(&mut z2, &skip);
                let mut out = Vec::new();
                mask_act_channel_into(&z2, m2, n, bp.cout, hw_out, self.poly, &mut out);
                (out, BlockTape { x_in, bn1, z1, a1, bn2, z2, a2: Vec::new(), bnp })
            }
            Family::Wrn => {
                let mut z1 = Vec::new();
                let bn1 = bn_train_into(&x_in, &params[bp.bn1..bp.bn1 + bp.cin], &params[bp.bn1 + bp.cin..bp.bn1 + 2 * bp.cin], n, bp.cin, hw_in, &mut z1);
                let mut y = Vec::new();
                mask_act_channel_into(&z1, m1, n, bp.cin, hw_in, self.poly, &mut y);
                let id = match bp.proj {
                    Some(pw) => {
                        let wp = &params[pw..pw + bp.cout * bp.cin];
                        let mut cp = Vec::new();
                        conv2d_same_into(&y, wp, n, bp.cin, bp.side_in, bp.side_in, bp.cout, 1, bp.stride, &mut cp);
                        cp
                    }
                    None => x_in.clone(),
                };
                let mut c1 = Vec::new();
                conv2d_same_into(&y, w1, n, bp.cin, bp.side_in, bp.side_in, bp.cout, 3, bp.stride, &mut c1);
                let mut z2 = Vec::new();
                let bn2 = bn_train_into(&c1, &params[bp.bn2..bp.bn2 + bp.cout], &params[bp.bn2 + bp.cout..bp.bn2 + 2 * bp.cout], n, bp.cout, hw_out, &mut z2);
                let mut h2 = Vec::new();
                mask_act_channel_into(&z2, m2, n, bp.cout, hw_out, self.poly, &mut h2);
                let mut out = Vec::new();
                conv2d_same_into(&h2, w2, n, bp.cout, bp.side_out, bp.side_out, bp.cout, 3, 1, &mut out);
                add_into(&mut out, &id);
                (out, BlockTape { x_in, bn1, z1, a1: y, bn2, z2, a2: h2, bnp: None })
            }
        }
    }

    /// Train-mode forward (batch-stat batchnorm) -> (logits, tape).
    pub fn forward_train(&self, params: &[f32], mask: &[f32], x: &[f32], n: usize) -> (Vec<f32>, TrainTape) {
        let s = self.image_size;
        let hw = s * s;
        let mut c0 = Vec::new();
        let w = &params[self.stem_conv..self.stem_conv + self.stem_c * self.channels * 9];
        conv2d_same_into(x, w, n, self.channels, s, s, self.stem_c, 3, 1, &mut c0);
        let (stem_bn, stem_z, stem_out) = match self.stem_bn {
            Some(off) => {
                let mut z = Vec::new();
                let cache = bn_train_into(&c0, &params[off..off + self.stem_c], &params[off + self.stem_c..off + 2 * self.stem_c], n, self.stem_c, hw, &mut z);
                let m0 = layer_slice(mask, &self.mask_layers[0]);
                let mut a = Vec::new();
                mask_act_channel_into(&z, m0, n, self.stem_c, hw, self.poly, &mut a);
                (Some(cache), Some(z), a)
            }
            None => (None, None, c0),
        };
        let mut blocks = Vec::with_capacity(self.blocks.len());
        let mut cur = stem_out;
        for bp in &self.blocks {
            let (out, tape) = self.block_train(bp, params, mask, cur, n);
            blocks.push(tape);
            cur = out;
        }
        let fhw = self.feat_side * self.feat_side;
        let (final_bn, final_z, gap_in) = match self.final_bn {
            Some(off) => {
                let mut z = Vec::new();
                let cache = bn_train_into(&cur, &params[off..off + self.feat_c], &params[off + self.feat_c..off + 2 * self.feat_c], n, self.feat_c, fhw, &mut z);
                let ml = layer_slice(mask, self.mask_layers.last().expect("wrn has layers"));
                let mut a = Vec::new();
                mask_act_channel_into(&z, ml, n, self.feat_c, fhw, self.poly, &mut a);
                (Some(cache), Some(z), a)
            }
            None => (None, None, cur),
        };
        let mut feats = Vec::new();
        gap_into(&gap_in, n, self.feat_c, fhw, &mut feats);
        let wh = &params[self.head_w..self.head_w + self.feat_c * self.num_classes];
        let bh = &params[self.head_b..self.head_b + self.num_classes];
        let mut logits = Vec::new();
        gemm_bias_into(&feats, wh, bh, n, self.feat_c, self.num_classes, &mut logits);
        (logits, TrainTape { x: x.to_vec(), stem_bn, stem_z, blocks, final_bn, final_z, feats })
    }

    fn block_backward(
        &self,
        bp: &BlockPlan,
        t: &BlockTape,
        params: &[f32],
        mask: &[f32],
        dparams: &mut [f32],
        dmask: &mut [f32],
        dout: &[f32],
        n: usize,
    ) -> Vec<f32> {
        let (hw_in, hw_out) = (bp.side_in * bp.side_in, bp.side_out * bp.side_out);
        let w1 = &params[bp.conv1..bp.conv1 + bp.cout * bp.cin * 9];
        let w2 = &params[bp.conv2..bp.conv2 + bp.cout * bp.cout * 9];
        let m1 = layer_slice(mask, &self.mask_layers[bp.act1_layer]);
        let m2 = layer_slice(mask, &self.mask_layers[bp.act2_layer]);
        let l1 = &self.mask_layers[bp.act1_layer];
        let l2 = &self.mask_layers[bp.act2_layer];
        match self.family {
            Family::Resnet => {
                let (dm2, dsum) = dact_channel(&t.z2, m2, dout, n, bp.cout, hw_out, self.poly);
                dmask[l2.offset..l2.offset + l2.size].copy_from_slice(&dm2);
                let dc2 = {
                    let (dg2, dbe2) = dparams[bp.bn2..bp.bn2 + 2 * bp.cout].split_at_mut(bp.cout);
                    bn_backward_train(&t.bn2, &params[bp.bn2..bp.bn2 + bp.cout], &dsum, dg2, dbe2, n, bp.cout, hw_out)
                };
                conv2d_same_dweight(&t.a1, &dc2, &mut dparams[bp.conv2..bp.conv2 + bp.cout * bp.cout * 9], n, bp.cout, bp.side_out, bp.side_out, bp.cout, 3, 1);
                let da1 = conv2d_same_dinput(&dc2, w2, n, bp.cout, bp.side_out, bp.side_out, bp.cout, 3, 1);
                let (dm1, dz1) = dact_channel(&t.z1, m1, &da1, n, bp.cout, hw_out, self.poly);
                dmask[l1.offset..l1.offset + l1.size].copy_from_slice(&dm1);
                let dc1 = {
                    let (dg1, dbe1) = dparams[bp.bn1..bp.bn1 + 2 * bp.cout].split_at_mut(bp.cout);
                    bn_backward_train(&t.bn1, &params[bp.bn1..bp.bn1 + bp.cout], &dz1, dg1, dbe1, n, bp.cout, hw_out)
                };
                conv2d_same_dweight(&t.x_in, &dc1, &mut dparams[bp.conv1..bp.conv1 + bp.cout * bp.cin * 9], n, bp.cin, bp.side_in, bp.side_in, bp.cout, 3, bp.stride);
                let mut dx = conv2d_same_dinput(&dc1, w1, n, bp.cin, bp.side_in, bp.side_in, bp.cout, 3, bp.stride);
                match (bp.proj, bp.bnp, &t.bnp) {
                    (Some(pw), Some(pb), Some(cache)) => {
                        let wp = &params[pw..pw + bp.cout * bp.cin];
                        let dcp = {
                            let (dgp, dbep) = dparams[pb..pb + 2 * bp.cout].split_at_mut(bp.cout);
                            bn_backward_train(cache, &params[pb..pb + bp.cout], &dsum, dgp, dbep, n, bp.cout, hw_out)
                        };
                        conv2d_same_dweight(&t.x_in, &dcp, &mut dparams[pw..pw + bp.cout * bp.cin], n, bp.cin, bp.side_in, bp.side_in, bp.cout, 1, bp.stride);
                        add_into(&mut dx, &conv2d_same_dinput(&dcp, wp, n, bp.cin, bp.side_in, bp.side_in, bp.cout, 1, bp.stride));
                    }
                    _ => add_into(&mut dx, &dsum),
                }
                dx
            }
            Family::Wrn => {
                conv2d_same_dweight(&t.a2, dout, &mut dparams[bp.conv2..bp.conv2 + bp.cout * bp.cout * 9], n, bp.cout, bp.side_out, bp.side_out, bp.cout, 3, 1);
                let dh2 = conv2d_same_dinput(dout, w2, n, bp.cout, bp.side_out, bp.side_out, bp.cout, 3, 1);
                let (dm2, dz2) = dact_channel(&t.z2, m2, &dh2, n, bp.cout, hw_out, self.poly);
                dmask[l2.offset..l2.offset + l2.size].copy_from_slice(&dm2);
                let dc1 = {
                    let (dg2, dbe2) = dparams[bp.bn2..bp.bn2 + 2 * bp.cout].split_at_mut(bp.cout);
                    bn_backward_train(&t.bn2, &params[bp.bn2..bp.bn2 + bp.cout], &dz2, dg2, dbe2, n, bp.cout, hw_out)
                };
                conv2d_same_dweight(&t.a1, &dc1, &mut dparams[bp.conv1..bp.conv1 + bp.cout * bp.cin * 9], n, bp.cin, bp.side_in, bp.side_in, bp.cout, 3, bp.stride);
                let mut dy = conv2d_same_dinput(&dc1, w1, n, bp.cin, bp.side_in, bp.side_in, bp.cout, 3, bp.stride);
                if let Some(pw) = bp.proj {
                    let wp = &params[pw..pw + bp.cout * bp.cin];
                    conv2d_same_dweight(&t.a1, dout, &mut dparams[pw..pw + bp.cout * bp.cin], n, bp.cin, bp.side_in, bp.side_in, bp.cout, 1, bp.stride);
                    add_into(&mut dy, &conv2d_same_dinput(dout, wp, n, bp.cin, bp.side_in, bp.side_in, bp.cout, 1, bp.stride));
                }
                let (dm1, dz1) = dact_channel(&t.z1, m1, &dy, n, bp.cin, hw_in, self.poly);
                dmask[l1.offset..l1.offset + l1.size].copy_from_slice(&dm1);
                let mut dx = {
                    let (dg1, dbe1) = dparams[bp.bn1..bp.bn1 + 2 * bp.cin].split_at_mut(bp.cin);
                    bn_backward_train(&t.bn1, &params[bp.bn1..bp.bn1 + bp.cin], &dz1, dg1, dbe1, n, bp.cin, hw_in)
                };
                if bp.proj.is_none() {
                    add_into(&mut dx, dout);
                }
                dx
            }
        }
    }

    /// Backprop `dlogits` through the taped train-mode forward ->
    /// `(dparams, dmask)`. Running-stat pack positions receive zero grad
    /// (they are not trained; [`Self::update_running_stats`] overwrites
    /// them after the SGD step).
    pub fn backward(&self, params: &[f32], mask: &[f32], tape: &TrainTape, dlogits: &[f32], n: usize) -> (Vec<f32>, Vec<f32>) {
        let mut dparams = vec![0.0f32; self.param_size];
        let mut dmask = vec![0.0f32; self.mask_size];
        let k = self.num_classes;
        let fhw = self.feat_side * self.feat_side;
        {
            let (dwh, dbh) = dparams[self.head_w..self.head_b + k].split_at_mut(self.feat_c * k);
            super::kernels::matgrad(&tape.feats, dlogits, dwh, dbh, n, self.feat_c, k);
        }
        let wh = &params[self.head_w..self.head_w + self.feat_c * k];
        let dfeats = super::kernels::dinput(dlogits, wh, n, self.feat_c, k);
        let mut dcur = gap_back(&dfeats, n, self.feat_c, fhw);
        if let (Some(off), Some(cache), Some(z)) = (self.final_bn, &tape.final_bn, &tape.final_z) {
            let ml = self.mask_layers.last().expect("wrn has layers");
            let (dmf, dzf) = dact_channel(z, layer_slice(mask, ml), &dcur, n, self.feat_c, fhw, self.poly);
            dmask[ml.offset..ml.offset + ml.size].copy_from_slice(&dmf);
            let (dg, dbe) = dparams[off..off + 2 * self.feat_c].split_at_mut(self.feat_c);
            dcur = bn_backward_train(cache, &params[off..off + self.feat_c], &dzf, dg, dbe, n, self.feat_c, fhw);
        }
        for (bp, t) in self.blocks.iter().zip(&tape.blocks).rev() {
            dcur = self.block_backward(bp, t, params, mask, &mut dparams, &mut dmask, &dcur, n);
        }
        let s = self.image_size;
        let hw = s * s;
        let dc0 = match (self.stem_bn, &tape.stem_bn, &tape.stem_z) {
            (Some(off), Some(cache), Some(z)) => {
                let l0 = &self.mask_layers[0];
                let (dm0, dz0) = dact_channel(z, layer_slice(mask, l0), &dcur, n, self.stem_c, hw, self.poly);
                dmask[l0.offset..l0.offset + l0.size].copy_from_slice(&dm0);
                let (dg, dbe) = dparams[off..off + 2 * self.stem_c].split_at_mut(self.stem_c);
                bn_backward_train(cache, &params[off..off + self.stem_c], &dz0, dg, dbe, n, self.stem_c, hw)
            }
            _ => dcur,
        };
        conv2d_same_dweight(&tape.x, &dc0, &mut dparams[self.stem_conv..self.stem_conv + self.stem_c * self.channels * 9], n, self.channels, s, s, self.stem_c, 3, 1);
        (dparams, dmask)
    }

    /// EMA-update every batchnorm's running mean/var in the pack from the
    /// batch statistics captured on `tape` (after the SGD step, mirroring
    /// the Python training loop's ordering).
    pub fn update_running_stats(&self, params: &mut [f32], tape: &TrainTape) {
        fn upd(params: &mut [f32], off: usize, c: usize, cache: &BnCache, mom: f32) {
            for ci in 0..c {
                let rm = off + 2 * c + ci;
                params[rm] = (1.0 - mom) * params[rm] + mom * cache.mean[ci];
                let rv = off + 3 * c + ci;
                params[rv] = (1.0 - mom) * params[rv] + mom * cache.var[ci];
            }
        }
        let mom = self.bn_momentum;
        if let (Some(off), Some(cache)) = (self.stem_bn, &tape.stem_bn) {
            upd(params, off, self.stem_c, cache, mom);
        }
        for (bp, t) in self.blocks.iter().zip(&tape.blocks) {
            upd(params, bp.bn1, self.bn1_c(bp), &t.bn1, mom);
            upd(params, bp.bn2, bp.cout, &t.bn2, mom);
            if let (Some(pb), Some(cache)) = (bp.bnp, &t.bnp) {
                upd(params, pb, bp.cout, cache, mom);
            }
        }
        if let (Some(off), Some(cache)) = (self.final_bn, &tape.final_bn) {
            upd(params, off, self.feat_c, cache, mom);
        }
    }
}

/// Mask-independent prologue of one block ([`ConvPlan::block_pre_s`]),
/// computed once per trial slab and shared across its hypotheses.
pub struct BlockShared {
    /// Pre-act1 tensor: bn1 output (ResNet) / pre-act bn output (WRN).
    z1: Vec<f32>,
    /// ResNet projection branch (proj conv + bn). `None` means the
    /// identity skip: the block input itself is added.
    skip: Option<Vec<f32>>,
}

impl BlockShared {
    /// Return the prologue's buffers to the arena.
    pub fn release(self, s: &mut Scratch) {
        s.put(self.z1);
        if let Some(v) = self.skip {
            s.put(v);
        }
    }
}

/// Per-block intermediates of one train-mode forward.
pub struct BlockTape {
    /// Block input (conv1 / projection dweight).
    x_in: Vec<f32>,
    bn1: BnCache,
    /// Pre-act1 (bn1 output).
    z1: Vec<f32>,
    /// Post-act1 (ResNet: conv2 input after conv1; WRN: `y`, the input of
    /// conv1 *and* the projection).
    a1: Vec<f32>,
    bn2: BnCache,
    /// Pre-act2 (ResNet: bn2 output *plus skip*; WRN: bn2 output).
    z2: Vec<f32>,
    /// WRN only: post-act2 (conv2 input). Empty for ResNet.
    a2: Vec<f32>,
    /// ResNet projection batchnorm cache.
    bnp: Option<BnCache>,
}

/// All intermediates [`ConvPlan::backward`] needs, captured by
/// [`ConvPlan::forward_train`].
pub struct TrainTape {
    x: Vec<f32>,
    stem_bn: Option<BnCache>,
    stem_z: Option<Vec<f32>>,
    blocks: Vec<BlockTape>,
    final_bn: Option<BnCache>,
    final_z: Option<Vec<f32>>,
    feats: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::kernels::softmax_ce;

    fn spec(family: Family, k: usize, img: usize, poly: bool) -> ConvSpec {
        ConvSpec {
            key: "t".into(),
            family,
            num_classes: k,
            image_size: img,
            channels: 3,
            poly,
            base: 8,
            widen: 4,
            blocks: 2,
            bn_momentum: 0.1,
        }
    }

    fn assert_tiles(entries: &[PackEntry], total: usize) {
        let mut off = 0;
        for e in entries {
            assert_eq!(e.offset, off, "{} not contiguous", e.name);
            assert_eq!(e.shape.iter().product::<usize>(), e.size, "{} shape/size", e.name);
            off += e.size;
        }
        assert_eq!(off, total);
    }

    fn rand_vec(rng: &mut Rng, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| rng.range_f32(lo, hi)).collect()
    }

    #[test]
    fn resnet_plan_matches_hand_counted_layout() {
        let p10 = ConvPlan::build(&spec(Family::Resnet, 10, 16, false));
        let p20 = ConvPlan::build(&spec(Family::Resnet, 20, 16, false));
        // Hand count: stem 216+32; s0 2x1216; s1 3776+4736; s2 14720+18688;
        // s3 58112+74240; head 64k+k.
        assert_eq!(p10.param_size, 177_602);
        assert_eq!(p20.param_size, 178_252);
        // Per-channel masks: 8 + 4*8 + 4*16 + 4*32 + 4*64.
        assert_eq!(p10.mask_size, 488);
        assert_eq!(p10.mask_layers.len(), 17);
        assert_eq!(p10.boundary_layers, vec![0, 2, 4, 6, 8, 10, 12, 14]);
        assert_eq!(p10.boundary_blocks, vec![0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(
            p10.boundary_entry,
            vec![2048, 2048, 2048, 1024, 1024, 512, 512, 256]
        );
        assert_eq!(p10.feat_c, 64);
        assert_eq!(p10.feat_side, 2);
        assert_eq!(p10.blocks.len(), 8);
        assert_tiles(&p10.mask_layers, p10.mask_size);
        assert_tiles(&p10.param_entries, p10.param_size);
        // 32px variant only stretches spatial dims, never the pack.
        let p32 = ConvPlan::build(&spec(Family::Resnet, 20, 32, false));
        assert_eq!(p32.param_size, 178_252);
        assert_eq!(p32.mask_size, 488);
        assert_eq!(p32.feat_side, 4);
    }

    #[test]
    fn wrn_plan_matches_hand_counted_layout() {
        let p10 = ConvPlan::build(&spec(Family::Wrn, 10, 16, false));
        let p20 = ConvPlan::build(&spec(Family::Wrn, 20, 16, false));
        assert_eq!(p10.param_size, 174_722);
        assert_eq!(p20.param_size, 175_372);
        assert_eq!(p10.mask_size, 456);
        assert_eq!(p10.mask_layers.len(), 13);
        assert_eq!(p10.boundary_layers, vec![1, 3, 5, 7, 9, 11]);
        assert_eq!(p10.boundary_blocks, vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(p10.feat_c, 64);
        assert_eq!(p10.feat_side, 4);
        assert_eq!(p10.blocks.len(), 6);
        // Pre-act: act1 of the first block sits on the stem width, act2 on
        // the group width.
        assert_eq!(p10.mask_layers[0].size, 8);
        assert_eq!(p10.mask_layers[1].size, 16);
        assert_tiles(&p10.mask_layers, p10.mask_size);
        assert_tiles(&p10.param_entries, p10.param_size);
    }

    #[test]
    fn init_is_deterministic_seed_sensitive_and_bn_exact() {
        for fam in [Family::Resnet, Family::Wrn] {
            let plan = ConvPlan::build(&spec(fam, 10, 16, false));
            let a = plan.init_params(7);
            let b = plan.init_params(7);
            let c = plan.init_params(8);
            assert_eq!(a, b);
            assert_ne!(a, c);
            assert_eq!(a.len(), plan.param_size);
            // Every batchnorm row is exactly [1, 0, 0, 1] per channel and
            // the head bias is zero.
            for e in &plan.param_entries {
                if e.shape.len() == 2 && e.shape[0] == 4 {
                    let ch = e.shape[1];
                    assert!(a[e.offset..e.offset + ch].iter().all(|&v| v == 1.0));
                    assert!(a[e.offset + ch..e.offset + 3 * ch].iter().all(|&v| v == 0.0));
                    assert!(a[e.offset + 3 * ch..e.offset + 4 * ch].iter().all(|&v| v == 1.0));
                }
            }
            let hb = plan.head_b;
            assert!(a[hb..hb + plan.num_classes].iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn eval_forward_has_right_shape_and_per_channel_mask_sensitivity() {
        for (fam, poly) in [(Family::Resnet, false), (Family::Wrn, true)] {
            let plan = ConvPlan::build(&spec(fam, 10, 16, poly));
            let params = plan.init_params(3);
            let mut rng = Rng::new(11);
            let n = 2;
            let x = rand_vec(&mut rng, n * 3 * 16 * 16, -2.0, 2.0);
            let full = vec![1.0f32; plan.mask_size];
            let logits = plan.forward_eval(&params, &full, &x, n);
            assert_eq!(logits.len(), n * 10);
            assert!(logits.iter().all(|v| v.is_finite()));
            // Zeroing a mid-network mask layer changes logits: per-channel
            // masks are actually consumed layer by layer.
            let mid = &plan.mask_layers[plan.mask_layers.len() / 2];
            let mut flipped = full.clone();
            flipped[mid.offset..mid.offset + mid.size].fill(0.0);
            assert_ne!(logits, plan.forward_eval(&params, &flipped, &x, n));
        }
    }

    #[test]
    fn staged_resume_is_bitwise_identical_at_every_boundary() {
        for fam in [Family::Resnet, Family::Wrn] {
            let plan = ConvPlan::build(&spec(fam, 10, 16, false));
            let params = plan.init_params(5);
            let mut rng = Rng::new(23);
            let n = 2;
            let x = rand_vec(&mut rng, n * 3 * 16 * 16, -2.0, 2.0);
            let mask = rand_vec(&mut rng, plan.mask_size, 0.0, 1.0);
            let full = plan.forward_eval(&params, &mask, &x, n);
            for seg in 0..plan.segment_count() {
                let acts = plan.forward_prefix(seg, &params, &mask, &x, n);
                assert_eq!(acts.len(), n * plan.boundary_entry[seg]);
                let suffix = &mask[plan.suffix_offset(seg)..];
                let resumed = plan.forward_from(seg, &acts, &params, suffix, n);
                assert_eq!(full, resumed, "{fam:?} segment {seg} diverged");
            }
        }
    }

    #[test]
    fn slab_shared_paths_are_bitwise_identical_to_single_trial() {
        use crate::runtime::lowering::Scratch;
        for fam in [Family::Resnet, Family::Wrn] {
            let plan = ConvPlan::build(&spec(fam, 10, 16, false));
            let params = plan.init_params(13);
            let mut rng = Rng::new(41);
            let n = 2;
            let x = rand_vec(&mut rng, n * 3 * 16 * 16, -2.0, 2.0);
            let masks: Vec<Vec<f32>> =
                (0..3).map(|_| rand_vec(&mut rng, plan.mask_size, 0.0, 1.0)).collect();
            // Full-forward slab: one stem_pre feeds every hypothesis.
            let mut s = Scratch::new();
            let pre = plan.stem_pre_s(&params, &x, n, &mut s);
            for m in &masks {
                let shared = plan.forward_eval_with_stem_s(&pre, &params, m, n, &mut s);
                assert_eq!(shared, plan.forward_eval(&params, m, &x, n), "{fam:?} full slab");
            }
            s.put(pre);
            // Resume slab: one first-block prologue feeds every hypothesis,
            // at every boundary (incl. WRN's head-only last boundary).
            for seg in 0..plan.segment_count() {
                let acts = plan.forward_prefix(seg, &params, &masks[0], &x, n);
                let off = plan.suffix_offset(seg);
                let resume = plan.resume_shared_s(seg, &acts, &params, n, &mut s);
                for m in &masks {
                    let got = plan.forward_from_with_shared_s(
                        seg, &acts, resume.as_ref(), &params, &m[off..], n, &mut s,
                    );
                    let want = plan.forward_from(seg, &acts, &params, &m[off..], n);
                    assert_eq!(got, want, "{fam:?} segment {seg} resume slab");
                }
                if let Some(sh) = resume {
                    sh.release(&mut s);
                }
            }
        }
    }

    #[test]
    fn train_backward_and_running_stat_update_fit_the_pack() {
        for fam in [Family::Resnet, Family::Wrn] {
            let plan = ConvPlan::build(&spec(fam, 10, 16, false));
            let mut params = plan.init_params(9);
            let mut rng = Rng::new(31);
            let n = 4;
            let x = rand_vec(&mut rng, n * 3 * 16 * 16, -2.0, 2.0);
            let mask = vec![1.0f32; plan.mask_size];
            let y: Vec<i32> = (0..n as i32).collect();
            let (logits, tape) = plan.forward_train(&params, &mask, &x, n);
            let (loss, _, dlogits) = softmax_ce(&logits, &y, 10);
            assert!(loss.is_finite());
            let (dparams, dmask) = plan.backward(&params, &mask, &tape, &dlogits, n);
            assert_eq!(dparams.len(), plan.param_size);
            assert_eq!(dmask.len(), plan.mask_size);
            // Running-stat positions carry zero grad; gamma/beta and at
            // least one conv weight carry signal.
            for e in &plan.param_entries {
                if e.shape.len() == 2 && e.shape[0] == 4 {
                    let ch = e.shape[1];
                    assert!(dparams[e.offset + 2 * ch..e.offset + 4 * ch].iter().all(|&v| v == 0.0), "{}", e.name);
                }
            }
            assert!(dparams[plan.stem_conv..plan.stem_conv + 10].iter().any(|&v| v != 0.0));
            assert!(dmask.iter().any(|&v| v != 0.0));
            // Running stats move off init after the EMA update.
            let before = params.clone();
            plan.update_running_stats(&mut params, &tape);
            let bn = plan
                .param_entries
                .iter()
                .find(|e| e.shape.len() == 2 && e.shape[0] == 4)
                .unwrap();
            let ch = bn.shape[1];
            assert_ne!(
                &params[bn.offset + 2 * ch..bn.offset + 4 * ch],
                &before[bn.offset + 2 * ch..bn.offset + 4 * ch]
            );
            // ...and only running stats moved.
            assert_eq!(&params[bn.offset..bn.offset + 2 * ch], &before[bn.offset..bn.offset + 2 * ch]);
        }
    }
}
