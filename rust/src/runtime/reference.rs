//! Pure-Rust reference backend: masked-activation networks with
//! hand-written forward/backward passes, implementing the full artifact
//! entry-point contract (`init`, `forward`, `eval_batch`, `train_step`,
//! `snl_step`, `kd_step`) without HLO artifacts, XLA, or any native
//! dependency.
//!
//! Two model families are served (DESIGN.md §12):
//!
//! - `mlp_*` / `mlpw_*` — two-hidden-layer MLP stand-ins, cheap enough for
//!   every CI tier. The deprecated `resnet_*` / `wrn_*` keys they were
//!   originally registered under still resolve to them as aliases.
//! - `resnet18_*` / `wrn22_*` — the paper's conv/residual topologies
//!   (post-act ResNet and pre-act WideResNet), compiled to flat-pack
//!   layouts by [`crate::runtime::convnet`] with per-channel mask layers
//!   and residual-block resume boundaries.
//!
//! Purpose (DESIGN note): coordinator logic — BCD, the baselines, the
//! parallel trial scan — is backbone-agnostic; it only needs *some*
//! differentiable network whose accuracy degrades as ReLUs are masked off.
//! This backend provides that, so integration tests and CI exercise
//! `run_bcd` end-to-end on machines with neither artifacts nor a PJRT
//! toolchain. Numerics intentionally do NOT match the HLO models: it is a
//! reference implementation of the *interface*, not of the backbone.
//!
//! Semantics of the mask, shared with the compiled models: for a hidden
//! unit with pre-activation `z` and mask value `m`,
//! `a = m * relu(z) + (1 - m) * g(z)` where `g` is the identity (paper
//! setting) or the AutoReP-style quadratic `0.25 z^2 + 0.5 z` for `_poly`
//! variants. `m = 1` keeps the ReLU, `m = 0` linearizes it.
//!
//! All dense math lives in [`crate::runtime::kernels`]; this module only
//! wires layouts and entry points. The batched multi-hypothesis paths
//! (`*_multi`, DESIGN.md §11) share each mask-independent affine across
//! the hypothesis axis — the masks act at the activations, so `z1` (full
//! route) and `z2` (staged route) are computed once per slab — then run
//! the per-hypothesis steps through the very same kernel functions the
//! single-trial path uses, making per-hypothesis results bit-identical to
//! single-hypothesis calls by construction. Conv slabs share the
//! analogous mask-independent prologues — the stem (full route) and the
//! first resumed block (staged route), each containing an im2col the
//! whole slab reuses — through the scratch-arena paths of DESIGN.md §13.

use crate::config::ModelConfig;
use crate::runtime::backend::{Backend, CallStats, DeviceBuf, HostArg, MaskSlab, StatsRecorder};
use crate::runtime::convnet::{ConvPlan, ConvSpec, Family};
use crate::runtime::kernels;
use crate::runtime::lowering::{self, with_scratch};
use crate::runtime::manifest::{Manifest, ModelInfo, PackEntry};
use crate::tensor::Tensor;
use crate::util::prng::Rng;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Specification of one reference-backend model variant.
#[derive(Clone, Debug)]
pub struct RefSpec {
    pub key: String,
    pub backbone: String,
    pub num_classes: usize,
    pub image_size: usize,
    pub channels: usize,
    pub poly: bool,
    /// Hidden-layer widths; each must be a multiple of 4 (the layer is
    /// exposed to channel-granularity sampling as `[width/4, 2, 2]`).
    pub hidden: (usize, usize),
}

/// Flat-pack layout of the MLP parameter vector.
#[derive(Clone, Copy, Debug)]
struct Layout {
    d_in: usize,
    h1: usize,
    h2: usize,
    k: usize,
}

impl Layout {
    fn param_size(&self) -> usize {
        self.d_in * self.h1 + self.h1 + self.h1 * self.h2 + self.h2 + self.h2 * self.k + self.k
    }

    fn mask_size(&self) -> usize {
        self.h1 + self.h2
    }

    /// Split a parameter vector into [w1, b1, w2, b2, w3, b3].
    fn split<'a>(&self, p: &'a [f32]) -> [&'a [f32]; 6] {
        let (w1, rest) = p.split_at(self.d_in * self.h1);
        let (b1, rest) = rest.split_at(self.h1);
        let (w2, rest) = rest.split_at(self.h1 * self.h2);
        let (b2, rest) = rest.split_at(self.h2);
        let (w3, b3) = rest.split_at(self.h2 * self.k);
        [w1, b1, w2, b2, w3, b3]
    }

    fn split_mut<'a>(&self, p: &'a mut [f32]) -> [&'a mut [f32]; 6] {
        let (w1, rest) = p.split_at_mut(self.d_in * self.h1);
        let (b1, rest) = rest.split_at_mut(self.h1);
        let (w2, rest) = rest.split_at_mut(self.h1 * self.h2);
        let (b2, rest) = rest.split_at_mut(self.h2);
        let (w3, b3) = rest.split_at_mut(self.h2 * self.k);
        [w1, b1, w2, b2, w3, b3]
    }
}

struct RefModel {
    layout: Layout,
    poly: bool,
}

/// A registered model: an MLP stand-in or a compiled conv/residual plan.
enum ModelImpl {
    Mlp(RefModel),
    Conv(ConvPlan),
}

/// Device-buffer payload of the reference backend (host-resident copies —
/// the "device" is the CPU, but the caching contract is identical to PJRT:
/// upload once, reuse across calls).
enum RefBuf {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A borrowed argument after host/device unification.
#[derive(Clone, Copy)]
enum ArgView<'a> {
    F32(&'a [f32]),
    I32(&'a [i32]),
}

/// The pure-Rust execution backend.
pub struct RefBackend {
    manifest: Manifest,
    models: BTreeMap<String, ModelImpl>,
    stats: StatsRecorder,
}

const MOMENTUM: f32 = 0.9;

/// Hypothesis-slab width limit of the batched `*_multi` paths. Wide enough
/// that one slab covers a whole BCD trial round (`rt` is typically ≤ 64),
/// small enough that the per-hypothesis scratch stays cache-resident.
const MULTI_WIDTH: usize = 64;

impl RefBackend {
    /// Build a backend serving `specs` at a fixed batch size.
    pub fn new(specs: &[RefSpec], batch: usize) -> RefBackend {
        let mut models = BTreeMap::new();
        let mut infos = BTreeMap::new();
        for spec in specs {
            assert!(
                spec.hidden.0 % 4 == 0 && spec.hidden.1 % 4 == 0,
                "hidden widths must be multiples of 4 for channel granularity"
            );
            let layout = Layout {
                d_in: spec.channels * spec.image_size * spec.image_size,
                h1: spec.hidden.0,
                h2: spec.hidden.1,
                k: spec.num_classes,
            };
            let mask_layers = vec![
                PackEntry {
                    name: "fc1".into(),
                    shape: vec![layout.h1 / 4, 2, 2],
                    offset: 0,
                    size: layout.h1,
                },
                PackEntry {
                    name: "fc2".into(),
                    shape: vec![layout.h2 / 4, 2, 2],
                    offset: layout.h1,
                    size: layout.h2,
                },
            ];
            let mut off = 0usize;
            let mut param_entries = Vec::new();
            for (name, n) in [
                ("w1", layout.d_in * layout.h1),
                ("b1", layout.h1),
                ("w2", layout.h1 * layout.h2),
                ("b2", layout.h2),
                ("w3", layout.h2 * layout.k),
                ("b3", layout.k),
            ] {
                param_entries.push(PackEntry {
                    name: name.into(),
                    shape: vec![n],
                    offset: off,
                    size: n,
                });
                off += n;
            }
            let info = ModelInfo {
                key: spec.key.clone(),
                backbone: spec.backbone.clone(),
                num_classes: spec.num_classes,
                image_size: spec.image_size,
                channels: spec.channels,
                poly: spec.poly,
                param_size: layout.param_size(),
                mask_size: layout.mask_size(),
                mask_layers,
                param_entries,
                artifacts: BTreeMap::new(),
            };
            infos.insert(spec.key.clone(), info);
            models.insert(
                spec.key.clone(),
                ModelImpl::Mlp(RefModel { layout, poly: spec.poly }),
            );
        }
        RefBackend {
            manifest: Manifest {
                batch,
                kernel_impl: "reference".into(),
                models: infos,
                dir: PathBuf::from("<builtin>"),
            },
            models,
            stats: StatsRecorder::new(),
        }
    }

    /// The standard model table at the default [`ModelConfig`] sizing.
    pub fn standard() -> RefBackend {
        RefBackend::standard_with(&ModelConfig::default())
    }

    /// The standard model table, mirroring the artifact manifest's key
    /// naming (`Experiment::model_key`) so pipelines, benches and the CLI
    /// run unchanged on this backend: MLP stand-ins under `mlp_*`/`mlpw_*`
    /// (the deprecated `resnet_*`/`wrn_*` keys still resolve as aliases)
    /// plus the paper's conv topologies `resnet18_*`/`wrn22_*` sized by
    /// `cfg` (DESIGN.md §12).
    pub fn standard_with(cfg: &ModelConfig) -> RefBackend {
        let mut specs = Vec::new();
        for backbone in ["mlp", "mlpw"] {
            let hidden = if backbone == "mlp" { (256, 128) } else { (320, 160) };
            for (size, classes) in [(16usize, 10usize), (16, 20), (32, 20)] {
                for poly in [false, true] {
                    let suffix = if poly { "_poly" } else { "" };
                    specs.push(RefSpec {
                        key: format!("{backbone}_{size}x{size}_c{classes}{suffix}"),
                        backbone: backbone.into(),
                        num_classes: classes,
                        image_size: size,
                        channels: 3,
                        poly,
                        hidden,
                    });
                }
            }
        }
        let mut be = RefBackend::new(&specs, 16);
        for (backbone, family) in [("resnet18", Family::Resnet), ("wrn22", Family::Wrn)] {
            for (size, classes) in [(16usize, 10usize), (16, 20), (32, 20)] {
                for poly in [false, true] {
                    let suffix = if poly { "_poly" } else { "" };
                    be.add_conv(&ConvSpec {
                        key: format!("{backbone}_{size}x{size}_c{classes}{suffix}"),
                        family,
                        num_classes: classes,
                        image_size: size,
                        channels: 3,
                        poly,
                        base: cfg.conv_base,
                        widen: cfg.conv_widen,
                        blocks: cfg.conv_blocks,
                        bn_momentum: cfg.bn_momentum,
                    });
                }
            }
        }
        be
    }

    /// Register one conv/residual model: compile the plan and publish its
    /// flat-pack layout through the manifest.
    pub fn add_conv(&mut self, spec: &ConvSpec) {
        let plan = ConvPlan::build(spec);
        let info = ModelInfo {
            key: spec.key.clone(),
            backbone: match spec.family {
                Family::Resnet => "resnet18".into(),
                Family::Wrn => "wrn22".into(),
            },
            num_classes: spec.num_classes,
            image_size: spec.image_size,
            channels: spec.channels,
            poly: spec.poly,
            param_size: plan.param_size,
            mask_size: plan.mask_size,
            mask_layers: plan.mask_layers.clone(),
            param_entries: plan.param_entries.clone(),
            artifacts: BTreeMap::new(),
        };
        self.manifest.models.insert(spec.key.clone(), info);
        self.models.insert(spec.key.clone(), ModelImpl::Conv(plan));
    }

    /// Resolve a model key, honouring the deprecated `resnet_*`/`wrn_*`
    /// aliases of the MLP stand-ins (renamed `mlp_*`/`mlpw_*` when the
    /// real conv backbones took the `resnet18_*`/`wrn22_*` names). The
    /// returned key is canonical: it indexes both `models` and the
    /// manifest.
    fn canon<'a>(&'a self, key: &'a str) -> &'a str {
        if self.models.contains_key(key) {
            return key;
        }
        let renamed = if let Some(rest) = key.strip_prefix("resnet_") {
            format!("mlp_{rest}")
        } else if let Some(rest) = key.strip_prefix("wrn_") {
            format!("mlpw_{rest}")
        } else {
            return key;
        };
        match self.models.get_key_value(renamed.as_str()) {
            Some((canonical, _)) => canonical.as_str(),
            None => key,
        }
    }

    fn model_impl(&self, key: &str) -> Result<&ModelImpl> {
        self.models
            .get(self.canon(key))
            .ok_or_else(|| anyhow!("reference backend has no model {key:?}"))
    }

    fn execute(&self, key: &str, fn_name: &str, args: &[ArgView]) -> Result<Vec<Tensor>> {
        match self.model_impl(key)? {
            ModelImpl::Mlp(model) => self.execute_mlp(key, model, fn_name, args),
            ModelImpl::Conv(plan) => {
                let r = self.execute_conv(key, plan, fn_name, args);
                self.flush_lowering_tallies();
                r
            }
        }
    }

    /// Drain the calling thread's conv-lowering tallies (DESIGN.md §13)
    /// into the recorder under `conv_lowering:*` keys. Conv work never
    /// leaves the thread that entered the backend, so draining at the end
    /// of each conv entry point attributes every count exactly once; zero
    /// deltas are skipped so MLP-only runs record no conv keys.
    fn flush_lowering_tallies(&self) {
        let t = lowering::drain_tallies();
        for (key, n) in [
            ("conv_lowering:im2col_calls", t.im2col_calls),
            ("conv_lowering:im2col_bytes", t.im2col_bytes),
            ("conv_lowering:scratch_hits", t.scratch_hits),
            ("conv_lowering:slab_patch_reuse", t.slab_patch_reuse),
        ] {
            if n > 0 {
                self.stats.bump(key, n);
            }
        }
    }

    fn execute_mlp(
        &self,
        key: &str,
        model: &RefModel,
        fn_name: &str,
        args: &[ArgView],
    ) -> Result<Vec<Tensor>> {
        match fn_name {
            "init" => {
                check_arity(key, fn_name, args, 1)?;
                let seed = i32_scalar(args, 0, "seed")?;
                Ok(vec![vec1(init_params(&model.layout, seed))])
            }
            "forward" => {
                check_arity(key, fn_name, args, 3)?;
                let (p, m, x, bsz) = pm_x(model, args, key, fn_name)?;
                let f = forward(&model.layout, model.poly, p, m, x, bsz);
                Ok(vec![Tensor::new(vec![bsz, model.layout.k], f.logits)])
            }
            "eval_batch" => {
                check_arity(key, fn_name, args, 4)?;
                let (p, m, x, bsz) = pm_x(model, args, key, fn_name)?;
                let y = i32_arg(args, 3, "y")?;
                check_len(key, fn_name, "y", y.len(), bsz)?;
                let f = forward(&model.layout, model.poly, p, m, x, bsz);
                let (loss, correct) =
                    kernels::softmax_ce_batch(&f.logits, y, model.layout.k, None);
                Ok(vec![Tensor::scalar(loss), Tensor::scalar(correct as f32)])
            }
            "train_step" => {
                check_arity(key, fn_name, args, 6)?;
                let p = f32_arg(args, 0, "params")?;
                let mom = f32_arg(args, 1, "mom")?;
                let m = f32_arg(args, 2, "mask")?;
                let x = f32_arg(args, 3, "x")?;
                let y = i32_arg(args, 4, "y")?;
                let lr = f32_scalar(args, 5, "lr")?;
                let bsz = batch_of(model, key, fn_name, x.len())?;
                check_len(key, fn_name, "params", p.len(), model.layout.param_size())?;
                check_len(key, fn_name, "mask", m.len(), model.layout.mask_size())?;
                check_len(key, fn_name, "y", y.len(), bsz)?;
                let f = forward(&model.layout, model.poly, p, m, x, bsz);
                let (loss, correct, dlogits) = kernels::softmax_ce(&f.logits, y, model.layout.k);
                let (grad, _) = backward(&model.layout, model.poly, p, m, x, &f, &dlogits, bsz);
                let (new_p, new_mom) = kernels::sgd_momentum(p, mom, &grad, lr, MOMENTUM);
                Ok(vec![
                    vec1(new_p),
                    vec1(new_mom),
                    Tensor::scalar(loss),
                    Tensor::scalar(correct as f32),
                ])
            }
            "snl_step" => {
                check_arity(key, fn_name, args, 8)?;
                let p = f32_arg(args, 0, "params")?;
                let mom = f32_arg(args, 1, "mom")?;
                let alphas = f32_arg(args, 2, "alphas")?;
                let x = f32_arg(args, 3, "x")?;
                let y = i32_arg(args, 4, "y")?;
                let lr = f32_scalar(args, 5, "lr")?;
                let alpha_lr = f32_scalar(args, 6, "alpha_lr")?;
                let lam = f32_scalar(args, 7, "lam")?;
                let bsz = batch_of(model, key, fn_name, x.len())?;
                check_len(key, fn_name, "alphas", alphas.len(), model.layout.mask_size())?;
                check_len(key, fn_name, "y", y.len(), bsz)?;
                let f = forward(&model.layout, model.poly, p, alphas, x, bsz);
                let (ce, _, dlogits) = kernels::softmax_ce(&f.logits, y, model.layout.k);
                let (grad, dalpha) =
                    backward(&model.layout, model.poly, p, alphas, x, &f, &dlogits, bsz);
                let (new_p, new_mom) = kernels::sgd_momentum(p, mom, &grad, lr, MOMENTUM);
                // Projected SGD on alpha under CE + lam * ||alpha||_1; alphas
                // live in [0, 1] so the l1 subgradient is simply +lam.
                let new_alphas: Vec<f32> = alphas
                    .iter()
                    .zip(&dalpha)
                    .map(|(&a, &da)| (a - alpha_lr * (da + lam)).clamp(0.0, 1.0))
                    .collect();
                let l1: f32 = alphas.iter().sum();
                Ok(vec![
                    vec1(new_p),
                    vec1(new_mom),
                    vec1(new_alphas),
                    Tensor::scalar(ce + lam * l1),
                ])
            }
            "kd_step" => {
                check_arity(key, fn_name, args, 8)?;
                let p = f32_arg(args, 0, "params")?;
                let mom = f32_arg(args, 1, "mom")?;
                let m = f32_arg(args, 2, "mask")?;
                let x = f32_arg(args, 3, "x")?;
                let y = i32_arg(args, 4, "y")?;
                let t_logits = f32_arg(args, 5, "t_logits")?;
                let lr = f32_scalar(args, 6, "lr")?;
                let temp = f32_scalar(args, 7, "temp")?.max(1e-3);
                let bsz = batch_of(model, key, fn_name, x.len())?;
                let k = model.layout.k;
                check_len(key, fn_name, "mask", m.len(), model.layout.mask_size())?;
                check_len(key, fn_name, "y", y.len(), bsz)?;
                check_len(key, fn_name, "t_logits", t_logits.len(), bsz * k)?;
                let f = forward(&model.layout, model.poly, p, m, x, bsz);
                let (ce, _, mut dlogits) = kernels::softmax_ce(&f.logits, y, model.layout.k);
                let kd_loss = kd_blend(&f.logits, t_logits, &mut dlogits, bsz, k, temp);
                let loss = 0.5 * ce + 0.5 * kd_loss;
                let (grad, _) = backward(&model.layout, model.poly, p, m, x, &f, &dlogits, bsz);
                let (new_p, new_mom) = kernels::sgd_momentum(p, mom, &grad, lr, MOMENTUM);
                Ok(vec![vec1(new_p), vec1(new_mom), Tensor::scalar(loss)])
            }
            other => bail!("reference backend: model {key}: no entry point {other:?}"),
        }
    }

    /// Conv/residual entry points. Scoring, SGD, the SNL alpha update and
    /// the KD blend are the very same code the MLP path runs; only the
    /// network forward/backward differs (routed through [`ConvPlan`]).
    /// Training steps use batch statistics and then fold them into the
    /// running-stat parameters; every scoring path is eval-mode BN, so
    /// per-example independence (and with it padding-safety and the
    /// staged-execution contract) holds on conv models too.
    fn execute_conv(
        &self,
        key: &str,
        plan: &ConvPlan,
        fn_name: &str,
        args: &[ArgView],
    ) -> Result<Vec<Tensor>> {
        let k = plan.num_classes;
        match fn_name {
            "init" => {
                check_arity(key, fn_name, args, 1)?;
                let seed = i32_scalar(args, 0, "seed")?;
                Ok(vec![vec1(plan.init_params(seed))])
            }
            "forward" => {
                check_arity(key, fn_name, args, 3)?;
                let (p, m, x, bsz) = conv_pm_x(plan, args, key, fn_name)?;
                let logits = plan.forward_eval(p, m, x, bsz);
                Ok(vec![Tensor::new(vec![bsz, k], logits)])
            }
            "eval_batch" => {
                check_arity(key, fn_name, args, 4)?;
                let (p, m, x, bsz) = conv_pm_x(plan, args, key, fn_name)?;
                let y = i32_arg(args, 3, "y")?;
                check_len(key, fn_name, "y", y.len(), bsz)?;
                let logits = plan.forward_eval(p, m, x, bsz);
                let (loss, correct) = kernels::softmax_ce_batch(&logits, y, k, None);
                Ok(vec![Tensor::scalar(loss), Tensor::scalar(correct as f32)])
            }
            "train_step" => {
                check_arity(key, fn_name, args, 6)?;
                let p = f32_arg(args, 0, "params")?;
                let mom = f32_arg(args, 1, "mom")?;
                let m = f32_arg(args, 2, "mask")?;
                let x = f32_arg(args, 3, "x")?;
                let y = i32_arg(args, 4, "y")?;
                let lr = f32_scalar(args, 5, "lr")?;
                let bsz = conv_batch_of(plan, key, fn_name, x.len())?;
                check_len(key, fn_name, "params", p.len(), plan.param_size)?;
                check_len(key, fn_name, "mask", m.len(), plan.mask_size)?;
                check_len(key, fn_name, "y", y.len(), bsz)?;
                let (logits, tape) = plan.forward_train(p, m, x, bsz);
                let (loss, correct, dlogits) = kernels::softmax_ce(&logits, y, k);
                let (grad, _) = plan.backward(p, m, &tape, &dlogits, bsz);
                let (mut new_p, new_mom) = kernels::sgd_momentum(p, mom, &grad, lr, MOMENTUM);
                plan.update_running_stats(&mut new_p, &tape);
                Ok(vec![
                    vec1(new_p),
                    vec1(new_mom),
                    Tensor::scalar(loss),
                    Tensor::scalar(correct as f32),
                ])
            }
            "snl_step" => {
                check_arity(key, fn_name, args, 8)?;
                let p = f32_arg(args, 0, "params")?;
                let mom = f32_arg(args, 1, "mom")?;
                let alphas = f32_arg(args, 2, "alphas")?;
                let x = f32_arg(args, 3, "x")?;
                let y = i32_arg(args, 4, "y")?;
                let lr = f32_scalar(args, 5, "lr")?;
                let alpha_lr = f32_scalar(args, 6, "alpha_lr")?;
                let lam = f32_scalar(args, 7, "lam")?;
                let bsz = conv_batch_of(plan, key, fn_name, x.len())?;
                check_len(key, fn_name, "params", p.len(), plan.param_size)?;
                check_len(key, fn_name, "alphas", alphas.len(), plan.mask_size)?;
                check_len(key, fn_name, "y", y.len(), bsz)?;
                let (logits, tape) = plan.forward_train(p, alphas, x, bsz);
                let (ce, _, dlogits) = kernels::softmax_ce(&logits, y, k);
                let (grad, dalpha) = plan.backward(p, alphas, &tape, &dlogits, bsz);
                let (mut new_p, new_mom) = kernels::sgd_momentum(p, mom, &grad, lr, MOMENTUM);
                plan.update_running_stats(&mut new_p, &tape);
                // Same projected SGD under CE + lam * ||alpha||_1 as the
                // MLP path; alphas here gate whole channels.
                let new_alphas: Vec<f32> = alphas
                    .iter()
                    .zip(&dalpha)
                    .map(|(&a, &da)| (a - alpha_lr * (da + lam)).clamp(0.0, 1.0))
                    .collect();
                let l1: f32 = alphas.iter().sum();
                Ok(vec![
                    vec1(new_p),
                    vec1(new_mom),
                    vec1(new_alphas),
                    Tensor::scalar(ce + lam * l1),
                ])
            }
            "kd_step" => {
                check_arity(key, fn_name, args, 8)?;
                let p = f32_arg(args, 0, "params")?;
                let mom = f32_arg(args, 1, "mom")?;
                let m = f32_arg(args, 2, "mask")?;
                let x = f32_arg(args, 3, "x")?;
                let y = i32_arg(args, 4, "y")?;
                let t_logits = f32_arg(args, 5, "t_logits")?;
                let lr = f32_scalar(args, 6, "lr")?;
                let temp = f32_scalar(args, 7, "temp")?.max(1e-3);
                let bsz = conv_batch_of(plan, key, fn_name, x.len())?;
                check_len(key, fn_name, "params", p.len(), plan.param_size)?;
                check_len(key, fn_name, "mask", m.len(), plan.mask_size)?;
                check_len(key, fn_name, "y", y.len(), bsz)?;
                check_len(key, fn_name, "t_logits", t_logits.len(), bsz * k)?;
                let (logits, tape) = plan.forward_train(p, m, x, bsz);
                let (ce, _, mut dlogits) = kernels::softmax_ce(&logits, y, k);
                let kd_loss = kd_blend(&logits, t_logits, &mut dlogits, bsz, k, temp);
                let loss = 0.5 * ce + 0.5 * kd_loss;
                let (grad, _) = plan.backward(p, m, &tape, &dlogits, bsz);
                let (mut new_p, new_mom) = kernels::sgd_momentum(p, mom, &grad, lr, MOMENTUM);
                plan.update_running_stats(&mut new_p, &tape);
                Ok(vec![vec1(new_p), vec1(new_mom), Tensor::scalar(loss)])
            }
            other => bail!("reference backend: model {key}: no entry point {other:?}"),
        }
    }

    /// Validate the boundary-0 resume arguments shared by
    /// [`Backend::forward_from`] and [`Backend::eval_from`] on MLP models:
    /// returns `(params, layer-1 mask, boundary-0 activations, batch)`.
    fn staged_args<'a>(
        &self,
        model: &RefModel,
        model_key: &str,
        fn_name: &str,
        segment: usize,
        acts: &'a DeviceBuf,
        params: &'a DeviceBuf,
        mask_suffix: &'a DeviceBuf,
    ) -> Result<(&'a [f32], &'a [f32], &'a [f32], usize)> {
        if segment != 0 {
            bail!("{model_key}:{fn_name}: no segment boundary {segment} (this model has 1)");
        }
        let p = ref_f32(params, "params")?;
        let m2 = ref_f32(mask_suffix, "mask_suffix")?;
        let a1 = ref_f32(acts, "acts")?;
        check_len(model_key, fn_name, "params", p.len(), model.layout.param_size())?;
        check_len(model_key, fn_name, "mask_suffix", m2.len(), model.layout.h2)?;
        let h1 = model.layout.h1;
        if a1.is_empty() || a1.len() % h1 != 0 {
            bail!(
                "{model_key}:{fn_name}: input \"acts\" has {} elements, expects a multiple of {h1}",
                a1.len()
            );
        }
        Ok((p, m2, a1, a1.len() / h1))
    }

    /// Conv counterpart of [`RefBackend::staged_args`]: validates a resume
    /// at any of the plan's block boundaries and returns
    /// `(params, mask suffix, boundary activations, batch)`.
    fn conv_staged_args<'a>(
        &self,
        plan: &ConvPlan,
        model_key: &str,
        fn_name: &str,
        segment: usize,
        acts: &'a DeviceBuf,
        params: &'a DeviceBuf,
        mask_suffix: &'a DeviceBuf,
    ) -> Result<(&'a [f32], &'a [f32], &'a [f32], usize)> {
        let segs = plan.segment_count();
        if segment >= segs {
            bail!("{model_key}:{fn_name}: no segment boundary {segment} (this model has {segs})");
        }
        let p = ref_f32(params, "params")?;
        let m = ref_f32(mask_suffix, "mask_suffix")?;
        let a = ref_f32(acts, "acts")?;
        check_len(model_key, fn_name, "params", p.len(), plan.param_size)?;
        let want = plan.mask_size - plan.suffix_offset(segment);
        check_len(model_key, fn_name, "mask_suffix", m.len(), want)?;
        let entry = plan.boundary_entry[segment];
        if a.is_empty() || a.len() % entry != 0 {
            bail!(
                "{model_key}:{fn_name}: input \"acts\" has {} elements, expects a multiple of {entry}",
                a.len()
            );
        }
        Ok((p, m, a, a.len() / entry))
    }

    /// Validate a hypothesis slab: `n` rows of `want_width` f32s, one
    /// liveness flag per row, within this backend's width limit.
    fn slab_rows<'a>(
        &self,
        model_key: &str,
        fn_name: &str,
        slab: &'a MaskSlab,
        want_width: usize,
        live: &[bool],
    ) -> Result<&'a [f32]> {
        if slab.width != want_width {
            bail!("{model_key}:{fn_name}: mask slab width {}, expects {want_width}", slab.width);
        }
        if slab.n != live.len() {
            bail!(
                "{model_key}:{fn_name}: mask slab has {} rows but live covers {}",
                slab.n,
                live.len()
            );
        }
        if slab.n == 0 || slab.n > MULTI_WIDTH {
            bail!(
                "{model_key}:{fn_name}: slab of {} hypotheses (supported 1..={MULTI_WIDTH})",
                slab.n
            );
        }
        let rows = ref_f32(&slab.buf, "masks")?;
        check_len(model_key, fn_name, "masks", rows.len(), slab.n * slab.width)?;
        Ok(rows)
    }

    /// Validate the boundary-0 batched-resume arguments shared by
    /// [`Backend::forward_from_multi`] and [`Backend::eval_from_multi`] on
    /// MLP models: returns `(params, suffix rows, boundary-0 acts, batch)`.
    #[allow(clippy::too_many_arguments)]
    fn staged_multi_args<'a>(
        &self,
        model: &RefModel,
        model_key: &str,
        fn_name: &str,
        segment: usize,
        acts: &'a DeviceBuf,
        params: &'a DeviceBuf,
        slab: &'a MaskSlab,
        live: &[bool],
    ) -> Result<(&'a [f32], &'a [f32], &'a [f32], usize)> {
        if segment != 0 {
            bail!("{model_key}:{fn_name}: no segment boundary {segment} (this model has 1)");
        }
        let p = ref_f32(params, "params")?;
        check_len(model_key, fn_name, "params", p.len(), model.layout.param_size())?;
        let rows = self.slab_rows(model_key, fn_name, slab, model.layout.h2, live)?;
        let a1 = ref_f32(acts, "acts")?;
        let h1 = model.layout.h1;
        if a1.is_empty() || a1.len() % h1 != 0 {
            bail!(
                "{model_key}:{fn_name}: input \"acts\" has {} elements, expects a multiple of {h1}",
                a1.len()
            );
        }
        Ok((p, rows, a1, a1.len() / h1))
    }

    /// Conv counterpart of [`RefBackend::staged_multi_args`]: suffix rows
    /// all resume from the same cached block-boundary activation.
    #[allow(clippy::too_many_arguments)]
    fn conv_staged_multi_args<'a>(
        &self,
        plan: &ConvPlan,
        model_key: &str,
        fn_name: &str,
        segment: usize,
        acts: &'a DeviceBuf,
        params: &'a DeviceBuf,
        slab: &'a MaskSlab,
        live: &[bool],
    ) -> Result<(&'a [f32], &'a [f32], &'a [f32], usize)> {
        let segs = plan.segment_count();
        if segment >= segs {
            bail!("{model_key}:{fn_name}: no segment boundary {segment} (this model has {segs})");
        }
        let p = ref_f32(params, "params")?;
        check_len(model_key, fn_name, "params", p.len(), plan.param_size)?;
        let width = plan.mask_size - plan.suffix_offset(segment);
        let rows = self.slab_rows(model_key, fn_name, slab, width, live)?;
        let a = ref_f32(acts, "acts")?;
        let entry = plan.boundary_entry[segment];
        if a.is_empty() || a.len() % entry != 0 {
            bail!(
                "{model_key}:{fn_name}: input \"acts\" has {} elements, expects a multiple of {entry}",
                a.len()
            );
        }
        Ok((p, rows, a, a.len() / entry))
    }

    /// Validate the batched-full arguments shared by
    /// [`Backend::forward_multi`] and [`Backend::eval_batch_multi`] on MLP
    /// models: returns `(params, full-mask rows, x, batch)`.
    fn full_multi_args<'a>(
        &self,
        model: &RefModel,
        model_key: &str,
        fn_name: &str,
        params: &'a DeviceBuf,
        slab: &'a MaskSlab,
        x: &'a DeviceBuf,
        live: &[bool],
    ) -> Result<(&'a [f32], &'a [f32], &'a [f32], usize)> {
        let p = ref_f32(params, "params")?;
        check_len(model_key, fn_name, "params", p.len(), model.layout.param_size())?;
        let rows = self.slab_rows(model_key, fn_name, slab, model.layout.mask_size(), live)?;
        let xv = ref_f32(x, "x")?;
        let bsz = batch_of(model, model_key, fn_name, xv.len())?;
        Ok((p, rows, xv, bsz))
    }

    /// Conv counterpart of [`RefBackend::full_multi_args`].
    fn conv_full_multi_args<'a>(
        &self,
        plan: &ConvPlan,
        model_key: &str,
        fn_name: &str,
        params: &'a DeviceBuf,
        slab: &'a MaskSlab,
        x: &'a DeviceBuf,
        live: &[bool],
    ) -> Result<(&'a [f32], &'a [f32], &'a [f32], usize)> {
        let p = ref_f32(params, "params")?;
        check_len(model_key, fn_name, "params", p.len(), plan.param_size)?;
        let rows = self.slab_rows(model_key, fn_name, slab, plan.mask_size, live)?;
        let xv = ref_f32(x, "x")?;
        let bsz = conv_batch_of(plan, model_key, fn_name, xv.len())?;
        Ok((p, rows, xv, bsz))
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Alias-aware lookup: deprecated `resnet_*`/`wrn_*` keys resolve to
    /// the renamed `mlp_*`/`mlpw_*` entries; `info.key` is always the
    /// canonical name.
    fn model(&self, key: &str) -> Result<&ModelInfo> {
        self.manifest.model(self.canon(key))
    }

    fn upload_f32(&self, data: &[f32], _dims: &[usize]) -> Result<DeviceBuf> {
        Ok(DeviceBuf::new(RefBuf::F32(data.to_vec())))
    }

    fn upload_i32(&self, data: &[i32], _dims: &[usize]) -> Result<DeviceBuf> {
        Ok(DeviceBuf::new(RefBuf::I32(data.to_vec())))
    }

    fn call(&self, model_key: &str, fn_name: &str, inputs: &[HostArg]) -> Result<Vec<Tensor>> {
        let args: Vec<ArgView> = inputs
            .iter()
            .map(|a| match a {
                HostArg::F32(t) => ArgView::F32(&t.data),
                HostArg::I32(t) => ArgView::I32(&t.data),
            })
            .collect();
        self.stats
            .timed(&format!("{model_key}:{fn_name}"), || self.execute(model_key, fn_name, &args))
    }

    fn call_b(
        &self,
        model_key: &str,
        fn_name: &str,
        inputs: &[&DeviceBuf],
    ) -> Result<Vec<Tensor>> {
        let mut args = Vec::with_capacity(inputs.len());
        for b in inputs {
            args.push(match b.downcast::<RefBuf>()? {
                RefBuf::F32(v) => ArgView::F32(v.as_slice()),
                RefBuf::I32(v) => ArgView::I32(v.as_slice()),
            });
        }
        self.stats
            .timed(&format!("{model_key}:{fn_name}"), || self.execute(model_key, fn_name, &args))
    }

    /// MLP models expose one resumable boundary: `a1`, the activation of
    /// mask layer 0. (Mask layer 1 feeds the output head directly, so no
    /// hypothesis has a first dirty layer past 1 — a second boundary would
    /// never be consulted.) Conv models expose one boundary per residual
    /// block whose resume could ever be consulted (the plan drops the
    /// final block's for the same reason).
    fn segments(&self, model_key: &str) -> usize {
        match self.models.get(self.canon(model_key)) {
            Some(ModelImpl::Mlp(_)) => 1,
            Some(ModelImpl::Conv(plan)) => plan.segment_count(),
            None => 0,
        }
    }

    /// MLP boundaries coincide with mask layers (the trait default); a
    /// conv boundary folds both activations of its residual block, so the
    /// mapping comes from the plan's `boundary_layers`.
    fn segment_layer(&self, model_key: &str, segment: usize) -> usize {
        match self.models.get(self.canon(model_key)) {
            Some(ModelImpl::Conv(plan)) => {
                plan.boundary_layers.get(segment).copied().unwrap_or(segment)
            }
            _ => segment,
        }
    }

    /// Conv boundary activations are image-shaped (`N*C*H*W` floats), not
    /// mask-layer-sized, so the trait default (mask-layer size) would
    /// undercount them badly and wreck the prefix-cache budget accounting.
    fn prefix_entry_bytes(&self, model_key: &str, segment: usize, batch: usize) -> usize {
        match self.models.get(self.canon(model_key)) {
            Some(ModelImpl::Conv(plan)) => {
                plan.boundary_entry.get(segment).map_or(0, |&e| 4 * batch * e)
            }
            Some(ModelImpl::Mlp(model)) => {
                if segment == 0 {
                    4 * batch * model.layout.h1
                } else {
                    0
                }
            }
            None => 0,
        }
    }

    fn forward_prefix(
        &self,
        model_key: &str,
        segment: usize,
        params: &DeviceBuf,
        mask: &DeviceBuf,
        x: &DeviceBuf,
    ) -> Result<DeviceBuf> {
        let p = ref_f32(params, "params")?;
        let m = ref_f32(mask, "mask")?;
        let xv = ref_f32(x, "x")?;
        match self.model_impl(model_key)? {
            ModelImpl::Mlp(model) => {
                if segment != 0 {
                    bail!(
                        "{model_key}:forward_prefix: no segment boundary {segment} (this model has 1)"
                    );
                }
                check_len(model_key, "forward_prefix", "params", p.len(), model.layout.param_size())?;
                check_len(model_key, "forward_prefix", "mask", m.len(), model.layout.mask_size())?;
                let bsz = batch_of(model, model_key, "forward_prefix", xv.len())?;
                self.stats.timed(&format!("{model_key}:forward_prefix"), || {
                    let head =
                        forward_head(&model.layout, model.poly, p, &m[..model.layout.h1], xv, bsz);
                    Ok(DeviceBuf::new(RefBuf::F32(head.a1)))
                })
            }
            ModelImpl::Conv(plan) => {
                let segs = plan.segment_count();
                if segment >= segs {
                    bail!(
                        "{model_key}:forward_prefix: no segment boundary {segment} (this model has {segs})"
                    );
                }
                check_len(model_key, "forward_prefix", "params", p.len(), plan.param_size)?;
                check_len(model_key, "forward_prefix", "mask", m.len(), plan.mask_size)?;
                let bsz = conv_batch_of(plan, model_key, "forward_prefix", xv.len())?;
                let r = self.stats.timed(&format!("{model_key}:forward_prefix"), || {
                    Ok(DeviceBuf::new(RefBuf::F32(plan.forward_prefix(segment, p, m, xv, bsz))))
                });
                self.flush_lowering_tallies();
                r
            }
        }
    }

    fn forward_from(
        &self,
        model_key: &str,
        segment: usize,
        acts: &DeviceBuf,
        params: &DeviceBuf,
        mask_suffix: &DeviceBuf,
    ) -> Result<Tensor> {
        match self.model_impl(model_key)? {
            ModelImpl::Mlp(model) => {
                let (p, m2, a1, bsz) = self
                    .staged_args(model, model_key, "forward_from", segment, acts, params, mask_suffix)?;
                self.stats.timed(&format!("{model_key}:forward_from"), || {
                    let tail = forward_tail(&model.layout, model.poly, p, m2, a1, bsz);
                    Ok(Tensor::new(vec![bsz, model.layout.k], tail.logits))
                })
            }
            ModelImpl::Conv(plan) => {
                let (p, m, a, bsz) = self.conv_staged_args(
                    plan,
                    model_key,
                    "forward_from",
                    segment,
                    acts,
                    params,
                    mask_suffix,
                )?;
                let r = self.stats.timed(&format!("{model_key}:forward_from"), || {
                    let logits = plan.forward_from(segment, a, p, m, bsz);
                    Ok(Tensor::new(vec![bsz, plan.num_classes], logits))
                });
                self.flush_lowering_tallies();
                r
            }
        }
    }

    fn eval_from(
        &self,
        model_key: &str,
        segment: usize,
        acts: &DeviceBuf,
        params: &DeviceBuf,
        mask_suffix: &DeviceBuf,
        y: &DeviceBuf,
    ) -> Result<Vec<Tensor>> {
        match self.model_impl(model_key)? {
            ModelImpl::Mlp(model) => {
                let (p, m2, a1, bsz) = self
                    .staged_args(model, model_key, "eval_from", segment, acts, params, mask_suffix)?;
                let yv = ref_i32(y, "y")?;
                check_len(model_key, "eval_from", "y", yv.len(), bsz)?;
                self.stats.timed(&format!("{model_key}:eval_from"), || {
                    let tail = forward_tail(&model.layout, model.poly, p, m2, a1, bsz);
                    let (loss, correct) =
                        kernels::softmax_ce_batch(&tail.logits, yv, model.layout.k, None);
                    Ok(vec![Tensor::scalar(loss), Tensor::scalar(correct as f32)])
                })
            }
            ModelImpl::Conv(plan) => {
                let (p, m, a, bsz) = self.conv_staged_args(
                    plan,
                    model_key,
                    "eval_from",
                    segment,
                    acts,
                    params,
                    mask_suffix,
                )?;
                let yv = ref_i32(y, "y")?;
                check_len(model_key, "eval_from", "y", yv.len(), bsz)?;
                let r = self.stats.timed(&format!("{model_key}:eval_from"), || {
                    let logits = plan.forward_from(segment, a, p, m, bsz);
                    let (loss, correct) =
                        kernels::softmax_ce_batch(&logits, yv, plan.num_classes, None);
                    Ok(vec![Tensor::scalar(loss), Tensor::scalar(correct as f32)])
                });
                self.flush_lowering_tallies();
                r
            }
        }
    }

    fn multi_width(&self, model_key: &str) -> usize {
        if self.models.contains_key(self.canon(model_key)) {
            MULTI_WIDTH
        } else {
            1
        }
    }

    fn eval_batch_multi(
        &self,
        model_key: &str,
        params: &DeviceBuf,
        masks: &MaskSlab,
        x: &DeviceBuf,
        y: &DeviceBuf,
        live: &[bool],
    ) -> Result<Vec<Option<(f32, f32)>>> {
        match self.model_impl(model_key)? {
            ModelImpl::Mlp(model) => {
                let (p, rows, xv, bsz) =
                    self.full_multi_args(model, model_key, "eval_batch_multi", params, masks, x, live)?;
                let yv = ref_i32(y, "y")?;
                check_len(model_key, "eval_batch_multi", "y", yv.len(), bsz)?;
                self.stats.timed(&format!("{model_key}:eval_batch_multi"), || {
                    let logits =
                        forward_full_multi(&model.layout, model.poly, p, rows, xv, bsz, live);
                    Ok(score_multi(&logits, yv, model.layout.k))
                })
            }
            ModelImpl::Conv(plan) => {
                let (p, rows, xv, bsz) = self
                    .conv_full_multi_args(plan, model_key, "eval_batch_multi", params, masks, x, live)?;
                let yv = ref_i32(y, "y")?;
                check_len(model_key, "eval_batch_multi", "y", yv.len(), bsz)?;
                let r = self.stats.timed(&format!("{model_key}:eval_batch_multi"), || {
                    let logits = conv_full_multi(plan, p, rows, xv, bsz, live);
                    Ok(score_multi(&logits, yv, plan.num_classes))
                });
                self.flush_lowering_tallies();
                r
            }
        }
    }

    fn forward_multi(
        &self,
        model_key: &str,
        params: &DeviceBuf,
        masks: &MaskSlab,
        x: &DeviceBuf,
        live: &[bool],
    ) -> Result<Vec<Option<Tensor>>> {
        match self.model_impl(model_key)? {
            ModelImpl::Mlp(model) => {
                let (p, rows, xv, bsz) =
                    self.full_multi_args(model, model_key, "forward_multi", params, masks, x, live)?;
                self.stats.timed(&format!("{model_key}:forward_multi"), || {
                    let logits =
                        forward_full_multi(&model.layout, model.poly, p, rows, xv, bsz, live);
                    Ok(logits
                        .into_iter()
                        .map(|l| l.map(|v| Tensor::new(vec![bsz, model.layout.k], v)))
                        .collect())
                })
            }
            ModelImpl::Conv(plan) => {
                let (p, rows, xv, bsz) =
                    self.conv_full_multi_args(plan, model_key, "forward_multi", params, masks, x, live)?;
                let r = self.stats.timed(&format!("{model_key}:forward_multi"), || {
                    let logits = conv_full_multi(plan, p, rows, xv, bsz, live);
                    Ok(logits
                        .into_iter()
                        .map(|l| l.map(|v| Tensor::new(vec![bsz, plan.num_classes], v)))
                        .collect())
                });
                self.flush_lowering_tallies();
                r
            }
        }
    }

    fn forward_from_multi(
        &self,
        model_key: &str,
        segment: usize,
        acts: &DeviceBuf,
        params: &DeviceBuf,
        mask_suffixes: &MaskSlab,
        live: &[bool],
    ) -> Result<Vec<Option<Tensor>>> {
        match self.model_impl(model_key)? {
            ModelImpl::Mlp(model) => {
                let (p, rows, a1, bsz) = self.staged_multi_args(
                    model,
                    model_key,
                    "forward_from_multi",
                    segment,
                    acts,
                    params,
                    mask_suffixes,
                    live,
                )?;
                self.stats.timed(&format!("{model_key}:forward_from_multi"), || {
                    let logits =
                        forward_tail_multi(&model.layout, model.poly, p, rows, a1, bsz, live);
                    Ok(logits
                        .into_iter()
                        .map(|l| l.map(|v| Tensor::new(vec![bsz, model.layout.k], v)))
                        .collect())
                })
            }
            ModelImpl::Conv(plan) => {
                let (p, rows, a, bsz) = self.conv_staged_multi_args(
                    plan,
                    model_key,
                    "forward_from_multi",
                    segment,
                    acts,
                    params,
                    mask_suffixes,
                    live,
                )?;
                let r = self.stats.timed(&format!("{model_key}:forward_from_multi"), || {
                    let logits = conv_tail_multi(plan, segment, p, rows, a, bsz, live);
                    Ok(logits
                        .into_iter()
                        .map(|l| l.map(|v| Tensor::new(vec![bsz, plan.num_classes], v)))
                        .collect())
                });
                self.flush_lowering_tallies();
                r
            }
        }
    }

    fn eval_from_multi(
        &self,
        model_key: &str,
        segment: usize,
        acts: &DeviceBuf,
        params: &DeviceBuf,
        mask_suffixes: &MaskSlab,
        y: &DeviceBuf,
        live: &[bool],
    ) -> Result<Vec<Option<(f32, f32)>>> {
        match self.model_impl(model_key)? {
            ModelImpl::Mlp(model) => {
                let (p, rows, a1, bsz) = self.staged_multi_args(
                    model,
                    model_key,
                    "eval_from_multi",
                    segment,
                    acts,
                    params,
                    mask_suffixes,
                    live,
                )?;
                let yv = ref_i32(y, "y")?;
                check_len(model_key, "eval_from_multi", "y", yv.len(), bsz)?;
                self.stats.timed(&format!("{model_key}:eval_from_multi"), || {
                    let logits =
                        forward_tail_multi(&model.layout, model.poly, p, rows, a1, bsz, live);
                    Ok(score_multi(&logits, yv, model.layout.k))
                })
            }
            ModelImpl::Conv(plan) => {
                let (p, rows, a, bsz) = self.conv_staged_multi_args(
                    plan,
                    model_key,
                    "eval_from_multi",
                    segment,
                    acts,
                    params,
                    mask_suffixes,
                    live,
                )?;
                let yv = ref_i32(y, "y")?;
                check_len(model_key, "eval_from_multi", "y", yv.len(), bsz)?;
                let r = self.stats.timed(&format!("{model_key}:eval_from_multi"), || {
                    let logits = conv_tail_multi(plan, segment, p, rows, a, bsz, live);
                    Ok(score_multi(&logits, yv, plan.num_classes))
                });
                self.flush_lowering_tallies();
                r
            }
        }
    }

    fn bump_stat(&self, key: &str, n: u64) {
        self.stats.bump(key, n)
    }

    fn stats(&self) -> BTreeMap<String, CallStats> {
        self.stats.snapshot()
    }
}

// ---- argument plumbing ----------------------------------------------------

/// View a staged-execution device buffer as f32 (typed trait methods take
/// individual buffers, not `ArgView` lists).
fn ref_f32<'a>(buf: &'a DeviceBuf, name: &str) -> Result<&'a [f32]> {
    match buf.downcast::<RefBuf>()? {
        RefBuf::F32(v) => Ok(v.as_slice()),
        RefBuf::I32(_) => bail!("staged input {name:?}: expected f32, got i32"),
    }
}

/// View a staged-execution device buffer as i32.
fn ref_i32<'a>(buf: &'a DeviceBuf, name: &str) -> Result<&'a [i32]> {
    match buf.downcast::<RefBuf>()? {
        RefBuf::I32(v) => Ok(v.as_slice()),
        RefBuf::F32(_) => bail!("staged input {name:?}: expected i32, got f32"),
    }
}

fn check_arity(key: &str, fn_name: &str, args: &[ArgView], want: usize) -> Result<()> {
    if args.len() != want {
        bail!("{key}:{fn_name}: got {} inputs, expects {want}", args.len());
    }
    Ok(())
}

fn check_len(key: &str, fn_name: &str, name: &str, got: usize, want: usize) -> Result<()> {
    if got != want {
        bail!("{key}:{fn_name}: input {name:?} has {got} elements, expects {want}");
    }
    Ok(())
}

fn f32_arg<'a>(args: &[ArgView<'a>], i: usize, name: &str) -> Result<&'a [f32]> {
    match args[i] {
        ArgView::F32(v) => Ok(v),
        ArgView::I32(_) => bail!("input {name:?} (slot {i}): expected f32, got i32"),
    }
}

fn i32_arg<'a>(args: &[ArgView<'a>], i: usize, name: &str) -> Result<&'a [i32]> {
    match args[i] {
        ArgView::I32(v) => Ok(v),
        ArgView::F32(_) => bail!("input {name:?} (slot {i}): expected i32, got f32"),
    }
}

fn f32_scalar(args: &[ArgView], i: usize, name: &str) -> Result<f32> {
    let v = f32_arg(args, i, name)?;
    if v.len() != 1 {
        bail!("input {name:?}: expected a scalar, got {} elements", v.len());
    }
    Ok(v[0])
}

fn i32_scalar(args: &[ArgView], i: usize, name: &str) -> Result<i32> {
    let v = i32_arg(args, i, name)?;
    if v.len() != 1 {
        bail!("input {name:?}: expected a scalar, got {} elements", v.len());
    }
    Ok(v[0])
}

/// Shared (params, mask, x) prefix of forward/eval entry points.
fn pm_x<'a>(
    model: &RefModel,
    args: &[ArgView<'a>],
    key: &str,
    fn_name: &str,
) -> Result<(&'a [f32], &'a [f32], &'a [f32], usize)> {
    let p = f32_arg(args, 0, "params")?;
    let m = f32_arg(args, 1, "mask")?;
    let x = f32_arg(args, 2, "x")?;
    check_len(key, fn_name, "params", p.len(), model.layout.param_size())?;
    check_len(key, fn_name, "mask", m.len(), model.layout.mask_size())?;
    let bsz = batch_of(model, key, fn_name, x.len())?;
    Ok((p, m, x, bsz))
}

fn batch_of(model: &RefModel, key: &str, fn_name: &str, x_len: usize) -> Result<usize> {
    let d = model.layout.d_in;
    if x_len == 0 || x_len % d != 0 {
        bail!("{key}:{fn_name}: input \"x\" has {x_len} elements, expects a multiple of {d}");
    }
    Ok(x_len / d)
}

/// Shared (params, mask, x) prefix of the conv forward/eval entry points.
fn conv_pm_x<'a>(
    plan: &ConvPlan,
    args: &[ArgView<'a>],
    key: &str,
    fn_name: &str,
) -> Result<(&'a [f32], &'a [f32], &'a [f32], usize)> {
    let p = f32_arg(args, 0, "params")?;
    let m = f32_arg(args, 1, "mask")?;
    let x = f32_arg(args, 2, "x")?;
    check_len(key, fn_name, "params", p.len(), plan.param_size)?;
    check_len(key, fn_name, "mask", m.len(), plan.mask_size)?;
    let bsz = conv_batch_of(plan, key, fn_name, x.len())?;
    Ok((p, m, x, bsz))
}

fn conv_batch_of(plan: &ConvPlan, key: &str, fn_name: &str, x_len: usize) -> Result<usize> {
    let d = plan.channels * plan.image_size * plan.image_size;
    if x_len == 0 || x_len % d != 0 {
        bail!("{key}:{fn_name}: input \"x\" has {x_len} elements, expects a multiple of {d}");
    }
    Ok(x_len / d)
}

/// Blend the hard-label CE gradient with the distillation term in place:
/// loss is `0.5*CE(y) + 0.5*T^2*CE(softmax(t/T), softmax(s/T))`; the
/// returned value is the KD component (`T^2 * soft-CE` batch-averaged).
/// `d(T^2 * soft-CE)/ds = T * (softmax(s/T) - softmax(t/T))`.
fn kd_blend(
    logits: &[f32],
    t_logits: &[f32],
    dlogits: &mut [f32],
    bsz: usize,
    k: usize,
    temp: f32,
) -> f32 {
    let mut kd_loss = 0.0f32;
    for bi in 0..bsz {
        let s = &logits[bi * k..(bi + 1) * k];
        let t = &t_logits[bi * k..(bi + 1) * k];
        let ps = kernels::softmax_t(s, temp);
        let pt = kernels::softmax_t(t, temp);
        for j in 0..k {
            kd_loss -= pt[j] * ps[j].max(1e-12).ln();
            dlogits[bi * k + j] =
                0.5 * dlogits[bi * k + j] + 0.5 * temp * (ps[j] - pt[j]) / bsz as f32;
        }
    }
    temp * temp * kd_loss / bsz as f32
}

/// Conv slab forward, full route. The stem prologue ([`ConvPlan::
/// stem_pre_s`] — the stem conv, its im2col of the input images, and the
/// stem batchnorm) is mask-independent, so it is computed once and feeds
/// every live hypothesis; each hypothesis then runs
/// [`ConvPlan::forward_eval_with_stem_s`], which is the exact float
/// program of the single-hypothesis forward (DESIGN.md §13), so
/// bit-identity to single calls holds by construction. All intermediates
/// come from one scratch arena shared across the slab.
fn conv_full_multi(
    plan: &ConvPlan,
    p: &[f32],
    rows: &[f32],
    x: &[f32],
    bsz: usize,
    live: &[bool],
) -> Vec<Option<Vec<f32>>> {
    let width = plan.mask_size;
    let live_count = live.iter().filter(|&&a| a).count();
    with_scratch(|s| {
        let pre = plan.stem_pre_s(p, x, bsz, s);
        lowering::note_slab_reuse(live_count.saturating_sub(1) as u64);
        let out = live
            .iter()
            .enumerate()
            .map(|(h, &alive)| {
                alive.then(|| {
                    plan.forward_eval_with_stem_s(&pre, p, &rows[h * width..(h + 1) * width], bsz, s)
                })
            })
            .collect();
        s.put(pre);
        out
    })
}

/// Conv slab forward, staged route: every live suffix row resumes from
/// the same cached boundary activation, so the first resumed block's
/// mask-independent prologue ([`ConvPlan::resume_shared_s`] — including
/// the im2col of the boundary activation inside it) is computed once per
/// slab; each hypothesis then runs
/// [`ConvPlan::forward_from_with_shared_s`], the exact float program of
/// the single-hypothesis [`ConvPlan::forward_from`].
fn conv_tail_multi(
    plan: &ConvPlan,
    segment: usize,
    p: &[f32],
    rows: &[f32],
    acts: &[f32],
    bsz: usize,
    live: &[bool],
) -> Vec<Option<Vec<f32>>> {
    let width = plan.mask_size - plan.suffix_offset(segment);
    let live_count = live.iter().filter(|&&a| a).count();
    with_scratch(|s| {
        let shared = plan.resume_shared_s(segment, acts, p, bsz, s);
        if shared.is_some() {
            lowering::note_slab_reuse(live_count.saturating_sub(1) as u64);
        }
        let out = live
            .iter()
            .enumerate()
            .map(|(h, &alive)| {
                alive.then(|| {
                    plan.forward_from_with_shared_s(
                        segment,
                        acts,
                        shared.as_ref(),
                        p,
                        &rows[h * width..(h + 1) * width],
                        bsz,
                        s,
                    )
                })
            })
            .collect();
        if let Some(sh) = shared {
            sh.release(s);
        }
        out
    })
}

fn vec1(data: Vec<f32>) -> Tensor {
    Tensor::new(vec![data.len()], data)
}

// ---- the network ----------------------------------------------------------

/// Deterministic Xavier-uniform initialization from a seed.
fn init_params(layout: &Layout, seed: i32) -> Vec<f32> {
    let mut rng = Rng::new((seed as u32 as u64) ^ 0x5EED_BACC_E17D_0001);
    let mut p = vec![0.0f32; layout.param_size()];
    let [w1, _b1, w2, _b2, w3, _b3] = layout.split_mut(&mut p);
    for (w, fan_in, fan_out) in [
        (w1, layout.d_in, layout.h1),
        (w2, layout.h1, layout.h2),
        (w3, layout.h2, layout.k),
    ] {
        let limit = (6.0f32 / (fan_in + fan_out) as f32).sqrt();
        for v in w.iter_mut() {
            *v = rng.range_f32(-limit, limit);
        }
    }
    p
}

struct ForwardCache {
    z1: Vec<f32>,
    a1: Vec<f32>,
    z2: Vec<f32>,
    a2: Vec<f32>,
    logits: Vec<f32>,
}

/// Activations up to segment boundary 0 (`a1`, the output of mask layer 0).
struct HeadCache {
    z1: Vec<f32>,
    a1: Vec<f32>,
}

/// Everything past boundary 0: mask layer 1 plus the output head.
struct TailCache {
    z2: Vec<f32>,
    a2: Vec<f32>,
    logits: Vec<f32>,
}

/// The forward prefix: input -> boundary-0 activation. `forward` and
/// `forward_prefix` both call this, so a cached prefix is bit-identical to
/// the one a full forward would compute (the staged-execution contract,
/// DESIGN.md §8).
fn forward_head(
    layout: &Layout,
    poly: bool,
    p: &[f32],
    m1: &[f32],
    x: &[f32],
    bsz: usize,
) -> HeadCache {
    let [w1, b1, _w2, _b2, _w3, _b3] = layout.split(p);
    let z1 = kernels::gemm_bias(x, w1, b1, bsz, layout.d_in, layout.h1);
    let a1 = kernels::mask_act(&z1, m1, bsz, layout.h1, poly);
    HeadCache { z1, a1 }
}

/// The forward tail: boundary-0 activation -> logits, under the layer-1
/// mask `m2`. Shared by `forward`, `forward_from` and `eval_from` for the
/// same bit-identity-by-construction reason as [`forward_head`].
fn forward_tail(
    layout: &Layout,
    poly: bool,
    p: &[f32],
    m2: &[f32],
    a1: &[f32],
    bsz: usize,
) -> TailCache {
    let [_w1, _b1, w2, b2, w3, b3] = layout.split(p);
    let z2 = kernels::gemm_bias(a1, w2, b2, bsz, layout.h1, layout.h2);
    let a2 = kernels::mask_act(&z2, m2, bsz, layout.h2, poly);
    let logits = kernels::gemm_bias(&a2, w3, b3, bsz, layout.h2, layout.k);
    TailCache { z2, a2, logits }
}

fn forward(
    layout: &Layout,
    poly: bool,
    p: &[f32],
    mask: &[f32],
    x: &[f32],
    bsz: usize,
) -> ForwardCache {
    let (m1, m2) = mask.split_at(layout.h1);
    let head = forward_head(layout, poly, p, m1, x, bsz);
    let tail = forward_tail(layout, poly, p, m2, &head.a1, bsz);
    ForwardCache { z1: head.z1, a1: head.a1, z2: tail.z2, a2: tail.a2, logits: tail.logits }
}

// ---- batched multi-hypothesis forwards (DESIGN.md §11) --------------------
//
// Bit-identity by construction: the shared affine gets the exact inputs the
// single-hypothesis path would hand the same kernel (masks act only at the
// activations, so `z1`/`z2` are hypothesis-independent), and every per-
// hypothesis step below IS the kernel call [`forward_head`]/[`forward_tail`]
// makes. Scratch buffers are reused across hypotheses (`*_into` clears).

/// Full-route slab forward: `rows` holds `live.len()` full dense masks.
/// Computes `z1` once, then per live hypothesis: mask-act, layer-2 affine,
/// mask-act, output affine. Returns logits per live hypothesis.
fn forward_full_multi(
    layout: &Layout,
    poly: bool,
    p: &[f32],
    rows: &[f32],
    x: &[f32],
    bsz: usize,
    live: &[bool],
) -> Vec<Option<Vec<f32>>> {
    let [w1, b1, w2, b2, w3, b3] = layout.split(p);
    let z1 = kernels::gemm_bias(x, w1, b1, bsz, layout.d_in, layout.h1);
    let width = layout.mask_size();
    let (mut a1, mut z2, mut a2) = (Vec::new(), Vec::new(), Vec::new());
    let mut out = Vec::with_capacity(live.len());
    for (h, &alive) in live.iter().enumerate() {
        if !alive {
            out.push(None);
            continue;
        }
        let (m1, m2) = rows[h * width..(h + 1) * width].split_at(layout.h1);
        kernels::mask_act_into(&z1, m1, bsz, layout.h1, poly, &mut a1);
        kernels::gemm_bias_into(&a1, w2, b2, bsz, layout.h1, layout.h2, &mut z2);
        kernels::mask_act_into(&z2, m2, bsz, layout.h2, poly, &mut a2);
        out.push(Some(kernels::gemm_bias(&a2, w3, b3, bsz, layout.h2, layout.k)));
    }
    out
}

/// Staged-route slab forward: `rows` holds `live.len()` layer-1 mask
/// suffixes, all resuming from the same boundary-0 activation `a1`.
/// Computes `z2` once, then per live hypothesis: mask-act + output affine.
fn forward_tail_multi(
    layout: &Layout,
    poly: bool,
    p: &[f32],
    rows: &[f32],
    a1: &[f32],
    bsz: usize,
    live: &[bool],
) -> Vec<Option<Vec<f32>>> {
    let [_w1, _b1, w2, b2, w3, b3] = layout.split(p);
    let z2 = kernels::gemm_bias(a1, w2, b2, bsz, layout.h1, layout.h2);
    let h2 = layout.h2;
    let mut a2 = Vec::new();
    let mut out = Vec::with_capacity(live.len());
    for (h, &alive) in live.iter().enumerate() {
        if !alive {
            out.push(None);
            continue;
        }
        kernels::mask_act_into(&z2, &rows[h * h2..(h + 1) * h2], bsz, h2, poly, &mut a2);
        out.push(Some(kernels::gemm_bias(&a2, w3, b3, bsz, h2, layout.k)));
    }
    out
}

/// Apply the one shared scoring epilogue to each live hypothesis' logits.
fn score_multi(logits: &[Option<Vec<f32>>], y: &[i32], k: usize) -> Vec<Option<(f32, f32)>> {
    logits
        .iter()
        .map(|l| {
            l.as_ref().map(|v| {
                let (loss, correct) = kernels::softmax_ce_batch(v, y, k, None);
                (loss, correct as f32)
            })
        })
        .collect()
}

/// Backprop from `dlogits` to the full parameter gradient; also returns the
/// per-unit mask gradient `dL/dm_j = sum_b da_bj * (relu(z) - g(z))` needed
/// by `snl_step`.
#[allow(clippy::too_many_arguments)]
fn backward(
    layout: &Layout,
    poly: bool,
    p: &[f32],
    mask: &[f32],
    x: &[f32],
    f: &ForwardCache,
    dlogits: &[f32],
    bsz: usize,
) -> (Vec<f32>, Vec<f32>) {
    let [_w1, _b1, w2, _b2, w3, _b3] = layout.split(p);
    let (m1, m2) = mask.split_at(layout.h1);
    let mut grad = vec![0.0f32; layout.param_size()];
    let mut dmask = vec![0.0f32; layout.mask_size()];
    {
        let [gw1, gb1, gw2, gb2, gw3, gb3] = layout.split_mut(&mut grad);
        // Output layer.
        kernels::matgrad(&f.a2, dlogits, gw3, gb3, bsz, layout.h2, layout.k);
        let da2 = kernels::dinput(dlogits, w3, bsz, layout.h2, layout.k);
        // Hidden layer 2.
        let (dm2, dz2) = kernels::dact(&f.z2, m2, &da2, bsz, layout.h2, poly);
        dmask[layout.h1..].copy_from_slice(&dm2);
        kernels::matgrad(&f.a1, &dz2, gw2, gb2, bsz, layout.h1, layout.h2);
        let da1 = kernels::dinput(&dz2, w2, bsz, layout.h1, layout.h2);
        // Hidden layer 1.
        let (dm1, dz1) = kernels::dact(&f.z1, m1, &da1, bsz, layout.h1, poly);
        dmask[..layout.h1].copy_from_slice(&dm1);
        kernels::matgrad(x, &dz1, gw1, gb1, bsz, layout.d_in, layout.h1);
    }
    (grad, dmask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::TensorI32;

    fn tiny_backend() -> RefBackend {
        RefBackend::new(
            &[RefSpec {
                key: "tiny".into(),
                backbone: "resnet".into(),
                num_classes: 3,
                image_size: 2,
                channels: 1,
                poly: false,
                hidden: (8, 4),
            }],
            4,
        )
    }

    fn host_call(be: &RefBackend, fn_name: &str, args: &[HostArg]) -> Vec<Tensor> {
        be.call("tiny", fn_name, args).unwrap()
    }

    #[test]
    fn init_is_deterministic_and_seed_sensitive() {
        let be = tiny_backend();
        let s7 = TensorI32::scalar(7);
        let s8 = TensorI32::scalar(8);
        let a = host_call(&be, "init", &[HostArg::I32(&s7)]);
        let b = host_call(&be, "init", &[HostArg::I32(&s7)]);
        let c = host_call(&be, "init", &[HostArg::I32(&s8)]);
        assert_eq!(a[0].data, b[0].data);
        assert_ne!(a[0].data, c[0].data);
        let info = be.model("tiny").unwrap();
        assert_eq!(a[0].len(), info.param_size);
        assert!(a[0].data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_shapes_and_mask_sensitivity() {
        let be = tiny_backend();
        let info = be.model("tiny").unwrap().clone();
        let seed = TensorI32::scalar(1);
        let p = host_call(&be, "init", &[HostArg::I32(&seed)]).remove(0);
        let full = Tensor::ones(vec![info.mask_size]);
        let zero = Tensor::zeros(vec![info.mask_size]);
        let mut x = Tensor::zeros(vec![4, 1, 2, 2]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i % 7) as f32 - 3.0) / 3.0;
        }
        let lf = host_call(
            &be,
            "forward",
            &[HostArg::F32(&p), HostArg::F32(&full), HostArg::F32(&x)],
        )
        .remove(0);
        assert_eq!(lf.shape, vec![4, 3]);
        let lz = host_call(
            &be,
            "forward",
            &[HostArg::F32(&p), HostArg::F32(&zero), HostArg::F32(&x)],
        )
        .remove(0);
        assert_ne!(lf.data, lz.data, "removing every ReLU must change the output");
    }

    #[test]
    fn zero_mask_network_is_affine() {
        // With the identity branch everywhere the whole net is affine:
        // f(x1 + x2) = f(x1) + f(x2) - f(0) row-wise.
        let be = tiny_backend();
        let info = be.model("tiny").unwrap().clone();
        let seed = TensorI32::scalar(3);
        let p = host_call(&be, "init", &[HostArg::I32(&seed)]).remove(0);
        let zero_mask = Tensor::zeros(vec![info.mask_size]);
        let fwd = |x: &Tensor| {
            host_call(
                &be,
                "forward",
                &[HostArg::F32(&p), HostArg::F32(&zero_mask), HostArg::F32(x)],
            )
            .remove(0)
        };
        let mut x1 = Tensor::zeros(vec![1, 1, 2, 2]);
        let mut x2 = Tensor::zeros(vec![1, 1, 2, 2]);
        for i in 0..4 {
            x1.data[i] = 0.1 * (i as f32 + 1.0);
            x2.data[i] = -0.2 * (i as f32 - 1.5);
        }
        let xs = Tensor::new(vec![1, 1, 2, 2], (0..4).map(|i| x1.data[i] + x2.data[i]).collect());
        let x0 = Tensor::zeros(vec![1, 1, 2, 2]);
        let (f1, f2, fs, f0) = (fwd(&x1), fwd(&x2), fwd(&xs), fwd(&x0));
        for j in 0..3 {
            let lhs = fs.data[j];
            let rhs = f1.data[j] + f2.data[j] - f0.data[j];
            assert!(
                (lhs - rhs).abs() < 1e-3,
                "affine identity violated at {j}: {lhs} vs {rhs}"
            );
        }
        // Sanity: with the full (ReLU) mask the identity must generally fail.
        let full = Tensor::ones(vec![info.mask_size]);
        let fwd_relu = |x: &Tensor| {
            host_call(
                &be,
                "forward",
                &[HostArg::F32(&p), HostArg::F32(&full), HostArg::F32(x)],
            )
            .remove(0)
        };
        let (r1, r2, rs, r0) = (fwd_relu(&x1), fwd_relu(&x2), fwd_relu(&xs), fwd_relu(&x0));
        let dev: f32 = (0..3)
            .map(|j| (rs.data[j] - (r1.data[j] + r2.data[j] - r0.data[j])).abs())
            .sum();
        assert!(dev > 1e-6, "ReLU network unexpectedly affine");
    }

    #[test]
    fn eval_batch_matches_forward_argmax() {
        let be = tiny_backend();
        let info = be.model("tiny").unwrap().clone();
        let seed = TensorI32::scalar(5);
        let p = host_call(&be, "init", &[HostArg::I32(&seed)]).remove(0);
        let full = Tensor::ones(vec![info.mask_size]);
        let mut x = Tensor::zeros(vec![4, 1, 2, 2]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i * 13 % 11) as f32 - 5.0) / 5.0;
        }
        let y = TensorI32::new(vec![4], vec![0, 1, 2, 0]);
        let logits = host_call(
            &be,
            "forward",
            &[HostArg::F32(&p), HostArg::F32(&full), HostArg::F32(&x)],
        )
        .remove(0);
        let out = host_call(
            &be,
            "eval_batch",
            &[HostArg::F32(&p), HostArg::F32(&full), HostArg::F32(&x), HostArg::I32(&y)],
        );
        let preds = logits.argmax_rows().unwrap();
        let want = preds
            .iter()
            .zip(&y.data)
            .filter(|(p, &t)| **p == t as usize)
            .count() as f32;
        assert_eq!(out[1].item(), want);
        assert!(out[0].item() > 0.0 && out[0].item().is_finite());
    }

    #[test]
    fn train_step_moves_params_and_momentum() {
        let be = tiny_backend();
        let info = be.model("tiny").unwrap().clone();
        let seed = TensorI32::scalar(2);
        let p = host_call(&be, "init", &[HostArg::I32(&seed)]).remove(0);
        let mom = Tensor::zeros(vec![info.param_size]);
        let mask = Tensor::ones(vec![info.mask_size]);
        let mut x = Tensor::zeros(vec![4, 1, 2, 2]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = (i as f32 % 5.0 - 2.0) / 2.0;
        }
        let y = TensorI32::new(vec![4], vec![1, 0, 2, 1]);
        let lr = Tensor::scalar(0.05);
        let out = host_call(
            &be,
            "train_step",
            &[
                HostArg::F32(&p),
                HostArg::F32(&mom),
                HostArg::F32(&mask),
                HostArg::F32(&x),
                HostArg::I32(&y),
                HostArg::F32(&lr),
            ],
        );
        assert_ne!(out[0].data, p.data, "params must move under a gradient step");
        assert!(out[1].data.iter().any(|&m| m != 0.0), "momentum must be nonzero");
        assert!(out[2].item().is_finite());
        // Deterministic: the same step replays bit-exactly.
        let out2 = host_call(
            &be,
            "train_step",
            &[
                HostArg::F32(&p),
                HostArg::F32(&mom),
                HostArg::F32(&mask),
                HostArg::F32(&x),
                HostArg::I32(&y),
                HostArg::F32(&lr),
            ],
        );
        assert_eq!(out[0].data, out2[0].data);
    }

    #[test]
    fn snl_l1_pressure_shrinks_alphas() {
        // With weight lr = 0 and a large lambda, alphas must strictly
        // decrease (the l1 term alone drives them down).
        let be = tiny_backend();
        let info = be.model("tiny").unwrap().clone();
        let seed = TensorI32::scalar(4);
        let p = host_call(&be, "init", &[HostArg::I32(&seed)]).remove(0);
        let mom = Tensor::zeros(vec![info.param_size]);
        let alphas = Tensor::ones(vec![info.mask_size]);
        let x = Tensor::zeros(vec![4, 1, 2, 2]);
        let y = TensorI32::new(vec![4], vec![0, 1, 2, 0]);
        let out = host_call(
            &be,
            "snl_step",
            &[
                HostArg::F32(&p),
                HostArg::F32(&mom),
                HostArg::F32(&alphas),
                HostArg::F32(&x),
                HostArg::I32(&y),
                HostArg::F32(&Tensor::scalar(0.0)),
                HostArg::F32(&Tensor::scalar(0.1)),
                HostArg::F32(&Tensor::scalar(1.0)),
            ],
        );
        assert_eq!(out[0].data, p.data, "lr=0 must leave weights untouched");
        let new_alphas = &out[2];
        let before: f32 = alphas.data.iter().sum();
        let after: f32 = new_alphas.data.iter().sum();
        assert!(after < before, "l1 pressure failed: {after} >= {before}");
        assert!(new_alphas.data.iter().all(|&a| (0.0..=1.0).contains(&a)));
    }

    #[test]
    fn staged_forward_matches_full_bitwise() {
        let be = tiny_backend();
        let info = be.model("tiny").unwrap().clone();
        let seed = TensorI32::scalar(9);
        let p = host_call(&be, "init", &[HostArg::I32(&seed)]).remove(0);
        // Hypothesis differs from the all-ones base mask only in layer 1.
        let h1 = info.mask_layers[0].size;
        let mut hyp = vec![1.0f32; info.mask_size];
        hyp[h1 + 1] = 0.0;
        hyp[h1 + 3] = 0.0;
        let mut x = Tensor::zeros(vec![4, 1, 2, 2]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i * 7 % 13) as f32 - 6.0) / 6.0;
        }
        let hyp_t = Tensor::new(vec![hyp.len()], hyp.clone());
        let full = host_call(
            &be,
            "forward",
            &[HostArg::F32(&p), HostArg::F32(&hyp_t), HostArg::F32(&x)],
        )
        .remove(0);

        assert_eq!(be.segments("tiny"), 1);
        assert_eq!(be.segments("no_such_model"), 0);
        let pb = be.upload_f32(&p.data, &p.shape).unwrap();
        let base = vec![1.0f32; info.mask_size];
        let mb = be.upload_f32(&base, &[base.len()]).unwrap();
        let xb = be.upload_f32(&x.data, &x.shape).unwrap();
        let acts = be.forward_prefix("tiny", 0, &pb, &mb, &xb).unwrap();
        let sb = be.upload_f32(&hyp[h1..], &[info.mask_size - h1]).unwrap();
        let inc = be.forward_from("tiny", 0, &acts, &pb, &sb).unwrap();
        assert_eq!(inc.shape, full.shape);
        assert_eq!(inc.data, full.data, "incremental logits must be bit-identical");

        // eval_from agrees with eval_batch exactly (same scoring code).
        let y = TensorI32::new(vec![4], vec![0, 1, 2, 1]);
        let yb = be.upload_i32(&y.data, &y.shape).unwrap();
        let hb = be.upload_f32(&hyp, &[hyp.len()]).unwrap();
        let full_eval = be.call_b("tiny", "eval_batch", &[&pb, &hb, &xb, &yb]).unwrap();
        let inc_eval = be.eval_from("tiny", 0, &acts, &pb, &sb, &yb).unwrap();
        assert_eq!(inc_eval[0].item(), full_eval[0].item());
        assert_eq!(inc_eval[1].item(), full_eval[1].item());

        // Staged calls are recorded per entry point.
        let stats = be.stats();
        assert!(stats.contains_key("tiny:forward_prefix"));
        assert!(stats.contains_key("tiny:forward_from"));
        assert!(stats.contains_key("tiny:eval_from"));
        be.bump_stat("prefix_cache:hit", 2);
        assert_eq!(be.stats().get("prefix_cache:hit").unwrap().calls, 2);

        // Bad boundary / suffix shapes fail readably, not numerically.
        assert!(be.forward_prefix("tiny", 1, &pb, &mb, &xb).is_err());
        assert!(be.forward_from("tiny", 1, &acts, &pb, &sb).is_err());
        assert!(be.forward_from("tiny", 0, &acts, &pb, &mb).is_err(), "full mask is not a suffix");
    }

    #[test]
    fn batched_multi_matches_single_bitwise() {
        let be = tiny_backend();
        let info = be.model("tiny").unwrap().clone();
        let seed = TensorI32::scalar(11);
        let p = host_call(&be, "init", &[HostArg::I32(&seed)]).remove(0);
        let mut x = Tensor::zeros(vec![4, 1, 2, 2]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i * 5 % 9) as f32 - 4.0) / 4.0;
        }
        let y = TensorI32::new(vec![4], vec![2, 0, 1, 2]);
        let pb = be.upload_f32(&p.data, &p.shape).unwrap();
        let xb = be.upload_f32(&x.data, &x.shape).unwrap();
        let yb = be.upload_i32(&y.data, &y.shape).unwrap();
        assert_eq!(be.multi_width("tiny"), MULTI_WIDTH);
        assert_eq!(be.multi_width("no_such_model"), 1);

        // Three full-mask hypotheses (middle one dead) differing in both
        // layers, plus the all-ones base.
        let h1 = info.mask_layers[0].size;
        let mut masks: Vec<Vec<f32>> = vec![vec![1.0; info.mask_size]; 3];
        masks[0][2] = 0.0;
        masks[1][h1] = 0.0;
        masks[2][1] = 0.0;
        masks[2][h1 + 3] = 0.0;
        let flat: Vec<f32> = masks.iter().flatten().copied().collect();
        let slab = MaskSlab {
            buf: be.upload_f32(&flat, &[3, info.mask_size]).unwrap(),
            n: 3,
            width: info.mask_size,
        };
        let live = [true, false, true];
        let multi = be.eval_batch_multi("tiny", &pb, &slab, &xb, &yb, &live).unwrap();
        let fwd_multi = be.forward_multi("tiny", &pb, &slab, &xb, &live).unwrap();
        assert!(multi[1].is_none() && fwd_multi[1].is_none(), "dead hypothesis must be skipped");
        for h in [0usize, 2] {
            let mb = be.upload_f32(&masks[h], &[info.mask_size]).unwrap();
            let single = be.call_b("tiny", "eval_batch", &[&pb, &mb, &xb, &yb]).unwrap();
            let (loss, correct) = multi[h].unwrap();
            assert_eq!(loss, single[0].item(), "hyp {h} loss");
            assert_eq!(correct, single[1].item(), "hyp {h} correct");
            let single_f = be.call_b("tiny", "forward", &[&pb, &mb, &xb]).unwrap();
            assert_eq!(fwd_multi[h].as_ref().unwrap().data, single_f[0].data, "hyp {h} logits");
        }

        // Staged route: suffix slab resuming from the base-mask prefix.
        let base = vec![1.0f32; info.mask_size];
        let mb = be.upload_f32(&base, &[base.len()]).unwrap();
        let acts = be.forward_prefix("tiny", 0, &pb, &mb, &xb).unwrap();
        let h2 = info.mask_size - h1;
        let mut sufs: Vec<Vec<f32>> = vec![vec![1.0; h2]; 2];
        sufs[0][0] = 0.0;
        sufs[1][3] = 0.0;
        let sflat: Vec<f32> = sufs.iter().flatten().copied().collect();
        let sslab = MaskSlab {
            buf: be.upload_f32(&sflat, &[2, h2]).unwrap(),
            n: 2,
            width: h2,
        };
        let slive = [true, true];
        let inc = be
            .eval_from_multi("tiny", 0, &acts, &pb, &sslab, &yb, &slive)
            .unwrap();
        let inc_f = be
            .forward_from_multi("tiny", 0, &acts, &pb, &sslab, &slive)
            .unwrap();
        for h in 0..2 {
            let sb = be.upload_f32(&sufs[h], &[h2]).unwrap();
            let single = be.eval_from("tiny", 0, &acts, &pb, &sb, &yb).unwrap();
            let (loss, correct) = inc[h].unwrap();
            assert_eq!(loss, single[0].item(), "suffix hyp {h} loss");
            assert_eq!(correct, single[1].item(), "suffix hyp {h} correct");
            let single_f = be.forward_from("tiny", 0, &acts, &pb, &sb).unwrap();
            assert_eq!(inc_f[h].as_ref().unwrap().data, single_f.data, "suffix hyp {h} logits");
        }

        // Multi calls are recorded per entry point.
        let stats = be.stats();
        for k in [
            "tiny:eval_batch_multi",
            "tiny:forward_multi",
            "tiny:eval_from_multi",
            "tiny:forward_from_multi",
        ] {
            assert!(stats.contains_key(k), "missing stat {k}");
        }
    }

    #[test]
    fn batched_multi_rejects_bad_slabs() {
        let be = tiny_backend();
        let info = be.model("tiny").unwrap().clone();
        let seed = TensorI32::scalar(13);
        let p = host_call(&be, "init", &[HostArg::I32(&seed)]).remove(0);
        let pb = be.upload_f32(&p.data, &p.shape).unwrap();
        let x = Tensor::zeros(vec![4, 1, 2, 2]);
        let xb = be.upload_f32(&x.data, &x.shape).unwrap();
        let yb = be.upload_i32(&[0, 1, 2, 0], &[4]).unwrap();
        let mk_slab = |n: usize, width: usize| MaskSlab {
            buf: be.upload_f32(&vec![1.0f32; n * width], &[n, width]).unwrap(),
            n,
            width,
        };
        // Wrong row width.
        let bad = mk_slab(2, info.mask_size - 1);
        assert!(be
            .eval_batch_multi("tiny", &pb, &bad, &xb, &yb, &[true, true])
            .is_err());
        // live length mismatch.
        let ok = mk_slab(2, info.mask_size);
        assert!(be.eval_batch_multi("tiny", &pb, &ok, &xb, &yb, &[true]).is_err());
        // Over the width limit.
        let wide = mk_slab(MULTI_WIDTH + 1, info.mask_size);
        let live = vec![true; MULTI_WIDTH + 1];
        assert!(be.eval_batch_multi("tiny", &pb, &wide, &xb, &yb, &live).is_err());
        // Staged slab must carry suffixes, not full masks.
        let mb = be.upload_f32(&vec![1.0f32; info.mask_size], &[info.mask_size]).unwrap();
        let acts = be.forward_prefix("tiny", 0, &pb, &mb, &xb).unwrap();
        let full_rows = mk_slab(2, info.mask_size);
        assert!(be
            .eval_from_multi("tiny", 0, &acts, &pb, &full_rows, &yb, &[true, true])
            .is_err());
    }

    #[test]
    fn standard_models_cover_experiment_keys() {
        let be = RefBackend::standard();
        // Deprecated MLP aliases, canonical MLP names, and the conv
        // topologies all resolve.
        for key in [
            "resnet_16x16_c10",
            "resnet_16x16_c20",
            "resnet_32x32_c20",
            "wrn_16x16_c20_poly",
            "wrn_32x32_c20",
            "mlp_16x16_c10",
            "mlpw_32x32_c20_poly",
            "resnet18_16x16_c10",
            "resnet18_32x32_c20_poly",
            "wrn22_16x16_c20",
            "wrn22_32x32_c20_poly",
        ] {
            let info = be.model(key).unwrap();
            assert!(info.mask_size > 0 && info.param_size > 0, "{key}");
        }
        assert!(be.model("nope").is_err());
        assert_eq!(be.batch(), 16);
        assert_eq!(be.manifest().models.len(), 24, "12 MLP + 12 conv variants");
    }

    #[test]
    fn deprecated_keys_alias_to_renamed_mlp_models() {
        let be = RefBackend::standard();
        // The alias resolves to the canonical entry: `info.key` names the
        // canonical model, not the alias.
        let direct = be.model("mlp_16x16_c10").unwrap().clone();
        let via_alias = be.model("resnet_16x16_c10").unwrap();
        assert_eq!(via_alias.key, "mlp_16x16_c10");
        assert_eq!(via_alias.backbone, "mlp");
        assert_eq!(via_alias.param_size, direct.param_size);
        assert_eq!(be.model("wrn_32x32_c20_poly").unwrap().key, "mlpw_32x32_c20_poly");
        // The conv backbones own the `resnet18_*`/`wrn22_*` namespace;
        // the alias prefixes must not capture them.
        assert_eq!(be.model("resnet18_16x16_c10").unwrap().backbone, "resnet18");
        assert_eq!(be.model("wrn22_16x16_c10").unwrap().backbone, "wrn22");
        // Entry points and staged plumbing accept aliases too.
        let seed = TensorI32::scalar(1);
        let p = be.call("resnet_16x16_c10", "init", &[HostArg::I32(&seed)]).unwrap();
        assert_eq!(p[0].len(), direct.param_size);
        assert_eq!(be.segments("resnet_16x16_c10"), 1);
        assert_eq!(be.multi_width("wrn_16x16_c20"), MULTI_WIDTH);
        // Unknown keys with an alias prefix still fail readably.
        assert!(be.model("resnet_99x99_c7").is_err());
    }

    #[test]
    fn conv_models_register_with_conv_layouts() {
        let be = RefBackend::standard();
        let r = be.model("resnet18_16x16_c10").unwrap();
        assert_eq!((r.param_size, r.mask_size, r.mask_layers.len()), (177602, 488, 17));
        assert_eq!(be.model("resnet18_32x32_c20").unwrap().param_size, 178252);
        let w = be.model("wrn22_16x16_c10").unwrap();
        assert_eq!((w.param_size, w.mask_size, w.mask_layers.len()), (174722, 456, 13));
        // Residual-block resume boundaries: strictly increasing mask-layer
        // mapping, image-shaped cached activations.
        assert_eq!(be.segments("resnet18_16x16_c10"), 8);
        assert_eq!(be.segments("wrn22_16x16_c10"), 6);
        for b in 1..be.segments("resnet18_16x16_c10") {
            assert!(
                be.segment_layer("resnet18_16x16_c10", b)
                    > be.segment_layer("resnet18_16x16_c10", b - 1)
            );
        }
        // Boundary 0 caches the 8-channel 16x16 stem activation.
        assert_eq!(be.prefix_entry_bytes("resnet18_16x16_c10", 0, 4), 4 * 4 * 8 * 16 * 16);
        assert_eq!(be.multi_width("resnet18_16x16_c10"), MULTI_WIDTH);
    }

    #[test]
    fn conv_staged_and_multi_match_full_bitwise() {
        let be = RefBackend::standard();
        let key = "wrn22_16x16_c10";
        let info = be.model(key).unwrap().clone();
        let seed = TensorI32::scalar(5);
        let p = be.call(key, "init", &[HostArg::I32(&seed)]).unwrap().remove(0);
        let mut x = Tensor::zeros(vec![2, 3, 16, 16]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = ((i * 7 % 23) as f32 - 11.0) / 11.0;
        }
        let y = TensorI32::new(vec![2], vec![3, 8]);
        // A hypothesis dirty only past the deepest boundary, so it is
        // resumable from there.
        let deep = be.segments(key) - 1;
        let suffix_off = info.mask_layers[be.segment_layer(key, deep) + 1].offset;
        let mut hyp = vec![1.0f32; info.mask_size];
        hyp[suffix_off] = 0.0;
        hyp[info.mask_size - 1] = 0.0;
        let hyp_t = Tensor::new(vec![hyp.len()], hyp.clone());
        let full = be
            .call(key, "forward", &[HostArg::F32(&p), HostArg::F32(&hyp_t), HostArg::F32(&x)])
            .unwrap()
            .remove(0);

        let pb = be.upload_f32(&p.data, &p.shape).unwrap();
        let base = vec![1.0f32; info.mask_size];
        let mb = be.upload_f32(&base, &[base.len()]).unwrap();
        let xb = be.upload_f32(&x.data, &x.shape).unwrap();
        let yb = be.upload_i32(&y.data, &y.shape).unwrap();
        let acts = be.forward_prefix(key, deep, &pb, &mb, &xb).unwrap();
        let sb = be.upload_f32(&hyp[suffix_off..], &[info.mask_size - suffix_off]).unwrap();
        let inc = be.forward_from(key, deep, &acts, &pb, &sb).unwrap();
        assert_eq!(inc.shape, full.shape);
        assert_eq!(inc.data, full.data, "staged conv logits must be bit-identical");

        let hb = be.upload_f32(&hyp, &[hyp.len()]).unwrap();
        let full_eval = be.call_b(key, "eval_batch", &[&pb, &hb, &xb, &yb]).unwrap();
        let inc_eval = be.eval_from(key, deep, &acts, &pb, &sb, &yb).unwrap();
        assert_eq!(inc_eval[0].item(), full_eval[0].item());
        assert_eq!(inc_eval[1].item(), full_eval[1].item());

        // Batched full-route slab vs single calls.
        let mut masks: Vec<Vec<f32>> = vec![vec![1.0; info.mask_size]; 2];
        masks[0][0] = 0.0;
        masks[1][info.mask_size / 2] = 0.0;
        let flat: Vec<f32> = masks.iter().flatten().copied().collect();
        let slab = MaskSlab {
            buf: be.upload_f32(&flat, &[2, info.mask_size]).unwrap(),
            n: 2,
            width: info.mask_size,
        };
        let multi = be.eval_batch_multi(key, &pb, &slab, &xb, &yb, &[true, true]).unwrap();
        for h in 0..2 {
            let mh = be.upload_f32(&masks[h], &[info.mask_size]).unwrap();
            let single = be.call_b(key, "eval_batch", &[&pb, &mh, &xb, &yb]).unwrap();
            let (loss, correct) = multi[h].unwrap();
            assert_eq!(loss, single[0].item(), "conv hyp {h} loss");
            assert_eq!(correct, single[1].item(), "conv hyp {h} correct");
        }

        // Batched staged slab vs single resumes; dead rows skipped.
        let sw = info.mask_size - suffix_off;
        let mut sufs: Vec<Vec<f32>> = vec![vec![1.0; sw]; 3];
        sufs[0][0] = 0.0;
        sufs[2][sw - 1] = 0.0;
        let sflat: Vec<f32> = sufs.iter().flatten().copied().collect();
        let sslab = MaskSlab {
            buf: be.upload_f32(&sflat, &[3, sw]).unwrap(),
            n: 3,
            width: sw,
        };
        let live = [true, false, true];
        let inc_multi = be.eval_from_multi(key, deep, &acts, &pb, &sslab, &yb, &live).unwrap();
        assert!(inc_multi[1].is_none());
        for h in [0usize, 2] {
            let sh = be.upload_f32(&sufs[h], &[sw]).unwrap();
            let single = be.eval_from(key, deep, &acts, &pb, &sh, &yb).unwrap();
            let (loss, correct) = inc_multi[h].unwrap();
            assert_eq!(loss, single[0].item(), "conv suffix hyp {h} loss");
            assert_eq!(correct, single[1].item(), "conv suffix hyp {h} correct");
        }

        // Shape misuse fails readably: full mask where a suffix belongs,
        // out-of-range boundary.
        assert!(be.forward_from(key, deep, &acts, &pb, &mb).is_err());
        assert!(be.forward_prefix(key, be.segments(key), &pb, &mb, &xb).is_err());
    }

    #[test]
    fn conv_train_steps_update_params_and_running_stats() {
        let be = RefBackend::standard();
        let key = "resnet18_16x16_c10";
        let info = be.model(key).unwrap().clone();
        let seed = TensorI32::scalar(2);
        let p = be.call(key, "init", &[HostArg::I32(&seed)]).unwrap().remove(0);
        let mom = Tensor::zeros(vec![info.param_size]);
        let mask = Tensor::ones(vec![info.mask_size]);
        let mut x = Tensor::zeros(vec![2, 3, 16, 16]);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = (i as f32 % 5.0 - 2.0) / 2.0;
        }
        let y = TensorI32::new(vec![2], vec![1, 7]);
        let lr = Tensor::scalar(0.01);
        let args = [
            HostArg::F32(&p),
            HostArg::F32(&mom),
            HostArg::F32(&mask),
            HostArg::F32(&x),
            HostArg::I32(&y),
            HostArg::F32(&lr),
        ];
        let out = be.call(key, "train_step", &args).unwrap();
        assert_ne!(out[0].data, p.data);
        assert!(out[2].item().is_finite() && out[2].item() > 0.0);
        // The stem's running mean moved off its zero init: batch stats
        // were folded in by the EMA after the SGD step.
        let bn = info.param_entries.iter().find(|e| e.name == "stem.bn").unwrap();
        let c = bn.size / 4;
        let rm_new = &out[0].data[bn.offset + 2 * c..bn.offset + 3 * c];
        assert!(rm_new.iter().any(|&v| v != 0.0), "running mean must move");
        // Replays bit-exactly.
        let out2 = be.call(key, "train_step", &args).unwrap();
        assert_eq!(out[0].data, out2[0].data);

        // SNL: large lambda with zero weight lr shrinks channel alphas;
        // only the running-stat rows of the params may move.
        let alphas = Tensor::ones(vec![info.mask_size]);
        let snl = be
            .call(
                key,
                "snl_step",
                &[
                    HostArg::F32(&p),
                    HostArg::F32(&mom),
                    HostArg::F32(&alphas),
                    HostArg::F32(&x),
                    HostArg::I32(&y),
                    HostArg::F32(&Tensor::scalar(0.0)),
                    HostArg::F32(&Tensor::scalar(0.1)),
                    HostArg::F32(&Tensor::scalar(1.0)),
                ],
            )
            .unwrap();
        let after: f32 = snl[2].data.iter().sum();
        assert!(after < info.mask_size as f32, "l1 pressure must shrink alphas");
        assert!(snl[2].data.iter().all(|&a| (0.0..=1.0).contains(&a)));
        let w1 = info.param_entries.iter().find(|e| e.name == "stem.conv.w").unwrap();
        assert_eq!(
            snl[0].data[w1.offset..w1.offset + w1.size],
            p.data[w1.offset..w1.offset + w1.size],
            "lr=0 leaves conv weights untouched (running stats may still move)"
        );

        // KD runs and yields a finite blended loss.
        let t_logits = Tensor::new(vec![2, 10], (0..20).map(|i| (i % 7) as f32 / 7.0).collect());
        let kd = be
            .call(
                key,
                "kd_step",
                &[
                    HostArg::F32(&p),
                    HostArg::F32(&mom),
                    HostArg::F32(&mask),
                    HostArg::F32(&x),
                    HostArg::I32(&y),
                    HostArg::F32(&t_logits),
                    HostArg::F32(&lr),
                    HostArg::F32(&Tensor::scalar(4.0)),
                ],
            )
            .unwrap();
        assert!(kd[2].item().is_finite());
        assert_ne!(kd[0].data, p.data);
    }
}
