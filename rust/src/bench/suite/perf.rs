//! §Perf: runtime microbenchmarks of the L3 hot path.
//!
//! Measures (and records in the `perf` report):
//!   - eval_batch literal path vs buffer-cached path (§Perf opt 1)
//!   - trial scan with vs without the early-exit accuracy bound (opt 2)
//!   - per-trial mask hypothesis cost (zero-alloc scratch, opt 3)
//!   - host->device upload costs by tensor size
//!   - parallel trial-scan throughput across worker counts (opt 4)
//!   - staged (prefix-reuse) vs full-forward scans at DRC ∈ {1,8,64} (opt 5)
//!   - batched multi-trial scoring vs full and staged at DRC ∈ {1,8,64}
//!     (`bcd.trial_batch`, opt 6)
//!   - end-to-end BCD iteration throughput

use crate::bench::{setup, BenchCtx};
use crate::coordinator::eval::{EvalOpts, Evaluator};
use crate::coordinator::trials::{scan_trials, BlockSampler};
use crate::data::synth;
use crate::metrics::write_csv;
use crate::runtime::session::Session;
use crate::runtime::Backend;
use crate::util::bench::{print_results, summarize, time};
use crate::util::prng::Rng;
use anyhow::{ensure, Result};

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    let sess = Session::new(engine, "resnet_16x16_c10")?;
    let (train_ds, _) = synth::generate(synth::by_name("synth10").unwrap());
    let st = sess.init_state(1)?;
    let info = sess.info().clone();
    let (iters, warmup) = if cx.full { (30, 5) } else { (10, 2) };

    let mut results = Vec::new();

    // Display names embed tensor sizes / grid parameters for the terminal
    // table, but report metric names must stay stable across quick/full
    // mode and model-shape changes — otherwise a renamed metric reads as
    // Missing (a config-blind gate failure) instead of a judged diff. So
    // every push records under an explicit stable key too.
    fn record(cx: &mut BenchCtx, key: &str, r: &crate::util::bench::BenchResult) {
        cx.time_ms("microbench", key, &r.samples_ms);
    }

    // --- upload costs ------------------------------------------------------
    let mask = vec![1.0f32; info.mask_size];
    results.push(time(
        &format!("upload mask [{} f32]", mask.len()),
        warmup,
        iters,
        || {
            let _ = engine.upload_f32(&mask, &[mask.len()]).unwrap();
        },
    ));
    record(cx, "upload_mask", results.last().unwrap());
    results.push(time(
        &format!("upload params [{} f32]", st.params.len()),
        warmup,
        iters,
        || {
            let _ = engine.upload_f32(&st.params.data, &st.params.shape).unwrap();
        },
    ));
    record(cx, "upload_params", results.last().unwrap());
    let (x, y) = train_ds.batch_at(0, sess.batch);
    results.push(time(
        &format!("upload batch x+y [{} f32]", x.len()),
        warmup,
        iters,
        || {
            let _ = sess.upload_batch(&x, &y).unwrap();
        },
    ));
    record(cx, "upload_batch", results.last().unwrap());

    // --- eval: host path vs buffer path -------------------------------------
    results.push(time("eval_batch host path", warmup, iters, || {
        let _ = sess.eval_batch(&st.params, &mask, &x, &y).unwrap();
    }));
    record(cx, "eval_batch_host", results.last().unwrap());
    let pbuf = engine.upload_f32(&st.params.data, &st.params.shape)?;
    let mbuf = engine.upload_f32(&mask, &[mask.len()])?;
    let (xbuf, ybuf) = sess.upload_batch(&x, &y)?;
    results.push(time("eval_batch buffer path", warmup, iters, || {
        let _ = sess.eval_batch_b(&pbuf, &mbuf, &xbuf, &ybuf).unwrap();
    }));
    record(cx, "eval_batch_buffer", results.last().unwrap());

    // --- trial scan: bound on vs off ----------------------------------------
    let drc = (info.mask_size / 20).max(1);
    let ev = Evaluator::new(&sess, &train_ds, 2)?;
    let params = ev.upload_params(&st.params)?;
    let base = ev.accuracy(&params, st.mask.dense())?;
    // Bound ON is the production path (floor = incumbent best); bound OFF is
    // emulated by an unreachable ADT and floor via accuracy() per trial.
    let sampler = BlockSampler::new(crate::config::Granularity::Pixel, sess.info());
    let mut rng = Rng::new(7);
    let t0 = std::time::Instant::now();
    let scan =
        scan_trials(&ev, &params, &st.mask, &sampler, drc, 8, -1e9, base, &mut rng, 1)?;
    let bounded_ms = t0.elapsed().as_secs_f64() * 1000.0;
    // Replay scan_trials' exact draw procedure (per-index fork + dedup) so
    // both timings score the identical hypothesis set.
    let mut rng = Rng::new(7);
    let t0 = std::time::Instant::now();
    let mut scratch = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for t in 0..8u64 {
        let mut trial_rng = rng.fork(t);
        let mut removed = sampler.sample(&st.mask, &mut trial_rng, drc);
        removed.sort_unstable();
        if !seen.insert(removed.clone()) {
            continue;
        }
        st.mask.hypothesis_into(&removed, &mut scratch);
        let _ = ev.accuracy(&params, &scratch)?; // no bound: full evaluation
    }
    let unbounded_ms = t0.elapsed().as_secs_f64() * 1000.0;
    results.push(summarize("trial scan x8, bound ON", vec![bounded_ms]));
    record(cx, "scan_bound_on", results.last().unwrap());
    results.push(summarize("trial scan x8, bound OFF", vec![unbounded_ms]));
    record(cx, "scan_bound_off", results.last().unwrap());
    println!(
        "bound cut {} of {} trials early ({} evals saved)",
        scan.bounded, scan.evaluated, scan.bounded
    );

    // --- parallel trial scan: worker sweep -----------------------------------
    // Unreachable ADT so every worker count scores the full RT hypotheses;
    // throughput = hypotheses/sec. The outcome must be identical at every
    // worker count (deterministic merge) — verified as we sweep.
    let sweep_rt = if cx.full { 32 } else { 16 };
    let mut sweep_rows = Vec::new();
    let mut reference_outcome = None;
    for &w in &[1usize, 2, 4, 8] {
        let mut rng = Rng::new(21);
        let t0 = std::time::Instant::now();
        let out = scan_trials(
            &ev, &params, &st.mask, &sampler, drc, sweep_rt, -1e9, base, &mut rng, w,
        )?;
        let secs = t0.elapsed().as_secs_f64();
        let hps = out.evaluated as f64 / secs;
        match &reference_outcome {
            None => reference_outcome = Some(out.clone()),
            // ensure!, not assert!: a determinism break must surface as a
            // bench failure (Err up through the CLI), not a process abort
            // that loses the report and any remaining tier entries.
            Some(r) => ensure!(r == &out, "worker count {w} changed the scan outcome"),
        }
        println!("scan workers={w}: {hps:7.1} hypotheses/sec ({:.1} ms)", 1000.0 * secs);
        results.push(summarize(
            &format!("trial scan x{sweep_rt}, workers={w}"),
            vec![1000.0 * secs],
        ));
        cx.rate("scan_workers", &format!("workers{w}"), hps, "hyp/s");
        sweep_rows.push(vec![w.to_string(), format!("{hps:.1}"), format!("{:.2}", 1000.0 * secs)]);
    }
    write_csv(
        &setup::results_csv("perf_scan_workers"),
        &["workers", "hypotheses_per_sec", "total_ms"],
        &sweep_rows,
    )?;

    // --- staged execution: full-forward vs incremental trial scan ------------
    // The bcd.cache_mb knob (DESIGN.md §8). Outcomes must be bit-identical;
    // only wall-clock may differ. Low DRC lands more hypotheses entirely in
    // late layers, so the prefix-reuse win shrinks as DRC grows.
    let ev_inc = Evaluator::with_cache(&sess, &train_ds, 2, 64)?;
    let staged_rt = if cx.full { 48 } else { 24 };
    let mut staged_rows = Vec::new();
    for &d in &[1usize, 8, 64] {
        let mut rng = Rng::new(33);
        let t0 = std::time::Instant::now();
        let full_out = scan_trials(
            &ev, &params, &st.mask, &sampler, d, staged_rt, -1e9, base, &mut rng, 1,
        )?;
        let full_ms = 1000.0 * t0.elapsed().as_secs_f64();
        let mut rng = Rng::new(33);
        let t0 = std::time::Instant::now();
        let inc_out = scan_trials(
            &ev_inc, &params, &st.mask, &sampler, d, staged_rt, -1e9, base, &mut rng, 1,
        )?;
        let inc_ms = 1000.0 * t0.elapsed().as_secs_f64();
        ensure!(
            full_out == inc_out,
            "staged scan diverged from full scan at DRC={d}"
        );
        let speedup = full_ms / inc_ms.max(1e-9);
        println!(
            "staged scan DRC={d}: full {full_ms:.1} ms, incremental {inc_ms:.1} ms => {speedup:.2}x"
        );
        results.push(summarize(
            &format!("trial scan x{staged_rt} DRC={d}, full fwd"),
            vec![full_ms],
        ));
        record(cx, &format!("staged_full_drc{d}"), results.last().unwrap());
        results.push(summarize(
            &format!("trial scan x{staged_rt} DRC={d}, incremental"),
            vec![inc_ms],
        ));
        record(cx, &format!("staged_incremental_drc{d}"), results.last().unwrap());
        cx.rate("staged", &format!("speedup_drc{d}"), speedup, "x");
        staged_rows.push(vec![
            d.to_string(),
            format!("{full_ms:.2}"),
            format!("{inc_ms:.2}"),
            format!("{speedup:.2}"),
        ]);
    }
    let (hits, misses, evictions) = ev_inc.cache_counters();
    println!("prefix cache: {hits} hits, {misses} misses, {evictions} evictions");
    write_csv(
        &setup::results_csv("perf_staged"),
        &["drc", "full_ms", "incremental_ms", "speedup"],
        &staged_rows,
    )?;

    // --- batched multi-trial scoring: full vs staged vs batched --------------
    // The bcd.trial_batch knob (DESIGN.md §11). A slab of hypotheses shares
    // every mask-independent affine per backend call; outcomes must be
    // bit-identical at every slab width — only wall-clock may differ. High
    // DRC dirties early layers, so the batched-FULL route (shared first
    // affine) carries the win where staged reuse cannot apply.
    let ev_batched = Evaluator::with_opts(
        &sess,
        &train_ds,
        2,
        EvalOpts {
            cache_bytes: 64 << 20,
            trial_batch: 16,
            verify_staged: false,
            verify_lowering: false,
        },
    )?;
    let mut batched_rows = Vec::new();
    for &d in &[1usize, 8, 64] {
        let mut rng = Rng::new(33);
        let t0 = std::time::Instant::now();
        let full_out = scan_trials(
            &ev, &params, &st.mask, &sampler, d, staged_rt, -1e9, base, &mut rng, 1,
        )?;
        let full_ms = 1000.0 * t0.elapsed().as_secs_f64();
        let mut rng = Rng::new(33);
        let t0 = std::time::Instant::now();
        let staged_out = scan_trials(
            &ev_inc, &params, &st.mask, &sampler, d, staged_rt, -1e9, base, &mut rng, 1,
        )?;
        let staged_ms = 1000.0 * t0.elapsed().as_secs_f64();
        let mut rng = Rng::new(33);
        let t0 = std::time::Instant::now();
        let batched_out = scan_trials(
            &ev_batched, &params, &st.mask, &sampler, d, staged_rt, -1e9, base, &mut rng, 1,
        )?;
        let batched_ms = 1000.0 * t0.elapsed().as_secs_f64();
        ensure!(
            full_out == batched_out && staged_out == batched_out,
            "batched scan diverged at DRC={d}"
        );
        let x_vs_full = full_ms / batched_ms.max(1e-9);
        let x_vs_staged = staged_ms / batched_ms.max(1e-9);
        println!(
            "batched scan DRC={d}: full {full_ms:.1} ms, staged {staged_ms:.1} ms, \
             batched {batched_ms:.1} ms => {x_vs_full:.2}x vs full, {x_vs_staged:.2}x vs staged"
        );
        results.push(summarize(
            &format!("trial scan x{staged_rt} DRC={d}, batched x16"),
            vec![batched_ms],
        ));
        record(cx, &format!("batched_drc{d}"), results.last().unwrap());
        cx.rate("staged_batched", &format!("speedup_vs_full_drc{d}"), x_vs_full, "x");
        cx.rate("staged_batched", &format!("speedup_vs_staged_drc{d}"), x_vs_staged, "x");
        batched_rows.push(vec![
            d.to_string(),
            format!("{full_ms:.2}"),
            format!("{staged_ms:.2}"),
            format!("{batched_ms:.2}"),
            format!("{x_vs_full:.2}"),
            format!("{x_vs_staged:.2}"),
        ]);
    }
    let (slabs, staged_tr, full_tr, calls, width_sum) = ev_batched.batch_counters();
    println!(
        "trial batching: {slabs} slabs ({staged_tr} staged + {full_tr} full hyps), \
         {calls} multi calls, mean width {:.1}",
        width_sum as f64 / (calls.max(1)) as f64
    );
    write_csv(
        &setup::results_csv("perf_staged_batched"),
        &["drc", "full_ms", "staged_ms", "batched_ms", "x_vs_full", "x_vs_staged"],
        &batched_rows,
    )?;

    // --- mask hypothesis cost (pure host) ------------------------------------
    let mut rng2 = Rng::new(9);
    results.push(time("mask sample+hypothesis (host)", warmup, 1000, || {
        let removed = st.mask.sample_present(&mut rng2, drc);
        st.mask.hypothesis_into(&removed, &mut scratch);
    }));
    record(cx, "mask_hypothesis", results.last().unwrap());

    // --- end-to-end BCD iteration throughput ---------------------------------
    let mut st2 = sess.init_state(2)?;
    let cfg = crate::config::BcdConfig {
        drc,
        rt: 4,
        adt: 0.3,
        finetune_steps: 4,
        finetune_lr: 1e-3,
        proxy_batches: 2,
        seed: 3,
        ..Default::default()
    };
    let target = st2.budget() - 4 * drc;
    let t0 = std::time::Instant::now();
    let out = crate::coordinator::bcd::run_bcd(&sess, &mut st2, &train_ds, target, &cfg, 0)?;
    let secs = t0.elapsed().as_secs_f64();
    results.push(summarize(
        "BCD iteration (RT=4, ft=4)",
        vec![1000.0 * secs / out.iterations.len() as f64],
    ));
    record(cx, "bcd_iteration", results.last().unwrap());
    cx.rate(
        "bcd",
        "iters_per_sec",
        out.iterations.len() as f64 / secs,
        "iters/s",
    );
    println!(
        "BCD end-to-end: {} iters in {secs:.1}s => {:.2} iters/s, {} trials ({} bounded)",
        out.iterations.len(),
        out.iterations.len() as f64 / secs,
        out.total_trials(),
        out.iterations.iter().map(|r| r.trials_bounded).sum::<usize>(),
    );

    print_results("§Perf — L3 hot-path microbenchmarks", &results);
    write_csv(
        &setup::results_csv("perf"),
        &["operation", "mean_ms", "p50_ms", "p95_ms", "n"],
        &results.iter().map(|r| r.row()).collect::<Vec<_>>(),
    )?;
    println!("\n{}", engine.stats_table());
    Ok(())
}
