//! Figure 10 (supplementary): SNL ReLU budget vs training step, and the
//! per-check budget decrease rate with the κ-update counter.
//!
//! Shape criterion: the decrease rate starts monotone; once the κ mechanism
//! fires it becomes erratic — the debugging evidence for how hard the
//! Lagrange multiplier is to tune.

use crate::bench::{setup, BenchCtx};
use crate::methods::snl::run_snl;
use crate::metrics::{ascii_plot, write_csv, Series};
use crate::pipeline::Pipeline;
use anyhow::Result;

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    let exp = setup::experiment("synth100", "resnet", false);
    let pl = Pipeline::new(engine, exp)?;
    let total = pl.sess.info().total_relus();
    let target = setup::scale_budget(15e3, total, "resnet", 16);

    let mut st = pl.baseline()?;
    let mut cfg = pl.exp.snl.clone();
    cfg.steps_per_check = 2;
    let out = run_snl(&pl.sess, &mut st, &pl.train_ds, target, &cfg, 0)?;
    cx.stat("snl_path", "checks", out.budget_trace.len() as f64, "checks");
    cx.stat("snl_path", "kappa_updates", out.kappa_updates.len() as f64, "updates");

    // (a) budget vs step.
    let s_budget = Series::new(
        "budget",
        out.budget_trace.iter().map(|&(s, b)| (s as f64, b as f64)).collect(),
    );
    println!("\n{}", ascii_plot("Fig. 10a — ReLU budget vs SNL step", &[s_budget], 60, 12));

    // (b) decrease per check + cumulative kappa updates.
    let mut deltas = Vec::new();
    for w in out.budget_trace.windows(2) {
        let (s, b1) = w[1];
        let (_, b0) = w[0];
        deltas.push((s as f64, b0 as f64 - b1 as f64));
    }
    let s_delta = Series::new("Δbudget per check", deltas.clone());
    let kappa_counter: Vec<(f64, f64)> = out
        .budget_trace
        .iter()
        .map(|&(s, _)| {
            (
                s as f64,
                out.kappa_updates.iter().filter(|&&u| u <= s).count() as f64,
            )
        })
        .collect();
    let s_kappa = Series::new("κ-update counter", kappa_counter.clone());
    println!(
        "{}",
        ascii_plot("Fig. 10b — budget decrease rate & κ updates", &[s_delta, s_kappa], 60, 12)
    );

    let rows: Vec<Vec<String>> = out
        .budget_trace
        .iter()
        .zip(std::iter::once(&(0usize, 0usize)).chain(out.budget_trace.iter()))
        .map(|(&(s, b), &(_, prev))| {
            vec![
                s.to_string(),
                b.to_string(),
                if prev > 0 { (prev as i64 - b as i64).to_string() } else { "0".into() },
                out.kappa_updates.iter().filter(|&&u| u <= s).count().to_string(),
            ]
        })
        .collect();
    write_csv(
        &setup::results_csv("fig10"),
        &["step", "budget", "delta", "kappa_updates"],
        &rows,
    )?;

    // Shape: was the decrease rate monotone before the first kappa update
    // and non-monotone after?
    if let Some(&first_kappa) = out.kappa_updates.first() {
        let before: Vec<f64> = deltas.iter().filter(|(s, _)| *s <= first_kappa as f64).map(|p| p.1).collect();
        let after: Vec<f64> = deltas.iter().filter(|(s, _)| *s > first_kappa as f64).map(|p| p.1).collect();
        let non_monotone = after.windows(2).any(|w| w[1] > w[0] + 1.0);
        println!(
            "\nshape: first κ update at step {first_kappa}; pre-κ checks {} post-κ checks {} (rate erratic after κ: {})",
            before.len(),
            after.len(),
            non_monotone
        );
    } else {
        println!("\nshape: κ never fired in this run (budget fell freely)");
    }
    Ok(())
}
