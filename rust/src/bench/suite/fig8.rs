//! Figure 8 (supplementary): Ours vs SENet on the WideResNet-22-8 backbone,
//! relative-to-baseline metric — same harness as Fig. 3, wide backbone.

use crate::bench::BenchCtx;
use anyhow::Result;

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    super::fig3::run_with(cx, "wrn", "fig8")
}
