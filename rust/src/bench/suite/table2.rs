//! Table 2: Test accuracy vs ReLU budget for the WideResNet-22-8-analog
//! backbone, SNL vs Ours (BCD).
//!
//! Paper budgets run extremely sparse (6K of 1359K = 0.4%); scaled budgets
//! preserve those fractions. Shape criterion: Ours >= SNL on every budget,
//! gap widest at the lowest budgets.

use crate::bench::{setup, BenchCtx};
use crate::runtime::Backend;
use anyhow::Result;

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    let mut all = Vec::new();
    let grids: &[(&str, &[f64], usize)] = &[
        ("synth10", &[6e3, 15e3, 100e3, 150e3], 2),
        ("synth100", &[6e3, 15e3, 100e3], 2),
        // wrn@32x32 costs ~2s/step on this testbed; quick mode skips it
        // (CDNL_BENCH_FULL=1 restores the full grid).
        ("synthtiny", &[59.1e3, 99.6e3, 150e3, 200e3], 0),
    ];
    for (dataset, paper_budgets, quick_n) in grids {
        // Alias-resolving lookup: "wrn" model keys are deprecated aliases
        // of the renamed mlpw_* stand-ins (DESIGN.md §12).
        let info = engine.model(&setup::experiment(dataset, "wrn", false).model_key())?;
        let total = info.mask_size;
        let size = info.image_size;
        let budgets: Vec<usize> = setup::grid(paper_budgets, *quick_n)
            .iter()
            .map(|&b| setup::scale_budget(b, total, "wrn", size).max(50))
            .collect();
        all.extend(setup::snl_vs_ours(engine, dataset, "wrn", &budgets)?);
    }
    for p in &all {
        let case = format!("{}/b{}", p.dataset, p.budget);
        cx.stat(&case, "snl_acc", p.snl_acc, "%");
        cx.stat(&case, "ours_acc", p.ours_acc, "%");
    }
    setup::report_snl_vs_ours(
        "table2",
        "Table 2 — Test Accuracy [%] vs ReLU Budget, WideResNet-22-8 backbone",
        &all,
    )
}
