//! Figure 1: Accuracy vs ReLU budget for the ResNet18 backbone on all
//! three datasets — Ours (BCD) against SNL, SENet and DeepReDuce.
//!
//! Shape criterion: BCD Pareto-dominates, with the largest margins in the
//! low-budget regime.

use crate::bench::{setup, BenchCtx};
use crate::methods::registry::{self, Method};
use crate::metrics::{ascii_plot, print_table, write_csv, Series};
use crate::pipeline::Pipeline;
use anyhow::Result;

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    let datasets: Vec<&str> = if cx.full {
        vec!["synth10", "synth100", "synthtiny"]
    } else {
        vec!["synth10", "synth100"]
    };
    // Paper Fig. 1 sweeps the low-to-mid budget range.
    let paper_budgets: &[f64] = &[50e3, 120e3, 240e3];
    let quick_n = 2;

    let mut csv = Vec::new();
    let mut rows = Vec::new();
    for dataset in datasets {
        let exp = setup::experiment(dataset, "resnet", false);
        let pl = Pipeline::new(engine, exp)?;
        let total = pl.sess.info().total_relus();
        let size = pl.sess.info().image_size;
        let budgets: Vec<usize> = setup::grid(paper_budgets, quick_n)
            .iter()
            .map(|&b| setup::scale_budget(b, total, "resnet", size))
            .collect();

        let baseline = pl.baseline()?;
        let base_acc = pl.test_acc(&baseline)?;
        let mut series: Vec<Series> = ["ours", "snl", "senet", "deepreduce"]
            .iter()
            .map(|m| Series::new(m, vec![]))
            .collect();
        for &budget in &budgets {
            // SNL direct + BCD from the SNL reference (shared zoo).
            let bref = setup::bref_for(&pl.exp, total, budget);
            let snl_acc = pl.test_acc(&pl.snl_ref(budget)?)?;
            let ours = pl.bcd_cached(&pl.snl_ref(bref)?, budget)?;
            let ours_acc = pl.test_acc(&ours)?;
            // SENet + DeepReDuce start from the trained baseline, through
            // the method registry (configs ride pl.exp — DESIGN.md §10).
            let mut st_se = baseline.clone();
            registry::find("senet")?.run(&pl.ctx(), &mut st_se, budget)?;
            let senet_acc = pl.test_acc(&st_se)?;
            let mut st_dr = baseline.clone();
            registry::find("deepreduce")?.run(&pl.ctx(), &mut st_dr, budget)?;
            let dr_acc = pl.test_acc(&st_dr)?;

            println!(
                "[{dataset}] b={budget}: ours {ours_acc:.2} snl {snl_acc:.2} senet {senet_acc:.2} deepreduce {dr_acc:.2}"
            );
            let case = format!("{dataset}/b{budget}");
            cx.stat(&case, "ours_acc", ours_acc, "%");
            cx.stat(&case, "snl_acc", snl_acc, "%");
            cx.stat(&case, "senet_acc", senet_acc, "%");
            cx.stat(&case, "deepreduce_acc", dr_acc, "%");
            for (s, acc) in series.iter_mut().zip([ours_acc, snl_acc, senet_acc, dr_acc]) {
                s.points.push((budget as f64, acc));
            }
            rows.push(vec![
                dataset.to_string(),
                budget.to_string(),
                format!("{ours_acc:.2}"),
                format!("{snl_acc:.2}"),
                format!("{senet_acc:.2}"),
                format!("{dr_acc:.2}"),
                format!("{base_acc:.2}"),
            ]);
            csv.push(vec![
                dataset.to_string(),
                budget.to_string(),
                format!("{ours_acc:.3}"),
                format!("{snl_acc:.3}"),
                format!("{senet_acc:.3}"),
                format!("{dr_acc:.3}"),
                format!("{base_acc:.3}"),
            ]);
        }
        println!(
            "\n{}",
            ascii_plot(
                &format!("Fig. 1 ({dataset}) — Accuracy [%] vs ReLU budget"),
                &series,
                60,
                14
            )
        );
    }
    print_table(
        "Figure 1 — Accuracy [%] vs ReLU Budget (ResNet18)",
        &["dataset", "budget", "ours", "snl", "senet", "deepreduce", "baseline"],
        &rows,
    );
    write_csv(
        &setup::results_csv("fig1"),
        &["dataset", "budget", "ours", "snl", "senet", "deepreduce", "baseline"],
        &csv,
    )?;
    Ok(())
}
