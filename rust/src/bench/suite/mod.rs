//! Benchmark implementations, one module per registry entry.
//!
//! These are the bodies of the old `benches/bench_*.rs` binaries, moved
//! into the library so the registry (`cdnl bench run`) and the thin cargo
//! bench wrappers share one implementation. Each module exposes
//! `pub fn run(&mut BenchCtx) -> Result<()>`: it prints the same tables /
//! ASCII figures as before, writes the same `results/<id>.csv`, and
//! additionally records typed metrics into the context's
//! [`crate::bench::report::BenchReport`].

pub mod ablations;
pub mod fig1;
pub mod fig10;
pub mod fig11;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod perf;
pub mod perf_conv_lowered;
pub mod perf_dist;
pub mod serve;
pub mod smoke;
pub mod table1;
pub mod table2;
pub mod table3;
