//! §Perf: GEMM-lowered convolution vs the retained direct loops
//! (DESIGN.md §13).
//!
//! Measures (and records in the `perf_conv_lowered` report):
//!   - the raw forward conv kernel, direct 7-deep loop vs im2col+GEMM
//!     lowering (the ≥5x claim in README's Perf table rides here);
//!   - full trial scans at DRC ∈ {1, 8, 64} on both conv families
//!     (`resnet18_16x16_c10`, `wrn22_16x16_c10`) under three routes:
//!     direct kernels, lowered kernels, and lowered + slab-wide patch
//!     reuse (`bcd.trial_batch` + prefix cache);
//!   - a bit-identity grid: lowered-kernel scans across
//!     `trial_batch ∈ {1, 32}` x `workers ∈ {1, 4}` against the
//!     direct-kernel reference outcome.
//!
//! Every scan outcome is `ensure!`d bit-identical across routes — the
//! lowering is a pure reordering-free replay of the direct loops, so only
//! wall-clock may differ. Timings and speedups are advisory (`time_ms` /
//! `rate` metrics plus `results/perf_conv_lowered*.csv`); the gate never
//! fails on them across hosts.

use crate::bench::{setup, BenchCtx};
use crate::coordinator::eval::{EvalOpts, Evaluator};
use crate::coordinator::trials::{scan_trials, BlockSampler};
use crate::data::synth;
use crate::metrics::write_csv;
use crate::runtime::kernels::conv2d_same_into;
use crate::runtime::lowering;
use crate::runtime::session::Session;
use crate::runtime::Backend;
use crate::util::bench::{print_results, summarize, time};
use crate::util::prng::Rng;
use anyhow::{ensure, Result};

const MODELS: [&str; 2] = ["resnet18_16x16_c10", "wrn22_16x16_c10"];

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    let (train_ds, _) = synth::generate(synth::by_name("synth10").unwrap());
    let (iters, warmup) = if cx.full { (20, 4) } else { (6, 1) };
    let rt = if cx.full { 24 } else { 8 };
    let mut results = Vec::new();

    // --- raw forward kernel: direct loop vs im2col+GEMM ----------------------
    // One representative mid-network shape (16ch 16x16, k=3, model batch);
    // the scan sections below cover the full per-model layer mix.
    let bsz = engine.manifest().batch;
    let (cin, h, wd, cout, k) = (16usize, 16usize, 16usize, 16usize, 3usize);
    let mut rng = Rng::new(0x70E5);
    let kx: Vec<f32> = (0..bsz * cin * h * wd).map(|_| rng.normal()).collect();
    let kw: Vec<f32> = (0..cout * cin * k * k).map(|_| rng.normal()).collect();
    let mut kout = Vec::new();
    lowering::set_conv_direct(true);
    let r = time(
        &format!("conv fwd [{bsz}x{cin}x{h}x{wd} k{k}] direct"),
        warmup,
        iters,
        || conv2d_same_into(&kx, &kw, bsz, cin, h, wd, cout, k, 1, &mut kout),
    );
    let direct_kernel_ms = r.p50_ms;
    cx.time_ms("kernel", "fwd_direct", &r.samples_ms);
    results.push(r);
    lowering::set_conv_direct(false);
    let mut kref = Vec::new();
    conv2d_same_into(&kx, &kw, bsz, cin, h, wd, cout, k, 1, &mut kref);
    lowering::set_conv_direct(true);
    let mut kdir = Vec::new();
    conv2d_same_into(&kx, &kw, bsz, cin, h, wd, cout, k, 1, &mut kdir);
    lowering::set_conv_direct(false);
    ensure!(kref == kdir, "lowered forward kernel diverged bitwise from direct");
    let r = time(
        &format!("conv fwd [{bsz}x{cin}x{h}x{wd} k{k}] lowered"),
        warmup,
        iters,
        || conv2d_same_into(&kx, &kw, bsz, cin, h, wd, cout, k, 1, &mut kout),
    );
    let lowered_kernel_ms = r.p50_ms;
    cx.time_ms("kernel", "fwd_lowered", &r.samples_ms);
    results.push(r);
    let kernel_speedup = direct_kernel_ms / lowered_kernel_ms.max(1e-9);
    cx.rate("kernel", "fwd_speedup", kernel_speedup, "x");
    println!(
        "conv forward kernel: direct {direct_kernel_ms:.2} ms, lowered \
         {lowered_kernel_ms:.2} ms => {kernel_speedup:.2}x"
    );
    write_csv(
        &setup::results_csv("perf_conv_lowered_kernel"),
        &["n", "cin", "h", "w", "cout", "k", "direct_ms", "lowered_ms", "speedup"],
        &[vec![
            bsz.to_string(),
            cin.to_string(),
            h.to_string(),
            wd.to_string(),
            cout.to_string(),
            k.to_string(),
            format!("{direct_kernel_ms:.3}"),
            format!("{lowered_kernel_ms:.3}"),
            format!("{kernel_speedup:.2}"),
        ]],
    )?;

    // --- trial scans: direct vs lowered vs slab-reused, DRC sweep ------------
    let mut scan_rows = Vec::new();
    for model in MODELS {
        let sess = Session::new(engine, model)?;
        let st = sess.init_state(1)?;
        let info = sess.info().clone();
        let sampler = BlockSampler::new(crate::config::Granularity::Pixel, sess.info());
        let ev = Evaluator::new(&sess, &train_ds, 2)?;
        let params = ev.upload_params(&st.params)?;
        let base = ev.accuracy(&params, st.mask.dense())?;
        let ev_slab = Evaluator::with_opts(
            &sess,
            &train_ds,
            2,
            EvalOpts {
                cache_bytes: 64 << 20,
                trial_batch: 16,
                verify_staged: false,
                verify_lowering: false,
            },
        )?;
        for &d in &[1usize, 8, 64] {
            let d = d.min(info.mask_size / 4); // tiny models: keep pools sane
            lowering::set_conv_direct(true);
            let mut rng = Rng::new(33);
            let t0 = std::time::Instant::now();
            let direct_out =
                scan_trials(&ev, &params, &st.mask, &sampler, d, rt, -1e9, base, &mut rng, 1)?;
            let direct_ms = 1000.0 * t0.elapsed().as_secs_f64();
            lowering::set_conv_direct(false);
            let mut rng = Rng::new(33);
            let t0 = std::time::Instant::now();
            let lowered_out =
                scan_trials(&ev, &params, &st.mask, &sampler, d, rt, -1e9, base, &mut rng, 1)?;
            let lowered_ms = 1000.0 * t0.elapsed().as_secs_f64();
            let mut rng = Rng::new(33);
            let t0 = std::time::Instant::now();
            let slab_out = scan_trials(
                &ev_slab, &params, &st.mask, &sampler, d, rt, -1e9, base, &mut rng, 1,
            )?;
            let slab_ms = 1000.0 * t0.elapsed().as_secs_f64();
            ensure!(
                direct_out == lowered_out && direct_out == slab_out,
                "conv scan outcome diverged across kernel routes ({model}, DRC={d})"
            );
            let x_lowered = direct_ms / lowered_ms.max(1e-9);
            let x_slab = direct_ms / slab_ms.max(1e-9);
            println!(
                "{model} DRC={d}: direct {direct_ms:.1} ms, lowered {lowered_ms:.1} ms \
                 ({x_lowered:.2}x), slab-reused {slab_ms:.1} ms ({x_slab:.2}x)"
            );
            results.push(summarize(
                &format!("{model} scan x{rt} DRC={d}, direct"),
                vec![direct_ms],
            ));
            results.push(summarize(
                &format!("{model} scan x{rt} DRC={d}, lowered"),
                vec![lowered_ms],
            ));
            results.push(summarize(
                &format!("{model} scan x{rt} DRC={d}, slab-reused"),
                vec![slab_ms],
            ));
            cx.time_ms(model, &format!("scan_direct_drc{d}"), &[direct_ms]);
            cx.time_ms(model, &format!("scan_lowered_drc{d}"), &[lowered_ms]);
            cx.time_ms(model, &format!("scan_slab_drc{d}"), &[slab_ms]);
            cx.rate(model, &format!("speedup_lowered_drc{d}"), x_lowered, "x");
            cx.rate(model, &format!("speedup_slab_drc{d}"), x_slab, "x");
            scan_rows.push(vec![
                model.to_string(),
                d.to_string(),
                format!("{direct_ms:.2}"),
                format!("{lowered_ms:.2}"),
                format!("{slab_ms:.2}"),
                format!("{x_lowered:.2}"),
                format!("{x_slab:.2}"),
            ]);
        }

        // --- bit-identity grid: trial_batch x workers vs direct kernels ------
        // One reference outcome from the direct loops, then every
        // (trial_batch, workers) combination of the lowered route must
        // reproduce it bit for bit (DESIGN.md §8 replay merge + §13).
        let grid_drc = 8.min(info.mask_size / 4);
        lowering::set_conv_direct(true);
        let mut rng = Rng::new(55);
        let reference = scan_trials(
            &ev, &params, &st.mask, &sampler, grid_drc, rt, -1e9, base, &mut rng, 1,
        )?;
        lowering::set_conv_direct(false);
        let mut checked = 0usize;
        for &tb in &[1usize, 32] {
            let ev_g = Evaluator::with_opts(
                &sess,
                &train_ds,
                2,
                EvalOpts {
                    cache_bytes: 64 << 20,
                    trial_batch: tb,
                    verify_staged: false,
                    verify_lowering: false,
                },
            )?;
            for &w in &[1usize, 4] {
                let mut rng = Rng::new(55);
                let out = scan_trials(
                    &ev_g, &params, &st.mask, &sampler, grid_drc, rt, -1e9, base, &mut rng, w,
                )?;
                ensure!(
                    out == reference,
                    "lowered scan (trial_batch={tb}, workers={w}) diverged from the \
                     direct-kernel reference on {model}"
                );
                checked += 1;
            }
        }
        cx.count(model, "grid_outcomes_checked", checked, "scans");
        println!("{model}: {checked} lowered trial_batch x workers scans == direct reference");
    }
    write_csv(
        &setup::results_csv("perf_conv_lowered"),
        &["model", "drc", "direct_ms", "lowered_ms", "slab_ms", "x_lowered", "x_slab"],
        &scan_rows,
    )?;

    print_results("§Perf — GEMM-lowered convolution", &results);
    println!("\n{}", engine.stats_table());
    Ok(())
}
