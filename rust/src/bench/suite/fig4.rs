//! Figure 4: Ours on top of AutoReP (CIFAR-100 analog), ResNet18 and
//! WideResNet-22-8 poly variants.
//!
//! Shape criterion: BCD run from an AutoReP reference reaches AutoReP's
//! accuracy with roughly half the ReLU budget.

use crate::bench::{setup, BenchCtx};
use crate::metrics::{ascii_plot, print_table, write_csv, Series};
use crate::pipeline::Pipeline;
use anyhow::Result;

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    let backbones: Vec<&str> = if cx.full {
        vec!["resnet", "wrn"]
    } else {
        vec!["resnet"]
    };
    let paper_budgets: &[f64] = &[50e3, 100e3, 150e3];
    let quick_n = 2;

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for backbone in backbones {
        let exp = setup::experiment("synth100", backbone, true);
        let pl = Pipeline::new(engine, exp)?;
        let total = pl.sess.info().total_relus();
        let size = pl.sess.info().image_size;
        let budgets: Vec<usize> = setup::grid(paper_budgets, quick_n)
            .iter()
            .map(|&b| setup::scale_budget(b, total, backbone, size))
            .collect();

        let mut s_arp = Series::new("autorep", vec![]);
        let mut s_ours = Series::new("ours on autorep", vec![]);
        for &budget in &budgets {
            let bref = setup::bref_for(&pl.exp, total, budget);
            // AutoReP straight to the target...
            let arp = pl.autorep_ref(budget)?;
            let arp_acc = pl.test_acc(&arp)?;
            // ...vs BCD from the AutoReP reference at B_ref.
            let ours = pl.bcd_cached(&pl.autorep_ref(bref)?, budget)?;
            let ours_acc = pl.test_acc(&ours)?;
            println!("[{backbone}] b={budget}: autorep {arp_acc:.2}%  ours {ours_acc:.2}%");
            let case = format!("{backbone}/b{budget}");
            cx.stat(&case, "autorep_acc", arp_acc, "%");
            cx.stat(&case, "ours_acc", ours_acc, "%");
            s_arp.points.push((budget as f64, arp_acc));
            s_ours.points.push((budget as f64, ours_acc));
            rows.push(vec![
                backbone.to_string(),
                budget.to_string(),
                format!("{arp_acc:.2}"),
                format!("{ours_acc:.2}"),
                format!("{:+.2}", ours_acc - arp_acc),
            ]);
            csv.push(vec![
                backbone.to_string(),
                budget.to_string(),
                bref.to_string(),
                format!("{arp_acc:.3}"),
                format!("{ours_acc:.3}"),
            ]);
        }
        println!(
            "\n{}",
            ascii_plot(
                &format!("Fig. 4 ({backbone}, synth100) — Accuracy vs budget"),
                &[s_ours.clone(), s_arp.clone()],
                60,
                12
            )
        );
        // Half-budget criterion: ours at the LOWEST budget vs autorep at ~2x.
        if s_ours.points.len() >= 2 {
            let (b_low, ours_low) = s_ours.points[0];
            let arp_best = s_arp
                .points
                .iter()
                .filter(|(b, _)| *b >= 2.0 * b_low)
                .map(|&(_, a)| a)
                .fold(f64::NEG_INFINITY, f64::max);
            if arp_best.is_finite() {
                println!(
                    "[{backbone}] half-budget check: ours@{b_low} = {ours_low:.2}% vs autorep@>=2x = {arp_best:.2}% {}",
                    if ours_low >= arp_best - 1.0 { "(holds)" } else { "(gap)" }
                );
            }
        }
    }
    print_table(
        "Figure 4 — Ours on top of AutoReP (synth100)",
        &["backbone", "budget", "autorep", "ours", "gap"],
        &rows,
    );
    write_csv(
        &setup::results_csv("fig4"),
        &["backbone", "budget", "bref", "autorep_acc", "ours_acc"],
        &csv,
    )?;
    Ok(())
}
