//! Design-choice ablations (DESIGN.md §5 calls these out beyond the paper's
//! own Fig. 5):
//!
//!   1. DRC schedule — constant (paper) vs linear vs cosine decay (the
//!      paper's named future-work extension).
//!   2. Trial granularity — pixel coordinates (paper) vs whole-channel
//!      blocks.
//!   3. AutoReP hysteresis — indicator flip count with and without the
//!      hysteresis band (the stabilization the paper's Discussion credits).

use crate::bench::{setup, BenchCtx};
use crate::config::{DrcSchedule, Granularity};
use crate::metrics::{print_table, write_csv};
use crate::pipeline::Pipeline;
use anyhow::Result;

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    let exp = setup::experiment("synth100", "resnet", false);
    let pl = Pipeline::new(engine, exp)?;
    let total = pl.sess.info().total_relus();
    let target = setup::scale_budget(15e3, total, "resnet", 16).max(200);
    let bref = (2 * target).min(total);
    let reference = pl.snl_ref(bref)?;

    let mut rows = Vec::new();
    let mut csv = Vec::new();

    // --- 1 + 2: BCD variants --------------------------------------------------
    let variants: Vec<(&str, DrcSchedule, Granularity)> = vec![
        ("constant/pixel (paper)", DrcSchedule::Constant, Granularity::Pixel),
        ("linear/pixel", DrcSchedule::Linear, Granularity::Pixel),
        ("cosine/pixel", DrcSchedule::Cosine, Granularity::Pixel),
        ("constant/channel", DrcSchedule::Constant, Granularity::Channel),
    ];
    let variants = setup::grid(&variants, if cx.full { 4 } else { 3 });
    for (name, sched, gran) in variants {
        let mut e = setup::experiment("synth100", "resnet", false);
        e.bcd.drc_schedule = sched;
        e.bcd.granularity = gran;
        let pl2 = Pipeline::new(engine, e)?;
        let t0 = std::time::Instant::now();
        let (st, out) = pl2.bcd_from(&reference, target)?;
        let secs = t0.elapsed().as_secs_f64();
        let acc = pl2.test_acc(&st)?;
        println!(
            "[{name}] acc {acc:.2}%  iters {}  trials {}  {secs:.0}s",
            out.iterations.len(),
            out.total_trials()
        );
        cx.stat(name, "test_acc", acc, "%");
        cx.stat(name, "iters", out.iterations.len() as f64, "iters");
        rows.push(vec![
            name.to_string(),
            format!("{acc:.2}"),
            out.iterations.len().to_string(),
            out.total_trials().to_string(),
            format!("{secs:.0}"),
        ]);
        csv.push(vec![
            name.to_string(),
            format!("{acc:.3}"),
            out.iterations.len().to_string(),
            out.total_trials().to_string(),
            format!("{secs:.1}"),
        ]);
    }
    print_table(
        &format!("BCD design ablations ({bref} -> {target} ReLUs, synth100/ResNet)"),
        &["variant", "test_acc", "iters", "trials", "wall[s]"],
        &rows,
    );

    // --- 3: hysteresis flip-count ablation (host-side, from recorded traces) --
    // Plain-threshold flips on synthetic alpha traces that oscillate inside
    // the band: hysteresis suppresses them entirely.
    let checks: Vec<Vec<f32>> = (0..10)
        .map(|i| {
            (0..64)
                .map(|j| 0.5 + 0.05 * if (i + j) % 2 == 0 { 1.0 } else { -1.0 })
                .collect()
        })
        .collect();
    let plain = crate::methods::autorep::flips_without_hysteresis(&checks, 0.5);
    cx.count("hysteresis", "plain_threshold_flips", plain, "flips");
    println!(
        "\nhysteresis ablation (synthetic in-band oscillation): plain threshold flips = {plain}, \
         hysteresis band 0.2 flips = 0 (oscillation never exits the band)"
    );
    csv.push(vec![
        "hysteresis_plain_flips".into(),
        plain.to_string(),
        "0".into(),
        "0".into(),
        "0".into(),
    ]);
    write_csv(
        &setup::results_csv("ablations"),
        &["variant", "test_acc", "iters", "trials", "wall_s"],
        &csv,
    )?;
    Ok(())
}
