//! Table 1: overall number of ReLUs per (network, image-size).
//!
//! Shape criterion (DESIGN.md §5): counts grow with backbone width and
//! ~(image size)^2, mirroring the paper's 570K/1359K/1966K/5439K table.

use crate::bench::{setup, BenchCtx};
use crate::metrics::{print_table, write_csv};
use crate::runtime::Backend;
use crate::util::fmt_relu_count;
use anyhow::{ensure, Result};

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for (key, m) in &engine.manifest().models {
        if m.poly {
            continue; // the paper's table counts the identity-replacement nets
        }
        let paper = setup::paper_total(&m.backbone, m.image_size);
        cx.count(key, "relus_ours", m.mask_size, "relus");
        cx.count(key, "relus_paper", paper as usize, "relus");
        rows.push(vec![
            key.clone(),
            format!("{}x{}", m.image_size, m.image_size),
            fmt_relu_count(m.mask_size),
            fmt_relu_count(paper as usize),
            format!("{:.1}x", paper / m.mask_size as f64),
        ]);
        csv.push(vec![
            key.clone(),
            m.backbone.clone(),
            m.image_size.to_string(),
            m.mask_size.to_string(),
            (paper as usize).to_string(),
        ]);
    }
    print_table(
        "Table 1 — Overall Number of ReLUs (ours vs paper, scaled backbones)",
        &["model", "input", "ours", "paper", "scale"],
        &rows,
    );
    write_csv(
        &setup::results_csv("table1"),
        &["model", "backbone", "image_size", "relus_ours", "relus_paper"],
        &csv,
    )?;

    // Shape criteria (ensure!, not assert!: a violation is a bench failure
    // reported through the CLI, not a process abort). `engine.model` (not
    // raw manifest indexing) so the deprecated `resnet_*`/`wrn_*` aliases
    // keep resolving to the renamed MLP stand-ins.
    let g = |k: &str| -> Result<f64> { Ok(engine.model(k)?.mask_size as f64) };
    ensure!(g("wrn_16x16_c10")? > g("resnet_16x16_c10")?, "wider net must have more ReLUs");
    let r_ratio = g("resnet_32x32_c20")? / g("resnet_16x16_c20")?;
    let w_ratio = g("wrn_32x32_c20")? / g("wrn_16x16_c20")?;
    ensure!((3.0..=4.1).contains(&r_ratio), "mlp image-size scaling {r_ratio}");
    ensure!((3.0..=4.1).contains(&w_ratio), "mlpw image-size scaling {w_ratio}");
    cx.stat("scaling", "resnet_size_ratio", r_ratio, "x");
    cx.stat("scaling", "wrn_size_ratio", w_ratio, "x");
    // The conv topologies mask per *channel* (DESIGN.md §12), so their ReLU
    // pool is image-size invariant — the opposite shape from the pixel-pool
    // stand-ins, pinned here so the distinction can't silently regress.
    let c_ratio = g("resnet18_32x32_c20")? / g("resnet18_16x16_c20")?;
    ensure!(c_ratio == 1.0, "per-channel conv pool must not scale with image size: {c_ratio}");
    println!("\nshape criteria OK: width ↑, image-size scaling {r_ratio:.2}x / {w_ratio:.2}x (paper: 3.4x-4.0x)");
    Ok(())
}
