//! Figure 3: Ours vs SENet on the ResNet18 backbone, in the paper's
//! baseline-agnostic metric: accuracy-at-budget / baseline accuracy.
//! Figure 8 (supplementary) reruns the same harness on the wide backbone
//! via [`run_with`].
//!
//! Shape criterion: Ours reaches the Pareto frontier on the CIFAR-100 and
//! TinyImageNet analogs, stays competitive on the CIFAR-10 analog.

use crate::bench::{setup, BenchCtx};
use crate::methods::registry::{self, Method};
use crate::metrics::{ascii_plot, print_table, write_csv, Series};
use crate::pipeline::Pipeline;
use anyhow::Result;

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    run_with(cx, "resnet", "fig3")
}

pub fn run_with(cx: &mut BenchCtx, backbone: &str, id: &str) -> Result<()> {
    let engine = cx.engine;
    let datasets: Vec<&str> = if cx.full {
        vec!["synth10", "synth100", "synthtiny"]
    } else {
        vec!["synth100"]
    };
    let paper_budgets: &[f64] = &[50e3, 120e3, 180e3];
    let quick_n = 2;

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for dataset in datasets {
        let exp = setup::experiment(dataset, backbone, false);
        let pl = Pipeline::new(engine, exp)?;
        let total = pl.sess.info().total_relus();
        let size = pl.sess.info().image_size;
        let budgets: Vec<usize> = setup::grid(paper_budgets, quick_n)
            .iter()
            .map(|&b| setup::scale_budget(b, total, backbone, size))
            .collect();
        let baseline = pl.baseline()?;
        let base_acc = pl.test_acc(&baseline)?;

        let mut s_ours = Series::new("ours", vec![]);
        let mut s_senet = Series::new("senet", vec![]);
        for &budget in &budgets {
            let bref = setup::bref_for(&pl.exp, total, budget);
            let ours = pl.bcd_cached(&pl.snl_ref(bref)?, budget)?;
            let ours_rel = pl.test_acc(&ours)? / base_acc;
            let mut st_se = baseline.clone();
            registry::find("senet")?.run(&pl.ctx(), &mut st_se, budget)?;
            let senet_rel = pl.test_acc(&st_se)? / base_acc;
            println!("[{dataset}] b={budget}: ours {ours_rel:.3} senet {senet_rel:.3} (rel. to {base_acc:.2}%)");
            let case = format!("{dataset}/b{budget}");
            cx.stat(&case, "ours_rel", ours_rel, "x");
            cx.stat(&case, "senet_rel", senet_rel, "x");
            s_ours.points.push((budget as f64, ours_rel));
            s_senet.points.push((budget as f64, senet_rel));
            rows.push(vec![
                dataset.to_string(),
                budget.to_string(),
                format!("{ours_rel:.3}"),
                format!("{senet_rel:.3}"),
            ]);
            csv.push(vec![
                dataset.to_string(),
                budget.to_string(),
                format!("{ours_rel:.4}"),
                format!("{senet_rel:.4}"),
                format!("{base_acc:.3}"),
            ]);
        }
        println!(
            "\n{}",
            ascii_plot(
                &format!("{id} ({dataset}) — acc/baseline vs budget"),
                &[s_ours, s_senet],
                60,
                12
            )
        );
    }
    print_table(
        &format!("Figure {id} — relative accuracy (acc@budget / baseline acc)"),
        &["dataset", "budget", "ours", "senet"],
        &rows,
    );
    write_csv(
        &setup::results_csv(id),
        &["dataset", "budget", "ours_rel", "senet_rel", "baseline_acc"],
        &csv,
    )?;
    Ok(())
}
