//! Serve tier: the fleet-scale PI serving benchmark (DESIGN.md §14).
//!
//! Both conv families (`resnet18_16x16_c10`, `wrn22_16x16_c10`) are
//! served at three ReLU budgets — full, half, and an aggressive eighth —
//! under the LAN and WAN protocols, with a small fixed fleet (6 clients x
//! 3 requests) sized so the batch window and the prep lookahead both
//! bind. Per case the suite:
//!
//! - runs the simulator twice and `ensure!`s bit-identical reports (the
//!   determinism contract of [`crate::pi::serve`]);
//! - `ensure!`s per-direction byte and round conservation against
//!   [`crate::pi::trace::simulate`] scaled by completed inferences (the
//!   simulator replays the same message script per request);
//! - records the structural tallies (completions, ReLUs, active layers,
//!   rounds, per-direction bytes, GEMM jobs, garbled requests) as exact
//!   `count` metrics — the substance of the committed `BENCH_serve.json`
//!   baseline, all float-independent closed forms;
//! - records the timing-dependent tallies (GEMM batches actually run,
//!   events processed) and the latency percentiles / throughput as
//!   report-only trend metrics, deliberately absent from the committed
//!   baseline.
//!
//! The budgets use prefix removal (drop the shallowest ReLUs first) — the
//! qualitative shape BCD converges to (early layers linearize first,
//! paper Fig. 7) — so `active_layers` sweeps 17 -> 4 -> 1 (ResNet18) and
//! 13 -> 4 -> 1 (WRN-22) and the round count collapses with it.

use crate::bench::BenchCtx;
use crate::model::Mask;
use crate::pi::serve::{serve, ServeConfig};
use crate::pi::{simulate, LAN, WAN};
use crate::runtime::Backend;
use anyhow::{ensure, Result};

/// Fixed fleet shape — semantic for this bench, hardcoded (not read from
/// `pi.*` config) so the committed baseline cannot drift with config
/// defaults. 6 clients x 3 requests at 40 req/s each keeps the whole grid
/// sub-second while still exercising queueing, batching and prep-ahead.
const FLEET: ServeConfig = ServeConfig {
    clients: 6,
    requests: 3,
    arrival_rate: 40.0,
    batch_window: 4,
    prep_ahead: 2,
    seed: 0x5EED,
};

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    for key in ["resnet18_16x16_c10", "wrn22_16x16_c10"] {
        let info = engine.model(key)?;
        let total = info.mask_size;
        let mut rows = Vec::new();
        for budget in [total, total / 2, total / 8] {
            let mut mask = Mask::full(total);
            if budget < total {
                let doomed: Vec<usize> = (0..total - budget).collect();
                mask.apply_removal(&doomed)?;
            }
            for proto in [&LAN, &WAN] {
                let case = format!("{key}_b{budget}_{}", proto.name);
                let r = serve(info, &mask, proto, &FLEET)?;
                let r2 = serve(info, &mask, proto, &FLEET)?;
                ensure!(r == r2, "serve must be bit-deterministic ({case})");
                let tr = simulate(info, &mask, proto);
                ensure!(
                    r.up_bytes == tr.up_bytes() as usize * r.completed
                        && r.down_bytes == tr.down_bytes() as usize * r.completed
                        && r.online_rounds == tr.rounds * r.completed,
                    "serve totals diverged from the pi::trace script ({case})"
                );
                cx.count(&case, "completed", r.completed, "inf");
                cx.count(&case, "relus", r.relus, "relus");
                cx.count(&case, "active_layers", r.active_layers, "layers");
                cx.count(&case, "rounds_per_inf", r.rounds_per_inference, "rounds");
                cx.count(&case, "online_rounds", r.online_rounds, "rounds");
                cx.count(&case, "up_bytes", r.up_bytes, "bytes");
                cx.count(&case, "down_bytes", r.down_bytes, "bytes");
                cx.count(&case, "gemm_jobs", r.gemm_jobs, "jobs");
                cx.count(&case, "prep_completed", r.prep_completed, "inf");
                // Timing-dependent tallies + latency floats: recorded for
                // trend-watching, deliberately absent from the committed
                // baseline (the comparator lists them as informational).
                cx.count(&case, "gemm_batches", r.gemm_batches, "batches");
                cx.count(&case, "events", r.events, "events");
                cx.time_ms(&case, "p50", &[r.p50_ms]);
                cx.time_ms(&case, "p95", &[r.p95_ms]);
                cx.time_ms(&case, "p99", &[r.p99_ms]);
                cx.rate(&case, "throughput", r.throughput_rps, "inf/s");
                rows.push(vec![
                    budget.to_string(),
                    proto.name.to_string(),
                    r.active_layers.to_string(),
                    r.rounds_per_inference.to_string(),
                    format!("{:.2}", (r.up_bytes + r.down_bytes) as f64 / 1e6),
                    format!("{}/{}", r.gemm_batches, r.gemm_jobs),
                    format!("{:.1}", r.p50_ms),
                    format!("{:.1}", r.p95_ms),
                    format!("{:.1}", r.p99_ms),
                    format!("{:.2}", r.throughput_rps),
                ]);
            }
        }
        crate::metrics::print_table(
            &format!(
                "PI serving vs ReLU budget: {key}, {} clients x {} requests \
                 (window {}, prep-ahead {}, seed {})",
                FLEET.clients, FLEET.requests, FLEET.batch_window, FLEET.prep_ahead, FLEET.seed
            ),
            &[
                "budget", "proto", "layers", "rnd/inf", "comm[MB]", "batch/jobs", "p50[ms]",
                "p95[ms]", "p99[ms]", "inf/s",
            ],
            &rows,
        );
    }
    Ok(())
}
