//! Figure 11 (supplementary): trajectories of individual SNL α values
//! (the soft mask entries) against the λ schedule.
//!
//! Shape criteria: αs decay slowly toward the threshold; threshold
//! crossings correlate with λ←κ·λ update events.

use crate::bench::{setup, BenchCtx};
use crate::methods::snl::run_snl;
use crate::metrics::{ascii_plot, write_csv, Series};
use crate::pipeline::Pipeline;
use anyhow::Result;

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    let exp = setup::experiment("synth100", "resnet", false);
    let pl = Pipeline::new(engine, exp)?;
    let total = pl.sess.info().total_relus();
    let target = setup::scale_budget(15e3, total, "resnet", 16);

    let mut st = pl.baseline()?;
    let mut cfg = pl.exp.snl.clone();
    cfg.steps_per_check = 2;
    let tracked = 8;
    let out = run_snl(&pl.sess, &mut st, &pl.train_ds, target, &cfg, tracked)?;

    let series: Vec<Series> = out
        .alpha_traces
        .iter()
        .enumerate()
        .map(|(k, tr)| {
            Series::new(
                &format!("alpha[{}]", out.alpha_indices[k]),
                tr.iter()
                    .enumerate()
                    .map(|(i, &a)| ((i * cfg.steps_per_check) as f64, a as f64))
                    .collect(),
            )
        })
        .collect();
    println!(
        "\n{}",
        ascii_plot(
            &format!(
                "Fig. 11 — {} tracked alphas over SNL steps (κ updates at {:?})",
                tracked, out.kappa_updates
            ),
            &series,
            64,
            14
        )
    );

    let mut rows = Vec::new();
    for (ci, _) in out.budget_trace.iter().enumerate() {
        let mut row = vec![(ci * cfg.steps_per_check).to_string()];
        for tr in &out.alpha_traces {
            row.push(format!("{:.4}", tr[ci]));
        }
        rows.push(row);
    }
    let header: Vec<String> = std::iter::once("step".to_string())
        .chain(out.alpha_indices.iter().map(|i| format!("alpha_{i}")))
        .collect();
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    write_csv(&setup::results_csv("fig11"), &header_refs, &rows)?;

    // Shape: alphas decay on average; some hover near the 0.5 threshold.
    let mut decayed = 0;
    let mut hovered = 0;
    for tr in &out.alpha_traces {
        if tr.last().unwrap_or(&1.0) < tr.first().unwrap_or(&1.0) {
            decayed += 1;
        }
        if tr.iter().any(|&a| (a - cfg.threshold).abs() < 0.15) {
            hovered += 1;
        }
    }
    cx.count("alphas", "tracked", out.alpha_traces.len(), "alphas");
    cx.stat("alphas", "decayed", decayed as f64, "alphas");
    cx.stat("alphas", "hovered_near_threshold", hovered as f64, "alphas");
    println!(
        "\nshape: {decayed}/{} alphas decayed, {hovered}/{} passed near the threshold, {} κ updates",
        out.alpha_traces.len(),
        out.alpha_traces.len(),
        out.kappa_updates.len()
    );
    Ok(())
}
