//! Figure 6: IoU of binarized ReLU masks along an SNL optimization path —
//! the mask-dynamics evidence motivating BCD's never-revisit design.
//!
//! (a) IoU of consecutive snapshots; (b) IoU over all snapshot pairs
//! (B1 < B2). Shape criterion: consistently high IoU (paper: > 0.85),
//! i.e. masks mostly shrink rather than churn.

use crate::bench::{setup, BenchCtx};
use crate::methods::snl::{consecutive_iou, run_snl};
use crate::metrics::{ascii_plot, print_table, write_csv, Series};
use crate::pipeline::Pipeline;
use anyhow::Result;

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    let exp = setup::experiment("synth100", "resnet", false);
    let pl = Pipeline::new(engine, exp)?;
    let total = pl.sess.info().total_relus();
    let target = setup::scale_budget(30e3, total, "resnet", 16);

    // One SNL path from the trained baseline down to the 30K-analog,
    // recording a mask snapshot at every schedule check.
    let mut st = pl.baseline()?;
    let mut snl_cfg = pl.exp.snl.clone();
    snl_cfg.steps_per_check = 2;
    let out = run_snl(&pl.sess, &mut st, &pl.train_ds, target, &snl_cfg, 0)?;
    println!("snl path: {} steps, {} snapshots", out.steps_run, out.snapshots.len());

    // (a) consecutive-pair IoU over the path.
    let cons = consecutive_iou(&out.snapshots);
    let s_cons = Series::new(
        "consecutive IoU",
        cons.iter().enumerate().map(|(i, &v)| (i as f64, v)).collect(),
    );
    println!("\n{}", ascii_plot("Fig. 6a — consecutive mask IoU over SNL checks", &[s_cons], 60, 10));

    // (b) all pairs (B1 < B2): containment of the smaller-budget mask in the
    // larger-budget one.
    let mut pair_rows = Vec::new();
    let mut min_iou: f64 = 1.0;
    let mut below_085 = 0usize;
    let mut n_pairs = 0usize;
    for i in 0..out.snapshots.len() {
        for j in (i + 1)..out.snapshots.len() {
            let (b2, ref m2) = out.snapshots[i]; // earlier => larger budget
            let (b1, ref m1) = out.snapshots[j];
            if b1 >= b2 {
                continue;
            }
            let iou = m1.containment(m2);
            min_iou = min_iou.min(iou);
            below_085 += (iou < 0.85) as usize;
            n_pairs += 1;
            pair_rows.push(vec![
                b1.to_string(),
                b2.to_string(),
                format!("{iou:.4}"),
            ]);
        }
    }
    write_csv(&setup::results_csv("fig6"), &["b1", "b2", "iou"], &pair_rows)?;
    cx.stat("iou", "min_pairwise", min_iou, "iou");
    cx.stat("iou", "pairs_below_085", below_085 as f64, "pairs");

    let show = pair_rows.iter().take(10).cloned().collect::<Vec<_>>();
    print_table("Figure 6b — pairwise mask IoU (first rows)", &["B1", "B2", "IoU"], &show);
    println!(
        "\npairs: {n_pairs}, min IoU {min_iou:.3}, below 0.85: {below_085} \
         (paper: all pairs above 0.85 => a shrinking 'golden set' of ReLUs)"
    );
    Ok(())
}
