//! §Perf: the distributed trial scan over loopback HTTP (DESIGN.md §15).
//!
//! One scan, four substrates: the local in-process path ([`scan_trials`])
//! and the [`crate::dist`] coordinator with 1, 2 and 4 loopback workers.
//! Every distributed outcome is `ensure!`d bit-identical to the local
//! reference — membership only moves wall-clock, never the result — and
//! timings/rates land in `results/perf_dist.csv` plus advisory `time_ms` /
//! `rate` metrics (lease counters are timing-dependent, so they are never
//! gated here; the `smoke` bench pins them on a deterministic schedule).

use crate::bench::{setup, BenchCtx};
use crate::cas::CasStore;
use crate::coordinator::bcd::ScanArgs;
use crate::coordinator::eval::{EvalOpts, Evaluator};
use crate::coordinator::trials::{scan_trials, BlockSampler};
use crate::data::synth;
use crate::dist::{dist_scanner, run_worker, HelloDoc, ScanServer, WorkerOpts};
use crate::metrics::write_csv;
use crate::runtime::session::Session;
use crate::util::prng::Rng;
use anyhow::{ensure, Result};

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    let mut exp = setup::experiment("synth10", "resnet", false);
    let rt = if cx.full { 24 } else { 8 };
    exp.apply("bcd.rt", &rt.to_string()).map_err(anyhow::Error::msg)?;
    let drc = if cx.full { 24usize } else { 8 };
    let (train_ds, _) = synth::generate(synth::by_name(&exp.dataset).unwrap());
    let sess = Session::new(engine, &exp.model_key())?;
    let st = sess.init_state(1)?;
    let sampler = BlockSampler::new(exp.bcd.granularity, sess.info());
    // Built exactly as a remote worker builds its evaluator from the hello
    // config (`run_worker`), so worker-produced scores are comparable.
    let ev = Evaluator::with_opts(
        &sess,
        &train_ds,
        exp.bcd.proxy_batches,
        EvalOpts {
            cache_bytes: exp.bcd.cache_mb.saturating_mul(1 << 20),
            trial_batch: exp.bcd.trial_batch,
            verify_staged: exp.bcd.verify_staged,
            verify_lowering: exp.bcd.verify_lowering,
        },
    )?;
    let params = ev.upload_params(&st.params)?;
    let base = ev.accuracy(&params, st.mask.dense())?;

    // Local reference: same seed, same knobs, in-process threads.
    let mut rng = Rng::new(7);
    let t0 = std::time::Instant::now();
    let local = scan_trials(
        &ev, &params, &st.mask, &sampler, drc, exp.bcd.rt, exp.bcd.adt, base, &mut rng, 1,
    )?;
    let local_ms = 1e3 * t0.elapsed().as_secs_f64();
    cx.time_ms("local", "scan_local", &[local_ms]);
    println!(
        "local scan: {} evaluated / {} bounded in {local_ms:.1} ms",
        local.evaluated, local.bounded
    );

    let mut rows = Vec::new();
    let mut checked = 0usize;
    for &w in &[1usize, 2, 4] {
        let cas_dir = std::env::temp_dir()
            .join(format!("cdnl_perf_dist_{}_{w}", std::process::id()));
        let _ = std::fs::remove_dir_all(&cas_dir);
        let srv = ScanServer::start(
            "127.0.0.1:0",
            &HelloDoc::for_experiment(&exp, engine.name()),
            CasStore::open(&cas_dir),
        )?;
        let addr = srv.addr().to_string();
        let (out, dist_ms) = std::thread::scope(|s| {
            let handles: Vec<_> = (0..w)
                .map(|i| {
                    let addr = addr.clone();
                    s.spawn(move || {
                        run_worker(
                            &addr,
                            engine,
                            &WorkerOpts {
                                id: format!("bench-w{i}"),
                                poll_ms: 5,
                                ..WorkerOpts::default()
                            },
                        )
                    })
                })
                .collect();
            let mut scan = dist_scanner(&srv, &exp.bcd, 10_000);
            let args = ScanArgs {
                ev: &ev,
                params: &params,
                params_host: &st.params,
                mask: &st.mask,
                sampler: &sampler,
                drc,
                base_acc: base,
                sweep: 1,
            };
            let mut rng = Rng::new(7);
            let t0 = std::time::Instant::now();
            let out = scan(&args, &mut rng);
            let dist_ms = 1e3 * t0.elapsed().as_secs_f64();
            srv.shutdown();
            for h in handles {
                if let Err(e) = h.join().expect("worker thread panicked") {
                    eprintln!("perf_dist: worker exited with error: {e:#}");
                }
            }
            (out, dist_ms)
        });
        let out = out?;
        ensure!(
            out == local,
            "distributed scan with {w} worker(s) diverged from the local outcome"
        );
        checked += 1;
        let x = local_ms / dist_ms.max(1e-9);
        cx.time_ms("dist", &format!("scan_{w}w"), &[dist_ms]);
        cx.rate("dist", &format!("vs_local_{w}w"), x, "x");
        println!("dist scan, {w} worker(s): {dist_ms:.1} ms ({x:.2}x of local)");
        rows.push(vec![
            w.to_string(),
            format!("{local_ms:.2}"),
            format!("{dist_ms:.2}"),
            format!("{x:.2}"),
            out.evaluated.to_string(),
            out.bounded.to_string(),
        ]);
        let _ = std::fs::remove_dir_all(&cas_dir);
    }
    cx.count("dist", "outcomes_identical", checked, "scans");
    write_csv(
        &setup::results_csv("perf_dist"),
        &["workers", "local_ms", "dist_ms", "x_vs_local", "evaluated", "bounded"],
        &rows,
    )?;
    println!("\n{}", engine.stats_table());
    Ok(())
}
