//! Figure 7: ReLU distribution across layers — the original network, SNL at
//! B_ref, SNL at B_target, and Ours (BCD) at B_target.
//!
//! Shape criterion: ours tracks the SNL-reference distribution shape;
//! deeper layers lose proportionally more ReLUs.

use crate::bench::{setup, BenchCtx};
use crate::metrics::{print_table, write_csv};
use crate::pipeline::Pipeline;
use anyhow::{ensure, Result};

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    let exp = setup::experiment("synth100", "resnet", false);
    let pl = Pipeline::new(engine, exp)?;
    let info = pl.sess.info();
    let total = info.total_relus();

    let target = setup::scale_budget(15e3, total, "resnet", 16);
    let bref = (2 * target).min(total);

    let snl_ref = pl.snl_ref(bref)?;
    let snl_tgt = pl.snl_ref(target)?;
    let ours = pl.bcd_cached(&snl_ref, target)?;

    let h_orig: Vec<usize> = info.mask_layers.iter().map(|e| e.size).collect();
    let h_ref = snl_ref.mask.layer_histogram(info);
    let h_tgt = snl_tgt.mask.layer_histogram(info);
    let h_ours = ours.mask.layer_histogram(info);

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    for (l, e) in info.mask_layers.iter().enumerate() {
        rows.push(vec![
            l.to_string(),
            e.name.clone(),
            h_orig[l].to_string(),
            h_ref[l].to_string(),
            h_tgt[l].to_string(),
            h_ours[l].to_string(),
        ]);
        csv.push(vec![
            l.to_string(),
            e.name.clone(),
            h_orig[l].to_string(),
            h_ref[l].to_string(),
            h_tgt[l].to_string(),
            h_ours[l].to_string(),
        ]);
    }
    print_table(
        &format!("Figure 7 — ReLUs kept per layer (orig / SNL@{bref} / SNL@{target} / Ours@{target})"),
        &["#", "layer", "orig", "snl_ref", "snl_tgt", "ours"],
        &rows,
    );
    write_csv(
        &setup::results_csv("fig7"),
        &["layer_idx", "layer", "orig", "snl_ref", "snl_tgt", "ours"],
        &csv,
    )?;

    // Shape: ours ends exactly on budget and correlates with the SNL-ref
    // distribution (rank correlation proxy: top-quartile overlap).
    let ours_total: usize = h_ours.iter().sum();
    ensure!(ours_total == target, "ours ended at {ours_total} ReLUs, target {target}");
    cx.count("shape", "ours_budget", ours_total, "relus");
    let top = |h: &[usize]| {
        let mut idx: Vec<usize> = (0..h.len()).collect();
        idx.sort_by_key(|&i| std::cmp::Reverse(h[i]));
        idx.truncate((h.len() / 4).max(1));
        idx.into_iter().collect::<std::collections::HashSet<_>>()
    };
    let overlap = top(&h_ours).intersection(&top(&h_ref)).count();
    cx.stat("shape", "top_quartile_overlap", overlap as f64, "layers");
    println!(
        "\nshape: ours top-quartile layers overlap SNL-ref top-quartile in {overlap}/{} slots",
        (info.mask_layers.len() / 4).max(1)
    );
    Ok(())
}
