//! Table 3: Test accuracy vs ReLU budget for the ResNet18-analog backbone,
//! SNL vs Ours (BCD), on all three datasets.
//!
//! Paper budgets (50K-300K for CIFAR, 200K-488.8K for TinyImageNet) are
//! scaled by the backbone ReLU ratio; quick mode keeps the first points of
//! each grid. Shape criterion: Ours >= SNL on every budget.

use crate::bench::{setup, BenchCtx};
use crate::runtime::Backend;
use anyhow::Result;

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    let mut all = Vec::new();
    // (dataset, paper budgets [#K], quick points)
    let grids: &[(&str, &[f64], usize)] = &[
        ("synth10", &[50e3, 240e3, 300e3], 2),
        ("synth100", &[50e3, 120e3, 150e3, 180e3], 2),
        ("synthtiny", &[200e3, 250e3, 488.8e3], 1),
    ];
    for (dataset, paper_budgets, quick_n) in grids {
        // Alias-resolving lookup: "resnet" model keys are deprecated
        // aliases of the renamed mlp_* stand-ins (DESIGN.md §12).
        let info = engine.model(&setup::experiment(dataset, "resnet", false).model_key())?;
        let total = info.mask_size;
        let size = info.image_size;
        let budgets: Vec<usize> = setup::grid(paper_budgets, *quick_n)
            .iter()
            .map(|&b| setup::scale_budget(b, total, "resnet", size))
            .collect();
        all.extend(setup::snl_vs_ours(engine, dataset, "resnet", &budgets)?);
    }
    for p in &all {
        let case = format!("{}/b{}", p.dataset, p.budget);
        cx.stat(&case, "snl_acc", p.snl_acc, "%");
        cx.stat(&case, "ours_acc", p.ours_acc, "%");
    }
    setup::report_snl_vs_ours(
        "table3",
        "Table 3 — Test Accuracy [%] vs ReLU Budget, ResNet18 backbone",
        &all,
    )
}
