//! Smoke tier: the CI gate benchmark (seconds, reference backend).
//!
//! Seven case groups:
//!
//! 1. **Structural manifest contract** — per-model ReLU pool sizes,
//!    parameter-vector lengths and mask-layer counts, plus the model count
//!    and batch size. These are `count` metrics: exact, host-independent,
//!    and the substance of the committed `BENCH_smoke.json` baseline — a
//!    model-shape drift fails `cdnl bench compare --gate` until the
//!    baseline is deliberately re-blessed.
//! 2. **Hot-path micro timings** — mask upload, host/buffer `eval_batch`,
//!    and a small trial scan. `time_ms` metrics gate only against a
//!    same-host baseline (DESIGN.md §9); across hosts they are advisory.
//! 3. **Method-registry contract** — a tiny run of *every* registered
//!    method, plus one `snl+bcd` chain, dispatched through the
//!    [`crate::methods::Method`] trait (DESIGN.md §10). The registry size
//!    and each run's exact
//!    landing budget ride as `count` metrics in the committed baseline, so
//!    a method that stops registering (or stops landing exactly) fails CI
//!    until deliberately re-blessed.
//! 4. **Batched-scoring contract** (DESIGN.md §11) — hand-built hypothesis
//!    slabs driven straight through [`Evaluator::eval_trial_slab`] with a
//!    zero floor, so the slab/route/call tallies are pure grouping
//!    arithmetic — exact, float-independent `count` metrics (the early-exit
//!    bound can never fire at floor 0). A grouping or routing regression
//!    changes a count and fails the gate until re-blessed; per-delta
//!    results are also checked against the single-trial path here, with
//!    `verify_staged` cross-checking every batched score against its own
//!    full forward.
//! 5. **Conv staged-execution contract** (DESIGN.md §12) — the smallest
//!    conv topology (`resnet18_16x16_c10`): segment count, one scan
//!    iteration (timing + evaluated stat), and the same slab grouping
//!    arithmetic as group 4 driven across residual-block boundaries, so
//!    the multi-segment staged route has its own exact `count` gate.
//! 6. **Conv-lowering contract** (DESIGN.md §13) — the GEMM-lowered conv
//!    kernels' float-independent tallies. Kernel-level calls (one bitwise
//!    ensure against the retained direct loop) pin the im2col call/byte
//!    arithmetic and the scratch-arena hit count; a staged and a full conv
//!    slab re-driven through group 5's evaluator pin the slab-wide
//!    patch-reuse counter, read back as a delta of the backend's
//!    `conv_lowering:slab_patch_reuse` stat.
//! 7. **Distributed lease/merge + CAS contract** (DESIGN.md §15) — the
//!    dist coordinator's lease protocol driven on a pinned clock (a full
//!    claim / kill / re-issue / duplicate-completion schedule with no
//!    sockets, threads or wall time), the sequential replay merge over the
//!    recorded results, and the content-addressed store's put / duplicate /
//!    tamper / gc arithmetic. Every metric is an exact `count`: a protocol
//!    or digest regression fails the gate until deliberately re-blessed.

use crate::bench::BenchCtx;
use crate::cas::{digest_hex, CasStore};
use crate::coordinator::eval::{EvalOpts, Evaluator, TrialEval};
use crate::coordinator::trials::{replay_merge, scan_trials, BlockSampler};
use crate::dist::LeasedScan;
use crate::data::synth;
use crate::model::MaskDelta;
use crate::methods::registry::{self, ChainSpec, Method, MethodCtx, RecordSink};
use crate::runtime::kernels::conv2d_same_direct_into;
use crate::runtime::lowering;
use crate::runtime::session::Session;
use crate::runtime::Backend;
use crate::util::bench::time;
use crate::util::prng::Rng;
use anyhow::{ensure, Result};

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;

    // --- 1: structural manifest contract ------------------------------------
    let manifest = engine.manifest();
    cx.count("manifest", "models", manifest.models.len(), "models");
    cx.count("manifest", "batch", manifest.batch, "examples");
    for (key, m) in &manifest.models {
        cx.count(key, "mask_size", m.mask_size, "relus");
        cx.count(key, "param_size", m.param_size, "params");
        cx.count(key, "mask_layers", m.mask_layers.len(), "layers");
    }
    println!(
        "manifest: {} models, batch {}",
        manifest.models.len(),
        manifest.batch
    );

    // --- 2: hot-path micro timings -------------------------------------------
    let sess = Session::new(engine, "resnet_16x16_c10")?;
    let (train_ds, _) = synth::generate(synth::by_name("synth10").unwrap());
    let st = sess.init_state(1)?;
    let info = sess.info().clone();
    let (iters, warmup) = if cx.full { (20, 4) } else { (8, 2) };

    let mask = vec![1.0f32; info.mask_size];
    let r = time("upload_mask", warmup, iters, || {
        let _ = sess.upload_f32(&mask, &[mask.len()]).unwrap();
    });
    cx.time_ms("hotpath", "upload_mask", &r.samples_ms);

    let (x, y) = train_ds.batch_at(0, sess.batch);
    let r = time("eval_batch_host", warmup, iters, || {
        let _ = sess.eval_batch(&st.params, &mask, &x, &y).unwrap();
    });
    cx.time_ms("hotpath", "eval_batch_host", &r.samples_ms);

    let pbuf = sess.upload_f32(&st.params.data, &st.params.shape)?;
    let mbuf = sess.upload_f32(&mask, &[mask.len()])?;
    let (xbuf, ybuf) = sess.upload_batch(&x, &y)?;
    let r = time("eval_batch_buffer", warmup, iters, || {
        let _ = sess.eval_batch_b(&pbuf, &mbuf, &xbuf, &ybuf).unwrap();
    });
    cx.time_ms("hotpath", "eval_batch_buffer", &r.samples_ms);

    // A small trial scan: wall time rides as a timing metric. The
    // evaluated tally is deterministic for a fixed seed *within one
    // configuration* — the early-exit bound depends on float accuracies —
    // so it rides as a config-scoped `stat`, not a structural `count`
    // (counts gate across config/backend boundaries; this must not).
    let ev = Evaluator::new(&sess, &train_ds, 2)?;
    let params = ev.upload_params(&st.params)?;
    let base = ev.accuracy(&params, st.mask.dense())?;
    cx.stat("hotpath", "base_acc", base, "%");
    let sampler = BlockSampler::new(crate::config::Granularity::Pixel, sess.info());
    let drc = (info.mask_size / 20).max(1);
    let mut rng = Rng::new(7);
    let t0 = std::time::Instant::now();
    let scan = scan_trials(&ev, &params, &st.mask, &sampler, drc, 8, -1e9, base, &mut rng, 1)?;
    cx.time_ms("hotpath", "trial_scan_x8", &[1000.0 * t0.elapsed().as_secs_f64()]);
    cx.stat("hotpath", "scan_evaluated", scan.evaluated as f64, "trials");
    println!(
        "smoke: base acc {base:.2}%, scan evaluated {} ({} bounded)",
        scan.evaluated, scan.bounded
    );

    // --- 3: the method registry, one tiny run per method ---------------------
    // Tiny schedules keep every run sub-second; every method lands on its
    // target budget *exactly* by construction, so the landings are exact
    // `count` contracts, not tolerance-band stats. drc=64 == the removal
    // below, so the BCD run is exactly one sweep.
    let exp = crate::bench::setup::tiny_method_experiment(64);

    let reg = registry::registry();
    cx.count("methods", "registered", reg.len(), "methods");
    // AutoReP runs on the poly variant; everything else on the plain model.
    let sess_poly = Session::new(engine, "resnet_16x16_c10_poly")?;
    let total = sess.info().total_relus();
    let target = total - 64;
    let sink = RecordSink::default();
    let t0 = std::time::Instant::now();
    for m in reg {
        let s: &Session = if m.name() == "autorep" { &sess_poly } else { &sess };
        let mut mst = s.init_state(11)?;
        let ctx = MethodCtx::new(s, &train_ds, &exp, &sink);
        let out = m.run(&ctx, &mut mst, target)?;
        ensure!(
            out.method() == m.name(),
            "outcome tag {} from method {}",
            out.method(),
            m.name()
        );
        cx.count("methods", &format!("{}_final", m.name()), mst.budget(), "relus");
        println!("smoke method {}", out.describe());
    }
    // One chain through ChainSpec: two stages, two provenance records.
    let chain = ChainSpec::parse("snl+bcd")?;
    let mut mst = sess.init_state(11)?;
    let ctx = MethodCtx::new(&sess, &train_ds, &exp, &sink);
    let before_records = sink.lock().unwrap().len();
    let outs = chain.run(&ctx, &mut mst, &[total - 40, total - 64])?;
    cx.count("methods", "chain_final", mst.budget(), "relus");
    cx.count("methods", "chain_stages", outs.len(), "stages");
    cx.count(
        "methods",
        "chain_records",
        sink.lock().unwrap().len() - before_records,
        "records",
    );
    cx.time_ms("methods", "tiny_runs_all", &[1000.0 * t0.elapsed().as_secs_f64()]);
    println!("smoke: {} methods + snl+bcd chain ran through the registry", reg.len());

    // --- 4: batched-scoring contract (DESIGN.md §11) -------------------------
    // Slab width 4 against hand-built single-index deltas: 2 all-staged
    // slabs, 1 all-full slab, and 1 mixed call that must split into one
    // staged + one full slab. At floor 0 the bound never fires, so with 2
    // eval batches every expected tally is exact grouping arithmetic:
    //   slabs = 2 + 1 + 2                           = 5
    //   staged_trials = 4 + 4 + 2                   = 10
    //   full_trials = 4 + 2                         = 6
    //   multi_calls = 5 slabs x 2 batches           = 10
    //   width_sum = 3 width-4 slabs x 8 + 2 x 4     = 32
    let ev_b = Evaluator::with_opts(
        &sess,
        &train_ds,
        2,
        EvalOpts {
            cache_bytes: 16 << 20,
            trial_batch: 4,
            verify_staged: true,
            verify_lowering: true,
        },
    )?;
    ensure!(ev_b.slab_width() == 4, "reference backend must accept slab width 4");
    ensure!(ev_b.num_batches() == 2, "count derivation assumes 2 eval batches");
    ev_b.begin_iteration(&st.mask)?;
    let l1 = info.mask_layers[1].offset;
    let staged_deltas: Vec<MaskDelta> =
        (0..8).map(|j| MaskDelta::new(vec![l1 + j])).collect();
    let full_deltas: Vec<MaskDelta> = (0..4).map(|j| MaskDelta::new(vec![j])).collect();
    let mixed_deltas: Vec<MaskDelta> =
        [l1 + 20, l1 + 21, 20, 21].map(|i| MaskDelta::new(vec![i])).into();
    let mut scratch: Vec<f32> = Vec::new();
    for slab in [
        &staged_deltas[..4],
        &staged_deltas[4..],
        &full_deltas[..],
        &mixed_deltas[..],
    ] {
        let evals = ev_b.eval_trial_slab(&params, &st.mask, slab, 0.0, &mut scratch)?;
        for (d, got) in slab.iter().zip(&evals) {
            let single = ev_b.eval_trial_delta(&params, &st.mask, d, 0.0, &mut scratch)?;
            ensure!(
                *got == single,
                "slab result diverged from single-trial path for delta {:?}",
                d.indices()
            );
        }
    }
    let (slabs, staged_trials, full_trials, multi_calls, width_sum) = ev_b.batch_counters();
    cx.count("scan_batched", "slabs", slabs as usize, "slabs");
    cx.count("scan_batched", "staged_trials", staged_trials as usize, "trials");
    cx.count("scan_batched", "full_trials", full_trials as usize, "trials");
    cx.count("scan_batched", "multi_calls", multi_calls as usize, "calls");
    cx.count("scan_batched", "width_sum", width_sum as usize, "hyps");
    ev_b.flush_cache_stats();
    println!(
        "smoke batched: {slabs} slabs ({staged_trials} staged + {full_trials} full), \
         {multi_calls} multi calls, width sum {width_sum}"
    );

    // --- 5: conv staged-execution contract (DESIGN.md §12) -------------------
    // The smallest conv topology: structural segment count, one small scan
    // (timing + evaluated stat, like group 2), and group-4's slab grouping
    // arithmetic across residual-block boundaries:
    //   1 staged slab of 4 + 1 full slab of 4 + 1 mixed call split 2+2:
    //   slabs = 1 + 1 + 2                           = 4
    //   staged_trials = 4 + 2                       = 6
    //   full_trials = 4 + 2                         = 6
    //   multi_calls = 4 slabs x 2 batches           = 8
    //   width_sum = (4 + 4 + 2 + 2) x 2 batches     = 24
    let conv = Session::new(engine, "resnet18_16x16_c10")?;
    let cinfo = conv.info().clone();
    cx.count("conv_staged", "segments", engine.segments(&conv.key), "segments");
    let cst = conv.init_state(1)?;
    let ev_c = Evaluator::new(&conv, &train_ds, 2)?;
    let cparams = ev_c.upload_params(&cst.params)?;
    let cbase = ev_c.accuracy(&cparams, cst.mask.dense())?;
    cx.stat("conv_staged", "base_acc", cbase, "%");
    let csampler = BlockSampler::new(crate::config::Granularity::Pixel, conv.info());
    let cdrc = (cinfo.mask_size / 20).max(1);
    let mut crng = Rng::new(7);
    let t0 = std::time::Instant::now();
    let cscan =
        scan_trials(&ev_c, &cparams, &cst.mask, &csampler, cdrc, 6, -1e9, cbase, &mut crng, 1)?;
    cx.time_ms("conv_staged", "trial_scan_x6", &[1000.0 * t0.elapsed().as_secs_f64()]);
    cx.stat("conv_staged", "scan_evaluated", cscan.evaluated as f64, "trials");

    let ev_cb = Evaluator::with_opts(
        &conv,
        &train_ds,
        2,
        EvalOpts {
            cache_bytes: 16 << 20,
            trial_batch: 4,
            verify_staged: true,
            verify_lowering: true,
        },
    )?;
    ensure!(ev_cb.slab_width() == 4, "conv model must accept slab width 4");
    ensure!(ev_cb.num_batches() == 2, "conv count derivation assumes 2 eval batches");
    ev_cb.begin_iteration(&cst.mask)?;
    // Deep per-channel deltas (mask layer 9, past several block boundaries)
    // route staged; layer-0 (stem) deltas force full forwards.
    let deep = cinfo.mask_layers[9].offset;
    let cstaged: Vec<MaskDelta> = (0..4).map(|j| MaskDelta::new(vec![deep + j])).collect();
    let cfull: Vec<MaskDelta> = (0..4).map(|j| MaskDelta::new(vec![j])).collect();
    let cmixed: Vec<MaskDelta> =
        [deep + 4, deep + 5, 4, 5].map(|i| MaskDelta::new(vec![i])).into();
    for slab in [&cstaged[..], &cfull[..], &cmixed[..]] {
        let evals = ev_cb.eval_trial_slab(&cparams, &cst.mask, slab, 0.0, &mut scratch)?;
        for (d, got) in slab.iter().zip(&evals) {
            let single = ev_cb.eval_trial_delta(&cparams, &cst.mask, d, 0.0, &mut scratch)?;
            ensure!(
                *got == single,
                "conv slab result diverged from single-trial path for delta {:?}",
                d.indices()
            );
        }
    }
    let (cslabs, cstaged_n, cfull_n, cmulti, cwidth) = ev_cb.batch_counters();
    cx.count("conv_staged", "slabs", cslabs as usize, "slabs");
    cx.count("conv_staged", "staged_trials", cstaged_n as usize, "trials");
    cx.count("conv_staged", "full_trials", cfull_n as usize, "trials");
    cx.count("conv_staged", "multi_calls", cmulti as usize, "calls");
    cx.count("conv_staged", "width_sum", cwidth as usize, "hyps");
    ev_cb.flush_cache_stats();
    println!(
        "smoke conv: {} segments, base acc {cbase:.2}%, {cslabs} slabs \
         ({cstaged_n} staged + {cfull_n} full)",
        engine.segments(&conv.key)
    );

    // --- 6: conv-lowering contract (DESIGN.md §13) ---------------------------
    // Kernel-level tally arithmetic. The lowering is called directly (not
    // through the verify dispatch), so the debug-build oracle cross-check
    // cannot move the counts — they are exact in every build. Shapes
    // (n=2, cin=3, 8x8, cout=4, k=3, s=1), so oh*ow = 64 and the patch
    // matrices are 27x64 (forward, 1728 floats) and 36x64 (dinput, 2304):
    //   im2col_calls  = (2 fwd + 1 dinput + 1 dweight) x 2 images   = 8
    //   im2col_bytes  = 4 x (1728*2*3 + 2304*2)                     = 59904
    //   scratch_hits  = fwd2 pt + dinput wflip + dweight acc & pt   = 4
    let _ = lowering::drain_tallies(); // isolate this case's counters
    let mut lsc = lowering::Scratch::new();
    let (ln, lcin, lh, lwd, lcout, lk) = (2usize, 3usize, 8usize, 8usize, 4usize, 3usize);
    let mut lrng = Rng::new(0xC0DE);
    let lx: Vec<f32> = (0..ln * lcin * lh * lwd).map(|_| lrng.normal()).collect();
    let lwt: Vec<f32> = (0..lcout * lcin * lk * lk).map(|_| lrng.normal()).collect();
    let mut ly = Vec::new();
    lowering::conv2d_lowered_into(&lx, &lwt, ln, lcin, lh, lwd, lcout, lk, 1, &mut ly, &mut lsc);
    let mut lwant = Vec::new();
    conv2d_same_direct_into(&lx, &lwt, ln, lcin, lh, lwd, lcout, lk, 1, &mut lwant);
    ensure!(ly == lwant, "lowered conv forward diverged bitwise from the direct loop");
    lowering::conv2d_lowered_into(&lx, &lwt, ln, lcin, lh, lwd, lcout, lk, 1, &mut ly, &mut lsc);
    let ldy = ly.clone();
    let _ldx = lowering::conv2d_lowered_dinput(&ldy, &lwt, ln, lcin, lh, lwd, lcout, lk, 1, &mut lsc);
    let mut ldw = vec![0.0f32; lwt.len()];
    lowering::conv2d_lowered_dweight(&lx, &ldy, &mut ldw, ln, lcin, lh, lwd, lcout, lk, 1, &mut lsc);
    let lt = lowering::drain_tallies();
    cx.count("conv_lowered", "im2col_calls", lt.im2col_calls as usize, "calls");
    cx.count("conv_lowered", "im2col_bytes", lt.im2col_bytes as usize, "bytes");
    cx.count("conv_lowered", "scratch_hits", lt.scratch_hits as usize, "takes");

    // Backend-level: re-drive one staged and one full width-4 slab from
    // group 5 and read the slab-wide patch-reuse counter back as a stats
    // delta. Each slab shares its prologue (stem conv / resumed block)
    // across every live hypothesis but the first:
    //   staged slab of 4: 2 batches x (4 - 1) = 6
    //   full   slab of 4: 2 batches x (4 - 1) = 6     => 12
    let reuse0 =
        engine.stats().get("conv_lowering:slab_patch_reuse").map_or(0, |s| s.calls);
    for slab in [&cstaged[..], &cfull[..]] {
        let _ = ev_cb.eval_trial_slab(&cparams, &cst.mask, slab, 0.0, &mut scratch)?;
    }
    let reuse =
        engine.stats().get("conv_lowering:slab_patch_reuse").map_or(0, |s| s.calls) - reuse0;
    cx.count("conv_lowered", "slab_patch_reuse", reuse as usize, "hyps");
    println!(
        "smoke conv lowering: {} im2col calls ({} bytes), {} scratch hits, \
         {reuse} slab-reused hyps",
        lt.im2col_calls, lt.im2col_bytes, lt.scratch_hits
    );

    // --- 7: distributed lease/merge + CAS contract (DESIGN.md §15) -----------
    // The dist protocol on a pinned clock: no sockets, no threads, no wall
    // time, so every counter is exact by construction. 10 trials, slabs of
    // 4, 100 ms leases, base 80.0 / adt 0.5:
    //   a, b, c claim (0,4) (4,4) (8,2) at t=0; b completes 4..8; at t=200
    //   the surviving leases (0 and 8) are both expired and b's re-claim
    //   re-issues the lowest start (0,4); c posts 8..10 with an accept at
    //   index 9 (dacc 0.2 < adt); b posts 0..4 (one runtime bound); the
    //   presumed-dead a posts 0..4 late — ignored, first write wins.
    //   claims_issued = 3 fresh + 1 re-issue          = 4
    //   leases_reissued                               = 1
    //   duplicate_completions                         = 1
    //   completed_slabs                               = 3
    // The replay merge then walks all 10 recorded trials (the Bounded one
    // included) and early-accepts at index 9: evaluated 10, bounded 1.
    let sc = |acc: f64| TrialEval::Scored { acc, batch_corrects: vec![acc] };
    let mut ls = LeasedScan::new(10, 80.0, 0.5, 100);
    let ga = ls.claim("a", 4, 0).expect("slab 0..4");
    let gb = ls.claim("b", 4, 0).expect("slab 4..8");
    let gtail = ls.claim("c", 4, 0).expect("slab 8..10");
    ensure!(
        [(ga.start, ga.len), (gb.start, gb.len), (gtail.start, gtail.len)]
            == [(0, 4), (4, 4), (8, 2)],
        "in-order slab grants moved"
    );
    ensure!(!ls.complete(4, vec![sc(70.0), sc(71.0), sc(72.0), sc(73.0)]));
    let rg = ls.claim("b", 4, 200).expect("re-issue of expired 0..4");
    ensure!((rg.start, rg.len) == (0, 4), "expired re-issue must be lowest start first");
    ensure!(!ls.complete(8, vec![sc(74.0), sc(79.8)]));
    ensure!(!ls.complete(0, vec![sc(75.0), TrialEval::Bounded, sc(76.0), sc(77.0)]));
    ensure!(
        ls.complete(0, vec![sc(1.0), sc(2.0), sc(3.0), sc(4.0)]),
        "zombie completion must be flagged duplicate"
    );
    ensure!(ls.done(), "all slabs completed, no lease outstanding");
    let lstats = ls.stats().clone();
    cx.count("dist", "claims_issued", lstats.claims_issued, "claims");
    cx.count("dist", "leases_reissued", lstats.leases_reissued, "leases");
    cx.count("dist", "duplicate_completions", lstats.duplicate_completions, "posts");
    cx.count("dist", "completed_slabs", lstats.completed_slabs, "slabs");
    let (results, _) = ls.into_results();
    let hyps: Vec<MaskDelta> = (0..10).map(|i| MaskDelta::new(vec![i])).collect();
    let merged = replay_merge(&hyps, results, 80.0, 0.5, |_, _| false);
    cx.count("dist", "merge_evaluated", merged.evaluated, "trials");
    cx.count("dist", "merge_bounded", merged.bounded, "trials");
    cx.count("dist", "merge_early_accept", merged.early_accept as usize, "accepts");
    cx.count("dist", "merge_chosen_idx", merged.chosen.removed[0], "index");

    // CAS arithmetic: two distinct blobs plus one duplicate put, a
    // tamper-then-read rejection, and a gc pass with one live digest.
    let cas_dir =
        std::env::temp_dir().join(format!("cdnl_smoke_cas_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&cas_dir);
    let cas = CasStore::open(&cas_dir);
    let p1 = cas.put_bytes(b"smoke blob one")?;
    let p2 = cas.put_bytes(b"smoke blob two")?;
    let dup = cas.put_bytes(b"smoke blob one")?;
    ensure!(p1.digest == digest_hex(b"smoke blob one"), "put digest != one-shot hash");
    cx.count("dist", "cas_objects", cas.list()?.len(), "blobs");
    cx.count(
        "dist",
        "cas_dup_puts",
        (dup.existed && !p1.existed && !p2.existed) as usize,
        "puts",
    );
    // Flip one byte behind the store's back: the read-side digest check
    // must reject the object (layout: objects/<digest[..2]>/<digest>).
    let obj = cas_dir.join("objects").join(&p2.digest[..2]).join(&p2.digest);
    let mut corrupt = std::fs::read(&obj)?;
    corrupt[0] ^= 0x01;
    std::fs::write(&obj, &corrupt)?;
    cx.count("dist", "cas_tamper_rejects", cas.get(&p2.digest).is_err() as usize, "reads");
    let live: std::collections::BTreeSet<String> =
        [p1.digest.clone()].into_iter().collect();
    cx.count("dist", "cas_gc_removed", cas.gc(&live, false)?.len(), "blobs");
    ensure!(cas.contains(&p1.digest), "live blob must survive gc");
    let _ = std::fs::remove_dir_all(&cas_dir);
    println!(
        "smoke dist: {} claims ({} re-issued, {} duplicate), merge {} evaluated / \
         {} bounded, accept idx {}",
        lstats.claims_issued,
        lstats.leases_reissued,
        lstats.duplicate_completions,
        merged.evaluated,
        merged.bounded,
        merged.chosen.removed[0]
    );
    Ok(())
}
