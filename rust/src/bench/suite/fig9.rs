//! Figure 9 (supplementary): SNL accuracy vs the λ-correction factor κ,
//! for two run configurations — from the full network down to the
//! 15K-analog, and from an SNL 30K-analog reference down to the same
//! target. Overlaid: BCD from the same 30K-analog reference.
//!
//! Shape criteria: lower κ helps SNL slightly (~0.5% in the paper); BCD
//! from the reference beats both (paper: +2%).

use crate::bench::{setup, BenchCtx};
use crate::methods::snl::run_snl;
use crate::metrics::{ascii_plot, print_table, write_csv, Series};
use crate::pipeline::Pipeline;
use anyhow::Result;

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    let exp = setup::experiment("synth100", "resnet", false);
    let pl = Pipeline::new(engine, exp)?;
    let total = pl.sess.info().total_relus();
    let target = setup::scale_budget(15e3, total, "resnet", 16);
    let bref = (2 * target).min(total);

    let kappas: Vec<f32> = setup::grid(&[1.05, 1.2, 1.5, 2.0], 2);
    let reference = pl.snl_ref(bref)?;
    let baseline = pl.baseline()?;

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut s_full = Series::new("snl from full", vec![]);
    let mut s_ref = Series::new("snl from 30K-analog", vec![]);
    for &kappa in &kappas {
        let mut cfg = pl.exp.snl.clone();
        cfg.kappa = kappa;
        // From the full network.
        let mut st_a = baseline.clone();
        run_snl(&pl.sess, &mut st_a, &pl.train_ds, target, &cfg, 0)?;
        let acc_full = pl.test_acc(&st_a)?;
        // From the SNL reference.
        let mut st_b = reference.clone();
        run_snl(&pl.sess, &mut st_b, &pl.train_ds, target, &cfg, 0)?;
        let acc_ref = pl.test_acc(&st_b)?;
        println!("[kappa={kappa}] from-full {acc_full:.2}%  from-ref {acc_ref:.2}%");
        let case = format!("kappa{kappa}");
        cx.stat(&case, "snl_from_full", acc_full, "%");
        cx.stat(&case, "snl_from_ref", acc_ref, "%");
        s_full.points.push((kappa as f64, acc_full));
        s_ref.points.push((kappa as f64, acc_ref));
        rows.push(vec![
            format!("{kappa}"),
            format!("{acc_full:.2}"),
            format!("{acc_ref:.2}"),
        ]);
        csv.push(vec![
            format!("{kappa}"),
            format!("{acc_full:.3}"),
            format!("{acc_ref:.3}"),
        ]);
    }

    // BCD overlay from the same reference (κ-independent).
    let ours = pl.bcd_cached(&reference, target)?;
    let bcd_acc = pl.test_acc(&ours)?;
    cx.stat("bcd", "from_ref", bcd_acc, "%");
    println!("[bcd] from-ref {bcd_acc:.2}% (kappa-independent)");

    println!(
        "\n{}",
        ascii_plot(
            &format!("Fig. 9 — SNL acc vs kappa at budget {target} (BCD: {bcd_acc:.2}%)"),
            &[s_full.clone(), s_ref.clone()],
            50,
            10
        )
    );
    print_table(
        "Figure 9 — Accuracy vs kappa (synth100 / ResNet18)",
        &["kappa", "snl_from_full", "snl_from_ref"],
        &rows,
    );
    csv.push(vec!["bcd".into(), format!("{bcd_acc:.3}"), format!("{bcd_acc:.3}")]);
    write_csv(
        &setup::results_csv("fig9"),
        &["kappa", "snl_from_full", "snl_from_ref"],
        &csv,
    )?;

    let best_snl = s_full
        .points
        .iter()
        .chain(&s_ref.points)
        .map(|p| p.1)
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "\nshape: BCD {bcd_acc:.2}% vs best SNL {best_snl:.2}% ({})",
        if bcd_acc >= best_snl { "BCD wins — matches paper" } else { "gap" }
    );
    Ok(())
}
