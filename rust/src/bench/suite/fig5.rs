//! Figure 5: hyperparameter ablations on the ResNet18 / CIFAR-100-analog
//! setting — (a) DRC, (b) finetune epochs, (c) ADT.
//!
//! Shape criteria: accuracy decreases as DRC increases (fewer CD iterations,
//! Eq. 3/6); accuracy increases (saturating) with finetune steps; ADT is
//! roughly flat.

use crate::bench::{setup, BenchCtx};
use crate::config::Experiment;
use crate::metrics::{ascii_plot, print_table, write_csv, Series};
use crate::pipeline::Pipeline;
use anyhow::Result;

pub fn run(cx: &mut BenchCtx) -> Result<()> {
    let engine = cx.engine;
    let exp = setup::experiment("synth100", "resnet", false);
    let pl = Pipeline::new(engine, exp)?;
    let total = pl.sess.info().total_relus();

    // Paper setting: B_ref = 30K, B_target = 15K (of 570K) => scaled ~2x.
    let target = setup::scale_budget(15e3, total, "resnet", 16).max(200);
    let bref = (2 * target).min(total);
    let reference = pl.snl_ref(bref)?;
    println!("ablation base: B_ref={bref} -> B_target={target}");

    let drcs: Vec<usize> = setup::grid(&[50, 100, 200, 400], 2);
    let fts: Vec<usize> = setup::grid(&[2, 8, 16, 32], 2);
    let adts: Vec<f64> = setup::grid(&[0.1, 0.3, 1.0, 3.0], 2);

    let mut rows = Vec::new();
    let mut csv = Vec::new();
    let mut run_one = |knob: &str, value: String, exp2: Experiment| -> Result<f64> {
        let pl2 = Pipeline::new(engine, exp2)?;
        let (st, out) = pl2.bcd_from(&reference, target)?;
        let acc = pl2.test_acc(&st)?;
        println!("[{knob}={value}] acc {acc:.2}%  ({} iters, {} trials)", out.iterations.len(), out.total_trials());
        rows.push(vec![knob.to_string(), value.clone(), format!("{acc:.2}")]);
        csv.push(vec![knob.to_string(), value, format!("{acc:.3}")]);
        Ok(acc)
    };

    // (a) DRC sweep.
    let mut s_drc = Series::new("acc vs DRC", vec![]);
    for &drc in &drcs {
        let mut e = setup::experiment("synth100", "resnet", false);
        e.bcd.drc = drc;
        let acc = run_one("drc", drc.to_string(), e)?;
        s_drc.points.push((drc as f64, acc));
    }
    // (b) finetune steps sweep.
    let mut s_ft = Series::new("acc vs finetune steps", vec![]);
    for &ft in &fts {
        let mut e = setup::experiment("synth100", "resnet", false);
        e.bcd.finetune_steps = ft;
        let acc = run_one("finetune_steps", ft.to_string(), e)?;
        s_ft.points.push((ft as f64, acc));
    }
    // (c) ADT sweep.
    let mut s_adt = Series::new("acc vs ADT", vec![]);
    for &adt in &adts {
        let mut e = setup::experiment("synth100", "resnet", false);
        e.bcd.adt = adt;
        let acc = run_one("adt", format!("{adt}"), e)?;
        s_adt.points.push((adt, acc));
    }
    for s in [&s_drc, &s_ft, &s_adt] {
        for &(x, acc) in &s.points {
            // Series label doubles as the case name; knob value keys the metric.
            let knob = match s.label.as_str() {
                "acc vs DRC" => "drc",
                "acc vs finetune steps" => "finetune_steps",
                _ => "adt",
            };
            cx.stat(knob, &format!("acc@{x}"), acc, "%");
        }
    }

    for s in [&s_drc, &s_ft, &s_adt] {
        println!("\n{}", ascii_plot(&s.label.clone(), std::slice::from_ref(s), 50, 10));
    }
    print_table(
        "Figure 5 — hyperparameter ablations (synth100 / ResNet18)",
        &["knob", "value", "test_acc"],
        &rows,
    );
    write_csv(&setup::results_csv("fig5"), &["knob", "value", "test_acc"], &csv)?;

    // Shape criteria (soft; report rather than assert in quick mode).
    let inc = |s: &Series| s.points.windows(2).all(|w| w[1].1 >= w[0].1 - 1.0);
    let dec = |s: &Series| s.points.windows(2).all(|w| w[1].1 <= w[0].1 + 1.0);
    println!("\nshape: DRC↑→acc↓ {}; finetune↑→acc↑ {}; ADT flat-ish {}",
        dec(&s_drc), inc(&s_ft),
        s_adt.points.iter().map(|p| p.1).fold(f64::NEG_INFINITY, f64::max)
            - s_adt.points.iter().map(|p| p.1).fold(f64::INFINITY, f64::min) < 5.0
    );
    Ok(())
}
